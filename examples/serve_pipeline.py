"""Serve a model with batched requests: BCPM-placed serving dataflow +
continuous-batching engine.

1. The BCPM mapper places the serving dataflow (frontend -> backbone) onto
   the pod's slice graph at the requested rate (paper technique, §2 analog).
2. A smoke-scale model serves a stream of prompts through the slot-based
   continuous-batching engine (prefill into free slots, lock-step decode).

    PYTHONPATH=src python examples/serve_pipeline.py --arch internvl2-2b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.placement import PodTopology, plan_serving
from repro.models.config import SHAPES
from repro.models.registry import init_model
from repro.serving import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internvl2-2b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--rate", type=float, default=4.0, help="req/s for placement")
    args = ap.parse_args()

    full = get_config(args.arch)
    plan = plan_serving(full, SHAPES["prefill_32k"], PodTopology(pods=1),
                        requests_per_sec=args.rate)
    if plan:
        print(f"[placement] {args.arch} serving dataflow -> slices "
              f"{plan.stage_slices}, route latency {plan.latency_us:.1f}us, "
              f"stage TFLOP/s {[round(x, 1) for x in plan.stage_tflops]}")
    else:
        print(f"[placement] rate {args.rate} req/s infeasible on one pod")

    cfg = get_config(args.arch, smoke=True)
    if cfg.family == "encdec":
        print("(engine demo uses decoder-only families; whisper serves via "
              "launch/serve.py)")
        return
    print(f"[engine] smoke-scale {cfg.name}: {args.requests} requests, "
          f"{args.slots} slots")
    params, _ = init_model(cfg, jax.random.key(0))
    eng = Engine(cfg, params, n_slots=args.slots, max_len=96,
                 temperature=0.8, top_k=20, seed=0)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        L = int(rng.integers(4, 12))
        eng.submit(Request(rid=i, prompt=rng.integers(0, cfg.vocab, L).astype(np.int32),
                           max_new=args.max_new))
    done, ticks = eng.run()
    dt = time.time() - t0
    tok = sum(len(r.out) for r in done)
    print(f"[engine] {len(done)} requests, {tok} tokens in {dt:.1f}s "
          f"({tok/dt:.1f} tok/s, {ticks} ticks)")
    for r in done[:3]:
        print(f"  req {r.rid}: {r.out}")


if __name__ == "__main__":
    main()
