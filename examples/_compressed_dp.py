"""shard_map data-parallel train step with int8 error-feedback gradient
all-reduce (optim/compress.py) — the explicit-collective variant of the
framework's gradient-compression story (8x traffic vs fp32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.launch.steps import BuiltStep
from repro.models.registry import init_model, input_specs, loss_fn
from repro.optim import compress
from repro.optim.adamw import OptConfig, TrainState, apply_updates, init_state


def build_compressed_train_step(cfg, shape, mesh, opt: OptConfig):
    loss = loss_fn(cfg)
    axis = "data"

    def local_loss(params, batch):
        return loss(cfg, params, batch, remat=False)

    @functools.partial(
        jax.shard_map, mesh=mesh,
        in_specs=(P(), P(), jax.tree.map(lambda _: P(axis), input_specs(cfg, shape, masked=True)), P()),
        out_specs=(P(), P(), P()),
        check_vma=False,
    )
    def spmd_grads(params, err, batch, key):
        l, g = jax.value_and_grad(local_loss)(params, batch)
        g, err = compress.compress_psum(g, err, key, axis)
        l = jax.lax.pmean(l, axis)
        return l, g, err

    def train_step(carry, batch):
        state, err, key = carry["state"], carry["err"], carry["key"]
        key, sub = jax.random.split(key)
        params_c = jax.tree.map(lambda t: t, state.params)
        l, grads, err = spmd_grads(params_c, err, batch, sub)
        state, metrics = apply_updates(opt, state, grads)
        return {"state": state, "err": err, "key": key}, dict(metrics, loss=l)

    fn = jax.jit(train_step, donate_argnums=(0,))
    params, _ = init_model(cfg, jax.random.key(0))
    state = init_state(params)
    carry = {
        "state": state,
        "err": compress.init_error_state(params),
        "key": jax.random.PRNGKey(1),  # uint32 form: checkpoint-serializable
    }
    built = BuiltStep(fn=fn, in_shardings=(None,), out_shardings=None,
                      abstract_args=(), meta=dict(kind="train-int8ef"))
    return built, carry


if __name__ == "__main__":
    pass
