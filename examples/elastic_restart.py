"""Fault-tolerance walkthrough: train on 4 devices, inject a failure, lose
half the fleet, restore the checkpoint onto the surviving 2-device mesh and
continue — the checkpoint-restart + elastic-scaling path end to end.

    PYTHONPATH=src python examples/elastic_restart.py
"""
import os

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax
import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import build_train_step, init_train_state
from repro.models.config import ModelConfig, ShapeConfig
from repro.optim.adamw import OptConfig
from repro.runtime.trainer import Trainer, TrainerConfig

CKPT = "/tmp/repro_elastic"


def main():
    cfg = ModelConfig(name="el", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
                      tie_embeddings=True, dtype="float32")
    shape = ShapeConfig("train", "train", seq_len=64, global_batch=8)
    opt = OptConfig(lr=2e-3, warmup_steps=3, total_steps=100)
    data = iter(SyntheticLM(cfg.vocab, shape.seq_len, shape.global_batch, seed=0))

    print(f"phase 1: {jax.device_count()} devices, 4-way data parallel")
    mesh4 = make_local_mesh(4, 1)
    built4 = build_train_step(cfg, shape, mesh4, opt, masked=True)
    tr = Trainer(TrainerConfig(ckpt_dir=CKPT, ckpt_every=5, async_ckpt=False),
                 init_train_state(cfg, built4), built4.fn, data,
                 state_shardings=built4.in_shardings[0])

    def fail_once(step):
        if step == 8 and tr.restarts == 0:
            raise RuntimeError("injected: host 3 heartbeat lost")

    tr.inject_failure = fail_once
    tr.run(12)
    print(f"  events: {[e['kind'] for e in tr.events]}")
    print(f"  loss trace: {[round(m['loss'], 3) for m in tr.metrics_log[-5:]]}")

    print("phase 2: elastic restart on 2 surviving devices")
    mesh2 = make_local_mesh(2, 1)
    built2 = build_train_step(cfg, shape, mesh2, opt, masked=True)
    state, step = ckpt.restore(CKPT, jax.tree.map(np.asarray, tr.state),
                               sharding_tree=built2.in_shardings[0])
    tr2 = Trainer(TrainerConfig(ckpt_dir=CKPT, ckpt_every=5, async_ckpt=False),
                  state, built2.fn, data,
                  state_shardings=built2.in_shardings[0])
    tr2.run(step + 6, start_step=step)
    print(f"  resumed at step {step}, continued to {step + 6}")
    print(f"  loss trace: {[round(m['loss'], 3) for m in tr2.metrics_log]}")
    print("OK: state resharded 4 -> 2 devices with no loss spike")


if __name__ == "__main__":
    main()
