"""Quickstart: map a dataflow computation onto a resource network.

Reproduces the paper's worked example (Fig. 1 + Fig. 3), then solves a
random BRITE-style instance with every algorithm in the library and prints
the paper's own comparison metrics (cost, partial-map set size, messages).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    SimConfig, anneal_python, leastcost_jax, leastcost_python, pathmap_exact,
    paper_example, random_dataflow, random_k_python, simulate,
    validate_mapping, waxman,
)

NAMES = "ABCDEFGH"


def show(tag, m, extra=""):
    if m is None:
        print(f"  {tag:28s} INFEASIBLE")
        return
    assign = "".join(NAMES[v] if v < 8 else str(v) for v in m.assign)
    print(f"  {tag:28s} cost={m.cost:8.2f}  assign={assign:12s} route={m.route} {extra}")


def main():
    print("== paper worked example (Fig. 1 resource net, Fig. 3 dataflow) ==")
    rg, df = paper_example()
    ex, est = pathmap_exact(rg, df)
    show("exact PathMap (Alg.1-3)", ex, f"[{est.max_set_size} partial maps]")
    lp, pst = leastcost_python(rg, df)
    show("LeastCostMap (§3.4.1)", lp, f"[{pst.max_set_size} partial maps]")
    lj, jst = leastcost_jax(rg, df)
    show("LeastCostMap (JAX DP)", lj, f"[{jst.rounds} supersteps]")
    for pol in ("exact", "leastcost", "annealed", "random_k"):
        m, st = simulate(rg, df, SimConfig(policy=pol, seed=0, k=2))
        show(f"distributed '{pol}' (Alg.4)", m, f"[{st.messages_sent} msgs]")

    print("\n== random Waxman topology, n=40 ==")
    rg = waxman(40, seed=7)
    df = random_dataflow(rg, 7, seed=42)
    print(f"  dataflow: p={df.p} creq={np.round(df.creq,1)} src={df.src} dst={df.dst}")
    lj, jst = leastcost_jax(rg, df)
    show("LeastCostMap (JAX DP)", lj)
    if lj is not None:
        ok, why = validate_mapping(rg, df, lj)
        print(f"  constraints re-validated: {ok} ({why})")
    m, st = simulate(rg, df, SimConfig(policy="leastcost"))
    show("distributed LeastCostMap", m, f"[{st.messages_sent} msgs]")
    ma, _ = anneal_python(rg, df, seed=1)
    show("AnnealedLeastCostMap", ma)
    mk, _ = random_k_python(rg, df, k=2, seed=1)
    show("RandomNeighbor(k=2)", mk)


if __name__ == "__main__":
    main()
