"""Telemetry plane, end to end: trace a spanning request's lifecycle and
export it as a Perfetto-loadable Chrome trace.

A live :class:`repro.obs.Tracer` is handed to the control-plane facade;
every plane level threads a *scoped* view to its children, so the one
event buffer collects gossip rounds, per-region solves, and the bounded
two-phase commit legs of a region-spanning dataflow under prefixed
tracks (``r0/placer``, ``r1/2pc``, ...).  The exported JSON drops into
https://ui.perfetto.dev or ``chrome://tracing`` as-is; the same events
feed a compact ASCII timeline and a by-rid lifecycle reconstruction, and
``metrics_registry()`` folds every region's counters into one labeled
snapshot.

Run:  PYTHONPATH=src python examples/trace_export.py [out.json]
"""
import sys

from repro.core import DataflowPath, region_line
from repro.obs import Tracer, reconstruct_request, text_timeline, \
    validate_chrome_trace, write_chrome_trace
from repro.service import ControlPlane


def main(out_path: str = "trace_export.json"):
    rg, assign = region_line(3, 4, seed=7)
    tracer = Tracer()
    cp = ControlPlane(rg, region_of=assign, micro_batch=8, fanout=2,
                      seed=7, method="leastcost_python", tracer=tracer)
    cp.register_tenant("svc", weight=1.0)

    # a few region-local requests for background traffic...
    background = [
        cp.submit("svc", DataflowPath.make([0.0, 0.3, 0.0], [1.0, 1.0],
                                           4 * i, 4 * i + 2))
        for i in range(3)
    ]
    # ...and one dataflow pinned end to end across the region line: it can
    # only be placed as a chained 2PC through every region in between.
    rid = cp.submit("svc", DataflowPath.make([0.0, 0.2, 0.0], [0.5, 0.5],
                                             0, rg.n - 1), klass=1)
    for _ in range(6):
        cp.pump()
        if rid in cp.active_ids():
            break
    for r in background + [rid]:
        if r in cp.active_ids():
            cp.release(r)

    doc = write_chrome_trace(tracer, out_path)
    errors = validate_chrome_trace(doc)
    print(f"wrote {out_path}: {len(doc['traceEvents'])} events, "
          f"{'valid' if not errors else errors}")

    life = reconstruct_request(doc, rid)
    print(f"\nrequest {rid} lifecycle:")
    print("  " + " -> ".join(e["name"] for e in life))

    print("\ntimeline:")
    print(text_timeline(tracer, max_rows=12))

    snap = cp.metrics_registry().snapshot()
    print("\nmetrics (per-region series carry plane labels):")
    for k in sorted(snap):
        if k.startswith(("twopc.", "gossip.")) or "plane=" in k:
            print(f"  {k} = {snap[k]}")


if __name__ == "__main__":
    main(*sys.argv[1:2])
