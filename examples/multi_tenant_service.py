"""Multi-tenant placement control plane, end to end.

Two tenants with a 3:1 weight ratio share one overloaded network; a
latency-critical request preempts best-effort work; a node fails and
restores; the background defrag pass re-optimizes the standing allocation
and re-admits previously-rejected requests.

Run:  PYTHONPATH=src python examples/multi_tenant_service.py
"""
import numpy as np

from repro.core import random_dataflow, waxman
from repro.service import (
    CLASS_BEST_EFFORT,
    CLASS_CRITICAL,
    ControlPlane,
    FairSharePolicy,
)


def main():
    rg = waxman(20, seed=11)
    cp = ControlPlane(rg, policy=FairSharePolicy(slack=0.4), micro_batch=16)
    cp.warmup(p=5)  # pre-compile the jit buckets before the first pump
    cp.register_tenant("gold", weight=3.0)
    cp.register_tenant("bronze", weight=1.0)

    # Identical offered load: 80 best-effort requests each (past capacity).
    for i in range(80):
        for tenant in ("gold", "bronze"):
            df = random_dataflow(rg, 5, seed=1000 + i * 2 + (tenant == "gold"),
                                 creq_range=(0.2, 0.6), breq_range=(2.0, 8.0))
            cp.submit(tenant, df, klass=CLASS_BEST_EFFORT)
    for _ in range(12):
        cp.pump()
    held = cp.committed_capacity()
    rep = cp.fairness_report()
    print(f"standing capacity  gold={held['gold']:.2f}  "
          f"bronze={held['bronze']:.2f}  "
          f"(weighted max-min deviation {rep['max_deviation']:.1%})")

    # A latency-critical arrival too big for ANY node's residual: greedy
    # admission fails, so it preempts best-effort work (strictly lower
    # class only), which re-enters its tenant queue.
    from repro.core import DataflowPath

    free = cp.placer.cap
    potential = free.copy()  # residual + preemptable best-effort load
    for t in cp.placer.tickets.values():
        if t.klass < CLASS_CRITICAL:
            for v, c in t.node_load.items():
                potential[v] += c
    target = int(np.argmax(potential))
    need = min(float(free.max()) + 0.3, float(potential[target]) - 0.3)
    s, d = rg.neighbors(target)[:2]
    crit = DataflowPath.make([0.0, need, 0.0], [1.0, 1.0], src=s, dst=d)
    cp.submit("gold", crit, klass=CLASS_CRITICAL)
    admitted = cp.pump()
    print(f"critical admission (creq {need:.1f} > max free "
          f"{float(free.max()):.1f}): admitted={bool(admitted)}  "
          f"preemptions={cp.placer.stats.preempted}  "
          f"(preempted work re-queued, never dropped)")

    # Churn: fail the busiest intermediate node, then restore it.
    load = np.zeros(rg.n)
    for t in cp.placer.tickets.values():
        for v in t.mapping.route:
            if v not in (t.df.src, t.df.dst):
                load[v] += 1
    victim = int(load.argmax())
    alive, requeued = cp.fail_node(victim)
    print(f"node {victim} failed: still-active={len(alive)} "
          f"displaced-to-queue={len(requeued)}")
    cp.restore_node(victim)

    # Background defrag: re-solve the standing set, retry the queue.
    res = cp.defrag()
    print(f"defrag: committed={res.committed} repacked={res.repacked} "
          f"moved={res.moved} readmitted={len(res.readmitted)} "
          f"objective {tuple(round(x, 1) for x in res.objective_before)} -> "
          f"{tuple(round(x, 1) for x in res.objective_after)}")

    cp.check_invariants()
    print("ledger:", cp.conservation())


if __name__ == "__main__":
    main()
