"""End-to-end training driver: model + synthetic data + AdamW + fault-
tolerant Trainer (checkpoint/restart, straggler watchdog) + BCPM placement.

Presets scale to the hardware at hand — ``100m`` is the assignment's
"train a ~100M model for a few hundred steps" target (sized for a real
accelerator); ``tiny`` finishes on this CPU container in ~a minute and
exercises the identical code path.

    PYTHONPATH=src python examples/train_100m.py --preset tiny --steps 30
    PYTHONPATH=src python examples/train_100m.py --preset 100m --steps 300
    PYTHONPATH=src python examples/train_100m.py --preset tiny --compress int8
"""
import argparse
import time

import jax
import numpy as np

from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.launch.mesh import make_local_mesh
from repro.launch.placement import PodTopology, plan_pipeline
from repro.launch.steps import build_train_step, init_train_state
from repro.models.config import ModelConfig, ShapeConfig
from repro.optim.adamw import OptConfig
from repro.runtime.trainer import Trainer, TrainerConfig

PRESETS = {
    # ~5M params: CPU-friendly smoke-scale driver
    "tiny": (ModelConfig(name="tiny", family="dense", n_layers=4, d_model=128,
                         n_heads=4, n_kv_heads=2, d_ff=512, vocab=512,
                         tie_embeddings=True, dtype="float32"),
             ShapeConfig("train", "train", seq_len=128, global_batch=8)),
    # ~110M params (GPT-2-small-ish llama): the assignment's target scale
    "100m": (ModelConfig(name="lm100m", family="dense", n_layers=12,
                         d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
                         vocab=32000, tie_embeddings=True, dtype="float32"),
             ShapeConfig("train", "train", seq_len=512, global_batch=32,
                         microbatch=4)),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--compress", default="none", choices=["none", "int8"])
    ap.add_argument("--data", type=int, default=2, help="data-parallel size")
    args = ap.parse_args()

    cfg, shape = PRESETS[args.preset]
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.1f}M params), "
          f"batch={shape.global_batch}x{shape.seq_len}")

    # BCPM placement preview for the production topology (the launcher would
    # apply this stage->slice assignment before building shardings):
    plan = plan_pipeline(cfg, shape, PodTopology(pods=1), steps_per_sec=1.0)
    if plan:
        print(f"BCPM pipeline placement: stages->slices {plan.stage_slices} "
              f"(route latency {plan.latency_us:.1f}us)")

    mesh = make_local_mesh(min(args.data, jax.device_count()), 1)
    opt = OptConfig(lr=3e-3, warmup_steps=5, total_steps=max(args.steps, 100))

    if args.compress == "int8":
        from examples._compressed_dp import build_compressed_train_step
        built, state = build_compressed_train_step(cfg, shape, mesh, opt)
    else:
        built = build_train_step(cfg, shape, mesh, opt, masked=True)
        state = init_train_state(cfg, built)

    data = Prefetcher(iter(SyntheticLM(cfg.vocab, shape.seq_len,
                                       shape.global_batch, seed=0)))
    tr = Trainer(
        TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=max(args.steps // 3, 10)),
        state, built.fn, data, state_shardings=built.in_shardings[0],
    )
    t0 = time.time()
    tr.run(args.steps)
    dt = time.time() - t0
    losses = [m["loss"] for m in tr.metrics_log]
    print(f"steps={len(losses)} wall={dt:.1f}s "
          f"loss: first={losses[0]:.3f} min={min(losses):.3f} last={losses[-1]:.3f}")
    assert losses[-1] < losses[0], "loss should decrease on the synthetic task"
    print(f"events: {tr.events}")
    print("checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
