"""Decentralized regional control plane, end to end.

A 6-region *line* topology (fully-connected 4-node regions, one gateway
link between neighbors) is sharded with ``ControlPlane(rg, regions=6,
region_of=...)``.  Each region drains its own tenant queues against its
own **compacted** residual view — every regional DP solve runs over
n_r = 4 nodes, never the global 24 (the view substrate of
``repro.core.compact``) — and fair shares are enforced from *gossiped
estimates* of what every tenant holds elsewhere (no global lock,
R * fanout messages per round).

A dataflow pinned from region 0 to region 2 has no direct cut edge: it is
decomposed over the multi-hop region chain 0 -> 1 -> 2 (one gateway-pinned
segment per region, region 1 possibly pure transit) and placed by ONE
bounded two-phase commit — previously such requests retried until
dropped.  A middle cut-link failure partitions the chain — the spanning
placement is displaced, queued, and re-admitted after the heal.

Run:  PYTHONPATH=src python examples/regional_service.py
"""
import numpy as np

from repro.core import DataflowPath, region_line
from repro.service import ControlPlane, FairSharePolicy, SpanningTicket


def main():
    rg, assign = region_line(6, 4, seed=11)
    cp = ControlPlane(rg, regions=6, region_of=assign, fanout=2, seed=0,
                      policy=FairSharePolicy(slack=0.4), micro_batch=16)
    print(f"{cp.R} regions in a line over {rg.n} nodes, "
          f"{len(cp.cut_base)} cut links "
          f"(region sizes {np.bincount(cp.region_of).tolist()}, "
          f"every solve compacted to n_r = "
          f"{max(v.n_local for v in cp.views)})")

    cp.register_tenant("gold", weight=3.0)
    cp.register_tenant("bronze", weight=1.0)
    cp.warmup(p=4)  # pre-compile every region's jit buckets before pumping

    # Overload both tenants with mixed-span work: in-region requests and
    # requests straddling 2..4 regions along the line.
    rng = np.random.default_rng(7)
    for i in range(60):
        for tenant in ("gold", "bronze"):
            r1 = int(rng.integers(0, 6))
            r2 = min(5, r1 + int(rng.integers(0, 4)))
            src = int(rng.choice(np.nonzero(assign == r1)[0]))
            dst = int(rng.choice(np.nonzero(assign == r2)[0]))
            if src == dst:
                continue
            p = int(rng.integers(2, 5))
            creq = rng.uniform(0.05, 0.3, p).astype(np.float32)
            creq[0] = creq[-1] = 0.0
            breq = rng.uniform(0.5, 2.0, p - 1).astype(np.float32)
            cp.submit(tenant, DataflowPath(creq, breq, src, dst))
    for _ in range(8):
        cp.pump()
    cp.check_invariants()

    held = cp.committed_capacity()
    rep = cp.fairness_report()
    coord = cp.coordination_report()
    size = coord["solve_size"]
    print(f"standing capacity  gold={held['gold']:.2f} "
          f"bronze={held['bronze']:.2f} "
          f"(weighted max-min deviation {rep['max_deviation']:.1%})")
    print(f"coordination: {coord['gossip_messages']} gossip msgs "
          f"({coord['gossip_messages_per_round']:.0f}/round = R*fanout), "
          f"{coord['twopc_messages']} 2PC msgs for "
          f"{coord['spanning']['admitted']} spanning placements "
          f"(longest chain {coord['spanning']['max_chain']} regions, "
          f"{coord['spanning']['multi_hop']} multi-hop)")
    print(f"solve size: mean padded n per regional solve = "
          f"{size['mean_solve_n']:.1f} (global n = {size['global_n']}; "
          f"{size['global_n'] / size['mean_solve_n']:.0f}x smaller DP)")

    # A dataflow pinned across THREE regions (0 -> 2): no direct cut edge
    # exists, so it is decomposed over the region chain by multi-hop 2PC.
    src = int(np.nonzero(assign == 0)[0][0])
    dst = int(np.nonzero(assign == 2)[0][-1])
    df = DataflowPath.make([0.0, 0.2, 0.2, 0.0], [1.0, 1.0, 1.0], src, dst)
    rid = cp.submit("gold", df)
    spans = [t for t in cp.pump()
             if isinstance(t, SpanningTicket) and t.rid == rid]
    if spans:
        st = spans[-1]
        print(f"spanning rid {rid}: chain {st.chain} "
              f"(splits {st.splits}), cuts {st.cuts}, "
              f"{[f'{b:.1f}' for b in st.cut_bws]} bw reserved by one 2PC")

        # Partition the chain at its middle cut: the whole composite
        # placement is displaced (never dropped), then heals + re-admits.
        mid = st.cuts[len(st.cuts) // 2]
        cp.fail_link(*mid)
        led = cp.conservation()
        print(f"middle cut {mid} failed: active={led['active']} "
              f"queued={led['queued']} dropped={led['dropped']}")
        cp.restore_link(*mid)
        cp.pump()
        print(f"healed: rid {rid} active again = "
              f"{rid in cp.active_ids()}")

    # Per-region background defrag — no global re-solve exists, by design.
    results = cp.defrag()
    print("regional defrag:",
          [(r.committed, r.moved, len(r.readmitted)) for r in results])

    cp.check_invariants()
    print("ledger:", cp.conservation())


if __name__ == "__main__":
    main()
