"""Decentralized regional control plane, end to end.

The network is sharded into 4 regions (``ControlPlane(rg, regions=4)``).
Each region drains its own tenant queues against its own residual view;
fair shares are enforced from *gossiped estimates* of what every tenant
holds elsewhere (no global lock, R * fanout messages per round), and a
dataflow whose endpoints straddle regions is decomposed at a cut edge and
placed by a bounded two-phase commit.  A cut-link failure partitions a
region pair — the spanning placement is displaced, queued, and re-admitted
after the heal.

Run:  PYTHONPATH=src python examples/regional_service.py
"""
import numpy as np

from repro.core import DataflowPath, random_dataflow, waxman
from repro.service import ControlPlane, FairSharePolicy, SpanningTicket


def main():
    rg = waxman(24, seed=11)
    cp = ControlPlane(rg, regions=4, fanout=2, seed=0,
                      policy=FairSharePolicy(slack=0.4), micro_batch=16)
    print(f"{cp.R} regions over {rg.n} nodes, "
          f"{len(cp.cut_base)} cut links "
          f"(region sizes {np.bincount(cp.region_of).tolist()})")

    cp.register_tenant("gold", weight=3.0)
    cp.register_tenant("bronze", weight=1.0)

    # Overload both tenants; requests land in whatever region their random
    # endpoints fall into — some straddle two regions.
    for i in range(60):
        for tenant in ("gold", "bronze"):
            df = random_dataflow(rg, 4, seed=900 + 2 * i + (tenant == "gold"),
                                 creq_range=(0.1, 0.4), breq_range=(0.5, 2.0))
            cp.submit(tenant, df)
    for _ in range(8):
        cp.pump()
    cp.check_invariants()

    held = cp.committed_capacity()
    rep = cp.fairness_report()
    coord = cp.coordination_report()
    print(f"standing capacity  gold={held['gold']:.2f} "
          f"bronze={held['bronze']:.2f} "
          f"(weighted max-min deviation {rep['max_deviation']:.1%})")
    print(f"coordination: {coord['gossip_messages']} gossip msgs "
          f"({coord['gossip_messages_per_round']:.0f}/round = R*fanout), "
          f"{coord['twopc_messages']} 2PC msgs for "
          f"{coord['spanning']['admitted']} spanning placements, "
          f"gossip staleness <= {coord['max_staleness']} versions")

    # A dataflow pinned across a region boundary: placed by reserve ->
    # commit on both sides of a cut edge.
    (u, v) = max(cp.cut_base, key=cp.cut_base.get)
    df = DataflowPath.make([0.2, 0.2], [1.0], src=u, dst=v)
    rid = cp.submit("gold", df)
    spans = [t for t in cp.pump() if isinstance(t, SpanningTicket)]
    if spans:
        st = spans[-1]
        print(f"spanning rid {rid}: split at dataflow edge {st.split}, "
              f"cut link {st.cut} "
              f"(regions {int(cp.region_of[st.cut[0]])}->"
              f"{int(cp.region_of[st.cut[1]])}), "
              f"{st.cut_bw:.1f} bw reserved by 2PC")

        # Partition the region pair: the spanning placement is displaced
        # (never dropped), then heals and re-admits.
        cp.fail_link(*st.cut)
        led = cp.conservation()
        print(f"cut link failed: active={led['active']} "
              f"queued={led['queued']} dropped={led['dropped']}")
        cp.restore_link(*st.cut)
        cp.pump()
        print(f"healed: rid {rid} active again = "
              f"{rid in cp.active_ids()}")

    # Per-region background defrag — no global re-solve exists, by design.
    results = cp.defrag()
    print("regional defrag:",
          [(r.committed, r.moved, len(r.readmitted)) for r in results])

    cp.check_invariants()
    print("ledger:", cp.conservation())


if __name__ == "__main__":
    main()
