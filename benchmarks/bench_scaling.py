"""Paper §3.2/§3.4.1: the exact algorithm is infeasible beyond ~50 nodes;
the heuristic scales.  Plus the beyond-paper tensorized-DP scaling curve
(wall time vs n) for the python path-carrying vs JAX DP implementations.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (
    leastcost_jax, leastcost_python, pathmap_exact, random_dataflow, waxman,
)


def run(seed0: int = 300):
    rows = []
    # exact blow-up curve
    for n in (10, 14, 18, 22, 26):
        t0 = time.perf_counter()
        states = 0
        blown = False
        for i in range(3):
            rg = waxman(n, seed=seed0 + i)
            df = random_dataflow(rg, 6, seed=seed0 + 50 + i)
            try:
                _, st = pathmap_exact(rg, df, max_states=250_000)
                states = max(states, st.max_set_size)
            except MemoryError:
                blown = True
        dt = (time.perf_counter() - t0) / 3
        rows.append({
            "name": f"exact_scaling_n{n}",
            "us_per_call": 1e6 * dt,
            "derived": f"max_states={states};state_explosion={blown}",
        })
        if blown:
            break
    # heuristic scaling (python vs tensorized JAX, warm jit)
    for n in (50, 100, 200, 400, 800):
        rg = waxman(n, seed=seed0)
        df = random_dataflow(rg, 8, seed=seed0 + 99)
        t0 = time.perf_counter()
        mp, _ = leastcost_python(rg, df)
        t_py = time.perf_counter() - t0
        leastcost_jax(rg, df)  # compile warmup
        t0 = time.perf_counter()
        mj, _ = leastcost_jax(rg, df)
        t_jax = time.perf_counter() - t0
        agree = (mp is None) == (mj is None) and (
            mp is None or abs(mp.cost - mj.cost) < 1e-3
        )
        rows.append({
            "name": f"leastcost_scaling_n{n}",
            "us_per_call": 1e6 * t_jax,
            "derived": (
                f"python_us={1e6*t_py:.0f};jax_us={1e6*t_jax:.0f};"
                f"speedup={t_py/max(t_jax,1e-9):.1f}x;agree={agree}"
            ),
        })
    return rows
