"""Placement-engine benchmark.

1. BCPM planning for every assigned architecture on the 2-pod slice graph
   (quality = end-to-end route latency; time = solver wall clock, warm jit).
2. Online multi-request placement service (``core.online.OnlinePlacer``):
   batched-kernel vs vmapped-jnp vs sequential ``solve()`` on the same
   request stream, plus a speedup curve over batch size and network size
   and an admission + churn exercise with residual-capacity invariants.
3. Streaming admission under a Poisson arrival/departure process with
   periodic node churn (the paper's dynamic scenario, quantified):
   steady-state admission rate and re-map latency, plus an offered-load
   sweep (rate x hold) past the knee of the admission-rate curve, and a
   pipeline-depth column at the knee (async pipelined admission: device
   solves overlapped with host commits; gated in ``criterion``).
4. Multi-tenant fairness at the knee (``repro.service.ControlPlane``):
   two tenants, weights 3:1, identical offered overload — weighted
   max-min standing shares vs the FCFS baseline — ending with the
   background-defrag pass on the churn-fragmented network.

``python -m benchmarks.bench_placement [--smoke]`` writes the online-service
numbers to ``BENCH_placement.json``, the churn process + overload sweep to
``BENCH_streaming.json`` and the fairness/defrag scenario to
``BENCH_fairness.json`` (all CI artifacts).

Off-TPU the ``use_kernel=True`` path runs the fused batched jnp mirror of
the Pallas superstep kernel (``kernels/minplus/batched``) — same math, same
shared-network batching, no per-request vmap graph.  On TPU the Pallas
kernel replaces it; its expected advantage is the HBM-traffic model in the
kernel's module docstring (O(n^2 + B*n*K) vs O(B*n^2*K) per superstep).
"""
from __future__ import annotations

import heapq
import json
import time

import numpy as np

from repro.core import (
    AdmissionPipeline,
    OnlinePlacer,
    random_dataflow,
    solve,
    solve_batch,
    waxman,
)


def run_archs():
    from repro.configs import ARCHS, get_config
    from repro.launch.placement import PodTopology, plan_pipeline
    from repro.models.config import SHAPES

    rows = []
    topo = PodTopology(pods=2)
    for arch in ARCHS:
        cfg = get_config(arch)
        plan_pipeline(cfg, SHAPES["train_4k"], topo, steps_per_sec=0.05,
                      dst_slice=topo.n_slices - 1)  # warm
        t0 = time.perf_counter()
        plan = plan_pipeline(cfg, SHAPES["train_4k"], topo, steps_per_sec=0.05,
                             dst_slice=topo.n_slices - 1)
        dt = time.perf_counter() - t0
        rows.append({
            "name": f"placement_{arch}",
            "us_per_call": 1e6 * dt,
            "derived": (
                f"stages={len(plan.stage_slices)};latency_us={plan.latency_us:.1f};"
                f"route_len={len(plan.route)}" if plan else "infeasible"
            ),
        })
    return rows


def _request_stream(rg, n_requests: int, p: int, seed0: int):
    """Light concurrent requests: many fit the shared network at once."""
    return [
        random_dataflow(rg, p, seed=seed0 + i,
                        creq_range=(0.02, 0.15), breq_range=(0.5, 4.0))
        for i in range(n_requests)
    ]


def _best_time(fn, reps: int = 7) -> float:
    """min-of-reps wall clock: the robust statistic on noisy shared runners
    (the true cost is the floor; everything above it is interference)."""
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run_batch_curve(*, n_list=(16, 32), batch_list=(1, 8, 32, 64), p: int = 6,
                    seed: int = 3, reps: int = 20):
    """Speedup curve over batch size and network size: batched-kernel DP vs
    vmapped-jnp DP vs a sequential solve loop on one shared network.

    The DP is timed directly (jit + block_until_ready): parent-pointer
    reconstruction is identical python work on both batched paths and would
    only add noise to the comparison the kernel changes.
    """
    import jax

    from repro.core.leastcost import _leastcost_dp_batched, _vmapped_dp
    from repro.core.problem import stack_requests

    curve = []
    for n in n_list:
        rg = waxman(n, seed=seed)
        dfs_all = _request_stream(rg, max(batch_list), p, seed0=2000)
        solve(rg, dfs_all[0], method="leastcost_jax")  # warm single shape
        for b in batch_list:
            dfs = dfs_all[:b]
            tensors, p_max = stack_requests(rg, dfs)
            vmapped = _vmapped_dp(n, p_max, n - 1)  # same cached jit as prod
            f_v = lambda: jax.block_until_ready(vmapped(tensors)[0])  # noqa: E731
            f_k = lambda: jax.block_until_ready(  # noqa: E731
                _leastcost_dp_batched(tensors, B=b, n=n, p=p_max,
                                      max_rounds=n - 1, impl="ref")[0])
            f_v(), f_k()  # warm both compiled paths
            # each path is measured in steady state (warm, back-to-back,
            # min-of-reps): alternating executables every call adds
            # allocator/cache churn that swamps the ~10% DP difference
            t_vmap = _best_time(f_v, reps)
            t_kern = _best_time(f_k, reps)
            t_seq = _best_time(
                lambda: [solve(rg, df, method="leastcost_jax") for df in dfs],
                max(2, reps - 4))
            curve.append({
                "n": n, "batch": b, "kernel_impl": "ref",
                "sequential_solve_s": t_seq, "vmapped_dp_s": t_vmap,
                "kernel_dp_s": t_kern,
                "kernel_vs_vmapped": t_vmap / max(t_kern, 1e-9),
            })
    return curve


def run_online(*, n: int = 24, p: int = 6, n_requests: int = 128,
               micro_batch: int = 64, seed: int = 7,
               curve_kwargs: dict | None = None,
               out_path: str = "BENCH_placement.json"):
    rg = waxman(n, seed=seed)
    dfs = _request_stream(rg, n_requests, p, seed0=1000)

    # DP speedup curve first: measured in a quiet process, before the
    # service exercise below fills the jit cache and allocator
    curve = run_batch_curve(**(curve_kwargs or {}))

    # warm all jit paths (single-request, batched, batched-kernel shapes)
    solve(rg, dfs[0], method="leastcost_jax")
    solve_batch(rg, dfs[:micro_batch], method="leastcost_jax")
    solve_batch(rg, dfs[:micro_batch], method="leastcost_jax", use_kernel=True)

    seq = [solve(rg, df, method="leastcost_jax")[0] for df in dfs]
    t_seq = _best_time(
        lambda: [solve(rg, df, method="leastcost_jax") for df in dfs], reps=3)

    def run_batched(**kw):
        out = []
        for i in range(0, n_requests, micro_batch):
            ms, _ = solve_batch(rg, dfs[i:i + micro_batch],
                                method="leastcost_jax", **kw)
            out.extend(ms)
        return out

    bat = run_batched()
    t_bat = _best_time(run_batched, reps=3)

    ker = run_batched(use_kernel=True)
    t_ker = _best_time(lambda: run_batched(use_kernel=True), reps=3)

    def _agree(a_list, b_list):
        return sum(
            (a is None) == (b is None)
            and (a is None or abs(a.cost - b.cost) < 1e-3)
            for a, b in zip(a_list, b_list)
        ) / n_requests

    # admission + churn against residual capacity (kernel path)
    placer = OnlinePlacer(rg, use_kernel=True)
    tickets = []
    for i in range(0, n_requests, micro_batch):
        tickets.extend(placer.admit_many(dfs[i:i + micro_batch]))
    placer.check_invariants()
    admitted_stream = placer.stats.admitted  # before churn re-admissions
    busiest = max(
        (v for t in tickets if t for v in t.mapping.route
         if v not in (t.df.src, t.df.dst)),
        key=lambda v: sum(v in t.mapping.route for t in tickets if t),
        default=0,
    )
    remapped, dropped = placer.fail_node(busiest)
    placer.check_invariants()

    record = {
        "n": n, "p": p, "n_requests": n_requests, "micro_batch": micro_batch,
        "sequential_s": t_seq, "batched_s": t_bat, "kernel_s": t_ker,
        "speedup": t_seq / max(t_bat, 1e-9),
        "speedup_kernel": t_seq / max(t_ker, 1e-9),
        "kernel_vs_vmapped": t_bat / max(t_ker, 1e-9),
        "agreement": _agree(seq, bat),
        "agreement_kernel": _agree(seq, ker),
        "admitted": admitted_stream,
        "admitted_total": placer.stats.admitted,  # incl. churn re-admissions
        "rejected": placer.stats.rejected,
        "batch_conflicts": placer.stats.batch_conflicts,
        "churn": {
            "failed_node": int(busiest),
            "displaced": len(remapped) + len(dropped),
            "remapped": len(remapped),
            "dropped": len(dropped),
        },
        "invariants_ok": True,
        "curve": curve,
        "tpu_note": (
            "off-TPU use_kernel runs the fused-jnp mirror of the batched "
            "Pallas superstep, which XLA compiles to nearly the same code "
            "as the jitted vmap — kernel_vs_vmapped ~1.0 +/- runner noise "
            "is the expected CPU reading.  The kernel's claimed advantage "
            "is the TPU HBM-traffic model (O(n^2 + B*n*K) vs O(B*n^2*K) "
            "per superstep, lat/bw tiles shared across the batch; see "
            "kernels/minplus/batched.py) which a CPU proxy cannot exhibit."
        ),
    }
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    return record


def _poisson_times(rng, rate: float, horizon: float) -> list[float]:
    ts, t = [], 0.0
    while True:
        t += rng.exponential(1.0 / max(rate, 1e-9))
        if t >= horizon:
            break
        ts.append(t)
    return ts


def run_streaming(*, n: int = 24, p: int = 5, rate: float = 24.0,
                  hold: float = 2.0, horizon: float = 10.0, tick: float = 0.25,
                  fail_every: float = 2.5, warmup: float = 2.0, seed: int = 11,
                  use_kernel: bool = True, pipeline_depth: int = 1,
                  cache: bool = True, repeat_pool: int | None = None,
                  out_path: str | None = "BENCH_streaming.json"):
    """Poisson arrival/departure process against one shared network.

    Requests arrive at ``rate``/unit-time, hold capacity for Exp(``hold``)
    and depart; every ``fail_every`` units a busy node fails (displacing its
    tickets through re-admission) and the previously failed node restores.
    Virtual time drives the process; wall clock is measured only around the
    micro-batched admissions and the churn re-maps.  ``out_path=None`` skips
    the JSON write (used by the overload sweep).

    ``pipeline_depth`` routes the tick batches through an
    :class:`~repro.core.AdmissionPipeline`: at depth d, a tick's solve is
    dispatched immediately but commits only when the window forces it out
    (or at the end-of-horizon flush), so device DPs overlap the host-side
    validate/commit of earlier batches.  ``depth=1`` commits every push
    in-line and is bit-identical to the synchronous ``admit_many`` path.
    Admissions are attributed to the *dispatch* tick for rate accounting
    (offered vs admitted must pair up) and to the *commit* tick for the
    departure clock (capacity is only held once committed).

    ``steady_admission_rate`` counts only arrivals after ``warmup``: the
    ramp-up (an empty network admits everything) otherwise masks the
    saturation knee the overload sweep is looking for.

    ``cache`` toggles the placer's incremental fast path;
    ``repeat_pool=k`` makes the workload repeat-heavy — the arrival
    stream cycles through ``k`` distinct request shapes instead of
    drawing a fresh one per arrival, which is the regime the
    mapping-reuse cache is built for (``run_cache_fastpath`` pairs the
    two knobs into the gated on/off comparison).
    """
    rng = np.random.default_rng(seed)
    rg = waxman(n, seed=seed)
    placer = OnlinePlacer(rg, use_kernel=use_kernel, cache_enabled=cache)
    warm_max = placer.warmup(max_batch=int(max(4 * rate * tick, 2)), p=p)
    pipe = AdmissionPipeline(placer, depth=pipeline_depth)

    # Poisson arrivals over the horizon
    arrivals = _poisson_times(rng, rate, horizon)
    if repeat_pool:
        pool = _request_stream(rg, repeat_pool, p, seed0=int(seed) * 131)
        reqs = [pool[k % repeat_pool] for k in range(len(arrivals))]
    else:
        reqs = _request_stream(rg, len(arrivals), p, seed0=int(seed) * 131)

    departures: list[tuple[float, int]] = []  # heap of (t_depart, tid)
    admit_ms: list[float] = []
    admit_ms_steady: list[float] = []  # pushes after `warmup` only
    remap_ms: list[float] = []
    displaced_total = remapped_total = 0
    offered = admitted_arrivals = 0  # arrival stream only (churn re-
    # admissions are tracked separately via placer.stats)
    offered_steady = admitted_steady = 0  # arrivals after `warmup`
    occupancy: list[int] = []
    failed_node: int | None = None
    next_fail = fail_every
    i = 0
    now = 0.0
    while now < horizon:
        now = min(now + tick, horizon)
        # departures due by `now`
        while departures and departures[0][0] <= now:
            _, tid = heapq.heappop(departures)
            if tid in placer.tickets:
                placer.release(tid)
        # churn: restore the previous casualty, fail the busiest node
        if now >= next_fail:
            next_fail += fail_every
            if failed_node is not None:
                placer.restore_node(failed_node)
            load = np.zeros(n)
            for tk in placer.tickets.values():
                for v in tk.mapping.route:
                    if v not in (tk.df.src, tk.df.dst):
                        load[v] += 1
            if load.max() > 0:
                failed_node = int(load.argmax())
                t0 = time.perf_counter()
                rem, drop = placer.fail_node(failed_node)
                remap_ms.append(1e3 * (time.perf_counter() - t0))
                displaced_total += len(rem) + len(drop)
                remapped_total += len(rem)
                # re-mapped tickets keep their tid, so the originally
                # scheduled departure entries stay valid — nothing to re-push
        # micro-batch the tick's arrivals
        batch = []
        while i < len(arrivals) and arrivals[i] <= now:
            batch.append(reqs[i])
            i += 1
        if batch:
            offered += len(batch)
            if now >= warmup:
                offered_steady += len(batch)
            t0 = time.perf_counter()
            committed = pipe.push(batch, tag=(now >= warmup))
            dt_ms = 1e3 * (time.perf_counter() - t0)
            admit_ms.append(dt_ms)
            if now >= warmup:
                admit_ms_steady.append(dt_ms)
            for pending, tickets in committed:
                for tk in tickets:
                    if tk is not None:
                        admitted_arrivals += 1
                        if pending.tag:  # steady flag from dispatch time
                            admitted_steady += 1
                        heapq.heappush(
                            departures, (now + rng.exponential(hold), tk.tid))
        occupancy.append(len(placer.tickets))
    # end-of-stream barrier: commit whatever the window still holds.  Timed
    # separately — one flush drains up to depth-1 batches, which is a
    # shutdown cost, not a per-admission latency sample.
    flush_ms = 0.0
    if pipe.in_flight:
        t0 = time.perf_counter()
        tail = pipe.flush()
        flush_ms = 1e3 * (time.perf_counter() - t0)
        for pending, tickets in tail:
            for tk in tickets:
                if tk is not None:
                    admitted_arrivals += 1
                    if pending.tag:
                        admitted_steady += 1
    placer.check_invariants()

    st = placer.stats
    record = {
        "n": n, "p": p, "rate": rate, "hold": hold, "horizon": horizon,
        "tick": tick, "fail_every": fail_every, "use_kernel": use_kernel,
        "pipeline_depth": pipeline_depth,
        "warmed_buckets_to": warm_max,  # larger churn batches may compile
        "offered": offered,
        "admitted": admitted_arrivals,  # arrival stream only
        "admitted_total": st.admitted,  # incl. churn re-admissions
        "rejected_total": st.rejected,
        "admission_rate": admitted_arrivals / max(offered, 1),
        "warmup": warmup,
        "steady_admission_rate": admitted_steady / max(offered_steady, 1),
        "steady_state_occupancy": float(np.mean(occupancy)) if occupancy else 0,
        "batches": st.batches,
        "batch_conflicts": st.batch_conflicts,
        "admit_ms_mean": float(np.mean(admit_ms)) if admit_ms else 0.0,
        "admit_ms_p95": float(np.percentile(admit_ms, 95)) if admit_ms else 0.0,
        # ramp-up excluded, same convention as steady_admission_rate: the
        # first pushes pay the one-time pool-fill solves (and, cache-on,
        # the signature-cache cold misses), which are not the steady tail
        "admit_ms_p95_steady": float(np.percentile(admit_ms_steady, 95))
        if admit_ms_steady else 0.0,
        "admit_ms_mean_steady": float(np.mean(admit_ms_steady))
        if admit_ms_steady else 0.0,
        "churn_events": len(remap_ms),
        "displaced": displaced_total,
        "remapped": remapped_total,
        "dropped": st.dropped,
        "remap_ms_mean": float(np.mean(remap_ms)) if remap_ms else 0.0,
        "remap_ms_p95": float(np.percentile(remap_ms, 95)) if remap_ms else 0.0,
        "solve_ms_total": st.solve_ms,
        "overhead_ms_total": st.overhead_ms,
        "conflict_resolve_ms": st.conflict_resolve_ms,
        "stale_batches": st.stale_batches,
        "flush_ms": flush_ms,
        "cache_enabled": cache,
        "repeat_pool": repeat_pool,
        "solves": st.solves,
        "cache_hits": st.cache_hits,
        "cache_misses": st.cache_misses,
        "cache_stale": st.cache_stale,
        "cache_neg_hits": st.cache_neg_hits,
        "hit_rate": st.cache_hits / max(
            st.cache_hits + st.cache_misses + st.cache_stale
            + st.cache_neg_hits, 1),
        "warm_solves": st.warm_solves,
        "warm_fallbacks": st.warm_fallbacks,
        "supersteps": {m: dict(b) for m, b in st.supersteps.items()},
        "invariants_ok": True,
    }
    if out_path is not None:
        with open(out_path, "w") as f:
            json.dump(record, f, indent=2)
    return record


def run_overload_sweep(*, rates=(12.0, 24.0, 48.0, 96.0, 192.0),
                       n: int = 24, p: int = 5, hold: float = 4.0,
                       horizon: float = 6.0, warmup: float = 2.0,
                       knee_threshold: float = 0.9,
                       pipeline_depths=(1, 2, 4),
                       pipeline_reps: int = 2,
                       seed: int = 11, use_kernel: bool = True,
                       baseline_rate: float = 24.0,
                       baseline_hold: float = 2.0,
                       out_path: str | None = "BENCH_streaming.json"):
    """Sweep offered load (arrival rate x hold time) past the admission knee.

    The ROADMAP observation: at the original operating point the service
    admits >90% — the interesting regime (where fairness and defrag matter)
    starts where admission collapses.  Offered concurrency is
    ``rate x hold``, so the sweep fixes a longer ``hold`` and doubles the
    rate until *steady-state* admission (ramp-up excluded; see
    ``run_streaming(warmup=...)``) falls below ``knee_threshold``.  That
    first saturated point is recorded as the knee; the fairness benchmark
    (``run_fairness``) runs past it on the same network.

    The knee point is then re-run at each ``pipeline_depths`` entry — the
    regime where batches are large and the network is contended, i.e. where
    pipelining has both the most to gain (device DP overlapped with host
    commit) and the most to lose (stale optimistic solves re-solved one by
    one).  ``record["criterion"]`` gates the trade: the deepest pipeline's
    admit p95 must stay within 1.1x of the synchronous knee value, and its
    steady-state admission rate within 2 points of the synchronous path.
    """
    base = run_streaming(n=n, p=p, rate=baseline_rate, hold=baseline_hold,
                         horizon=horizon, warmup=warmup, seed=seed,
                         use_kernel=use_kernel, out_path=None)
    sweep = []
    for r in sorted(rates):
        rec = run_streaming(n=n, p=p, rate=float(r), hold=hold,
                            horizon=horizon, warmup=warmup, seed=seed,
                            use_kernel=use_kernel, out_path=None)
        sweep.append({
            "rate": float(r),
            "hold": hold,
            "offered_concurrency": float(r) * hold,
            "offered": rec["offered"],
            "admission_rate": rec["admission_rate"],
            "steady_admission_rate": rec["steady_admission_rate"],
            "occupancy": rec["steady_state_occupancy"],
            "admit_ms_mean": rec["admit_ms_mean"],
        })
    found = next(
        (s for s in sweep if s["steady_admission_rate"] < knee_threshold),
        None,
    )
    knee = found if found is not None else sweep[-1]

    # ---- pipeline-depth column at the knee ------------------------------
    # Virtual time makes admission outcomes deterministic per (depth, seed);
    # only the wall-clock columns vary between reps.  min-of-reps on the
    # p95 is the same robust-floor statistic ``_best_time`` uses: the true
    # admission cost is the floor, everything above it is runner
    # interference.  A longer horizon gives the percentile enough samples
    # (~64 pushes at 16s vs ~20 at the smoke horizon) that the p95 is a
    # deep quantile instead of the 2nd-worst sample: both depths' tails
    # are churn-push costs of ~equal magnitude, so with enough samples the
    # ratio concentrates near 1 and the 1.1x gate has real margin.
    pipeline = []
    for d in sorted({max(1, int(d)) for d in pipeline_depths}):
        best = None
        for _ in range(pipeline_reps):
            rec = run_streaming(n=n, p=p, rate=knee["rate"],
                                hold=knee["hold"],
                                horizon=max(horizon, 16.0), warmup=warmup,
                                seed=seed, use_kernel=use_kernel,
                                pipeline_depth=d, out_path=None)
            if best is None or rec["admit_ms_p95"] < best["admit_ms_p95"]:
                best = rec
        pipeline.append({
            "pipeline_depth": d,
            "admit_ms_mean": best["admit_ms_mean"],
            "admit_ms_p95": best["admit_ms_p95"],
            "steady_admission_rate": best["steady_admission_rate"],
            "batch_conflicts": best["batch_conflicts"],
            "stale_batches": best["stale_batches"],
            "conflict_resolve_ms": best["conflict_resolve_ms"],
            "overhead_ms_total": best["overhead_ms_total"],
        })
    d_sync, d_deep = pipeline[0], pipeline[-1]

    # ---- disabled-telemetry overhead gate --------------------------------
    # Every admission crosses a bounded number of instrumentation sites
    # (pump/solve/dispatch/commit spans + flow-event guards).  With the
    # default NullTracer each site costs one constant no-op; measure that
    # cost directly and bound the worst-case per-admission total against
    # the pipelined admit p95 — deterministic, unlike differencing two
    # noisy p95 runs.
    obs = _obs_disabled_overhead()
    obs_bound_ms = (
        obs["hooks_per_admit_bound"]
        * max(obs["span_ns"], obs["guard_ns"]) / 1e6
    )
    obs["overhead_ms_per_admit_bound"] = obs_bound_ms

    criterion = {
        # deeper windows mean staler optimistic solves; the gates assert
        # the overlap never costs tail latency or admitted work
        "pipeline_p95_depth4_le_1p1x_depth1":
            d_deep["admit_ms_p95"] <= 1.1 * d_sync["admit_ms_p95"],
        "pipeline_admission_within_2pts":
            abs(d_deep["steady_admission_rate"]
                - d_sync["steady_admission_rate"]) <= 0.02,
        # telemetry off == telemetry absent: the disabled hooks' bounded
        # per-admission cost stays within 3% of the pipelined admit p95
        "obs_disabled_overhead_within_3pct":
            obs_bound_ms <= 0.03 * d_deep["admit_ms_p95"],
    }
    record = {
        "obs_overhead": obs,
        "baseline": base,
        "sweep": sweep,
        "knee": {
            "rate": knee["rate"],
            "hold": knee["hold"],
            "steady_admission_rate": knee["steady_admission_rate"],
            "threshold": knee_threshold,
            # False = the sweep never crossed the threshold and the "knee"
            # is just its last point; downstream overload scenarios (and
            # their CI gates) are then meaningless — widen the sweep.
            "saturated": found is not None,
        },
        "pipeline": pipeline,
        "criterion": criterion,
    }
    if out_path is not None:
        with open(out_path, "w") as f:
            json.dump(record, f, indent=2)
    return record


def _superstep_stats(supersteps: dict) -> dict:
    """{mode: {rounds: count}} -> {mode: {solves, mean, max}} (tolerates
    the string keys a JSON round-trip introduces)."""
    out = {}
    for mode, buckets in supersteps.items():
        total = sum(buckets.values())
        out[mode] = {
            "solves": total,
            "mean": sum(int(r) * c for r, c in buckets.items())
            / max(total, 1),
            "max": max((int(r) for r in buckets), default=0),
        }
    return out


def run_cache_fastpath(*, n: int = 24, p: int = 5, rate: float = 16.0,
                       hold: float = 0.6, horizon: float = 12.0,
                       churn_hold: float = 2.0, churn_fail_every: float = 2.5,
                       warmup: float = 2.0, repeat_pool: int = 6,
                       reps: int = 2, seed: int = 11,
                       use_kernel: bool = True):
    """Repeat-heavy streaming point, incremental fast path on vs off.

    Two workload phases, matching the two tiers:

    - **steady** (the p95 gate): the arrival stream cycles
      ``repeat_pool`` request shapes below the knee with no churn and a
      short ``hold``, so repeats mostly find the residual their cached
      mapping was committed against — tier-1 hits replace the DP with an
      O(p) revalidation and the admit tail collapses.  min-of-reps on
      the p95 (the robust floor; everything above it is interference).
    - **churn** (the superstep gate): same pool under periodic node
      failure and a longer hold, so entries go stale and the tier-2
      warm-started bounded correction path runs; its superstep buckets
      must sit strictly below the cold fixpoint's worst case (the
      ``max_correction_supersteps`` fuse, vs the rounds a cold batch
      solve actually takes).

    Gates in ``criterion`` (merged into BENCH_streaming.json):
    cache-on admit p95 <= 0.5x cache-off; lookup hit rate >= 0.5;
    steady-state admission rate within 1 point of the cold path; warm
    solves report strictly fewer supersteps than cold.
    """
    def _best(cache, **kw):
        best = None
        for _ in range(max(1, reps)):
            rec = run_streaming(
                n=n, p=p, rate=rate, horizon=horizon, warmup=warmup,
                seed=seed, use_kernel=use_kernel, cache=cache,
                repeat_pool=repeat_pool, out_path=None, **kw)
            if (best is None
                    or rec["admit_ms_p95_steady"]
                    < best["admit_ms_p95_steady"]):
                best = rec
        return best

    quiet = dict(hold=hold, fail_every=4 * horizon)  # no churn in-horizon
    off = _best(False, **quiet)
    on = _best(True, **quiet)
    churn = _best(True, hold=churn_hold, fail_every=churn_fail_every)
    ss = _superstep_stats(churn["supersteps"])
    warm, cold = ss.get("warm"), ss.get("cold")
    keep = ("admit_ms_mean", "admit_ms_p95", "admit_ms_mean_steady",
            "admit_ms_p95_steady", "steady_admission_rate",
            "solves", "cache_hits", "cache_misses", "cache_stale",
            "cache_neg_hits", "hit_rate", "warm_solves", "warm_fallbacks",
            "supersteps", "stale_batches", "batch_conflicts")
    record = {
        "n": n, "p": p, "rate": rate, "hold": hold, "horizon": horizon,
        "churn_hold": churn_hold, "churn_fail_every": churn_fail_every,
        "repeat_pool": repeat_pool, "reps": reps,
        "off": {k: off[k] for k in keep},
        "on": {k: on[k] for k in keep},
        "churn": {k: churn[k] for k in keep},
        "p95_ratio": on["admit_ms_p95_steady"]
        / max(off["admit_ms_p95_steady"], 1e-9),
        "superstep_stats": ss,
        "criterion": {
            "cache_p95_le_0p5x_off":
                on["admit_ms_p95_steady"]
                <= 0.5 * off["admit_ms_p95_steady"],
            "cache_hit_rate_ge_0p5": on["hit_rate"] >= 0.5,
            "cache_admission_within_1pt":
                abs(on["steady_admission_rate"]
                    - off["steady_admission_rate"]) <= 0.01,
            "warm_supersteps_lt_cold": bool(
                warm and cold and warm["max"] < cold["max"]),
        },
    }
    return record


def merge_cache_fastpath(swrec: dict, crec: dict,
                         out_path: str | None = "BENCH_streaming.json"
                         ) -> dict:
    """Fold the cache on/off comparison into the streaming record (its
    gates join the record-level ``criterion`` the CI fast lane asserts)."""
    swrec["cache"] = crec
    swrec["criterion"].update(crec["criterion"])
    if out_path is not None:
        with open(out_path, "w") as f:
            json.dump(swrec, f, indent=2)
    return swrec


def _obs_disabled_overhead(iters: int = 50_000) -> dict:
    """Per-site cost of the telemetry plane when DISABLED (the default):
    one ``NULL.span(...)`` context entry/exit, and one ``tracer.enabled``
    guard check — the only work any hot path pays without a live tracer."""
    from repro.obs import NULL

    t0 = time.perf_counter()
    for _ in range(iters):
        with NULL.span("bench", track="t", cat="c", k=1):
            pass
    span_ns = (time.perf_counter() - t0) / iters * 1e9
    t0 = time.perf_counter()
    for _ in range(iters):
        if NULL.enabled:
            NULL.flow_point(1, "bench")
    guard_ns = (time.perf_counter() - t0) / iters * 1e9
    return {
        "span_ns": round(span_ns, 1),
        "guard_ns": round(guard_ns, 1),
        # generous upper bound on instrumentation sites one admission
        # crosses: pump round + dispatch + solve + validate/commit +
        # conflict re-solve spans, plus every flow-event guard
        "hooks_per_admit_bound": 16,
    }


def run_fairness(*, knee_rate: float, n: int = 24, p: int = 5,
                 overload_factor: float = 1.5, weights=(3.0, 1.0),
                 hold: float = 4.0, horizon: float = 8.0, tick: float = 0.25,
                 fail_every: float = 2.5, warmup: float = 2.0,
                 micro_batch: int = 32, seed: int = 11,
                 use_kernel: bool = True,
                 out_path: str | None = "BENCH_fairness.json"):
    """Two-tenant overload scenario past the admission knee, weights 3:1.

    Runs on the *same* network and request distribution as the overload
    sweep, at ``overload_factor`` x the knee rate.  One shared Poisson
    arrival process is split round-robin between the tenants, so both offer
    exactly the same load and arrival order carries no information about
    entitlement:

    - **weighted** — the control plane's weighted max-min scheduler; the
      steady-state standing committed capacity should split by weight
      (3:1 -> fractions 0.75/0.25) within ~10%.
    - **fcfs** — the bare ``OnlinePlacer`` admitting in arrival order; both
      tenants then hold ~equal capacity, >25% off their weighted shares.

    Ends with the background-defrag exercise on the churn-fragmented
    network: after the node fail/restore cycles, restore everything and run
    one ``defrag()`` pass — it must strictly improve the global objective
    (or no-op) and re-admit previously-rejected (queued) requests.
    """
    from repro.service import ControlPlane, FairSharePolicy

    rng = np.random.default_rng(seed)
    rg = waxman(n, seed=seed)
    rate_total = float(knee_rate) * overload_factor
    names = ("gold", "bronze")
    w = dict(zip(names, weights))
    frac = {t: w[t] / sum(w.values()) for t in names}
    times = _poisson_times(rng, rate_total, horizon)
    stream = _request_stream(rg, len(times), p, seed0=seed * 977)
    # round-robin split: identical offered load, interleaved arrival order
    arrivals = {t: [] for t in names}
    reqs = {t: [] for t in names}
    for k, (at, df) in enumerate(zip(times, stream)):
        t = names[k % 2]
        arrivals[t].append(at)
        reqs[t].append(df)

    def _churn_tick(placer, now, state):
        """Shared fail/restore cycle: restore the previous casualty and pick
        the busiest intermediate node for the caller to fail."""
        if now < state["next_fail"]:
            return
        state["next_fail"] += fail_every
        if state["failed"] is not None:
            placer.restore_node(state["failed"])
            state["failed"] = None
        load = np.zeros(n)
        for tk in placer.tickets.values():
            for v in tk.mapping.route:
                if v not in (tk.df.src, tk.df.dst):
                    load[v] += 1
        if load.max() > 0:
            state["failed"] = int(load.argmax())
            state["cycles"] += 1
            return state["failed"]
        return None

    # ---- weighted: the control plane ------------------------------------
    cp = ControlPlane(rg, policy=FairSharePolicy(slack=0.4),
                      micro_batch=micro_batch, max_attempts=10,
                      use_kernel=use_kernel)
    # one warmup covers both runs: the FCFS placer below hits the same
    # process-wide jit cache entries
    cp.warmup(max_batch=int(max(micro_batch, 4 * rate_total * tick)), p=p)
    for t in names:
        cp.register_tenant(t, weight=w[t])
    # departure entries carry (rid, tid): a request displaced to the queue
    # and later re-admitted gets a NEW ticket (new tid) and a new timer —
    # its stale entry must not release it early.  In-place re-mapping
    # preserves the tid, so those entries stay valid.
    dep: list[tuple[float, int, int]] = []
    scheduled: dict[int, int] = {}  # rid -> tid of the armed entry
    samples = {t: [] for t in names}
    backlogged_ticks = total_ticks = 0
    state = {"next_fail": fail_every, "failed": None, "cycles": 0}
    idx = {t: 0 for t in names}
    drng = np.random.default_rng(seed + 1)

    def _arm(tk, when):
        rid = cp.rid_of(tk)
        if rid is not None and scheduled.get(rid) != tk.tid:
            scheduled[rid] = tk.tid
            heapq.heappush(dep, (when, rid, tk.tid))

    now = 0.0
    while now < horizon:
        now = min(now + tick, horizon)
        while dep and dep[0][0] <= now:
            _, rid, tid = heapq.heappop(dep)
            entry = cp.active.get(rid)
            if entry is not None and entry[1].tid == tid:
                cp.release(rid)
                scheduled.pop(rid, None)
        victim = _churn_tick(cp.placer, now, state)
        if victim is not None:
            alive, _requeued = cp.fail_node(victim)
            for tk in alive:  # preemptive rescues carry a NEW tid: arm them
                _arm(tk, now + drng.exponential(hold))
        for t in names:
            while idx[t] < len(arrivals[t]) and arrivals[t][idx[t]] <= now:
                cp.submit(t, reqs[t][idx[t]])
                idx[t] += 1
        for tk in cp.pump():
            _arm(tk, now + drng.exponential(hold))
        if now >= warmup:
            held = cp.committed_capacity()
            for t in names:
                samples[t].append(held[t])
            total_ticks += 1
            backlogged_ticks += all(
                cp.tenants[t].queue for t in names
            )
    cp.check_invariants()

    def _shares(mean_held):
        total = sum(mean_held.values())
        actual = {t: mean_held[t] / max(total, 1e-12) for t in names}
        dev = {t: abs(actual[t] - frac[t]) / frac[t] for t in names}
        return actual, dev

    mean_w = {t: float(np.mean(samples[t])) for t in names}
    actual_w, dev_w = _shares(mean_w)
    weighted = {
        "mean_committed": mean_w,
        "actual_fractions": actual_w,
        "target_fractions": frac,
        "deviation": dev_w,
        "max_deviation": max(dev_w.values()),
        "backlogged_frac": backlogged_ticks / max(total_ticks, 1),
        "preempted": cp.placer.stats.preempted,
        "dropped": cp.conservation()["dropped"],
        "queued_end": cp.conservation()["queued"],
        "conservation_ok": cp.conservation()["ok"],
    }

    # ---- defrag on the churn-fragmented end state -----------------------
    if state["failed"] is not None:  # run against the fully-restored net
        cp.restore_node(state["failed"])
        state["failed"] = None
    queued_before = cp.conservation()["queued"]
    res = cp.defrag()
    cp.check_invariants()
    defrag_rec = {
        "churn_cycles": state["cycles"],
        "standing": res.standing,
        "queued_before": queued_before,
        "committed": res.committed,
        "repacked": res.repacked,
        "objective_before": list(res.objective_before),
        "objective_after": list(res.objective_after),
        "moved": res.moved,
        "readmitted": len(res.readmitted),
        "never_regresses": res.objective_after >= res.objective_before,
        "invariants_ok": True,
    }

    # ---- FCFS baseline: same traces through the bare placer -------------
    placer = OnlinePlacer(rg, use_kernel=use_kernel)
    merged = sorted(
        (at, t, i)
        for t in names for i, at in enumerate(arrivals[t])
    )
    dep2: list[tuple[float, int]] = []
    samples2 = {t: [] for t in names}
    state2 = {"next_fail": fail_every, "failed": None, "cycles": 0}
    drng2 = np.random.default_rng(seed + 1)
    j = 0
    now = 0.0
    while now < horizon:
        now = min(now + tick, horizon)
        while dep2 and dep2[0][0] <= now:
            _, tid = heapq.heappop(dep2)
            if tid in placer.tickets:
                placer.release(tid)
        victim = _churn_tick(placer, now, state2)
        if victim is not None:
            placer.fail_node(victim)
        batch, metas = [], []
        while j < len(merged) and merged[j][0] <= now:
            _, t, i = merged[j]
            batch.append(reqs[t][i])
            metas.append((t, 0))
            j += 1
        for tk in placer.admit_many(batch, metas=metas):
            if tk is not None:
                heapq.heappush(dep2, (now + drng2.exponential(hold), tk.tid))
        if now >= warmup:
            held = {t: 0.0 for t in names}
            for tk in placer.tickets.values():
                held[tk.tenant] += float(np.sum(tk.df.creq))
            for t in names:
                samples2[t].append(held[t])
    placer.check_invariants()
    mean_f = {t: float(np.mean(samples2[t])) for t in names}
    actual_f, dev_f = _shares(mean_f)
    fcfs = {
        "mean_committed": mean_f,
        "actual_fractions": actual_f,
        "target_fractions": frac,
        "deviation": dev_f,
        "max_deviation": max(dev_f.values()),
    }

    record = {
        "n": n, "p": p, "knee_rate": float(knee_rate),
        "overload_factor": overload_factor, "rate_total": rate_total,
        "weights": w, "hold": hold, "horizon": horizon, "tick": tick,
        "fail_every": fail_every, "warmup": warmup,
        "micro_batch": micro_batch, "use_kernel": use_kernel,
        "weighted": weighted,
        "fcfs": fcfs,
        "defrag": defrag_rec,
        "criterion": {
            "weighted_within_10pct": weighted["max_deviation"] <= 0.10,
            "fcfs_deviation_gt_25pct": fcfs["max_deviation"] > 0.25,
            "defrag_never_regresses": defrag_rec["never_regresses"],
            "defrag_readmitted_any": defrag_rec["readmitted"] >= 1,
        },
    }
    if out_path is not None:
        with open(out_path, "w") as f:
            json.dump(record, f, indent=2)
    return record


def run():
    rows = run_archs()
    rec = run_online()
    rows.append({
        "name": "placement_online_service",
        "us_per_call": 1e6 * rec["batched_s"] / rec["n_requests"],
        "derived": (
            f"speedup_batched={rec['speedup']:.1f}x;"
            f"speedup_kernel={rec['speedup_kernel']:.1f}x;"
            f"admitted={rec['admitted']}/{rec['n_requests']};"
            f"agreement={rec['agreement']:.2f};"
            f"churn_remapped={rec['churn']['remapped']}/"
            f"{rec['churn']['displaced']}"
        ),
    })
    swrec = run_overload_sweep()
    swrec = merge_cache_fastpath(swrec, run_cache_fastpath())
    srec = swrec["baseline"]
    rows.append({
        "name": "placement_streaming_poisson",
        "us_per_call": 1e3 * srec["admit_ms_mean"],
        "derived": (
            f"admission_rate={srec['admission_rate']:.2f};"
            f"occupancy={srec['steady_state_occupancy']:.1f};"
            f"remap_ms_p95={srec['remap_ms_p95']:.1f};"
            f"dropped={srec['dropped']};"
            f"knee_rate={swrec['knee']['rate']:.0f}"
        ),
    })
    crec = swrec["cache"]
    rows.append({
        "name": "placement_cache_fastpath",
        "us_per_call": 1e3 * crec["on"]["admit_ms_mean"],
        "derived": (
            f"p95_ratio={crec['p95_ratio']:.2f};"
            f"hit_rate={crec['on']['hit_rate']:.2f};"
            f"warm_solves={crec['on']['warm_solves']};"
            f"solves_on={crec['on']['solves']};"
            f"solves_off={crec['off']['solves']}"
        ),
    })
    frec = run_fairness(knee_rate=swrec["knee"]["rate"])
    rows.append({
        "name": "placement_fairness_overload",
        "us_per_call": 0.0,
        "derived": (
            f"weighted_dev={frec['weighted']['max_deviation']:.3f};"
            f"fcfs_dev={frec['fcfs']['max_deviation']:.3f};"
            f"defrag_readmitted={frec['defrag']['readmitted']};"
            f"preempted={frec['weighted']['preempted']}"
        ),
    })
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="online + streaming + fairness only, small sizes "
                         "(CI artifact)")
    args = ap.parse_args()
    if args.smoke:
        rec = run_online(
            n=24, n_requests=64, micro_batch=64,
            curve_kwargs=dict(n_list=(16, 24), batch_list=(1, 8, 32),
                              reps=20),
        )
        swrec = run_overload_sweep(
            n=20, rates=(24.0, 48.0, 96.0, 192.0), horizon=5.0,
            baseline_rate=16.0,
        )
        swrec = merge_cache_fastpath(swrec, run_cache_fastpath(n=20))
        frec = run_fairness(knee_rate=swrec["knee"]["rate"], n=20,
                            horizon=6.0, warmup=2.0)
    else:
        rec = run_online()
        swrec = run_overload_sweep()
        swrec = merge_cache_fastpath(swrec, run_cache_fastpath())
        frec = run_fairness(knee_rate=swrec["knee"]["rate"])
    print(json.dumps(
        {"online": rec, "streaming": swrec, "fairness": frec}, indent=2))
