"""Placement-engine benchmark: BCPM planning for every assigned architecture
on the 2-pod slice graph (quality = end-to-end route latency; time = solver
wall clock, warm jit)."""
from __future__ import annotations

import time

from repro.configs import ARCHS, get_config
from repro.launch.placement import PodTopology, plan_pipeline
from repro.models.config import SHAPES


def run():
    rows = []
    topo = PodTopology(pods=2)
    for arch in ARCHS:
        cfg = get_config(arch)
        plan_pipeline(cfg, SHAPES["train_4k"], topo, steps_per_sec=0.05,
                      dst_slice=topo.n_slices - 1)  # warm
        t0 = time.perf_counter()
        plan = plan_pipeline(cfg, SHAPES["train_4k"], topo, steps_per_sec=0.05,
                             dst_slice=topo.n_slices - 1)
        dt = time.perf_counter() - t0
        rows.append({
            "name": f"placement_{arch}",
            "us_per_call": 1e6 * dt,
            "derived": (
                f"stages={len(plan.stage_slices)};latency_us={plan.latency_us:.1f};"
                f"route_len={len(plan.route)}" if plan else "infeasible"
            ),
        })
    return rows
