"""Placement-engine benchmark.

1. BCPM planning for every assigned architecture on the 2-pod slice graph
   (quality = end-to-end route latency; time = solver wall clock, warm jit).
2. Online multi-request placement service (``core.online.OnlinePlacer``):
   micro-batched vmapped-DP throughput vs a sequential ``solve()`` loop on
   the same request stream, plus an admission + churn exercise with
   residual-capacity invariants checked.

``python -m benchmarks.bench_placement [--smoke]`` writes the online-service
numbers to ``BENCH_placement.json`` (the CI smoke artifact).
"""
from __future__ import annotations

import json
import time

from repro.core import OnlinePlacer, random_dataflow, solve, solve_batch, waxman
from repro.launch.placement import PodTopology, plan_pipeline


def run_archs():
    from repro.configs import ARCHS, get_config
    from repro.models.config import SHAPES

    rows = []
    topo = PodTopology(pods=2)
    for arch in ARCHS:
        cfg = get_config(arch)
        plan_pipeline(cfg, SHAPES["train_4k"], topo, steps_per_sec=0.05,
                      dst_slice=topo.n_slices - 1)  # warm
        t0 = time.perf_counter()
        plan = plan_pipeline(cfg, SHAPES["train_4k"], topo, steps_per_sec=0.05,
                             dst_slice=topo.n_slices - 1)
        dt = time.perf_counter() - t0
        rows.append({
            "name": f"placement_{arch}",
            "us_per_call": 1e6 * dt,
            "derived": (
                f"stages={len(plan.stage_slices)};latency_us={plan.latency_us:.1f};"
                f"route_len={len(plan.route)}" if plan else "infeasible"
            ),
        })
    return rows


def _request_stream(rg, n_requests: int, p: int, seed0: int):
    """Light concurrent requests: many fit the shared network at once."""
    return [
        random_dataflow(rg, p, seed=seed0 + i,
                        creq_range=(0.02, 0.15), breq_range=(0.5, 4.0))
        for i in range(n_requests)
    ]


def run_online(*, n: int = 24, p: int = 6, n_requests: int = 128,
               micro_batch: int = 64, seed: int = 7,
               out_path: str = "BENCH_placement.json"):
    rg = waxman(n, seed=seed)
    dfs = _request_stream(rg, n_requests, p, seed0=1000)

    # warm both jit paths (single-request and batched shapes)
    solve(rg, dfs[0], method="leastcost_jax")
    solve_batch(rg, dfs[:micro_batch], method="leastcost_jax")

    t0 = time.perf_counter()
    seq = [solve(rg, df, method="leastcost_jax")[0] for df in dfs]
    t_seq = time.perf_counter() - t0

    t0 = time.perf_counter()
    bat = []
    for i in range(0, n_requests, micro_batch):
        ms, _ = solve_batch(rg, dfs[i:i + micro_batch], method="leastcost_jax")
        bat.extend(ms)
    t_bat = time.perf_counter() - t0

    agree = sum(
        (a is None) == (b is None)
        and (a is None or abs(a.cost - b.cost) < 1e-3)
        for a, b in zip(seq, bat)
    )

    # admission + churn against residual capacity
    placer = OnlinePlacer(rg)
    tickets = []
    for i in range(0, n_requests, micro_batch):
        tickets.extend(placer.admit_many(dfs[i:i + micro_batch]))
    placer.check_invariants()
    admitted_stream = placer.stats.admitted  # before churn re-admissions
    busiest = max(
        (v for t in tickets if t for v in t.mapping.route
         if v not in (t.df.src, t.df.dst)),
        key=lambda v: sum(v in t.mapping.route for t in tickets if t),
        default=0,
    )
    remapped, dropped = placer.fail_node(busiest)
    placer.check_invariants()

    record = {
        "n": n, "p": p, "n_requests": n_requests, "micro_batch": micro_batch,
        "sequential_s": t_seq, "batched_s": t_bat,
        "speedup": t_seq / max(t_bat, 1e-9),
        "agreement": agree / n_requests,
        "admitted": admitted_stream,
        "admitted_total": placer.stats.admitted,  # incl. churn re-admissions
        "rejected": placer.stats.rejected,
        "batch_conflicts": placer.stats.batch_conflicts,
        "churn": {
            "failed_node": int(busiest),
            "displaced": len(remapped) + len(dropped),
            "remapped": len(remapped),
            "dropped": len(dropped),
        },
        "invariants_ok": True,
    }
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    return record


def run():
    rows = run_archs()
    rec = run_online()
    rows.append({
        "name": "placement_online_service",
        "us_per_call": 1e6 * rec["batched_s"] / rec["n_requests"],
        "derived": (
            f"speedup_batched={rec['speedup']:.1f}x;"
            f"admitted={rec['admitted']}/{rec['n_requests']};"
            f"agreement={rec['agreement']:.2f};"
            f"churn_remapped={rec['churn']['remapped']}/"
            f"{rec['churn']['displaced']}"
        ),
    })
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="online service only, small sizes (CI artifact)")
    args = ap.parse_args()
    if args.smoke:
        rec = run_online(n=24, n_requests=64, micro_batch=64)
    else:
        rec = run_online()
    print(json.dumps(rec, indent=2))
