"""Trace replay: the hierarchy's scaling claim under a realistic workload.

One pre-generated trace — heavy-tailed (Pareto-modulated Poisson)
arrivals, a diurnal load sinusoid, 80/15/5 leaf/block/anywhere endpoint
locality, exponential holds, and correlated regional churn (a burst of
co-located node failures, restored a few rounds later) — is replayed
bit-for-bit over a flat regional plane and 2-/3-level hierarchical
planes built on the same ``region_tree`` topology (1k–10k nodes).

Reported per plane: steady-state admission rate, p50/p99 admit latency
in pump rounds, max per-component resident state
(``resident_state_report``), coordination messages per round (gossip +
2PC across every level), drops, and wall clock.  The acceptance gates
(``criterion``) encode the ISSUE's claims: at n >= 1000 the 2-level
plane's max resident component is strictly below the flat plane's,
steady-state admission stays within 5 points, and the smoke run fits
the CI slow-lane wall-clock budget.

    PYTHONPATH=src python benchmarks/bench_trace.py --smoke   # CI, n=1024
    PYTHONPATH=src python benchmarks/bench_trace.py           # adds n=4096
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core import DataflowPath, region_line, region_tree
from repro.obs import (
    Tracer,
    reconstruct_request,
    text_timeline,
    validate_chrome_trace,
    write_chrome_trace,
)
from repro.service import ControlPlane

TENANTS = ("svc-a", "svc-b", "batch", "edge")
SMOKE_WALLCLOCK_BUDGET_S = 300.0  # measured ~7s locally; CI-CPU headroom


# -- trace generation ---------------------------------------------------------

def build_trace(
    n: int,
    assign: np.ndarray,
    block: int,
    *,
    rounds: int,
    warmup: int,
    base_rate: float,
    hold_mean: float = 8.0,
    churn_period: int = 12,
    churn_down: int = 3,
    seed: int = 0,
):
    """Pre-generate the whole workload; every plane replays it verbatim.

    ``block`` is the leaf-block size for the 15% "nearby" locality class
    (endpoints in sibling leaves under one parent — crosses only the
    lowest cut); 5% of requests pick a uniformly random leaf and may
    cross the top-level cut.
    """
    rng = np.random.default_rng(seed)
    leaves = int(assign.max()) + 1
    k = n // leaves
    events: list[dict] = []
    churn: list[tuple[int, str, list[int]]] = []
    for t in range(rounds):
        diurnal = 1.0 + 0.6 * np.sin(2.0 * np.pi * t / 24.0)
        burst = min(1.0 + float(rng.pareto(2.5)), 8.0)  # heavy tail, capped
        for _ in range(int(rng.poisson(base_rate * diurnal * burst))):
            tenant = TENANTS[int(rng.integers(len(TENANTS)))]
            leaf = int(rng.integers(leaves))
            src = leaf * k + int(rng.integers(k))
            u = float(rng.random())
            if u < 0.80:
                dleaf = leaf
            elif u < 0.95:
                dleaf = (leaf // block) * block + int(rng.integers(block))
            else:
                dleaf = int(rng.integers(leaves))
            dst = dleaf * k + int(rng.integers(k))
            if dst == src:
                dst = dleaf * k + (src - dleaf * k + 1) % k
            p = int(rng.integers(3, 6))
            creq = rng.uniform(0.3, 1.5, size=p).astype(np.float32)
            creq[0] = creq[-1] = 0.0
            breq = rng.uniform(4.0, 18.0, size=p - 1).astype(np.float32)
            events.append({
                "round": t,
                "tenant": tenant,
                "df": DataflowPath(creq, breq, src, dst),
                "hold": max(1, int(rng.exponential(hold_mean))),
                "klass": int(rng.integers(3)),
            })
        # correlated regional churn: a co-located burst in one leaf
        if t >= warmup and t % churn_period == 0:
            leaf = int(rng.integers(leaves))
            down = [leaf * k + i for i in range(max(1, k // 4))]
            churn.append((t, "fail", down))
            restore_at = t + churn_down
            if restore_at < rounds:
                churn.append((restore_at, "restore", down))
    return events, churn


# -- replay -------------------------------------------------------------------

def replay(make_plane, events, churn, *, rounds: int, warmup: int,
           label: str) -> dict:
    t0 = time.perf_counter()
    cp = make_plane()
    for t in TENANTS:
        cp.register_tenant(t, weight=1.0)
    by_round: dict[int, list] = {}
    for ev in events:
        by_round.setdefault(ev["round"], []).append(ev)
    churn_by_round: dict[int, list] = {}
    for r, kind, nodes in churn:
        churn_by_round.setdefault(r, []).append((kind, nodes))

    pending: dict[int, dict] = {}  # rid -> {sub, expiry, adm}
    steady_sub = steady_adm = 0
    latencies: list[int] = []
    for t in range(rounds):
        for kind, nodes in churn_by_round.get(t, []):
            for v in nodes:
                cp.fail_node(v) if kind == "fail" else cp.restore_node(v)
        for ev in by_round.get(t, []):
            rid = cp.submit(ev["tenant"], ev["df"], klass=ev["klass"])
            pending[rid] = {"sub": t, "expiry": t + ev["hold"], "adm": None}
            if t >= warmup:
                steady_sub += 1
        cp.pump(rounds=1)
        active = set(cp.active_ids())
        for rid, info in pending.items():
            if info["adm"] is None and rid in active:
                info["adm"] = t
                if info["sub"] >= warmup:
                    steady_adm += 1
                    latencies.append(t - info["sub"])
        # holds expire relative to the submit round (trace-determined, so
        # identical across planes); an un-admitted rid stays pending and
        # is released on the first round it IS active past expiry
        for rid in [r for r, i in pending.items()
                    if i["expiry"] <= t and r in active]:
            cp.release(rid)
            del pending[rid]

    cp.check_invariants()
    led = cp.conservation()
    cr = cp.coordination_report()
    if "children" in cr:  # hierarchical: totals aggregated over all levels
        msgs = cr["gossip_messages_total"] + cr["twopc_messages_total"]
    else:
        msgs = cr["gossip_messages"] + cr["twopc_messages"]
    # incremental-fast-path columns, summed over every per-region placer
    # through the plane's merged metrics registry (zero when disabled)
    reg = cp.metrics_registry()
    lat = np.asarray(latencies, np.float64)
    return {
        "plane": label,
        "cache_hits": int(reg.total("placer.cache_hits")),
        "cache_misses": int(reg.total("placer.cache_misses")),
        "cache_stale": int(reg.total("placer.cache_stale")),
        "warm_solves": int(reg.total("placer.warm_solves")),
        "steady_submitted": steady_sub,
        "steady_admitted": steady_adm,
        "admission_rate": round(steady_adm / max(steady_sub, 1), 4),
        "p50_admit_rounds": float(np.percentile(lat, 50)) if lat.size else -1.0,
        "p99_admit_rounds": float(np.percentile(lat, 99)) if lat.size else -1.0,
        "max_component_state": cp.resident_state_report()[
            "max_component_state"],
        "max_solve_n": cp.solve_size_report()["max_solve_n"],
        "messages_per_round": round(msgs / rounds, 2),
        "dropped": led["dropped"],
        "conservation_ok": bool(led["ok"]),
        "wallclock_s": round(time.perf_counter() - t0, 2),
    }


# -- scenarios ----------------------------------------------------------------

def run_scenario(levels_phys: int, branching_phys: int, k: int, *,
                 rounds: int, warmup: int, base_rate: float,
                 plane_cfgs, method: str = "leastcost_python",
                 seed: int = 11) -> dict:
    rg, assign = region_tree(levels_phys, branching_phys, k, seed=seed)
    events, churn = build_trace(
        rg.n, assign, branching_phys,
        rounds=rounds, warmup=warmup, base_rate=base_rate, seed=seed + 1,
    )
    planes = []
    for label, kw in plane_cfgs:
        planes.append(replay(
            lambda kw=kw: ControlPlane(
                rg, region_of=assign, method=method, seed=5, **kw),
            events, churn, rounds=rounds, warmup=warmup, label=label,
        ))
    return {
        "n": rg.n,
        "leaf_regions": int(assign.max()) + 1,
        "k": k,
        "rounds": rounds,
        "warmup": warmup,
        "arrivals": len(events),
        "churn_events": len(churn),
        "planes": planes,
    }


def run_json(smoke: bool = False, out_path: str = "BENCH_trace.json") -> dict:
    t0 = time.perf_counter()
    scenarios = []
    # n=1024: 64 16-node leaves; flat R=64 vs 2-level (8x8) vs 3-level (4^3)
    scenarios.append(run_scenario(
        3, 4, 16, rounds=36, warmup=12, base_rate=12.0,
        plane_cfgs=[
            ("flat", {}),
            ("2-level", {"levels": 2, "branching": 8}),
            ("3-level", {"levels": 3, "branching": 4}),
        ],
    ))
    if not smoke:
        # n=4096: same leaf count, 64-node leaves — resident state scales
        # with n_leaf, the broker tables do not
        scenarios.append(run_scenario(
            3, 4, 64, rounds=36, warmup=12, base_rate=12.0,
            plane_cfgs=[
                ("flat", {}),
                ("2-level", {"levels": 2, "branching": 8}),
                ("3-level", {"levels": 3, "branching": 4}),
            ],
        ))
    wallclock = time.perf_counter() - t0

    def plane(sc, name):
        return next(p for p in sc["planes"] if p["plane"] == name)

    big = [sc for sc in scenarios if sc["n"] >= 1000]
    report = {
        "bench": "trace_replay",
        "smoke": smoke,
        "wallclock_s": round(wallclock, 2),
        "scenarios": scenarios,
        "criterion": {
            # ISSUE gate 1: at n >= 1000 the 2-level plane's largest
            # resident component is STRICTLY below the flat plane's
            "hier_state_strictly_smaller": all(
                plane(sc, "2-level")["max_component_state"]
                < plane(sc, "flat")["max_component_state"]
                for sc in big
            ),
            # ISSUE gate 2: steady-state admission within 5 points of flat
            "admission_within_5pts": all(
                abs(plane(sc, name)["admission_rate"]
                    - plane(sc, "flat")["admission_rate"]) <= 0.05
                for sc in big for name in ("2-level", "3-level")
            ),
            # every plane's ledger balanced after churn + replay
            "conservation_ok": all(
                p["conservation_ok"] for sc in scenarios
                for p in sc["planes"]
            ),
            # no plane ever solved over more than a leaf-sized slice
            "solves_leaf_local": all(
                p["max_solve_n"] <= sc["k"] for sc in scenarios
                for p in sc["planes"]
            ),
            # CI slow-lane budget (smoke runs only)
            "within_wallclock_budget": (
                wallclock <= SMOKE_WALLCLOCK_BUDGET_S or not smoke
            ),
        },
    }
    report["ok"] = all(report["criterion"].values())
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    return report


def run_scale10k(out_path: str = "BENCH_trace10k.json", *,
                 rounds: int = 24, warmup: int = 8,
                 base_rate: float = 12.0) -> dict:
    """The ROADMAP's full 10k-node scheduled-lane point: one trace over
    ``region_tree(4, 4, 40)`` (256 40-node leaves, n=10240), replayed on
    the flat R=256 plane and the 2-level (16x16) hierarchy, each with the
    incremental fast path on and off.  Fewer rounds than the 1k/4k
    scenarios — at this scale each round already spans hundreds of
    region-local solves, and the point of the run is the scaling shape
    (resident state, admission, cache traffic), not tail quantiles."""
    t0 = time.perf_counter()
    sc = run_scenario(
        4, 4, 40, rounds=rounds, warmup=warmup, base_rate=base_rate,
        plane_cfgs=[
            ("flat", {}),
            ("flat-nocache", {"cache_enabled": False}),
            ("2-level", {"levels": 2, "branching": 16}),
            ("2-level-nocache",
             {"levels": 2, "branching": 16, "cache_enabled": False}),
        ],
    )
    wallclock = time.perf_counter() - t0

    def plane(name):
        return next(p for p in sc["planes"] if p["plane"] == name)

    report = {
        "bench": "trace_replay_10k",
        "wallclock_s": round(wallclock, 2),
        "scenario": sc,
        "criterion": {
            # the hierarchy's scaling claim holds at the full 10k point
            "hier_state_strictly_smaller":
                plane("2-level")["max_component_state"]
                < plane("flat")["max_component_state"],
            # the fast path pays for itself in traffic without costing
            # admitted work, at both plane shapes
            "cache_hits_positive": all(
                plane(name)["cache_hits"] > 0
                for name in ("flat", "2-level")
            ),
            "cache_admission_within_5pts": all(
                abs(plane(name)["admission_rate"]
                    - plane(f"{name}-nocache")["admission_rate"]) <= 0.05
                for name in ("flat", "2-level")
            ),
            "conservation_ok": all(
                p["conservation_ok"] for p in sc["planes"]),
            "solves_leaf_local": all(
                p["max_solve_n"] <= sc["k"] for p in sc["planes"]),
        },
    }
    report["ok"] = all(report["criterion"].values())
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    return report


def run_trace_export(out_path: str = "BENCH_trace_events.json",
                     *, seed: int = 9) -> dict:
    """Export a Perfetto/Chrome-trace JSON of one spanning request's full
    lifecycle over a line-of-regions plane: submit -> chained 2PC reserves
    across >= 2 regions -> commit -> release, plus the gossip rounds and
    per-region solve spans around it.  The exported file loads in
    ui.perfetto.dev / chrome://tracing; the acceptance check here is that
    the flow events reconstruct the lifecycle in order."""
    rng = np.random.default_rng(seed)
    R, k = 3, 4
    rg, assign = region_line(R, k, seed=seed)
    tracer = Tracer()
    cp = ControlPlane(
        rg, region_of=assign, method="leastcost_python", seed=seed,
        micro_batch=8, fanout=2, tracer=tracer,
    )
    cp.register_tenant("svc-a", weight=1.0)

    def mkdf(r1, r2, p):
        src = int(rng.choice(np.nonzero(assign == r1)[0]))
        dst = int(rng.choice(np.nonzero(assign == r2)[0]))
        creq = rng.uniform(0.02, 0.15, p).astype(np.float32)
        creq[0] = creq[-1] = 0.0
        breq = rng.uniform(0.5, 2.0, p - 1).astype(np.float32)
        return DataflowPath(creq, breq, src, dst)

    # background in-region traffic so the trace shows regional solve spans
    bg = [cp.submit("svc-a", mkdf(r, r, 3), klass=0) for r in range(R)]
    # THE spanning request: endpoints 2 regions apart -> chain r0-r1-r2
    rid = cp.submit("svc-a", mkdf(0, R - 1, 5), klass=1)
    for _ in range(6):
        cp.pump(rounds=1)
        if rid in cp.active_ids():
            break
    admitted = rid in cp.active_ids()
    if admitted:
        cp.release(rid)
    for b in bg:
        if b in cp.active_ids():
            cp.release(b)
    cp.check_invariants()

    doc = write_chrome_trace(tracer, out_path)
    errors = validate_chrome_trace(doc)
    life = reconstruct_request(doc, rid)
    names = [e["name"] for e in life]
    reserves = {e["args"]["region"] for e in life
                if e["name"] == "2pc.reserve" and "args" in e}
    lifecycle_ok = (
        admitted
        and names[:1] == ["submit"]
        and len(reserves) >= 2
        and "2pc.commit" in names
        and names[-1] == "release"
    )
    report = {
        "bench": "trace_export",
        "out": out_path,
        "events": len(doc["traceEvents"]),
        "spanning_rid": rid,
        "lifecycle": names,
        "regions_reserved": sorted(reserves),
        "criterion": {
            "schema_valid": not errors,
            "spanning_lifecycle_reconstructable": lifecycle_ok,
        },
        "schema_errors": errors[:8],
        "timeline": text_timeline(tracer, max_rows=12),
    }
    report["ok"] = all(report["criterion"].values())
    return report


def run(smoke: bool = True):
    """benchmarks.run harness hook: one CSV row per plane per scenario."""
    rep = run_json(smoke=smoke, out_path="BENCH_trace.json")
    rows = []
    for sc in rep["scenarios"]:
        for p in sc["planes"]:
            rows.append({
                "name": f"trace_n{sc['n']}_{p['plane']}",
                "us_per_call": 1e6 * p["wallclock_s"] / max(sc["rounds"], 1),
                "derived": (
                    f"admit={p['admission_rate']};"
                    f"p99_rounds={p['p99_admit_rounds']};"
                    f"state={p['max_component_state']};"
                    f"msgs_per_round={p['messages_per_round']}"
                ),
            })
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="n=1024 only; CI slow-lane budget")
    ap.add_argument("--out", default="BENCH_trace.json")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="export a Perfetto/Chrome-trace JSON of one "
                         "spanning request's lifecycle and exit (skips "
                         "the replay benchmark)")
    ap.add_argument("--scale10k", action="store_true",
                    help="the scheduled-lane n=10240 point (flat vs "
                         "2-level, cache on/off) -> BENCH_trace10k.json; "
                         "skips the regular replay benchmark")
    args = ap.parse_args()
    if args.scale10k:
        rep = run_scale10k()
        sc = rep["scenario"]
        for p in sc["planes"]:
            print(f"n={sc['n']:5d} {p['plane']:16s} "
                  f"admit={p['admission_rate']:.3f} "
                  f"state={p['max_component_state']} "
                  f"hits={p['cache_hits']} warm={p['warm_solves']} "
                  f"wall={p['wallclock_s']}s")
        print(json.dumps(rep["criterion"], indent=2))
        print(f"ok={rep['ok']} wallclock={rep['wallclock_s']}s "
              "-> BENCH_trace10k.json")
        raise SystemExit(0 if rep["ok"] else 1)
    if args.trace_out is not None:
        rep = run_trace_export(args.trace_out)
        print(rep["timeline"])
        print(f"lifecycle: {' -> '.join(rep['lifecycle'])}")
        print(f"regions reserved: {rep['regions_reserved']}")
        print(json.dumps(rep["criterion"], indent=2))
        print(f"{rep['events']} events -> {args.trace_out} "
              "(load in ui.perfetto.dev)")
        raise SystemExit(0 if rep["ok"] else 1)
    rep = run_json(smoke=args.smoke, out_path=args.out)
    for sc in rep["scenarios"]:
        for p in sc["planes"]:
            print(f"n={sc['n']:5d} {p['plane']:8s} "
                  f"admit={p['admission_rate']:.3f} "
                  f"p99={p['p99_admit_rounds']:.1f} "
                  f"state={p['max_component_state']} "
                  f"msgs/round={p['messages_per_round']} "
                  f"wall={p['wallclock_s']}s")
    print(json.dumps(rep["criterion"], indent=2))
    print(f"ok={rep['ok']} wallclock={rep['wallclock_s']}s -> {args.out}")
    raise SystemExit(0 if rep["ok"] else 1)
