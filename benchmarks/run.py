"""Benchmark harness: one module per paper table/claim.  Prints
``name,us_per_call,derived`` CSV (EXPERIMENTS.md cites these numbers),
then aggregates every ``BENCH_*.json`` artifact the suites wrote into
``BENCH_summary.json`` — a flat metric map plus a bounded trajectory of
previous summaries — and prints a one-screen delta table against the
previous record.

    PYTHONPATH=src python -m benchmarks.run [--quick]
    PYTHONPATH=src python -m benchmarks.run --summarize   # aggregate only
"""
import argparse
import glob
import json
import math
import os
import sys
import time

# non-record artifacts: the summary itself, and the Perfetto event dump
_SKIP = {"BENCH_summary.json", "BENCH_trace_events.json"}
_ENTRY_KEYS = ("generated_at", "sources", "criteria_pass",
               "criteria_failed", "metrics")


def _flatten(obj, prefix="", out=None, depth=0):
    """Dotted-path flattening of the scalar/bool leaves.  Short lists are
    indexed by their row label (``plane`` / ``name`` / ``pipeline_depth``)
    when they have one, so trajectory keys stay stable as rows reorder."""
    if out is None:
        out = {}
    if depth > 7:
        return out
    if isinstance(obj, dict):
        for k, v in obj.items():
            _flatten(v, f"{prefix}.{k}" if prefix else str(k), out,
                     depth + 1)
    elif isinstance(obj, bool):
        out[prefix] = obj
    elif isinstance(obj, (int, float)):
        if math.isfinite(obj):
            out[prefix] = obj
    elif isinstance(obj, list) and len(obj) <= 16:
        for i, v in enumerate(obj):
            label = i
            if isinstance(v, dict):
                label = v.get("plane") or v.get("name") \
                    or v.get("pipeline_depth") or i
            _flatten(v, f"{prefix}[{label}]", out, depth + 1)
    return out


def _print_delta(old_metrics, metrics, criteria, sources,
                 max_rows: int = 24) -> None:
    failed = sorted(k for k, v in criteria.items() if not v)
    print(f"\n== BENCH_summary: {len(metrics)} metrics "
          f"from {len(sources)} artifacts; "
          f"criteria {len(criteria) - len(failed)}/{len(criteria)} pass")
    for k in failed:
        print(f"   FAIL {k}")
    if not old_metrics:
        print("   (no previous summary — baseline recorded)")
        return
    rows = []
    for k, v in metrics.items():
        o = old_metrics.get(k)
        if isinstance(v, bool) or not isinstance(o, (int, float)) \
                or isinstance(o, bool) or o == v:
            continue
        rel = abs(v - o) / max(abs(o), 1e-12)
        rows.append((rel, k, o, v))
    if not rows:
        print("   (no numeric metric changed since the previous summary)")
        return
    rows.sort(reverse=True)
    print(f"   top deltas vs previous ({min(len(rows), max_rows)} "
          f"of {len(rows)} changed):")
    for rel, k, o, v in rows[:max_rows]:
        sign = "+" if v >= o else "-"
        print(f"   {k:64.64s} {o:>12.4g} -> {v:>12.4g}  "
              f"({sign}{100 * rel:.1f}%)")


def summarize(out_path: str = "BENCH_summary.json", directory: str = ".",
              trajectory_cap: int = 20, quiet: bool = False) -> dict:
    """Fold every ``BENCH_*.json`` in ``directory`` into one summary
    record.  The previous summary (if any) is pushed onto a bounded
    ``trajectory`` list, so the artifact carries its own history across
    CI runs; the delta table prints current vs previous."""
    files = sorted(
        f for f in glob.glob(os.path.join(directory, "BENCH_*.json"))
        if os.path.basename(f) not in _SKIP
    )
    metrics, criteria, sources = {}, {}, []
    for path in files:
        tag = os.path.basename(path)[len("BENCH_"):-len(".json")]
        try:
            with open(path) as fh:
                doc = json.load(fh)
        except (OSError, json.JSONDecodeError) as e:
            print(f"summarize: skipping {path}: {e}", file=sys.stderr)
            continue
        sources.append(os.path.basename(path))
        for k, v in _flatten(doc, tag).items():
            metrics[k] = v
            # every criterion gate and module-level ok flag, pass or fail
            if ".criterion." in k or k.endswith(".ok"):
                criteria[k] = bool(v)
    entry = {
        "generated_at": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "sources": sources,
        "criteria_pass": all(criteria.values()) if criteria else None,
        "criteria_failed": sorted(k for k, v in criteria.items() if not v),
        "metrics": metrics,
    }
    prev = None
    if os.path.exists(out_path):
        try:
            with open(out_path) as fh:
                prev = json.load(fh)
        except (OSError, json.JSONDecodeError):
            prev = None
    trajectory = []
    if prev:
        trajectory = list(prev.get("trajectory", []))
        trajectory.append({k: prev[k] for k in _ENTRY_KEYS if k in prev})
        trajectory = trajectory[-trajectory_cap:]
    summary = dict(entry, trajectory=trajectory)
    with open(out_path, "w") as fh:
        json.dump(summary, fh, indent=2, sort_keys=True)
        fh.write("\n")
    if not quiet:
        _print_delta(prev.get("metrics") if prev else None, metrics,
                     criteria, sources)
    return summary


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer instances")
    ap.add_argument("--only", default="", help="substring filter")
    ap.add_argument("--summarize", action="store_true",
                    help="skip the suites; aggregate existing BENCH_*.json "
                         "into BENCH_summary.json and print the delta table")
    args = ap.parse_args()

    if not args.summarize:
        from benchmarks import (
            bench_kernel, bench_messages, bench_optimality, bench_placement,
            bench_scaling, bench_trace,
        )

        suites = [
            ("optimality", lambda: bench_optimality.run(
                n_instances=10 if args.quick else 40)),
            ("messages", lambda: bench_messages.run(
                n_instances=8 if args.quick else 25)),
            ("scaling", lambda: bench_scaling.run(smoke=args.quick)),
            ("kernel", bench_kernel.run),
            ("placement", bench_placement.run),
            ("trace", lambda: bench_trace.run(smoke=True)),
        ]
        print("name,us_per_call,derived")
        for name, fn in suites:
            if args.only and args.only not in name:
                continue
            try:
                for row in fn():
                    print(f"{row['name']},{row['us_per_call']:.1f},"
                          f"\"{row['derived']}\"")
            except Exception as e:  # keep the harness running
                print(f"{name}_FAILED,0,\"{type(e).__name__}: {e}\"",
                      file=sys.stdout)
    summarize()
    sys.stdout.flush()


if __name__ == "__main__":
    main()
