"""Benchmark harness: one module per paper table/claim.  Prints
``name,us_per_call,derived`` CSV (EXPERIMENTS.md cites these numbers).

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer instances")
    ap.add_argument("--only", default="", help="substring filter")
    args = ap.parse_args()

    from benchmarks import (
        bench_kernel, bench_messages, bench_optimality, bench_placement,
        bench_scaling, bench_trace,
    )

    suites = [
        ("optimality", lambda: bench_optimality.run(
            n_instances=10 if args.quick else 40)),
        ("messages", lambda: bench_messages.run(
            n_instances=8 if args.quick else 25)),
        ("scaling", lambda: bench_scaling.run(smoke=args.quick)),
        ("kernel", bench_kernel.run),
        ("placement", bench_placement.run),
        ("trace", lambda: bench_trace.run(smoke=True)),
    ]
    print("name,us_per_call,derived")
    for name, fn in suites:
        if args.only and args.only not in name:
            continue
        try:
            for row in fn():
                print(f"{row['name']},{row['us_per_call']:.1f},\"{row['derived']}\"")
        except Exception as e:  # keep the harness running
            print(f"{name}_FAILED,0,\"{type(e).__name__}: {e}\"", file=sys.stdout)
    sys.stdout.flush()


if __name__ == "__main__":
    main()
