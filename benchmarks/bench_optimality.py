"""Paper §3.4.1, claim 1: LeastCostMap finds the optimum in ~99% of random
BRITE-style instances, with 100-1000x reduction in partial-map set size.

One row per (topology model, n): optimality rate, mean/max set-size
reduction vs the exact algorithm, fallback + validity rates for the
tensorized JAX DP.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (
    barabasi_albert, pathmap_exact, random_dataflow, solve, validate_mapping,
    waxman,
)


def run(n_instances: int = 40, sizes=(15, 25), p: int = 6, seed0: int = 0):
    rows = []
    for gen in (waxman, barabasi_albert):
        for n in sizes:
            opt_py = opt_jax = feas = 0
            ratios = []
            fallbacks = 0
            t_py = t_jax = 0.0
            for i in range(n_instances):
                rg = gen(n, seed=seed0 + i)
                df = random_dataflow(rg, p, seed=seed0 + 10_000 + i)
                try:
                    ex, est = pathmap_exact(rg, df, max_states=400_000)
                except MemoryError:
                    continue
                if ex is None:
                    continue
                feas += 1
                t0 = time.perf_counter()
                mp, pst = solve(rg, df, method="leastcost_python")
                t_py += time.perf_counter() - t0
                t0 = time.perf_counter()
                mj, jst = solve(rg, df, method="leastcost_jax")
                t_jax += time.perf_counter() - t0
                if mp is not None and abs(mp.cost - ex.cost) < 1e-4:
                    opt_py += 1
                if mj is not None and abs(mj.cost - ex.cost) < 1e-4:
                    opt_jax += 1
                if mj is not None:
                    ok, _ = validate_mapping(rg, df, mj)
                    assert ok
                fallbacks += int(jst.fallback_used)
                ratios.append(est.max_set_size / max(pst.max_set_size, 1))
            if feas == 0:
                continue
            rows.append({
                "name": f"optimality_{gen.__name__}_n{n}",
                "us_per_call": 1e6 * t_py / max(feas, 1),
                "derived": (
                    f"opt_py={opt_py/feas:.3f};opt_jax={opt_jax/feas:.3f};"
                    f"setsize_reduction_mean={np.mean(ratios):.1f}x;"
                    f"setsize_reduction_max={np.max(ratios):.0f}x;"
                    f"feasible={feas};jax_fallbacks={fallbacks};"
                    f"jax_us={1e6*t_jax/max(feas,1):.0f}"
                ),
            })
    return rows
