"""Relaxation hot-spot microbenchmark: the bandwidth-masked min-plus move
step.  On this CPU container the Pallas kernel runs in interpret mode
(correctness only — see tests/test_kernels.py); wall-clock here measures the
jnp oracle (the DP's CPU path) across problem sizes, and derives the
VMEM-roofline estimate for the TPU kernel from its tile configuration.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.minplus import masked_minplus_ref
from repro.kernels.minplus.minplus import BIG, K_TILE, V_TILE, W_TILE


def _inst(n, K, seed=0):
    rng = np.random.default_rng(seed)
    P = np.where(rng.random((n, K)) < 0.3, BIG, rng.random((n, K)) * 10)
    lat = np.where(rng.random((n, n)) < 0.6, BIG, rng.random((n, n)) * 5 + 0.1)
    bw = rng.random((n, n)) * 100
    breq = rng.random(K - 1) * 80
    return (jnp.asarray(P, jnp.float32), jnp.asarray(lat, jnp.float32),
            jnp.asarray(bw, jnp.float32), jnp.asarray(breq, jnp.float32))


def run():
    rows = []
    f = jax.jit(masked_minplus_ref)
    for n, K in [(128, 9), (512, 9), (1024, 17), (2048, 17)]:
        args = _inst(n, K)
        jax.block_until_ready(f(*args))  # warmup/compile
        reps = max(3, int(2e8 / (n * n * K)))
        t0 = time.perf_counter()
        for _ in range(reps):
            out = f(*args)
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / reps
        work = n * n * K  # min-plus "MACs"
        # TPU kernel VMEM estimate per grid step (v_tile x w_tile x k_tile
        # candidate block + input tiles, fp32)
        vmem = 4 * (V_TILE * W_TILE * K_TILE + V_TILE * K_TILE
                    + 2 * V_TILE * W_TILE + 2 * W_TILE * K_TILE)
        rows.append({
            "name": f"minplus_move_n{n}_K{K}",
            "us_per_call": 1e6 * dt,
            "derived": (
                f"gmacs_per_s={work/dt/1e9:.2f};"
                f"kernel_tiles={V_TILE}x{W_TILE}x{K_TILE};"
                f"kernel_vmem_bytes={vmem}"
            ),
        })
    return rows
