"""Relaxation hot-spot microbenchmark: the bandwidth-masked min-plus move
step, and the batched fused-superstep kernel's tile-size sweep.  On this CPU
container the Pallas kernels run in interpret mode (correctness only — see
tests/test_batched_kernel.py); wall-clock here measures the jnp oracles (the
DP's CPU paths) across problem sizes, and derives the VMEM model for the TPU
kernels from their tile configurations.

``python -m benchmarks.bench_kernel`` writes the batched-kernel sweep
(per-config interpret parity, VMEM-model bytes, fused-ref vs vmapped
timings, chosen defaults) to ``BENCH_kernel.json``.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.minplus import masked_minplus_ref
from repro.kernels.minplus.minplus import BIG, K_TILE, V_TILE, W_TILE


def _inst(n, K, seed=0):
    rng = np.random.default_rng(seed)
    P = np.where(rng.random((n, K)) < 0.3, BIG, rng.random((n, K)) * 10)
    lat = np.where(rng.random((n, n)) < 0.6, BIG, rng.random((n, n)) * 5 + 0.1)
    bw = rng.random((n, n)) * 100
    breq = rng.random(K - 1) * 80
    return (jnp.asarray(P, jnp.float32), jnp.asarray(lat, jnp.float32),
            jnp.asarray(bw, jnp.float32), jnp.asarray(breq, jnp.float32))


# Tile configs swept for the batched fused-superstep kernel.  Interpret-mode
# wall clock is an emulation (relative) number; the TPU-relevant criterion is
# the VMEM model: pick the largest network tiles that keep the double-
# buffered live set well inside ~16 MB, then the largest b_tile (each
# increment amortizes one more request onto the shared lat/bw tile fetch).
BATCHED_SWEEP = [
    (1, 8, 8, 8),
    (2, 8, 8, 8),
    (4, 8, 8, 8),
    (2, 16, 16, 8),
    (4, 16, 8, 4),
    (8, 16, 16, 8),
]


def run_batched_sweep(*, n: int = 12, ps=(4, 6, 3, 5), seed: int = 9,
                      out_path: str = "BENCH_kernel.json"):
    """Sweep (b_tile, v_tile, w_tile, k_tile) for the batched superstep:
    interpret-mode parity vs the fused-jnp oracle + per-config VMEM model,
    plus fused-ref vs vmapped-jnp DP timings at online-placer shapes."""
    from repro.core import random_dataflow, waxman
    from repro.core.leastcost import _leastcost_dp_batched
    from repro.core.problem import stack_requests
    from repro.kernels.minplus import batched as bk

    rg = waxman(n, seed=seed)
    dfs = [random_dataflow(rg, p, seed=seed * 100 + i,
                           creq_range=(0.02, 0.2), breq_range=(0.5, 5.0))
           for i, p in enumerate(ps)]
    tensors, p_max = stack_requests(rg, dfs)
    B = len(dfs)
    ref = _leastcost_dp_batched(tensors, B=B, n=n, p=p_max, max_rounds=n - 1,
                                impl="ref")
    sweep = []
    for tiles in BATCHED_SWEEP:
        b_t, v_t, w_t, k_t = tiles
        K_pad = -(-(p_max + 1) // k_t) * k_t
        t0 = time.perf_counter()
        out = _leastcost_dp_batched(tensors, B=B, n=n, p=p_max,
                                    max_rounds=n - 1, impl="interpret",
                                    tiles=tiles)
        jax.block_until_ready(out[0])
        t_first = time.perf_counter() - t0  # trace/lower/compile dominated
        t0 = time.perf_counter()
        out = _leastcost_dp_batched(tensors, B=B, n=n, p=p_max,
                                    max_rounds=n - 1, impl="interpret",
                                    tiles=tiles)
        jax.block_until_ready(out[0])
        t_warm = time.perf_counter() - t0  # pure emulated execution
        ok = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(ref[:5], out[:5])
        )
        sweep.append({
            "tiles": {"b": b_t, "v": v_t, "w": w_t, "k": k_t},
            "parity_vs_ref": ok,
            "first_call_s": t_first,
            "interpret_warm_s": t_warm,
            "vmem_model_bytes": bk.vmem_model_bytes(b_t, v_t, w_t, k_t, K_pad),
        })

    # fused-ref vs vmapped-jnp at the shapes the online placer sees
    from repro.core import solve_batch
    timings = []
    for nn, bb in [(16, 8), (24, 32)]:
        rg2 = waxman(nn, seed=3)
        dfs2 = [random_dataflow(rg2, 6, seed=500 + i, creq_range=(0.02, 0.15),
                                breq_range=(0.5, 4.0)) for i in range(bb)]
        solve_batch(rg2, dfs2, method="leastcost_jax")  # warm
        solve_batch(rg2, dfs2, method="leastcost_jax", use_kernel=True)
        t0 = time.perf_counter()
        solve_batch(rg2, dfs2, method="leastcost_jax")
        t_v = time.perf_counter() - t0
        t0 = time.perf_counter()
        solve_batch(rg2, dfs2, method="leastcost_jax", use_kernel=True)
        t_k = time.perf_counter() - t0
        timings.append({"n": nn, "batch": bb, "vmapped_s": t_v,
                        "fused_ref_s": t_k,
                        "speedup": t_v / max(t_k, 1e-9)})

    defaults = dict(zip(("b", "v", "w", "k"), bk.DEFAULT_TILES))
    record = {
        "defaults": defaults,
        "defaults_vmem_bytes": bk.vmem_model_bytes(*bk.DEFAULT_TILES, 8),
        "sweep": sweep,
        "fused_ref_vs_vmapped": timings,
        "note": (
            "first_call_s is trace/lower/compile of the interpret-mode grid "
            "(grows with grid size); interpret_warm_s is pure emulated "
            "execution — neither predicts TPU time; tile choice follows the "
            "VMEM model + largest b_tile"
        ),
    }
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    return record


def run():
    rows = []
    f = jax.jit(masked_minplus_ref)
    for n, K in [(128, 9), (512, 9), (1024, 17), (2048, 17)]:
        args = _inst(n, K)
        jax.block_until_ready(f(*args))  # warmup/compile
        reps = max(3, int(2e8 / (n * n * K)))
        t0 = time.perf_counter()
        for _ in range(reps):
            out = f(*args)
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / reps
        work = n * n * K  # min-plus "MACs"
        # TPU kernel VMEM estimate per grid step (v_tile x w_tile x k_tile
        # candidate block + input tiles, fp32)
        vmem = 4 * (V_TILE * W_TILE * K_TILE + V_TILE * K_TILE
                    + 2 * V_TILE * W_TILE + 2 * W_TILE * K_TILE)
        rows.append({
            "name": f"minplus_move_n{n}_K{K}",
            "us_per_call": 1e6 * dt,
            "derived": (
                f"gmacs_per_s={work/dt/1e9:.2f};"
                f"kernel_tiles={V_TILE}x{W_TILE}x{K_TILE};"
                f"kernel_vmem_bytes={vmem}"
            ),
        })
    rec = run_batched_sweep()
    ok = sum(s["parity_vs_ref"] for s in rec["sweep"])
    best = min(rec["fused_ref_vs_vmapped"], key=lambda r: r["fused_ref_s"])
    rows.append({
        "name": "batched_superstep_sweep",
        "us_per_call": 1e6 * best["fused_ref_s"],
        "derived": (
            f"parity={ok}/{len(rec['sweep'])};"
            f"defaults=b{rec['defaults']['b']}v{rec['defaults']['v']}"
            f"w{rec['defaults']['w']}k{rec['defaults']['k']};"
            f"vmem_bytes={rec['defaults_vmem_bytes']};"
            f"fused_vs_vmapped={best['speedup']:.2f}x"
        ),
    })
    return rows


if __name__ == "__main__":
    print(json.dumps(run_batched_sweep(), indent=2))
