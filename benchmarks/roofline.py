"""Roofline table generator: reads results/dryrun/*.json, emits the
per-(arch x shape x mesh) three-term roofline (EXPERIMENTS.md §Roofline).

Terms (per the assignment; quantities from the per-device SPMD module, so
the chips factor cancels):

    compute    = HLO_FLOPs_per_dev / peak          (197 TFLOP/s bf16)
    memory     = HLO_bytes_per_dev / HBM_bw        (819 GB/s)
    collective = coll_bytes_per_dev / link_bw      (50 GB/s/link ICI)

plus MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference) with N = active params,
and the usefulness ratio MODEL_FLOPS / HLO_FLOPs (remat/redundancy waste).
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def model_flops_global(rec: dict) -> float:
    n = rec["active_params"]
    if rec["kind"] == "train":
        tokens = rec["global_batch"] * rec["seq_len"]
        return 6.0 * n * tokens
    if rec["kind"] == "prefill":
        tokens = rec["global_batch"] * rec["seq_len"]
        return 2.0 * n * tokens
    tokens = rec["global_batch"]  # decode: one token per sequence
    return 2.0 * n * tokens


def analyze_record(rec: dict) -> dict:
    la = rec["loop_aware"]
    chips = rec["chips"]
    compute_s = la["flops"] / PEAK_FLOPS
    memory_s = la["bytes_hbm"] / HBM_BW
    coll_s = la["collective_bytes_total"] / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops_global(rec)
    hlo_global = la["flops"] * chips
    step_s = max(terms.values())
    mfu = mf / (chips * PEAK_FLOPS) / step_s if step_s > 0 else 0.0
    return {
        "cell": f'{rec["arch"]}__{rec["shape"]}__{rec["mesh"]}',
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "chips": chips,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "bottleneck": bottleneck,
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "roofline_frac": mfu,  # fraction of chips' peak the model-flops
        # achieve if the dominant term sets the step time
        "mem_gb": (rec["memory"]["temp_bytes"] or 0) / 1e9,
        "arg_gb": (rec["memory"]["argument_bytes"] or 0) / 1e9,
        "coll_detail": {k: v["bytes"] for k, v in la["collectives"].items()},
    }


def load_all(out_dir: str = "results/dryrun") -> list[dict]:
    rows = []
    for fn in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        if os.path.basename(fn).startswith("_"):
            continue
        with open(fn) as f:
            rec = json.load(f)
        if "loop_aware" not in rec:
            continue
        rows.append(analyze_record(rec))
    return rows


def markdown_table(rows: list[dict], mesh: str = "single") -> str:
    hdr = ("| cell | compute s | memory s | collective s | bottleneck | "
           "MODEL/HLO | roofline frac | temp GB/dev |\n|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        lines.append(
            f'| {r["arch"]} x {r["shape"]} | {r["compute_s"]:.3g} | '
            f'{r["memory_s"]:.3g} | {r["collective_s"]:.3g} | {r["bottleneck"]} | '
            f'{r["useful_ratio"]:.2f} | {r["roofline_frac"]:.3f} | {r["mem_gb"]:.1f} |'
        )
    return "\n".join(lines)


def main():
    rows = load_all()
    print(markdown_table(rows, "single"))
    print()
    print("worst roofline fractions (hillclimb candidates):")
    for r in sorted([r for r in rows if r["mesh"] == "single"],
                    key=lambda r: r["roofline_frac"])[:6]:
        print(f'  {r["cell"]}: frac={r["roofline_frac"]:.4f} bottleneck={r["bottleneck"]}')
    print("most collective-bound:")
    for r in sorted([r for r in rows if r["mesh"] == "single"],
                    key=lambda r: -(r["collective_s"] / max(r["compute_s"], 1e-12)))[:6]:
        print(f'  {r["cell"]}: coll/comp={r["collective_s"]/max(r["compute_s"],1e-12):.1f}')


if __name__ == "__main__":
    main()
