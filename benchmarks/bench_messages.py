"""Paper §3.4.1/§3.4.3, distributed claims: the distributed LeastCostMap is
optimal in >99% of cases with ~100x fewer messages than exhaustive flooding;
RandomNeighbor(k=1) reduces messages dramatically but loses quality.

Event-driven simulator (core/simulator.py) on Waxman topologies; plus the
BSP shard_map engine's async-equivalent message count for comparison.  All
solves go through the unified mapper engine (``repro.core.engine.solve``);
message counts come from the unified ``Stats``.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import SimConfig, pathmap_exact, random_dataflow, solve, waxman


def run(n_instances: int = 25, n: int = 20, p: int = 6, seed0: int = 100,
        sizes=(20, 26)):
    # the reduction factor grows with n (paper: ~100x); n is capped by where
    # exhaustive flooding still terminates under the message budget
    rows = []
    for nn in sizes:
        rows += _run_one(n_instances, nn, p if nn <= 22 else 5, seed0)
    return rows


def _run_one(n_instances, n, p, seed0):
    policies = [
        ("exact", SimConfig(policy="exact", max_messages=3_000_000)),
        ("leastcost", SimConfig(policy="leastcost")),
        ("annealed", SimConfig(policy="annealed")),
        ("random_k1", SimConfig(policy="random_k", k=1)),
        ("random_k2", SimConfig(policy="random_k", k=2)),
        ("random_k3", SimConfig(policy="random_k", k=3)),
    ]
    stats = {name: {"msgs": [], "opt": 0, "found": 0, "t": 0.0} for name, _ in policies}
    bsp_msgs = []
    feas = 0
    for i in range(n_instances):
        rg = waxman(n, seed=seed0 + i)
        df = random_dataflow(rg, p, seed=seed0 + 5_000 + i)
        try:
            ex, _ = pathmap_exact(rg, df, max_states=400_000)
        except MemoryError:
            continue
        if ex is None:
            continue
        feas += 1
        for name, cfg in policies:
            t0 = time.perf_counter()
            try:
                m, st = solve(rg, df, method="simulate", cfg=cfg)
            except MemoryError:
                continue
            stats[name]["t"] += time.perf_counter() - t0
            stats[name]["msgs"].append(st.messages_sent)
            if m is not None:
                stats[name]["found"] += 1
                if abs(m.cost - ex.cost) < 1e-4:
                    stats[name]["opt"] += 1
        _, dst = solve(rg, df, method="shard_map")
        bsp_msgs.append(dst.messages_sent)

    rows = []
    base = np.mean(stats["exact"]["msgs"]) if stats["exact"]["msgs"] else float("nan")
    for name, _ in policies:
        s = stats[name]
        if not s["msgs"]:
            continue
        rows.append({
            "name": f"messages_{name}_n{n}",
            "us_per_call": 1e6 * s["t"] / max(feas, 1),
            "derived": (
                f"msgs_mean={np.mean(s['msgs']):.0f};"
                f"reduction_vs_exact={base/np.mean(s['msgs']):.1f}x;"
                f"optimal_rate={s['opt']/feas:.3f};found_rate={s['found']/feas:.3f}"
            ),
        })
    rows.append({
        "name": f"messages_bsp_shardmap_n{n}",
        "us_per_call": 0.0,
        "derived": (
            f"msgs_mean={np.mean(bsp_msgs):.0f};"
            f"reduction_vs_exact={base/np.mean(bsp_msgs):.1f}x"
        ),
    })
    return rows
