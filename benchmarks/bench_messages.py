"""Paper §3.4.1/§3.4.3, distributed claims: the distributed LeastCostMap is
optimal in >99% of cases with ~100x fewer messages than exhaustive flooding;
RandomNeighbor(k=1) reduces messages dramatically but loses quality.

Event-driven simulator (core/simulator.py) on Waxman topologies; plus the
BSP shard_map engine's async-equivalent message count for comparison.  All
solves go through the unified mapper engine (``repro.core.engine.solve``);
message counts come from the unified ``Stats``.

:func:`run_regional` extends the message story to the *control plane*
(``repro.service.regions``): it sweeps the regional plane over (R, fanout)
on a tenant-skewed overload workload, recording weighted fair-share
deviation, admission quality, per-round coordination messages (gossip +
2PC), gossip staleness, and the **compacted solve size** (mean padded n
per regional DP solve — n_r under the view substrate vs the global n the
masked plane paid) against the centralized PR-3 plane.
:func:`run_multi_hop` adds the multi-hop admission row: a line of regions
where every request spans >= 3 regions, admitted via chained 2PC
(previously dropped outright).  ``python -m benchmarks.bench_messages
--smoke`` writes the sweep + acceptance criteria to
``BENCH_messages.json`` (CI artifact).
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core import (
    DataflowPath, SimConfig, pathmap_exact, random_dataflow, solve, waxman,
)


def run(n_instances: int = 25, n: int = 20, p: int = 6, seed0: int = 100,
        sizes=(20, 26)):
    # the reduction factor grows with n (paper: ~100x); n is capped by where
    # exhaustive flooding still terminates under the message budget
    rows = []
    for nn in sizes:
        rows += _run_one(n_instances, nn, p if nn <= 22 else 5, seed0)
    return rows


def _run_one(n_instances, n, p, seed0):
    policies = [
        ("exact", SimConfig(policy="exact", max_messages=3_000_000)),
        ("leastcost", SimConfig(policy="leastcost")),
        ("annealed", SimConfig(policy="annealed")),
        ("random_k1", SimConfig(policy="random_k", k=1)),
        ("random_k2", SimConfig(policy="random_k", k=2)),
        ("random_k3", SimConfig(policy="random_k", k=3)),
    ]
    stats = {name: {"msgs": [], "opt": 0, "found": 0, "t": 0.0} for name, _ in policies}
    bsp_msgs = []
    feas = 0
    for i in range(n_instances):
        rg = waxman(n, seed=seed0 + i)
        df = random_dataflow(rg, p, seed=seed0 + 5_000 + i)
        try:
            ex, _ = pathmap_exact(rg, df, max_states=400_000)
        except MemoryError:
            continue
        if ex is None:
            continue
        feas += 1
        for name, cfg in policies:
            t0 = time.perf_counter()
            try:
                m, st = solve(rg, df, method="simulate", cfg=cfg)
            except MemoryError:
                continue
            stats[name]["t"] += time.perf_counter() - t0
            stats[name]["msgs"].append(st.messages_sent)
            if m is not None:
                stats[name]["found"] += 1
                if abs(m.cost - ex.cost) < 1e-4:
                    stats[name]["opt"] += 1
        _, dst = solve(rg, df, method="shard_map")
        bsp_msgs.append(dst.messages_sent)

    rows = []
    base = np.mean(stats["exact"]["msgs"]) if stats["exact"]["msgs"] else float("nan")
    for name, _ in policies:
        s = stats[name]
        if not s["msgs"]:
            continue
        rows.append({
            "name": f"messages_{name}_n{n}",
            "us_per_call": 1e6 * s["t"] / max(feas, 1),
            "derived": (
                f"msgs_mean={np.mean(s['msgs']):.0f};"
                f"reduction_vs_exact={base/np.mean(s['msgs']):.1f}x;"
                f"optimal_rate={s['opt']/feas:.3f};found_rate={s['found']/feas:.3f}"
            ),
        })
    rows.append({
        "name": f"messages_bsp_shardmap_n{n}",
        "us_per_call": 0.0,
        "derived": (
            f"msgs_mean={np.mean(bsp_msgs):.0f};"
            f"reduction_vs_exact={base/np.mean(bsp_msgs):.1f}x"
        ),
    })
    return rows


# ---------------------------------------------------------------------------
# regional control plane: coordination messages vs fairness/admission
# ---------------------------------------------------------------------------


def _skewed_workload(rg, assign, n_per_tenant, p, seed):
    """Per-tenant request lists on one fixed partition: ``gold`` (weight 3)
    spreads uniformly over the whole network, ``bronze`` (weight 1) is
    concentrated in region 0 — the case where *local* per-region fairness
    is blind (each region only ever sees part of gold's global holdings)
    and gossiped estimates have to carry the signal."""
    rng = np.random.default_rng(seed)
    region0 = np.nonzero(assign == 0)[0]
    reqs = {"gold": [], "bronze": []}

    def _df(nodes):
        src, dst = rng.choice(nodes, size=2, replace=False)
        creq = rng.uniform(0.05, 0.25, size=p).astype(np.float32)
        creq[0] = creq[-1] = 0.0
        breq = rng.uniform(0.5, 2.0, size=p - 1).astype(np.float32)
        return DataflowPath(creq, breq, int(src), int(dst))

    for _ in range(n_per_tenant):
        reqs["gold"].append(_df(np.arange(rg.n)))
        reqs["bronze"].append(_df(region0))
    return reqs


def _solve_size(cp) -> dict:
    """Mean padded node dimension per DP solve: the regional plane reads
    its compacted substrate report; the centralized plane always solves
    at the global n."""
    if hasattr(cp, "solve_size_report"):
        rep = cp.solve_size_report()
        return {
            "global_n": rep["global_n"],
            "mean_solve_n": rep["mean_solve_n"],
            "max_solve_n": rep["max_solve_n"],
            "balanced_n_r": rep["balanced_n_r"],
        }
    st = cp.placer.stats
    return {
        "global_n": cp.placer.base.n,
        "mean_solve_n": st.mean_solve_n,
        "max_solve_n": cp.placer.base.n if st.solves else 0,
        "balanced_n_r": cp.placer.base.n,
    }


def _drive_plane(cp, reqs, pumps):
    for i in range(max(len(reqs["gold"]), len(reqs["bronze"]))):
        for t in ("gold", "bronze"):
            if i < len(reqs[t]):
                cp.submit(t, reqs[t][i])
    for _ in range(pumps):
        cp.pump()
    cp.check_invariants()
    held = cp.committed_capacity()
    total = sum(held.values()) or 1.0
    frac = {"gold": 0.75, "bronze": 0.25}  # weights 3:1, both saturated
    dev = {
        t: abs(held[t] / total - frac[t]) / frac[t] for t in held
    }
    led = cp.conservation()
    return {
        "committed": {t: float(v) for t, v in held.items()},
        "actual_fractions": {t: float(held[t] / total) for t in held},
        "target_fractions": frac,
        "deviation": {t: float(d) for t, d in dev.items()},
        "max_deviation": float(max(dev.values())),
        "admitted_fraction": led["active"] / max(led["submitted"], 1),
        "ledger": led,
        "solve_size": _solve_size(cp),
    }


def run_multi_hop(
    R: int = 6,
    k: int = 4,
    n_requests: int = 40,
    pumps: int = 8,
    seed: int = 9,
    method: str = "leastcost_python",
):
    """Multi-hop admission on a line of R fully-connected regions.

    Every request pins its endpoints at least two regions apart, so
    nothing is placeable without a spanning chain of >= 3 regions —
    exactly the workload the single-cut broker dropped outright.
    Records the admission fraction, the chain-length distribution proxy
    (max chain, multi-hop count) and the compacted solve sizes.
    """
    from repro.core import region_line
    from repro.service import FairSharePolicy, RegionalControlPlane

    rg, assign = region_line(R, k, seed=seed)
    cp = RegionalControlPlane(
        rg, regions=R, region_of=assign, fanout=2, seed=seed,
        micro_batch=16, policy=FairSharePolicy(slack=0.4), method=method,
    )
    cp.register_tenant("gold", weight=3.0)
    cp.register_tenant("bronze", weight=1.0)
    rng = np.random.default_rng(seed)
    for i in range(n_requests):
        tenant = "gold" if i % 2 == 0 else "bronze"
        r1 = int(rng.integers(0, R - 2))
        r2 = int(rng.integers(r1 + 2, R))  # >= 2 regions apart: chain >= 3
        src = int(rng.choice(np.nonzero(assign == r1)[0]))
        dst = int(rng.choice(np.nonzero(assign == r2)[0]))
        p = int(rng.integers(2, 6))
        creq = rng.uniform(0.02, 0.15, p).astype(np.float32)
        creq[0] = creq[-1] = 0.0
        breq = rng.uniform(0.5, 2.0, p - 1).astype(np.float32)
        cp.submit(tenant, DataflowPath(creq, breq, src, dst))
    for _ in range(pumps):
        cp.pump()
    cp.check_invariants()
    led = cp.conservation()
    return {
        "R": R, "k": k, "n": rg.n, "requests": n_requests, "pumps": pumps,
        "admitted_fraction": led["active"] / max(led["submitted"], 1),
        "ledger": led,
        "spanning": dict(cp.span_stats),
        "twopc_messages": cp.engine_stats().twopc_messages,
        "solve_size": _solve_size(cp),
        "gossip_window": cp.bus.snapshot(reset=True),
    }


def run_multi_hop_hotspot(
    rows: int = 2,
    cols: int = 3,
    k: int = 3,
    n_requests: int = 24,
    pumps: int = 6,
    chain_k: int = 2,
    seed: int = 9,
    method: str = "leastcost_python",
):
    """Gateway-hotspot scenario on a region grid: a standing reservation
    saturates the (0, 1) cut, then every request pins src in region 0 /
    dst in region 2 — the fewest-hop chain 0-1-2 runs through the hot
    cut, but the grid has cold bypass chains around it.

    Three planes serve the identical workload:

    - ``uniform``: chain_k racer on the *cold* grid — the reference
      admission rate with no hotspot;
    - ``hot_single``: chain_k=1 on the hot grid — the legacy broker
      burns every attempt on the one saturated chain (collapse);
    - ``hot_k``: the chain_k racer on the hot grid — must route around
      the hotspot and recover the uniform admission rate, inside the
      single-chain 2PC candidate budget.
    """
    from repro.core import region_grid
    from repro.service import FairSharePolicy, RegionalControlPlane

    def _drive(ck, hot):
        rg, assign = region_grid(rows, cols, k, seed=seed)
        cp = RegionalControlPlane(
            rg, regions=rows * cols, region_of=assign, fanout=2,
            seed=seed, micro_batch=16, chain_k=ck,
            policy=FairSharePolicy(slack=0.4), method=method,
        )
        cp.register_tenant("gold", weight=3.0)
        cp.register_tenant("bronze", weight=1.0)
        if hot:
            (e,) = cp._cut_by_pair[(0, 1)]
            u, v = e
            b = cp.cut_residual[e] - 0.25  # leave less than any breq below
            cp.submit("bronze", DataflowPath.make([0.01, 0.01], [b], u, v))
            cp.pump()
            assert cp.cut_residual[e] < 0.3, "hotspot setup failed"
        base = cp.conservation()["active"]
        rng = np.random.default_rng(seed + 1)
        for i in range(n_requests):
            tenant = "gold" if i % 2 == 0 else "bronze"
            src = int(rng.choice(np.nonzero(assign == 0)[0]))
            dst = int(rng.choice(np.nonzero(assign == 2)[0]))
            p = int(rng.integers(3, 6))
            creq = rng.uniform(0.02, 0.12, p).astype(np.float32)
            creq[0] = creq[-1] = 0.0
            breq = rng.uniform(0.4, 1.0, p - 1).astype(np.float32)
            cp.submit(tenant, DataflowPath(creq, breq, src, dst))
        for _ in range(pumps):
            cp.pump()
        cp.check_invariants()
        led = cp.conservation()
        return {
            "chain_k": ck, "hotspot": hot,
            "admitted_fraction": (led["active"] - base) / n_requests,
            "ledger": led,
            "spanning": dict(cp.span_stats),
            "twopc_messages": cp.engine_stats().twopc_messages,
            "max_cut_attempts": cp.max_cut_attempts,
        }

    uniform = _drive(chain_k, hot=False)
    hot_single = _drive(1, hot=True)
    hot_k = _drive(chain_k, hot=True)
    # racing never widens the probe budget: the per-candidate message
    # bound is the SAME max_cut_attempts quota the single-chain broker
    # had (<= chain_k x that quota by construction, 1x in fact)
    max_chain = max(hot_k["spanning"]["max_chain"], 2)
    budget_ok = hot_k["twopc_messages"] <= (
        hot_k["spanning"]["attempts"] * chain_k
        * hot_k["max_cut_attempts"] * (2 * max_chain + 2)
    )
    return {
        "rows": rows, "cols": cols, "k": k, "chain_k": chain_k,
        "requests": n_requests, "pumps": pumps,
        "uniform": uniform,
        "hot_single_chain": hot_single,
        "hot_k_chain": hot_k,
        "hotspot_admitted_gap": abs(
            hot_k["admitted_fraction"] - uniform["admitted_fraction"]),
        "message_budget_bounded": bool(budget_ok),
    }


def run_regional(
    n: int = 24,
    p: int = 4,
    n_per_tenant: int = 60,
    pumps: int = 10,
    sweep=((1, 2), (2, 2), (4, 0), (4, 1), (4, 2)),
    R_max: int = 4,
    seed: int = 7,
    method: str = "leastcost_python",
    out_path: str | None = "BENCH_messages.json",
):
    """Regional-plane sweep over (R, fanout) vs the centralized plane.

    Both planes serve the identical tenant-skewed overload workload
    (weights 3:1).  Recorded per point: weighted fair-share deviation of
    the standing allocation, admitted fraction, coordination messages per
    pump round (gossip exactly ``R * fanout`` + bounded 2PC) and gossip
    staleness.  Criteria (the PR acceptance gates):

    - at R=4 with the default fanout the weighted fair-share deviation
      stays within 15 percentage-of-target points of the centralized
      plane's;
    - per-round gossip messages are exactly ``R * fanout`` — O(R*fanout),
      not O(n^2);
    - every regional solve runs over the compacted substrate: mean/max
      padded solve dimension <= ceil(n/R) + slack, never the global n;
    - dataflows spanning >= 3 regions are admitted via multi-hop 2PC
      (``run_multi_hop``; admission rate > 0 where the single-cut broker
      dropped them);
    - R=1 bit-identity with the centralized plane is enforced separately
      in ``tests/test_regions.py`` (noted here for the record).
    """
    from repro.service import (
        ControlPlane, FairSharePolicy, RegionalControlPlane,
        partition_regions,
    )

    rg = waxman(n, seed=seed)
    assign = partition_regions(rg, R_max, seed=seed)
    reqs = _skewed_workload(rg, assign, n_per_tenant, p, seed)
    kw = dict(policy=FairSharePolicy(slack=0.4), micro_batch=16,
              method=method)

    def _fresh(regions=None, fanout=None):
        if regions is None:
            return ControlPlane(rg, **kw)
        # regional machinery even at R=1 (the facade would degrade it to
        # the centralized plane — here the degenerate case is the point)
        return RegionalControlPlane(rg, regions=regions, fanout=fanout,
                                    seed=seed, **kw)

    def _register(cp):
        cp.register_tenant("gold", weight=3.0)
        cp.register_tenant("bronze", weight=1.0)
        return cp

    central = _drive_plane(_register(_fresh()), reqs, pumps)
    points = []
    for (R, fanout) in sweep:
        cp = _register(_fresh(R, fanout))
        rec = _drive_plane(cp, reqs, pumps)
        rec.update({
            "R": R, "fanout": fanout,
            "coordination": cp.coordination_report(),
            "gossip_messages_per_round": (
                cp.bus.messages_sent / max(cp.bus.rounds, 1)
            ),
            # windowed counters: this point's gossip volume only, however
            # the plane is driven afterwards (closes the window, never
            # rewinds the lifetime counters the gates above read)
            "gossip_window": cp.bus.snapshot(reset=True),
            # unified telemetry snapshot (per-region registries merged
            # under plane=r{r} labels + broker gossip/2PC/span counters)
            "telemetry": cp.metrics_registry().snapshot(),
        })
        points.append(rec)

    # the fairness gate grades the most decentralized point with the most
    # gossip: largest R, then largest fanout, in whatever sweep ran
    gate = max(points, key=lambda x: (x["R"], x["fanout"]))
    # solve-size gate: the compacted substrate must keep every regional
    # solve at n_r <= ceil(n/R) + slack, never the global n
    slack = 2
    size_ok = all(
        x["solve_size"]["mean_solve_n"]
        <= x["solve_size"]["balanced_n_r"] + slack
        and x["solve_size"]["max_solve_n"]
        <= x["solve_size"]["balanced_n_r"] + slack
        for x in points if x["R"] > 1
    )
    multi_hop = run_multi_hop(method=method)
    hotspot = run_multi_hop_hotspot(method=method)
    record = {
        "n": n, "p": p, "n_per_tenant": n_per_tenant, "pumps": pumps,
        "seed": seed, "method": method, "weights": {"gold": 3.0, "bronze": 1.0},
        "centralized": central,
        "sweep": points,
        "multi_hop": multi_hop,
        "multi_hop_hotspot": hotspot,
        "criterion": {
            "gate_point": {"R": gate["R"], "fanout": gate["fanout"]},
            "r4_fairness_within_15pct_of_centralized": bool(
                gate["max_deviation"] <= central["max_deviation"] + 0.15
            ),
            "r4_centralized_deviation": central["max_deviation"],
            "r4_regional_deviation": gate["max_deviation"],
            "gossip_messages_O_R_fanout": all(
                x["coordination"]["gossip_messages"]
                == pumps * x["R"] * min(x["fanout"], x["R"] - 1)
                for x in points
            ),
            # payload accounting: every gossip message carries at most R
            # records (a region pushes its whole view, never more), so the
            # per-round record volume is O(R * fanout) records — bandwidth
            # scales with the region count, not with node count or time
            "gossip_payload_O_R_fanout_records": all(
                x["coordination"]["gossip"]["records_per_message"] <= x["R"]
                and x["coordination"]["gossip"]["records_per_round"]
                <= x["R"] * min(x["fanout"], x["R"] - 1) * x["R"]
                for x in points if x["R"] > 1
            ),
            "compacted_solve_n_le_balanced": bool(size_ok),
            "solve_n_slack": slack,
            "solve_size_reduction_at_gate": (
                float(n) / max(gate["solve_size"]["mean_solve_n"], 1e-9)
            ),
            "multi_hop_admitted": bool(
                multi_hop["admitted_fraction"] > 0
                and multi_hop["spanning"]["max_chain"] >= 3
            ),
            "multi_hop_admitted_fraction": multi_hop["admitted_fraction"],
            # gateway-hotspot gates: the k-chain racer recovers the
            # uniform-load admission rate (within 0.1) where the legacy
            # single-chain broker collapses, without widening the 2PC
            # candidate budget past k x the single-chain quota
            "multi_hop_hotspot_admitted": bool(
                hotspot["hotspot_admitted_gap"] <= 0.1
                and hotspot["hot_single_chain"]["admitted_fraction"]
                <= hotspot["uniform"]["admitted_fraction"] - 0.3
                and hotspot["hot_k_chain"]["spanning"]["rerouted"] >= 1
            ),
            "hotspot_uniform_fraction": (
                hotspot["uniform"]["admitted_fraction"]),
            "hotspot_single_chain_fraction": (
                hotspot["hot_single_chain"]["admitted_fraction"]),
            "hotspot_k_chain_fraction": (
                hotspot["hot_k_chain"]["admitted_fraction"]),
            "hotspot_message_budget_bounded": (
                hotspot["message_budget_bounded"]),
            "r1_bit_identity": "enforced in tests/test_regions.py",
            "k1_bit_identity": "enforced in tests/test_regions.py",
        },
    }
    if out_path is not None:
        with open(out_path, "w") as f:
            json.dump(record, f, indent=2)
    return record


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="regional sweep only, CI sizes; writes "
                         "BENCH_messages.json")
    args = ap.parse_args()
    if args.smoke:
        rec = run_regional()
    else:
        for row in run():
            print(f"{row['name']},{row['us_per_call']:.1f},\"{row['derived']}\"")
        rec = run_regional()
    print(json.dumps(
        {"regional": {k: rec[k] for k in ("centralized", "criterion")},
         "multi_hop": rec["multi_hop"],
         "multi_hop_hotspot": {
             k: rec["multi_hop_hotspot"][k]
             for k in ("hotspot_admitted_gap", "message_budget_bounded")
         },
         "sweep": [
             {"solve_n": x["solve_size"]["mean_solve_n"],
              **{k: x[k] for k in ("R", "fanout", "max_deviation",
                                   "admitted_fraction",
                                   "gossip_messages_per_round")}}
             for x in rec["sweep"]
         ]}, indent=2))
