"""Deterministic synthetic data pipeline with packing and prefetch.

Serves two purposes: (1) CPU-runnable end-to-end training examples with a
*learnable* distribution (affine-recurrence token streams: t_{i+1} =
(a * t_i + c) mod V within documents, so next-token loss can fall well below
the uniform entropy); (2) the input-spec contract for the dry-run (shape and
dtype identical to the real batches).

Per-host sharding: each process materializes only its slice of the global
batch (``host_slice``); a background thread prefetches ``prefetch`` batches.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np


class SyntheticLM:
    """Packed affine-recurrence documents -> {tokens, labels, loss_mask}."""

    def __init__(self, vocab: int, seq_len: int, global_batch: int, *,
                 seed: int = 0, doc_len_range=(64, 512),
                 process_index: int = 0, process_count: int = 1):
        assert global_batch % process_count == 0
        self.vocab, self.seq = vocab, seq_len
        self.local_batch = global_batch // process_count
        self.rng = np.random.default_rng(seed + 1013 * process_index)
        self.doc_len_range = doc_len_range

    def _doc(self, length: int) -> np.ndarray:
        a = int(self.rng.integers(1, 64)) * 2 + 1  # odd multiplier
        c = int(self.rng.integers(0, self.vocab))
        t = np.empty(length, np.int64)
        t[0] = self.rng.integers(0, self.vocab)
        for i in range(1, length):
            t[i] = (a * t[i - 1] + c) % self.vocab
        return t

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()

    def next_batch(self) -> dict:
        B, S = self.local_batch, self.seq
        toks = np.zeros((B, S + 1), np.int64)
        mask = np.ones((B, S), np.float32)
        for b in range(B):
            pos = 0
            while pos < S + 1:
                L = int(self.rng.integers(*self.doc_len_range))
                d = self._doc(min(L, S + 1 - pos))
                toks[b, pos : pos + len(d)] = d
                if pos > 0:
                    mask[b, pos - 1] = 0.0  # no loss across document boundary
                pos += len(d)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
            "loss_mask": mask,
        }


class Prefetcher:
    """Background-thread prefetch wrapper around any batch iterator."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._err: Optional[BaseException] = None
        self._t = threading.Thread(target=self._run, daemon=True)
        self._t.start()

    def _run(self):
        try:
            for item in self._it:
                self._q.put(item)
        except BaseException as e:  # surfaced on next()
            self._err = e
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise self._err or StopIteration
        return item
