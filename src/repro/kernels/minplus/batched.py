"""Batched fused Pallas TPU kernel: one full DP superstep for a request grid.

``core.leastcost.leastcost_jax_batched`` serves the online placer: B mapping
requests relax against ONE shared resource network.  The vmapped-jnp path
re-streams the shared ``lat``/``bw`` matrices from HBM once per request and
materializes per-request candidate slabs; this kernel instead runs the whole
superstep

    place:  P[b,v,k]  = min_{j<=k, prefix[b,k]-prefix[b,j] <= cap[v]} C[b,v,j]
    move:   C'[b,w,k] = min_{v, bw[v,w] >= breq_k[b,k]}  P[b,v,k] + lat[v,w]
    update: Cn = where(C' < C - EPS_IMPROVE, C', C)   (+ parent pointers)

as ONE ``pallas_call`` with grid ``(batch, w_blocks, k_blocks, v_blocks)``.
The network tiles (``lat``/``bw``/``cap``) use index maps that IGNORE the
batch coordinate, so they are the same VMEM-resident tiles for every request
(the pipeline skips the re-fetch whenever consecutive grid steps map to the
same block); per-request operands (``prefix``/``breq_k``/state) are
batch-indexed.  The intermediate P tensor and the (V, W, K) move candidates
never touch HBM: the move reduction is unrolled per k column as fused
mask/shift/min VPU ops on (V, W) tiles.

HBM-traffic model per superstep (fp32 words, K_pad = padded p_max+1):
  vmapped jnp : O(B * n^2 * K)     (per-request (w, v) slabs for every k,
                                    link matrices broadcast per request)
  this kernel : O((B / b_tile) * ceil(K_pad / k_tile) * n^2  +  B * n * K_pad)
                -> O(n^2 + B * n * K) when one (b, k) block covers the batch
                   and prefix columns (the common online-placer shape).

A ``b_tile``-row batch block amortizes each shared network tile over
``b_tile`` requests (unrolled in-kernel, so VMEM live-set stays at one
request's working set).  Min-plus has no MXU path; everything runs on the
VPU with (8, 128)-aligned tiles.

``batched_superstep_ref`` is the fused pure-jnp oracle used off-TPU and as
the CI cross-check: it mirrors ``core.leastcost._superstep``'s exact update
semantics (same tie-breaking, same EPS thresholds) bit-for-bit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.problem import BIG, EPS_CAP_F32, EPS_IMPROVE

try:  # TPU compiler params (ignored in interpret mode)
    from jax.experimental.pallas import tpu as pltpu

    _COMPILER_PARAMS = pltpu.CompilerParams(
        dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
    )
except Exception:  # pragma: no cover
    _COMPILER_PARAMS = None

# Defaults: largest (8,128)-aligned network tiles that keep the double-
# buffered live set well inside 16 MB VMEM (see benchmarks/bench_kernel.py
# sweep; VMEM model below).  b_tile=8 amortizes each lat/bw tile fetch over
# 8 requests at ~zero extra VMEM (the batch loop is unrolled).
B_TILE = 8
V_TILE = 128
W_TILE = 128
K_TILE = 8

DEFAULT_TILES = (B_TILE, V_TILE, W_TILE, K_TILE)


def vmem_model_bytes(b_tile: int, v_tile: int, w_tile: int, k_tile: int,
                     k_pad: int) -> int:
    """fp32 VMEM live-set of one grid step (inputs + outputs + the largest
    in-kernel intermediate, which is one request's place candidate block)."""
    inputs = (b_tile * k_pad              # prefix (full row)
              + b_tile * k_tile           # pre_out (this block's k columns)
              + b_tile * k_tile           # breq_k
              + v_tile                    # cap
              + 2 * v_tile * w_tile       # lat, bw
              + b_tile * v_tile * k_pad   # C slab (place input)
              + 3 * b_tile * w_tile * k_tile)  # prev C / par_v / par_j
    outputs = 3 * b_tile * w_tile * k_tile
    scratch = v_tile * k_tile * k_pad + v_tile * w_tile  # place cand + move tile
    return 4 * (inputs + outputs + scratch)


def _superstep_kernel(prefix_ref, pre_out_ref, breq_ref, cap_ref, lat_ref,
                      bw_ref, c_slab_ref, c_prev_ref, pv_prev_ref, pj_prev_ref,
                      c_ref, pv_ref, pj_ref):
    k_blk = pl.program_id(2)
    v_blk = pl.program_id(3)
    nv = pl.num_programs(3)

    @pl.when(v_blk == 0)
    def _init():
        c_ref[...] = jnp.full_like(c_ref, BIG)
        pv_ref[...] = jnp.zeros_like(pv_ref)
        pj_ref[...] = jnp.zeros_like(pj_ref)

    lat = lat_ref[...]  # (V, W) — shared across the batch dimension
    bw = bw_ref[...]  # (V, W)
    cap = cap_ref[...]  # (V, 1)
    BT, KT = pre_out_ref.shape
    KP = prefix_ref.shape[1]
    V, W = lat.shape

    j_idx = jax.lax.broadcasted_iota(jnp.int32, (KT, KP), 1)
    k_idx = k_blk * KT + jax.lax.broadcasted_iota(jnp.int32, (KT, KP), 0)
    v_iota = jax.lax.broadcasted_iota(jnp.int32, (V, W), 0)

    for bi in range(BT):  # unrolled: shared tiles amortized over b_tile reqs
        C = c_slab_ref[bi]  # (V, KP)
        prefix = prefix_ref[bi, :]  # (KP,)
        pre_out = pre_out_ref[bi, :]  # (KT,) = prefix at this block's k cols
        breq = breq_ref[bi, :]  # (KT,)

        # -- fused place: P[v, kt] = min_{j<=k, window<=cap} C[v, j]
        window = pre_out[:, None] - prefix[None, :]  # (KT, KP)
        feas = (j_idx <= k_idx)[None, :, :] & (
            window[None, :, :] <= cap[:, 0][:, None, None] + EPS_CAP_F32
        )  # (V, KT, KP)
        candp = jnp.where(feas, C[:, None, :], BIG)
        P = jnp.min(candp, axis=2)  # (V, KT)
        # tie-break: LARGEST feasible j achieving the min (matches the
        # descending-j strict-improvement scan of core.leastcost._place_step)
        pj_place = jnp.max(
            jnp.where(candp == P[:, :, None], j_idx[None, :, :], -1), axis=2
        ).astype(jnp.int32)

        # -- fused move, one k column at a time: no (V, W, KT) candidate
        best_cols, argv_cols, pj_cols = [], [], []
        for t in range(KT):
            cand = jnp.where(bw >= breq[t], P[:, t][:, None] + lat, BIG)
            cand = jnp.minimum(cand, BIG)  # BIG + lat must stay min-plus BIG
            best_cols.append(jnp.min(cand, axis=0))  # (W,)
            arg = jnp.argmin(cand, axis=0).astype(jnp.int32)  # first-v ties
            argv_cols.append(arg + v_blk * V)
            # place-argmin at the winning v, one-hot (no dynamic gather)
            pj_cols.append(jnp.max(
                jnp.where(v_iota == arg[None, :], pj_place[:, t][:, None], -1),
                axis=0,
            ))
        best = jnp.stack(best_cols, axis=1)  # (W, KT)
        argv = jnp.stack(argv_cols, axis=1)
        pjw = jnp.stack(pj_cols, axis=1)

        prev = c_ref[bi]
        take = best < prev  # strict: earlier v-tile wins ties (argmin rule)
        c_ref[bi] = jnp.where(take, best, prev)
        pv_ref[bi] = jnp.where(take, argv, pv_ref[bi])
        pj_ref[bi] = jnp.where(take, pjw, pj_ref[bi])

    @pl.when(v_blk == nv - 1)
    def _final():  # monotone EPS_IMPROVE update vs the previous superstep
        cmv = c_ref[...]
        cprev = c_prev_ref[...]
        upd = cmv < cprev - EPS_IMPROVE
        c_ref[...] = jnp.where(upd, cmv, cprev)
        pv_ref[...] = jnp.where(upd, pv_ref[...], pv_prev_ref[...])
        pj_ref[...] = jnp.where(upd, pj_ref[...], pj_prev_ref[...])


def pad_batched_problem(lat, bw, cap, prefix, breq_k, *, tiles=None):
    """Pad the shared network and per-request operands to tile multiples.

    Padded resource rows get BIG latency / zero bandwidth / -1 capacity (never
    feasible); padded k columns and batch rows get BIG prefix/breq (fully
    masked in both the place window and the move).  Returns a dict of padded
    arrays; the padded state must be built by the caller with BIG / -1 fill.
    """
    b_tile, v_tile, w_tile, k_tile = tiles or DEFAULT_TILES
    B, K = prefix.shape
    n = lat.shape[0]
    nt = max(v_tile, w_tile)
    assert nt % v_tile == 0 and nt % w_tile == 0, (v_tile, w_tile)
    Bp = -(-B // b_tile) * b_tile
    n_pad = -(-n // nt) * nt
    K_pad = -(-K // k_tile) * k_tile
    return dict(
        lat=jnp.full((n_pad, n_pad), BIG, jnp.float32).at[:n, :n].set(lat),
        bw=jnp.zeros((n_pad, n_pad), jnp.float32).at[:n, :n].set(bw),
        cap=jnp.full((n_pad, 1), -1.0, jnp.float32).at[:n, 0].set(cap),
        prefix=jnp.full((Bp, K_pad), BIG, jnp.float32).at[:B, :K].set(prefix),
        breq_k=jnp.full((Bp, K_pad), BIG, jnp.float32).at[:B, :K].set(breq_k),
    )


@functools.partial(jax.jit, static_argnames=("tiles", "interpret"))
def batched_superstep_pallas(C, par_v, par_j, lat, bw, cap, prefix, breq_k, *,
                             tiles=None, interpret: bool = False):
    """One fused superstep on PRE-PADDED operands (see pad_batched_problem).

    Shapes: C/par_v/par_j (Bp, n_pad, K_pad); lat/bw (n_pad, n_pad);
    cap (n_pad, 1); prefix/breq_k (Bp, K_pad).  Returns (Cn, par_vn, par_jn).
    """
    b_tile, v_tile, w_tile, k_tile = tiles or DEFAULT_TILES
    Bp, n_pad, K_pad = C.shape
    assert Bp % b_tile == 0 and n_pad % v_tile == 0, (C.shape, tiles)
    assert n_pad % w_tile == 0 and K_pad % k_tile == 0, (C.shape, tiles)

    grid = (Bp // b_tile, n_pad // w_tile, K_pad // k_tile, n_pad // v_tile)
    out = pl.pallas_call(
        _superstep_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b_tile, K_pad), lambda b, w, k, v: (b, 0)),  # prefix
            pl.BlockSpec((b_tile, k_tile), lambda b, w, k, v: (b, k)),  # pre_out
            pl.BlockSpec((b_tile, k_tile), lambda b, w, k, v: (b, k)),  # breq_k
            pl.BlockSpec((v_tile, 1), lambda b, w, k, v: (v, 0)),  # cap (shared)
            pl.BlockSpec((v_tile, w_tile), lambda b, w, k, v: (v, w)),  # lat
            pl.BlockSpec((v_tile, w_tile), lambda b, w, k, v: (v, w)),  # bw
            pl.BlockSpec((b_tile, v_tile, K_pad), lambda b, w, k, v: (b, v, 0)),
            pl.BlockSpec((b_tile, w_tile, k_tile), lambda b, w, k, v: (b, w, k)),
            pl.BlockSpec((b_tile, w_tile, k_tile), lambda b, w, k, v: (b, w, k)),
            pl.BlockSpec((b_tile, w_tile, k_tile), lambda b, w, k, v: (b, w, k)),
        ],
        out_specs=[
            pl.BlockSpec((b_tile, w_tile, k_tile), lambda b, w, k, v: (b, w, k)),
            pl.BlockSpec((b_tile, w_tile, k_tile), lambda b, w, k, v: (b, w, k)),
            pl.BlockSpec((b_tile, w_tile, k_tile), lambda b, w, k, v: (b, w, k)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, n_pad, K_pad), jnp.float32),
            jax.ShapeDtypeStruct((Bp, n_pad, K_pad), jnp.int32),
            jax.ShapeDtypeStruct((Bp, n_pad, K_pad), jnp.int32),
        ],
        compiler_params=_COMPILER_PARAMS,
        interpret=interpret,
    )(prefix, prefix, breq_k, cap, lat, bw, C, C, par_v, par_j)
    return tuple(out)


# ---------------------------------------------------------------------------
# Fused pure-jnp oracle (off-TPU fast path + CI cross-check)
# ---------------------------------------------------------------------------


def _place_batched_ref(C, cap, prefix):
    """Batched mirror of ``core.leastcost._place_step`` (same op sequence per
    request, so results are bit-identical).  C (B, n, K), prefix (B, K)."""
    B, n, K = C.shape
    P = jnp.full_like(C, BIG)
    pj = jnp.zeros(C.shape, jnp.int32)
    k_idx = jnp.arange(K)
    for x in range(K):
        j_idx = k_idx - x
        valid_j = j_idx >= 0
        shifted = jnp.where(valid_j[None, None, :], jnp.roll(C, x, axis=2), BIG)
        block = prefix - jnp.take(prefix, jnp.maximum(j_idx, 0), axis=1)
        feas = valid_j[None, None, :] & (
            block[:, None, :] <= cap[None, :, None] + EPS_CAP_F32
        )
        cand = jnp.where(feas, shifted, BIG)
        upd = cand < P
        P = jnp.where(upd, cand, P)
        pj = jnp.where(upd, jnp.maximum(j_idx, 0)[None, None, :], pj)
    return P, pj


def _move_batched_ref(P, lat, bw, breq_k):
    """Batched mirror of ``core.leastcost._move_step_ref``: the shared link
    matrices are transposed ONCE and broadcast over the batch — not stacked
    per request as under vmap.  P (B, n, K), breq_k (B, K)."""
    latT = lat.T  # (w, v): reduction over the contiguous axis
    bwT = bw.T

    def one_k(args):
        bk, Pk = args  # (B,), (B, V)
        cand = jnp.where(
            bwT[None, :, :] >= bk[:, None, None],
            latT[None, :, :] + Pk[:, None, :],
            BIG,
        )  # (B, W, V)
        return jnp.min(cand, axis=2), jnp.argmin(cand, axis=2).astype(jnp.int32)

    Cmv_t, pv_t = jax.lax.map(one_k, (breq_k.T, P.transpose(2, 0, 1)))
    return Cmv_t.transpose(1, 2, 0), pv_t.transpose(1, 2, 0)


def batched_superstep_ref(C, par_v, par_j, lat, bw, cap, prefix, breq_k):
    """Fused batched superstep, pure jnp, UNPADDED shapes.  Bit-for-bit equal
    to one ``core.leastcost._superstep`` per request (same tie rules, same
    EPS_IMPROVE threshold); the kernel is cross-checked against this."""
    P, pj = _place_batched_ref(C, cap, prefix)
    Cmv, pv = _move_batched_ref(P, lat, bw, breq_k)
    upd = Cmv < C - EPS_IMPROVE
    pj_of_pv = jnp.take_along_axis(pj, pv, axis=1)
    Cn = jnp.where(upd, Cmv, C)
    par_vn = jnp.where(upd, pv, par_v)
    par_jn = jnp.where(upd, pj_of_pv, par_j)
    return Cn, par_vn, par_jn
