"""Public op: bandwidth-masked min-plus relaxation (kernel or oracle).

``masked_minplus(P, lat, bw, breq)`` — signature matches the DP's move step
(``breq`` is the raw (p-1,) dataflow-edge requirement vector; the k-indexed
threshold vector is built here).  Dispatches to the Pallas TPU kernel
(interpret mode off-TPU) or the pure-jnp oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import minplus as _kernel
from . import ref as _ref

BIG = _ref.BIG


def _breq_k(breq, K):
    return jnp.concatenate(
        [jnp.full((1,), BIG), breq.astype(jnp.float32),
         jnp.full((K - 1 - breq.shape[0],), BIG)]
    )


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def masked_minplus(P, lat, bw, breq, *, tiles: tuple[int, int, int] | None = None):
    """Move step: returns (C' (n,K) float32, pv (n,K) int32)."""
    K = P.shape[1]
    bq = _breq_k(breq, K)
    kw = {}
    if tiles is not None:
        kw = dict(v_tile=tiles[0], w_tile=tiles[1], k_tile=tiles[2])
    return _kernel.masked_minplus_pallas(
        P, lat, bw, bq, interpret=not _on_tpu(), **kw
    )


def masked_minplus_ref(P, lat, bw, breq):
    K = P.shape[1]
    return _ref.masked_minplus_ref(P, lat, bw, _breq_k(breq, K))
