from . import batched, ops, ref  # noqa: F401
from .batched import (  # noqa: F401
    batched_superstep_pallas,
    batched_superstep_ref,
    pad_batched_problem,
)
from .minplus import masked_minplus_pallas  # noqa: F401
from .ops import masked_minplus, masked_minplus_ref  # noqa: F401
