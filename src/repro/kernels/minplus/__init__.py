from . import ops, ref  # noqa: F401
from .minplus import masked_minplus_pallas  # noqa: F401
from .ops import masked_minplus, masked_minplus_ref  # noqa: F401
