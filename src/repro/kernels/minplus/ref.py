"""Pure-jnp oracle for the bandwidth-masked min-plus relaxation (move step).

    C[w, k]  = min_v  P[v, k] + lat[v, w]   s.t.  bw[v, w] >= breq_k[k]
    pv[w, k] = argmin_v (first minimal v, ties broken towards smaller v)

Shapes: P (n, K), lat (n, n), bw (n, n), breq_k (K,).  Infeasible entries
hold BIG (finite +inf stand-in; min-plus absorbing).  This is the inner loop
of the tensorized LeastCostMap DP (paper §3.4.1) — one relaxation of every
resource edge for every prefix length at once.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.problem import BIG


def masked_minplus_ref(P, lat, bw, breq_k):
    """O(n^2) live memory (k-looped) reference."""

    def one_k(args):
        bk, Pk = args
        cand = jnp.where(bw >= bk, Pk[:, None] + lat, BIG)  # [v, w]
        cand = jnp.minimum(cand, BIG)
        return jnp.min(cand, axis=0), jnp.argmin(cand, axis=0).astype(jnp.int32)

    C_t, pv_t = jax.lax.map(one_k, (breq_k, P.T))
    return C_t.T, pv_t.T
