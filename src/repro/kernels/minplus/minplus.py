"""Pallas TPU kernel: bandwidth-masked tropical (min,+) matmul with argmin.

The move step of the tensorized BCPM relaxation (see ``core/leastcost.py``):

    C[w, k]  = min_v  P[v, k] + lat[v, w]   s.t.  bw[v, w] >= breq_k[k]
    pv[w, k] = argmin_v

Mapping the paper's per-message set relaxation to the TPU memory hierarchy
(DESIGN.md §5): the naive masked formulation materializes an (n, n, K)
candidate tensor in HBM; this kernel tiles the (w, k) output into VMEM
blocks and streams (v,) reduction tiles through VMEM, fusing the bandwidth
mask and latency shift into the reduction — HBM traffic O(n^2 + nK) instead
of O(n^2 K).  Min-plus has no MXU path, so the reduction runs on the VPU;
all tile dims are multiples of the (8, 128) vreg shape.

Grid: (w_blocks, k_blocks, v_blocks) with v innermost so each (w, k) output
block stays resident in VMEM across its reduction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.problem import BIG

try:  # TPU compiler params (ignored in interpret mode)
    from jax.experimental.pallas import tpu as pltpu

    _COMPILER_PARAMS = pltpu.CompilerParams(
        dimension_semantics=("parallel", "parallel", "arbitrary")
    )
except Exception:  # pragma: no cover
    _COMPILER_PARAMS = None

# Default tile sizes (hillclimbed in EXPERIMENTS.md §Perf; see ops.py).
V_TILE = 128  # reduction tile (v)
W_TILE = 128  # output rows per block (w)
K_TILE = 8  # output cols per block (k) — cand tensor is (V, W, K_TILE)


def _kernel(breq_ref, p_ref, lat_ref, bw_ref, c_ref, pv_ref):
    v_blk = pl.program_id(2)
    # Initialize output block on the first reduction step.
    @pl.when(v_blk == 0)
    def _init():
        c_ref[...] = jnp.full_like(c_ref, BIG)
        pv_ref[...] = jnp.zeros_like(pv_ref)

    p = p_ref[...]  # (V, K)
    lat = lat_ref[...]  # (V, W)
    bw = bw_ref[...]  # (V, W)
    breq = breq_ref[0, :]  # (K,)

    # cand[v, w, k] = P[v, k] + lat[v, w]  where bw[v, w] >= breq[k]
    feas = bw[:, :, None] >= breq[None, None, :]  # (V, W, K)
    cand = jnp.where(feas, p[:, None, :] + lat[:, :, None], BIG)
    cand = jnp.minimum(cand, BIG)  # keep BIG + lat from overflowing to inf
    best = jnp.min(cand, axis=0)  # (W, K)
    arg = jnp.argmin(cand, axis=0).astype(jnp.int32) + v_blk * cand.shape[0]

    prev = c_ref[...]
    take = best < prev  # strict: earlier v-tile wins ties (matches argmin)
    c_ref[...] = jnp.where(take, best, prev)
    pv_ref[...] = jnp.where(take, arg, pv_ref[...])


@functools.partial(
    jax.jit,
    static_argnames=("v_tile", "w_tile", "k_tile", "interpret"),
)
def masked_minplus_pallas(
    P,
    lat,
    bw,
    breq_k,
    *,
    v_tile: int = V_TILE,
    w_tile: int = W_TILE,
    k_tile: int = K_TILE,
    interpret: bool = False,
):
    """Padded, tiled pallas_call wrapper.  Shapes: P (n, K), lat/bw (n, n),
    breq_k (K,).  Returns (C (n, K) float32, pv (n, K) int32)."""
    n, K = P.shape
    n_pad = -(-n // max(v_tile, w_tile)) * max(v_tile, w_tile)
    K_pad = -(-K // k_tile) * k_tile

    Pp = jnp.full((n_pad, K_pad), BIG, jnp.float32).at[:n, :K].set(P)
    latp = jnp.full((n_pad, n_pad), BIG, jnp.float32).at[:n, :n].set(lat)
    bwp = jnp.zeros((n_pad, n_pad), jnp.float32).at[:n, :n].set(bw)
    # padded k columns get BIG requirement -> fully masked
    bq = jnp.full((1, K_pad), BIG, jnp.float32).at[0, :K].set(breq_k)

    grid = (n_pad // w_tile, K_pad // k_tile, n_pad // v_tile)
    C, pv = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, k_tile), lambda w, k, v: (0, k)),  # breq
            pl.BlockSpec((v_tile, k_tile), lambda w, k, v: (v, k)),  # P
            pl.BlockSpec((v_tile, w_tile), lambda w, k, v: (v, w)),  # lat
            pl.BlockSpec((v_tile, w_tile), lambda w, k, v: (v, w)),  # bw
        ],
        out_specs=[
            pl.BlockSpec((w_tile, k_tile), lambda w, k, v: (w, k)),  # C
            pl.BlockSpec((w_tile, k_tile), lambda w, k, v: (w, k)),  # pv
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, K_pad), jnp.float32),
            jax.ShapeDtypeStruct((n_pad, K_pad), jnp.int32),
        ],
        compiler_params=_COMPILER_PARAMS,
        interpret=interpret,
    )(bq, Pp, latp, bwp)
    return C[:n, :K], pv[:n, :K]
