from . import minplus, place  # noqa: F401
