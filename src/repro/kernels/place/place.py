"""Pallas TPU kernel: capacity-window minimum (the BCPM place step).

    P[v, k]  = min_{j <= k, prefix[k] - prefix[j] <= cap[v]}  C[v, j]

Tiling mirrors kernels/minplus: (v, k) output blocks in VMEM; the j
reduction is materialized as a (V, K_OUT, K) candidate block (K = padded
prefix length, small) and min-reduced on the VPU.  Feasibility is computed
in-kernel from the prefix sums and per-row capacities — no (n, K, K) mask
ever touches HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.problem import BIG, EPS_CAP_F32

V_TILE = 128
K_OUT_TILE = 8

try:
    from jax.experimental.pallas import tpu as pltpu

    _COMPILER_PARAMS = pltpu.CompilerParams(
        dimension_semantics=("parallel", "parallel")
    )
except Exception:  # pragma: no cover
    _COMPILER_PARAMS = None


def _kernel(prefix_ref, prefix_out_ref, c_ref, cap_ref, p_ref, pj_ref):
    k_blk = pl.program_id(1)
    C = c_ref[...]  # (V, K)
    cap = cap_ref[...]  # (V, 1)
    prefix = prefix_ref[0, :]  # (K,)
    prefix_out = prefix_out_ref[0, :]  # (K_OUT,) = prefix[k] for this block

    K = C.shape[1]
    KO = prefix_out.shape[0]
    j_idx = jax.lax.broadcasted_iota(jnp.int32, (1, KO, K), 2)
    k_idx = k_blk * KO + jax.lax.broadcasted_iota(jnp.int32, (1, KO, K), 1)
    block = prefix_out[None, :, None] - prefix[None, None, :]  # (1, KO, K)
    feas = (j_idx <= k_idx) & (block <= cap[:, :, None] + EPS_CAP_F32)  # (V, KO, K)
    cand = jnp.where(feas, C[:, None, :], BIG)
    p_ref[...] = jnp.min(cand, axis=2)
    pj_ref[...] = jnp.argmin(cand, axis=2).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("v_tile", "k_out_tile", "interpret"))
def place_window_pallas(C, cap, prefix, *, v_tile: int = V_TILE,
                        k_out_tile: int = K_OUT_TILE, interpret: bool = False):
    n, K = C.shape
    n_pad = -(-n // v_tile) * v_tile
    K_pad = -(-K // k_out_tile) * k_out_tile

    Cp = jnp.full((n_pad, K_pad), BIG, jnp.float32).at[:n, :K].set(C)
    capp = jnp.full((n_pad, 1), -1.0, jnp.float32).at[:n, 0].set(cap)
    # padded prefix entries get +inf so padded k columns are infeasible
    pre = jnp.full((1, K_pad), BIG, jnp.float32).at[0, :K].set(prefix)

    grid = (n_pad // v_tile, K_pad // k_out_tile)
    P, pj = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, K_pad), lambda v, k: (0, 0)),  # full prefix
            pl.BlockSpec((1, k_out_tile), lambda v, k: (0, k)),  # prefix[k]
            pl.BlockSpec((v_tile, K_pad), lambda v, k: (v, 0)),  # C rows
            pl.BlockSpec((v_tile, 1), lambda v, k: (v, 0)),  # cap
        ],
        out_specs=[
            pl.BlockSpec((v_tile, k_out_tile), lambda v, k: (v, k)),
            pl.BlockSpec((v_tile, k_out_tile), lambda v, k: (v, k)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, K_pad), jnp.float32),
            jax.ShapeDtypeStruct((n_pad, K_pad), jnp.int32),
        ],
        compiler_params=_COMPILER_PARAMS,
        interpret=interpret,
    )(pre, pre, Cp, capp)
    return P[:n, :K], pj[:n, :K]
