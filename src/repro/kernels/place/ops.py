"""Public op: capacity-window place step (Pallas kernel or oracle)."""
from __future__ import annotations

import jax

from . import place as _kernel
from . import ref as _ref

BIG = _ref.BIG


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def place_window(C, cap, prefix, *, tiles=None):
    kw = {}
    if tiles is not None:
        kw = dict(v_tile=tiles[0], k_out_tile=tiles[1])
    return _kernel.place_window_pallas(C, cap, prefix,
                                       interpret=not _on_tpu(), **kw)


def place_window_ref(C, cap, prefix):
    return _ref.place_window_ref(C, cap, prefix)
