"""Pure-jnp oracle for the capacity-window place step.

    P[v, k]  = min_{j <= k,  prefix[k] - prefix[j] <= cap[v]}  C[v, j]
    pj[v, k] = argmin j (first minimal)

The "place" half of the BCPM relaxation (core/leastcost.py): extend the
partial map at node v by hosting dataflow nodes j..k-1, subject to v's
compute capacity (prefix = cumulative creq).  Infeasible = BIG.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.problem import BIG, EPS_CAP_F32


def place_window_ref(C, cap, prefix):
    """C (n, K), cap (n,), prefix (K,) -> (P (n, K), pj (n, K) int32)."""
    n, K = C.shape
    j = jnp.arange(K)
    k = jnp.arange(K)
    block = prefix[None, :, None] - prefix[None, None, :]  # [1, k, j]
    feas = (j[None, None, :] <= k[None, :, None]) & (
        block <= cap[:, None, None] + EPS_CAP_F32
    )  # [v, k, j]
    cand = jnp.where(feas, C[:, None, :], BIG)
    return jnp.min(cand, axis=2), jnp.argmin(cand, axis=2).astype(jnp.int32)
