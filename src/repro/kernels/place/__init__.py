from .ops import place_window, place_window_ref  # noqa: F401
from .place import place_window_pallas  # noqa: F401
