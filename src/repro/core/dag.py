"""Tree-topology dataflow mapping — the paper's §4 proposed extension.

The paper handles path topologies and names tree-shaped computations
(multi-source continual queries) as future work.  This module implements
that extension as a bottom-up dynamic program over the dataflow tree,
composing the path machinery:

  ``C[i][v]`` = min cost of mapping the subtree rooted at dataflow node ``i``
  with ``i`` placed on resource node ``v``:

  ``C[i][v] = [creq(i) <= cap(v)] * ( sum_children_c  min_u ( C[c][u] +
               bw-constrained-shortest-path_{breq(c,i)}(u -> v) ) )``

Like LeastCostMap this keeps one table entry per (dataflow node, resource
node); capacity is enforced per placement and *cumulatively re-validated* on
the reconstructed mapping (subtrees are combined independently, so two
subtrees may co-locate on one node; violations trigger a repair pass that
re-places offending nodes using their next-best table entries).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
from scipy.sparse.csgraph import dijkstra
from scipy.sparse import csr_matrix

from .graph import ResourceGraph

BIGF = 1e18


@dataclasses.dataclass(frozen=True)
class DataflowTree:
    """In-tree dataflow: every node sends its stream to ``parent[i]``;
    ``parent[sink] = -1``.  ``breq[i]`` = bandwidth of edge (i -> parent[i]).
    ``pinned``: {dataflow node -> resource node} (sources + sink at minimum).
    """

    creq: np.ndarray  # (p,)
    parent: np.ndarray  # (p,) int, -1 at sink
    breq: np.ndarray  # (p,), breq[sink] unused
    pinned: dict[int, int]

    @property
    def p(self) -> int:
        return int(self.creq.shape[0])

    @property
    def sink(self) -> int:
        return int(np.nonzero(self.parent < 0)[0][0])

    def children(self, i: int) -> list[int]:
        return [int(c) for c in np.nonzero(self.parent == i)[0]]


@dataclasses.dataclass
class TreeMapping:
    assign: tuple[int, ...]
    cost: float
    valid: bool
    routes: dict[int, tuple[int, ...]]  # dataflow node -> route to its parent


def _bw_shortest_paths(rg: ResourceGraph, breq: float) -> tuple[np.ndarray, np.ndarray]:
    """All-pairs shortest latency using only links with bw >= breq.

    Returns (dist, predecessors); O(n^2 log n) via scipy Dijkstra.
    """
    mask = (rg.bw >= breq) & np.isfinite(rg.lat) & (rg.lat > 0)
    w = np.where(mask, rg.lat, 0.0)
    dist, pred = dijkstra(
        csr_matrix(w), directed=True, return_predecessors=True
    )
    return dist, pred


def _extract_route(pred: np.ndarray, u: int, v: int) -> Optional[tuple[int, ...]]:
    if u == v:
        return (u,)
    route = [v]
    while route[-1] != u:
        p = pred[u, route[-1]]
        if p < 0:
            return None
        route.append(int(p))
    return tuple(reversed(route))


def treemap_leastcost(
    rg: ResourceGraph, tree: DataflowTree
) -> Optional[TreeMapping]:
    """Bottom-up LeastCostMap-style DP for tree dataflows."""
    p, n = tree.p, rg.n
    sink = tree.sink
    # Cache shortest paths per distinct bandwidth requirement.
    sp_cache: dict[float, tuple[np.ndarray, np.ndarray]] = {}

    def sp(b: float):
        key = float(b)
        if key not in sp_cache:
            sp_cache[key] = _bw_shortest_paths(rg, key)
        return sp_cache[key]

    order = []  # topological (leaves first)
    state = [0] * p
    stack = [sink]
    post = []
    while stack:
        i = stack.pop()
        post.append(i)
        stack.extend(tree.children(i))
    order = list(reversed(post))

    C = np.zeros((p, n), np.float64)
    choice: dict[tuple[int, int], dict[int, int]] = {}  # (i, v) -> {child: u}
    for i in order:
        ci = np.where(rg.cap >= tree.creq[i] - 1e-9, 0.0, BIGF)
        if i in tree.pinned:
            pin = np.full(n, BIGF)
            pin[tree.pinned[i]] = 0.0
            ci = np.maximum(ci, pin)
        for c in tree.children(i):
            dist, pred = sp(float(tree.breq[c]))
            # add min over u of C[c][u] + dist[u, v] for each v
            tot = C[c][:, None] + dist  # (u, v)
            ci = ci + tot.min(axis=0)
            arg = tot.argmin(axis=0)
            for v in range(n):
                choice.setdefault((i, v), {})[c] = int(arg[v])
        C[i] = np.minimum(ci, BIGF)

    v_sink = tree.pinned[sink]
    if C[sink][v_sink] >= BIGF / 2:
        return None
    # Reconstruct.
    assign = np.full(p, -1, np.int64)
    routes: dict[int, tuple[int, ...]] = {}
    stack = [(sink, v_sink)]
    total = 0.0
    while stack:
        i, v = stack.pop()
        assign[i] = v
        for c in tree.children(i):
            u = choice.get((i, v), {}).get(c)
            if u is None:
                return None
            dist, pred = sp(float(tree.breq[c]))
            r = _extract_route(pred, u, v)
            if r is None:
                return None
            routes[c] = r
            total += float(dist[u, v])
            stack.append((c, u))
    # Cumulative capacity validation + one repair pass.
    valid = _capacity_ok(rg, tree, assign)
    if not valid:
        assign, valid = _repair(rg, tree, assign, C)
        if valid:  # recompute routes/cost after repair
            return treemap_fixed(rg, tree, assign)
    return TreeMapping(tuple(int(a) for a in assign), total, bool(valid), routes)


def _capacity_ok(rg, tree, assign) -> bool:
    used = np.zeros(rg.n)
    for i, v in enumerate(assign):
        used[v] += tree.creq[i]
    return bool(np.all(used <= rg.cap + 1e-6))


def _repair(rg, tree, assign, C):
    """Move nodes off over-subscribed resources to their next-best entries."""
    assign = assign.copy()
    for _ in range(tree.p):
        used = np.zeros(rg.n)
        for i, v in enumerate(assign):
            used[v] += tree.creq[i]
        over = np.nonzero(used > rg.cap + 1e-6)[0]
        if len(over) == 0:
            return assign, True
        v = int(over[0])
        movable = [
            i for i in range(tree.p)
            if assign[i] == v and i not in tree.pinned and tree.creq[i] > 0
        ]
        if not movable:
            return assign, False
        i = max(movable, key=lambda i: tree.creq[i])
        costs = C[i].copy()
        costs[v] = BIGF
        headroom = rg.cap - used + (0)
        costs[headroom < tree.creq[i] - 1e-9] = BIGF
        nv = int(np.argmin(costs))
        if costs[nv] >= BIGF / 2:
            return assign, False
        assign[i] = nv
    return assign, False


def treemap_fixed(rg: ResourceGraph, tree: DataflowTree, assign) -> Optional[TreeMapping]:
    """Cost/route evaluation of a fixed assignment (used after repair)."""
    total = 0.0
    routes = {}
    for c in range(tree.p):
        par = int(tree.parent[c])
        if par < 0:
            continue
        dist, pred = _bw_shortest_paths(rg, float(tree.breq[c]))
        u, v = int(assign[c]), int(assign[par])
        r = _extract_route(pred, u, v)
        if r is None or not np.isfinite(dist[u, v]):
            return None
        routes[c] = r
        total += float(dist[u, v])
    return TreeMapping(tuple(int(a) for a in assign), total, _capacity_ok(rg, tree, assign), routes)
