"""Shared problem machinery for every BCPM solver backend.

Single source of truth for

- the numerical constants: the finite ``BIG`` sentinel that stands in for
  +inf inside min-plus kernels, and the feasibility epsilons that were
  previously re-declared (inconsistently: 1e-9 vs 1e-6 vs 1e-12) in
  ``core/leastcost.py``, ``core/simulator.py``, ``core/heuristics.py``,
  ``core/exact.py`` and both Pallas kernel packages;
- the per-instance precomputation every backend used to re-derive
  independently: capacity prefix sums + the ``cap_ok`` window test, and the
  dense float32 tensors consumed by the tensorized DP / Pallas kernels;
- request padding/stacking for the micro-batched multi-request DP
  (``core.engine.solve_batch`` / ``core.online.OnlinePlacer``): requests of
  *mixed* length ``p`` share one vmapped DP by padding the capacity prefix
  (repeat last value — trailing ghost nodes cost nothing) and the bandwidth
  requirements (``BIG`` — ghost dataflow edges admit no move), with the true
  length carried as a per-request ``p_eff`` scalar read only by the final
  reduction at ``dst``.
"""
from __future__ import annotations

import numpy as np

from .graph import DataflowPath, ResourceGraph

BIG = np.float32(1e18)  # finite stand-in for +inf inside kernels (min-plus safe)

# Feasibility slacks.  Scalar/python relaxations accumulate in float64 and use
# the tight slack; float32 tensor paths and end-to-end mapping validation use
# the loose one (float32 prefix sums lose ~7 digits).  ``EPS_COST`` is the
# strict-improvement tie-break of the python relaxations; ``EPS_IMPROVE`` the
# monotone-update threshold of the float32 DP.
EPS_CAP = 1e-9
EPS_CAP_F32 = 1e-6
EPS_BW = 1e-9
EPS_COST = 1e-12
EPS_IMPROVE = 1e-9


def creq_prefix(df: DataflowPath) -> np.ndarray:
    """(p+1,) float64 prefix sums of compute requirements; prefix[k]-prefix[j]
    is the load of placing dataflow nodes j..k-1 on one resource node."""
    return np.concatenate([[0.0], np.cumsum(df.creq)])


def make_cap_ok(rg: ResourceGraph, df: DataflowPath):
    """The capacity window test shared by all scalar relaxations:
    ``cap_ok(j, k, v)`` — can dataflow nodes j..k-1 be placed on node v?"""
    prefix = creq_prefix(df)

    def cap_ok(j: int, k: int, v: int) -> bool:
        return prefix[k] - prefix[j] <= float(rg.cap[v]) + EPS_CAP

    return cap_ok


def finite_lat(rg: ResourceGraph) -> np.ndarray:
    """Latency matrix with INF -> BIG and a BIG diagonal (moves never stay
    in place; the place step handles co-location)."""
    lat = np.where(np.isfinite(rg.lat), rg.lat, BIG).astype(np.float32)
    np.fill_diagonal(lat, BIG)
    return lat


def problem_tensors(rg: ResourceGraph, df: DataflowPath,
                    graph_tensors: dict | None = None) -> dict:
    """Dense float32 tensors for the DP/kernels. INF replaced by BIG.

    Region-local (compacted) problems reach here already sized ``n_r``:
    ``engine.solve(view=...)`` and ``OnlinePlacer(view=...)`` compact the
    graph/request up front, and :func:`stack_requests` accepts a ``view``
    for direct batched-tensor callers — one compaction path, owned by
    :mod:`repro.core.compact`.

    ``graph_tensors`` (``{cap, bw, lat}`` jnp arrays, e.g. from
    :meth:`repro.core.residual.ResidualState.device_tensors`) substitutes
    already-device-resident network tensors for the host upload — the
    pipelined admission path passes these so each micro-batch dispatch
    ships only the O(p) request tensors, never the O(n^2) network.
    """
    import jax.numpy as jnp  # deferred: numpy-only callers never touch jax

    s = creq_prefix(df).astype(np.float32)
    if graph_tensors is None:
        graph_tensors = dict(
            cap=jnp.asarray(rg.cap),
            bw=jnp.asarray(rg.bw),
            lat=jnp.asarray(finite_lat(rg)),
        )
    return dict(
        cap=graph_tensors["cap"],
        bw=graph_tensors["bw"],
        lat=graph_tensors["lat"],
        prefix=jnp.asarray(s),  # (p+1,)
        breq=jnp.asarray(df.breq.astype(np.float32)),  # (p-1,)
        src=jnp.asarray(df.src, jnp.int32),
        dst=jnp.asarray(df.dst, jnp.int32),
        p_eff=jnp.asarray(df.p, jnp.int32),
    )


def pad_request(df: DataflowPath, p_max: int) -> tuple[np.ndarray, np.ndarray]:
    """Pad one request's (prefix, breq) to the batch-wide ``p_max``.

    The prefix repeats its final value (ghost nodes require no compute) and
    breq pads with BIG (no link can carry a ghost dataflow edge, so the DP
    never extends a route past the request's true sink column).  Columns
    beyond ``p_eff`` are unreachable garbage the final reduction never reads.
    """
    p = df.p
    assert p <= p_max
    prefix = creq_prefix(df).astype(np.float32)
    prefix = np.concatenate([prefix, np.full(p_max - p, prefix[-1], np.float32)])
    breq = np.concatenate(
        [df.breq.astype(np.float32), np.full(p_max - p, BIG, np.float32)]
    )
    return prefix, breq[: p_max - 1]


def stack_requests(rg: ResourceGraph, dfs: list[DataflowPath],
                   pad_to: int | None = None, *, view=None,
                   graph_tensors: dict | None = None) -> tuple[dict, int]:
    """Stack mixed-``p`` requests against one shared resource network into
    the batched tensor dict for the batched DP.  Returns (tensors, p_max);
    link matrices are shared (axis None under vmap), per-request tensors are
    stacked on axis 0.

    ``pad_to`` pads the batch dimension to a fixed size by repeating the
    last request (a well-formed dummy problem) — the online placer buckets
    micro-batches to powers of two this way so a churning arrival process
    compiles at most log2(max batch) DP specializations per request shape.
    Callers must ignore results beyond ``len(dfs)``.

    ``view`` compacts a global problem into the view's local id space: the
    node dimension of every stacked tensor pads to the region-local
    ``n_r``, not the global ``n`` (see :mod:`repro.core.compact`).

    ``graph_tensors`` injects device-resident ``{cap, bw, lat}`` (already in
    whatever id space ``dfs`` use — incompatible with ``view`` compaction).
    """
    import jax.numpy as jnp

    assert dfs
    if view is not None:
        assert graph_tensors is None, "view compaction vs device tensors"
        rg = view.compact_graph(rg)
        dfs = [view.compact_df(d) for d in dfs]
    reqs = list(dfs)
    if pad_to is not None:
        assert pad_to >= len(reqs)
        reqs += [reqs[-1]] * (pad_to - len(reqs))
    p_max = max(d.p for d in reqs)
    padded = [pad_request(d, p_max) for d in reqs]
    base = problem_tensors(rg, reqs[0], graph_tensors=graph_tensors)
    tensors = dict(
        cap=base["cap"],
        bw=base["bw"],
        lat=base["lat"],
        prefix=jnp.asarray(np.stack([pr for pr, _ in padded])),
        breq=jnp.asarray(np.stack([bq for _, bq in padded])),
        src=jnp.asarray([d.src for d in reqs], jnp.int32),
        dst=jnp.asarray([d.dst for d in reqs], jnp.int32),
        p_eff=jnp.asarray([d.p for d in reqs], jnp.int32),
    )
    return tensors, p_max


BATCH_IN_AXES = {
    "cap": None, "bw": None, "lat": None,
    "prefix": 0, "breq": 0, "src": 0, "dst": 0, "p_eff": 0,
}
