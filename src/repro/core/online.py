"""Online multi-request placement service (the paper's dynamicity regime).

The paper's setting is *long-running* data-flow applications on a *dynamic*
network: mapping is not a one-shot solve but a continuous service admitting
a stream of requests against **residual** capacity (cf. Benoit et al. 2009,
Eidenbenz & Locher 2016 — concurrent in-network stream processing).

:class:`OnlinePlacer` owns a residual-capacity view of a
:class:`ResourceGraph` and provides:

- ``admit(df)`` / ``release(ticket)`` — placement against the residual
  network with capacity *and* bandwidth commit; rollback-free because a
  mapping is only committed after validating against the residual;
- ``admit_many(dfs)`` — micro-batches concurrent arrivals into a single
  vmapped DP (``engine.solve_batch`` -> ``leastcost_jax_batched``; mixed-p
  requests are padded, see ``core.problem``).  Batched solves share one
  residual snapshot, so each result is re-validated against the *current*
  residual before committing; conflicting requests are re-solved
  individually — optimistic concurrency at micro-batch granularity;
- ``fail_node`` / ``fail_link`` (+ ``restore_*``) — simulated churn.  A
  failure displaces every ticket whose route uses the failed element; the
  placer releases them and re-admits on the degraded residual network
  (highest preemption class first, tids preserved), returning
  ``(remapped new tickets, dropped old tickets)`` — the paper's dynamic
  re-mapping scenario served at throughput;
- service-layer hooks for the multi-tenant control plane
  (``repro.service``): per-ticket ``tenant``/``klass`` metadata,
  ``snapshot``/``restore`` for transactional multi-step mutations,
  ``admit_preempting`` (conservative, strictly class-ordered preemption)
  and ``rekey`` (stable ticket handles across re-mapping/defrag).

Invariant (checked by ``check_invariants``): for every node and link,
``base == residual + sum(ticket loads)`` and ``residual >= 0``.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import itertools
import time
from types import MappingProxyType
from typing import Mapping as MappingT, Optional, Sequence

import numpy as np

from . import engine
from .graph import DataflowPath, Mapping, ResourceGraph, validate_mapping
from .residual import ResidualState
from .solution_cache import SolutionCache, request_signature
from ..obs import trace as obs_trace


@dataclasses.dataclass(frozen=True, eq=False)
class Ticket:
    """A committed placement: the handle for ``release`` / churn re-mapping.

    ``node_load`` / ``edge_load`` are read-only views over private defensive
    copies: the placer's conservation invariant
    (``base == residual + sum(ticket loads)``) is computed from these, so a
    caller must not be able to mutate them after commit — item assignment
    raises ``TypeError`` and the dict a caller passed in is never aliased.

    ``tenant`` / ``klass`` are control-plane metadata (``repro.service``):
    the owning tenant and the preemption class.  A ticket may only ever be
    preempted by an admission of *strictly greater* class.
    """

    tid: int
    df: DataflowPath
    mapping: Mapping
    node_load: MappingT[int, float]  # resource node -> committed compute
    edge_load: MappingT[tuple, float]  # (u, v) -> committed bandwidth
    tenant: str = ""
    klass: int = 0

    def __post_init__(self):
        object.__setattr__(self, "node_load", MappingProxyType(dict(self.node_load)))
        object.__setattr__(self, "edge_load", MappingProxyType(dict(self.edge_load)))


@dataclasses.dataclass
class OnlineStats:
    admitted: int = 0
    rejected: int = 0
    released: int = 0
    remapped: int = 0
    dropped: int = 0
    preempted: int = 0  # released to make room for a higher-class admission
    batches: int = 0
    batch_conflicts: int = 0  # re-solved individually after a stale batch solve
    stale_batches: int = 0  # in-flight batches invalidated by churn/restore
    defrag_rounds: int = 0  # global re-optimization passes attempted
    defrag_commits: int = 0  # ... that improved the objective and committed
    solve_ms: float = 0.0  # device solve + reconstruction wall clock
    overhead_ms: float = 0.0  # host validation/commit loops around the solves
    conflict_resolve_ms: float = 0.0  # individual conflict re-solves, end to end
    solves: int = 0  # DP solves issued (a micro-batch counts once)
    solve_n_sum: int = 0  # summed padded node dimension of those solves
    # incremental fast path (SolutionCache): cache-hit admissions commit a
    # revalidated prior mapping with ZERO DP work, so they are deliberately
    # excluded from solve_ms/solves/solve_n_sum — the timing split and
    # mean_solve_n keep describing actual solver work.
    cache_hits: int = 0  # positive hit revalidated against current residual
    cache_misses: int = 0  # signature never seen (or evicted)
    cache_stale: int = 0  # entry found but no longer feasible
    cache_neg_hits: int = 0  # exact-stamp negative entry short-circuited
    warm_solves: int = 0  # bounded correction solves seeded from stale entries
    warm_fallbacks: int = 0  # warm pass placed nothing -> cold re-solve
    # solves per kernel backend ("pallas" / "ref" / native impl name):
    # non-additive engine.Stats fields (kernel_impl) carried as labeled
    # counts instead of last-writer-wins when stats fold across regions
    kernel_impls: dict = dataclasses.field(default_factory=dict)
    # superstep (relaxation-round) histogram per solve mode:
    # {"cold" | "warm": {rounds: solve count}} — the stat that proves the
    # warm-started path converges in fewer supersteps than a cold solve
    supersteps: dict = dataclasses.field(default_factory=dict)

    # solver-work fields preserved across speculative rollbacks (preemption
    # probes, defrag): wall clock was really spent and cache traffic really
    # happened even when the state change is rolled back
    _SOLVE_CARRY = (
        "solve_ms", "overhead_ms", "conflict_resolve_ms", "solves",
        "solve_n_sum", "cache_hits", "cache_misses", "cache_stale",
        "cache_neg_hits", "warm_solves", "warm_fallbacks",
    )

    @property
    def mean_solve_n(self) -> float:
        """Mean padded node dimension per DP solve — the number the
        compacted regional substrate shrinks from the global ``n`` to the
        region-local ``n_r`` (bench_messages solve-size column)."""
        return self.solve_n_sum / self.solves if self.solves else 0.0

    def clone(self) -> "OnlineStats":
        """Deep-enough copy for snapshot/restore: ``dataclasses.replace``
        would alias ``kernel_impls``/``supersteps`` and leak post-snapshot
        mutations through a rollback."""
        c = dataclasses.replace(self)
        c.kernel_impls = dict(self.kernel_impls)
        c.supersteps = {k: dict(v) for k, v in self.supersteps.items()}
        return c

    def solve_accounting(self) -> dict:
        """Capture the solver-work counters before a speculative rollback."""
        acct = {f: getattr(self, f) for f in self._SOLVE_CARRY}
        acct["kernel_impls"] = dict(self.kernel_impls)
        acct["supersteps"] = {k: dict(v) for k, v in self.supersteps.items()}
        return acct

    def restore_solve_accounting(self, acct: dict) -> None:
        """Re-apply counters captured by :meth:`solve_accounting` after a
        ``restore`` — probes did real solver work even when rolled back."""
        for f in self._SOLVE_CARRY:
            setattr(self, f, acct[f])
        self.kernel_impls = dict(acct["kernel_impls"])
        self.supersteps = {k: dict(v) for k, v in acct["supersteps"].items()}


def _edge_loads(df: DataflowPath, mapping: Mapping) -> dict:
    """Bandwidth committed per directed resource link: walk the route; the
    carried dataflow edge advances when the assigned node changes (the same
    walk as ``validate_mapping``)."""
    loads: dict = {}
    assign, route = mapping.assign, mapping.route
    pos = 0
    for u, v in zip(route[:-1], route[1:]):
        while pos + 1 < df.p and assign[pos + 1] == u:
            pos += 1
        loads[(u, v)] = loads.get((u, v), 0.0) + float(df.breq[pos])
    return loads


def _node_loads(df: DataflowPath, mapping: Mapping) -> dict:
    loads: dict = {}
    for i, v in enumerate(mapping.assign):
        loads[v] = loads.get(v, 0.0) + float(df.creq[i])
    return loads


@dataclasses.dataclass(eq=False)
class PendingAdmission:
    """An in-flight micro-batch: solve dispatched, commit deferred.

    Produced by :meth:`OnlinePlacer.dispatch_admit`, consumed exactly once
    by :meth:`OnlinePlacer.commit_admit`.  ``epoch`` is the placer's fence
    value at dispatch: if it moved by commit time (churn, restore, regional
    view invalidation) the dispatched results are discarded and the batch
    re-solves fresh.  The engine handle holds immutable device arrays, so
    residual mutations between dispatch and commit can never corrupt the
    in-flight solve — only make it *stale*, which commit-time validation
    (optimistic concurrency) or the epoch fence handles.

    ``tag`` is opaque caller context carried dispatch-to-commit (the
    streaming bench stores dispatch-time virtual clock / steady-phase
    flags there).

    With the incremental fast path active, ``plan`` records the dispatch
    classification of each request — ``("hit", mapping)`` (cached mapping
    revalidated at dispatch; commit revalidates again), ``("neg", None)``
    (exact-stamp negative), ``("warm", seed)`` (stale entry seeding a
    bounded correction solve in ``warm_handle``) or ``("cold", None)``
    (full solve in ``handle``).  ``plan is None`` means the cache was off
    for this batch and the commit path is byte-identical to the pre-cache
    code.  ``stamp`` is the (residual version, epoch) pair at dispatch —
    rejections only record negative cache entries if it still matches at
    commit time.
    """

    dfs: list
    metas: list
    handle: Optional[engine.PendingBatchSolve]
    epoch: int
    tag: object = None
    committed: bool = False
    plan: Optional[list] = None
    cold_idx: Optional[list] = None
    warm_idx: Optional[list] = None
    warm_handle: Optional[engine.PendingBatchSolve] = None
    stamp: Optional[tuple] = None


class OnlinePlacer:
    """Residual-capacity placement service over one resource network."""

    def __init__(
        self,
        rg: ResourceGraph,
        *,
        method: str = "leastcost_jax",
        use_kernel: bool = False,
        view=None,
        tracer=None,
        cache_enabled: bool = True,
        cache_size: int = 512,
        max_correction_supersteps: int = 4,
        **solve_cfg,
    ):
        """``use_kernel=True`` serves admissions through the fused batched
        Pallas DP path (``kernels/minplus/batched``; Pallas on TPU, its
        fused-jnp mirror elsewhere) — both micro-batched ``admit_many`` and
        single-request ``admit`` re-solves take it.  Extra ``solve_cfg``
        (e.g. ``tiles`` or ``kernel_impl``) is forwarded to the backend.

        ``cache_enabled`` turns on the two-tier incremental fast path: a
        :class:`~repro.core.solution_cache.SolutionCache` of the last
        committed mapping per request signature (tier 1 — an O(p)
        revalidation replaces the whole DP on repeat shapes), and, for
        stale entries on batched backends, a warm-started DP bounded to
        ``max_correction_supersteps`` relaxation rounds (tier 2) whose
        failures fall back to a full cold solve — admission quality is
        never below the cold path.  The cache is advisory: every hit is
        revalidated against the float64 residual truth before any
        reserve, so it can never over-commit, and ``cache_enabled=False``
        is bit-identical to the pre-cache admission path (fuzz-enforced).
        Both knobs ride ``**solve_cfg`` through
        ``ControlPlane``/``RegionalControlPlane``/``HierarchicalControlPlane``
        down to every per-region placer, whose caches operate entirely in
        view-local ids.

        ``view`` (a :class:`~repro.core.compact.CompactedView`) makes this
        a *region-local* placer: ``rg`` may be the global graph — it is
        compacted through the view up front, so every piece of state
        (residual arrays, liveness masks, tickets, routes) and every DP
        solve/kernel tile lives at the region-local ``n_r``, never the
        global ``n``.  All dataflows passed to ``admit*`` must already be
        in the view's local id space (``view.compact_df``); owners of
        global id spaces (the regional 2PC broker) translate at their
        boundary and can read the bijection back from ``placer.view``.

        ``tracer`` (:class:`repro.obs.Tracer`) records solve/commit spans;
        defaults to the no-op :data:`repro.obs.NULL` — tracing is purely
        observational (wall clock only), so enabling it never changes an
        admission decision.
        """
        self.tracer = tracer if tracer is not None else obs_trace.NULL
        self.view = view
        if view is not None:
            rg = view.compact_graph(rg) if rg.n == view.n_global else rg
            assert rg.n == view.n_local, "graph does not match the view"
        self.base = rg
        self.method = method
        if use_kernel:
            solve_cfg = dict(solve_cfg, use_kernel=True)
        self.solve_cfg = solve_cfg
        self.res = ResidualState(rg)
        self.tickets: dict[int, Ticket] = {}
        self.stats = OnlineStats()
        self._tid = itertools.count()
        self.cache = SolutionCache(cache_size) if cache_enabled else None
        self.max_correction_supersteps = int(max_correction_supersteps)
        self._cache_suspend = 0

    # -- incremental fast path ----------------------------------------------

    @property
    def _cache(self) -> Optional[SolutionCache]:
        """The cache, or None while disabled/suspended (defrag repacks
        suspend it: serving the standing mappings back from cache would
        make the re-optimization a no-op by construction)."""
        if self.cache is None or self._cache_suspend:
            return None
        return self.cache

    @contextlib.contextmanager
    def cache_suspended(self):
        """Bypass the cache (lookups AND fills) inside the block."""
        self._cache_suspend += 1
        try:
            yield
        finally:
            self._cache_suspend -= 1

    def _stamp(self) -> tuple:
        """Exact residual identity: host mutation version + staleness epoch
        (the epoch folds in the CompactedView version, so regional view
        remaps invalidate negative entries automatically)."""
        return (self.res.version, self.epoch)

    # -- residual view ------------------------------------------------------
    # The residual arrays live in ResidualState (host float64 truth +
    # device-resident float32 mirror); these read-only views keep the
    # placer's public surface (tests, regional conservation, examples).

    @property
    def cap(self) -> np.ndarray:
        return self.res.cap

    @property
    def bw(self) -> np.ndarray:
        return self.res.bw

    @property
    def node_up(self) -> np.ndarray:
        return self.res.node_up

    @property
    def link_up(self) -> np.ndarray:
        return self.res.link_up

    @property
    def epoch(self) -> int:
        """Staleness fence for in-flight optimistic batches: residual epoch
        (liveness changes, rollbacks) plus the CompactedView version when
        this is a region-local placer — regional churn invalidates the view,
        which must also invalidate any batch solved on the old compaction."""
        e = self.res.epoch
        if self.view is not None:
            e += self.view.version
        return e

    def residual_graph(self) -> ResourceGraph:
        """The network the next solve sees: committed capacity subtracted,
        failed nodes/links removed (cap 0 / bw 0 / lat INF)."""
        return self.res.residual_graph()

    def utilization(self) -> dict:
        base_cap = float(np.sum(self.base.cap))
        return {
            "nodes_committed": 1.0 - float(np.sum(self.cap)) / max(base_cap, 1e-12),
            "tickets": len(self.tickets),
            "nodes_down": int(np.sum(~self.node_up)),
        }

    # -- commit / release ---------------------------------------------------

    def _commit(self, df: DataflowPath, mapping: Mapping, *,
                tenant: str = "", klass: int = 0) -> Ticket:
        node_load = _node_loads(df, mapping)
        edge_load = _edge_loads(df, mapping)
        self.res.apply_load(node_load, edge_load, -1.0)
        t = Ticket(next(self._tid), df, mapping, node_load, edge_load,
                   tenant=tenant, klass=klass)
        self.tickets[t.tid] = t
        cache = self._cache
        if cache is not None:
            # cache filled only at commit: the entry is a mapping that
            # really held capacity, the strongest reuse candidate
            cache.put(request_signature(df), mapping)
        return t

    def release(self, ticket: Ticket | int, *,
                reason: Optional[str] = "released") -> Ticket:
        """Return a ticket's capacity to the residual.

        ``reason`` selects the stats counter: ``"released"`` (a normal
        departure), ``"preempted"`` (displaced to make room for a
        higher-class admission), or ``None`` (internal bookkeeping, e.g. the
        defrag pass clearing the standing set before the re-solve — counted
        by its own counters instead).
        """
        tid = ticket if isinstance(ticket, int) else ticket.tid
        t = self.tickets.pop(tid)
        self.res.apply_load(t.node_load, t.edge_load, 1.0)
        if reason == "released":
            self.stats.released += 1
        elif reason == "preempted":
            self.stats.preempted += 1
        return t

    # -- snapshot / atomic commit hooks (service-layer defrag + preemption) -

    def snapshot(self) -> dict:
        """Copy-out of the full service state (residuals, liveness, tickets,
        stats).  With :meth:`restore` this brackets speculative multi-step
        mutations — preemption probing, the defrag re-solve — so they either
        commit in full or leave no trace."""
        snap = self.res.snapshot()
        snap["tickets"] = dict(self.tickets)
        snap["stats"] = self.stats.clone()
        return snap

    def restore(self, snap: dict) -> None:
        """Roll back to a :meth:`snapshot` (the snapshot stays reusable).

        The residual epoch advances — it is never rewound — so any batch
        dispatched between snapshot and restore is fenced out: its results
        are *invalidated* at commit, never optimistically applied."""
        self.res.restore(snap)
        self.tickets = dict(snap["tickets"])
        self.stats = snap["stats"].clone()

    def rekey(self, new: Ticket, tid: int) -> Ticket:
        """Re-register a freshly committed ticket under a prior tid, so the
        handle an external holder keeps (control plane, departure timers)
        survives re-mapping and defrag re-placement."""
        kept = dataclasses.replace(new, tid=tid)
        del self.tickets[new.tid]
        self.tickets[tid] = kept
        return kept

    # -- admission ----------------------------------------------------------

    def _admissible(self, df: DataflowPath, mapping: Optional[Mapping],
                    rg: ResourceGraph) -> bool:
        if mapping is None:
            return False
        ok, _why = validate_mapping(rg, df, mapping)
        return ok

    def _note_solve(self, st, *, mode: str = "cold") -> None:
        """Fold one engine.Stats into the lifetime counters, keeping the
        non-additive ``kernel_impl`` as a labeled count and the superstep
        count as a per-mode histogram bucket."""
        self.stats.solve_ms += st.solve_ms
        self.stats.solves += 1
        self.stats.solve_n_sum += st.solve_n
        if st.kernel_impl:
            k = self.stats.kernel_impls
            k[st.kernel_impl] = k.get(st.kernel_impl, 0) + 1
        if mode == "warm":
            self.stats.warm_solves += 1
        if st.rounds:
            bucket = self.stats.supersteps.setdefault(mode, {})
            bucket[int(st.rounds)] = bucket.get(int(st.rounds), 0) + 1

    def admit(self, df: DataflowPath, *, tenant: str = "",
              klass: int = 0) -> Optional[Ticket]:
        """Place one request against the current residual network.

        With the cache enabled this consults tier 1 first: an exact-stamp
        negative short-circuits to rejection (sound — the residual is
        bit-identical to when the deterministic solve last rejected this
        signature), and a positive entry that revalidates against the
        current residual commits with zero DP work (and is deliberately
        NOT counted as a solve).  Anything else falls through to the full
        solve, exactly the pre-cache path."""
        if not (self.node_up[df.src] and self.node_up[df.dst]):
            self.stats.rejected += 1
            return None
        cache = self._cache
        sig = stamp = None
        if cache is not None:
            sig = request_signature(df)
            stamp = self._stamp()
            if cache.negative_hit(sig, stamp):
                self.stats.cache_neg_hits += 1
                self.stats.rejected += 1
                return None
            entry = cache.get(sig)
            if entry is not None:
                if self._admissible(df, entry, self.residual_graph()):
                    self.stats.cache_hits += 1
                    self.stats.admitted += 1
                    return self._commit(df, entry, tenant=tenant, klass=klass)
                self.stats.cache_stale += 1
            else:
                self.stats.cache_misses += 1
        rg = self.residual_graph()
        with self.tracer.span("solve", track="placer", cat="solve"):
            mapping, st = engine.solve(rg, df, method=self.method,
                                       **self.solve_cfg)
        self._note_solve(st)
        if not self._admissible(df, mapping, rg):
            if cache is not None and self._stamp() == stamp:
                cache.put_negative(sig, stamp)
            self.stats.rejected += 1
            return None
        self.stats.admitted += 1
        return self._commit(df, mapping, tenant=tenant, klass=klass)

    def admit_preempting(
        self, df: DataflowPath, *, tenant: str = "", klass: int = 0,
        max_preempt: int = 8, max_displaced_cost: Optional[float] = None,
    ) -> tuple[Optional[Ticket], list[Ticket]]:
        """Admit, displacing strictly-lower-class tickets if necessary.

        Victims are probed lowest class first; within a class, tickets
        loading the *target node* — the node where residual plus
        preemptable load peaks, i.e. where released capacity can
        accumulate into a hole big enough for the request — go first, then
        larger tickets, then newer.  After each release the request is
        re-solved on the freed residual.  If no victim set below ``klass``
        makes the request feasible the whole probe rolls back — preemption
        is *conservative*: capacity is never destroyed on a failed attempt,
        and a class-k ticket is only ever displaced by an admission of
        class > k.  Returns ``(ticket, preempted)``; the caller owns
        re-queueing the preempted work (e.g. through its tenant queue in
        the control plane).

        ``max_displaced_cost`` is the preemption *cost budget*: the summed
        committed compute of the displaced victims may not exceed it.  A
        victim that fits exactly at the budget may still be displaced; the
        first victim that would push past it ends the probe, which then
        rolls back cleanly if the request is still infeasible.
        """
        rejected0 = self.stats.rejected  # a served request is not a rejection
        t = self.admit(df, tenant=tenant, klass=klass)
        if t is not None:
            return t, []
        candidates = [v for v in self.tickets.values() if v.klass < klass]
        if not candidates:
            return None, []
        # concentrate releases where they can open the largest hole
        # (downed nodes can never host the request, whatever their cap)
        potential = np.where(self.node_up, self.cap, -np.inf)
        for v in candidates:
            for node, c in v.node_load.items():
                potential[node] += c
        target = int(np.argmax(potential))
        victims = sorted(
            candidates,
            key=lambda v: (
                v.klass,
                -v.node_load.get(target, 0.0),
                -sum(v.node_load.values()),
                -v.tid,
            ),
        )
        snap = self.snapshot()
        preempted: list[Ticket] = []
        displaced_cost = 0.0
        for v in victims[:max_preempt]:
            vcost = sum(v.node_load.values())
            if (
                max_displaced_cost is not None
                and displaced_cost + vcost > max_displaced_cost + 1e-9
            ):
                break  # over budget: end the probe (rolls back below)
            self.release(v, reason="preempted")
            preempted.append(v)
            displaced_cost += vcost
            t = self.admit(df, tenant=tenant, klass=klass)
            if t is not None:
                # probe rejections along the way are not real rejections
                self.stats.rejected = rejected0
                return t, preempted
        # probes did real solver work: keep the solve accounting across the
        # rollback (state restores, wall-clock and solve counts do not)
        acct = self.stats.solve_accounting()
        self.restore(snap)
        self.stats.restore_solve_accounting(acct)
        return None, []

    def _dispatch_solve(self, dfs: list[DataflowPath], *,
                        warm_starts=None,
                        max_rounds: Optional[int] = None,
                        ) -> engine.PendingBatchSolve:
        """Dispatch a batched solve for ``dfs`` against the current residual.

        On natively-batching backends the DP consumes the device-resident
        residual tensors (no O(n^2) host upload per micro-batch) and the
        batch is bucketed to the next power of two so a churning arrival
        process triggers at most log2(max batch) jit specializations per
        request shape.  Other backends solve synchronously inside the
        returned handle.

        ``warm_starts``/``max_rounds`` run the tier-2 bounded correction
        pass (batched backends only): the DP frontier is seeded from stale
        cached mappings and the relaxation capped at the fuse."""
        cfg = self.solve_cfg
        graph_tensors = None
        if self.method in engine.BATCHED_METHODS:
            cfg = dict(cfg, bucket_batch=True)
            if warm_starts is not None:
                cfg["warm_starts"] = warm_starts
            if max_rounds is not None:
                cfg["max_rounds"] = max_rounds
            graph_tensors = self.res.device_tensors()
        with self.tracer.span("dispatch", track="placer", cat="solve",
                              batch=len(dfs)), \
                self.tracer.annotate("minplus.dispatch"):
            return engine.solve_batch_dispatch(
                self.residual_graph(), list(dfs), method=self.method,
                graph_tensors=graph_tensors, **cfg,
            )

    def dispatch_admit(
        self,
        dfs: list[DataflowPath],
        metas: Optional[Sequence[tuple[str, int]]] = None,
        *,
        tag: object = None,
    ) -> PendingAdmission:
        """Start a micro-batch admission: dispatch the batched DP against a
        residual snapshot and return without waiting.  The device solve runs
        while the caller does host work (typically committing the previous
        batch); :meth:`commit_admit` finishes the admission.

        With the cache enabled each request is classified first (see
        :class:`PendingAdmission`); only the cold subset dispatches the
        full DP and only the stale-entry subset dispatches the bounded
        warm-started correction pass — a batch of pure repeats dispatches
        no solve at all."""
        dfs = list(dfs)
        if metas is None:
            metas = [("", 0)] * len(dfs)
        if not dfs:
            return PendingAdmission([], [], None, self.epoch, tag=tag)
        self.stats.batches += 1
        cache = self._cache
        if cache is None:
            handle = self._dispatch_solve(dfs)
            return PendingAdmission(dfs, list(metas), handle, self.epoch,
                                    tag=tag)
        t0 = time.perf_counter()
        rg = self.residual_graph()
        stamp = self._stamp()
        warm_ok = (self.method in engine.BATCHED_METHODS
                   and self.max_correction_supersteps > 0)
        plan: list[tuple] = []
        for df in dfs:
            sig = request_signature(df)
            if cache.negative_hit(sig, stamp):
                self.stats.cache_neg_hits += 1
                plan.append(("neg", None))
                continue
            entry = cache.get(sig)
            if entry is None:
                self.stats.cache_misses += 1
                plan.append(("cold", None))
                continue
            if (self.node_up[df.src] and self.node_up[df.dst]
                    and self._admissible(df, entry, rg)):
                # provisional hit: commit_admit revalidates against the
                # then-current residual before any reserve
                plan.append(("hit", entry))
                continue
            self.stats.cache_stale += 1
            seed = None
            if warm_ok:
                from .leastcost import warm_seed_from_mapping
                seed = warm_seed_from_mapping(rg, df, entry)
            plan.append(("warm", seed) if seed is not None else ("cold", None))
        cold_idx = [i for i, (k, _) in enumerate(plan) if k == "cold"]
        warm_idx = [i for i, (k, _) in enumerate(plan) if k == "warm"]
        self.stats.overhead_ms += 1e3 * (time.perf_counter() - t0)
        handle = (self._dispatch_solve([dfs[i] for i in cold_idx])
                  if cold_idx else None)
        warm_handle = None
        if warm_idx:
            warm_handle = self._dispatch_solve(
                [dfs[i] for i in warm_idx],
                warm_starts=[plan[i][1] for i in warm_idx],
                max_rounds=self.max_correction_supersteps,
            )
        return PendingAdmission(dfs, list(metas), handle, self.epoch, tag=tag,
                                plan=plan, cold_idx=cold_idx,
                                warm_idx=warm_idx, warm_handle=warm_handle,
                                stamp=stamp)

    def commit_admit(self, pending: PendingAdmission) -> list[Optional[Ticket]]:
        """Finish an in-flight admission: block on the solve (the only
        ``block_until_ready`` point), validate every mapping against the
        *current* residual, and commit.

        Three staleness layers, cheapest first:

        - epoch fence: if churn / restore / view invalidation happened since
          dispatch, the whole in-flight solve is discarded (never committed)
          and the batch re-solves fresh on the degraded network;
        - per-request validation: a mapping invalidated by commits that
          landed after dispatch (earlier in this batch, or — pipelined —
          whole batches) is re-solved individually, the existing
          optimistic-concurrency retry;
        - endpoint liveness re-check, as in the synchronous path.
        """
        assert not pending.committed, "commit_admit consumed twice"
        pending.committed = True
        dfs, metas = pending.dfs, pending.metas
        if not dfs:
            return []
        plan = pending.plan
        if pending.epoch != self.epoch:
            # the network changed shape under the in-flight solve: results
            # are unsalvageable (routes may cross dead elements in ways
            # validation against residuals can't always see) — invalidate,
            # re-solve on the current network.  Cached dispositions are
            # discarded with the rest: dispatch-time hits were validated
            # against a residual whose epoch is gone.
            plan = None
            self.stats.stale_batches += 1
            with self.tracer.span("solve.resolve_stale", track="placer",
                                  cat="solve", batch=len(dfs)):
                mappings, st = self._dispatch_solve(dfs).finalize()
            self._note_solve(st)
        elif plan is None:
            with self.tracer.span("solve.wait", track="placer", cat="solve",
                                  batch=len(dfs)):
                mappings, st = pending.handle.finalize()
            self._note_solve(st)
        else:
            # merge the classified subsets back into request order; only
            # the dispatched subsets count as solves (cache hits are zero
            # DP work and must not deflate the solve timing/size stats)
            mappings = [None] * len(dfs)
            for i, (kind, payload) in enumerate(plan):
                if kind == "hit":
                    mappings[i] = payload
            if pending.handle is not None:
                with self.tracer.span("solve.wait", track="placer",
                                      cat="solve", batch=len(pending.cold_idx)):
                    cold_maps, st = pending.handle.finalize()
                self._note_solve(st)
                for i, m in zip(pending.cold_idx, cold_maps):
                    mappings[i] = m
            if pending.warm_handle is not None:
                with self.tracer.span("solve.warm_wait", track="placer",
                                      cat="solve", batch=len(pending.warm_idx)):
                    warm_maps, wst = pending.warm_handle.finalize()
                self._note_solve(wst, mode="warm")
                for i, m in zip(pending.warm_idx, warm_maps):
                    mappings[i] = m
        cache = self._cache if plan is not None else None
        span = self.tracer.span("validate.commit", track="placer",
                                cat="admit", batch=len(dfs))
        t_host = time.perf_counter()
        conflict_ms = 0.0
        out: list[Optional[Ticket]] = []
        with span:
            current = self.residual_graph()
            for idx, (df, m, (tenant, klass)) in enumerate(
                    zip(dfs, mappings, metas)):
                kind = plan[idx][0] if plan is not None else "cold"
                if (
                    m is not None
                    and self.node_up[df.src]
                    and self.node_up[df.dst]
                    and self._admissible(df, m, current)
                ):
                    if kind == "hit":
                        self.stats.cache_hits += 1
                    self.stats.admitted += 1
                    out.append(self._commit(df, m, tenant=tenant, klass=klass))
                    current = self.residual_graph()
                elif m is not None:
                    # stale snapshot (a commit since dispatch took the
                    # capacity) — optimistic-concurrency retry, individually.
                    # A dispatch-time hit invalidated by an earlier commit in
                    # this batch lands here too; the retry's own cache lookup
                    # counts it as stale, so it is not a batch conflict (no
                    # solver work was wasted on it).
                    if kind != "hit":
                        self.stats.batch_conflicts += 1
                    t0 = time.perf_counter()
                    with self.tracer.span("conflict.resolve", track="placer",
                                          cat="admit"):
                        t = self.admit(df, tenant=tenant, klass=klass)
                    conflict_ms += 1e3 * (time.perf_counter() - t0)
                    out.append(t)
                    if t is not None:
                        current = self.residual_graph()
                elif kind == "warm":
                    # the bounded correction pass placed nothing — the fuse:
                    # fall back to a full cold re-solve so admission quality
                    # is never below the cold path
                    self.stats.warm_fallbacks += 1
                    t0 = time.perf_counter()
                    with self.tracer.span("warm.fallback", track="placer",
                                          cat="admit"):
                        t = self.admit(df, tenant=tenant, klass=klass)
                    conflict_ms += 1e3 * (time.perf_counter() - t0)
                    out.append(t)
                    if t is not None:
                        current = self.residual_graph()
                else:
                    self.stats.rejected += 1
                    if (cache is not None and kind == "cold"
                            and self._stamp() == pending.stamp):
                        # the residual is bit-identical to the dispatch
                        # snapshot the solve rejected against: an exact-
                        # stamp negative is sound
                        cache.put_negative(request_signature(df),
                                           pending.stamp)
                    out.append(None)
        self.stats.conflict_resolve_ms += conflict_ms
        self.stats.overhead_ms += 1e3 * (time.perf_counter() - t_host) - conflict_ms
        return out

    def admit_many(
        self,
        dfs: list[DataflowPath],
        metas: Optional[Sequence[tuple[str, int]]] = None,
    ) -> list[Optional[Ticket]]:
        """Micro-batch concurrent arrivals into one batched DP solve.

        All requests solve against one residual snapshot; commits are
        serialized, and any mapping invalidated by an earlier commit in the
        same batch is re-solved individually on the fresh residual.

        Exactly :meth:`dispatch_admit` immediately followed by
        :meth:`commit_admit` — the depth-1 degenerate of the admission
        pipeline, so the synchronous and pipelined paths cannot drift.
        """
        if not dfs:
            return []
        return self.commit_admit(self.dispatch_admit(dfs, metas))

    def warmup(self, *, max_batch: int = 32, p: int = 5) -> int:
        """Pre-compile the jit specializations the admission path will hit:
        the single-request DP (conflict re-solves / churn re-admissions) and
        every power-of-two batch bucket up to ``max_batch``, for requests of
        length ``p``.  Returns the largest warmed bucket (0 when the backend
        has no jit path).  Solves run on the residual network but commit
        nothing and touch no stats — cold-start compile spikes move here
        instead of polluting the first admissions' latency.
        """
        if self.method not in engine.BATCHED_METHODS:
            return 0
        rg = self.residual_graph()
        warm = DataflowPath.make(
            np.zeros(p, np.float32), np.zeros(p - 1, np.float32),
            src=0, dst=0,
        )
        engine.solve(rg, warm, method=self.method, **self.solve_cfg)
        warm_max = 1 << max(1, int(max_batch - 1).bit_length())
        # tier-2 correction solves compile their own specialization (warm
        # frontier tensors + the bounded-rounds fuse); pre-compile the
        # common seed-length buckets so the first stale-entry batch does
        # not pay the trace inside a timed admission
        seed = None
        if self.cache is not None and self.max_correction_supersteps > 0:
            seed = {
                "v": np.zeros(4, np.int32),
                "j": np.arange(1, 5, dtype=np.int32).clip(max=p),
                "cost": np.zeros(4, np.float32),
                "pv": np.zeros(4, np.int32),
                "pj": np.arange(0, 4, dtype=np.int32).clip(max=p - 1),
            }
        b = 1
        while b <= warm_max:
            engine.solve_batch(rg, [warm] * b, method=self.method,
                               bucket_batch=True, **self.solve_cfg)
            if seed is not None:
                engine.solve_batch(
                    rg, [warm] * b, method=self.method, bucket_batch=True,
                    warm_starts=[seed] * b,
                    max_rounds=self.max_correction_supersteps,
                    **self.solve_cfg)
            b *= 2
        self.res.warm_deltas()  # the commit-side scatter-add buckets too
        return warm_max

    # -- churn --------------------------------------------------------------

    def _displaced(self, pred) -> list[Ticket]:
        return [t for t in self.tickets.values() if pred(t)]

    def _remap(self, displaced: list[Ticket]) -> tuple[list[Ticket], list[Ticket]]:
        """Release the displaced tickets and re-admit them on the degraded
        residual, highest preemption class first (a class never waits behind
        a lower one for the post-failure capacity).  Re-admitted tickets keep
        their original ``tid`` (:meth:`rekey`), so handles held outside the
        placer — control-plane records, departure timers — stay valid across
        re-mapping.  Returns ``(remapped new tickets, dropped old tickets)``;
        dropped entries carry their ``df``/``tenant``/``klass`` so the caller
        can re-queue or escalate them.
        """
        displaced = sorted(displaced, key=lambda t: (-t.klass, t.tid))
        for t in displaced:
            self.release(t, reason=None)
        remapped, dropped = [], []
        tickets = self.admit_many(
            [t.df for t in displaced],
            metas=[(t.tenant, t.klass) for t in displaced],
        )
        for t, nt in zip(displaced, tickets):
            if nt is None:
                dropped.append(t)
                self.stats.dropped += 1
            else:
                remapped.append(self.rekey(nt, t.tid))
                self.stats.remapped += 1
        return remapped, dropped

    def fail_node(self, v: int) -> tuple[list[Ticket], list[Ticket]]:
        """Take node ``v`` down; re-map every placement routed through it.
        Bumps the residual epoch: in-flight optimistic batches are fenced
        out and will re-solve on the degraded network at commit."""
        self.res.set_node_up(v, False)
        return self._remap(self._displaced(lambda t: v in t.mapping.route))

    def fail_link(self, u: int, v: int) -> tuple[list[Ticket], list[Ticket]]:
        """Take the (symmetric) link down; re-map placements using it."""
        self.res.set_link_up(u, v, False)
        return self._remap(
            self._displaced(
                lambda t: (u, v) in t.edge_load or (v, u) in t.edge_load
            )
        )

    def restore_node(self, v: int) -> None:
        self.res.set_node_up(v, True)

    def restore_link(self, u: int, v: int) -> None:
        up = np.isfinite(self.base.lat[u, v])
        self.res.set_link_up(u, v, bool(up))

    # -- invariants ---------------------------------------------------------

    def check_invariants(self, atol: float = 1e-4) -> None:
        """base == residual + sum(ticket loads), residual >= 0, everywhere."""
        n = self.base.n
        cap_used = np.zeros(n)
        bw_used = np.zeros((n, n))
        for t in self.tickets.values():
            for v, c in t.node_load.items():
                cap_used[v] += c
            for (u, v), b in t.edge_load.items():
                bw_used[u, v] += b
        assert np.allclose(self.cap + cap_used, self.base.cap, atol=atol), (
            "node capacity conservation violated"
        )
        assert np.allclose(self.bw + bw_used, self.base.bw, atol=atol), (
            "link bandwidth conservation violated"
        )
        assert np.all(self.cap >= -atol), "negative residual capacity"
        assert np.all(self.bw >= -atol), "negative residual bandwidth"


class AdmissionPipeline:
    """Depth-bounded cross-batch admission pipeline over one placer.

    ``push(dfs)`` dispatches a new micro-batch solve and commits the oldest
    in-flight batch(es) once the window is full, so batch k+1's device DP
    runs while batch k's results validate and commit on the host.  With
    ``depth=1`` every push commits immediately — structurally identical to
    :meth:`OnlinePlacer.admit_many` (the bit-identity the fuzz suite
    enforces).  Deeper windows trade result staleness (more optimistic
    conflicts, re-solved individually at commit) for dead-time: the host
    never waits on a solve that hasn't had a full batch-interval to finish.

    Commit order is FIFO — admission outcomes depend only on the order
    batches *commit*, which matches the order they were pushed.
    """

    def __init__(self, placer: OnlinePlacer, depth: int = 1):
        self.placer = placer
        self.depth = max(1, int(depth))
        self._q: collections.deque[PendingAdmission] = collections.deque()

    @property
    def in_flight(self) -> int:
        return len(self._q)

    def push(
        self,
        dfs: list[DataflowPath],
        metas: Optional[Sequence[tuple[str, int]]] = None,
        *,
        tag: object = None,
    ) -> list[tuple[PendingAdmission, list[Optional[Ticket]]]]:
        """Dispatch ``dfs``; commit whatever the window forces out.  Returns
        ``(pending, tickets)`` for each batch committed by this call — the
        pending carries the caller's dispatch-time ``tag``."""
        if dfs:
            tr = self.placer.tracer
            if tr.enabled:
                tr.instant("pipeline.push", track="placer", cat="pipeline",
                           batch=len(dfs), in_flight=len(self._q))
            self._q.append(self.placer.dispatch_admit(dfs, metas, tag=tag))
        out = []
        while len(self._q) >= self.depth:
            out.append(self._commit_oldest())
        return out

    def flush(self) -> list[tuple[PendingAdmission, list[Optional[Ticket]]]]:
        """Commit every in-flight batch (end of stream / barrier)."""
        out = []
        while self._q:
            out.append(self._commit_oldest())
        return out

    def _commit_oldest(self):
        pending = self._q.popleft()
        return pending, self.placer.commit_admit(pending)
