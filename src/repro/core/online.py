"""Online multi-request placement service (the paper's dynamicity regime).

The paper's setting is *long-running* data-flow applications on a *dynamic*
network: mapping is not a one-shot solve but a continuous service admitting
a stream of requests against **residual** capacity (cf. Benoit et al. 2009,
Eidenbenz & Locher 2016 — concurrent in-network stream processing).

:class:`OnlinePlacer` owns a residual-capacity view of a
:class:`ResourceGraph` and provides:

- ``admit(df)`` / ``release(ticket)`` — placement against the residual
  network with capacity *and* bandwidth commit; rollback-free because a
  mapping is only committed after validating against the residual;
- ``admit_many(dfs)`` — micro-batches concurrent arrivals into a single
  vmapped DP (``engine.solve_batch`` -> ``leastcost_jax_batched``; mixed-p
  requests are padded, see ``core.problem``).  Batched solves share one
  residual snapshot, so each result is re-validated against the *current*
  residual before committing; conflicting requests are re-solved
  individually — optimistic concurrency at micro-batch granularity;
- ``fail_node`` / ``fail_link`` (+ ``restore_*``) — simulated churn.  A
  failure displaces every ticket whose route uses the failed element; the
  placer releases them and re-admits on the degraded residual network,
  returning (remapped, dropped) — the paper's dynamic re-mapping scenario
  served at throughput.

Invariant (checked by ``check_invariants``): for every node and link,
``base == residual + sum(ticket loads)`` and ``residual >= 0``.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

import numpy as np

from . import engine
from .graph import INF, DataflowPath, Mapping, ResourceGraph, validate_mapping


@dataclasses.dataclass(frozen=True)
class Ticket:
    """A committed placement: the handle for ``release`` / churn re-mapping."""

    tid: int
    df: DataflowPath
    mapping: Mapping
    node_load: dict  # resource node -> committed compute
    edge_load: dict  # (u, v) -> committed bandwidth


@dataclasses.dataclass
class OnlineStats:
    admitted: int = 0
    rejected: int = 0
    released: int = 0
    remapped: int = 0
    dropped: int = 0
    batches: int = 0
    batch_conflicts: int = 0  # re-solved individually after a stale batch solve
    solve_ms: float = 0.0


def _edge_loads(df: DataflowPath, mapping: Mapping) -> dict:
    """Bandwidth committed per directed resource link: walk the route; the
    carried dataflow edge advances when the assigned node changes (the same
    walk as ``validate_mapping``)."""
    loads: dict = {}
    assign, route = mapping.assign, mapping.route
    pos = 0
    for u, v in zip(route[:-1], route[1:]):
        while pos + 1 < df.p and assign[pos + 1] == u:
            pos += 1
        loads[(u, v)] = loads.get((u, v), 0.0) + float(df.breq[pos])
    return loads


def _node_loads(df: DataflowPath, mapping: Mapping) -> dict:
    loads: dict = {}
    for i, v in enumerate(mapping.assign):
        loads[v] = loads.get(v, 0.0) + float(df.creq[i])
    return loads


class OnlinePlacer:
    """Residual-capacity placement service over one resource network."""

    def __init__(
        self,
        rg: ResourceGraph,
        *,
        method: str = "leastcost_jax",
        use_kernel: bool = False,
        **solve_cfg,
    ):
        """``use_kernel=True`` serves admissions through the fused batched
        Pallas DP path (``kernels/minplus/batched``; Pallas on TPU, its
        fused-jnp mirror elsewhere) — both micro-batched ``admit_many`` and
        single-request ``admit`` re-solves take it.  Extra ``solve_cfg``
        (e.g. ``tiles`` or ``kernel_impl``) is forwarded to the backend."""
        self.base = rg
        self.method = method
        if use_kernel:
            solve_cfg = dict(solve_cfg, use_kernel=True)
        self.solve_cfg = solve_cfg
        n = rg.n
        self.cap = rg.cap.astype(np.float64).copy()
        self.bw = rg.bw.astype(np.float64).copy()
        self.node_up = np.ones(n, bool)
        self.link_up = np.isfinite(rg.lat) & ~np.eye(n, dtype=bool)
        self.tickets: dict[int, Ticket] = {}
        self.stats = OnlineStats()
        self._tid = itertools.count()

    # -- residual view ------------------------------------------------------

    def residual_graph(self) -> ResourceGraph:
        """The network the next solve sees: committed capacity subtracted,
        failed nodes/links removed (cap 0 / bw 0 / lat INF)."""
        n = self.base.n
        up2 = self.node_up[:, None] & self.node_up[None, :]
        alive = self.link_up & up2
        cap = np.where(self.node_up, self.cap, 0.0).astype(np.float32)
        bw = np.where(alive, self.bw, 0.0).astype(np.float32)
        lat = np.where(alive, self.base.lat, INF).astype(np.float32)
        np.fill_diagonal(lat, 0.0)
        return ResourceGraph(cap, bw, lat)

    def utilization(self) -> dict:
        base_cap = float(np.sum(self.base.cap))
        return {
            "nodes_committed": 1.0 - float(np.sum(self.cap)) / max(base_cap, 1e-12),
            "tickets": len(self.tickets),
            "nodes_down": int(np.sum(~self.node_up)),
        }

    # -- commit / release ---------------------------------------------------

    def _commit(self, df: DataflowPath, mapping: Mapping) -> Ticket:
        node_load = _node_loads(df, mapping)
        edge_load = _edge_loads(df, mapping)
        for v, c in node_load.items():
            self.cap[v] -= c
        for (u, v), b in edge_load.items():
            self.bw[u, v] -= b
        t = Ticket(next(self._tid), df, mapping, node_load, edge_load)
        self.tickets[t.tid] = t
        return t

    def release(self, ticket: Ticket | int) -> None:
        tid = ticket if isinstance(ticket, int) else ticket.tid
        t = self.tickets.pop(tid)
        for v, c in t.node_load.items():
            self.cap[v] += c
        for (u, v), b in t.edge_load.items():
            self.bw[u, v] += b
        self.stats.released += 1

    # -- admission ----------------------------------------------------------

    def _admissible(self, df: DataflowPath, mapping: Optional[Mapping],
                    rg: ResourceGraph) -> bool:
        if mapping is None:
            return False
        ok, _why = validate_mapping(rg, df, mapping)
        return ok

    def admit(self, df: DataflowPath) -> Optional[Ticket]:
        """Place one request against the current residual network."""
        if not (self.node_up[df.src] and self.node_up[df.dst]):
            self.stats.rejected += 1
            return None
        rg = self.residual_graph()
        mapping, st = engine.solve(rg, df, method=self.method, **self.solve_cfg)
        self.stats.solve_ms += st.solve_ms
        if not self._admissible(df, mapping, rg):
            self.stats.rejected += 1
            return None
        self.stats.admitted += 1
        return self._commit(df, mapping)

    def admit_many(self, dfs: list[DataflowPath]) -> list[Optional[Ticket]]:
        """Micro-batch concurrent arrivals into one batched DP solve.

        All requests solve against one residual snapshot; commits are
        serialized, and any mapping invalidated by an earlier commit in the
        same batch is re-solved individually on the fresh residual.

        On natively-batching backends the DP batch is bucketed to the next
        power of two (``bucket_batch``: dummy tensor rows, never
        reconstructed), so a churning arrival process triggers at most
        log2(max batch) jit specializations per request shape instead of
        one per distinct micro-batch size.
        """
        if not dfs:
            return []
        self.stats.batches += 1
        snapshot = self.residual_graph()
        cfg = self.solve_cfg
        if self.method in engine.BATCHED_METHODS:
            cfg = dict(cfg, bucket_batch=True)
        mappings, st = engine.solve_batch(
            snapshot, list(dfs), method=self.method, **cfg
        )
        self.stats.solve_ms += st.solve_ms
        out: list[Optional[Ticket]] = []
        current = snapshot  # refreshed only on commit (the only mutation)
        for df, m in zip(dfs, mappings):
            if (
                m is not None
                and self.node_up[df.src]
                and self.node_up[df.dst]
                and self._admissible(df, m, current)
            ):
                self.stats.admitted += 1
                out.append(self._commit(df, m))
                current = self.residual_graph()
            elif m is not None:
                # stale snapshot (an earlier commit in this batch took the
                # capacity) — optimistic-concurrency retry, individually
                self.stats.batch_conflicts += 1
                t = self.admit(df)
                out.append(t)
                if t is not None:
                    current = self.residual_graph()
            else:
                self.stats.rejected += 1
                out.append(None)
        return out

    # -- churn --------------------------------------------------------------

    def _displaced(self, pred) -> list[Ticket]:
        return [t for t in self.tickets.values() if pred(t)]

    def _remap(self, displaced: list[Ticket]) -> tuple[list[Ticket], list[DataflowPath]]:
        for t in displaced:
            self.release(t)
        remapped, dropped = [], []
        tickets = self.admit_many([t.df for t in displaced])
        for t, nt in zip(displaced, tickets):
            if nt is None:
                dropped.append(t.df)
                self.stats.dropped += 1
            else:
                remapped.append(nt)
                self.stats.remapped += 1
        return remapped, dropped

    def fail_node(self, v: int) -> tuple[list[Ticket], list[DataflowPath]]:
        """Take node ``v`` down; re-map every placement routed through it."""
        self.node_up[v] = False
        return self._remap(self._displaced(lambda t: v in t.mapping.route))

    def fail_link(self, u: int, v: int) -> tuple[list[Ticket], list[DataflowPath]]:
        """Take the (symmetric) link down; re-map placements using it."""
        self.link_up[u, v] = self.link_up[v, u] = False
        return self._remap(
            self._displaced(
                lambda t: (u, v) in t.edge_load or (v, u) in t.edge_load
            )
        )

    def restore_node(self, v: int) -> None:
        self.node_up[v] = True

    def restore_link(self, u: int, v: int) -> None:
        up = np.isfinite(self.base.lat[u, v])
        self.link_up[u, v] = self.link_up[v, u] = bool(up)

    # -- invariants ---------------------------------------------------------

    def check_invariants(self, atol: float = 1e-4) -> None:
        """base == residual + sum(ticket loads), residual >= 0, everywhere."""
        n = self.base.n
        cap_used = np.zeros(n)
        bw_used = np.zeros((n, n))
        for t in self.tickets.values():
            for v, c in t.node_load.items():
                cap_used[v] += c
            for (u, v), b in t.edge_load.items():
                bw_used[u, v] += b
        assert np.allclose(self.cap + cap_used, self.base.cap, atol=atol), (
            "node capacity conservation violated"
        )
        assert np.allclose(self.bw + bw_used, self.base.bw, atol=atol), (
            "link bandwidth conservation violated"
        )
        assert np.all(self.cap >= -atol), "negative residual capacity"
        assert np.all(self.bw >= -atol), "negative residual bandwidth"
