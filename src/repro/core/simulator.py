"""Event-driven simulator of the paper's distributed algorithm (Alg. 4).

Faithful asynchronous message-passing: each resource node knows only its
immediate neighborhood (its capacity, bandwidth/latency of incident links).
A mapping request is injected at the pinned source node; partial maps travel
as messages whose delivery delay is the link latency; a node receiving a map
runs ``ProcessMap`` — extend locally with 0..p-j-1 computations, forward
along links satisfying the next dataflow edge's bandwidth requirement,
avoiding nodes already in the carried route (Alg. 4 line 12).  Messages
carry the partial mapping itself (Alg. 4 line 1).

Pruning policies reproduce the paper's §3.4 heuristics:

- ``exact``        — no pruning, per-node dedup of identical states.
- ``leastcost``    — keep/forward only new per-(node, prefix-length) minima;
                     higher-cost maps that *arrive first* are still processed
                     (the asynchrony caveat of §3.4.1).
- ``annealed``     — additionally accept a non-minimal map with prob
                     exp(-delta/T), T decaying with virtual time (§3.4.2).
- ``random_k``     — forward to a random subset of k feasible neighbors
                     (§3.4.3).

Instrumented: messages sent/processed/pruned, per-node set sizes, virtual
completion time — these feed ``benchmarks/bench_messages.py`` (the paper's
~100x message-reduction and RandomNeighbor quality claims).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Optional

import numpy as np

from .graph import DataflowPath, Mapping, ResourceGraph
from .problem import EPS_BW, EPS_COST, make_cap_ok


@dataclasses.dataclass
class SimStats:
    messages_sent: int = 0
    messages_processed: int = 0
    messages_pruned: int = 0
    max_set_size: int = 0  # max total stored partial maps across all nodes
    virtual_time: float = 0.0
    completed_at: Optional[float] = None  # virtual time of first feasible map


@dataclasses.dataclass
class SimConfig:
    policy: str = "leastcost"  # exact | leastcost | annealed | random_k
    stop: str = "quiesce"  # "first" (paper's forced termination) | "quiesce"
    k: int = 1  # random_k fan-out
    t0: float = 5.0  # annealed initial temperature
    tau: float = 50.0  # annealed time constant (virtual-time units)
    seed: int = 0
    max_messages: int = 5_000_000


def simulate(
    rg: ResourceGraph, df: DataflowPath, cfg: SimConfig = SimConfig()
) -> tuple[Optional[Mapping], SimStats]:
    p, n = df.p, rg.n
    src, dst = df.src, df.dst
    rng = np.random.default_rng(cfg.seed)
    stats = SimStats()
    cap_ok = make_cap_ok(rg, df)  # place nodes j..k-1 on v

    neighbors = {u: rg.neighbors(u) for u in range(n)}

    # Per-node state (strictly local knowledge).
    seen: list[set] = [set() for _ in range(n)]  # exact/random_k dedup
    best_cost: list[list[float]] = [[np.inf] * (p + 1) for _ in range(n)]
    stored: list[int] = [0] * n

    best: Optional[Mapping] = None
    counter = itertools.count()
    queue: list = []  # (time, tiebreak, target, assign, route, cost)

    def send(t: float, u: int, v: int, assign: tuple, route: tuple, cost: float):
        stats.messages_sent += 1
        if stats.messages_sent > cfg.max_messages:
            raise MemoryError(f"message explosion (> {cfg.max_messages})")
        heapq.heappush(
            queue, (t + float(rg.lat[u, v]), next(counter), v, assign, route, cost)
        )

    def accept(u: int, assign: tuple, route: tuple, cost: float, t: float) -> bool:
        """Per-policy decision to process (and store) an arriving map."""
        j = len(assign)
        if cfg.policy in ("exact", "random_k"):
            key = (assign, route)
            if key in seen[u]:
                return False
            seen[u].add(key)
            stored[u] += 1
            return True
        if cost < best_cost[u][j] - EPS_COST:
            best_cost[u][j] = cost
            stored[u] += 1
            return True
        if cfg.policy == "annealed":
            T = cfg.t0 * np.exp(-t / cfg.tau)
            if T > 1e-9 and rng.random() < np.exp(-(cost - best_cost[u][j]) / T):
                stored[u] += 1
                return True
        return False

    def process(u: int, assign: tuple, route: tuple, cost: float, t: float):
        """Paper Alg. 4 (ProcessMap)."""
        nonlocal best
        stats.messages_processed += 1
        j = len(assign)
        if u == dst:
            # Alg. 4 lines 3-7: place all remaining computations on t.
            if cap_ok(j, p, u):
                m = Mapping(assign + (u,) * (p - j), route, cost)
                if best is None or cost < best.cost:
                    best = m
                    if stats.completed_at is None:
                        stats.completed_at = t
            return
        # Alg. 4 lines 9-19.
        for x in range(0, p - j):
            if not cap_ok(j, j + x, u):
                break  # monotone prefix sums
            k = j + x  # nodes placed after this extension
            if k < 1:
                continue  # the pinned source computation must be placed first
            new_assign = assign + (u,) * x
            outs = [
                v
                for v in neighbors[u]
                if v not in route
                and float(rg.bw[u, v]) + EPS_BW >= float(df.breq[k - 1])
            ]
            if cfg.policy == "random_k" and len(outs) > cfg.k:
                outs = [int(v) for v in rng.choice(outs, size=cfg.k, replace=False)]
            for v in outs:
                # "extend m_x by appending a map of 0 computations on node v"
                send(t, u, v, new_assign, route + (v,), cost + float(rg.lat[u, v]))

    # Request injection: the source processes the empty map (Alg. 4 line 1:
    # the first message carries the requirement definition of the computation).
    if src == dst:
        if cap_ok(0, p, src):
            best = Mapping((src,) * p, (src,), 0.0)
            stats.completed_at = 0.0
        return best, stats
    if accept(src, (), (src,), 0.0, 0.0):
        process(src, (), (src,), 0.0, 0.0)

    while queue:
        t, _, u, assign, route, cost = heapq.heappop(queue)
        stats.virtual_time = t
        stats.max_set_size = max(stats.max_set_size, sum(stored))
        if cfg.stop == "first" and best is not None:
            break  # forced termination broadcast (paper §3.3)
        if accept(u, assign, route, cost, t):
            process(u, assign, route, cost, t)
        else:
            stats.messages_pruned += 1
    return best, stats
