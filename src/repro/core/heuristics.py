"""Centralized variants of the paper's §3.4 heuristics.

``anneal_python``  — AnnealedLeastCostMap (§3.4.2): per (node, prefix) keep
the incumbent minimum plus, with probability exp(-delta/T(round)), bounded
extra non-minimal maps, trading message/set complexity for solution quality.

``random_k_python`` — RandomNeighbor (§3.4.3): LeastCostMap pruning, but each
relaxed map is only offered to a random subset of k neighbors.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from .graph import DataflowPath, Mapping, ResourceGraph
from .leastcost import HeuristicStats
from .problem import EPS_BW, EPS_COST, make_cap_ok


def _run(
    rg: ResourceGraph,
    df: DataflowPath,
    *,
    policy: str,
    k: int = 1,
    t0: float = 5.0,
    decay: float = 0.7,
    max_keep: int = 4,
    seed: int = 0,
) -> tuple[Optional[Mapping], HeuristicStats]:
    p, n = df.p, rg.n
    src, dst = df.src, df.dst
    rng = np.random.default_rng(seed)
    stats = HeuristicStats()
    cap_ok = make_cap_ok(rg, df)

    # M[u][j] = list of (cost, assign, route); index 0 is the incumbent min.
    M: list[list[list]] = [[[] for _ in range(p + 1)] for _ in range(n)]
    best: Optional[Mapping] = None

    for j in range(1, p):
        if not cap_ok(0, j, src):
            break
        M[src][j] = [(0.0, (src,) * j, (src,))]
    if src == dst and cap_ok(0, p, src):
        best = Mapping((src,) * p, (src,), 0.0)

    out_nbrs = {u: rg.neighbors(u) for u in range(n)}
    fresh = {(src, j) for j in range(1, p) if M[src][j]}
    for rnd in range(n - 1):
        stats.rounds = rnd + 1
        T = t0 * (decay ** rnd)
        new_fresh: set = set()
        for (u, j) in sorted(fresh):
            for (cost, assign, route) in list(M[u][j]):
                nbrs = out_nbrs[u]
                if policy == "random_k" and len(nbrs) > k:
                    nbrs = [int(v) for v in rng.choice(nbrs, size=k, replace=False)]
                for v in nbrs:
                    if v in route:
                        continue
                    if float(rg.bw[u, v]) + EPS_BW < float(df.breq[j - 1]):
                        continue
                    ncost = cost + float(rg.lat[u, v])
                    if v == dst:
                        if cap_ok(j, p, v):
                            m = Mapping(assign + (v,) * (p - j), route + (v,), ncost)
                            if best is None or m.cost < best.cost:
                                best = m
                        continue
                    for x in range(0, p - j):
                        if not cap_ok(j, j + x, v):
                            break
                        jj = j + x
                        entry = (ncost, assign + (v,) * x, route + (v,))
                        cur = M[v][jj]
                        if not cur or ncost < cur[0][0] - EPS_COST:
                            cur.insert(0, entry)
                            del cur[max_keep:]
                            stats.total_maps_generated += 1
                            new_fresh.add((v, jj))
                        elif policy == "annealed" and T > 1e-9:
                            delta = ncost - cur[0][0]
                            if rng.random() < np.exp(-delta / T) and len(cur) < max_keep:
                                cur.append(entry)
                                stats.total_maps_generated += 1
                                new_fresh.add((v, jj))
        stats.max_set_size = max(
            stats.max_set_size, sum(len(c) for row in M for c in row)
        )
        fresh = new_fresh
        if not fresh:
            break
    return best, stats


def anneal_python(rg, df, *, t0=5.0, decay=0.7, max_keep=4, seed=0):
    return _run(rg, df, policy="annealed", t0=t0, decay=decay, max_keep=max_keep, seed=seed)


def random_k_python(rg, df, *, k=1, seed=0):
    # LeastCostMap-style storage (one map per (node, prefix)), random fan-out.
    return _run(rg, df, policy="random_k", k=k, seed=seed, max_keep=1)
