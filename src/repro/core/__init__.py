"""Core: the paper's contribution — BCPM/BCDM mapping algorithms.

Public API:
  problem:     BIG sentinel + feasibility epsilons, shared precomputation
  compact:     CompactedView — global<->local id bijection; region-local
               compacted solves (n_r-sized tensors, read/write-through)
  graph:       ResourceGraph, DataflowPath, Mapping, validate_mapping
  engine:      solve / solve_batch — ONE entry point over every backend
  online:      OnlinePlacer + AdmissionPipeline — residual-capacity
               multi-request service with cross-batch solve/commit overlap
  residual:    ResidualState — device-resident residual tensors, versioned
               host mirror, staleness epochs for in-flight solves
  solution_cache: SolutionCache — mapping-reuse cache behind the placer's
               incremental admission fast path (validate-before-reserve)
  exact:       pathmap_exact (paper Alg. 1-3), brute_force oracle
  leastcost:   leastcost_python (faithful §3.4.1), leastcost_jax (tensorized)
  simulator:   simulate (paper Alg. 4, async message passing, all §3.4 policies)
  distributed: leastcost_shard_map (decentralized on a JAX device mesh)
  heuristics:  anneal_python (§3.4.2), random_k_python (§3.4.3)
  dag:         treemap_leastcost (paper §4 future-work extension)
  topology:    waxman / barabasi_albert (BRITE stand-ins), random_dataflow
"""
from .problem import BIG  # noqa: F401
from .compact import CompactedView, compact_view  # noqa: F401
from .graph import (  # noqa: F401
    DataflowPath,
    Mapping,
    ResourceGraph,
    mapping_cost,
    route_from_assign,
    validate_mapping,
)
from .exact import ExactStats, brute_force, pathmap_exact  # noqa: F401
from .leastcost import (  # noqa: F401
    HeuristicStats,
    leastcost_jax,
    leastcost_jax_batched,
    leastcost_python,
)
from .simulator import SimConfig, SimStats, simulate  # noqa: F401
from .heuristics import anneal_python, random_k_python  # noqa: F401
from .dag import DataflowTree, TreeMapping, treemap_leastcost  # noqa: F401
from .engine import (  # noqa: F401
    Stats,
    backends,
    register,
    solve,
    solve_batch,
    solve_batch_dispatch,
)
from .online import (  # noqa: F401
    AdmissionPipeline,
    OnlinePlacer,
    OnlineStats,
    PendingAdmission,
    Ticket,
)
from .residual import ResidualState  # noqa: F401
from .solution_cache import SolutionCache, request_signature  # noqa: F401
from .topology import (  # noqa: F401
    barabasi_albert,
    paper_example,
    random_dataflow,
    region_grid,
    region_line,
    region_tree,
    waxman,
)
