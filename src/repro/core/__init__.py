"""Core: the paper's contribution — BCPM/BCDM mapping algorithms.

Public API:
  graph:       ResourceGraph, DataflowPath, Mapping, validate_mapping
  exact:       pathmap_exact (paper Alg. 1-3), brute_force oracle
  leastcost:   leastcost_python (faithful §3.4.1), leastcost_jax (tensorized)
  simulator:   simulate (paper Alg. 4, async message passing, all §3.4 policies)
  distributed: leastcost_shard_map (decentralized on a JAX device mesh)
  heuristics:  anneal_python (§3.4.2), random_k_python (§3.4.3)
  dag:         treemap_leastcost (paper §4 future-work extension)
  topology:    waxman / barabasi_albert (BRITE stand-ins), random_dataflow
"""
from .graph import (  # noqa: F401
    DataflowPath,
    Mapping,
    ResourceGraph,
    mapping_cost,
    route_from_assign,
    validate_mapping,
)
from .exact import ExactStats, brute_force, pathmap_exact  # noqa: F401
from .leastcost import (  # noqa: F401
    HeuristicStats,
    leastcost_jax,
    leastcost_python,
)
from .simulator import SimConfig, SimStats, simulate  # noqa: F401
from .heuristics import anneal_python, random_k_python  # noqa: F401
from .dag import DataflowTree, TreeMapping, treemap_leastcost  # noqa: F401
from .topology import (  # noqa: F401
    barabasi_albert,
    paper_example,
    random_dataflow,
    waxman,
)
