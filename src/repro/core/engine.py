"""Unified mapper engine: one entry point over every solver backend.

Before this module the repo had five solver backends with five incompatible
call signatures (``simulator.simulate``, ``leastcost_python``,
``heuristics.anneal/random_k``, ``leastcost_jax[_batched]``,
``distributed.leastcost_shard_map``).  The engine registers each behind a
name and exposes

    solve(rg, df, method="leastcost_jax", **cfg) -> (Mapping | None, Stats)
    solve_batch(rg, dfs, **cfg)                  -> (list[Mapping | None], Stats)

with a single :class:`Stats` dataclass covering rounds / messages /
set sizes / fallbacks across all backends, so callers (``launch/placement``,
``core.online.OnlinePlacer``, benchmarks) never see a backend-specific API.

Registered methods:

  ``exact``             paper Alg. 1-3 (centralized PathMap; exponential)
  ``simulate``          event-driven async simulator (Alg. 4); ``policy=``
                        exact | leastcost | annealed | random_k
  ``leastcost_python``  faithful path-carrying LeastCostMap (§3.4.1)
  ``anneal``            AnnealedLeastCostMap (§3.4.2)
  ``random_k``          RandomNeighbor (§3.4.3)
  ``leastcost_jax``     tensorized (min,+) DP; ``use_kernel=True`` runs the
                        fused batched Pallas superstep (minplus/batched)
  ``shard_map``         decentralized BSP engine on a JAX device mesh

New backends register with :func:`register`; ``solve`` stays the only API.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

from .graph import DataflowPath, Mapping, ResourceGraph


@dataclasses.dataclass
class Stats:
    """Backend-independent solve statistics.

    Fields not meaningful for a backend keep their zero default (e.g. the
    python relaxations send no messages; the simulator has no fallback).
    """

    method: str = ""
    rounds: int = 0  # relaxation rounds / BSP supersteps
    messages_sent: int = 0  # async messages, or BSP async-equivalent count
    messages_processed: int = 0
    messages_pruned: int = 0
    messages_cross_device: int = 0  # BSP: messages crossing a partition
    max_set_size: int = 0  # peak live partial-map states
    maps_generated: int = 0
    fallback_used: bool = False  # tensorized backends: path-carrying rescue
    validated: bool = True
    kernel_impl: str = ""  # use_kernel paths: "pallas" | "interpret" | "ref"
    virtual_time: float = 0.0  # simulator virtual completion time
    solve_ms: float = 0.0  # wall clock inside the backend (device + reconstruct)
    # host-side admission overhead: validation / reserve / commit loops
    # around the solves — the half of admit latency the pipelined path
    # overlaps with the next batch's device work (service-layer counter,
    # filled by OnlinePlacer via engine_stats; zero for bare solves).
    overhead_ms: float = 0.0
    # wall clock spent re-solving optimistic-concurrency conflicts
    # individually after a stale batch solve (service-layer counter).
    conflict_resolve_ms: float = 0.0
    # batches whose in-flight solve was invalidated wholesale by a
    # churn/restore epoch bump and re-solved fresh (service-layer counter).
    stale_batches: int = 0
    batch_size: int = 1
    # node dimension the solve actually ran over — the padded DP/kernel
    # size.  Equals rg.n, or the region-local n_r when a CompactedView was
    # passed: the compaction win the regional plane is graded on
    # (bench_messages solve-size column).
    solve_n: int = 0
    # service-layer counters (repro.service control plane): how much solver
    # work was spent displacing lower-class tickets / re-optimizing the
    # standing allocation, surfaced next to the per-solve numbers so a
    # benchmark row tells the whole admission story.
    preemptions: int = 0  # tickets displaced by higher-class admissions
    defrag_rounds: int = 0  # global re-optimization passes attempted
    # regional control plane (repro.service.regions): cross-region
    # coordination traffic — push-gossip share dissemination and the
    # two-phase commit protocol placing region-spanning dataflows.  Both
    # fold into messages_sent so one column compares a decentralized plane
    # against the per-solve flooding counts of the async simulator.
    gossip_messages: int = 0  # share-estimate pushes (O(R*fanout) per round)
    twopc_messages: int = 0  # reserve/commit/abort traffic for spanning dfs


def _unify(native, method: str) -> Stats:
    """Map any backend's native stats object onto the unified Stats."""
    s = Stats(method=method)
    if native is None:
        return s
    s.rounds = int(getattr(native, "rounds", 0) or getattr(native, "supersteps", 0))
    s.messages_sent = int(
        getattr(native, "messages_sent", 0) or getattr(native, "messages_total", 0)
    )
    s.messages_processed = int(getattr(native, "messages_processed", 0))
    s.messages_pruned = int(getattr(native, "messages_pruned", 0))
    s.messages_cross_device = int(getattr(native, "messages_cross_device", 0))
    s.max_set_size = int(getattr(native, "max_set_size", 0))
    s.maps_generated = int(getattr(native, "total_maps_generated", 0))
    s.fallback_used = bool(getattr(native, "fallback_used", False))
    s.validated = bool(getattr(native, "validated", True))
    s.kernel_impl = str(getattr(native, "kernel_impl", ""))
    s.virtual_time = float(
        getattr(native, "completed_at", None) or getattr(native, "virtual_time", 0.0)
    )
    s.preemptions = int(getattr(native, "preempted", 0))
    s.defrag_rounds = int(getattr(native, "defrag_rounds", 0))
    s.gossip_messages = int(getattr(native, "gossip_messages", 0))
    s.twopc_messages = int(getattr(native, "twopc_messages", 0))
    return s


_REGISTRY: dict[str, Callable] = {}

# Backends that natively batch many requests into one solve in solve_batch
# (everything else falls back to a sequential loop).  Callers that shape
# their batches around native batching (e.g. OnlinePlacer's power-of-two
# bucketing) key off this set rather than hardcoding method names.
BATCHED_METHODS = frozenset({"leastcost_jax"})


def register(name: str):
    """Register ``fn(rg, df, **cfg) -> (Mapping | None, native_stats)``."""

    def deco(fn):
        _REGISTRY[name] = fn
        return fn

    return deco


def backends() -> list[str]:
    return sorted(_REGISTRY)


def solve(
    rg: ResourceGraph,
    df: DataflowPath,
    method: str = "leastcost_jax",
    view=None,
    **cfg,
) -> tuple[Optional[Mapping], Stats]:
    """Solve one mapping request with the named backend.

    ``view`` (a :class:`~repro.core.compact.CompactedView`) makes this a
    *region-local* solve: ``rg`` and ``df`` stay in global ids, but the
    backend runs over the view's compacted ``n_r``-node slice and the
    returned mapping is lifted back to global ids.  ``Stats.solve_n``
    records the node dimension the backend actually saw.
    """
    try:
        fn = _REGISTRY[method]
    except KeyError:
        raise ValueError(
            f"unknown mapper backend {method!r}; registered: {backends()}"
        ) from None
    t0 = time.perf_counter()
    if view is not None and not view.is_identity:
        mapping, native = fn(view.compact_graph(rg), view.compact_df(df), **cfg)
        if mapping is not None:
            mapping = view.uncompact_mapping(mapping)
        solve_n = view.n_local
    else:
        mapping, native = fn(rg, df, **cfg)
        solve_n = rg.n
    stats = _unify(native, method)
    stats.solve_n = solve_n
    stats.solve_ms = 1e3 * (time.perf_counter() - t0)
    return mapping, stats


def solve_batch(
    rg: ResourceGraph,
    dfs: list[DataflowPath],
    method: str = "leastcost_jax",
    view=None,
    **cfg,
) -> tuple[list[Optional[Mapping]], Stats]:
    """Solve many requests against one shared network.

    ``leastcost_jax`` batches into a single batched DP (mixed-``p`` requests
    are padded; see ``core.problem``); with ``use_kernel=True`` in ``cfg``
    the fused batched Pallas superstep of ``repro.kernels.minplus.batched``
    replaces the vmapped per-request graph (``Stats.kernel_impl`` records
    which implementation ran).  Every other backend falls back to a
    sequential loop through :func:`solve`.

    ``view`` compacts the whole batch into the view's local id space
    before solving (every request's endpoints must live in the view):
    tiles pad to the region-local ``n_r``, mappings come back global.

    ``graph_tensors`` (in ``cfg``, batched methods only) injects
    device-resident ``{cap, bw, lat}`` so the solve skips the per-batch
    host upload of the network — see :func:`solve_batch_dispatch` for the
    fully asynchronous variant.
    """
    if not dfs:
        return [], Stats(method=method, batch_size=0)
    t0 = time.perf_counter()
    if view is not None and not view.is_identity:
        rg = view.compact_graph(rg)
        dfs = [view.compact_df(d) for d in dfs]
    if method in BATCHED_METHODS:
        from .leastcost import leastcost_jax_batched

        # warm-start seeds live in the caller's (already-local) id space;
        # they cannot survive a view compaction done here
        assert view is None or view.is_identity or "warm_starts" not in cfg
        stats = Stats(method=method)
        mappings = leastcost_jax_batched(rg, list(dfs), stats=stats, **cfg)
    else:
        cfg.pop("graph_tensors", None)  # host-loop backends have no device path
        cfg.pop("warm_starts", None)  # warm seeding is a batched-DP feature
        mappings = []
        stats = Stats(method=method)
        for df in dfs:
            m, st = solve(rg, df, method=method, **cfg)
            mappings.append(m)
            stats.messages_sent += st.messages_sent
            stats.rounds = max(stats.rounds, st.rounds)
            stats.max_set_size = max(stats.max_set_size, st.max_set_size)
            stats.fallback_used |= st.fallback_used
            stats.validated &= st.validated
            stats.preemptions += st.preemptions
            stats.defrag_rounds += st.defrag_rounds
            # non-additive: keep the first backend impl seen rather than
            # dropping it on the floor (per-impl counts live in the
            # telemetry registry / OnlineStats.kernel_impls)
            stats.kernel_impl = stats.kernel_impl or st.kernel_impl
    if view is not None and not view.is_identity:
        mappings = [
            view.uncompact_mapping(m) if m is not None else None
            for m in mappings
        ]
    stats.solve_n = rg.n
    stats.batch_size = len(dfs)
    stats.solve_ms = 1e3 * (time.perf_counter() - t0)
    return mappings, stats


class PendingBatchSolve:
    """Handle for an asynchronously dispatched :func:`solve_batch`.

    Batched backends dispatch the device DP and return immediately; the
    host blocks only inside :meth:`finalize` (the commit point).  Backends
    without native batching solve synchronously at dispatch time and
    finalize just hands the stored result back — callers get one uniform
    dispatch/finalize API whatever the backend (the fuzz suites drive the
    pipeline through ``leastcost_python`` this way).
    """

    def __init__(self, method: str, view, dfs, *, pending=None, ready=None,
                 dispatch_ms: float = 0.0):
        self.method = method
        self.view = view
        self.dfs = dfs
        self._pending = pending  # leastcost.PendingDP (batched backends)
        self._ready = ready  # (mappings, Stats) (sync backends)
        self._dispatch_ms = dispatch_ms
        self._solve_n = pending.rg.n if pending is not None else None

    def finalize(self) -> tuple[list[Optional[Mapping]], Stats]:
        """Block until the solve completes; return ``(mappings, stats)``.

        ``stats.solve_ms`` covers dispatch plus the blocking wait and
        reconstruction — the same wall clock :func:`solve_batch` reports,
        minus whatever the caller overlapped between the two halves."""
        if self._ready is not None:
            return self._ready
        from .leastcost import leastcost_jax_batched_finalize

        t0 = time.perf_counter()
        stats = Stats(method=self.method)
        mappings = leastcost_jax_batched_finalize(self._pending, stats=stats)
        if self.view is not None and not self.view.is_identity:
            mappings = [
                self.view.uncompact_mapping(m) if m is not None else None
                for m in mappings
            ]
        stats.solve_n = self._solve_n
        stats.batch_size = len(self.dfs)
        stats.solve_ms = self._dispatch_ms + 1e3 * (time.perf_counter() - t0)
        self._ready = (mappings, stats)
        self._pending = None
        return self._ready


def solve_batch_dispatch(
    rg: ResourceGraph,
    dfs: list[DataflowPath],
    method: str = "leastcost_jax",
    view=None,
    graph_tensors=None,
    **cfg,
) -> PendingBatchSolve:
    """Asynchronous :func:`solve_batch`: dispatch now, block at
    :meth:`PendingBatchSolve.finalize`.

    On batched backends the device computation starts immediately (JAX
    async dispatch) while the caller keeps the host busy — the online
    placer overlaps batch k+1's solve with batch k's validation/commit.
    ``graph_tensors`` injects device-resident network tensors (see
    ``core.residual.ResidualState``) so the dispatch ships only the O(p)
    request tensors.  Non-batching backends run synchronously here.
    """
    if not dfs:
        return PendingBatchSolve(method, view, [],
                                 ready=([], Stats(method=method, batch_size=0)))
    if method in BATCHED_METHODS:
        from .leastcost import leastcost_jax_batched_dispatch

        t0 = time.perf_counter()
        if view is not None and not view.is_identity:
            assert graph_tensors is None, "view compaction vs device tensors"
            assert "warm_starts" not in cfg, "warm seeds vs view compaction"
            rg = view.compact_graph(rg)
            dfs = [view.compact_df(d) for d in dfs]
        pending = leastcost_jax_batched_dispatch(
            rg, list(dfs), graph_tensors=graph_tensors, **cfg
        )
        return PendingBatchSolve(
            method, view, list(dfs), pending=pending,
            dispatch_ms=1e3 * (time.perf_counter() - t0),
        )
    ready = solve_batch(rg, list(dfs), method=method, view=view, **cfg)
    return PendingBatchSolve(method, view, list(dfs), ready=ready)


# ---------------------------------------------------------------------------
# Backend adapters
# ---------------------------------------------------------------------------


@register("exact")
def _exact(rg, df, **cfg):
    from .exact import pathmap_exact

    return pathmap_exact(rg, df, **cfg)


@register("simulate")
def _simulate(rg, df, **cfg):
    from .simulator import SimConfig, simulate

    sim_cfg = cfg.pop("cfg", None) or SimConfig(**cfg)
    return simulate(rg, df, sim_cfg)


@register("leastcost_python")
def _leastcost_python(rg, df, **cfg):
    from .leastcost import leastcost_python

    return leastcost_python(rg, df, **cfg)


@register("anneal")
def _anneal(rg, df, **cfg):
    from .heuristics import anneal_python

    return anneal_python(rg, df, **cfg)


@register("random_k")
def _random_k(rg, df, **cfg):
    from .heuristics import random_k_python

    return random_k_python(rg, df, **cfg)


@register("leastcost_jax")
def _leastcost_jax(rg, df, **cfg):
    from .leastcost import leastcost_jax

    return leastcost_jax(rg, df, **cfg)


@register("shard_map")
def _shard_map_backend(rg, df, **cfg):
    from .distributed import leastcost_shard_map

    return leastcost_shard_map(rg, df, **cfg)
