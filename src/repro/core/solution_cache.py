"""SolutionCache — tier 1 of the incremental admission fast path.

Steady-state streams repeat request shapes, and churn re-admits the same
dataflows over a residual graph that has barely moved.  The cache keeps,
per canonical request signature, the *last committed mapping* so a
repeat admit can skip the batched (min,+) DP entirely.

Safety discipline (same as gossip / congestion estimates): the cache is
**advisory only**.  A positive hit is *always* re-validated against the
float64 host residual truth (``validate_mapping``) before any reserve,
so a stale entry can cause extra work but never an over-commit.
Negative entries ("this signature was just rejected") are only honored
at the **exact** ``(ResidualState.version, epoch)`` stamp they were
recorded under — the residual is versioned on every host mutation, so
an identical stamp means an identical residual and the deterministic DP
would reject again; any mutation invalidates the negative implicitly.

Entries live in the placer's id space.  Per-region placers operate on
``CompactedView``-local ids, so regional / hierarchical planes get
per-region caches for free, and the broker's spanning sub-segments
(admitted via ``placer.admit(view.compact_df(seg))``) ride the same
per-region cache.  The placer folds ``view.version`` into the epoch it
stamps with, so a view remap invalidates negatives automatically.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Optional, Tuple

from .graph import DataflowPath, Mapping

__all__ = ["SolutionCache", "request_signature"]

Signature = Tuple
Stamp = Tuple[int, int]  # (ResidualState.version, placer epoch)


def request_signature(df: DataflowPath) -> Signature:
    """Canonical signature of a request: length, per-node compute demands,
    per-edge bandwidth demands, and the src/dst pins — everything the DP
    reads from the request side of the problem.  Ids are whatever space
    the owning placer solves in (global for the flat plane, view-local
    for regional planes)."""
    return (df.p, int(df.src), int(df.dst),
            df.creq.tobytes(), df.breq.tobytes())


class SolutionCache:
    """LRU positive entries (signature -> last committed mapping) plus
    exact-stamp negative entries (signature -> rejection stamp)."""

    def __init__(self, capacity: int = 512):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self._pos: "OrderedDict[Signature, Mapping]" = OrderedDict()
        self._neg: "OrderedDict[Signature, Stamp]" = OrderedDict()

    # -- positive entries ------------------------------------------------------

    def get(self, sig: Signature) -> Optional[Mapping]:
        """Last committed mapping for ``sig``, or None.  The caller MUST
        re-validate against current residual truth before reserving."""
        m = self._pos.get(sig)
        if m is not None:
            self._pos.move_to_end(sig)
        return m

    def put(self, sig: Signature, mapping: Mapping) -> None:
        """Record a *committed* mapping; clears any negative for ``sig``
        (the commit itself proves the signature admissible)."""
        self._neg.pop(sig, None)
        self._pos[sig] = mapping
        self._pos.move_to_end(sig)
        while len(self._pos) > self.capacity:
            self._pos.popitem(last=False)

    # -- negative entries ------------------------------------------------------

    def put_negative(self, sig: Signature, stamp: Stamp) -> None:
        """Record a rejection observed at ``stamp``.  Only meaningful if
        the residual did not move between solve and record — the caller
        checks that."""
        self._neg[sig] = stamp
        self._neg.move_to_end(sig)
        while len(self._neg) > self.capacity:
            self._neg.popitem(last=False)

    def negative_hit(self, sig: Signature, stamp: Stamp) -> bool:
        """True iff ``sig`` was rejected at exactly this residual stamp.
        Sound (identical residual => the deterministic solve rejects
        again) and can only ever under-admit by zero: any host mutation
        bumps the version, so the entry simply stops matching."""
        return self._neg.get(sig) == stamp

    # -- maintenance -----------------------------------------------------------

    def drop(self, sig: Signature) -> None:
        self._pos.pop(sig, None)
        self._neg.pop(sig, None)

    def clear(self) -> None:
        self._pos.clear()
        self._neg.clear()

    def __len__(self) -> int:
        return len(self._pos)

    @property
    def negatives(self) -> int:
        return len(self._neg)
