"""Decentralized BCPM on a JAX device mesh (the paper's Alg. 4, SPMD-native).

The paper's constraint — "each node in the resource network is aware of the
state of its immediate neighborhood only" — is mapped onto SPMD hardware by
partitioning the resource-graph nodes across devices with ``shard_map``:

- each device owns a contiguous block of resource nodes: their capacities,
  their partial-map state rows ``C[v, :]`` and their *incoming* link columns
  ``lat[:, owned]``, ``bw[:, owned]`` (= local neighborhood knowledge);
- one relaxation superstep = local *place* step + frontier exchange
  (``all_gather`` of the placed frontier ``P`` — the bulk-synchronous
  analogue of the paper's asynchronous message flood) + local *move* step;
- termination: a psum'd ``changed`` flag inside ``lax.while_loop`` —
  the paper's quiescence detection (or first-feasible forced stop).

Message accounting matches the async algorithm: a superstep "sends" one
message per (improved frontier state, feasible outgoing neighbor) pair;
we report total and cross-device counts so the BSP engine is comparable to
``core.simulator`` in ``benchmarks/bench_messages.py``.

This module is also the production path for *placement at scale*: mapping
requests for thousands-of-node resource graphs are solved on the very pod
they will run on, with the graph state sharded — no single host ever holds
the full network state (the paper's motivating constraint).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .graph import DataflowPath, Mapping, ResourceGraph
from .leastcost import HeuristicStats, _place_step
from .problem import BIG, EPS_CAP_F32, EPS_IMPROVE, creq_prefix, finite_lat
from .reconstruct import reconstruct_mapping

# jax >= 0.6 promotes shard_map to the top-level namespace; older releases
# (the pinned 0.4.x) only ship the experimental entry point.
_shard_map = getattr(jax, "shard_map", None)
_SHARD_MAP_KW: dict = {}
if _shard_map is None:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map

    # the experimental tracer has no replication rule for while_loop
    _SHARD_MAP_KW = {"check_rep": False}


@dataclasses.dataclass
class DistStats(HeuristicStats):
    messages_total: int = 0  # async-equivalent messages
    messages_cross_device: int = 0  # messages that crossed a partition
    supersteps: int = 0


def _pad_to(x: np.ndarray, n_pad: int, fill) -> np.ndarray:
    pad = [(0, n_pad - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
    return np.pad(x, pad, constant_values=fill)


def _local_move(P_all, lat_cols, bw_cols, breq_k):
    """C'[w,k] for owned w: min_v P_all[v,k] + lat[v,w], bw[v,w] >= breq[k-1]."""

    def one_k(args):
        bk, Pk = args  # Pk: (n_pad,)
        cand = jnp.where(bw_cols >= bk, Pk[:, None] + lat_cols, BIG)  # [v, w_loc]
        return jnp.min(cand, axis=0), jnp.argmin(cand, axis=0).astype(jnp.int32)

    Cmv_t, pv_t = jax.lax.map(one_k, (breq_k, P_all.T))
    return Cmv_t.T, pv_t.T  # (n_loc, p+1)


def _dist_body(C, par_v, par_j, msg_tot, msg_x, cap_loc, lat_cols, bw_cols,
               prefix, breq_k, out_deg, out_deg_x, axis: str):
    """One superstep, executed inside shard_map."""
    P_loc, pj_loc = _place_step(C, cap_loc, prefix)
    P_all = jax.lax.all_gather(P_loc, axis, tiled=True)  # frontier exchange
    pj_all = jax.lax.all_gather(pj_loc, axis, tiled=True)
    Cmv, pv = _local_move(P_all, lat_cols, bw_cols, breq_k)
    upd = Cmv < C - EPS_IMPROVE
    Cn = jnp.where(upd, Cmv, C)
    pj_of_pv = pj_all[pv, jnp.arange(C.shape[1])[None, :]]
    par_vn = jnp.where(upd, pv, par_v)
    par_jn = jnp.where(upd, pj_of_pv, par_j)
    # Async-message equivalence: a newly accepted map at owned node (w,k)
    # would be forwarded to every outgoing neighbor of w (one message each).
    msg_tot = msg_tot + jax.lax.psum(jnp.sum(upd * out_deg[:, None]), axis)
    msg_x = msg_x + jax.lax.psum(jnp.sum(upd * out_deg_x[:, None]), axis)
    changed = jax.lax.psum(jnp.any(upd).astype(jnp.int32), axis) > 0
    return Cn, par_vn, par_jn, msg_tot, msg_x, changed


def leastcost_shard_map(
    rg: ResourceGraph,
    df: DataflowPath,
    *,
    mesh: Optional[Mesh] = None,
    validate: bool = True,
    max_rounds: Optional[int] = None,
) -> tuple[Optional[Mapping], DistStats]:
    """LeastCostMap with the resource graph partitioned over a device mesh."""
    axis = "nodes"
    if mesh is None:
        mesh = jax.make_mesh((jax.device_count(),), (axis,))
    D = mesh.devices.size
    n, p = rg.n, df.p
    n_pad = -(-n // D) * D
    stats = DistStats()

    lat_p = np.full((n_pad, n_pad), BIG, np.float32)
    lat_p[:n, :n] = finite_lat(rg)
    bw_p = np.zeros((n_pad, n_pad), np.float32)
    bw_p[:n, :n] = rg.bw
    cap_p = _pad_to(rg.cap.astype(np.float32), n_pad, 0.0)
    prefix = creq_prefix(df).astype(np.float32)
    breq_k = np.concatenate([[BIG], df.breq, [BIG]]).astype(np.float32)
    finite_edge = np.isfinite(rg.lat) & ~np.eye(n, dtype=bool)
    out_deg = _pad_to(finite_edge.sum(1).astype(np.int32), n_pad, 0)
    owner = np.arange(n_pad) // (n_pad // D)
    cross = finite_edge & (owner[:n, None] != owner[None, :n])
    out_deg_x = _pad_to(cross.sum(1).astype(np.int32), n_pad, 0)

    C0 = np.full((n_pad, p + 1), BIG, np.float32)
    C0[df.src, 0] = 0.0
    pv0 = np.full((n_pad, p + 1), -1, np.int32)
    pj0 = np.full((n_pad, p + 1), -1, np.int32)
    T = max_rounds or max(n - 1, 1)

    row = NamedSharding(mesh, P(axis))
    col = NamedSharding(mesh, P(None, axis))
    rep = NamedSharding(mesh, P())

    @functools.partial(
        _shard_map,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(None, axis), P(None, axis),
                  P(), P(), P(axis), P(axis)),
        out_specs=(P(axis), P(axis), P(axis), P(), P()),
        **_SHARD_MAP_KW,
    )
    def run(C, pv, pj, cap_loc, lat_cols, bw_cols, prefix, breq_k, out_deg, out_deg_x):
        def cond(s):
            t, _C, _pv, _pj, mt, mx, changed = s
            return (t < T) & changed

        def body(s):
            t, C, pv, pj, mt, mx, _ = s
            C, pv, pj, mt, mx, changed = _dist_body(
                C, pv, pj, mt, mx, cap_loc, lat_cols, bw_cols,
                prefix, breq_k, out_deg, out_deg_x, axis,
            )
            return t + 1, C, pv, pj, mt, mx, changed

        t, C, pv, pj, mt, mx, _ = jax.lax.while_loop(
            cond, body,
            (jnp.int32(0), C, pv, pj, jnp.float32(0), jnp.float32(0), jnp.bool_(True)),
        )
        return C, pv, pj, mt, jnp.stack([mx, t.astype(jnp.float32)])

    args = [
        jax.device_put(jnp.asarray(C0), row),
        jax.device_put(jnp.asarray(pv0), row),
        jax.device_put(jnp.asarray(pj0), row),
        jax.device_put(jnp.asarray(cap_p), row),
        jax.device_put(jnp.asarray(lat_p), col),
        jax.device_put(jnp.asarray(bw_p), col),
        jax.device_put(jnp.asarray(prefix), rep),
        jax.device_put(jnp.asarray(breq_k), rep),
        jax.device_put(jnp.asarray(out_deg).astype(jnp.float32), row),
        jax.device_put(jnp.asarray(out_deg_x).astype(jnp.float32), row),
    ]
    C, par_v, par_j, msg_tot, mx_t = jax.jit(run)(*args)
    C = np.asarray(C)[:n]
    par_v, par_j = np.asarray(par_v)[:n], np.asarray(par_j)[:n]
    stats.messages_total = int(msg_tot)
    stats.messages_cross_device = int(np.asarray(mx_t)[0])
    stats.supersteps = stats.rounds = int(np.asarray(mx_t)[1])
    stats.max_set_size = int(np.sum(C < BIG / 2))

    # finish: min over j<p with capacity for the tail on dst
    feas = (np.arange(p + 1) < p) & (
        prefix[p] - prefix <= float(rg.cap[df.dst]) + EPS_CAP_F32
    )
    final = np.where(feas, C[df.dst], BIG)
    best_j = int(np.argmin(final))
    m = reconstruct_mapping(
        rg, df, par_v, par_j, float(final[best_j]), best_j,
        validate=validate, stats=stats,
    )
    return m, stats
