"""Paper-faithful exact BCPM algorithm (paper Alg. 1/2/3) + brute-force oracle.

``pathmap_exact`` implements the centralized Bellman-Ford-style relaxation:
every resource node ``u`` maintains sets ``M(u, j)`` of feasible partial maps
of the first ``j`` dataflow nodes onto simple resource paths ``src ⇝ u``.
``|V_R| - 1`` rounds of relaxing every edge enumerate all feasible complete
mappings at ``dst`` (Theorem 3.3).  Exponential in the worst case — this is
the oracle for tests and the baseline for the heuristic benchmarks (the
paper could not run it beyond ~50-node networks; same here).

A partial map is ``(assign, route, cost)`` with ``route`` the simple resource
path (cycle avoidance, paper Alg. 4 line 12) — identical state to the
distributed message payload.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

import numpy as np

from .graph import DataflowPath, Mapping, ResourceGraph, mapping_cost
from .problem import EPS_BW, EPS_CAP


@dataclasses.dataclass
class ExactStats:
    """Instrumentation for the paper's complexity claims (§3.2, §3.4.1)."""

    max_set_size: int = 0  # max total partial maps alive at once
    total_maps_generated: int = 0
    rounds: int = 0


def _extend_ok(df: DataflowPath, rg: ResourceGraph, j: int, x: int, v: int) -> bool:
    """Paper Alg. 3 (Extend): can dataflow nodes j..j+x-1 be placed on v?"""
    return float(np.sum(df.creq[j : j + x])) <= float(rg.cap[v]) + EPS_CAP


def pathmap_exact(
    rg: ResourceGraph,
    df: DataflowPath,
    *,
    find_first: bool = False,
    max_states: int = 2_000_000,
) -> tuple[Optional[Mapping], ExactStats]:
    """Paper Alg. 1 (Pathmap) + Alg. 2 (Relax) + Alg. 3 (Extend).

    Returns the minimum-latency feasible mapping (or the first found when
    ``find_first``, matching Relax lines 10-12), and set-size stats.
    Raises ``MemoryError`` when the partial-map set exceeds ``max_states``
    (the paper's ">50 nodes infeasible" regime).
    """
    p, n = df.p, rg.n
    src, dst = df.src, df.dst
    # M[u][j] : dict keyed by (assign, route) -> cost (dedup identical states).
    M: list[list[dict]] = [[{} for _ in range(p + 1)] for _ in range(n)]
    stats = ExactStats()
    best: Optional[Mapping] = None

    def consider_complete(assign, route, cost):
        nonlocal best
        m = Mapping(tuple(assign), tuple(route), float(cost))
        if best is None or m.cost < best.cost:
            best = m

    # Initialization (Alg. 1 lines 1-7): prefixes of P_J co-located on src.
    for j in range(1, p + 1):
        if not _extend_ok(df, rg, 0, j, src):
            break  # creq prefix sums are monotone
        if j == p:
            if src == dst:
                consider_complete((src,) * p, (src,), 0.0)
            continue
        M[src][j][((src,) * j, (src,))] = 0.0
        stats.total_maps_generated += 1

    fresh: dict[tuple[int, int], list] = {
        (src, j): list(M[src][j].keys()) for j in range(1, p) if M[src][j]
    }
    edges = list(rg.edges())

    # Outer relaxation loop (Alg. 1 lines 13-17): at most n-1 rounds; we stop
    # early when no new partial map was produced (fixpoint).
    for rnd in range(n - 1):
        stats.rounds = rnd + 1
        produced = {}  # (v, j) -> list of ((assign, route), cost) to merge after the round
        for (u, v) in edges:
            for j in range(1, p):
                keys = fresh.get((u, j))
                if not keys:
                    continue  # Relax line 6: only maps new in the last iteration
                if float(rg.bw[u, v]) + EPS_BW < float(df.breq[j - 1]):
                    continue  # Relax line 5: bandwidth of dataflow edge (j-1, j)
                for (assign, route) in keys:
                    cost = M[u][j][(assign, route)]
                    if v in route:
                        continue  # cycle avoidance (Alg. 4 line 12)
                    ncost = cost + float(rg.lat[u, v])
                    if v == dst:
                        # Relax lines 7-12: place all remaining nodes on t.
                        if _extend_ok(df, rg, j, p - j, v):
                            consider_complete(
                                assign + (v,) * (p - j), route + (v,), ncost
                            )
                            if find_first:
                                return best, stats
                    else:
                        # Relax lines 13-22: all extensions 0..p-j-1 on v.
                        for x in range(0, p - j):
                            if not _extend_ok(df, rg, j, x, v):
                                break  # monotone prefix sums
                            key = (assign + (v,) * x, route + (v,))
                            produced.setdefault((v, j + x), []).append((key, ncost))
        new_fresh: dict[tuple[int, int], list] = {}
        for (v, j), items in produced.items():
            target = M[v][j]
            for key, cost in items:
                if key not in target:
                    stats.total_maps_generated += 1
                    target[key] = cost
                    new_fresh.setdefault((v, j), []).append(key)
        alive = sum(len(d) for row in M for d in row)
        stats.max_set_size = max(stats.max_set_size, alive)
        if alive > max_states:
            raise MemoryError(
                f"exact PathMap state explosion: {alive} partial maps (n={n}, p={p})"
            )
        fresh = new_fresh
        if not fresh:
            break
    return best, stats


def brute_force(
    rg: ResourceGraph, df: DataflowPath, *, max_routes: int = 200_000
) -> Optional[Mapping]:
    """Independent oracle: enumerate simple routes src⇝dst and all contiguous
    placements of the dataflow path along each route.  For tiny instances only.
    """
    import networkx as nx

    G = nx.DiGraph()
    G.add_nodes_from(range(rg.n))
    for u, v in rg.edges():
        G.add_edge(u, v)
    p = df.p
    best: Optional[Mapping] = None
    count = 0
    if df.src == df.dst:
        routes = itertools.chain([[df.src]], nx.all_simple_paths(G, df.src, df.dst))
    else:
        routes = nx.all_simple_paths(G, df.src, df.dst)
    for route in routes:
        count += 1
        if count > max_routes:
            raise MemoryError("brute force route explosion")
        L = len(route)
        if p == 1 and L > 1:
            continue
        # Compositions: c_b >= 0 nodes on route[b] (0 = pass-through hop: a
        # dataflow edge spanning a multi-hop resource path, paper §2.1);
        # c_0 >= 1 and c_{L-1} >= 1 (pinned endpoints).  Cut points are
        # non-decreasing values in [1, p-1].
        for cuts in itertools.combinations_with_replacement(range(1, p), L - 1):
            counts = np.diff((0,) + cuts + (p,))
            assign = []
            ok = True
            for b, c in enumerate(counts):
                if float(np.sum(df.creq[len(assign) : len(assign) + c])) > float(
                    rg.cap[route[b]]
                ) + EPS_CAP:
                    ok = False
                    break
                assign.extend([route[b]] * int(c))
            if not ok:
                continue
            prefix = np.cumsum(counts)
            for b in range(L - 1):
                k = int(prefix[b])  # nodes placed before the hop
                if float(rg.bw[route[b], route[b + 1]]) + EPS_BW < float(df.breq[k - 1]):
                    ok = False
                    break
            if not ok:
                continue
            cost = mapping_cost(rg, route)
            if best is None or cost < best.cost:
                best = Mapping(tuple(assign), tuple(route), cost)
    return best
