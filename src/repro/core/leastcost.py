"""LeastCostMap heuristic (paper §3.4.1), two implementations.

1. ``leastcost_python`` — faithful path-carrying version: the exact PathMap
   relaxation but with ``M(u, j)`` pruned to the single cheapest partial map
   per (node, prefix-length).  Complexity ``O(n·e·p^2)``; sound w.r.t. the
   cumulative-capacity constraint because the route is carried in the state.

2. ``leastcost_jax`` — beyond-paper tensorized dynamic program over the
   tropical (min,+) semiring so the relaxation runs on TPU vector units.
   State ``C[v, j]`` = min cost of placing the first ``j`` dataflow nodes on
   a route ending at ``v``.  One superstep is

       place:  P[v,k]  = min_{j<=k, s[k]-s[j] <= cap[v]}  C[v,j]
       move:   C'[w,k] = min_{v != w, bw[v,w] >= breq[k-1]}  P[v,k] + lat[v,w]

   iterated to fixpoint (<= n-1 supersteps, Lemma 3.2).  On the kernel path
   (``use_kernel=True``) the whole superstep runs as the fused batched
   Pallas kernel of ``repro.kernels.minplus.batched`` — the single-step
   kernels in ``kernels/minplus``/``kernels/place`` remain as step-level
   oracles only.  Parent pointers are tracked for reconstruction; anomaly
   handling (broken chain / revisit) lives in ``core.reconstruct``.

Shared constants/tensors come from ``core.problem``; ``leastcost_jax_batched``
solves many (possibly mixed-``p``) requests on one shared network in one
batched DP — the continuous-arrival path behind ``core.online.OnlinePlacer``.
With ``use_kernel=True`` the whole superstep (place + move + monotone update)
runs as the fused batched Pallas kernel of ``repro.kernels.minplus.batched``
(grid over (batch, w, k, v) with network tiles shared across the batch);
off-TPU the kernel's fused-jnp mirror replaces the vmapped per-request graph.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .graph import (
    INF,
    DataflowPath,
    Mapping,
    ResourceGraph,
    mapping_cost,
    validate_mapping,
)
from .problem import (
    BIG,
    EPS_BW,
    EPS_CAP_F32,
    EPS_COST,
    EPS_IMPROVE,
    make_cap_ok,
    problem_tensors,
    stack_requests,
    BATCH_IN_AXES,
)
from .reconstruct import reconstruct_mapping


@dataclasses.dataclass
class HeuristicStats:
    max_set_size: int = 0
    total_maps_generated: int = 0
    rounds: int = 0
    fallback_used: bool = False
    validated: bool = True
    kernel_impl: str = ""  # "", "pallas", "interpret", or "ref"


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


# ---------------------------------------------------------------------------
# 1. Faithful path-carrying LeastCostMap (centralized, paper §3.4.1)
# ---------------------------------------------------------------------------


def leastcost_python(
    rg: ResourceGraph, df: DataflowPath
) -> tuple[Optional[Mapping], HeuristicStats]:
    p, n = df.p, rg.n
    src, dst = df.src, df.dst
    stats = HeuristicStats()
    # M[u][j] = (cost, assign, route) | None — single cheapest per (u, j).
    M: list[list[Optional[tuple]]] = [[None] * (p + 1) for _ in range(n)]
    best: Optional[Mapping] = None

    cap_ok = make_cap_ok(rg, df)

    for j in range(1, p):
        if not cap_ok(0, j, src):
            break
        M[src][j] = (0.0, (src,) * j, (src,))
        stats.total_maps_generated += 1
    if cap_ok(0, p, src) and src == dst:
        best = Mapping((src,) * p, (src,), 0.0)

    edges = list(rg.edges())
    fresh = {(src, j) for j in range(1, p) if M[src][j]}
    for rnd in range(n - 1):
        stats.rounds = rnd + 1
        new_fresh: set[tuple[int, int]] = set()
        for (u, v) in edges:
            for j in range(1, p):
                if (u, j) not in fresh or M[u][j] is None:
                    continue
                if float(rg.bw[u, v]) + EPS_BW < float(df.breq[j - 1]):
                    continue
                cost, assign, route = M[u][j]
                if v in route:
                    continue
                ncost = cost + float(rg.lat[u, v])
                if v == dst:
                    if cap_ok(j, p, v):
                        m = Mapping(assign + (v,) * (p - j), route + (v,), ncost)
                        if best is None or m.cost < best.cost:
                            best = m
                else:
                    for x in range(0, p - j):
                        if not cap_ok(j, j + x, v):
                            break
                        cur = M[v][j + x]
                        if cur is None or ncost < cur[0] - EPS_COST:
                            M[v][j + x] = (ncost, assign + (v,) * x, route + (v,))
                            stats.total_maps_generated += 1
                            new_fresh.add((v, j + x))
        stats.max_set_size = max(
            stats.max_set_size, sum(1 for row in M for e in row if e is not None)
        )
        fresh = new_fresh
        if not fresh:
            break
    return best, stats


# ---------------------------------------------------------------------------
# 2. Tensorized JAX DP (beyond paper)
# ---------------------------------------------------------------------------

# Tensor keys carrying a warm-start cost frontier (see
# :func:`warm_seed_from_mapping`).  Their presence in the ``tensors`` dict
# is a python-level (trace-time) condition, so warm and cold solves compile
# as separate specializations and the cold path is byte-identical to before.
_WARM_KEYS = ("warm_v", "warm_j", "warm_c", "warm_pv", "warm_pj")
_WARM_IN_AXES = {k: 0 for k in _WARM_KEYS}


def warm_seed_from_mapping(rg: ResourceGraph, df: DataflowPath, mapping):
    """Host-side O(p + route) walk turning a previously-committed (now
    possibly infeasible) mapping into a DP cost frontier.

    Walks the mapping's route edge by edge under the *current* residual
    ``rg``, emitting one seed state per arrival ``(v, j, cost)`` with its
    parent ``(u, j_prev)`` — exactly the arrival states the cold DP would
    rediscover — and stops at the first constraint violation (capacity
    window, bandwidth gate, dead link, or route exhaustion).  Every seeded
    state is achievable under the current residual, so seeding ``C0`` with
    it preserves the DP invariant "C[v,j] is a realizable cost" and the
    relaxation can only improve on it.  Returns a seed dict (numpy arrays
    ``v/j/cost/pv/pj``) or None when not even the first hop survives.
    """
    assign, route = mapping.assign, mapping.route
    cap, bw, lat = rg.cap, rg.bw, rg.lat
    p = df.p
    sv, sj, sc, spv, spj = [], [], [], [], []
    pos = 0  # last df node whose outgoing edge has been carried
    prev_j = 0  # arrival prefix length at the current route node
    cost = np.float32(0.0)
    for u, w in zip(route[:-1], route[1:]):
        while pos + 1 < p and assign[pos + 1] == u:
            pos += 1
        # df nodes placed at u this visit: prev_j .. pos inclusive
        block = float(np.sum(df.creq[prev_j:pos + 1], dtype=np.float64))
        if block > float(cap[u]) + EPS_CAP_F32:
            break
        if pos >= p - 1:
            break  # nothing left to move; dst tail handled by the DP
        lw = float(lat[u, w])
        if not np.isfinite(lw):
            break
        if float(bw[u, w]) < float(df.breq[pos]):
            break  # same exact gate as the DP move step
        cost = np.float32(cost + np.float32(lw))
        sv.append(w)
        sj.append(pos + 1)
        sc.append(cost)
        spv.append(u)
        spj.append(prev_j)
        prev_j = pos + 1
    if not sv:
        return None
    return {
        "v": np.asarray(sv, np.int32), "j": np.asarray(sj, np.int32),
        "cost": np.asarray(sc, np.float32),
        "pv": np.asarray(spv, np.int32), "pj": np.asarray(spj, np.int32),
    }


def stack_warm_seeds(warm_starts, B: int, p_max: int) -> dict:
    """Stack per-request seed dicts (None = no seed) into padded (B, S)
    device tensors.  S is power-of-two padded so the stream of varying
    seed lengths compiles at most log2(max route) warm specializations.
    Pad slots use ``cost=BIG`` + parents ``-1``: ``_apply_warm`` merges
    with ``.min``/``.max``, so a pad slot is provably a no-op against the
    cold init (``C0=BIG``, parents ``-1``)."""
    S = 1
    for w in warm_starts:
        if w is not None and len(w["v"]) > S:
            S = len(w["v"])
    S = 1 << (S - 1).bit_length()
    wv = np.zeros((B, S), np.int32)
    wj = np.zeros((B, S), np.int32)
    wc = np.full((B, S), BIG, np.float32)
    wpv = np.full((B, S), -1, np.int32)
    wpj = np.full((B, S), -1, np.int32)
    for b in range(min(B, len(warm_starts))):
        w = warm_starts[b]
        if w is None:
            continue
        s = len(w["v"])
        wv[b, :s] = w["v"]
        wj[b, :s] = w["j"]
        wc[b, :s] = w["cost"]
        wpv[b, :s] = w["pv"]
        wpj[b, :s] = w["pj"]
    return {
        "warm_v": jnp.asarray(wv), "warm_j": jnp.asarray(wj),
        "warm_c": jnp.asarray(wc), "warm_pv": jnp.asarray(wpv),
        "warm_pj": jnp.asarray(wpj),
    }


def _apply_warm(C0, pv0, pj0, tensors):
    """Merge a warm-start frontier into the cold DP init.  ``min`` on
    costs keeps the invariant that every finite C entry is realizable;
    ``max`` on parents is exact because real seeds target distinct
    ``(v, j)`` cells (a simple route visits each node once) whose cold
    parents are ``-1``, and pad slots carry ``-1``/``BIG`` no-ops."""
    wv, wj = tensors["warm_v"], tensors["warm_j"]
    wc, wpv, wpj = tensors["warm_c"], tensors["warm_pv"], tensors["warm_pj"]
    if C0.ndim == 3:  # batched (B, n, K)
        b = jnp.arange(C0.shape[0])[:, None]
        return (C0.at[b, wv, wj].min(wc),
                pv0.at[b, wv, wj].max(wpv),
                pj0.at[b, wv, wj].max(wpj))
    return (C0.at[wv, wj].min(wc),
            pv0.at[wv, wj].max(wpv),
            pj0.at[wv, wj].max(wpj))


def _place_step(C, cap, prefix):
    """P[v,k] = min over x>=0 of C[v,k-x] s.t. prefix[k]-prefix[k-x] <= cap[v].

    Also returns pj[v,k] = the achieving j = k-x.  Unrolled over x (p is
    static and small); O(n p^2) work, O(n p) memory.
    """
    n, P1 = C.shape
    P = jnp.full_like(C, BIG)
    pj = jnp.zeros(C.shape, jnp.int32)
    k_idx = jnp.arange(P1)
    for x in range(P1):
        j_idx = k_idx - x
        valid_j = j_idx >= 0
        shifted = jnp.where(
            valid_j[None, :], jnp.roll(C, x, axis=1), BIG
        )  # shifted[v,k] = C[v,k-x]
        block = prefix[k_idx] - prefix[jnp.maximum(j_idx, 0)]
        feas = valid_j[None, :] & (block[None, :] <= cap[:, None] + EPS_CAP_F32)
        cand = jnp.where(feas, shifted, BIG)
        upd = cand < P
        P = jnp.where(upd, cand, P)
        pj = jnp.where(upd, jnp.maximum(j_idx, 0)[None, :], pj)
    return P, pj


# §Perf hillclimb C (EXPERIMENTS.md): C2 (fused (n,n,K) pass) was REFUTED on
# CPU — 2x slower than the k-loop (cache blowout); C4 below transposes the
# min-reduction onto the contiguous axis instead.
_BATCHED_MOVE_LIMIT = 0  # C2 disabled; the TPU Pallas kernel tiles explicitly


def _move_step_ref(P, lat, bw, breq):
    """C'[w,k] = min_v P[v,k] + lat[v,w] s.t. bw[v,w] >= breq[k-1]; plus argmin.

    Pure-jnp oracle for the Pallas kernel.  k = 0 column is invalid (no
    dataflow edge precedes node 0) -> BIG.  C4: the reduction runs over the
    minor (contiguous) axis of the transposed link matrices — XLA hoists the
    loop-invariant transposes out of the relaxation while-loop.
    """
    n, P1 = P.shape
    # breq_k[k] = requirement of the dataflow edge carried when k nodes are
    # placed (edge (k-1, k)); k=0 and k=p get BIG (no move possible).
    breq_k = jnp.concatenate(
        [jnp.full((1,), BIG), breq, jnp.full((P1 - 1 - breq.shape[0],), BIG)]
    )
    if n * n * P1 <= _BATCHED_MOVE_LIMIT:
        # single fused pass over (v, w, k)
        cand = jnp.where(
            bw[:, :, None] >= breq_k[None, None, :],
            P[:, None, :] + lat[:, :, None],
            BIG,
        )
        return jnp.min(cand, axis=0), jnp.argmin(cand, axis=0).astype(jnp.int32)

    latT = lat.T  # (w, v): reduction axis contiguous
    bwT = bw.T

    def one_k(args):
        bk, Pk = args
        cand = jnp.where(bwT >= bk, latT + Pk[None, :], BIG)  # (w, v)
        return jnp.min(cand, axis=1), jnp.argmin(cand, axis=1).astype(jnp.int32)

    # lax.map with O(n^2) live slabs: measured best on CPU (C2 fused-3D and
    # C5 vmap-over-k both refuted — cache blowout; EXPERIMENTS.md §Perf C).
    Cmv_t, pv_t = jax.lax.map(one_k, (breq_k, P.T))
    return Cmv_t.T, pv_t.T


def _superstep(state, tensors):
    C, par_v, par_j, changed = state
    P, pj = _place_step(C, tensors["cap"], tensors["prefix"])
    Cmv, pv = _move_step_ref(P, tensors["lat"], tensors["bw"], tensors["breq"])
    upd = Cmv < C - EPS_IMPROVE
    Cn = jnp.where(upd, Cmv, C)
    # parent arrival state of (w,k) is (pv[w,k], pj[pv[w,k],k])
    pj_of_pv = pj[pv, jnp.arange(C.shape[1])[None, :]]
    par_vn = jnp.where(upd, pv, par_v)
    par_jn = jnp.where(upd, pj_of_pv, par_j)
    return Cn, par_vn, par_jn, jnp.any(upd)


@functools.partial(jax.jit, static_argnames=("n", "p", "max_rounds"))
def _leastcost_dp(tensors, n: int, p: int, max_rounds: int):
    """Run the relaxation to fixpoint (pure-jnp path).  ``p`` is the static
    column count; ``tensors["p_eff"]`` is the (possibly traced, per-request)
    true dataflow length — the final reduction at ``dst`` only reads columns
    ``< p_eff``, so padded mixed-``p`` batches share one compiled DP.  The
    kernel path lives in :func:`_leastcost_dp_batched` (``use_kernel=True``
    routes there, with B=1 for single requests)."""
    C0 = jnp.full((n, p + 1), BIG, jnp.float32)
    # arrival state at src with 0 nodes placed costs 0
    C0 = C0.at[tensors["src"], 0].set(0.0)
    par_v0 = jnp.full((n, p + 1), -1, jnp.int32)
    par_j0 = jnp.full((n, p + 1), -1, jnp.int32)
    if "warm_v" in tensors:
        C0, par_v0, par_j0 = _apply_warm(C0, par_v0, par_j0, tensors)

    def cond(carry):
        t, (C, pv, pj, changed) = carry
        return (t < max_rounds) & changed

    def body(carry):
        t, state = carry
        state = _superstep((state[0], state[1], state[2], state[3]), tensors)
        return t + 1, state

    t, (C, par_v, par_j, _) = jax.lax.while_loop(
        cond, body, (0, (C0, par_v0, par_j0, jnp.array(True)))
    )
    # answer: min over j<p_eff of C[dst, j] + place nodes j..p_eff-1 on dst
    prefix = tensors["prefix"]
    p_eff = tensors.get("p_eff", jnp.asarray(p, jnp.int32))
    j_idx = jnp.arange(p + 1)
    cap_dst = tensors["cap"][tensors["dst"]]
    feas = (j_idx < p_eff) & (prefix[p_eff] - prefix[j_idx] <= cap_dst + EPS_CAP_F32)
    final = jnp.where(feas, C[tensors["dst"], :], BIG)
    best_j = jnp.argmin(final)
    return C, par_v, par_j, final[best_j], best_j, t


@functools.lru_cache(maxsize=None)
def _vmapped_dp(n: int, p: int, max_rounds: int, warm: bool = False):
    """Cached jit-of-vmap of the per-request DP: without the outer jit the
    python-level vmap batching trace re-runs on every call, a measurable
    per-batch overhead on the online placer's hot path.  ``warm=True``
    expects the ``_WARM_KEYS`` frontier tensors batched along axis 0."""
    axes = dict(BATCH_IN_AXES, **_WARM_IN_AXES) if warm else BATCH_IN_AXES
    return jax.jit(
        jax.vmap(
            lambda t: _leastcost_dp(t, n=n, p=p, max_rounds=max_rounds),
            in_axes=(axes,),
        )
    )


@functools.partial(
    jax.jit, static_argnames=("B", "n", "p", "max_rounds", "impl", "tiles")
)
def _leastcost_dp_batched(tensors, B: int, n: int, p: int, max_rounds: int,
                          impl: str = "ref", tiles=None):
    """Run B requests' relaxations to fixpoint with ONE fused batched
    superstep per round (``repro.kernels.minplus.batched``): the shared
    ``lat``/``bw`` tiles serve the whole batch instead of being re-streamed
    per request under vmap.

    ``impl``: "pallas" (TPU), "interpret" (Pallas interpreter — the CPU-CI
    cross-check path), or "ref" (fused jnp oracle, the fast off-TPU path).
    All three produce bit-identical results to the vmapped jnp DP.
    """
    from repro.kernels.minplus import batched as _batched

    K = p + 1
    lat, bw, cap = tensors["lat"], tensors["bw"], tensors["cap"]
    prefix = tensors["prefix"]  # (B, K)
    # breq_k[b, k] = bandwidth of the dataflow edge carried when k nodes are
    # placed (edge (k-1, k)); k = 0 and k = p get BIG (no move possible).
    breq_k = jnp.concatenate(
        [jnp.full((B, 1), BIG, jnp.float32), tensors["breq"],
         jnp.full((B, 1), BIG, jnp.float32)], axis=1)

    C0 = jnp.full((B, n, K), BIG, jnp.float32)
    C0 = C0.at[jnp.arange(B), tensors["src"], 0].set(0.0)
    pv0 = jnp.full((B, n, K), -1, jnp.int32)
    pj0 = jnp.full((B, n, K), -1, jnp.int32)
    if "warm_v" in tensors:
        # warm frontier merged before the kernel-path fill(), so the padded
        # state inherits the seeds too
        C0, pv0, pj0 = _apply_warm(C0, pv0, pj0, tensors)

    if impl == "ref":
        step = functools.partial(
            _batched.batched_superstep_ref,
            lat=lat, bw=bw, cap=cap, prefix=prefix, breq_k=breq_k)
        state0 = (C0, pv0, pj0)
    else:
        pads = _batched.pad_batched_problem(
            lat, bw, cap, prefix, breq_k, tiles=tiles)
        Bp, K_pad = pads["prefix"].shape
        n_pad = pads["lat"].shape[0]
        fill = lambda x, v: jnp.full(  # noqa: E731
            (Bp, n_pad, K_pad), v, x.dtype).at[:B, :n, :K].set(x)
        step = functools.partial(
            _batched.batched_superstep_pallas,
            lat=pads["lat"], bw=pads["bw"], cap=pads["cap"],
            prefix=pads["prefix"], breq_k=pads["breq_k"],
            tiles=tiles, interpret=(impl == "interpret"))
        state0 = (fill(C0, BIG), fill(pv0, -1), fill(pj0, -1))

    def cond(carry):
        t, C, pv, pj, changed = carry
        return (t < max_rounds) & changed

    def body(carry):
        t, C, pv, pj, _ = carry
        Cn, pvn, pjn = step(C, pv, pj)
        # the EPS_IMPROVE update is monotone, so any change is a decrease
        return t + 1, Cn, pvn, pjn, jnp.any(Cn < C)

    # named scope = free trace-time metadata: the relaxation loop shows up
    # as one labeled block in XLA/Perfetto profiles (repro.obs annotate()
    # wraps the dispatch side; this labels the compiled computation itself)
    with jax.named_scope(f"minplus_dp_batched[{impl}]"):
        t, Cp, pvp, pjp, _ = jax.lax.while_loop(
            cond, body, (0, *state0, jnp.array(True))
        )
    C, par_v, par_j = Cp[:B, :n, :K], pvp[:B, :n, :K], pjp[:B, :n, :K]

    # answer per request: min over j<p_eff of C[dst, j] + tail placed on dst
    p_eff = tensors["p_eff"]  # (B,)
    j_idx = jnp.arange(K)
    pre_pe = jnp.take_along_axis(prefix, p_eff[:, None], axis=1)  # (B, 1)
    cap_dst = cap[tensors["dst"]]  # (B,)
    feas = (j_idx[None, :] < p_eff[:, None]) & (
        pre_pe - prefix <= cap_dst[:, None] + EPS_CAP_F32
    )
    C_dst = C[jnp.arange(B), tensors["dst"], :]  # (B, K)
    final = jnp.where(feas, C_dst, BIG)
    best_j = jnp.argmin(final, axis=1)
    best_cost = jnp.take_along_axis(final, best_j[:, None], axis=1)[:, 0]
    return C, par_v, par_j, best_cost, best_j, t


@dataclasses.dataclass(eq=False)
class PendingDP:
    """An in-flight batched DP: device arrays dispatched, not yet synced.

    Produced by :func:`leastcost_jax_batched_dispatch`; holds everything
    :func:`leastcost_jax_batched_finalize` needs to block, pull parent
    pointers to host, and reconstruct mappings.  The jnp fields are
    immutable device arrays over the tensors captured at dispatch time, so
    later residual mutations cannot corrupt an in-flight solve — the basis
    of the online placer's cross-batch optimistic pipeline.
    """

    rg: ResourceGraph  # host residual snapshot (reconstruction/validation)
    dfs: list
    par_v: object  # (B, n, K) device array
    par_j: object
    best_cost: object  # (B,) device array
    best_j: object
    rounds: object  # device scalar (kernel) | (B,) array (vmapped) | None
    kernel_impl: str = ""
    validate: bool = True
    warm: bool = False  # True iff this solve was warm-start seeded


def leastcost_jax_batched_dispatch(
    rg: ResourceGraph,
    dfs: list,
    *,
    validate: bool = True,
    max_rounds: Optional[int] = None,
    use_kernel: bool = False,
    kernel_impl: Optional[str] = None,
    tiles=None,
    bucket_batch: bool = False,
    graph_tensors=None,
    warm_starts=None,
) -> PendingDP:
    """Dispatch the batched DP without waiting for the result.

    JAX dispatch is asynchronous: the returned :class:`PendingDP` holds
    unblocked device arrays, so the caller can overlap host-side work
    (validating/committing a previous batch) with the device computation
    and only synchronize in :func:`leastcost_jax_batched_finalize`.

    ``graph_tensors`` injects device-resident ``{cap, bw, lat}`` (see
    ``core.residual.ResidualState.device_tensors``) so the dispatch ships
    only the O(p) per-request tensors; ``rg`` is still required as the host
    graph the reconstruction loop walks.

    ``warm_starts`` (optional, aligned with ``dfs``) seeds the DP's cost
    frontier per request — tier 2 of the incremental admission fast path.
    Each entry is None, a seed dict from :func:`warm_seed_from_mapping`,
    or a previously-committed ``Mapping`` (converted here against ``rg``).
    Combine with a small ``max_rounds`` to run a bounded number of
    correction supersteps instead of a full cold relaxation; the caller
    falls back to a cold solve for requests the bounded pass cannot place.
    """
    assert dfs
    n = rg.n
    B = len(dfs)
    if bucket_batch:
        B = 1 << (B - 1).bit_length()  # next power of two
    tensors, p_max = stack_requests(rg, dfs, pad_to=B,
                                    graph_tensors=graph_tensors)
    warm = False
    if warm_starts is not None:
        seeds = [
            w if (w is None or isinstance(w, dict))
            else warm_seed_from_mapping(rg, df, w)
            for w, df in zip(warm_starts, dfs)
        ]
        if any(s is not None for s in seeds):
            tensors = dict(tensors, **stack_warm_seeds(seeds, B, p_max))
            warm = True
    max_rounds = max_rounds or (n - 1 if n > 1 else 1)
    impl = ""
    if use_kernel:
        impl = kernel_impl or ("pallas" if _on_tpu() else "ref")
        C, par_v, par_j, best_cost, best_j, rounds = _leastcost_dp_batched(
            tensors, B=B, n=n, p=p_max, max_rounds=max_rounds,
            impl=impl, tiles=tiles,
        )
    else:
        fn = _vmapped_dp(n, p_max, max_rounds, warm)
        C, par_v, par_j, best_cost, best_j, rounds = fn(tensors)
    return PendingDP(rg, list(dfs), par_v, par_j, best_cost, best_j,
                     rounds, kernel_impl=impl, validate=validate, warm=warm)


def leastcost_jax_batched_finalize(pending: PendingDP, stats=None) -> list:
    """Block on an in-flight batched DP and reconstruct its mappings.

    This is the only host synchronization point of the batched path: the
    ``np.asarray`` pulls force completion of the dispatched computation
    (the pipelined placer's commit-time ``block_until_ready``)."""
    par_v, par_j = np.asarray(pending.par_v), np.asarray(pending.par_j)
    best_cost, best_j = np.asarray(pending.best_cost), np.asarray(pending.best_j)
    if stats is not None and pending.rounds is not None:
        if pending.kernel_impl:
            stats.kernel_impl = pending.kernel_impl
        # kernel path: one shared device scalar; vmapped path: (B,) per-
        # request superstep counts — report the batch's slowest request
        stats.rounds = int(np.max(np.asarray(pending.rounds)))
    out = []
    for i, df in enumerate(pending.dfs):
        per = HeuristicStats()
        out.append(
            reconstruct_mapping(
                pending.rg, df, par_v[i], par_j[i],
                float(best_cost[i]), int(best_j[i]),
                validate=pending.validate, stats=per,
            )
        )
        if stats is not None:
            stats.fallback_used |= per.fallback_used
            stats.validated &= per.validated
    return out


def leastcost_jax_batched(
    rg: ResourceGraph,
    dfs: list,
    *,
    validate: bool = True,
    max_rounds: Optional[int] = None,
    use_kernel: bool = False,
    kernel_impl: Optional[str] = None,
    tiles=None,
    bucket_batch: bool = False,
    stats=None,
    graph_tensors=None,
    warm_starts=None,
) -> list:
    """Solve many mapping requests on ONE shared resource network in a
    single vmapped DP (§Perf C6): the realistic continuous-arrival case —
    link matrices are shared across the batch, so the per-request marginal
    cost is one (n, p_max) state tensor.  Requests of mixed ``p`` are padded
    (``core.problem.pad_request``).  Returns a list of (Mapping | None).

    Implemented as dispatch + finalize (see
    :func:`leastcost_jax_batched_dispatch`): callers that want to overlap
    the device solve with host work use the two halves directly.

    ``use_kernel=True`` selects the fused batched superstep path
    (``repro.kernels.minplus.batched``) instead of vmapping the per-request
    DP: the Pallas kernel on TPU, its fused-jnp mirror elsewhere.
    ``kernel_impl`` overrides the dispatch ("pallas" | "interpret" | "ref");
    ``tiles`` = (b_tile, v_tile, w_tile, k_tile) for the Pallas grid.

    ``bucket_batch=True`` pads the batch dimension to the next power of two
    at the TENSOR level (dummy rows, ignored by the reconstruction loop), so
    a stream of varying micro-batch sizes compiles at most log2(max batch)
    DP specializations — the online placer's admission path sets this.

    ``stats`` (optional, e.g. the engine's unified ``Stats``) aggregates
    anomaly signals across the batch: ``fallback_used`` is set if ANY
    request needed the path-carrying rescue, ``validated`` cleared if ANY
    reconstruction failed validation."""
    pending = leastcost_jax_batched_dispatch(
        rg, dfs, validate=validate, max_rounds=max_rounds,
        use_kernel=use_kernel, kernel_impl=kernel_impl, tiles=tiles,
        bucket_batch=bucket_batch, graph_tensors=graph_tensors,
        warm_starts=warm_starts,
    )
    return leastcost_jax_batched_finalize(pending, stats=stats)


def leastcost_jax(
    rg: ResourceGraph,
    df: DataflowPath,
    *,
    use_kernel: bool = False,
    kernel_impl: Optional[str] = None,
    tiles=None,
    max_rounds: Optional[int] = None,
    validate: bool = True,
    warm_start=None,
) -> tuple[Optional[Mapping], HeuristicStats]:
    """Tensorized LeastCostMap.  Returns (mapping | None, stats).

    ``use_kernel=True`` runs the fused batched superstep path with B=1 —
    the same code path that serves ``leastcost_jax_batched`` (B is a static
    jit argument, so B=1 compiles its own specialization; the online
    placer's recompile bound comes from ``admit_many``'s power-of-two
    batch bucketing).

    ``warm_start`` (a seed dict from :func:`warm_seed_from_mapping` or a
    prior ``Mapping``) seeds the DP frontier; pair with a small
    ``max_rounds`` for a bounded correction solve.
    """
    n, p = rg.n, df.p
    stats = HeuristicStats()
    max_rounds = max_rounds or (n - 1 if n > 1 else 1)
    if warm_start is not None and not isinstance(warm_start, dict):
        warm_start = warm_seed_from_mapping(rg, df, warm_start)
    if use_kernel:
        impl = kernel_impl or ("pallas" if _on_tpu() else "ref")
        stats.kernel_impl = impl
        tensors, _ = stack_requests(rg, [df])
        if warm_start is not None:
            tensors = dict(tensors, **stack_warm_seeds([warm_start], 1, p))
        Cb, par_vb, par_jb, best_costb, best_jb, rounds = _leastcost_dp_batched(
            tensors, B=1, n=n, p=p, max_rounds=max_rounds, impl=impl,
            tiles=tiles,
        )
        C, par_v, par_j = Cb[0], par_vb[0], par_jb[0]
        best_cost, best_j = best_costb[0], best_jb[0]
    else:
        tensors = problem_tensors(rg, df)
        if warm_start is not None:
            batched = stack_warm_seeds([warm_start], 1, p)
            tensors = dict(tensors, **{k: v[0] for k, v in batched.items()})
        C, par_v, par_j, best_cost, best_j, rounds = _leastcost_dp(
            tensors, n=n, p=p, max_rounds=max_rounds
        )
    stats.rounds = int(rounds)
    stats.max_set_size = int(np.sum(np.asarray(C) < BIG / 2))
    if float(best_cost) >= BIG / 2:
        return None, stats
    # Backtrack parent pointers; on a broken chain or revisit anomaly the
    # sound path-carrying version is substituted (rare; counted in stats).
    m = reconstruct_mapping(
        rg, df, par_v, par_j, float(best_cost), int(best_j),
        validate=validate, stats=stats,
    )
    return m, stats
