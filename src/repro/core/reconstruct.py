"""Parent-pointer reconstruction shared by the tensorized DP backends.

Before the engine refactor this logic existed near-verbatim three times
(``leastcost._reconstruct``, an inline copy in ``leastcost_jax`` and another
in ``distributed.leastcost_shard_map``).  The DP does not carry visited
sets, so two anomalies are possible and both are handled here:

- *broken chain*: a parent pointer is missing (-1) or the walk exceeds the
  ``n * (p + 2)`` guard — the backtrack cannot reach ``(src, 0)``;
- *revisit anomaly*: the chain closes but the reconstructed route visits a
  resource node twice (possible only in adversarial instances because the
  state drops the carried route) — caught by ``validate_mapping``.

Either way the sound path-carrying ``leastcost_python`` is used as the
fallback (rare; counted in Stats / benchmarks).
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np

from .graph import DataflowPath, Mapping, ResourceGraph, validate_mapping
from .problem import BIG


def backtrack(
    par_v: np.ndarray,
    par_j: np.ndarray,
    *,
    src: int,
    dst: int,
    best_j: int,
    p: int,
    n: int,
) -> tuple[np.ndarray, list[int], bool]:
    """Walk parent pointers from (dst, best_j) to (src, 0).

    Returns (assign (p,) int64, route in travel order, chain_ok).  When the
    chain is broken, ``assign`` may contain -1 entries.
    """
    assign = np.full(p, -1, np.int64)
    k = int(best_j)
    assign[k:p] = dst
    w, route, guard, ok = dst, [dst], 0, True
    while not (w == src and k == 0):
        v, j = int(par_v[w, k]), int(par_j[w, k])
        if v < 0 or guard > n * (p + 2):
            ok = False
            break
        assign[j:k] = v
        route.append(v)
        w, k = v, j
        guard += 1
    route.reverse()
    return assign, route, ok and int(assign.min()) >= 0


def reconstruct_mapping(
    rg: ResourceGraph,
    df: DataflowPath,
    par_v: np.ndarray,
    par_j: np.ndarray,
    best_cost: float,
    best_j: int,
    *,
    validate: bool = True,
    fallback: Optional[Callable] = None,
    use_fallback: bool = True,
    stats=None,
) -> Optional[Mapping]:
    """Backtrack + validate + (optional) sound fallback.

    ``stats`` (any object with ``validated`` / ``fallback_used`` attributes,
    e.g. ``HeuristicStats`` or the engine's ``Stats``) is updated in place.
    ``fallback`` defaults to ``leastcost_python``.
    """
    if best_cost >= BIG / 2:
        return None
    par_v = np.asarray(par_v)
    par_j = np.asarray(par_j)
    assign, route, ok = backtrack(
        par_v, par_j, src=df.src, dst=df.dst, best_j=best_j, p=df.p, n=rg.n
    )
    if ok:
        m = Mapping(tuple(int(a) for a in assign), tuple(route), float(best_cost))
        if validate:
            ok, _reason = validate_mapping(rg, df, m)
        if stats is not None:
            stats.validated = bool(ok)
        if ok:
            return m
    elif stats is not None:
        stats.validated = False
    if not use_fallback:
        return None
    if stats is not None:
        stats.fallback_used = True
    if fallback is None:
        from .leastcost import leastcost_python

        fallback = leastcost_python
    m, _ = fallback(rg, df)
    return m
