"""Random resource-network topologies and dataflow paths.

The paper evaluates on BRITE-generated Internet topologies [7].  BRITE's two
router-level models are Waxman and Barabasi-Albert; we implement both with
the same parameterization (nodes in a unit square, distance-proportional
latency) plus uniform capacity/bandwidth annotations, and a generator for
random dataflow paths, so the benchmark instances match the paper's setup.
"""
from __future__ import annotations

import numpy as np

from .graph import INF, DataflowPath, ResourceGraph


def _annotate(
    rng: np.random.Generator,
    pos: np.ndarray,
    adj: np.ndarray,
    cap_range=(2.0, 10.0),
    bw_range=(10.0, 100.0),
    lat_scale=10.0,
) -> ResourceGraph:
    n = pos.shape[0]
    # Connect components (BRITE guarantees connectivity): link each component
    # representative to the nearest node outside it.
    comp = np.arange(n)

    def find(a):
        while comp[a] != a:
            comp[a] = comp[comp[a]]
            a = comp[a]
        return a

    for u, v in zip(*np.nonzero(adj)):
        comp[find(u)] = find(v)
    d2 = ((pos[:, None, :] - pos[None, :, :]) ** 2).sum(-1)
    while True:
        roots = {find(i) for i in range(n)}
        if len(roots) == 1:
            break
        r = min(roots)
        mine = np.array([find(i) == find(r) for i in range(n)])
        dd = np.where(mine[:, None] & ~mine[None, :], d2, np.inf)
        u, v = np.unravel_index(np.argmin(dd), dd.shape)
        adj[u, v] = adj[v, u] = True
        comp[find(u)] = find(v)

    dist = np.sqrt(d2)
    cap = rng.uniform(*cap_range, size=n).astype(np.float32)
    bw = np.zeros((n, n), np.float32)
    lat = np.full((n, n), INF, np.float32)
    np.fill_diagonal(lat, 0.0)
    bvals = rng.uniform(*bw_range, size=(n, n)).astype(np.float32)
    bvals = np.minimum(bvals, bvals.T)  # symmetric links
    m = adj | adj.T
    bw[m] = bvals[m]
    lat[m] = (lat_scale * dist[m] + 0.1).astype(np.float32)  # strictly > 0
    return ResourceGraph(cap, bw, lat)


def waxman(
    n: int,
    *,
    alpha: float = 0.4,
    beta: float = 0.3,
    seed: int = 0,
    **annotate_kw,
) -> ResourceGraph:
    """Waxman model: P(u,v) = alpha * exp(-d(u,v) / (beta * L))."""
    rng = np.random.default_rng(seed)
    pos = rng.uniform(size=(n, 2))
    d = np.sqrt(((pos[:, None, :] - pos[None, :, :]) ** 2).sum(-1))
    L = np.sqrt(2.0)
    prob = alpha * np.exp(-d / (beta * L))
    adj = (rng.uniform(size=(n, n)) < prob) & ~np.eye(n, dtype=bool)
    adj = np.triu(adj, 1)
    return _annotate(rng, pos, adj, **annotate_kw)


def barabasi_albert(n: int, *, m: int = 2, seed: int = 0, **annotate_kw) -> ResourceGraph:
    """BA preferential attachment (BRITE's second router model)."""
    rng = np.random.default_rng(seed)
    pos = rng.uniform(size=(n, 2))
    adj = np.zeros((n, n), dtype=bool)
    deg = np.zeros(n)
    start = min(m + 1, n)
    for u in range(start):
        for v in range(u + 1, start):
            adj[u, v] = True
            deg[u] += 1
            deg[v] += 1
    for u in range(start, n):
        p = deg[:u] / deg[:u].sum()
        targets = rng.choice(u, size=min(m, u), replace=False, p=p)
        for v in targets:
            adj[min(u, v), max(u, v)] = True
            deg[u] += 1
            deg[v] += 1
    return _annotate(rng, pos, adj, **annotate_kw)


def region_line(
    R: int,
    k: int = 4,
    *,
    cap_range=(2.0, 10.0),
    bw_range=(10.0, 100.0),
    lat_intra: float = 1.0,
    lat_inter: float = 5.0,
    gateways: int = 1,
    seed: int = 0,
) -> tuple[ResourceGraph, np.ndarray]:
    """A line of ``R`` fully-connected ``k``-node regions.

    Consecutive regions are joined by ``gateways`` inter-region links
    (node ``k-1-g`` of region ``r`` to node ``g`` of region ``r+1``), so a
    dataflow pinned from region 0 to region ``R-1`` can only be served by
    a spanning chain through every region in between — the multi-hop
    decomposition scenario of the regional control plane.  Returns
    ``(graph, assign)`` where ``assign`` is the canonical node -> region
    map (pass it as ``RegionalControlPlane(region_of=assign)`` to pin the
    partition to the topology).
    """
    assert R >= 1 and k >= 1 and 1 <= gateways <= k
    rng = np.random.default_rng(seed)
    n = R * k
    cap = rng.uniform(*cap_range, size=n).astype(np.float32)
    bw = np.zeros((n, n), np.float32)
    lat = np.full((n, n), INF, np.float32)
    np.fill_diagonal(lat, 0.0)

    def _link(u, v, l):
        b = float(rng.uniform(*bw_range))
        bw[u, v] = bw[v, u] = b
        lat[u, v] = lat[v, u] = l

    for r in range(R):
        base = r * k
        for i in range(k):
            for j in range(i + 1, k):
                _link(base + i, base + j, lat_intra)
        if r + 1 < R:
            for g in range(gateways):
                _link(base + (k - 1 - g), base + k + g, lat_inter)
    assign = np.repeat(np.arange(R, dtype=np.int64), k)
    return ResourceGraph(cap, bw, lat), assign


def region_grid(
    rows: int,
    cols: int,
    k: int = 4,
    *,
    cap_range=(2.0, 10.0),
    bw_range=(10.0, 100.0),
    lat_intra: float = 1.0,
    lat_inter: float = 5.0,
    seed: int = 0,
) -> tuple[ResourceGraph, np.ndarray]:
    """A ``rows x cols`` grid of fully-connected ``k``-node regions.

    Regions are numbered row-major (region ``i * cols + j`` sits at grid
    cell ``(i, j)``); horizontally and vertically adjacent regions are
    joined by one inter-region link each.  Unlike :func:`region_line`,
    whose quotient graph is a single path, the grid's quotient graph has
    *distinct* region chains between most pairs — the topology k-shortest
    multi-chain routing needs: when the fewest-hop chain runs through a
    saturated region, a longer bypass chain exists around it.

    Gateway node indices rotate per direction (east uses node ``k-1`` ->
    ``0``, south uses ``k-2`` -> ``1``, mod ``k``) so a region's cuts do
    not all share one node where ``k`` allows.  Returns ``(graph,
    assign)`` with ``assign`` the canonical node -> region map.
    """
    assert rows >= 1 and cols >= 1 and k >= 1
    rng = np.random.default_rng(seed)
    R = rows * cols
    n = R * k
    cap = rng.uniform(*cap_range, size=n).astype(np.float32)
    bw = np.zeros((n, n), np.float32)
    lat = np.full((n, n), INF, np.float32)
    np.fill_diagonal(lat, 0.0)

    def _link(u, v, l):
        b = float(rng.uniform(*bw_range))
        bw[u, v] = bw[v, u] = b
        lat[u, v] = lat[v, u] = l

    for r in range(R):
        base = r * k
        for i in range(k):
            for j in range(i + 1, k):
                _link(base + i, base + j, lat_intra)
    for i in range(rows):
        for j in range(cols):
            base = (i * cols + j) * k
            if j + 1 < cols:  # east
                _link(base + (k - 1), (i * cols + j + 1) * k, lat_inter)
            if i + 1 < rows:  # south
                _link(base + (k - 2) % k,
                      ((i + 1) * cols + j) * k + (1 % k), lat_inter)
    assign = np.repeat(np.arange(R, dtype=np.int64), k)
    return ResourceGraph(cap, bw, lat), assign


def region_tree(
    levels: int,
    branching: int,
    k: int = 4,
    *,
    cap_range=(2.0, 10.0),
    bw_range=(10.0, 100.0),
    lat_intra: float = 1.0,
    lat_level: float = 5.0,
    gateway_bw_scale: float = 4.0,
    seed: int = 0,
) -> tuple[ResourceGraph, np.ndarray]:
    """A ``branching``-ary tree of fully-meshed ``k``-node leaf regions.

    ``branching ** levels`` leaf regions are numbered depth-first, so any
    contiguous block of ``branching ** (levels - 1)`` leaves is exactly one
    top-level subtree — the grouping the hierarchical control plane uses.
    At every tree level ``l`` in ``1..levels`` the ``branching`` sibling
    subtrees under a common parent are joined all-to-all by one gateway
    link per pair (between the first leaf of each subtree, on node index
    ``(l - 1) % k`` so distinct levels use distinct gateway nodes where
    ``k`` allows), with latency ``lat_level * l`` — higher cuts are more
    expensive, as in a datacenter/pod/rack hierarchy.  Gateway links get
    ``gateway_bw_scale`` x the leaf bandwidth draw since they carry
    aggregated traffic.

    Returns ``(graph, assign)`` where ``assign`` maps node -> leaf region;
    pass it as ``ControlPlane(region_of=assign, levels=...)``.
    """
    assert levels >= 1 and branching >= 1 and k >= 1
    rng = np.random.default_rng(seed)
    leaves = branching**levels
    n = leaves * k
    cap = rng.uniform(*cap_range, size=n).astype(np.float32)
    bw = np.zeros((n, n), np.float32)
    lat = np.full((n, n), INF, np.float32)
    np.fill_diagonal(lat, 0.0)

    def _link(u, v, l, scale=1.0):
        b = scale * float(rng.uniform(*bw_range))
        bw[u, v] = bw[v, u] = b
        lat[u, v] = lat[v, u] = l

    for leaf in range(leaves):
        base = leaf * k
        for i in range(k):
            for j in range(i + 1, k):
                _link(base + i, base + j, lat_intra)
    for lvl in range(1, levels + 1):
        sub = branching ** (lvl - 1)  # leaves per child subtree at this level
        block = sub * branching  # leaves per parent block
        gw = (lvl - 1) % k
        for start in range(0, leaves, block):
            reps = [(start + c * sub) * k + gw for c in range(branching)]
            for i in range(branching):
                for j in range(i + 1, branching):
                    _link(reps[i], reps[j], lat_level * lvl, gateway_bw_scale)
    assign = np.repeat(np.arange(leaves, dtype=np.int64), k)
    return ResourceGraph(cap, bw, lat), assign


def random_dataflow(
    rg: ResourceGraph,
    p: int,
    *,
    seed: int = 0,
    creq_range=(0.5, 3.0),
    breq_range=(10.0, 60.0),
    endpoint_creq: float = 0.0,
) -> DataflowPath:
    """Random linear dataflow computation with pinned random endpoints."""
    rng = np.random.default_rng(seed)
    creq = rng.uniform(*creq_range, size=p).astype(np.float32)
    creq[0] = creq[-1] = endpoint_creq
    breq = rng.uniform(*breq_range, size=p - 1).astype(np.float32)
    src, dst = rng.choice(rg.n, size=2, replace=False)
    return DataflowPath(creq, breq, int(src), int(dst))


def paper_example() -> tuple[ResourceGraph, DataflowPath]:
    """The worked example of paper Fig. 1 + Fig. 3 (path topology).

    Eight nodes A..H.  Figure annotations are partially illegible in the
    text, so values are chosen consistent with the described feasible/optimal
    mapping (s->B, x1,x2->B, x3->D, t->F): B has enough capacity for three
    computations, D for one, and the B-D-F corridor is the low-latency route.
    """
    A, B, C, D, E, F, G, H = range(8)
    cap = [2.0, 6.0, 2.0, 3.0, 4.0, 1.0, 3.0, 2.0]
    edges = [
        (A, B, 40.0, 3.0), (A, C, 60.0, 2.0), (B, D, 50.0, 2.0),
        (C, E, 50.0, 2.0), (C, G, 40.0, 4.0), (D, E, 40.0, 3.0),
        (D, F, 60.0, 2.0), (E, G, 50.0, 2.0), (F, G, 30.0, 3.0),
        (F, H, 40.0, 2.0), (G, H, 50.0, 2.0),
    ]
    rg = ResourceGraph.from_edge_list(cap, edges)
    df = DataflowPath.make(
        creq=[0.0, 2.0, 2.0, 1.5, 0.0], breq=[30.0, 25.0, 25.0, 20.0], src=B, dst=F
    )
    return rg, df
