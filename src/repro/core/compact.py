"""Region-local compacted solve substrate: global <-> local id bijections.

The decentralized control plane shards the network into R regions, but a
region that keeps *global* node ids (masking foreign capacity to zero)
still pays the global ``n`` in every solve: the DP state is (n, p+1), the
batched kernel pads its tiles to n, and the residual bookkeeping is
O(n^2) per region.  Sharding then buys message locality but zero compute
locality — R regions are not R x smaller solves.

A :class:`CompactedView` is the bijection that fixes this: region ``r``
owns ``n_r`` global nodes; the view maps them onto the contiguous local
id space ``[0, n_r)`` and carries

- the **remapped network tensors** (``cap``/``bw``/``lat`` sliced to the
  member rows/columns — cross-region links drop out of the submatrix by
  construction), exposed as an ``n_r``-node :class:`ResourceGraph`;
- **read-through** for residual state: :meth:`compact_graph` slices any
  global-shaped graph (e.g. a residual snapshot) down to the local space,
  so a solver only ever sees ``n_r``;
- **write-through** for committed state: :meth:`uncompact_node_load` /
  :meth:`uncompact_edge_load` / the ``uncompact_*_vec`` scatter helpers
  lift local ticket loads and residual arrays back to global ids, so a
  global conservation ledger stays checkable over locally-sized regions;
- a **version** counter, bumped by :meth:`invalidate` whenever the
  region's slice of truth changes (node/link churn).  Holders that record
  local ids next to the version (the 2PC broker's spanning parts) can
  detect handles minted under a stale bijection generation.

The identity view (:meth:`CompactedView.identity`, or any view covering
every node in order) short-circuits every translation to return its input
*object* unchanged — the R = 1 regional plane therefore stays bit-for-bit
identical to the centralized plane, by construction rather than by
re-verification.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .graph import DataflowPath, Mapping, ResourceGraph


@dataclasses.dataclass(eq=False)
class CompactedView:
    """Global <-> local node-id bijection for one region.

    ``nodes`` holds the member global ids in ascending order; local id
    ``i`` denotes global node ``nodes[i]``.  All translation methods
    raise ``ValueError`` for ids outside the member set — a foreign id
    reaching a region's solve path is a broker bug, never a mask.
    """

    base: ResourceGraph  # the full global graph this view slices
    nodes: np.ndarray  # (n_local,) ascending global ids
    version: int = 0

    def __post_init__(self):
        self.nodes = np.asarray(self.nodes, np.int64)
        if self.nodes.size == 0:
            raise ValueError(
                "CompactedView over an empty region: every region must own "
                "at least one node (check partition_regions / region_of)"
            )
        if np.any(np.diff(self.nodes) <= 0):
            raise ValueError("view nodes must be strictly ascending")
        if self.nodes[0] < 0 or self.nodes[-1] >= self.base.n:
            raise ValueError("view nodes out of range for the base graph")
        self._local_of = np.full(self.base.n, -1, np.int64)
        self._local_of[self.nodes] = np.arange(self.n_local)
        self.is_identity = bool(
            self.n_local == self.base.n
            and np.array_equal(self.nodes, np.arange(self.base.n))
        )
        self._graph = None  # cached compacted base tensors
        # derivation links (hierarchical planes): views nested over this
        # view's compacted graph, and the view this one was derived from.
        # Invalidation propagates through the chain — see invalidate().
        self._outer: "CompactedView | None" = None
        self._inner: list["CompactedView"] = []

    # -- construction --------------------------------------------------------

    @staticmethod
    def identity(rg: ResourceGraph) -> "CompactedView":
        """The whole-graph view: every translation is the identity (and
        returns its input object unchanged — the R=1 bit-identity hook)."""
        return CompactedView(rg, np.arange(rg.n, dtype=np.int64))

    @staticmethod
    def from_assign(
        rg: ResourceGraph, assign: np.ndarray, r: int
    ) -> "CompactedView":
        """The view of region ``r`` under a node -> region assignment."""
        members = np.nonzero(np.asarray(assign) == r)[0]
        if members.size == 0:
            raise ValueError(
                f"region {r} is empty under the given assignment "
                f"(n={rg.n}); partition the graph into fewer regions or "
                "merge the empty region before building views"
            )
        return CompactedView(rg, members)

    # -- sizes ---------------------------------------------------------------

    @property
    def n_local(self) -> int:
        return int(self.nodes.shape[0])

    @property
    def n_global(self) -> int:
        return self.base.n

    # -- id translation ------------------------------------------------------

    def contains(self, v: int) -> bool:
        return 0 <= int(v) < self.base.n and self._local_of[int(v)] >= 0

    def to_local(self, v):
        """Global -> local ids (scalar or array); raises on foreign ids."""
        lv = self._local_of[np.asarray(v)]
        if np.any(np.asarray(lv) < 0):
            raise ValueError(f"node(s) {v!r} not in this view's region")
        return lv if isinstance(lv, np.ndarray) else int(lv)

    def to_global(self, v):
        """Local -> global ids (scalar or array)."""
        gv = self.nodes[np.asarray(v)]
        return gv if isinstance(gv, np.ndarray) else int(gv)

    # -- graph compaction (residual read-through) ----------------------------

    def graph(self) -> ResourceGraph:
        """The compacted base network (cached; rebuilt by invalidate)."""
        if self._graph is None:
            self._graph = self.compact_graph(self.base)
        return self._graph

    def compact_graph(self, rg: ResourceGraph) -> ResourceGraph:
        """Slice any global-shaped graph (base or a residual snapshot) to
        the local id space.  Cross-region links are outside the submatrix,
        so nothing foreign survives — no masking, no sentinel rows."""
        if self.is_identity:
            return rg
        assert rg.n == self.base.n, "compact_graph expects a global graph"
        ix = np.ix_(self.nodes, self.nodes)
        return ResourceGraph(rg.cap[self.nodes], rg.bw[ix], rg.lat[ix])

    # -- request / mapping translation ---------------------------------------

    def compact_df(self, df: DataflowPath) -> DataflowPath:
        """Re-pin a dataflow's endpoints into local ids (requirements are
        id-free and shared by reference)."""
        if self.is_identity:
            return df
        return DataflowPath(
            df.creq, df.breq, self.to_local(df.src), self.to_local(df.dst)
        )

    def uncompact_df(self, df: DataflowPath) -> DataflowPath:
        if self.is_identity:
            return df
        return DataflowPath(
            df.creq, df.breq, self.to_global(df.src), self.to_global(df.dst)
        )

    def compact_mapping(self, m: Mapping) -> Mapping:
        if self.is_identity:
            return m
        return Mapping(
            tuple(int(x) for x in self.to_local(np.asarray(m.assign))),
            tuple(int(x) for x in self.to_local(np.asarray(m.route))),
            m.cost,
        )

    def uncompact_mapping(self, m: Mapping) -> Mapping:
        """Lift a local-id mapping back to global ids (cost unchanged —
        the compacted tensors are slices, not rescalings)."""
        if self.is_identity:
            return m
        return Mapping(
            tuple(int(x) for x in self.to_global(np.asarray(m.assign))),
            tuple(int(x) for x in self.to_global(np.asarray(m.route))),
            m.cost,
        )

    # -- load / residual translation (write-through) -------------------------

    def uncompact_node_load(self, load: dict) -> dict:
        """Local ticket node-load -> global ids."""
        if self.is_identity:
            return dict(load)
        return {self.to_global(v): c for v, c in load.items()}

    def uncompact_edge_load(self, load: dict) -> dict:
        """Local ticket edge-load -> global id pairs."""
        if self.is_identity:
            return dict(load)
        return {
            (self.to_global(u), self.to_global(v)): b
            for (u, v), b in load.items()
        }

    def uncompact_node_vec(self, vec: np.ndarray) -> np.ndarray:
        """Scatter a local per-node vector (e.g. residual capacity) into a
        global-sized vector, zero outside the region."""
        out = np.zeros(self.base.n, dtype=np.asarray(vec).dtype)
        out[self.nodes] = vec
        return out

    def uncompact_link_mat(self, mat: np.ndarray) -> np.ndarray:
        """Scatter a local link matrix (e.g. residual bandwidth) into a
        global-sized matrix, zero outside the region's submatrix."""
        out = np.zeros((self.base.n, self.base.n), dtype=np.asarray(mat).dtype)
        out[np.ix_(self.nodes, self.nodes)] = mat
        return out

    # -- nesting (hierarchical planes) ---------------------------------------

    def derive(self, nodes: np.ndarray) -> "CompactedView":
        """A nested view over THIS view's compacted graph: ``nodes`` are
        ascending ids in this view's *local* space.  The child is linked
        into the derivation chain so :meth:`invalidate` propagates (see
        there for the direction rules)."""
        return self.adopt(CompactedView(self.graph(), np.asarray(nodes, np.int64)))

    def adopt(self, child: "CompactedView") -> "CompactedView":
        """Link an existing view built over this view's compacted graph
        into the derivation chain (used when a child plane constructs its
        own views over ``outer.graph()``)."""
        if child.base.n != self.n_local:
            raise ValueError(
                f"cannot adopt: child view is over an n={child.base.n} graph "
                f"but this view compacts to n_local={self.n_local}"
            )
        child._outer = self
        self._inner.append(child)
        return child

    def compose(self, inner: "CompactedView") -> "CompactedView":
        """Flatten a bijection-of-bijection into one direct view: ``inner``
        maps ids of this view's compacted graph; the result maps
        ``inner``-local ids straight to THIS view's base (global) ids.

        The composed view is a snapshot (its version is the sum of the two
        generations at compose time) and is not linked into the derivation
        chain — use it for cross-level lifts (write-through conservation),
        not as a long-lived handle."""
        if inner.base.n != self.n_local:
            raise ValueError(
                f"cannot compose: inner view is over an n={inner.base.n} "
                f"graph but this view compacts to n_local={self.n_local}"
            )
        return CompactedView(
            self.base, self.nodes[inner.nodes], version=self.version + inner.version
        )

    # -- invalidation --------------------------------------------------------

    def invalidate(self) -> int:
        """The region's slice of truth changed (node/link churn): bump the
        bijection generation and drop the cached compacted tensors.  Ids
        themselves are stable under liveness churn — the version exists so
        holders of (local id, version) records can tell which generation
        minted them.

        Propagation through a derivation chain: *ancestors* contain this
        region's slice, so their generation bumps too (a leaf churn is
        visible at every enclosing level); *descendants* slice this view's
        tensors, so they bump when THIS view is the invalidation origin.
        Siblings are untouched — their slice of truth did not change."""
        self._bump_up()
        self._bump_down()
        return self.version

    def _bump_up(self) -> None:
        self.version += 1
        self._graph = None
        if self._outer is not None:
            self._outer._bump_up()

    def _bump_down(self) -> None:
        for child in self._inner:
            child.version += 1
            child._graph = None
            child._bump_down()


def compact_view(rg: ResourceGraph, assign: np.ndarray, r: int) -> CompactedView:
    """Functional alias for :meth:`CompactedView.from_assign`."""
    return CompactedView.from_assign(rg, assign, r)
