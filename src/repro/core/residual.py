"""Device-resident residual tensors with a versioned host mirror.

:class:`ResidualState` is the single owner of the online placer's residual
capacity/bandwidth state.  The float64 host arrays remain the source of
truth — every commit/release mutates them immediately, and validation at
commit time always reads them — but the float32 tensors the batched DP
consumes (``cap``/``bw``/``lat`` with liveness applied) are kept *device
resident*: commits accumulate into a small delta buffer that is applied as
one scatter-add the next time a solve is dispatched, instead of re-uploading
the full O(n^2) residual every micro-batch.

Two counters version the state:

- ``version`` bumps on **every** host mutation (commit, release, liveness
  change, restore).  Cheap cache key for anything derived from residuals.
- ``epoch`` bumps only on events that make an in-flight optimistic solve
  *unsalvageable*: liveness changes (``fail_node``/``fail_link``/restores)
  and :meth:`restore` rollbacks.  Plain commits/releases do NOT bump it —
  an in-flight batch solved against a slightly older residual is still
  usable because every mapping is re-validated against the host residual
  before committing (the existing optimistic-concurrency hook).  ``epoch``
  is monotone and never restored from a snapshot, so a stale in-flight
  solve can never be made to look fresh by a rollback.

Float32 drift: the device tensors are updated incrementally in float32
while the host accumulates in float64, so after many commits they can
differ from a fresh ``float32(host)`` round-trip by a few ulps.  That is
safe by construction — the DP only *proposes* mappings; host-side
``validate_mapping`` against the float64 truth gates every commit, and a
proposal the drifted tensors made infeasible-looking merely costs a
conflict re-solve.  Liveness changes drop the device cache entirely (they
rewrite ``lat`` semantics, not just magnitudes).
"""
from __future__ import annotations

import numpy as np

from .graph import INF, ResourceGraph
from .problem import finite_lat


def _pow2_pad(arr: np.ndarray) -> np.ndarray:
    """Zero-pad a 1-d scatter operand to the next power-of-two length.

    Padding appends index 0 / value 0.0 pairs, which are no-ops under
    scatter-*add* — the point is shape stability: delta sizes vary per
    commit, and an unpadded update would jit-compile one executable per
    distinct length instead of O(log n) bucketed ones."""
    k = len(arr)
    m = 1 << max(0, int(k - 1).bit_length())
    if m == k:
        return arr
    return np.concatenate([arr, np.zeros(m - k, arr.dtype)])


class ResidualState:
    """Residual capacity/bandwidth of one resource network: float64 host
    truth + lazily synchronized float32 device tensors + staleness fences."""

    def __init__(self, base: ResourceGraph):
        self.base = base
        n = base.n
        self.cap = base.cap.astype(np.float64).copy()
        self.bw = base.bw.astype(np.float64).copy()
        self.node_up = np.ones(n, bool)
        self.link_up = np.isfinite(base.lat) & ~np.eye(n, dtype=bool)
        self.version = 0  # bumps on every host mutation
        self.epoch = 0  # bumps only when in-flight solves become invalid
        self._dev: dict | None = None  # {"cap","bw","lat"} jnp tensors
        self._node_delta: dict[int, float] = {}  # node -> pending cap delta
        self._edge_delta: dict[tuple, float] = {}  # (u,v) -> pending bw delta
        # telemetry (repro.obs registry reads these): how often the device
        # mirror paid a full O(n^2) upload vs an O(delta) scatter-add
        self.sync_stats = {"full_uploads": 0, "delta_syncs": 0,
                           "invalidations": 0}

    # -- host truth ---------------------------------------------------------

    def residual_graph(self) -> ResourceGraph:
        """The network the next solve sees: committed capacity subtracted,
        failed nodes/links removed (cap 0 / bw 0 / lat INF)."""
        up2 = self.node_up[:, None] & self.node_up[None, :]
        alive = self.link_up & up2
        cap = np.where(self.node_up, self.cap, 0.0).astype(np.float32)
        bw = np.where(alive, self.bw, 0.0).astype(np.float32)
        lat = np.where(alive, self.base.lat, INF).astype(np.float32)
        np.fill_diagonal(lat, 0.0)
        return ResourceGraph(cap, bw, lat)

    def apply_load(self, node_load: dict, edge_load: dict, sign: float) -> None:
        """Commit (``sign=-1``) or release (``sign=+1``) a ticket's loads.

        Host arrays update immediately; the device mirror accumulates the
        delta and applies it as one scatter-add at the next dispatch."""
        for v, c in node_load.items():
            d = sign * c
            self.cap[v] += d
            if self._dev is not None and self.node_up[v]:
                self._node_delta[v] = self._node_delta.get(v, 0.0) + d
        for (u, v), b in edge_load.items():
            d = sign * b
            self.bw[u, v] += d
            if self._dev is not None and self.link_up[u, v]:
                key = (u, v)
                self._edge_delta[key] = self._edge_delta.get(key, 0.0) + d
        self.version += 1

    # -- liveness (drops the device cache: lat changes shape of the problem)

    def set_node_up(self, v: int, up: bool) -> None:
        self.node_up[v] = up
        self._invalidate()

    def set_link_up(self, u: int, v: int, up: bool) -> None:
        self.link_up[u, v] = self.link_up[v, u] = up
        self._invalidate()

    def _invalidate(self) -> None:
        """Liveness changed or state rolled back: fence out in-flight solves
        and force a full device re-upload on the next dispatch."""
        self.version += 1
        self.epoch += 1
        self._dev = None
        self._node_delta.clear()
        self._edge_delta.clear()
        self.sync_stats["invalidations"] += 1

    # -- snapshot / restore -------------------------------------------------

    def snapshot(self) -> dict:
        return {
            "cap": self.cap.copy(),
            "bw": self.bw.copy(),
            "node_up": self.node_up.copy(),
            "link_up": self.link_up.copy(),
        }

    def restore(self, snap: dict) -> None:
        """Roll back to a snapshot.  ``epoch`` advances (never rewinds): any
        solve dispatched between snapshot and restore stays stale forever."""
        self.cap = snap["cap"].copy()
        self.bw = snap["bw"].copy()
        self.node_up = snap["node_up"].copy()
        self.link_up = snap["link_up"].copy()
        self._invalidate()

    # -- device mirror ------------------------------------------------------

    def warm_deltas(self) -> None:
        """Pre-compile the pow2-bucketed scatter-add executables by pushing
        zero-valued (no-op) deltas of every bucket size through the update
        path.  Residuals, ``version`` and ``epoch`` are untouched — this
        exists so the first *real* commits after a cold start don't pay the
        per-shape jit (the same reason :meth:`OnlinePlacer.warmup` exists
        for the DP buckets)."""
        self.device_tensors()  # materialize the mirror (full-upload path)
        n = self.base.n
        pairs = [(u, v) for u in range(n) for v in range(n) if u != v]
        k = 1
        while k <= min(4 * n, len(pairs)):
            self._node_delta = {v: 0.0 for v in range(min(k, n))}
            self._edge_delta = {pairs[i]: 0.0 for i in range(k)}
            self.device_tensors()
            k *= 2

    def device_tensors(self) -> dict:
        """Float32 jnp ``{cap, bw, lat}`` of the current residual network.

        Full upload when the cache was dropped (construction, liveness
        change, restore); otherwise one scatter-add per tensor over the
        pending commit/release deltas."""
        import jax.numpy as jnp  # deferred: numpy-only backends never touch jax

        if self._dev is None:
            rg = self.residual_graph()
            self._dev = dict(
                cap=jnp.asarray(rg.cap),
                bw=jnp.asarray(rg.bw),
                lat=jnp.asarray(finite_lat(rg)),
            )
            self._node_delta.clear()
            self._edge_delta.clear()
            self.sync_stats["full_uploads"] += 1
            return self._dev
        if self._node_delta or self._edge_delta:
            self.sync_stats["delta_syncs"] += 1
        # delta lengths are padded to the next power of two (pad entries add
        # 0.0 at index 0 — a no-op under scatter-ADD), so the jitted update
        # compiles O(log n) shape specializations, not one per delta size
        if self._node_delta:
            idx = _pow2_pad(np.fromiter(
                self._node_delta, np.int32, len(self._node_delta)))
            val = _pow2_pad(np.fromiter(
                self._node_delta.values(), np.float32, len(self._node_delta)))
            self._dev["cap"] = self._dev["cap"].at[jnp.asarray(idx)].add(
                jnp.asarray(val))
            self._node_delta.clear()
        if self._edge_delta:
            us = _pow2_pad(
                np.array([u for u, _ in self._edge_delta], np.int32))
            vs = _pow2_pad(
                np.array([v for _, v in self._edge_delta], np.int32))
            val = _pow2_pad(np.fromiter(
                self._edge_delta.values(), np.float32, len(self._edge_delta)))
            self._dev["bw"] = self._dev["bw"].at[
                jnp.asarray(us), jnp.asarray(vs)].add(jnp.asarray(val))
            self._edge_delta.clear()
        return self._dev
