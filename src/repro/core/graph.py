"""Problem data structures for BCPM/BCDM (paper §2).

A :class:`ResourceGraph` is an arbitrary network of compute nodes (capacity
``cap``) and links (bandwidth ``bw``, additive latency ``lat``).  A
:class:`DataflowPath` is a linear dataflow computation: ``p`` nodes with
compute requirements ``creq`` and ``p-1`` edges with bandwidth requirements
``breq``.  Endpoints are pinned (``M(0)=src``, ``M(p-1)=dst``).

Dense float32 matrices are used throughout so the same objects feed the
Python reference algorithms, the tensorized JAX DP and the Pallas kernels.
``INF`` marks absent links / infeasible states (min-plus absorbing element).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

INF = np.float32(np.inf)


@dataclasses.dataclass(frozen=True)
class ResourceGraph:
    """Arbitrary resource network (paper Fig. 1).

    Attributes:
      cap: (n,) float32 — available computational capacity ``C_av`` per node.
      bw:  (n, n) float32 — available bandwidth ``B_av`` per directed link;
        0 where no link exists.
      lat: (n, n) float32 — additive latency ``D`` per directed link; INF
        where no link exists.  Diagonal is 0 (zero-length paths, paper §2.1).
    """

    cap: np.ndarray
    bw: np.ndarray
    lat: np.ndarray

    def __post_init__(self):
        n = self.cap.shape[0]
        assert self.bw.shape == (n, n) and self.lat.shape == (n, n)

    @property
    def n(self) -> int:
        return int(self.cap.shape[0])

    @property
    def num_edges(self) -> int:
        return int(np.count_nonzero(np.isfinite(self.lat) & ~np.eye(self.n, dtype=bool)))

    def edges(self) -> Iterable[tuple[int, int]]:
        """Directed edges (u, v), u != v, in deterministic order."""
        fin = np.isfinite(self.lat) & ~np.eye(self.n, dtype=bool)
        for u, v in zip(*np.nonzero(fin)):
            yield int(u), int(v)

    def neighbors(self, u: int) -> list[int]:
        fin = np.isfinite(self.lat[u]) & (np.arange(self.n) != u)
        return [int(v) for v in np.nonzero(fin)[0]]

    @staticmethod
    def from_edge_list(
        cap: Sequence[float],
        edges: Sequence[tuple[int, int, float, float]],
        symmetric: bool = True,
    ) -> "ResourceGraph":
        """Build from ``(u, v, bandwidth, latency)`` tuples."""
        n = len(cap)
        bw = np.zeros((n, n), np.float32)
        lat = np.full((n, n), INF, np.float32)
        np.fill_diagonal(lat, 0.0)
        for u, v, b, l in edges:
            bw[u, v] = b
            lat[u, v] = l
            if symmetric:
                bw[v, u] = b
                lat[v, u] = l
        return ResourceGraph(np.asarray(cap, np.float32), bw, lat)


@dataclasses.dataclass(frozen=True)
class DataflowPath:
    """Linear dataflow computation (paper Fig. 3) with pinned endpoints.

    Attributes:
      creq: (p,) float32 — compute requirement per dataflow node (source and
        sink included; commonly 0 for them).
      breq: (p-1,) float32 — bandwidth requirement of dataflow edge (i, i+1).
      src, dst: pinned resource-node ids for dataflow nodes 0 and p-1.
    """

    creq: np.ndarray
    breq: np.ndarray
    src: int
    dst: int

    def __post_init__(self):
        assert self.breq.shape[0] == self.creq.shape[0] - 1

    @property
    def p(self) -> int:
        return int(self.creq.shape[0])

    @staticmethod
    def make(creq: Sequence[float], breq: Sequence[float], src: int, dst: int) -> "DataflowPath":
        return DataflowPath(np.asarray(creq, np.float32), np.asarray(breq, np.float32), src, dst)


@dataclasses.dataclass(frozen=True)
class Mapping:
    """A complete mapping of a DataflowPath onto a ResourceGraph.

    ``assign[i]`` = resource node hosting dataflow node ``i``.  ``route`` is
    the simple resource path traversed (consecutive duplicates removed); it
    visits every assigned node in order.  ``cost`` = summed link latency.
    """

    assign: tuple[int, ...]
    route: tuple[int, ...]
    cost: float


def route_from_assign(assign: Sequence[int]) -> tuple[int, ...]:
    """Collapse consecutive duplicates: the resource route of a co-located run."""
    route = []
    for v in assign:
        if not route or route[-1] != v:
            route.append(int(v))
    return tuple(route)


def mapping_cost(rg: ResourceGraph, route: Sequence[int]) -> float:
    c = 0.0
    for u, v in zip(route[:-1], route[1:]):
        c += float(rg.lat[u, v])
    return c


def validate_mapping(
    rg: ResourceGraph, df: DataflowPath, mapping: Mapping, *, require_simple: bool = True
) -> tuple[bool, str]:
    """Check all BCPM constraints (paper §2.1/§2.2). Returns (ok, reason).

    - endpoints pinned;
    - route edges exist;
    - route is simple (the paper's cycle-avoidance; co-location collapses
      count as one visit);
    - cumulative capacity: total creq mapped on a resource node <= cap;
    - bandwidth: every resource edge carrying dataflow edge (i,i+1) has
      bw >= breq[i];
    - cost consistent with route latency.
    """
    from .problem import EPS_CAP_F32  # function-local: graph is problem's dep

    assign, route = mapping.assign, mapping.route
    p = df.p
    if len(assign) != p:
        return False, "assign length"
    if assign[0] != df.src or assign[-1] != df.dst:
        return False, "endpoints not pinned"
    if route != route_from_assign(assign):
        # Route may include pass-through nodes hosting no computation; it must
        # still visit assigned nodes in order as a supersequence.
        it = iter(route)
        for v in route_from_assign(assign):
            for w in it:
                if w == v:
                    break
            else:
                return False, "route does not visit assigned nodes in order"
    if require_simple and len(set(route)) != len(route):
        return False, "route revisits a node"
    for u, v in zip(route[:-1], route[1:]):
        if not np.isfinite(rg.lat[u, v]) or u == v:
            return False, f"missing link ({u},{v})"
    # Cumulative capacity.
    used: dict[int, float] = {}
    for i, v in enumerate(assign):
        used[v] = used.get(v, 0.0) + float(df.creq[i])
    for v, c in used.items():
        if c > float(rg.cap[v]) + EPS_CAP_F32:
            return False, f"capacity exceeded at node {v}"
    # Bandwidth: walk the route; dataflow edge index advances when the
    # assigned node changes.  Pass-through hops carry the current edge.
    pos = 0  # dataflow node index whose outgoing edge is being carried
    for u, v in zip(route[:-1], route[1:]):
        # advance pos to the last dataflow node assigned at u
        while pos + 1 < p and assign[pos + 1] == u:
            pos += 1
        if pos >= p - 1:
            return False, "route continues past sink"
        if float(rg.bw[u, v]) + EPS_CAP_F32 < float(df.breq[pos]):
            return False, f"bandwidth violated on ({u},{v}) for dataflow edge {pos}"
    expect = mapping_cost(rg, route)
    if abs(expect - mapping.cost) > 1e-4 * max(1.0, abs(expect)):
        return False, f"cost mismatch {mapping.cost} vs {expect}"
    return True, "ok"
