"""Gradient compression: int8 stochastic quantization + error feedback.

Used by the ``shard_map`` data-parallel path (examples/train_100m.py with
``--compress int8``): per-device gradients are quantized to int8 with a
per-tensor scale before the cross-replica ``psum``; the quantization error is
carried in the train state and added back next step (error feedback keeps
the method unbiased-in-the-limit; Karimireddy et al., 2019).

8x traffic reduction vs fp32 all-reduce (4x vs bf16) at the cost of one
extra state buffer.  The big-model jit path uses plain bf16 reduction (see
optim/adamw.py docstring) — int8 EF is exercised end-to-end at example scale.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any


def init_error_state(params: Pytree) -> Pytree:
    return jax.tree.map(lambda t: jnp.zeros(t.shape, jnp.float32), params)


def quantize_int8(g, key):
    """Stochastic int8 quantization with per-tensor scale."""
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    scaled = g / scale
    noise = jax.random.uniform(key, g.shape, jnp.float32, -0.5, 0.5)
    q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
    return q, scale


def compress_psum(grads: Pytree, err: Pytree, key, axis_name: str):
    """int8+EF psum over ``axis_name`` (call inside shard_map).

    Returns (reduced fp32 grads, new error state)."""
    leaves, treedef = jax.tree.flatten(grads)
    errs = treedef.flatten_up_to(err)
    keys = jax.random.split(key, len(leaves))
    outs, new_errs = [], []
    for g, e, k in zip(leaves, errs, keys):
        g32 = g.astype(jnp.float32) + e
        q, scale = quantize_int8(g32, k)
        deq = q.astype(jnp.float32) * scale
        new_errs.append(g32 - deq)
        # int8 tensors cross the interconnect; sum in int32 to avoid overflow
        red = jax.lax.psum(q.astype(jnp.int32), axis_name).astype(jnp.float32)
        n = jax.lax.psum(1, axis_name)
        outs.append(red * scale / n)
    return jax.tree.unflatten(treedef, outs), jax.tree.unflatten(treedef, new_errs)
