"""AdamW + global-norm clipping + warmup-cosine schedule (pure JAX).

The train state keeps fp32 *master* params and moments (fully sharded over
the mesh per ``dist.sharding.train_state_rules``); the forward/backward pass
consumes a bf16 cast constrained to the compute sharding — the cast happens
*before* the cross-``data`` all-gather, halving parameter-gather traffic
(the framework's baseline "communication compression"; see optim/compress.py
for the int8 error-feedback variant used by the data-parallel example).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Pytree = Any


class TrainState(NamedTuple):
    step: jnp.ndarray  # int32 scalar
    params: Pytree  # fp32 master
    m: Pytree
    v: Pytree


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init_state(params: Pytree) -> TrainState:
    f32 = jax.tree.map(lambda t: t.astype(jnp.float32), params)
    zeros = jax.tree.map(jnp.zeros_like, f32)
    return TrainState(jnp.zeros((), jnp.int32), f32,
                      zeros, jax.tree.map(jnp.zeros_like, f32))


def global_norm(tree: Pytree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(t.astype(jnp.float32))) for t in jax.tree.leaves(tree))
    )


def apply_updates(cfg: OptConfig, state: TrainState, grads: Pytree) -> tuple[TrainState, dict]:
    g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    gn = global_norm(g32)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
    g32 = jax.tree.map(lambda g: g * scale, g32)

    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g, state.m, g32)
    new_v = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g, state.v, g32)

    def upd(p, m, v):
        mhat = m / b1c
        vhat = v / b2c
        return p - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p)

    new_p = jax.tree.map(upd, state.params, new_m, new_v)
    return TrainState(step, new_p, new_m, new_v), {"grad_norm": gn, "lr": lr}
