"""Hierarchical regional control plane: regions of regions, recursively.

The flat :class:`~repro.service.regions.RegionalControlPlane` decentralizes
admission, but two of its components still scale with the whole plane: the
broker holds every global gateway id, and the gossip bus is all-to-all in
R.  Past a few hundred regions that is the centralized bottleneck again,
one level up.  This module nests the plane instead::

    HierarchicalControlPlane (levels=L, branching=b)
      ids: GLOBAL — but only at its own boundary (top-level cut gateways)
      owns: top cut ledger, top spanning queues, one GossipBus over its
            b children (aggregated records)
        |
        +-- child g in 0..b-1: a CompactedView of group g's nodes, and
            under it a plane of levels L-1 (RegionalControlPlane at the
            bottom) whose ids are the view's LOCAL space [0, n_g)
              ... recursing until b leaf regions of ~n^(1/L) nodes each

Identity discipline — which component owns which ids:

- every level's broker sees exactly two id kinds: its own boundary
  gateways (cut ledger) and opaque child rids.  It never sees a
  grandchild id; translation happens once per level, at the
  ``CompactedView`` boundary (bijection-of-bijection by construction).
- spanning decomposition **recurses**: a dataflow crossing a top-level
  cut is chain-split at this level (same quotient-graph machinery as the
  flat plane, via the shared :class:`~repro.service.regions.ChainBroker`),
  and each segment is handed to its child through
  ``broker_admit`` — a synchronous, abortable phase-1 reserve.  The child
  places the segment as its OWN spanning problem, so it may split again
  at its own cuts.  Abort/commit are O(chain) messages per level.
- gossip is tree-structured: siblings gossip within their parent only
  (``b * fanout`` msgs/round per level, each message carrying at most
  ``b`` *aggregated* records), and each parent publishes the summed
  remote estimate downward through ``pump(extra_committed=...)`` — so no
  component ever holds more than O(branching + n_leaf) state.

The ``levels=1`` plane is a single flat child under the identity view
with pure delegation — bit-identical to :class:`RegionalControlPlane` by
construction (the same composition argument that makes R=1 bit-identical
to the centralized plane), and fuzz-enforced in ``tests/test_hierarchy``.
"""
from __future__ import annotations

import collections
import itertools
from typing import Optional

import numpy as np

from ..core import engine
from ..core.compact import CompactedView
from ..core.graph import DataflowPath, ResourceGraph
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from .controlplane import ControlPlane, Request, TenantState
from .gossip import GossipBus
from .policy import FairSharePolicy, TenantConfig, fairness_summary
from .regions import (
    ChainBroker,
    RegionalControlPlane,
    SpanPart,
    SpanningTicket,
    partition_regions,
    split_dataflow_chain,
    validate_region_of,
)

_EPS = 1e-9


def resolve_nesting(levels, branching, regions, detected_leaves=None):
    """Fail-fast resolution of the nesting kwargs into
    ``(levels, branching, leaf_regions)``.  Contradictory combinations
    raise with a clear message instead of silently building some other
    plane (mirrors the flat plane's ``regions=`` vs ``region_of=``
    contradiction check)."""
    levels = int(levels)
    if levels < 1:
        raise ValueError(f"levels={levels} must be >= 1")
    leaves = detected_leaves
    if regions is not None:
        if leaves is not None and int(regions) != leaves:
            raise ValueError(
                f"regions={regions} contradicts region_of, which defines "
                f"{leaves} regions"
            )
        leaves = int(regions)
    if levels == 1:
        if branching is not None and leaves is not None \
                and int(branching) != leaves:
            raise ValueError(
                f"branching={branching} contradicts {leaves} leaf regions "
                "at levels=1 (a 1-level plane has branching == regions)"
            )
        if leaves is None:
            leaves = int(branching) if branching is not None else 2
        return levels, leaves, leaves
    if branching is None:
        if leaves is None:
            branching = 2
        else:
            branching = round(leaves ** (1.0 / levels))
            if branching**levels != leaves:
                raise ValueError(
                    f"regions={leaves} is not a perfect levels={levels} "
                    "power; pass branching= explicitly (leaf regions = "
                    "branching ** levels)"
                )
    branching = int(branching)
    if branching < 1:
        raise ValueError(f"branching={branching} must be >= 1")
    if leaves is not None and branching**levels != leaves:
        raise ValueError(
            f"regions={leaves} contradicts levels={levels} x "
            f"branching={branching} (expected {branching ** levels} "
            "leaf regions)"
        )
    return levels, branching, branching**levels


class HierarchicalControlPlane(ChainBroker):
    """``levels`` nested regional planes with ``branching`` children per
    level.  Mirrors the plane-agnostic surface of
    :class:`RegionalControlPlane` (register_tenant / submit / pump /
    release / fail_* / restore_* / defrag / conservation /
    fairness_report / engine_stats / check_invariants / active_ids), plus
    the ``broker_admit`` / ``broker_release`` parent-broker interface so
    hierarchies nest to any depth.  ``**solve_cfg`` (including the
    incremental-fast-path knobs ``cache_enabled`` / ``cache_size`` /
    ``max_correction_supersteps``) propagates through every level down to
    the leaf planes' per-region placers."""

    def __init__(
        self,
        rg: ResourceGraph,
        *,
        levels: int = 2,
        branching: Optional[int] = None,
        regions: Optional[int] = None,
        region_of=None,
        policy: Optional[FairSharePolicy] = None,
        micro_batch: int = 32,
        max_attempts: int = 8,
        preempt: bool = True,
        preempt_budget: Optional[float] = None,
        pipeline_depth: int = 1,
        method: str = "leastcost_jax",
        use_kernel: bool = False,
        fanout: int = 2,
        gossip_period: int = 1,
        max_cut_attempts: int = 4,
        chain_k: int = 2,
        congestion_weight: float = 1.0,
        max_cum_attempts: Optional[int] = None,
        seed: int = 0,
        tracer=None,
        **solve_cfg,
    ):
        self.base = rg
        # each child gets a scoped view of this tracer ("g{g}/" prefixes),
        # so flow ids and track names nest the way the planes do
        self.tracer = tracer if tracer is not None else obs_trace.NULL
        assign = None
        if region_of is not None:
            assign = validate_region_of(rg, region_of)
        self.levels, self.branching, leaves = resolve_nesting(
            levels, branching, regions,
            detected_leaves=(int(assign.max()) + 1 if assign is not None
                             else None),
        )
        self.policy = policy or FairSharePolicy()
        self.micro_batch = int(micro_batch)
        self.max_attempts = int(max_attempts)
        self.max_cut_attempts = int(max_cut_attempts)
        # same routing/backoff knobs at every level of the tree: the
        # recursive spanning decomposition races congestion-priced chains
        # with the same k and weight wherever a segment lands
        self.chain_k = max(1, int(chain_k))
        self.congestion_weight = float(congestion_weight)
        self.max_cum_attempts = (
            4 * self.max_attempts if max_cum_attempts is None
            else int(max_cum_attempts)
        )
        self.gossip_period = max(1, int(gossip_period))
        self.method = method
        self.node_up = np.ones(rg.n, bool)
        child_kw = dict(
            policy=self.policy, micro_batch=micro_batch,
            max_attempts=max_attempts, preempt=preempt,
            preempt_budget=preempt_budget, pipeline_depth=pipeline_depth,
            method=method, use_kernel=use_kernel, fanout=fanout,
            gossip_period=gossip_period, max_cut_attempts=max_cut_attempts,
            chain_k=chain_k, congestion_weight=congestion_weight,
            max_cum_attempts=max_cum_attempts,
            **solve_cfg,
        )

        if self.levels == 1:
            # the flat special case: ONE child over the identity view,
            # pure delegation — bit-identical to RegionalControlPlane by
            # construction (same seed, same kwargs, same object graph)
            self.B = 1
            self.group_of = np.zeros(rg.n, np.int64)
            self.views = [CompactedView.identity(rg)]
            self.children: list = [RegionalControlPlane(
                rg,
                regions=(None if assign is not None
                         else leaves if (regions is not None
                                         or branching is not None)
                         else None),
                region_of=assign, seed=seed,
                tracer=self.tracer.scoped("g0"), **child_kw,
            )]
        else:
            self.B = self.branching
            sub = self.branching ** (self.levels - 1)  # leaves per child
            if assign is not None:
                self.group_of = assign // sub
            else:
                self.group_of = partition_regions(rg, self.B, seed=seed)
            self.views = [
                CompactedView.from_assign(rg, self.group_of, g)
                for g in range(self.B)
            ]
            self.children = []
            for g in range(self.B):
                view = self.views[g]
                base_g = view.graph()
                inner = (assign[view.nodes] - g * sub
                         if assign is not None else None)
                if self.levels == 2:
                    child = RegionalControlPlane(
                        base_g,
                        regions=(None if inner is not None else self.branching),
                        region_of=inner, seed=seed + 1000 * (g + 1),
                        tracer=self.tracer.scoped(f"g{g}"), **child_kw,
                    )
                else:
                    child = HierarchicalControlPlane(
                        base_g, levels=self.levels - 1,
                        branching=self.branching, region_of=inner,
                        seed=seed + 1000 * (g + 1),
                        tracer=self.tracer.scoped(f"g{g}"), **child_kw,
                    )
                self.children.append(child)
        # link child views into the derivation chain so a leaf churn's
        # invalidate() propagates up to this level's views (and a parent
        # invalidation cascades down) — bijection-of-bijection versioning
        for g, child in enumerate(self.children):
            for cv in child.views:
                self.views[g].adopt(cv)
            child.on_broker_displace = (
                lambda crid, g=g: self._child_displaced(g, crid))
            child.on_drop = (lambda crid, g=g: self._forget_local(g, crid))

        # node -> leaf region over the WHOLE tree (reporting convenience;
        # the plane itself never indexes by it)
        self.leaf_region_of = np.zeros(rg.n, np.int64)
        off = 0
        for g, (view, child) in enumerate(zip(self.views, self.children)):
            inner_leaf = (child.leaf_region_of
                          if isinstance(child, HierarchicalControlPlane)
                          else child.region_of)
            self.leaf_region_of[view.nodes] = off + inner_leaf
            off += int(inner_leaf.max()) + 1
        self.leaf_regions = off

        # this level's broker: region_of maps node -> direct child
        self.region_of = self.group_of
        self._init_cut_ledger()
        self.bus = GossipBus(self.B, fanout=fanout, seed=seed + 7)

        self.span_tenants: dict[str, TenantState] = {}
        self._span_q: list[dict[str, collections.deque]] = [
            {} for _ in range(self.B)
        ]
        self._span_active: dict[int, SpanningTicket] = {}
        self._part_of: dict[tuple[int, int], int] = {}  # (group, crid) -> rid
        self._rid = itertools.count()
        self._local: dict[int, tuple[int, int]] = {}  # rid -> (group, crid)
        self._grid_of: dict[tuple[int, int], int] = {}  # (group, crid) -> rid
        self._pumps = 0
        self._twopc_msgs = 0
        self._churn_collector: Optional[list] = None
        self._broker_held: set[int] = set()
        self.on_broker_displace = None
        self.on_drop = None
        self.span_stats = {
            "attempts": 0, "admitted": 0, "dropped": 0,
            "displaced": 0, "no_cut": 0, "multi_hop": 0, "max_chain": 0,
            "broker_local": 0, "rerouted": 0, "livelock_dropped": 0,
            "max_req_attempts": 0,
        }

    # -- registration / submission ------------------------------------------

    def register_tenant(
        self, name: str, *, weight: float = 1.0,
        budget: Optional[float] = None,
    ) -> TenantConfig:
        if name in self.span_tenants:
            raise ValueError(f"tenant {name!r} already registered")
        cfg = TenantConfig(name, weight=weight, budget=budget)
        for child in self.children:
            child.register_tenant(name, weight=weight, budget=budget)
        self.span_tenants[name] = TenantState(cfg)
        for q in self._span_q:
            q[name] = collections.deque()
        return cfg

    def submit(self, tenant: str, df: DataflowPath, *, klass: int = 0) -> int:
        """Queue a request; one whose endpoints stay inside a single child
        delegates (compacted to the child's id space — the child may still
        split it across ITS children); one crossing a top-level cut queues
        with the source group's broker side and is placed by this level's
        2PC at pump time."""
        st = self.span_tenants[tenant]  # KeyError for unregistered
        rid = next(self._rid)
        ga = int(self.group_of[df.src])
        gb = int(self.group_of[df.dst])
        if ga == gb:
            crid = self.children[ga].submit(
                tenant, self.views[ga].compact_df(df), klass=klass
            )
            self._local[rid] = (ga, crid)
            self._grid_of[(ga, crid)] = rid
        else:
            st.submitted += 1
            ControlPlane._enqueue(
                self._span_q[ga][tenant], Request(rid, tenant, df, klass=klass)
            )
            if self.tracer.enabled:
                self.tracer.flow_begin(
                    rid, "submit", tenant=tenant, klass=klass,
                    spanning=True, home=ga,
                )
        return rid

    # -- live accounting -----------------------------------------------------

    def committed_capacity(self) -> dict[str, float]:
        held = {t: 0.0 for t in self.span_tenants}
        for child in self.children:
            for t, c in child.committed_capacity().items():
                held[t] = held.get(t, 0.0) + c
        return held

    def residual_capacity(self) -> float:
        return float(sum(c.residual_capacity() for c in self.children))

    def queued_demand(self) -> dict[str, float]:
        out = {t: 0.0 for t in self.span_tenants}
        for child in self.children:
            for t, c in child.queued_demand().items():
                out[t] = out.get(t, 0.0) + c
        for q in self._span_q:
            for t, dq in q.items():
                out[t] += sum(r.creq_sum for r in dq)
        return out

    def active_ids(self) -> list[int]:
        out = [
            self._grid_of[(g, crid)]
            for g, child in enumerate(self.children)
            for crid in child.active_ids()
            if (g, crid) in self._grid_of
        ]
        out += [rid for rid in self._span_active
                if rid not in self._broker_held]
        return sorted(out)

    def ticket_live(self, t) -> bool:
        if self._span_active.get(getattr(t, "rid", -1)) is t:
            return True
        return any(child.ticket_live(t) for child in self.children)

    def conservation(self) -> dict[str, int]:
        """Children's ledgers + this level's spanning ledger.  Each level
        accounts its own requests; a top spanning request contributes one
        entry here plus one broker-held entry per segment in its child —
        both sides balance independently, so ``ok`` composes."""
        agg = {"submitted": 0, "queued": 0, "in_flight": 0, "active": 0,
               "released": 0, "dropped": 0}
        for child in self.children:
            led = child.conservation()
            for k in agg:
                agg[k] += led[k]
        agg["submitted"] += sum(
            st.submitted for st in self.span_tenants.values())
        agg["queued"] += sum(
            len(dq) for q in self._span_q for dq in q.values())
        agg["active"] += len(self._span_active)
        agg["released"] += sum(
            st.released for st in self.span_tenants.values())
        agg["dropped"] += sum(
            st.dropped for st in self.span_tenants.values())
        agg["ok"] = agg["submitted"] == (
            agg["queued"] + agg["in_flight"] + agg["active"]
            + agg["released"] + agg["dropped"]
        )
        return agg

    # -- gossip (tree-structured) --------------------------------------------

    def node_occupancy(self, v: int) -> float:
        """Compute occupancy of node ``v`` (this plane's id space) in
        [0, 1]: recurses down the tree to the leaf region placer that
        holds the node's live residual."""
        g = int(self.group_of[v])
        return self.children[g].node_occupancy(
            int(self.views[g].to_local(v)))

    def _gateway_occupancy(self, g: int) -> dict[int, float]:
        """Occupancy of child ``g``'s gateway nodes at THIS level's cuts
        (this plane's ids) — the per-cut congestion estimate the tree
        gossip disseminates among siblings, read from the child's leaf
        placers, regardless of how many levels it hides."""
        view = self.views[g]
        return {
            u: self.children[g].node_occupancy(int(view.to_local(u)))
            for u in self._gateways_of.get(g, ())
        }

    def _publish(self, g: int) -> None:
        """Publish child g's AGGREGATED accounting into this level's bus:
        one record per child, regardless of how many leaves it hides."""
        child = self.children[g]
        queued = child.queued_demand()
        for t, dq in self._span_q[g].items():
            queued[t] = queued.get(t, 0.0) + sum(x.creq_sum for x in dq)
        self.bus.publish(
            g, child.committed_capacity(), queued, child.residual_capacity(),
            congestion=self._gateway_occupancy(g),
        )

    # -- admission -----------------------------------------------------------

    def pump(self, *, rounds: int = 1, extra_committed=None) -> list:
        """One drain round per ``rounds`` at every level: publish +
        sibling gossip at this level, push the aggregated remote estimate
        DOWN into each child's drain (``extra_committed`` — the tree
        downlink), recurse, then place this level's spanning queue by
        recursive 2PC."""
        admitted: list = []
        spanned: list[SpanningTicket] = []
        for _ in range(int(rounds)):
            self._pumps += 1
            for g in range(self.B):
                self._publish(g)
            if self.B > 1 and self._pumps % self.gossip_period == 0:
                with self.tracer.span("gossip.round", track="gossip",
                                      cat="gossip", round=self._pumps):
                    self.bus.tick()
            for g, child in enumerate(self.children):
                extra: dict[str, float] = dict(extra_committed or {})
                if self.B > 1:
                    for t, c in self.bus.remote_committed(g).items():
                        extra[t] = extra.get(t, 0.0) + c
                admitted += child.pump(rounds=1, extra_committed=extra or None)
            spanned += self._pump_spanning(extra_committed)
        live = [t for t in admitted if self.ticket_live(t)]
        live += [s for s in spanned if s.rid in self._span_active]
        return live

    def flush(self) -> list:
        admitted: list = []
        for child in self.children:
            admitted += child.flush()
        return [t for t in admitted if self.ticket_live(t)]

    def warmup(self, *, max_batch: Optional[int] = None, p: int = 5) -> int:
        return max(
            (c.warmup(max_batch=max_batch, p=p) for c in self.children),
            default=0,
        )

    def _pump_spanning(self, extra_committed=None) -> list[SpanningTicket]:
        if self.B <= 1:
            return []
        out: list[SpanningTicket] = []
        cfgs = {t: st.cfg for t, st in self.span_tenants.items()}
        for g in range(self.B):
            queues = self._span_q[g]
            if not any(queues.values()):
                continue
            committed = self.children[g].committed_capacity()
            for t, c in self.bus.remote_committed(g).items():
                if t in committed:
                    committed[t] += c
            for t, c in (extra_committed or {}).items():
                if t in committed:
                    committed[t] += c
            picked = self.policy.select(
                cfgs, queues, committed, self.micro_batch
            )
            for req in picked:
                q = queues[req.tenant]
                assert q[0] is req, "policy must select queue heads in order"
                q.popleft()
            for req in picked:
                q = queues[req.tenant]
                st = self._try_place_spanning(req)
                if st is not None:
                    self.span_tenants[req.tenant].admitted += 1
                    if self.tracer.enabled:
                        self.tracer.flow_point(
                            req.rid, "admit", chain=len(st.parts))
                    out.append(st)
                else:
                    req.attempts += 1
                    req.cum_attempts += 1
                    self.span_stats["max_req_attempts"] = max(
                        self.span_stats["max_req_attempts"], req.cum_attempts)
                    exhausted = req.attempts >= self.max_attempts
                    livelocked = req.cum_attempts >= self.max_cum_attempts
                    if exhausted or livelocked:
                        self.span_tenants[req.tenant].dropped += 1
                        self.span_stats["dropped"] += 1
                        if livelocked and not exhausted:
                            self.span_stats["livelock_dropped"] += 1
                        if self.tracer.enabled:
                            self.tracer.flow_end(
                                req.rid, "drop", outcome="dropped",
                                attempts=req.attempts,
                                cum_attempts=req.cum_attempts,
                            )
                        if self.on_drop is not None:
                            self.on_drop(req.rid)
                    else:
                        ControlPlane._enqueue(q, req, front_of_class=True)
        return out

    # -- recursive two-phase commit -----------------------------------------

    def _attempt_candidate(self, req: Request, chain: list[int], splits,
                           gates) -> Optional[SpanningTicket]:
        """One bounded 2PC over a candidate at THIS level: each segment's
        phase-1 reserve is the child's ``broker_admit`` — inside which the
        child may run its own chain split and its own (recursive) 2PC.
        This level never sees how the child placed the segment; it holds
        an opaque child rid.  No preemptive escalation at interior levels
        (a child's broker_admit already applies its own local policy);
        abort releases every held child reservation."""
        df = req.df
        segs = split_dataflow_chain(df, splits, gates)
        held: dict[int, int] = {}
        seg_local: dict[int, DataflowPath] = {}
        ok = True
        tr = self.tracer
        for i, seg in enumerate(segs):
            self._twopc_msgs += 1  # prepare segment i
            g = chain[i]
            lseg = self.views[g].compact_df(seg)
            with tr.span("2pc.reserve", track="2pc", cat="2pc", group=g):
                crid = self.children[g].broker_admit(
                    req.tenant, lseg, klass=req.klass)
            if crid is None:
                self._twopc_msgs += 1  # nack i
                if tr.enabled:
                    tr.flow_point(req.rid, "2pc.nack", region=g)
                ok = False
                break
            held[i] = crid
            seg_local[i] = lseg
            if tr.enabled:
                tr.flow_point(req.rid, "2pc.reserve", region=g)
        ok = ok and all(
            self.cut_residual[e] + _EPS >= float(df.breq[s])
            for s, e in zip(splits, gates)
        )
        if not ok:
            for i in sorted(held):
                self._twopc_msgs += 1  # abort i
                if tr.enabled:
                    tr.flow_point(req.rid, "2pc.abort", region=chain[i])
                self.children[chain[i]].broker_release(held[i])
            return None
        self._twopc_msgs += len(segs)  # commit every segment
        if tr.enabled:
            tr.flow_point(req.rid, "2pc.commit", chain=len(segs))
        cut_bws = [float(df.breq[s]) for s in splits]
        for e, b in zip(gates, cut_bws):
            self.cut_residual[e] -= b
        parts = [
            SpanPart(chain[i], held[i], seg_local[i],
                     self.views[chain[i]].version)
            for i in range(len(segs))
        ]
        st = SpanningTicket(
            rid=req.rid, req=req, parts=parts,
            cuts=[tuple(e) for e in gates], cut_bws=cut_bws,
            splits=list(splits),
        )
        self._span_active[req.rid] = st
        for part in parts:
            self._part_of[(part.region, part.tid)] = req.rid
        self.span_stats["admitted"] += 1
        if len(chain) >= 3:
            self.span_stats["multi_hop"] += 1
        self.span_stats["max_chain"] = max(
            self.span_stats["max_chain"], len(chain))
        return st

    def _try_place_spanning(self, req: Request) -> Optional[SpanningTicket]:
        """Chain selection + recursive 2PC — the single accounting site
        for this level's spanning attempts/admissions, mirroring
        :meth:`RegionalControlPlane._try_place_spanning`: ``chain_k == 1``
        takes the legacy fewest-hop chain; ``chain_k > 1`` races Yen
        k-shortest chains under the load-aware cost fed by this level's
        sibling gossip, within the same ``max_cut_attempts`` budget."""
        df = req.df
        self.span_stats["attempts"] += 1
        ga = int(self.group_of[df.src])
        gb = int(self.group_of[df.dst])
        if self.chain_k <= 1:
            chain = self._region_chain(ga, gb)
            if chain is None:
                self.span_stats["no_cut"] += 1
                return None
            candidates = self._candidate_chains(df, chain)
            if not candidates:
                self.span_stats["no_cut"] += 1
                return None
            for (splits, gates) in candidates:
                st = self._attempt_candidate(req, chain, splits, gates)
                if st is not None:
                    return st
            return None
        occ = self.bus.congestion_view(ga)
        chains = self._region_chains(ga, gb, occ)
        if not chains:
            self.span_stats["no_cut"] += 1
            return None
        raced = self._race_candidates(df, chains, occ)
        if not raced:
            self.span_stats["no_cut"] += 1
            return None
        for (chain, splits, gates) in raced:
            st = self._attempt_candidate(req, chain, splits, gates)
            if st is not None:
                if chain != self._region_chain(ga, gb):
                    self.span_stats["rerouted"] += 1
                return st
        return None

    # -- parent-plane broker interface (nesting deeper) ----------------------

    def broker_admit(self, tenant: str, df: DataflowPath, *,
                     klass: int = 0) -> Optional[int]:
        """Same contract as :meth:`RegionalControlPlane.broker_admit`, one
        level up: a grandparent's segment lands here and is placed either
        inside one of this plane's children or across its own cuts."""
        st = self.span_tenants[tenant]
        rid = next(self._rid)
        req = Request(rid, tenant, df, klass=klass)
        ga = int(self.group_of[df.src])
        gb = int(self.group_of[df.dst])
        if ga == gb:
            lseg = self.views[ga].compact_df(df)
            crid = self.children[ga].broker_admit(tenant, lseg, klass=klass)
            if crid is None:
                return None
            self.span_stats["broker_local"] += 1
            span = SpanningTicket(
                rid=rid, req=req,
                parts=[SpanPart(ga, crid, lseg, self.views[ga].version)],
                cuts=[], cut_bws=[], splits=[],
            )
            self._span_active[rid] = span
            self._part_of[(ga, crid)] = rid
        else:
            span = self._try_place_spanning(req)
            if span is None:
                return None
        st.submitted += 1
        st.admitted += 1
        self._broker_held.add(rid)
        return rid

    def broker_release(self, rid: int) -> None:
        if rid not in self._broker_held:
            return
        self._broker_held.discard(rid)
        st = self._span_active.pop(rid)
        self._teardown_span(st)
        self.span_tenants[st.tenant].released += 1

    def broker_uses_node(self, rid: int, v: int) -> bool:
        st = self._span_active.get(rid)
        return st is not None and self._span_uses_node(st, int(v))

    def broker_uses_link(self, rid: int, u: int, v: int) -> bool:
        st = self._span_active.get(rid)
        if st is None:
            return False
        u, v = int(u), int(v)
        if any(c in ((u, v), (v, u)) for c in st.cuts):
            return True
        ga, gb = int(self.group_of[u]), int(self.group_of[v])
        if ga != gb:
            return False
        view = self.views[ga]
        for part in st.parts:
            if part.region != ga:
                continue
            if self.children[ga].broker_uses_link(
                    part.tid, int(view.to_local(u)), int(view.to_local(v))):
                return True
        return False

    # -- teardown / displacement ---------------------------------------------

    def _teardown_span(self, st: SpanningTicket,
                       skip: Optional[tuple[int, int]] = None) -> None:
        """Release every still-held child reservation of a top spanning
        placement (``skip`` names a (group, crid) the child already
        displaced) and return this level's cut bandwidth.  Child releases
        are idempotent, so the teardown always completes."""
        for part in st.parts:
            self._part_of.pop((part.region, part.tid), None)
            if skip is not None and (part.region, part.tid) == skip:
                continue
            self.children[part.region].broker_release(part.tid)
        for e, b in zip(st.cuts, st.cut_bws):
            self.cut_residual[e] += b

    def _drop_or_requeue(self, rid: int, st: SpanningTicket) -> bool:
        """After a displacement teardown: hand a parent-held reservation
        up, or requeue an owned request at its home group (dropping it if
        its cumulative attempt budget is spent — the livelock backstop).
        Returns True when the request stays owned by this level."""
        if rid in self._broker_held:
            self._broker_held.discard(rid)
            self.span_tenants[st.tenant].released += 1
            if self.on_broker_displace is not None:
                self.on_broker_displace(rid)
            return False
        self._requeue_or_livelock_drop(st)
        return True

    def _child_displaced(self, g: int, crid: int) -> None:
        """Child g's plane displaced (preemption/churn) a segment this
        level reserved through broker_admit: tear down the composite's
        sibling reservations + cut bandwidth and requeue the request at
        this level (or hand it further up if it was itself broker-held)."""
        rid = self._part_of.get((g, crid))
        if rid is None:
            return
        st = self._span_active.pop(rid, None)
        if st is None:
            self._part_of.pop((g, crid), None)
            return
        self._teardown_span(st, skip=(g, crid))
        self.span_stats["displaced"] += 1
        self.span_tenants[st.tenant].preempted += 1
        if self.tracer.enabled:
            self.tracer.flow_point(rid, "displaced", group=g)
        self._drop_or_requeue(rid, st)
        if self._churn_collector is not None:
            self._churn_collector.append(st)

    def _forget_local(self, g: int, crid: int) -> None:
        rid = self._grid_of.pop((g, crid), None)
        if rid is not None:
            self._local.pop(rid, None)
            if self.on_drop is not None:
                self.on_drop(rid)

    def _displace_spans(self, pred) -> list[SpanningTicket]:
        displaced: list[SpanningTicket] = []
        for rid in [r for r, st in self._span_active.items() if pred(st)]:
            st = self._span_active.pop(rid)
            self._teardown_span(st)
            self.span_stats["displaced"] += 1
            self.span_tenants[st.tenant].preempted += 1
            if self.tracer.enabled:
                self.tracer.flow_point(rid, "displaced", churn=True)
            if rid in self._broker_held:
                self._broker_held.discard(rid)
                self.span_tenants[st.tenant].released += 1
                if self.on_broker_displace is not None:
                    self.on_broker_displace(rid)
                continue
            displaced.append(st)
        # back-to-front so the batch keeps FIFO-within-class order in any
        # shared home queue (a cumulative-budget drop leaves its slot empty)
        for st in reversed(displaced):
            self._requeue_or_livelock_drop(st)
        return displaced

    # -- release / churn ------------------------------------------------------

    def release(self, rid: int) -> None:
        if rid in self._broker_held:
            raise KeyError(
                f"rid {rid} is a parent-held broker reservation; it is "
                "released through broker_release by the plane that holds it"
            )
        st = self._span_active.pop(rid, None)
        if st is not None:
            self._teardown_span(st)
            self.span_tenants[st.tenant].released += 1
            if self.tracer.enabled:
                self.tracer.flow_end(rid, "release", outcome="released")
            return
        g, crid = self._local[rid]
        self.children[g].release(crid)  # raises if not active (caller bug)
        del self._local[rid]
        del self._grid_of[(g, crid)]

    def _span_uses_node(self, st: SpanningTicket, v: int) -> bool:
        """Does a top placement touch node ``v`` (this plane's id space) —
        as a gateway of any top hop, or anywhere inside a child segment
        (asked recursively, translated once at the view boundary)?"""
        for (u, w) in st.cuts:
            if v in (u, w):
                return True
        for part in st.parts:
            view = self.views[part.region]
            if not view.contains(v):
                continue
            if self.children[part.region].broker_uses_node(
                    part.tid, int(view.to_local(v))):
                return True
        return False

    def _churn_call(self, fn):
        self._churn_collector = collected = []
        try:
            alive, requeued = fn()
        finally:
            self._churn_collector = None
        return alive, requeued + collected

    def fail_node(self, v: int):
        """Take node ``v`` down: displace top spans touching it, then
        delegate to the owning child (whose own displacement of any
        parent-held segment chains back up through on_broker_displace).
        The child's view invalidation propagates UP the derivation chain
        automatically, so this level's bijection generation bumps too."""
        v = int(v)
        self.node_up[v] = False
        requeued_span = self._displace_spans(
            lambda st: self._span_uses_node(st, v)
        )
        g = int(self.group_of[v])
        alive, requeued = self._churn_call(
            lambda: self.children[g].fail_node(int(self.views[g].to_local(v)))
        )
        return alive, requeued + requeued_span

    def fail_link(self, u: int, v: int):
        u, v = int(u), int(v)
        if self.group_of[u] == self.group_of[v]:
            requeued_span = self._displace_spans(
                lambda st: self.broker_uses_link_span(st, u, v)
            )
            g = int(self.group_of[u])
            view = self.views[g]
            alive, requeued = self._churn_call(
                lambda: self.children[g].fail_link(
                    int(view.to_local(u)), int(view.to_local(v)))
            )
            return alive, requeued + requeued_span
        for e in ((u, v), (v, u)):
            if e in self.cut_link_up:
                self.cut_link_up[e] = False
        requeued_span = self._displace_spans(
            lambda st: any(c in ((u, v), (v, u)) for c in st.cuts)
        )
        return [], requeued_span

    def broker_uses_link_span(self, st: SpanningTicket, u: int, v: int) -> bool:
        """Link-usage predicate for an in-group link, applied to a TOP
        span: only its segment inside that group can ride the link."""
        ga = int(self.group_of[u])
        view = self.views[ga]
        for part in st.parts:
            if part.region != ga:
                continue
            if self.children[ga].broker_uses_link(
                    part.tid, int(view.to_local(u)), int(view.to_local(v))):
                return True
        return False

    def restore_node(self, v: int) -> None:
        v = int(v)
        self.node_up[v] = True
        g = int(self.group_of[v])
        self.children[g].restore_node(int(self.views[g].to_local(v)))

    def restore_link(self, u: int, v: int) -> None:
        u, v = int(u), int(v)
        if self.group_of[u] == self.group_of[v]:
            g = int(self.group_of[u])
            view = self.views[g]
            self.children[g].restore_link(
                int(view.to_local(u)), int(view.to_local(v)))
            return
        for e in ((u, v), (v, u)):
            if e in self.cut_link_up:
                self.cut_link_up[e] = bool(np.isfinite(self.base.lat[e]))

    # -- defragmentation ------------------------------------------------------

    def defrag(self, *, max_extras: Optional[int] = None) -> list:
        """Leaf-local re-optimization, recursively — still no global
        re-solve at any level.  Returns the flattened list of per-leaf
        DefragResults."""
        out: list = []
        for child in self.children:
            out += list(child.defrag(max_extras=max_extras))
        return out

    # -- reporting / invariants ----------------------------------------------

    def leaf_planes(self):
        """Every leaf region's (composed global->leaf view, ControlPlane)
        across the whole tree — the bijection-of-bijection flattened once,
        for cross-level write-through checks and reporting."""
        out = []
        for g, child in enumerate(self.children):
            if isinstance(child, HierarchicalControlPlane):
                for (cv, cp) in child.leaf_planes():
                    out.append((self.views[g].compose(cv), cp))
            else:
                for r, cp in enumerate(child.regions):
                    out.append((self.views[g].compose(child.views[r]), cp))
        return out

    def _kernel_impl_counts(self) -> dict:
        """Per-backend solve counts summed over the whole tree."""
        out: dict[str, int] = {}
        for child in self.children:
            for k, v in child._kernel_impl_counts().items():
                out[k] = out.get(k, 0) + v
        return out

    def _solve_counts(self) -> tuple[int, int]:
        solves = n_sum = 0
        for child in self.children:
            s, n = child._solve_counts()
            solves += s
            n_sum += n
        return solves, n_sum

    def engine_stats(self) -> engine.Stats:
        s = engine.Stats(method=self.method)
        for child in self.children:
            cs = child.engine_stats()
            s.preemptions += cs.preemptions
            s.defrag_rounds += cs.defrag_rounds
            s.solve_ms += cs.solve_ms
            s.overhead_ms += cs.overhead_ms
            s.conflict_resolve_ms += cs.conflict_resolve_ms
            s.stale_batches += cs.stale_batches
            s.gossip_messages += cs.gossip_messages
            s.twopc_messages += cs.twopc_messages
        s.batch_size = self.micro_batch
        s.rounds = self.bus.rounds
        s.gossip_messages += self.bus.messages_sent
        s.twopc_messages += self._twopc_msgs
        s.messages_sent = s.gossip_messages + s.twopc_messages
        solves, n_sum = self._solve_counts()
        if solves:
            s.solve_n = round(n_sum / solves)
        s.kernel_impl = ControlPlane._consensus_impl(
            self._kernel_impl_counts())
        return s

    def metrics_registry(self) -> obs_metrics.MetricsRegistry:
        """Children's registries merged under ``plane=g{g}`` (label paths
        compose per level, e.g. ``g0/r1``), plus this level's gossip, 2PC
        and spanning counters."""
        reg = obs_metrics.MetricsRegistry()
        for g, child in enumerate(self.children):
            reg.merge(child.metrics_registry(), plane=f"g{g}")
        obs_metrics.absorb_gossip_stats(reg, self.bus.gossip_stats())
        obs_metrics.absorb_span_stats(reg, self.span_stats)
        reg.inc("twopc.messages", float(self._twopc_msgs))
        reg.gauge("plane.levels", float(self.levels))
        return reg

    def solve_size_report(self) -> dict:
        per = []
        for i, (cv, cp) in enumerate(self.leaf_planes()):
            st = cp.placer.stats
            per.append({
                "region": i,
                "n_r": cv.n_local,
                "solves": st.solves,
                "mean_solve_n": st.mean_solve_n,
            })
        solves = sum(p["solves"] for p in per)
        nsum = sum(p["solves"] * p["mean_solve_n"] for p in per)
        return {
            "global_n": self.base.n,
            "regions": per,
            "solves": solves,
            "mean_solve_n": (nsum / solves) if solves else 0.0,
            "max_solve_n": max(
                (p["n_r"] for p in per if p["solves"]), default=0),
        }

    def resident_state_report(self) -> dict:
        """Max per-component resident state across the WHOLE tree: this
        level's broker (its boundary gateway id table + one quotient
        entry and one gossip record per direct child) plus every child's
        components, recursively.  The hierarchy's headline claim is that
        this maximum is O(branching + n_leaf), vs the flat plane's
        O(global boundary + R)."""
        gateway_ids = {v for e in self.cut_base for v in e}
        comps = [{
            "component": "broker",
            "id_table": len(gateway_ids),
            "peers": self.B,
            "state": len(gateway_ids) + self.B,
        }]
        for g, child in enumerate(self.children):
            for c in child.resident_state_report()["components"]:
                comps.append({**c, "component": f"child[{g}].{c['component']}"})
        return {
            "components": comps,
            "max_component_state": max(c["state"] for c in comps),
        }

    def coordination_report(self) -> dict:
        return {
            "levels": self.levels,
            "branching": self.B,
            "leaf_regions": self.leaf_regions,
            "fanout": self.bus.fanout,
            "gossip_period": self.gossip_period,
            "gossip": self.bus.gossip_stats(),
            "gossip_messages_total": self.engine_stats().gossip_messages,
            "twopc_messages": self._twopc_msgs,
            "twopc_messages_total": self.engine_stats().twopc_messages,
            "spanning": dict(self.span_stats),
            "cut_edges": len(self.cut_base),
            "children": [c.coordination_report() for c in self.children],
            "solve_size": self.solve_size_report(),
            "resident": self.resident_state_report(),
        }

    def fairness_report(self) -> dict:
        rep = fairness_summary(
            self.committed_capacity(),
            self.queued_demand(),
            {t: st.cfg.weight for t, st in self.span_tenants.items()},
        )
        rep["coordination"] = self.coordination_report()
        timing = {"solve_ms": 0.0, "overhead_ms": 0.0,
                  "conflict_resolve_ms": 0.0}
        for child in self.children:
            for k, v in child.fairness_report()["timing"].items():
                timing[k] += v
        rep["timing"] = timing
        return rep

    def check_invariants(self) -> None:
        """Every child's invariants recursively, this level's ledger +
        cut conservation + span integrity, and the cross-level
        write-through: leaf residuals and ticket loads lifted through the
        COMPOSED bijections must re-assemble the global base exactly —
        the conservation argument survives nesting."""
        for child in self.children:
            child.check_invariants()
        led = self.conservation()
        assert led["ok"], f"hierarchical ticket conservation violated: {led}"
        # span accounting: single-sited attempts/admitted counters nest
        # strictly (mirrors RegionalControlPlane.check_invariants)
        ss = self.span_stats
        assert 0 <= ss["admitted"] <= ss["attempts"], (
            f"span accounting violated: {ss}")
        assert ss["multi_hop"] <= ss["admitted"], (
            f"span accounting violated: {ss}")
        assert ss["rerouted"] <= ss["admitted"], (
            f"span accounting violated: {ss}")
        assert ss["livelock_dropped"] <= ss["dropped"] <= ss["attempts"], (
            f"span accounting violated: {ss}")
        assert len(self._span_active) <= ss["admitted"] + ss["broker_local"], (
            f"more active spans than admissions: {ss}")
        reserved = {e: 0.0 for e in self.cut_base}
        for st in self._span_active.values():
            for e, b in zip(st.cuts, st.cut_bws):
                reserved[e] += b
        for e, base_bw in self.cut_base.items():
            assert abs(self.cut_residual[e] + reserved[e] - base_bw) < 1e-6, (
                f"top cut bandwidth conservation violated on {e}"
            )
            assert self.cut_residual[e] >= -1e-6, (
                f"negative top cut residual on {e}"
            )
        for rid, st in self._span_active.items():
            assert len(st.parts) == len(st.cuts) + 1, (
                f"top spanning rid {rid}: chain/cut arity mismatch"
            )
            for i, (u, v) in enumerate(st.cuts):
                assert int(self.group_of[u]) == st.parts[i].region
                assert int(self.group_of[v]) == st.parts[i + 1].region
            for part in st.parts:
                child = self.children[part.region]
                assert part.tid in child._span_active, (
                    f"top spanning rid {rid} holds a dead child "
                    f"reservation in group {part.region}"
                )
                assert part.tid in child._broker_held
                assert self._part_of.get((part.region, part.tid)) == rid
                assert part.version <= self.views[part.region].version, (
                    f"top spanning rid {rid}: part minted under a future "
                    "bijection version"
                )
        # cross-level write-through conservation through composed views
        n = self.base.n
        cap_res = np.zeros(n)
        cap_load = np.zeros(n)
        bw_res = np.zeros((n, n))
        bw_load = np.zeros((n, n))
        in_region = np.zeros((n, n), bool)
        for cv, cp in self.leaf_planes():
            cap_res += cv.uncompact_node_vec(cp.placer.cap)
            bw_res += cv.uncompact_link_mat(cp.placer.bw)
            in_region |= cv.uncompact_link_mat(
                np.ones((cv.n_local, cv.n_local), bool))
            for tk in cp.placer.tickets.values():
                for gv, c in cv.uncompact_node_load(tk.node_load).items():
                    cap_load[gv] += c
                for (gu, gv), b in cv.uncompact_edge_load(
                        tk.edge_load).items():
                    bw_load[gu, gv] += b
        assert np.allclose(cap_res + cap_load, self.base.cap, atol=1e-4), (
            "cross-level write-through broke node-capacity conservation"
        )
        assert np.allclose(
            (bw_res + bw_load)[in_region], self.base.bw[in_region], atol=1e-4
        ), "cross-level write-through broke link-bandwidth conservation"
