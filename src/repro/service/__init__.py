"""Multi-tenant placement control plane (service layer).

The layer between ``core.online.OnlinePlacer`` and the launch/serving
front ends:

  policy:       TenantConfig, weighted max-min shares (water-filling),
                FairSharePolicy drain scheduling, preemption-class rules
  controlplane: ControlPlane — per-tenant queues, fair admission into
                ``admit_many`` micro-batches, preemption, churn
                reconciliation, conservation ledger
  defrag:       atomic global re-optimization of the standing ticket set
  gossip:       GossipBus — push-gossip of versioned per-region share
                estimates (R * fanout messages per round)
  regions:      RegionalControlPlane — R sharded planes coordinated only
                by gossip + bounded 2PC over cut edges; constructed by
                ``ControlPlane(rg, regions=R)``, bit-identical to the
                centralized plane at R = 1
"""
from .controlplane import ControlPlane, Request, TenantState  # noqa: F401
from .defrag import DefragResult, defrag, global_objective  # noqa: F401
from .gossip import GossipBus, ShareRecord  # noqa: F401
from .regions import (  # noqa: F401
    RegionalControlPlane,
    SpanningTicket,
    cut_edges,
    partition_regions,
    region_subgraph,
    split_dataflow,
)
from .policy import (  # noqa: F401
    CLASS_BEST_EFFORT,
    CLASS_CRITICAL,
    CLASS_STANDARD,
    FairSharePolicy,
    TenantConfig,
    maxmin_shares,
    may_preempt,
)
