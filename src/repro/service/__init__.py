"""Multi-tenant placement control plane (service layer).

The layer between ``core.online.OnlinePlacer`` and the launch/serving
front ends:

  policy:       TenantConfig, weighted max-min shares (water-filling),
                FairSharePolicy drain scheduling, preemption-class rules
  controlplane: ControlPlane — per-tenant queues, fair admission into
                ``admit_many`` micro-batches, preemption, churn
                reconciliation, conservation ledger
  defrag:       atomic global re-optimization of the standing ticket set
  gossip:       GossipBus — push-gossip of versioned per-region share
                estimates (R * fanout messages per round)
  regions:      RegionalControlPlane — R sharded planes over compacted
                region-local subgraphs (core.compact views: every solve
                sized n_r, not n), coordinated only by gossip + one
                bounded 2PC per spanning dataflow over its multi-hop
                region chain; constructed by ``ControlPlane(rg,
                regions=R)``, bit-identical to the centralized plane at
                R = 1
  hierarchy:    HierarchicalControlPlane — regions of regions: per-level
                brokers that translate ids only at their own boundary,
                recursive spanning decomposition, tree-structured gossip
                (O(branching * fanout) msgs/round per level); constructed
                by ``ControlPlane(rg, levels=L, branching=b)``,
                bit-identical to the flat regional plane at levels = 1
"""
from .controlplane import ControlPlane, Request, TenantState  # noqa: F401
from .defrag import DefragResult, defrag, global_objective  # noqa: F401
from .gossip import GossipBus, ShareRecord  # noqa: F401
from .hierarchy import (  # noqa: F401
    HierarchicalControlPlane,
    resolve_nesting,
)
from .regions import (  # noqa: F401
    ChainBroker,
    RegionalControlPlane,
    SpanPart,
    SpanningTicket,
    cut_edges,
    partition_regions,
    region_subgraph,
    split_dataflow,
    split_dataflow_chain,
    validate_region_of,
)
from .policy import (  # noqa: F401
    CLASS_BEST_EFFORT,
    CLASS_CRITICAL,
    CLASS_STANDARD,
    FairSharePolicy,
    TenantConfig,
    fairness_summary,
    maxmin_shares,
    may_preempt,
)
