"""Background defragmentation: globally re-solve the standing allocation.

Greedy churn re-mapping (``OnlinePlacer.fail_node`` squeezing displaced
tickets into whatever residual happens to be free) fragments capacity: after
a fail/restore cycle the restored node sits empty while the standing
placements crowd the survivors, and later arrivals are rejected even though
a better global packing would fit them (Eidenbenz & Locher 2016: re-optimize
the *standing* allocation, not only the arrivals).

:func:`defrag` re-solves the whole ticket set as ONE batched kernel solve
against a blank residual snapshot (same node/link liveness, zero committed
load) and atomically commits the new placement only if it improves the
global objective — otherwise it restores the pre-pass state bit-for-bit.
The pass is transactional end to end:

- re-placement order is class-major (then admission order), so the
  re-solve can never leave a high class worse off because of a low one;
- the commit requires *every* standing ticket to re-place — defrag never
  drops or displaces standing work, whatever its class;
- re-placed tickets keep their ``tid`` (``OnlinePlacer.rekey``), so
  external handles survive the move;
- previously-rejected / queued requests (``extras``) are retried on the
  re-packed residual; admitting any of them raises the objective's leading
  term, which is what makes the pass worth running under overload.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from ..core.graph import DataflowPath
from ..core.online import OnlinePlacer, Ticket


def global_objective(placer: OnlinePlacer) -> tuple[int, float]:
    """Higher is better: ``(tickets placed, -total route latency)``.

    Admitted count dominates (serving more standing work beats any latency
    win); total mapped latency breaks ties — the paper's mapping objective
    summed over the standing set.
    """
    return (
        len(placer.tickets),
        -sum(t.mapping.cost for t in placer.tickets.values()),
    )


@dataclasses.dataclass
class DefragResult:
    committed: bool  # anything changed (full re-pack, or extras admitted)
    repacked: bool  # the standing set was re-solved and the re-pack committed
    objective_before: tuple[int, float]
    objective_after: tuple[int, float]  # == before when nothing committed
    standing: int  # tickets in the re-solved set
    moved: int  # standing tickets whose assignment changed (0 if rolled back)
    readmitted: list  # extras admitted: (extra_index, Ticket)


def defrag(
    placer: OnlinePlacer,
    *,
    extras: Sequence[tuple[DataflowPath, tuple[str, int]]] = (),
) -> DefragResult:
    """One atomic re-optimization pass over ``placer``'s standing tickets.

    ``extras`` are (df, (tenant, klass)) pairs — typically queued or
    previously-rejected requests — retried on the re-packed network in the
    given order.  The full re-pack commits iff every standing ticket
    re-places AND the global objective strictly improves.  A greedy
    class-major re-pack is not guaranteed to re-place a set the incremental
    history managed to interleave (early tickets can grab the bandwidth a
    later one needs), so on a failed or non-improving re-pack the pass
    restores the pre-pass state bit-for-bit and *falls back* to retrying
    the extras on the current residual — still strictly
    objective-improving (admitted count only goes up), still displacing
    nobody.  The admission/rejection counters only ever record the net
    effect of what committed (speculative churn is reconciled away,
    leaving ``defrag_rounds`` and solver wall-clock).
    """
    snap = placer.snapshot()
    obj_before = global_objective(placer)
    standing = sorted(
        placer.tickets.values(), key=lambda t: (-t.klass, t.tid)
    )

    with placer.cache_suspended():
        # The re-pack runs with the SolutionCache bypassed: serving the
        # just-released standing mappings back from cache would make the
        # re-optimization a structural no-op (and the speculative
        # release/commit churn must not pollute the cache either way).

        # clear the standing set; re-solve it as one batched solve on the
        # blank residual (stats churn from this speculative work is
        # reconciled below)
        with placer.tracer.span("defrag.repack", track="placer", cat="defrag",
                                standing=len(standing)):
            for t in standing:
                placer.release(t, reason=None)
            new = placer.admit_many(
                [t.df for t in standing],
                metas=[(t.tenant, t.klass) for t in standing],
            )
        ok = all(nt is not None for nt in new)

        def _admit_extras() -> list[tuple[int, Ticket]]:
            """One batched solve over the extras (micro-batched admission
            with per-result revalidation, same as the service path)."""
            if not extras:
                return []
            tickets = placer.admit_many(
                [df for df, _ in extras], metas=[meta for _, meta in extras]
            )
            return [(i, t) for i, t in enumerate(tickets) if t is not None]

        readmitted: list[tuple[int, Ticket]] = []
        moved = 0
        obj_after = obj_before
        if ok:
            kept: list[Ticket] = []
            for t, nt in zip(standing, new):
                kept.append(placer.rekey(nt, t.tid))
                moved += int(nt.mapping.assign != t.mapping.assign)
            readmitted = _admit_extras()
            obj_after = global_objective(placer)

        repacked = ok and obj_after > obj_before
        # speculative solves did real work: solve accounting (wall clock,
        # solve counts, cache/warm traffic) survives rollback
        acct = placer.stats.solve_accounting()
        if not repacked:
            placer.restore(snap)
            placer.stats.restore_solve_accounting(acct)
            # fallback: keep the standing placement, retry the extras on the
            # current residual (probe rejections are not service rejections)
            readmitted = _admit_extras()
            placer.stats.rejected = snap["stats"].rejected
            placer.stats.defrag_rounds += 1
            placer.stats.defrag_commits += bool(readmitted)
            placer.check_invariants()
            return DefragResult(
                committed=bool(readmitted),
                repacked=False,
                objective_before=obj_before,
                objective_after=global_objective(placer),
                standing=len(standing),
                moved=0,
                readmitted=readmitted,
            )

    # committed re-pack: rebase stats on the snapshot so the speculative
    # release/re-admit churn vanishes and only the net effect remains
    stats = snap["stats"].clone()
    stats.restore_solve_accounting(acct)
    stats.admitted += len(readmitted)
    stats.defrag_rounds += 1
    stats.defrag_commits += 1
    placer.stats = stats
    placer.check_invariants()
    return DefragResult(
        committed=True,
        repacked=True,
        objective_before=obj_before,
        objective_after=obj_after,
        standing=len(standing),
        moved=moved,
        readmitted=readmitted,
    )
