"""Cross-tenant allocation policy: weighted max-min shares + drain order.

The paper's regime is many long-running data-flow applications competing
for one network.  Benoit et al. 2009 show that concurrent in-network
stream-processing applications need an *explicit* cross-application
allocation policy — per-application greedy admission (FCFS) lets one heavy
tenant take whatever arrives first.  This module is that policy, kept free
of any service state so it can be unit-tested and swapped:

- :func:`maxmin_shares` — weighted max-min (water-filling) allocation of a
  scalar capacity among tenants with demands; the fairness target the
  control plane is graded against.
- :class:`FairSharePolicy` — given the per-tenant queues and the live
  committed-capacity accounting, picks which queued requests the next
  ``admit_many`` micro-batch should attempt, such that under overload each
  tenant's *standing committed compute* converges to its weighted max-min
  share of whatever total the network can actually hold (the total is never
  known a priori — feasibility is decided by the placement DP — so shares
  are enforced against the observed committed total, self-normalizing).
- Preemption-class rules: :func:`may_preempt` is the single place encoding
  "a class-k ticket is only ever displaced by class > k".

Classes are small ints; three conventional levels are named here but any
int works (higher = more important).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence

# Conventional preemption classes (any int is a valid class; higher wins).
CLASS_BEST_EFFORT = 0
CLASS_STANDARD = 1
CLASS_CRITICAL = 2


@dataclasses.dataclass(frozen=True)
class TenantConfig:
    """Registration record for one tenant.

    ``weight`` sets the tenant's share under weighted max-min fairness;
    ``budget`` (optional) is an absolute ceiling on the tenant's committed
    compute regardless of its fair share — a hard cap for capped plans.
    """

    name: str
    weight: float = 1.0
    budget: Optional[float] = None

    def __post_init__(self):
        assert self.weight > 0, "tenant weight must be positive"


def maxmin_shares(
    demands: Mapping[str, float],
    weights: Mapping[str, float],
    capacity: float,
) -> dict[str, float]:
    """Weighted max-min (progressive water-filling) allocation.

    Each tenant receives at most its demand; unused share of a satisfied
    tenant is redistributed among the still-unsatisfied ones in proportion
    to weight.  The classic fixed point: no tenant can gain without a
    tenant of equal-or-smaller normalized allocation losing.
    """
    shares = {t: 0.0 for t in demands}
    active = {t for t, d in demands.items() if d > 0}
    remaining = max(float(capacity), 0.0)
    while active and remaining > 1e-12:
        wsum = sum(weights[t] for t in active)
        level = {t: remaining * weights[t] / wsum for t in active}
        satisfied = [
            t for t in active if demands[t] - shares[t] <= level[t] + 1e-12
        ]
        if not satisfied:
            # nobody saturates: hand out the full proportional level
            for t in active:
                shares[t] += level[t]
            break
        for t in satisfied:
            take = demands[t] - shares[t]
            shares[t] = demands[t]
            remaining -= take
            active.remove(t)
    return shares


def may_preempt(victim_klass: int, aggressor_klass: int) -> bool:
    """Preemption is strictly class-ordered: > only, never >=."""
    return victim_klass < aggressor_klass


def fairness_summary(
    held: Mapping[str, float],
    queued: Mapping[str, float],
    weights: Mapping[str, float],
) -> dict:
    """Actual standing shares vs weighted max-min targets — the single
    definition both the centralized and the regional plane report (and
    that the CI fairness gates compare between them).

    Shares are taken over the *observed* committed total (the network
    decides what fits; the policy only divides it) and targets come from
    :func:`maxmin_shares` with each tenant's demand = committed + queued —
    a tenant demanding less than its share keeps only its demand, the
    rest is redistributed by weight."""
    held = dict(held)
    total = sum(held.values())
    demands = {t: held[t] + queued[t] for t in held}
    target = maxmin_shares(demands, weights, total)
    deviation = {
        t: abs(held[t] - target[t]) / target[t]
        for t in held
        if target[t] > 1e-9
    }
    return {
        "committed": held,
        "queued_demand": dict(queued),
        "total_committed": total,
        "target_shares": target,
        "deviation": deviation,
        "max_deviation": max(deviation.values(), default=0.0),
    }


class FairSharePolicy:
    """Weighted max-min scheduler over per-tenant FIFO queues.

    ``select`` simulates granting requests one at a time: a tenant is
    *eligible* while its committed compute (including tentative grants this
    round) stays within its weighted fraction of the total committed
    compute, plus a slack.  Among eligible backlogged tenants the most
    under-served one (smallest committed/weight) drains first — the
    water-filling order.

    The slack absorbs request granularity: fluid shares cannot be tracked
    finer than one request, and a slack much smaller than a typical request
    stalls the drain far below what the network holds (every tenant looks
    "over share" the moment it commits one request).  ``select`` therefore
    uses ``max(slack, largest head request)`` each round — the configured
    ``slack`` is a floor, and the fairness error stays bounded by one
    request size, shrinking relative to the total as the system fills.

    The fraction test self-normalizes: it needs no estimate of how much the
    network can hold.  Whatever total the placement DP admits, each
    backlogged tenant's standing share converges to weight_t / sum(weights
    of demanding tenants) of it.
    """

    def __init__(self, *, slack: float = 0.5):
        self.slack = float(slack)

    # -- eligibility --------------------------------------------------------

    def eligible(
        self,
        cfg: TenantConfig,
        creq: float,
        virt: Mapping[str, float],
        frac: float,
        slack: Optional[float] = None,
    ) -> bool:
        held = virt[cfg.name]
        if cfg.budget is not None and held + creq > cfg.budget + 1e-9:
            return False
        if held <= 0:
            # granularity floor: a backlogged tenant holding nothing may
            # always attempt its head request — fluid max-min shares are
            # meaningless below one request, and without this floor a
            # request larger than the slack could wedge the whole drain
            return True
        total = sum(virt.values())
        s = self.slack if slack is None else slack
        return held + creq <= frac * (total + creq) + s

    # -- drain selection ----------------------------------------------------

    def select(
        self,
        tenants: Mapping[str, TenantConfig],
        queues: Mapping[str, Sequence],
        committed: Mapping[str, float],
        slots: int,
    ) -> list:
        """Pick up to ``slots`` queued requests for the next micro-batch.

        ``queues`` maps tenant -> FIFO of requests exposing ``creq_sum``;
        queues are only read (the caller pops the returned heads).  Per
        tenant the FIFO order is preserved; an ineligible head blocks that
        tenant for the round (no reordering within a tenant).
        """
        virt = {t: float(committed.get(t, 0.0)) for t in tenants}
        idx = {t: 0 for t in tenants}
        picked: list = []
        while len(picked) < slots:
            backlogged = [t for t in tenants if idx[t] < len(queues.get(t, ()))]
            if not backlogged:
                break
            # granularity-aware slack: at least one head-request size
            slack = max(
                self.slack,
                max(queues[t][idx[t]].creq_sum for t in backlogged),
            )
            # tenants with live demand split the pie; idle tenants' weight
            # is redistributed (work conservation)
            demanding = [
                t for t in tenants if virt[t] > 0 or t in backlogged
            ]
            wsum = sum(tenants[t].weight for t in demanding)
            best = None
            for t in sorted(
                backlogged,
                key=lambda t: (virt[t] / tenants[t].weight, t),
            ):
                req = queues[t][idx[t]]
                frac = tenants[t].weight / wsum
                if self.eligible(tenants[t], req.creq_sum, virt, frac,
                                 slack=slack):
                    best = (t, req)
                    break
            if best is None:
                break
            t, req = best
            idx[t] += 1
            virt[t] += req.creq_sum
            picked.append(req)
        return picked

    # -- reporting ----------------------------------------------------------

    def fair_fractions(
        self,
        tenants: Mapping[str, TenantConfig],
        demanding: Sequence[str],
    ) -> dict[str, float]:
        """Weight-proportional target fractions among demanding tenants."""
        wsum = sum(tenants[t].weight for t in demanding) or 1.0
        return {t: tenants[t].weight / wsum for t in demanding}
