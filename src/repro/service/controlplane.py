"""Multi-tenant placement control plane over :class:`OnlinePlacer`.

The layer between the online placer and the serving front end.  Tenants
register with a weight (and optional budget); arrivals queue per tenant
(class-major, FIFO within a class) and :meth:`ControlPlane.pump` drains the
queues into ``admit_many`` micro-batches under the weighted max-min
:class:`FairSharePolicy` — under overload, residual capacity divides by
weight instead of by arrival order.
Every request carries a preemption class; rejected high-class admissions
and churn re-mapping may displace strictly-lower-class tickets
(:meth:`OnlinePlacer.admit_preempting`), and preempted work re-enters
through its tenant queue, never silently dropped.  A background
:meth:`defrag` pass re-solves the whole standing set as one batched kernel
solve and commits atomically only on improvement (``service.defrag``).

Request lifecycle (conservation-checked by the fuzz tests)::

    submit -> queued -> active -> released
                 ^         |
                 |         +-- preempted / displaced-by-failure (requeued)
                 +-- retried (admission failed, attempts left)
    queued/active -> dropped (attempts exhausted, or infeasible)

``conservation()`` returns the ledger; ``submitted == queued + active +
released + dropped`` holds after every public call.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import Optional

import numpy as np

from ..core import engine
from ..core.graph import DataflowPath, ResourceGraph
from ..core.online import OnlinePlacer, Ticket
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from . import defrag as defrag_mod
from .policy import FairSharePolicy, TenantConfig, may_preempt


@dataclasses.dataclass(eq=False)
class Request:
    """One submitted placement request (``eq=False``: identity semantics so
    deque removal and bookkeeping never compare numpy payloads)."""

    rid: int
    tenant: str
    df: DataflowPath
    klass: int = 0
    attempts: int = 0  # failed placement tries this episode (reset on displace)
    cum_attempts: int = 0  # lifetime tries + displacements (never reset)
    creq_sum: float = 0.0

    def __post_init__(self):
        self.creq_sum = float(np.sum(self.df.creq))


@dataclasses.dataclass
class TenantState:
    cfg: TenantConfig
    queue: collections.deque = dataclasses.field(
        default_factory=collections.deque
    )
    submitted: int = 0
    admitted: int = 0
    released: int = 0
    dropped: int = 0
    preempted: int = 0  # times this tenant's work was displaced (then requeued)


class ControlPlane:
    """Fair admission + preemption classes + background defrag.

    ``ControlPlane(rg, regions=R)`` with ``R > 1`` constructs the
    decentralized regional plane instead (``service.regions``): the network
    is sharded into R regions, each with its own queues/residual/placer,
    coordinated only by gossiped share estimates and a bounded two-phase
    commit for region-spanning dataflows.  ``R = 1`` (the default) is this
    centralized plane — the bit-identical degenerate case.
    """

    def __new__(cls, rg=None, *args, regions: int = 1, **kwargs):
        levels = kwargs.get("levels")
        if levels is not None and int(levels) < 1:
            raise ValueError(f"levels={levels} must be >= 1")
        if cls is ControlPlane and levels is not None and int(levels) > 1:
            from .hierarchy import HierarchicalControlPlane

            # nested planes: levels >= 2 builds the hierarchy regardless of
            # how the leaf partition is given (regions=, region_of=, or
            # branching=); contradictions fail fast in resolve_nesting.
            return HierarchicalControlPlane(
                rg,
                regions=int(regions) if int(regions) > 1 else None,
                **kwargs,
            )
        regional = (
            int(regions) > 1
            or kwargs.get("region_of") is not None
            or levels is not None  # levels=1 asks for the flat regional plane
            or kwargs.get("branching") is not None  # fails fast there
        )
        if cls is ControlPlane and regional:
            from .regions import RegionalControlPlane

            # not a ControlPlane subclass, so __init__ below is not re-run.
            # A caller-pinned region_of alone implies the regional plane
            # (its region count comes from the assignment); an explicit
            # regions= is cross-checked against it there.
            return RegionalControlPlane(
                rg,
                regions=int(regions) if int(regions) > 1 else None,
                **kwargs,
            )
        return super().__new__(cls)

    def __init__(
        self,
        rg: ResourceGraph,
        *,
        regions: int = 1,
        levels: Optional[int] = None,
        branching: Optional[int] = None,
        policy: Optional[FairSharePolicy] = None,
        micro_batch: int = 32,
        max_attempts: int = 8,
        preempt: bool = True,
        preempt_budget: Optional[float] = None,
        pipeline_depth: int = 1,
        method: str = "leastcost_jax",
        use_kernel: bool = False,
        view=None,
        tracer=None,
        **solve_cfg,
    ):
        """``view`` (a :class:`~repro.core.compact.CompactedView`) makes
        this a *region-local* plane: the placer compacts ``rg`` through it
        so all state and every solve is sized to the view's ``n_r``; all
        submitted dataflows must already be in the view's local id space
        (the regional broker translates at its boundary).

        ``pipeline_depth`` bounds the admission pipeline: each
        :meth:`pump` round *dispatches* its micro-batch solve immediately
        but only *commits* once the in-flight window reaches the depth, so
        batch k+1's device DP overlaps batch k's validation/commit.  Depth
        1 (default) is the synchronous path, bit for bit.  In-flight
        batches persist across ``pump`` calls (``conservation()`` counts
        them); :meth:`flush` forces them all to commit.

        ``tracer`` (:class:`repro.obs.Tracer`) records request-lifecycle
        flow events (submit/dispatch/admit/reject/preempt/release) and
        pump/solve/defrag spans; defaults to the no-op
        :data:`repro.obs.NULL`.

        The incremental-fast-path knobs (``cache_enabled`` /
        ``cache_size`` / ``max_correction_supersteps``) ride
        ``**solve_cfg`` into the plane's :class:`OnlinePlacer`, as they
        do for every plane class — the placer consumes them as named
        parameters, so they never leak into the solver backend."""
        assert int(regions) <= 1, "regions > 1 is dispatched in __new__"
        # nesting kwargs are facade-dispatched in __new__; reaching this
        # body with either set means a direct centralized construction
        # that would otherwise silently ignore them
        if levels is not None and int(levels) != 1:
            raise ValueError(
                f"levels={levels}: the centralized ControlPlane is "
                "single-level; build a hierarchy with ControlPlane(rg, "
                "levels=...) on the facade"
            )
        if branching is not None:
            raise ValueError(
                f"branching={branching} requires a hierarchical plane "
                "(levels >= 2)"
            )
        self.tracer = tracer if tracer is not None else obs_trace.NULL
        self.placer = OnlinePlacer(
            rg, method=method, use_kernel=use_kernel, view=view,
            tracer=self.tracer, **solve_cfg
        )
        self.policy = policy or FairSharePolicy()
        self.micro_batch = int(micro_batch)
        self.max_attempts = int(max_attempts)
        self.preempt = bool(preempt)
        self.preempt_budget = preempt_budget
        self.pipeline_depth = max(1, int(pipeline_depth))
        # (picked requests, PendingAdmission) windows dispatched but not yet
        # committed — FIFO, survives across pump calls
        self._inflight: collections.deque = collections.deque()
        self.tenants: dict[str, TenantState] = {}
        self.active: dict[int, tuple[Request, Ticket]] = {}  # by rid
        self._rid_of_tid: dict[int, int] = {}
        self._rid = itertools.count()
        # victims preempted here that this plane does not own (e.g. spanning
        # segments reserved by the regional broker) are handed to this hook
        # so their composite placements can be reconciled
        self.on_foreign_preempt: Optional[callable] = None
        # called with the Request whenever this plane drops it (attempts
        # exhausted) — lets an owner of external rid maps (the regional
        # broker) forget its bookkeeping for terminal requests
        self.on_drop: Optional[callable] = None

    # -- registration / submission ------------------------------------------

    def register_tenant(
        self, name: str, *, weight: float = 1.0,
        budget: Optional[float] = None,
    ) -> TenantConfig:
        if name in self.tenants:
            raise ValueError(f"tenant {name!r} already registered")
        cfg = TenantConfig(name, weight=weight, budget=budget)
        self.tenants[name] = TenantState(cfg)
        return cfg

    @staticmethod
    def _enqueue(queue: collections.deque, r: Request, *,
                 front_of_class: bool = False) -> None:
        """Class-major insertion: higher classes drain first, FIFO within a
        class.  ``front_of_class`` re-inserts ahead of the request's own
        class band (preempted/displaced work resumes before new arrivals of
        its class)."""
        if front_of_class:
            i = next((i for i, x in enumerate(queue) if x.klass <= r.klass),
                     len(queue))
        else:
            i = next((i for i, x in enumerate(queue) if x.klass < r.klass),
                     len(queue))
        queue.insert(i, r)

    def submit(self, tenant: str, df: DataflowPath, *, klass: int = 0) -> int:
        """Queue a request; returns its rid.  Nothing is placed until
        :meth:`pump` drains the queues under the fairness policy."""
        st = self.tenants[tenant]  # KeyError for unregistered: caller bug
        r = Request(next(self._rid), tenant, df, klass=klass)
        self._enqueue(st.queue, r)
        st.submitted += 1
        if self.tracer.enabled:
            self.tracer.flow_begin(r.rid, "submit", tenant=tenant,
                                   klass=klass, p=int(df.p))
        return r.rid

    # -- live accounting -----------------------------------------------------

    def committed_capacity(self) -> dict[str, float]:
        """Live committed compute per tenant (from the active tickets, the
        ground truth — never a counter that could drift)."""
        held = {t: 0.0 for t in self.tenants}
        for req, _ in self.active.values():
            held[req.tenant] += req.creq_sum
        return held

    def queued_demand(self) -> dict[str, float]:
        return {
            t: sum(r.creq_sum for r in st.queue)
            for t, st in self.tenants.items()
        }

    def active_ids(self) -> list[int]:
        """Sorted rids of the currently active (admitted, unreleased)
        requests — the handles :meth:`release` accepts.  Mirrored by the
        regional plane so callers can stay plane-agnostic."""
        return sorted(self.active)

    def rid_of(self, ticket: Ticket) -> Optional[int]:
        """The request id an admitted ticket belongs to (stable across
        re-mapping and defrag, which preserve tids)."""
        return self._rid_of_tid.get(ticket.tid)

    def conservation(self) -> dict[str, int]:
        """The ticket ledger; ``ok`` iff every submitted request is in
        exactly one terminal/live state.  ``in_flight`` counts requests
        popped from their queues into a dispatched-but-uncommitted pipeline
        window — a live state of its own until the window commits."""
        queued = sum(len(st.queue) for st in self.tenants.values())
        released = sum(st.released for st in self.tenants.values())
        dropped = sum(st.dropped for st in self.tenants.values())
        submitted = sum(st.submitted for st in self.tenants.values())
        in_flight = sum(len(picked) for picked, _ in self._inflight)
        return {
            "submitted": submitted,
            "queued": queued,
            "in_flight": in_flight,
            "active": len(self.active),
            "released": released,
            "dropped": dropped,
            "ok": submitted
            == queued + in_flight + len(self.active) + released + dropped,
        }

    # -- admission -----------------------------------------------------------

    def _activate(self, req: Request, ticket: Ticket) -> None:
        self.active[req.rid] = (req, ticket)
        self._rid_of_tid[ticket.tid] = req.rid
        self.tenants[req.tenant].admitted += 1

    def _deactivate(self, rid: int) -> tuple[Request, Ticket]:
        req, ticket = self.active.pop(rid)
        self._rid_of_tid.pop(ticket.tid, None)
        return req, ticket

    def _requeue(self, req: Request, *, front: bool = True) -> None:
        self._enqueue(self.tenants[req.tenant].queue, req,
                      front_of_class=front)

    def _drop(self, req: Request) -> None:
        self.tenants[req.tenant].dropped += 1
        if self.tracer.enabled:
            self.tracer.flow_end(req.rid, "drop", outcome="dropped",
                                 attempts=req.attempts)
        if self.on_drop is not None:
            self.on_drop(req)

    def preempt_reclaim(self, victims: list[Ticket]) -> list[Ticket]:
        """Re-queue displaced victims this plane owns: each re-enters its
        tenant queue at the front of its class band (accounted, never
        dropped).  Victims whose tid is unknown here — e.g. segments of a
        region-spanning placement reserved directly by the regional broker —
        are returned for the caller to reconcile."""
        leftovers: list[Ticket] = []
        owned: list[Request] = []
        for v in victims:
            vrid = self._rid_of_tid.get(v.tid)
            if vrid is None:
                leftovers.append(v)
                continue
            vreq, _ = self._deactivate(vrid)
            vreq.attempts = 0
            self.tenants[vreq.tenant].preempted += 1
            if self.tracer.enabled:
                self.tracer.flow_point(vreq.rid, "preempt",
                                       tenant=vreq.tenant, klass=vreq.klass)
            owned.append(vreq)
        # front-of-class insertion reverses a batch; requeue back-to-front
        # so displaced work keeps its relative (FIFO-within-class) order
        for vreq in reversed(owned):
            self._requeue(vreq, front=True)
        return leftovers

    def _try_preempt(self, req: Request) -> Optional[Ticket]:
        """Attempt class-ordered preemptive admission for ``req``; on
        success, every displaced victim re-enters its tenant queue at the
        front of its class band (accounted, never dropped)."""
        if not self.preempt or not any(
            may_preempt(t.klass, req.klass)
            for t in self.placer.tickets.values()
        ):
            return None
        ticket, victims = self.placer.admit_preempting(
            req.df, tenant=req.tenant, klass=req.klass,
            max_displaced_cost=self.preempt_budget,
        )
        if ticket is None:
            return None
        leftovers = self.preempt_reclaim(victims)
        if leftovers and self.on_foreign_preempt is not None:
            self.on_foreign_preempt(leftovers)
        self._activate(req, ticket)
        return ticket

    def _handle_reject(self, req: Request) -> Optional[Ticket]:
        """A drained request the placer could not fit: try class preemption,
        else retry later (bounded) or drop."""
        req.attempts += 1
        if self.tracer.enabled:
            self.tracer.flow_point(req.rid, "reject", attempts=req.attempts)
        ticket = self._try_preempt(req)
        if ticket is not None:
            return ticket
        if req.attempts >= self.max_attempts:
            self._drop(req)
        else:
            self._requeue(req, front=True)
        return None

    def pump(
        self, *, rounds: int = 1,
        extra_committed: Optional[dict[str, float]] = None,
    ) -> list[Ticket]:
        """Drain the tenant queues under the fairness policy.

        Each round selects up to ``micro_batch`` eligible queue heads
        (weighted max-min over live committed compute), pops them, and
        admits them as ONE ``admit_many`` micro-batch — the batched kernel
        serves the whole drain.  Rejections go through preemption /
        retry / drop handling.  Returns the tickets admitted.

        ``extra_committed`` (tenant -> compute) is added to the live local
        accounting before the fairness selection: the regional plane passes
        each region the *gossiped estimate* of what every tenant holds in
        the other regions, so the drain enforces estimated global shares
        without any global view.  Admission itself still validates against
        this plane's own residual only — stale estimates can skew the drain
        order, never over-commit capacity.

        With ``pipeline_depth > 1`` each round dispatches its micro-batch
        and commits only the rounds the window forces out; the rest stay
        in flight (returned by a later ``pump`` or :meth:`flush`).  The
        fairness selection then reads committed capacity that may lag by
        up to ``depth - 1`` windows — the same staleness-for-latency trade
        the gossiped regional shares make, and with the same safety net:
        the drain order can skew, admission never over-commits.
        """
        admitted: list[Ticket] = []
        cfgs = {t: st.cfg for t, st in self.tenants.items()}
        for _ in range(rounds):
            with self.tracer.span("pump.round", track="plane", cat="pump"):
                queues = {t: st.queue for t, st in self.tenants.items()}
                committed = self.committed_capacity()
                for t, c in (extra_committed or {}).items():
                    if t in committed:
                        committed[t] += float(c)
                picked = self.policy.select(
                    cfgs, queues, committed, self.micro_batch
                )
                if not picked:
                    break
                for r in picked:  # selection reads per-tenant heads in order
                    q = self.tenants[r.tenant].queue
                    assert q[0] is r, "policy must select queue heads in order"
                    q.popleft()
                    if self.tracer.enabled:
                        self.tracer.flow_point(r.rid, "dispatch",
                                               attempts=r.attempts)
                pending = self.placer.dispatch_admit(
                    [r.df for r in picked],
                    metas=[(r.tenant, r.klass) for r in picked],
                )
                self._inflight.append((picked, pending))
                while len(self._inflight) >= self.pipeline_depth:
                    admitted.extend(self._commit_oldest())
        # a later preemption in the same pump may have displaced an earlier
        # admission: hand back only handles that are still live
        return [t for t in admitted if self.placer.tickets.get(t.tid) is t]

    def _commit_oldest(self) -> list[Ticket]:
        """Commit the oldest in-flight window: block on its solve, then
        activate / reject-handle each request exactly as the synchronous
        path does."""
        picked, pending = self._inflight.popleft()
        tickets = self.placer.commit_admit(pending)
        # activate every successful admission BEFORE any reject handling:
        # a rejected request's preemption may displace a sibling from this
        # very window, and reclaim can only requeue victims it finds in
        # the registry — activating afterwards would resurrect a ticket
        # the placer already released (stale-registry leak)
        out: list[Ticket] = []
        for r, t in zip(picked, tickets):
            if t is not None:
                self._activate(r, t)
                if self.tracer.enabled:
                    self.tracer.flow_point(r.rid, "admit", tid=t.tid)
                out.append(t)
        for r, t in zip(picked, tickets):
            if t is None:
                t2 = self._handle_reject(r)
                if t2 is not None:
                    out.append(t2)
        return out

    def flush(self) -> list[Ticket]:
        """Commit every in-flight pipeline window (barrier).  Returns the
        still-live tickets it admitted.  Call before anything that needs
        the full picture of committed state — defrag does this itself."""
        admitted: list[Ticket] = []
        while self._inflight:
            admitted.extend(self._commit_oldest())
        return [t for t in admitted if self.placer.tickets.get(t.tid) is t]

    # -- release / churn ------------------------------------------------------

    def release(self, rid: int) -> None:
        req, ticket = self._deactivate(rid)
        self.placer.release(ticket)
        self.tenants[req.tenant].released += 1
        if self.tracer.enabled:
            self.tracer.flow_end(rid, "release", outcome="released")

    def _reconcile_churn(
        self, remapped: list[Ticket], dropped: list[Ticket]
    ) -> tuple[list[Ticket], list[Ticket]]:
        """After ``fail_*``: remapped tickets kept their tid (update the
        handle); dropped ones re-enter their tenant queue — displacement by
        the environment is handled exactly like preemption, and a dropped
        high-class request may immediately preempt lower-class survivors
        (which are requeued in turn).  Returns ``(alive, requeued)``:
        every ticket still active after reconciliation — in-place remaps
        (tid preserved) plus preemptive rescues (new tid) — and the old
        tickets of requests that went back to a queue, so a caller can
        attach lifecycle (departure timers) to exactly the live set."""
        for nt in remapped:
            rid = self._rid_of_tid.get(nt.tid)
            if rid is not None:
                req, _ = self.active[rid]
                self.active[rid] = (req, nt)
        # a dropped ticket with no local rid is foreign work reserved here
        # directly (a spanning segment owned by the regional broker): hand
        # it to the owner BEFORE the rescue pass, so the broker can tear
        # down the rest of the composite placement instead of leaking its
        # sibling reservations (the partial-teardown regression)
        foreign = [t for t in dropped if self._rid_of_tid.get(t.tid) is None]
        if foreign and self.on_foreign_preempt is not None:
            self.on_foreign_preempt(foreign)
        rescued: list[Ticket] = []
        requeued: list[Ticket] = []
        to_requeue: list[Request] = []
        for old in dropped:
            rid = self._rid_of_tid.get(old.tid)
            if rid is None:
                continue
            req, _ = self._deactivate(rid)
            req.attempts = 0
            self.tenants[req.tenant].preempted += 1
            t = self._try_preempt(req)
            if t is None:
                to_requeue.append(req)
                requeued.append(old)
            else:
                rescued.append(t)
        # back-to-front so the batch keeps FIFO-within-class order
        for req in reversed(to_requeue):
            self._requeue(req, front=True)
        alive = [
            t for t in remapped + rescued
            if self.placer.tickets.get(t.tid) is t  # rescue may preempt one
        ]
        return alive, requeued

    def fail_node(self, v: int) -> tuple[list[Ticket], list[Ticket]]:
        """Take node ``v`` down.  Returns ``(alive, requeued)``: the
        tickets still active after re-mapping and preemptive rescue, and
        the old tickets of displaced requests now waiting in their tenant
        queues (see :meth:`_reconcile_churn`)."""
        return self._reconcile_churn(*self.placer.fail_node(v))

    def fail_link(self, u: int, v: int) -> tuple[list[Ticket], list[Ticket]]:
        """Take the (symmetric) link down; same contract as
        :meth:`fail_node`."""
        return self._reconcile_churn(*self.placer.fail_link(u, v))

    def restore_node(self, v: int) -> None:
        self.placer.restore_node(v)

    def restore_link(self, u: int, v: int) -> None:
        self.placer.restore_link(u, v)

    # -- defragmentation ------------------------------------------------------

    def _fair_queue_heads(self, limit: Optional[int]) -> list[Request]:
        """Queued requests in defrag retry order: class-major, then the
        water-filling drain order (most under-served tenant first), FIFO
        within a tenant.  Tenant budgets stay hard caps: requests that
        would push a tenant past its budget are left queued."""
        held = self.committed_capacity()
        order: list[Request] = []
        heads = {
            t: list(st.queue) for t, st in self.tenants.items() if st.queue
        }
        virt = dict(held)
        while heads:
            t = min(
                heads,
                key=lambda t: (virt[t] / self.tenants[t].cfg.weight, t),
            )
            r = heads[t].pop(0)
            if not heads[t]:
                del heads[t]
            budget = self.tenants[t].cfg.budget
            if budget is not None and virt[t] + r.creq_sum > budget + 1e-9:
                continue
            virt[t] += r.creq_sum
            order.append(r)
        order.sort(key=lambda r: -r.klass)  # stable: keeps fair order per class
        if limit is not None:
            order = order[:limit]
        return order

    def defrag(self, *, max_extras: Optional[int] = None) -> defrag_mod.DefragResult:
        """Global re-optimization of the standing set (``service.defrag``),
        retrying queued requests on the re-packed network.  Atomic: on a
        non-improving pass nothing changes."""
        # the re-pack must see the whole standing set, and its
        # snapshot/restore would fence out any in-flight window anyway
        self.flush()
        extras = self._fair_queue_heads(max_extras)
        with self.tracer.span("defrag", track="plane", cat="defrag",
                              standing=len(self.placer.tickets),
                              extras=len(extras)):
            result = defrag_mod.defrag(
                self.placer,
                extras=[(r.df, (r.tenant, r.klass)) for r in extras],
            )
        if result.committed:
            # standing tickets were re-placed under their old tids: refresh
            # the handles the active table holds
            for rid, (req, ticket) in list(self.active.items()):
                self.active[rid] = (req, self.placer.tickets[ticket.tid])
            for i, ticket in result.readmitted:
                req = extras[i]
                self.tenants[req.tenant].queue.remove(req)
                self._activate(req, ticket)
        return result

    # -- reporting -----------------------------------------------------------

    @staticmethod
    def _consensus_impl(counts: dict) -> str:
        """Fold per-impl solve counts back into the single ``kernel_impl``
        slot: the one impl when unanimous, ``"mixed(a,b)"`` otherwise —
        never last-writer-wins (the labeled truth lives in the registry)."""
        if not counts:
            return ""
        if len(counts) == 1:
            return next(iter(counts))
        return "mixed(" + ",".join(sorted(counts)) + ")"

    def _kernel_impl_counts(self) -> dict:
        """Solves per kernel backend — the labeled carrier for the
        non-additive ``Stats.kernel_impl`` across regional merges."""
        return dict(self.placer.stats.kernel_impls)

    def _solve_counts(self) -> tuple[int, int]:
        """``(solves, solve_n_sum)`` — the additive carrier for the
        non-additive ``Stats.solve_n`` (a mean) across regional merges."""
        st = self.placer.stats
        return st.solves, st.solve_n_sum

    def engine_stats(self) -> engine.Stats:
        """The service-level story in the engine's unified Stats vocabulary
        (preemptions / defrag rounds next to solver wall-clock)."""
        st = self.placer.stats
        s = engine.Stats(method=self.placer.method)
        s.preemptions = st.preempted
        s.defrag_rounds = st.defrag_rounds
        s.solve_ms = st.solve_ms
        s.overhead_ms = st.overhead_ms
        s.conflict_resolve_ms = st.conflict_resolve_ms
        s.stale_batches = st.stale_batches
        s.batch_size = self.micro_batch
        # non-additive fields, carried through the labeled counters
        # instead of being dropped (or last-writer-won) on the fold
        s.kernel_impl = self._consensus_impl(self._kernel_impl_counts())
        solves, n_sum = self._solve_counts()
        if solves:
            s.solve_n = round(n_sum / solves)
        return s

    def metrics_registry(self) -> obs_metrics.MetricsRegistry:
        """This plane's stats surfaces as one labeled registry snapshot
        (see ``repro.obs.metrics``).  Parent planes merge per-region
        registries under a composed ``plane`` label — mirroring the
        gossip aggregation, a plane only reports what it can see."""
        reg = obs_metrics.MetricsRegistry()
        obs_metrics.absorb_online_stats(reg, self.placer.stats)
        for k, v in self.placer.res.sync_stats.items():
            if v:
                reg.inc(f"residual.{k}", float(v))
        committed = self.committed_capacity()
        for t, st in self.tenants.items():
            reg.gauge("tenant.committed", committed[t], tenant=t)
            by_klass: dict[int, int] = {}
            for r in st.queue:
                by_klass[r.klass] = by_klass.get(r.klass, 0) + 1
            for k, c in by_klass.items():
                reg.gauge("queue.depth", float(c), tenant=t, klass=str(k))
        return reg

    def warmup(self, *, max_batch: Optional[int] = None, p: int = 5) -> int:
        """Pre-compile the jit buckets admission will hit (delegates to
        :meth:`OnlinePlacer.warmup`); ``max_batch`` defaults to the
        micro-batch size."""
        return self.placer.warmup(
            max_batch=self.micro_batch if max_batch is None else max_batch,
            p=p,
        )

    def fairness_report(self) -> dict:
        """Actual standing shares vs weighted max-min targets (the shared
        :func:`policy.fairness_summary` definition)."""
        from .policy import fairness_summary

        rep = fairness_summary(
            self.committed_capacity(),
            self.queued_demand(),
            {t: st.cfg.weight for t, st in self.tenants.items()},
        )
        st = self.placer.stats
        rep["timing"] = {
            "solve_ms": st.solve_ms,
            "overhead_ms": st.overhead_ms,
            "conflict_resolve_ms": st.conflict_resolve_ms,
        }
        return rep

    def check_invariants(self) -> None:
        """Placer conservation + the control-plane ledger."""
        self.placer.check_invariants()
        ledger = self.conservation()
        assert ledger["ok"], f"ticket conservation violated: {ledger}"
        # every active rid's ticket is registered in the placer under its tid
        for rid, (req, ticket) in self.active.items():
            assert self.placer.tickets.get(ticket.tid) is ticket, (
                f"active rid {rid} holds a stale ticket"
            )
