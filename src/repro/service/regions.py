"""Decentralized regional control plane: sharded queues, gossiped shares,
and bounded two-phase commit for region-spanning dataflows.

The paper argues mapping should be computable *without* aggregating global
network state at one node.  PR 3's :class:`ControlPlane` still held a
global view; this module shards it.  ``ControlPlane(rg, regions=R)``
builds a :class:`RegionalControlPlane`:

- the network is partitioned into R balanced, BFS-grown regions
  (:func:`partition_regions`); each region owns a full centralized
  :class:`ControlPlane` over its subgraph (:func:`region_subgraph`) —
  its own tenant queues, residual view, and ``OnlinePlacer``.  Composition
  makes ``R = 1`` the *bit-identical* degenerate case: one region, the
  whole graph, no broker in the path.
- regions never read each other's live accounting.  A
  :class:`~repro.service.gossip.GossipBus` spreads versioned per-tenant
  committed-share / residual estimates on a configurable fanout & period
  (``R * fanout`` messages per round, independent of node count) and each
  region's fair-share drain runs against *local truth + gossiped
  estimates* (``ControlPlane.pump(extra_committed=...)``).  Stale
  estimates can only skew drain order — admission always validates
  against the region's own residual, so capacity is never over-committed
  (property-tested with maximally stale gossip in ``tests/test_regions``).
- a request whose endpoints live in different regions is decomposed at a
  *cut edge*: dataflow nodes ``0..s`` become a segment pinned to the cut's
  tail gateway in the source region, nodes ``s+1..p-1`` a segment pinned
  to the head gateway in the destination region, and the cut link carries
  dataflow edge ``s`` (:func:`split_dataflow`).  The broker tries at most
  ``max_cut_attempts`` (split, cut-edge) candidates — splits ordered by
  compute balance, cuts by latency — and places each candidate with a
  bounded two-phase commit: reserve the segments in their regions
  (optionally preempting strictly-lower classes under the
  ``preempt_budget`` displaced-cost cap), reserve the cut bandwidth, then
  commit — or roll every reservation back.  2PC traffic is counted in
  ``Stats.twopc_messages``; gossip in ``Stats.gossip_messages``.

The per-region subgraphs keep *global* node ids (out-of-region capacity
masked to zero, links removed): tickets, routes and failure injection use
one id space, and cross-region conservation stays checkable.  A
production plane would compact each subgraph; the subject here is the
coordination structure and its message complexity, not per-region FLOPs.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import Optional

import numpy as np

from ..core import engine
from ..core.graph import INF, DataflowPath, ResourceGraph
from ..core.online import Ticket
from .controlplane import ControlPlane, Request, TenantState
from .gossip import GossipBus
from .policy import FairSharePolicy, TenantConfig, maxmin_shares

_EPS = 1e-9


# ---------------------------------------------------------------------------
# partitioning
# ---------------------------------------------------------------------------


def partition_regions(rg: ResourceGraph, R: int, *, seed: int = 0) -> np.ndarray:
    """Balanced BFS partition: node -> region id in ``[0, R)``.

    R seed nodes are drawn (seeded rng), then regions grow breadth-first
    one node per sweep — sizes differ by at most one.  A region whose
    frontier is exhausted (disconnected remainder) grabs the
    lowest-indexed unassigned node, so every node is always assigned.
    Deterministic for a fixed (graph, R, seed).
    """
    n = rg.n
    R = max(1, min(int(R), n))
    if R == 1:
        return np.zeros(n, np.int64)
    rng = np.random.default_rng(seed)
    assign = np.full(n, -1, np.int64)
    seeds = np.sort(rng.choice(n, size=R, replace=False))
    frontiers: list[collections.deque] = []
    for r, s in enumerate(seeds):
        assign[s] = r
        frontiers.append(collections.deque(rg.neighbors(int(s))))
    unassigned = n - R
    while unassigned:
        for r in range(R):
            node = None
            while frontiers[r]:
                cand = int(frontiers[r].popleft())
                if assign[cand] < 0:
                    node = cand
                    break
            if node is None:
                rem = np.nonzero(assign < 0)[0]
                if rem.size == 0:
                    break
                node = int(rem[0])
            assign[node] = r
            frontiers[r].extend(rg.neighbors(node))
            unassigned -= 1
            if not unassigned:
                break
    return assign


def region_subgraph(rg: ResourceGraph, assign: np.ndarray, r: int) -> ResourceGraph:
    """The subgraph region ``r`` owns, in the global id space: out-of-region
    nodes keep their ids but lose all capacity and links.  With one region
    this reproduces ``rg`` exactly (the R=1 identity hinges on it)."""
    mine = assign == r
    pair = mine[:, None] & mine[None, :]
    cap = np.where(mine, rg.cap, 0.0).astype(np.float32)
    bw = np.where(pair, rg.bw, 0.0).astype(np.float32)
    lat = np.where(pair, rg.lat, INF).astype(np.float32)
    np.fill_diagonal(lat, 0.0)
    return ResourceGraph(cap, bw, lat)


def cut_edges(rg: ResourceGraph, assign: np.ndarray) -> list[tuple[int, int]]:
    """Directed physical links crossing a region boundary."""
    return [
        (u, v) for (u, v) in rg.edges() if assign[u] != assign[v]
    ]


def split_dataflow(
    df: DataflowPath, s: int, u: int, v: int
) -> tuple[DataflowPath, DataflowPath]:
    """Decompose ``df`` at dataflow edge ``s`` across the cut link (u, v):
    nodes ``0..s`` stay in the source region with node ``s`` pinned to the
    tail gateway ``u``; nodes ``s+1..p-1`` go to the destination region
    with node ``s+1`` pinned to the head gateway ``v``; the cut link
    carries ``breq[s]``."""
    seg_a = DataflowPath(
        np.asarray(df.creq[: s + 1], np.float32),
        np.asarray(df.breq[:s], np.float32),
        int(df.src), int(u),
    )
    seg_b = DataflowPath(
        np.asarray(df.creq[s + 1:], np.float32),
        np.asarray(df.breq[s + 1:], np.float32),
        int(v), int(df.dst),
    )
    return seg_a, seg_b


# ---------------------------------------------------------------------------
# spanning placements
# ---------------------------------------------------------------------------


@dataclasses.dataclass(eq=False)
class SpanningTicket:
    """Composite handle for a cross-region placement: one reserved segment
    per region plus the cut-bandwidth reservation.  ``parts`` hold tids,
    not Ticket objects — region defrag re-keys tickets under stable tids,
    so the handle survives re-optimization."""

    rid: int
    req: Request
    parts: list[tuple[int, int, DataflowPath]]  # (region, tid, segment)
    cut: tuple[int, int]
    cut_bw: float
    split: int  # dataflow edge index carried by the cut link

    @property
    def tenant(self) -> str:
        return self.req.tenant

    @property
    def klass(self) -> int:
        return self.req.klass

    @property
    def df(self) -> DataflowPath:
        return self.req.df


class RegionalControlPlane:
    """R sharded control planes + gossip + a cut-edge 2PC broker.

    Mirrors the centralized :class:`ControlPlane` surface (register_tenant
    / submit / pump / release / fail_* / restore_* / defrag /
    committed_capacity / conservation / fairness_report / engine_stats /
    check_invariants / active_ids), so call sites are plane-agnostic.
    ``pump`` returns a mix of :class:`Ticket` (in-region) and
    :class:`SpanningTicket` (cross-region) handles; ``defrag`` returns one
    :class:`~repro.service.defrag.DefragResult` per region — there is no
    global re-solve, by design.
    """

    def __init__(
        self,
        rg: ResourceGraph,
        *,
        regions: int = 2,
        policy: Optional[FairSharePolicy] = None,
        micro_batch: int = 32,
        max_attempts: int = 8,
        preempt: bool = True,
        preempt_budget: Optional[float] = None,
        method: str = "leastcost_jax",
        use_kernel: bool = False,
        fanout: int = 2,
        gossip_period: int = 1,
        max_cut_attempts: int = 4,
        seed: int = 0,
        **solve_cfg,
    ):
        self.base = rg
        self.region_of = partition_regions(rg, regions, seed=seed)
        self.R = int(self.region_of.max()) + 1
        self.policy = policy or FairSharePolicy()
        self.micro_batch = int(micro_batch)
        self.max_attempts = int(max_attempts)
        self.preempt = bool(preempt)
        self.preempt_budget = preempt_budget
        self.method = method
        self.max_cut_attempts = int(max_cut_attempts)
        self.regions = [
            ControlPlane(
                region_subgraph(rg, self.region_of, r),
                policy=self.policy,
                micro_batch=micro_batch,
                max_attempts=max_attempts,
                preempt=preempt,
                preempt_budget=preempt_budget,
                method=method,
                use_kernel=use_kernel,
                **solve_cfg,
            )
            for r in range(self.R)
        ]
        for r, cp in enumerate(self.regions):
            # an in-region preemption rescue may evict a spanning segment;
            # the broker must then tear down its sibling reservations
            cp.on_foreign_preempt = (
                lambda tickets, r=r: [
                    self._displace_span_part(r, t) for t in tickets
                ]
            )
            # a region dropping a local request terminates its lifecycle;
            # forget the broker's global-rid bookkeeping for it
            cp.on_drop = (
                lambda lreq, r=r: self._forget_local(r, lreq.rid)
            )
        self.bus = GossipBus(self.R, fanout=fanout, seed=seed + 1)
        self.gossip_period = max(1, int(gossip_period))
        self.node_up = np.ones(rg.n, bool)

        # cut-edge bandwidth ledger: owned by the broker, reserved by 2PC
        self.cut_base: dict[tuple[int, int], float] = {}
        self.cut_residual: dict[tuple[int, int], float] = {}
        self.cut_link_up: dict[tuple[int, int], bool] = {}
        self._cut_by_pair: dict[tuple[int, int], list[tuple[int, int]]] = {}
        for (u, v) in cut_edges(rg, self.region_of):
            self.cut_base[(u, v)] = float(rg.bw[u, v])
            self.cut_residual[(u, v)] = float(rg.bw[u, v])
            self.cut_link_up[(u, v)] = True
            self._cut_by_pair.setdefault(
                (int(self.region_of[u]), int(self.region_of[v])), []
            ).append((u, v))

        # spanning-request bookkeeping (the broker's ledger)
        self.span_tenants: dict[str, TenantState] = {}
        self._span_q: list[dict[str, collections.deque]] = [
            {} for _ in range(self.R)
        ]
        self._span_active: dict[int, SpanningTicket] = {}
        self._part_of: dict[tuple[int, int], int] = {}  # (region, tid) -> rid

        # global rid space over both local and spanning requests
        self._rid = itertools.count()
        self._local: dict[int, tuple[int, int]] = {}  # rid -> (region, lrid)
        self._grid_of: dict[tuple[int, int], int] = {}  # (region, lrid) -> rid
        self._pumps = 0
        self._twopc_msgs = 0
        # while a churn call (fail_node/fail_link) is reconciling, spanning
        # placements torn down by in-region rescue preemptions collect here
        # so the churn return contract covers them too
        self._churn_collector: Optional[list] = None
        self.span_stats = {
            "attempts": 0, "admitted": 0, "dropped": 0,
            "displaced": 0, "no_cut": 0,
        }

    # -- registration / submission ------------------------------------------

    def register_tenant(
        self, name: str, *, weight: float = 1.0,
        budget: Optional[float] = None,
    ) -> TenantConfig:
        if name in self.span_tenants:
            raise ValueError(f"tenant {name!r} already registered")
        cfg = TenantConfig(name, weight=weight, budget=budget)
        for cp in self.regions:
            cp.register_tenant(name, weight=weight, budget=budget)
        self.span_tenants[name] = TenantState(cfg)
        for q in self._span_q:
            q[name] = collections.deque()
        return cfg

    def submit(self, tenant: str, df: DataflowPath, *, klass: int = 0) -> int:
        """Queue a request with its *home* (source) region; a request whose
        endpoints straddle regions queues with the home region's broker
        side instead and is placed by 2PC at pump time.  Returns a global
        rid valid across regions."""
        st = self.span_tenants[tenant]  # KeyError for unregistered
        rid = next(self._rid)
        ra = int(self.region_of[df.src])
        rb = int(self.region_of[df.dst])
        if ra == rb:
            lrid = self.regions[ra].submit(tenant, df, klass=klass)
            self._local[rid] = (ra, lrid)
            self._grid_of[(ra, lrid)] = rid
        else:
            st.submitted += 1
            ControlPlane._enqueue(
                self._span_q[ra][tenant], Request(rid, tenant, df, klass=klass)
            )
        return rid

    # -- live accounting -----------------------------------------------------

    def _region_committed(self, r: int) -> dict[str, float]:
        """Region r's exact local per-tenant committed compute, from the
        placer tickets (includes spanning segments reserved there)."""
        held = {t: 0.0 for t in self.span_tenants}
        for tk in self.regions[r].placer.tickets.values():
            if tk.tenant in held:
                held[tk.tenant] += float(np.sum(tk.df.creq))
        return held

    def committed_capacity(self) -> dict[str, float]:
        held = {t: 0.0 for t in self.span_tenants}
        for r in range(self.R):
            for t, c in self._region_committed(r).items():
                held[t] += c
        return held

    def queued_demand(self) -> dict[str, float]:
        out = {t: 0.0 for t in self.span_tenants}
        for cp in self.regions:
            for t, c in cp.queued_demand().items():
                out[t] += c
        for q in self._span_q:
            for t, dq in q.items():
                out[t] += sum(r.creq_sum for r in dq)
        return out

    def active_ids(self) -> list[int]:
        """Global rids of active requests across every region + spanning."""
        out = [
            self._grid_of[(r, lrid)]
            for r, cp in enumerate(self.regions)
            for lrid in cp.active
        ]
        out += list(self._span_active)
        return sorted(out)

    def conservation(self) -> dict[str, int]:
        """The global ticket ledger: regional ledgers + the broker's
        spanning ledger.  ``ok`` iff every submitted request is in exactly
        one state *summed over regions*."""
        agg = {"submitted": 0, "queued": 0, "active": 0, "released": 0,
               "dropped": 0}
        for cp in self.regions:
            led = cp.conservation()
            for k in agg:
                agg[k] += led[k]
        agg["submitted"] += sum(
            st.submitted for st in self.span_tenants.values())
        agg["queued"] += sum(
            len(dq) for q in self._span_q for dq in q.values())
        agg["active"] += len(self._span_active)
        agg["released"] += sum(
            st.released for st in self.span_tenants.values())
        agg["dropped"] += sum(
            st.dropped for st in self.span_tenants.values())
        agg["ok"] = agg["submitted"] == (
            agg["queued"] + agg["active"] + agg["released"] + agg["dropped"]
        )
        return agg

    # -- gossip --------------------------------------------------------------

    def _publish(self, r: int) -> None:
        cp = self.regions[r]
        queued = cp.queued_demand()
        for t, dq in self._span_q[r].items():
            queued[t] = queued.get(t, 0.0) + sum(x.creq_sum for x in dq)
        residual = float(
            np.sum(np.where(cp.placer.node_up, cp.placer.cap, 0.0))
        )
        self.bus.publish(r, self._region_committed(r), queued, residual)

    # -- admission -----------------------------------------------------------

    def pump(self, *, rounds: int = 1) -> list:
        """One decentralized drain round per ``rounds``: publish + gossip
        share estimates, drain every region's queues under
        estimated-global fair shares, then place queued spanning requests
        by bounded 2PC.  Returns the still-live admitted handles
        (:class:`Ticket` for in-region, :class:`SpanningTicket` for
        cross-region)."""
        admitted: list[Ticket] = []
        spanned: list[SpanningTicket] = []
        for _ in range(int(rounds)):
            self._pumps += 1
            for r in range(self.R):
                self._publish(r)
            if self.R > 1 and self._pumps % self.gossip_period == 0:
                self.bus.tick()
            for r, cp in enumerate(self.regions):
                extra = None
                if self.R > 1:
                    # gossiped estimate of remote holdings, plus the
                    # broker-reserved spanning segments physically held in
                    # THIS region (they are placer tickets but not local
                    # control-plane requests, so the local accounting
                    # cannot see them)
                    extra = self.bus.remote_committed(r)
                    local_cp = cp.committed_capacity()
                    for t, c in self._region_committed(r).items():
                        diff = c - local_cp.get(t, 0.0)
                        if diff > _EPS:
                            extra[t] = extra.get(t, 0.0) + diff
                admitted += cp.pump(rounds=1, extra_committed=extra or None)
            spanned += self._pump_spanning()
        live = [
            t for t in admitted
            if any(cp.placer.tickets.get(t.tid) is t for cp in self.regions)
        ]
        live += [s for s in spanned if s.rid in self._span_active]
        return live

    def _pump_spanning(self) -> list[SpanningTicket]:
        if self.R <= 1:
            return []
        out: list[SpanningTicket] = []
        cfgs = {t: st.cfg for t, st in self.span_tenants.items()}
        for r in range(self.R):
            queues = self._span_q[r]
            if not any(queues.values()):
                continue
            committed = self._region_committed(r)
            for t, c in self.bus.remote_committed(r).items():
                if t in committed:
                    committed[t] += c
            picked = self.policy.select(
                cfgs, queues, committed, self.micro_batch
            )
            # pop every selected head BEFORE placing: a 2PC attempt may
            # displace another spanning request to the front of one of
            # these very queues, which must not disturb the drain order
            for req in picked:
                q = queues[req.tenant]
                assert q[0] is req, "policy must select queue heads in order"
                q.popleft()
            for req in picked:
                q = queues[req.tenant]
                self.span_stats["attempts"] += 1
                st = self._try_place_spanning(req)
                if st is not None:
                    self.span_stats["admitted"] += 1
                    self.span_tenants[req.tenant].admitted += 1
                    out.append(st)
                else:
                    req.attempts += 1
                    if req.attempts >= self.max_attempts:
                        self.span_tenants[req.tenant].dropped += 1
                        self.span_stats["dropped"] += 1
                    else:
                        ControlPlane._enqueue(q, req, front_of_class=True)
        return out

    # -- two-phase commit over cut edges -------------------------------------

    def _cut_alive(self, u: int, v: int) -> bool:
        return (
            self.cut_link_up.get((u, v), False)
            and bool(self.node_up[u]) and bool(self.node_up[v])
        )

    def _candidate_cuts(self, df: DataflowPath, ra: int, rb: int) -> list:
        """Up to ``max_cut_attempts`` (split, cut-edge) candidates: splits
        ordered by compute balance between the halves, cut edges by link
        latency; gateway pinning must stay consistent with the pinned
        endpoints, and the cut must have the bandwidth left."""
        edges = [
            e for e in self._cut_by_pair.get((ra, rb), ())
            if self._cut_alive(*e)
        ]
        if not edges:
            return []
        edges.sort(key=lambda e: float(self.base.lat[e]))
        total = float(np.sum(df.creq))
        prefix = np.cumsum(df.creq.astype(np.float64))
        splits = sorted(
            range(df.p - 1),
            key=lambda s: (abs(2.0 * float(prefix[s]) - total), s),
        )
        out = []
        for s in splits:
            need = float(df.breq[s])
            for (u, v) in edges:
                if s == 0 and u != df.src:
                    continue  # a 1-node head segment pins src == gateway
                if s == df.p - 2 and v != df.dst:
                    continue  # a 1-node tail segment pins gateway == dst
                if self.cut_residual[(u, v)] + _EPS < need:
                    continue
                out.append((s, u, v))
                if len(out) >= self.max_cut_attempts:
                    return out
        return out

    def _reserve_plain(self, r: int, seg: DataflowPath, tenant: str,
                       klass: int) -> Optional[Ticket]:
        """Phase-1 reserve of one segment in region ``r`` against its own
        residual only — freely abortable, displaces nothing."""
        return self.regions[r].placer.admit(seg, tenant=tenant, klass=klass)

    def _reserve_preempting(self, r: int, seg: DataflowPath, tenant: str,
                            klass: int) -> Optional[Ticket]:
        """Preemptive phase-1 reserve under the displaced-cost budget.

        Only called for the LAST missing reservation of a candidate — every
        sibling reservation is already held, so success here guarantees the
        commit and victims are never displaced by an admission that then
        aborts (a failed probe rolls back inside ``admit_preempting``).
        Victims owned by the region's plane re-enter its tenant queues; a
        victim that is itself a spanning segment displaces its whole
        spanning placement back to the broker queue (accounted, never
        dropped)."""
        cp = self.regions[r]
        t, victims = cp.placer.admit_preempting(
            seg, tenant=tenant, klass=klass,
            max_displaced_cost=self.preempt_budget,
        )
        if victims:
            for part in cp.preempt_reclaim(victims):
                self._displace_span_part(r, part)
        return t

    def _abort_reservation(self, r: int, ticket: Ticket) -> None:
        """Undo a phase-1 reserve: bookkeeping-only release (no released
        counter, no admitted inflation)."""
        cp = self.regions[r]
        cp.placer.release(ticket.tid, reason=None)
        cp.placer.stats.admitted -= 1  # the reserve never really served

    def _commit_spanning(self, req: Request, s: int, u: int, v: int,
                         parts: list) -> SpanningTicket:
        need = float(req.df.breq[s])
        self.cut_residual[(u, v)] -= need
        st = SpanningTicket(
            rid=req.rid, req=req, parts=parts,
            cut=(u, v), cut_bw=need, split=s,
        )
        self._span_active[req.rid] = st
        for (pr, tid, _seg) in parts:
            self._part_of[(pr, tid)] = req.rid
        return st

    def _try_place_spanning(self, req: Request) -> Optional[SpanningTicket]:
        """Bounded 2PC over the cut candidates.

        Per candidate, reservations are plain (freely abortable) except
        that the *last* missing one may escalate to budgeted preemption —
        in at most ONE region per admission, and only when every sibling
        reservation is already held, so preemption victims are displaced
        only by an admission that commits.  A candidate that cannot
        complete aborts every reservation it took; nothing standing is
        ever destroyed by a failed attempt."""
        df = req.df
        ra = int(self.region_of[df.src])
        rb = int(self.region_of[df.dst])
        candidates = self._candidate_cuts(df, ra, rb)
        if not candidates:
            self.span_stats["no_cut"] += 1
            return None
        can_preempt = self.preempt and req.klass > 0
        for (s, u, v) in candidates:
            need = float(df.breq[s])
            seg_a, seg_b = split_dataflow(df, s, u, v)
            self._twopc_msgs += 1  # prepare A
            t_a = self._reserve_plain(ra, seg_a, req.tenant, req.klass)
            if t_a is not None:
                if self.cut_residual[(u, v)] + _EPS < need:
                    self._twopc_msgs += 1  # abort A
                    self._abort_reservation(ra, t_a)
                    continue
                self._twopc_msgs += 1  # prepare B
                t_b = self._reserve_plain(rb, seg_b, req.tenant, req.klass)
                if t_b is None and can_preempt:
                    self._twopc_msgs += 1  # prepare B, preemptive retry
                    t_b = self._reserve_preempting(
                        rb, seg_b, req.tenant, req.klass)
                if t_b is None:
                    self._twopc_msgs += 2  # nack B + abort A
                    self._abort_reservation(ra, t_a)
                    continue
                self._twopc_msgs += 2  # commit A + commit B
                return self._commit_spanning(
                    req, s, u, v,
                    [(ra, t_a.tid, seg_a), (rb, t_b.tid, seg_b)])
            self._twopc_msgs += 1  # nack A
            if not can_preempt:
                continue
            # A is the blocker: hold B (plain) first, then preempt into A
            # as the final reservation of the candidate
            if self.cut_residual[(u, v)] + _EPS < need:
                continue
            self._twopc_msgs += 1  # prepare B
            t_b = self._reserve_plain(rb, seg_b, req.tenant, req.klass)
            if t_b is None:
                self._twopc_msgs += 1  # nack B
                continue
            self._twopc_msgs += 1  # prepare A, preemptive
            t_a = self._reserve_preempting(ra, seg_a, req.tenant, req.klass)
            if t_a is None:
                self._twopc_msgs += 2  # nack A + abort B
                self._abort_reservation(rb, t_b)
                continue
            self._twopc_msgs += 2  # commit A + commit B
            return self._commit_spanning(
                req, s, u, v,
                [(ra, t_a.tid, seg_a), (rb, t_b.tid, seg_b)])
        return None

    def _forget_local(self, r: int, lrid: int) -> None:
        """A region terminated (dropped) a local request: the global-rid
        maps must not grow without bound over the plane's lifetime."""
        rid = self._grid_of.pop((r, lrid), None)
        if rid is not None:
            self._local.pop(rid, None)

    def _displace_span_part(self, r: int, part: Ticket) -> None:
        """A spanning segment was preempted out of region ``r``: tear down
        the rest of its composite placement (other-region segments + the
        cut reservation) and requeue the whole request with its home
        region, front of its class band."""
        rid = self._part_of.pop((r, part.tid), None)
        if rid is None:
            return  # not a spanning segment (placer used directly)
        st = self._span_active.pop(rid)
        old_parts = [part]
        for (pr, tid, _seg) in st.parts:
            if (pr, tid) == (r, part.tid):
                continue
            self._part_of.pop((pr, tid), None)
            tk = self.regions[pr].placer.tickets.get(tid)
            if tk is not None:
                # the displacement event was already counted once by the
                # victim segment's preemption — siblings are bookkeeping
                self.regions[pr].placer.release(tid, reason=None)
                old_parts.append(tk)
        self.cut_residual[st.cut] += st.cut_bw
        self.span_stats["displaced"] += 1
        self.span_tenants[st.tenant].preempted += 1
        st.req.attempts = 0
        home = int(self.region_of[st.df.src])
        ControlPlane._enqueue(
            self._span_q[home][st.tenant], st.req, front_of_class=True
        )
        if self._churn_collector is not None:
            self._churn_collector.extend(old_parts)

    # -- release / churn ------------------------------------------------------

    def release(self, rid: int) -> None:
        st = self._span_active.get(rid)
        if st is not None:
            del self._span_active[rid]
            for (pr, tid, _seg) in st.parts:
                self._part_of.pop((pr, tid), None)
                self.regions[pr].placer.release(tid)
            self.cut_residual[st.cut] += st.cut_bw
            self.span_tenants[st.tenant].released += 1
            return
        r, lrid = self._local[rid]
        self.regions[r].release(lrid)  # raises if not active (caller bug)
        del self._local[rid]
        del self._grid_of[(r, lrid)]

    def _displace_spans(self, pred) -> list[Ticket]:
        """Tear down every active spanning placement matching ``pred`` and
        requeue its request with its home region (environment displacement
        is handled exactly like preemption: accounted, never dropped).
        Returns the old part tickets, mirroring the centralized churn
        contract."""
        old: list[Ticket] = []
        displaced: list[SpanningTicket] = []
        for rid in [
            g for g, st in self._span_active.items() if pred(st)
        ]:
            st = self._span_active.pop(rid)
            for (pr, tid, _seg) in st.parts:
                self._part_of.pop((pr, tid), None)
                tk = self.regions[pr].placer.tickets.get(tid)
                if tk is not None:
                    self.regions[pr].placer.release(tid, reason=None)
                    old.append(tk)
            self.cut_residual[st.cut] += st.cut_bw
            self.span_stats["displaced"] += 1
            self.span_tenants[st.tenant].preempted += 1
            st.req.attempts = 0
            displaced.append(st)
        # back-to-front so the batch keeps FIFO-within-class order in any
        # shared home queue
        for st in reversed(displaced):
            home = int(self.region_of[st.df.src])
            ControlPlane._enqueue(
                self._span_q[home][st.tenant], st.req, front_of_class=True
            )
        return old

    def _span_uses_node(self, st: SpanningTicket, v: int) -> bool:
        if v in st.cut:
            return True
        for (pr, tid, _seg) in st.parts:
            tk = self.regions[pr].placer.tickets.get(tid)
            if tk is not None and v in tk.mapping.route:
                return True
        return False

    def _span_uses_link(self, st: SpanningTicket, u: int, v: int) -> bool:
        for (pr, tid, _seg) in st.parts:
            tk = self.regions[pr].placer.tickets.get(tid)
            if tk is not None and (
                (u, v) in tk.edge_load or (v, u) in tk.edge_load
            ):
                return True
        return False

    def _churn_call(self, fn) -> tuple[list[Ticket], list[Ticket]]:
        """Run a region churn operation collecting any spanning placements
        its rescue preemptions displace, so the ``(alive, requeued)``
        return covers every handle the event invalidated."""
        self._churn_collector = hook_old = []
        try:
            alive, requeued = fn()
        finally:
            self._churn_collector = None
        return alive, requeued + hook_old

    def fail_node(self, v: int) -> tuple[list[Ticket], list[Ticket]]:
        """Take node ``v`` down.  Spanning placements touching it (as a
        gateway or anywhere on a segment route) are displaced back to
        their broker queues first, then the owning region re-maps its
        local tickets on the degraded subgraph.  Same ``(alive,
        requeued)`` contract as the centralized plane; ``requeued`` also
        covers spanning placements displaced by rescue preemptions during
        the re-map."""
        v = int(v)
        self.node_up[v] = False
        requeued_span = self._displace_spans(
            lambda st: self._span_uses_node(st, v)
        )
        alive, requeued = self._churn_call(
            lambda: self.regions[int(self.region_of[v])].fail_node(v)
        )
        return alive, requeued + requeued_span

    def fail_link(self, u: int, v: int) -> tuple[list[Ticket], list[Ticket]]:
        """Take a (symmetric) link down: an in-region link fails through
        the owning region; a *cut* link partitions the region pair — every
        spanning placement riding it is displaced and requeued (healed by
        ``restore_link``)."""
        u, v = int(u), int(v)
        if self.region_of[u] == self.region_of[v]:
            # spanning segments routed over the link must leave through the
            # broker (the inner remap cannot requeue a composite placement)
            requeued_span = self._displace_spans(
                lambda st: self._span_uses_link(st, u, v)
            )
            alive, requeued = self._churn_call(
                lambda: self.regions[int(self.region_of[u])].fail_link(u, v)
            )
            return alive, requeued + requeued_span
        for e in ((u, v), (v, u)):
            if e in self.cut_link_up:
                self.cut_link_up[e] = False
        requeued_span = self._displace_spans(
            lambda st: st.cut in ((u, v), (v, u))
        )
        return [], requeued_span

    def restore_node(self, v: int) -> None:
        v = int(v)
        self.node_up[v] = True
        self.regions[int(self.region_of[v])].restore_node(v)

    def restore_link(self, u: int, v: int) -> None:
        u, v = int(u), int(v)
        if self.region_of[u] == self.region_of[v]:
            self.regions[int(self.region_of[u])].restore_link(u, v)
            return
        for e in ((u, v), (v, u)):
            if e in self.cut_link_up:
                self.cut_link_up[e] = bool(np.isfinite(self.base.lat[e]))

    # -- defragmentation ------------------------------------------------------

    def defrag(self, *, max_extras: Optional[int] = None) -> list:
        """Per-region re-optimization — there is deliberately no global
        re-solve (that would be the centralized plane again).  Spanning
        segments are standing tickets with pinned gateways, so each region
        may re-pack them locally; tids (and thus spanning handles) are
        preserved.  Returns one DefragResult per region."""
        return [cp.defrag(max_extras=max_extras) for cp in self.regions]

    # -- reporting / invariants ----------------------------------------------

    def engine_stats(self) -> engine.Stats:
        s = engine.Stats(method=self.method)
        s.preemptions = sum(
            cp.placer.stats.preempted for cp in self.regions)
        s.defrag_rounds = sum(
            cp.placer.stats.defrag_rounds for cp in self.regions)
        s.solve_ms = sum(cp.placer.stats.solve_ms for cp in self.regions)
        s.batch_size = self.micro_batch
        s.rounds = self.bus.rounds
        s.gossip_messages = self.bus.messages_sent
        s.twopc_messages = self._twopc_msgs
        s.messages_sent = s.gossip_messages + s.twopc_messages
        return s

    def coordination_report(self) -> dict:
        """The decentralization story in numbers: gossip volume/staleness
        and 2PC traffic next to the spanning admission outcomes."""
        return {
            "regions": self.R,
            "fanout": self.bus.fanout,
            "gossip_period": self.gossip_period,
            "gossip_rounds": self.bus.rounds,
            "gossip_messages": self.bus.messages_sent,
            "gossip_messages_per_round": (
                self.bus.messages_sent / max(self.bus.rounds, 1)
            ),
            "max_staleness": self.bus.max_staleness(),
            "twopc_messages": self._twopc_msgs,
            "spanning": dict(self.span_stats),
            "cut_edges": len(self.cut_base),
        }

    def fairness_report(self) -> dict:
        held = self.committed_capacity()
        queued = self.queued_demand()
        total = sum(held.values())
        demands = {t: held[t] + queued[t] for t in self.span_tenants}
        weights = {
            t: st.cfg.weight for t, st in self.span_tenants.items()
        }
        target = maxmin_shares(demands, weights, total)
        deviation = {
            t: abs(held[t] - target[t]) / target[t]
            for t in self.span_tenants
            if target[t] > _EPS
        }
        return {
            "committed": held,
            "queued_demand": queued,
            "total_committed": total,
            "target_shares": target,
            "deviation": deviation,
            "max_deviation": max(deviation.values(), default=0.0),
            "coordination": self.coordination_report(),
        }

    def check_invariants(self) -> None:
        """Every region's placer + ledger invariants, the global ledger,
        cut-bandwidth conservation, and spanning-handle integrity."""
        for cp in self.regions:
            cp.check_invariants()
        led = self.conservation()
        assert led["ok"], f"global ticket conservation violated: {led}"
        reserved = {e: 0.0 for e in self.cut_base}
        for st in self._span_active.values():
            reserved[st.cut] += st.cut_bw
        for e, base_bw in self.cut_base.items():
            assert abs(self.cut_residual[e] + reserved[e] - base_bw) < 1e-6, (
                f"cut bandwidth conservation violated on {e}"
            )
            assert self.cut_residual[e] >= -1e-6, (
                f"negative cut residual on {e}"
            )
        for rid, st in self._span_active.items():
            u, v = st.cut
            assert self.region_of[u] != self.region_of[v]
            for (pr, tid, seg) in st.parts:
                tk = self.regions[pr].placer.tickets.get(tid)
                assert tk is not None and tk.df is seg, (
                    f"spanning rid {rid} holds a stale segment in region {pr}"
                )
                assert self._part_of.get((pr, tid)) == rid
