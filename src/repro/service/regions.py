"""Decentralized regional control plane: sharded queues, gossiped shares,
compacted region-local solves, and bounded two-phase commit for
region-spanning dataflows decomposed over multi-hop region chains.

The paper argues mapping should be computable *without* aggregating global
network state at one node.  PR 3's :class:`ControlPlane` still held a
global view; this module shards it.  ``ControlPlane(rg, regions=R)``
builds a :class:`RegionalControlPlane`:

- the network is partitioned into R balanced, BFS-grown regions
  (:func:`partition_regions`, or a caller-pinned ``region_of``
  assignment); each region owns a full centralized :class:`ControlPlane`
  over its **compacted** subgraph: a
  :class:`~repro.core.compact.CompactedView` remaps the region's nodes
  onto the contiguous local id space ``[0, n_r)``, so every piece of
  regional state — residual arrays, liveness masks, tickets, DP state,
  kernel tiles — is sized ``n_r``, not the global ``n``.  R regions are
  R x smaller solves, not just R x smaller mailboxes.  Composition makes
  ``R = 1`` the *bit-identical* degenerate case: the identity view
  translates by returning its inputs unchanged, so one region runs the
  centralized plane's exact objects.
- regions never read each other's live accounting.  A
  :class:`~repro.service.gossip.GossipBus` spreads versioned per-tenant
  committed-share / residual estimates on a configurable fanout & period
  (``R * fanout`` messages per round, independent of node count) and each
  region's fair-share drain runs against *local truth + gossiped
  estimates* (``ControlPlane.pump(extra_committed=...)``).  Stale
  estimates can only skew drain order — admission always validates
  against the region's own residual, so capacity is never over-committed
  (property-tested with maximally stale gossip in ``tests/test_regions``).
- a request whose endpoints live in different regions is decomposed over
  a **region chain**: the fewest-hop path from the source region to the
  destination region over the quotient graph of regions (edges = alive
  cut links), possibly through intermediate regions.  The dataflow is cut
  at one edge per hop (:func:`split_dataflow_chain`) into one
  gateway-pinned segment per region on the chain; the broker tries at
  most ``max_cut_attempts`` (splits, cut-edges) candidates — splits
  ordered by compute balance across the segments, cuts by latency — and
  places each candidate with ONE bounded two-phase commit: reserve every
  segment in its region (the single blocker may escalate to budgeted
  class preemption, only as the candidate's *last* reservation), reserve
  every cut's bandwidth, then commit — or roll every reservation back.
  A candidate costs at most ``2 * len(chain) + 2`` messages; 2PC traffic
  is counted in ``Stats.twopc_messages``, gossip in
  ``Stats.gossip_messages``.

The broker is the only holder of global node ids: regional tickets live
in their region's local id space, and every spanning reservation is
recorded as a :class:`SpanPart` — ``(region, tid, local segment,
bijection version)`` — so a handle minted under a stale view generation
is detectable.  Cross-region (cut) links belong to no region; their
bandwidth is the broker's own conservation ledger.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import itertools
import math
from typing import Optional

import numpy as np

from ..core import engine
from ..core.compact import CompactedView
from ..core.graph import INF, DataflowPath, ResourceGraph
from ..core.online import Ticket
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from .controlplane import ControlPlane, Request, TenantState
from .gossip import GossipBus
from .policy import FairSharePolicy, TenantConfig, fairness_summary

_EPS = 1e-9


# ---------------------------------------------------------------------------
# partitioning
# ---------------------------------------------------------------------------


def partition_regions(rg: ResourceGraph, R: int, *, seed: int = 0) -> np.ndarray:
    """Balanced BFS partition: node -> region id in ``[0, R)``.

    R seed nodes are drawn (seeded rng), then regions grow breadth-first
    one node per sweep — sizes differ by at most one.  A region whose
    frontier is exhausted (disconnected remainder) grabs the
    lowest-indexed unassigned node, so every node is always assigned.
    Deterministic for a fixed (graph, R, seed).  Every region is
    guaranteed non-empty (R is clamped to ``n``; each region owns its
    seed node) — an empty region raises instead of failing downstream in
    view construction.
    """
    n = rg.n
    if n == 0:
        raise ValueError("cannot partition an empty resource graph (n=0)")
    R = max(1, min(int(R), n))
    if R == 1:
        return np.zeros(n, np.int64)
    rng = np.random.default_rng(seed)
    assign = np.full(n, -1, np.int64)
    seeds = np.sort(rng.choice(n, size=R, replace=False))
    frontiers: list[collections.deque] = []
    for r, s in enumerate(seeds):
        assign[s] = r
        frontiers.append(collections.deque(rg.neighbors(int(s))))
    unassigned = n - R
    while unassigned:
        for r in range(R):
            node = None
            while frontiers[r]:
                cand = int(frontiers[r].popleft())
                if assign[cand] < 0:
                    node = cand
                    break
            if node is None:
                rem = np.nonzero(assign < 0)[0]
                if rem.size == 0:
                    break
                node = int(rem[0])
            assign[node] = r
            frontiers[r].extend(rg.neighbors(node))
            unassigned -= 1
            if not unassigned:
                break
    counts = np.bincount(assign, minlength=R)
    if counts.min() == 0:  # unreachable with seeded growth; fail loudly
        raise ValueError(
            f"partition produced an empty region (n={n}, R={R}, "
            f"sizes={counts.tolist()}); use fewer regions"
        )
    return assign


def validate_region_of(rg: ResourceGraph, region_of) -> np.ndarray:
    """Validate a caller-supplied node -> region assignment: one id per
    node, contiguous region ids ``0..R-1``, every region non-empty.
    Raises a clear ``ValueError`` instead of letting view construction
    fail downstream."""
    assign = np.asarray(region_of, np.int64)
    if assign.shape != (rg.n,):
        raise ValueError(
            f"region_of must map every node: expected shape ({rg.n},), "
            f"got {assign.shape}"
        )
    if rg.n == 0:
        raise ValueError("cannot shard an empty resource graph (n=0)")
    if assign.min() < 0:
        raise ValueError("region_of contains negative region ids")
    R = int(assign.max()) + 1
    counts = np.bincount(assign, minlength=R)
    empty = np.nonzero(counts == 0)[0]
    if empty.size:
        raise ValueError(
            f"region_of leaves region(s) {empty.tolist()} empty "
            f"(region ids must be contiguous 0..{R - 1} and every region "
            "must own at least one node); merge or renumber the regions"
        )
    return assign


def region_subgraph(rg: ResourceGraph, assign: np.ndarray, r: int) -> ResourceGraph:
    """The subgraph region ``r`` owns, in the *global* id space:
    out-of-region nodes keep their ids but lose all capacity and links.

    Superseded on the control-plane path by
    :class:`~repro.core.compact.CompactedView` (which drops foreign rows
    entirely instead of masking them, so solves run at ``n_r``); kept as
    the masking reference the compacted substrate is equivalence-tested
    against."""
    mine = assign == r
    pair = mine[:, None] & mine[None, :]
    cap = np.where(mine, rg.cap, 0.0).astype(np.float32)
    bw = np.where(pair, rg.bw, 0.0).astype(np.float32)
    lat = np.where(pair, rg.lat, INF).astype(np.float32)
    np.fill_diagonal(lat, 0.0)
    return ResourceGraph(cap, bw, lat)


def cut_edges(rg: ResourceGraph, assign: np.ndarray) -> list[tuple[int, int]]:
    """Directed physical links crossing a region boundary."""
    return [
        (u, v) for (u, v) in rg.edges() if assign[u] != assign[v]
    ]


def split_dataflow_chain(
    df: DataflowPath,
    splits,
    gates,
) -> list[DataflowPath]:
    """Decompose ``df`` along a region chain: cut at dataflow edges
    ``splits[0] <= ... <= splits[m-1]``, hop ``i`` crossing the cut link
    ``gates[i] = (u_i, v_i)``.  Segment ``i`` holds dataflow nodes
    ``splits[i-1]+1 .. splits[i]`` (sentinels -1 / p-1), pinned from the
    inbound head gateway ``v_{i-1}`` (``df.src`` for the first) to the
    outbound tail gateway ``u_i`` (``df.dst`` for the last); cut ``i``
    carries ``breq[splits[i]]``.

    Segments are pinned to the gateways through **ghost endpoints**: a
    zero-compute dataflow node at the in/out gateway, joined to the
    segment's real boundary node by an edge carrying the cut dataflow
    edge's bandwidth — so the in-region transport from wherever the
    boundary node is placed to the gateway is reserved honestly, and no
    dataflow node is forced to sit *at* a gateway.  Equal consecutive
    splits make the region between them a pure **transit** region: no
    real dataflow node, just the two ghost gateway endpoints and the one
    carried edge (a single ghost node when both gateways coincide).
    Transit is what admits a short dataflow between non-adjacent regions
    (e.g. p = 2 across a 3-region chain).  Endpoints stay in global ids —
    the broker compacts each segment into its region's local space at
    reserve time.
    """
    p = df.p
    m = len(splits)
    bounds = [-1] + list(splits) + [p - 1]
    segs = []
    for i in range(len(bounds) - 1):
        lo, hi = bounds[i] + 1, bounds[i + 1]
        if lo > hi:  # transit: carries dataflow edge splits[i-1] only
            u, v = int(gates[i - 1][1]), int(gates[i][0])
            carried = float(df.breq[splits[i - 1]])
            if u == v:
                segs.append(DataflowPath(
                    np.zeros(1, np.float32), np.zeros(0, np.float32), u, v))
            else:
                segs.append(DataflowPath(
                    np.zeros(2, np.float32),
                    np.asarray([carried], np.float32), u, v))
            continue
        creq = list(np.asarray(df.creq[lo:hi + 1], np.float64))
        breq = list(np.asarray(df.breq[lo:hi], np.float64))
        if i == 0:
            src = int(df.src)
        else:  # ghost at the inbound head gateway, carrying the cut edge
            src = int(gates[i - 1][1])
            creq = [0.0] + creq
            breq = [float(df.breq[splits[i - 1]])] + breq
        if i == m:
            dst = int(df.dst)
        else:  # ghost at the outbound tail gateway, carrying the cut edge
            dst = int(gates[i][0])
            creq = creq + [0.0]
            breq = breq + [float(df.breq[splits[i]])]
        segs.append(DataflowPath(
            np.asarray(creq, np.float32), np.asarray(breq, np.float32),
            src, dst,
        ))
    return segs


def split_dataflow(
    df: DataflowPath, s: int, u: int, v: int
) -> tuple[DataflowPath, DataflowPath]:
    """Single-cut decomposition at dataflow edge ``s`` across the cut
    link (u, v) — the chain of length 2 (see
    :func:`split_dataflow_chain`)."""
    a, b = split_dataflow_chain(df, [s], [(u, v)])
    return a, b


# ---------------------------------------------------------------------------
# spanning placements
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SpanPart:
    """One reserved segment of a spanning placement: the owning region,
    the region-local ticket id, the *local-id* segment object the
    region's ticket holds (identity-checked by the invariants), and the
    region view's bijection version at reserve time — a part minted under
    an older generation than the view's current one is a churn survivor,
    and one minted under a newer-than-current version is a bug."""

    region: int
    tid: int
    seg: DataflowPath
    version: int


@dataclasses.dataclass(eq=False)
class SpanningTicket:
    """Composite handle for a cross-region placement: one reserved
    segment per region on the chain plus one cut-bandwidth reservation
    per hop.  ``parts`` hold (region, tid) pairs, not Ticket objects —
    region defrag re-keys tickets under stable tids, so the handle
    survives re-optimization."""

    rid: int
    req: Request
    parts: list[SpanPart]  # ordered along the region chain
    cuts: list[tuple[int, int]]  # global gateway pairs, one per hop
    cut_bws: list[float]
    splits: list[int]  # dataflow edge indices carried by the cuts

    @property
    def tenant(self) -> str:
        return self.req.tenant

    @property
    def klass(self) -> int:
        return self.req.klass

    @property
    def df(self) -> DataflowPath:
        return self.req.df

    @property
    def chain(self) -> list[int]:
        """The ordered region chain this placement spans."""
        return [p.region for p in self.parts]

    # single-cut convenience (the chain-of-2 common case)
    @property
    def cut(self) -> tuple[int, int]:
        return self.cuts[0]

    @property
    def cut_bw(self) -> float:
        return self.cut_bws[0]

    @property
    def split(self) -> int:
        return self.splits[0]


class ChainBroker:
    """Cut-edge ledger + quotient-graph chain selection, shared by every
    plane that brokers spanning placements over child partitions: the flat
    :class:`RegionalControlPlane` over its regions, and the
    :class:`~repro.service.hierarchy.HierarchicalControlPlane` over its
    child planes.

    Subclasses provide ``base`` (the network in THIS plane's id space),
    ``region_of`` (node -> child index), ``node_up`` and
    ``max_cut_attempts`` before calling :meth:`_init_cut_ledger`.  The
    broker's resident state is deliberately small: the cut ledger holds
    only the *boundary* gateway ids plus the quotient graph over direct
    children — never the full membership of any child."""

    base: ResourceGraph
    region_of: np.ndarray
    node_up: np.ndarray
    max_cut_attempts: int
    chain_k: int
    congestion_weight: float
    max_cum_attempts: int

    def _init_cut_ledger(self) -> None:
        """Build the cut-edge bandwidth ledger: cut links belong to no
        child (they are outside every compacted submatrix), so this ledger
        is their only accounting, reserved/released by the plane's 2PC."""
        self.cut_base: dict[tuple[int, int], float] = {}
        self.cut_residual: dict[tuple[int, int], float] = {}
        self.cut_link_up: dict[tuple[int, int], bool] = {}
        self._cut_by_pair: dict[tuple[int, int], list[tuple[int, int]]] = {}
        self._gateways_of: dict[int, list[int]] = {}
        for (u, v) in cut_edges(self.base, self.region_of):
            self.cut_base[(u, v)] = float(self.base.bw[u, v])
            self.cut_residual[(u, v)] = float(self.base.bw[u, v])
            self.cut_link_up[(u, v)] = True
            self._cut_by_pair.setdefault(
                (int(self.region_of[u]), int(self.region_of[v])), []
            ).append((u, v))
            gws = self._gateways_of.setdefault(int(self.region_of[u]), [])
            if u not in gws:
                gws.append(u)
        for gws in self._gateways_of.values():
            gws.sort()

    def _cut_alive(self, u: int, v: int) -> bool:
        return (
            self.cut_link_up.get((u, v), False)
            and bool(self.node_up[u]) and bool(self.node_up[v])
        )

    def _quotient_adjacency(self) -> dict[int, dict[int, float]]:
        """The quotient graph of children under the currently-alive cut
        edges: ``adj[r1][r2]`` = min latency among alive (r1 -> r2) cuts."""
        adj: dict[int, dict[int, float]] = {}
        for (r1, r2), edges in self._cut_by_pair.items():
            lats = [
                float(self.base.lat[e]) for e in edges if self._cut_alive(*e)
            ]
            if lats:
                adj.setdefault(r1, {})[r2] = min(lats)
        return adj

    def _region_chain(self, ra: int, rb: int) -> Optional[list[int]]:
        """Fewest-hop child chain ``ra -> ... -> rb`` over the quotient
        graph (ties by summed min cut latency, then child ids — fully
        deterministic).  None when the quotient graph is partitioned."""
        adj = self._quotient_adjacency()
        best: dict[int, tuple[int, float]] = {ra: (0, 0.0)}
        heap: list[tuple[int, float, tuple[int, ...]]] = [(0, 0.0, (ra,))]
        while heap:
            hops, lat, path = heapq.heappop(heap)
            r = path[-1]
            if r == rb:
                return list(path)
            if (hops, lat) > best.get(r, (hops, lat)):
                continue  # stale heap entry
            for nb in sorted(adj.get(r, {})):
                if nb in path:
                    continue
                cand = (hops + 1, lat + adj[r][nb])
                if nb not in best or cand < best[nb]:
                    best[nb] = cand
                    heapq.heappush(heap, (*cand, path + (nb,)))
        return None

    # -- congestion-aware k-shortest chains -----------------------------------

    def _edge_congestion(self, e: tuple[int, int],
                         occ_view: dict[int, float]) -> float:
        """Congestion estimate for one cut edge: this broker's own ledger
        utilization of the cut, plus the gossiped occupancy of both
        gateway endpoints.  The ledger term is exact (2PC-maintained);
        the occupancy terms may be arbitrarily stale — they only ever
        rank chains, never admit over capacity."""
        base = self.cut_base[e]
        util = 1.0 - self.cut_residual[e] / base if base > 0 else 0.0
        u, v = e
        return max(0.0, util) + occ_view.get(u, 0.0) + occ_view.get(v, 0.0)

    def _edge_cost(self, e: tuple[int, int],
                   occ_view: dict[int, float]) -> float:
        """Load-aware chain metric: ``lat * (1 + w * congestion)``.  With
        ``congestion_weight == 0`` this degenerates to pure latency."""
        lat = float(self.base.lat[e])
        w = self.congestion_weight
        if w <= 0.0:
            return lat
        return lat * (1.0 + w * self._edge_congestion(e, occ_view))

    def _cost_adjacency(
        self, occ_view: dict[int, float]
    ) -> dict[int, dict[int, float]]:
        """Quotient graph under the load-aware metric: ``adj[r1][r2]`` =
        min :meth:`_edge_cost` among alive (r1 -> r2) cuts."""
        adj: dict[int, dict[int, float]] = {}
        for (r1, r2), edges in self._cut_by_pair.items():
            costs = [
                self._edge_cost(e, occ_view)
                for e in edges if self._cut_alive(*e)
            ]
            if costs:
                adj.setdefault(r1, {})[r2] = min(costs)
        return adj

    @staticmethod
    def _dijkstra_chain(adj, ra: int, rb: int, banned_nodes=(),
                        banned_edges=()) -> Optional[tuple[float, list[int]]]:
        """Deterministic least-cost loopless path ``ra -> rb`` over a cost
        adjacency (ties by hops then child ids).  ``banned_nodes`` /
        ``banned_edges`` support Yen spur searches."""
        banned_nodes = set(banned_nodes)
        banned_edges = set(banned_edges)
        best: dict[int, tuple[float, int]] = {ra: (0.0, 0)}
        heap: list[tuple[float, int, tuple[int, ...]]] = [(0.0, 0, (ra,))]
        while heap:
            cost, hops, path = heapq.heappop(heap)
            r = path[-1]
            if r == rb:
                return cost, list(path)
            if (cost, hops) > best.get(r, (cost, hops)):
                continue  # stale heap entry
            for nb in sorted(adj.get(r, {})):
                if nb in path or nb in banned_nodes or (r, nb) in banned_edges:
                    continue
                cand = (cost + adj[r][nb], hops + 1)
                if nb not in best or cand < best[nb]:
                    best[nb] = cand
                    heapq.heappush(heap, (*cand, path + (nb,)))
        return None

    def _region_chains(self, ra: int, rb: int,
                       occ_view: dict[int, float]) -> list[list[int]]:
        """Up to ``chain_k`` loopless region chains ``ra -> rb`` by Yen's
        algorithm under the load-aware edge cost, cheapest first.  Chains
        through hot gateways cost more, so a saturated fewest-hop chain
        sorts behind a longer cold bypass *before* any 2PC probes it.
        ``chain_k == 1`` planes never call this — they take the legacy
        fewest-hop :meth:`_region_chain` path unchanged."""
        adj = self._cost_adjacency(occ_view)
        first = self._dijkstra_chain(adj, ra, rb)
        if first is None:
            return []
        found: list[tuple[float, list[int]]] = [first]
        seen = {tuple(first[1])}
        frontier: list[tuple[float, int, tuple[int, ...]]] = []
        while len(found) < self.chain_k:
            _, prev = found[-1]
            for i in range(len(prev) - 1):
                root = prev[:i + 1]
                spur_bans = {
                    (p[i], p[i + 1]) for _, p in found
                    if len(p) > i + 1 and p[:i + 1] == root
                }
                spur = self._dijkstra_chain(
                    adj, root[-1], rb, banned_nodes=root[:-1],
                    banned_edges=spur_bans,
                )
                if spur is None:
                    continue
                scost, spath = spur
                rcost = sum(adj[root[j]][root[j + 1]] for j in range(i))
                path = tuple(root[:-1] + spath)
                if path not in seen:
                    seen.add(path)
                    heapq.heappush(
                        frontier, (rcost + scost, len(path) - 1, path))
            if not frontier:
                break
            cost, _, path = heapq.heappop(frontier)
            found.append((cost, list(path)))
        return [p for _, p in found]

    def _race_candidates(self, df: DataflowPath, chains: list[list[int]],
                         occ_view: dict[int, float]) -> list:
        """Round-robin interleave of ``(chain, splits, gates)`` candidates
        across the k chains, cheapest chain first, with gates per hop
        ordered by the same load-aware cost.  The total is capped at
        ``max_cut_attempts`` — racing chains never widens the 2PC probe
        budget beyond the single-chain broker's."""
        budget = self.max_cut_attempts

        def key(e):
            return (self._edge_cost(e, occ_view), float(self.base.lat[e]), e)

        per = [
            collections.deque(
                self._candidate_chains(df, ch, limit=budget, edge_key=key))
            for ch in chains
        ]
        out = []
        while len(out) < budget and any(per):
            for ch, dq in zip(chains, per):
                if dq:
                    splits, gates = dq.popleft()
                    out.append((ch, splits, gates))
                    if len(out) >= budget:
                        break
        return out

    def _requeue_or_livelock_drop(self, st: SpanningTicket) -> None:
        """Requeue a displaced spanning request at its home child — or
        drop it when its *cumulative* attempt budget is spent.  The
        per-episode ``attempts`` resets (displacement is not the
        request's fault) but ``cum_attempts`` never does: a request
        ping-ponging between a saturated chain and displacement meets
        ``max_cum_attempts`` instead of livelocking forever."""
        st.req.attempts = 0
        st.req.cum_attempts += 1
        self.span_stats["max_req_attempts"] = max(
            self.span_stats["max_req_attempts"], st.req.cum_attempts)
        if st.req.cum_attempts >= self.max_cum_attempts:
            self.span_tenants[st.tenant].dropped += 1
            self.span_stats["dropped"] += 1
            self.span_stats["livelock_dropped"] += 1
            if self.tracer.enabled:
                self.tracer.flow_end(
                    st.rid, "drop", outcome="livelock",
                    cum_attempts=st.req.cum_attempts,
                )
            if self.on_drop is not None:
                self.on_drop(st.rid)
            return
        home = int(self.region_of[st.df.src])
        ControlPlane._enqueue(
            self._span_q[home][st.tenant], st.req, front_of_class=True
        )

    def _chain_feasible(self, df: DataflowPath, splits, gates) -> bool:
        """Cut-bandwidth screen for one candidate.  Ghost gateway
        endpoints (see :func:`split_dataflow_chain`) remove every
        structural pinning constraint — whether a segment can actually
        route from its gateway is the child solve's decision."""
        for s, e in zip(splits, gates):
            if self.cut_residual[e] + _EPS < float(df.breq[s]):
                return False
        return True

    def _candidate_chains(self, df: DataflowPath, chain: list[int], *,
                          limit: Optional[int] = None,
                          edge_key=None) -> list:
        """Up to ``limit`` (default ``max_cut_attempts``) (splits,
        cut-edges) candidates for a child chain: split combinations
        (non-decreasing — repeats make transit regions) ordered by compute
        balance across the segments, cut edges per hop by ``edge_key``
        (default link latency; hop order lexicographic)."""
        limit = self.max_cut_attempts if limit is None else max(1, int(limit))
        m = len(chain) - 1
        p = df.p
        edge_lists = []
        for (r1, r2) in zip(chain[:-1], chain[1:]):
            edges = [
                e for e in self._cut_by_pair.get((r1, r2), ())
                if self._cut_alive(*e)
            ]
            if not edges:
                return []
            edges.sort(key=edge_key if edge_key is not None
                       else lambda e: float(self.base.lat[e]))
            edge_lists.append(edges)
        prefix = np.concatenate([[0.0], np.cumsum(df.creq.astype(np.float64))])
        target = float(prefix[-1]) / (m + 1)

        def balance(splits):
            bounds = (-1,) + splits + (p - 1,)
            return sum(
                abs(float(prefix[bounds[i + 1] + 1] - prefix[bounds[i] + 1])
                    - target)
                for i in range(m + 1)
            )

        # bounded search: the exact combination space C(p+m-2, m) is only
        # enumerated while it is small; long dataflows over long chains
        # restrict each cut's candidate positions to a window around its
        # balanced quantile (where balance() is minimized anyway), and a
        # hard islice cap bounds the scoring work outright.  nsmallest
        # then keeps a pool sized so even an adversarial run of
        # infeasible splits cannot starve the max_cut_attempts quota.
        positions = range(p - 1)
        if math.comb(p - 1 + m - 1, m) > 20_000:
            target_pos = {
                min(max(int(np.searchsorted(
                    prefix, float(prefix[-1]) * i / (m + 1))) + d, 0), p - 2)
                for i in range(1, m + 1)
                for d in range(-4, 5)
            }
            positions = sorted(target_pos)
        pool = max(32, 8 * self.max_cut_attempts)
        combos = heapq.nsmallest(
            pool,
            itertools.islice(
                itertools.combinations_with_replacement(positions, m),
                50_000),
            key=lambda s: (balance(s), s),
        )
        out = []
        for splits in combos:
            for gates in itertools.product(*edge_lists):
                if not self._chain_feasible(df, splits, gates):
                    continue
                out.append((splits, gates))
                if len(out) >= limit:
                    return out
        return out


class RegionalControlPlane(ChainBroker):
    """R sharded control planes + gossip + a multi-hop cut-edge 2PC broker.

    Mirrors the centralized :class:`ControlPlane` surface (register_tenant
    / submit / pump / release / fail_* / restore_* / defrag /
    committed_capacity / conservation / fairness_report / engine_stats /
    check_invariants / active_ids), so call sites are plane-agnostic.
    ``pump`` returns a mix of :class:`Ticket` (in-region; their
    mappings/routes are in the owning region's *local* id space —
    resolve the owner with :meth:`owner_region` and lift through
    ``plane.views[r]``) and :class:`SpanningTicket` (cross-region,
    global gateways) handles; ``defrag`` returns one
    :class:`~repro.service.defrag.DefragResult` per region — there is no
    global re-solve, by design.

    ``**solve_cfg`` (including the incremental-fast-path knobs
    ``cache_enabled`` / ``cache_size`` / ``max_correction_supersteps``)
    is forwarded to every per-region placer: each region keeps its own
    :class:`~repro.core.solution_cache.SolutionCache` over *view-local*
    request signatures, invalidated by its own residual version + epoch —
    no cross-region cache coherence is needed because a region only ever
    admits against its own residual truth.
    """

    def __init__(
        self,
        rg: ResourceGraph,
        *,
        regions: Optional[int] = None,
        region_of=None,
        levels: Optional[int] = None,
        branching: Optional[int] = None,
        policy: Optional[FairSharePolicy] = None,
        micro_batch: int = 32,
        max_attempts: int = 8,
        preempt: bool = True,
        preempt_budget: Optional[float] = None,
        pipeline_depth: int = 1,
        method: str = "leastcost_jax",
        use_kernel: bool = False,
        fanout: int = 2,
        gossip_period: int = 1,
        max_cut_attempts: int = 4,
        chain_k: int = 2,
        congestion_weight: float = 1.0,
        max_cum_attempts: Optional[int] = None,
        seed: int = 0,
        tracer=None,
        **solve_cfg,
    ):
        self.base = rg
        # nesting kwargs fail fast: this class IS the levels=1 plane — a
        # levels > 1 request must go through ControlPlane(levels=...) /
        # HierarchicalControlPlane, never silently build flat
        if levels is not None and int(levels) != 1:
            raise ValueError(
                f"levels={levels}: RegionalControlPlane is the flat "
                "(levels=1) plane; build a hierarchy with "
                "ControlPlane(rg, levels=...) or HierarchicalControlPlane"
            )
        if branching is not None:
            raise ValueError(
                f"branching={branching} requires a hierarchical plane "
                "(levels >= 2); the flat plane takes regions= or region_of="
            )
        if region_of is not None:
            # caller-pinned partition (e.g. a line-of-regions topology
            # whose canonical assignment the BFS grower would not find);
            # the region count comes from the assignment, and an
            # explicitly contradicting regions= fails fast
            self.region_of = validate_region_of(rg, region_of)
            detected = int(self.region_of.max()) + 1
            if regions is not None and int(regions) != detected:
                raise ValueError(
                    f"regions={regions} contradicts region_of, which "
                    f"defines {detected} regions"
                )
        else:
            self.region_of = partition_regions(
                rg, 2 if regions is None else regions, seed=seed)
        self.R = int(self.region_of.max()) + 1
        self.policy = policy or FairSharePolicy()
        self.micro_batch = int(micro_batch)
        self.max_attempts = int(max_attempts)
        self.preempt = bool(preempt)
        self.preempt_budget = preempt_budget
        self.pipeline_depth = max(1, int(pipeline_depth))
        self.method = method
        self.max_cut_attempts = int(max_cut_attempts)
        # chain_k > 1 races k-shortest region chains under the load-aware
        # cost; chain_k == 1 is the legacy single fewest-hop chain,
        # bit-identical by construction (same code path)
        self.chain_k = max(1, int(chain_k))
        self.congestion_weight = float(congestion_weight)
        # lifetime attempt budget across displacement episodes: a request
        # ping-ponging between admission and displacement resets its
        # per-episode attempts but never this one (livelock backstop)
        self.max_cum_attempts = (
            4 * self.max_attempts if max_cum_attempts is None
            else int(max_cum_attempts)
        )
        # the broker's tracer; each region gets a scoped view sharing the
        # same event buffer ("r{r}/" track prefixes, so region-local rids
        # never collide with broker-level flow ids)
        self.tracer = tracer if tracer is not None else obs_trace.NULL
        # the compacted solve substrate: one global<->local bijection per
        # region; every regional plane below is sized n_r, not n
        self.views = [
            CompactedView.from_assign(rg, self.region_of, r)
            for r in range(self.R)
        ]
        self.regions = [
            ControlPlane(
                rg,
                view=self.views[r],
                policy=self.policy,
                micro_batch=micro_batch,
                max_attempts=max_attempts,
                preempt=preempt,
                preempt_budget=preempt_budget,
                pipeline_depth=pipeline_depth,
                method=method,
                use_kernel=use_kernel,
                tracer=self.tracer.scoped(f"r{r}"),
                **solve_cfg,
            )
            for r in range(self.R)
        ]
        for r, cp in enumerate(self.regions):
            # an in-region preemption OR churn re-map may displace/drop a
            # spanning segment; the broker must then tear down its sibling
            # reservations (the region plane hands over every foreign tid)
            cp.on_foreign_preempt = (
                lambda tickets, r=r: [
                    self._displace_span_part(r, t) for t in tickets
                ]
            )
            # a region dropping a local request terminates its lifecycle;
            # forget the broker's global-rid bookkeeping for it
            cp.on_drop = (
                lambda lreq, r=r: self._forget_local(r, lreq.rid)
            )
        self.bus = GossipBus(self.R, fanout=fanout, seed=seed + 1)
        self.gossip_period = max(1, int(gossip_period))
        self.node_up = np.ones(rg.n, bool)

        # cut-edge bandwidth ledger: owned by the broker, reserved by 2PC
        # (see ChainBroker._init_cut_ledger)
        self._init_cut_ledger()

        # spanning-request bookkeeping (the broker's ledger)
        self.span_tenants: dict[str, TenantState] = {}
        self._span_q: list[dict[str, collections.deque]] = [
            {} for _ in range(self.R)
        ]
        self._span_active: dict[int, SpanningTicket] = {}
        self._part_of: dict[tuple[int, int], int] = {}  # (region, tid) -> rid
        # global rid space over both local and spanning requests
        self._rid = itertools.count()
        self._local: dict[int, tuple[int, int]] = {}  # rid -> (region, lrid)
        self._grid_of: dict[tuple[int, int], int] = {}  # (region, lrid) -> rid
        self._pumps = 0
        self._twopc_msgs = 0
        # while a churn call (fail_node/fail_link) is reconciling, spanning
        # placements torn down by in-region rescue preemptions collect here
        # so the churn return contract covers them too
        self._churn_collector: Optional[list] = None
        # reservations held by a PARENT plane's 2PC (broker_admit): their
        # lifecycle belongs to the parent — a displacement fires
        # on_broker_displace(rid) instead of requeueing locally, and they
        # are not caller-visible active requests
        self._broker_held: set[int] = set()
        self.on_broker_displace = None  # parent hook: rid -> None
        self.on_drop = None  # parent hook: plane-level rid -> None
        self.span_stats = {
            "attempts": 0, "admitted": 0, "dropped": 0,
            "displaced": 0, "no_cut": 0,
            "multi_hop": 0,  # admitted over chains of >= 3 regions
            "max_chain": 0,  # longest admitted region chain
            "broker_local": 0,  # parent-held single-region reservations
            "rerouted": 0,  # admitted via a non-fewest-hop chain
            "livelock_dropped": 0,  # dropped by the cumulative budget
            "max_req_attempts": 0,  # highest lifetime attempts on one req
        }

    # -- registration / submission ------------------------------------------

    def register_tenant(
        self, name: str, *, weight: float = 1.0,
        budget: Optional[float] = None,
    ) -> TenantConfig:
        if name in self.span_tenants:
            raise ValueError(f"tenant {name!r} already registered")
        cfg = TenantConfig(name, weight=weight, budget=budget)
        for cp in self.regions:
            cp.register_tenant(name, weight=weight, budget=budget)
        self.span_tenants[name] = TenantState(cfg)
        for q in self._span_q:
            q[name] = collections.deque()
        return cfg

    def submit(self, tenant: str, df: DataflowPath, *, klass: int = 0) -> int:
        """Queue a request with its *home* (source) region; a request whose
        endpoints straddle regions queues with the home region's broker
        side instead and is placed by 2PC at pump time.  ``df`` is in
        global ids; in-region requests are compacted into the owning
        region's local id space here, at the broker boundary.  Returns a
        global rid valid across regions."""
        st = self.span_tenants[tenant]  # KeyError for unregistered
        rid = next(self._rid)
        ra = int(self.region_of[df.src])
        rb = int(self.region_of[df.dst])
        if ra == rb:
            lrid = self.regions[ra].submit(
                tenant, self.views[ra].compact_df(df), klass=klass
            )
            self._local[rid] = (ra, lrid)
            self._grid_of[(ra, lrid)] = rid
        else:
            st.submitted += 1
            ControlPlane._enqueue(
                self._span_q[ra][tenant], Request(rid, tenant, df, klass=klass)
            )
            if self.tracer.enabled:
                self.tracer.flow_begin(
                    rid, "submit", tenant=tenant, klass=klass,
                    spanning=True, home=ra,
                )
        return rid

    # -- live accounting -----------------------------------------------------

    def _region_committed(self, r: int) -> dict[str, float]:
        """Region r's exact local per-tenant committed compute, from the
        placer tickets (includes spanning segments reserved there)."""
        held = {t: 0.0 for t in self.span_tenants}
        for tk in self.regions[r].placer.tickets.values():
            if tk.tenant in held:
                held[tk.tenant] += float(np.sum(tk.df.creq))
        return held

    def committed_capacity(self) -> dict[str, float]:
        held = {t: 0.0 for t in self.span_tenants}
        for r in range(self.R):
            for t, c in self._region_committed(r).items():
                held[t] += c
        return held

    def residual_capacity(self) -> float:
        """Summed live residual node capacity across every region (the
        scalar a parent plane publishes as this child's aggregate)."""
        return float(sum(
            np.sum(np.where(cp.placer.node_up, cp.placer.cap, 0.0))
            for cp in self.regions
        ))

    def queued_demand(self) -> dict[str, float]:
        out = {t: 0.0 for t in self.span_tenants}
        for cp in self.regions:
            for t, c in cp.queued_demand().items():
                out[t] += c
        for q in self._span_q:
            for t, dq in q.items():
                out[t] += sum(r.creq_sum for r in dq)
        return out

    def owner_region(self, ticket: Ticket) -> Optional[int]:
        """The region whose placer holds ``ticket`` (by object identity —
        tids are per-region counters and collide across regions).  Use it
        to pick the right ``plane.views[r]`` for lifting an in-region
        handle's local-id mapping/route back to global ids."""
        for r, cp in enumerate(self.regions):
            if cp.placer.tickets.get(ticket.tid) is ticket:
                return r
        return None

    def active_ids(self) -> list[int]:
        """Global rids of active requests across every region + spanning.
        Parent-held broker reservations are excluded — they are segments
        of a composite the parent plane accounts for."""
        out = [
            self._grid_of[(r, lrid)]
            for r, cp in enumerate(self.regions)
            for lrid in cp.active
        ]
        out += [rid for rid in self._span_active if rid not in self._broker_held]
        return sorted(out)

    def ticket_live(self, t) -> bool:
        """Is a handle returned by :meth:`pump` still standing?  (A later
        round — or an enclosing plane's 2PC — may have displaced it.)"""
        if self._span_active.get(getattr(t, "rid", -1)) is t:
            return True
        return any(
            cp.placer.tickets.get(getattr(t, "tid", -1)) is t
            for cp in self.regions
        )

    def conservation(self) -> dict[str, int]:
        """The global ticket ledger: regional ledgers + the broker's
        spanning ledger.  ``ok`` iff every submitted request is in exactly
        one state *summed over regions*."""
        agg = {"submitted": 0, "queued": 0, "in_flight": 0, "active": 0,
               "released": 0, "dropped": 0}
        for cp in self.regions:
            led = cp.conservation()
            for k in agg:
                agg[k] += led[k]
        agg["submitted"] += sum(
            st.submitted for st in self.span_tenants.values())
        agg["queued"] += sum(
            len(dq) for q in self._span_q for dq in q.values())
        agg["active"] += len(self._span_active)
        agg["released"] += sum(
            st.released for st in self.span_tenants.values())
        agg["dropped"] += sum(
            st.dropped for st in self.span_tenants.values())
        agg["ok"] = agg["submitted"] == (
            agg["queued"] + agg["in_flight"] + agg["active"]
            + agg["released"] + agg["dropped"]
        )
        return agg

    # -- gossip --------------------------------------------------------------

    def node_occupancy(self, v: int) -> float:
        """Compute occupancy of global node ``v`` in [0, 1] from its
        owning region's live residual (1.0 when the node is down)."""
        r = int(self.region_of[v])
        cp = self.regions[r]
        lv = int(self.views[r].to_local(v))
        if not (bool(self.node_up[v]) and bool(cp.placer.node_up[lv])):
            return 1.0
        base = float(cp.placer.base.cap[lv])
        if base <= 0.0:
            return 0.0
        return min(1.0, max(0.0, 1.0 - float(cp.placer.cap[lv]) / base))

    def _gateway_occupancy(self, r: int) -> dict[int, float]:
        """Occupancy of region ``r``'s own gateway nodes (global ids) —
        the per-cut congestion estimate it publishes into gossip."""
        return {u: self.node_occupancy(u)
                for u in self._gateways_of.get(r, ())}

    def _publish(self, r: int) -> None:
        cp = self.regions[r]
        queued = cp.queued_demand()
        for t, dq in self._span_q[r].items():
            queued[t] = queued.get(t, 0.0) + sum(x.creq_sum for x in dq)
        residual = float(
            np.sum(np.where(cp.placer.node_up, cp.placer.cap, 0.0))
        )
        self.bus.publish(r, self._region_committed(r), queued, residual,
                         congestion=self._gateway_occupancy(r))

    # -- admission -----------------------------------------------------------

    def pump(self, *, rounds: int = 1, extra_committed=None) -> list:
        """One decentralized drain round per ``rounds``: publish + gossip
        share estimates, drain every region's queues under
        estimated-global fair shares, then place queued spanning requests
        by bounded 2PC.  Returns the still-live admitted handles
        (:class:`Ticket` for in-region, :class:`SpanningTicket` for
        cross-region).

        ``extra_committed`` is a parent plane's downward-published
        estimate of per-tenant holdings *outside this plane entirely*
        (the tree-gossip downlink); it folds into every region's drain
        the same way gossiped sibling estimates do — advisory for drain
        order, never capacity."""
        admitted: list[Ticket] = []
        spanned: list[SpanningTicket] = []
        for _ in range(int(rounds)):
            self._pumps += 1
            for r in range(self.R):
                self._publish(r)
            if self.R > 1 and self._pumps % self.gossip_period == 0:
                with self.tracer.span("gossip.round", track="gossip",
                                      cat="gossip", round=self._pumps):
                    self.bus.tick()
            for r, cp in enumerate(self.regions):
                extra: dict[str, float] = dict(extra_committed or {})
                if self.R > 1:
                    # gossiped estimate of remote holdings, plus the
                    # broker-reserved spanning segments physically held in
                    # THIS region (they are placer tickets but not local
                    # control-plane requests, so the local accounting
                    # cannot see them)
                    for t, c in self.bus.remote_committed(r).items():
                        extra[t] = extra.get(t, 0.0) + c
                    local_cp = cp.committed_capacity()
                    for t, c in self._region_committed(r).items():
                        diff = c - local_cp.get(t, 0.0)
                        if diff > _EPS:
                            extra[t] = extra.get(t, 0.0) + diff
                admitted += cp.pump(rounds=1, extra_committed=extra or None)
            spanned += self._pump_spanning(extra_committed)
        live = [t for t in admitted if self.ticket_live(t)]
        live += [s for s in spanned if s.rid in self._span_active]
        return live

    def flush(self) -> list[Ticket]:
        """Commit every region's in-flight pipeline windows (barrier); see
        :meth:`ControlPlane.flush`.  The broker's spanning 2PC needs no
        flush of its own — it reserves host-side through ``placer.admit``,
        and an in-flight regional batch that loses capacity to a spanning
        reservation simply re-solves its conflicts at commit."""
        admitted: list[Ticket] = []
        for cp in self.regions:
            admitted += cp.flush()
        return [
            t for t in admitted
            if any(cp.placer.tickets.get(t.tid) is t for cp in self.regions)
        ]

    def warmup(self, *, max_batch: Optional[int] = None, p: int = 5) -> int:
        """Pre-compile each region's jit buckets (region-local ``n_r``
        shapes differ per region, so every placer warms its own)."""
        return max(
            (cp.warmup(max_batch=max_batch, p=p) for cp in self.regions),
            default=0,
        )

    def _pump_spanning(self, extra_committed=None) -> list[SpanningTicket]:
        if self.R <= 1:
            return []
        out: list[SpanningTicket] = []
        cfgs = {t: st.cfg for t, st in self.span_tenants.items()}
        for r in range(self.R):
            queues = self._span_q[r]
            if not any(queues.values()):
                continue
            committed = self._region_committed(r)
            for t, c in self.bus.remote_committed(r).items():
                if t in committed:
                    committed[t] += c
            for t, c in (extra_committed or {}).items():
                if t in committed:
                    committed[t] += c
            picked = self.policy.select(
                cfgs, queues, committed, self.micro_batch
            )
            # pop every selected head BEFORE placing: a 2PC attempt may
            # displace another spanning request to the front of one of
            # these very queues, which must not disturb the drain order
            for req in picked:
                q = queues[req.tenant]
                assert q[0] is req, "policy must select queue heads in order"
                q.popleft()
            for req in picked:
                q = queues[req.tenant]
                st = self._try_place_spanning(req)
                if st is not None:
                    self.span_tenants[req.tenant].admitted += 1
                    if self.tracer.enabled:
                        self.tracer.flow_point(
                            req.rid, "admit", chain=len(st.parts))
                    out.append(st)
                else:
                    req.attempts += 1
                    req.cum_attempts += 1
                    self.span_stats["max_req_attempts"] = max(
                        self.span_stats["max_req_attempts"], req.cum_attempts)
                    exhausted = req.attempts >= self.max_attempts
                    livelocked = req.cum_attempts >= self.max_cum_attempts
                    if exhausted or livelocked:
                        self.span_tenants[req.tenant].dropped += 1
                        self.span_stats["dropped"] += 1
                        if livelocked and not exhausted:
                            self.span_stats["livelock_dropped"] += 1
                        if self.tracer.enabled:
                            self.tracer.flow_end(
                                req.rid, "drop", outcome="dropped",
                                attempts=req.attempts,
                                cum_attempts=req.cum_attempts,
                            )
                        if self.on_drop is not None:
                            self.on_drop(req.rid)
                    else:
                        ControlPlane._enqueue(q, req, front_of_class=True)
        return out

    # -- parent-plane broker interface (hierarchical nesting) ----------------

    def broker_admit(self, tenant: str, df: DataflowPath, *,
                     klass: int = 0) -> Optional[int]:
        """Synchronous, abortable admission used by a PARENT plane's 2PC:
        place ``df`` (in THIS plane's id space) immediately — in one
        region, or spanning this plane's own regions (the recursion that
        lets a top-level segment split again at the child's cuts).

        Returns a rid releasable with :meth:`broker_release`, or None
        (nothing reserved).  The reservation is a first-class spanning
        entry in this plane's ledger, so conservation and invariants hold
        at every level; if churn or preemption inside this plane later
        displaces it, ``on_broker_displace(rid)`` fires instead of a local
        requeue — the composite belongs to the parent."""
        st = self.span_tenants[tenant]  # KeyError for unregistered
        rid = next(self._rid)
        req = Request(rid, tenant, df, klass=klass)
        ra = int(self.region_of[df.src])
        rb = int(self.region_of[df.dst])
        if ra == rb:
            t = self._reserve_plain(ra, df, tenant, klass)
            if t is None:
                return None
            self.span_stats["broker_local"] += 1
            span = SpanningTicket(
                rid=rid, req=req,
                parts=[SpanPart(ra, t.tid, t.df, self.views[ra].version)],
                cuts=[], cut_bws=[], splits=[],
            )
            self._span_active[rid] = span
            self._part_of[(ra, t.tid)] = rid
        else:
            span = self._try_place_spanning(req)
            if span is None:
                return None
        st.submitted += 1
        st.admitted += 1
        self._broker_held.add(rid)
        return rid

    def broker_release(self, rid: int) -> None:
        """Release (or phase-1 abort) a :meth:`broker_admit` reservation.
        Idempotent: releasing a reservation this plane already displaced
        (and reported via ``on_broker_displace``) is a no-op."""
        if rid not in self._broker_held:
            return
        self._broker_held.discard(rid)
        st = self._span_active.pop(rid)
        self._teardown_span(st)
        self.span_tenants[st.tenant].released += 1

    def broker_uses_node(self, rid: int, v: int) -> bool:
        """Does a broker reservation touch node ``v`` (this plane's id
        space)?  Used by the parent to scope churn displacement."""
        st = self._span_active.get(rid)
        return st is not None and self._span_uses_node(st, int(v))

    def broker_uses_link(self, rid: int, u: int, v: int) -> bool:
        st = self._span_active.get(rid)
        if st is None:
            return False
        return self._span_uses_link(st, int(u), int(v)) or any(
            c in ((int(u), int(v)), (int(v), int(u))) for c in st.cuts
        )

    # -- two-phase commit over the chain -------------------------------------

    def _reserve_plain(self, r: int, seg: DataflowPath, tenant: str,
                       klass: int) -> Optional[Ticket]:
        """Phase-1 reserve of one segment in region ``r`` against its own
        residual only — freely abortable, displaces nothing.  The segment
        (global gateway pins) is compacted into the region's local id
        space here.  A failed reserve is a 2PC probe, not a service
        rejection (the spanning outcome is accounted by the broker's
        ledger/span_stats), so the placer's rejected counter is
        reconciled — same convention as ``admit_preempting``'s probes."""
        placer = self.regions[r].placer
        t = placer.admit(
            self.views[r].compact_df(seg), tenant=tenant, klass=klass
        )
        if t is None:
            placer.stats.rejected -= 1
        return t

    def _reserve_preempting(self, r: int, seg: DataflowPath, tenant: str,
                            klass: int) -> Optional[Ticket]:
        """Preemptive phase-1 reserve under the displaced-cost budget.

        Only called for the LAST missing reservation of a candidate — every
        sibling reservation is already held, so success here guarantees the
        commit and victims are never displaced by an admission that then
        aborts (a failed probe rolls back inside ``admit_preempting``).
        Victims owned by the region's plane re-enter its tenant queues; a
        victim that is itself a spanning segment displaces its whole
        spanning placement back to the broker queue (accounted, never
        dropped)."""
        cp = self.regions[r]
        t, victims = cp.placer.admit_preempting(
            self.views[r].compact_df(seg), tenant=tenant, klass=klass,
            max_displaced_cost=self.preempt_budget,
        )
        if t is None:
            cp.placer.stats.rejected -= 1  # a probe, not a rejection
        if victims:
            for part in cp.preempt_reclaim(victims):
                self._displace_span_part(r, part)
        return t

    def _abort_reservation(self, r: int, ticket: Ticket) -> None:
        """Undo a phase-1 reserve: bookkeeping-only release (no released
        counter, no admitted inflation)."""
        cp = self.regions[r]
        cp.placer.release(ticket.tid, reason=None)
        cp.placer.stats.admitted -= 1  # the reserve never really served

    def _commit_spanning(self, req: Request, chain: list[int], splits,
                         gates, tickets: list[Ticket]) -> SpanningTicket:
        cut_bws = [float(req.df.breq[s]) for s in splits]
        for e, b in zip(gates, cut_bws):
            self.cut_residual[e] -= b
        parts = [
            SpanPart(chain[i], t.tid, t.df, self.views[chain[i]].version)
            for i, t in enumerate(tickets)
        ]
        st = SpanningTicket(
            rid=req.rid, req=req, parts=parts,
            cuts=[tuple(e) for e in gates], cut_bws=cut_bws,
            splits=list(splits),
        )
        self._span_active[req.rid] = st
        for part in parts:
            self._part_of[(part.region, part.tid)] = req.rid
        self.span_stats["admitted"] += 1
        if len(chain) >= 3:
            self.span_stats["multi_hop"] += 1
        self.span_stats["max_chain"] = max(
            self.span_stats["max_chain"], len(chain))
        return st

    def _attempt_candidate(self, req: Request, chain: list[int], splits,
                           gates, can_preempt: bool) -> Optional[SpanningTicket]:
        """One bounded 2PC over every segment of one candidate.

        Reservations are plain (freely abortable) in chain order; at most
        ONE may escalate to budgeted preemption, and only as the *last*
        reservation of the candidate while every sibling is already held —
        so preemption victims are displaced only by an admission that
        commits.  A candidate that cannot complete aborts every
        reservation it took; nothing standing is ever destroyed by a
        failed attempt.  Message cost per candidate is at most
        ``2 * len(chain) + 2`` (prepare/commit per segment, plus the
        nack + preemptive re-prepare of the single blocker).
        """
        df = req.df
        segs = split_dataflow_chain(df, splits, gates)
        held: dict[int, Ticket] = {}
        failed: list[int] = []
        tr = self.tracer
        for i, seg in enumerate(segs):
            self._twopc_msgs += 1  # prepare segment i
            with tr.span("2pc.reserve", track="2pc", cat="2pc",
                         region=chain[i]):
                t = self._reserve_plain(chain[i], seg, req.tenant, req.klass)
            if t is None:
                self._twopc_msgs += 1  # nack i
                if tr.enabled:
                    tr.flow_point(req.rid, "2pc.nack", region=chain[i])
                failed.append(i)
                if not can_preempt or len(failed) > 1:
                    break  # candidate dead: >1 blocker can't be rescued
            else:
                held[i] = t
                if tr.enabled:
                    tr.flow_point(req.rid, "2pc.reserve", region=chain[i])
        if len(failed) == 1 and can_preempt and len(held) == len(segs) - 1:
            i = failed[0]
            self._twopc_msgs += 1  # prepare i, preemptive retry (last)
            with tr.span("2pc.reserve.preempt", track="2pc", cat="2pc",
                         region=chain[i]):
                t = self._reserve_preempting(chain[i], segs[i],
                                             req.tenant, req.klass)
            if t is None:
                self._twopc_msgs += 1  # nack i
                if tr.enabled:
                    tr.flow_point(req.rid, "2pc.nack", region=chain[i],
                                  preempting=True)
            else:
                held[i] = t
                failed = []
                if tr.enabled:
                    tr.flow_point(req.rid, "2pc.reserve", region=chain[i],
                                  preempting=True)
        ok = not failed and len(held) == len(segs) and all(
            self.cut_residual[e] + _EPS >= float(df.breq[s])
            for s, e in zip(splits, gates)
        )
        if not ok:
            for i in sorted(held):
                self._twopc_msgs += 1  # abort i
                if tr.enabled:
                    tr.flow_point(req.rid, "2pc.abort", region=chain[i])
                self._abort_reservation(chain[i], held[i])
            return None
        self._twopc_msgs += len(segs)  # commit every segment
        if tr.enabled:
            tr.flow_point(req.rid, "2pc.commit", chain=len(segs))
        return self._commit_spanning(
            req, chain, splits, gates, [held[i] for i in range(len(segs))]
        )

    def _try_place_spanning(self, req: Request) -> Optional[SpanningTicket]:
        """Chain selection + bounded 2PC over the cut candidates.  This is
        the single accounting site for spanning placement attempts —
        ``span_stats["attempts"]`` counts every entry here (from the pump
        drain AND from a parent plane's ``broker_admit``), ``admitted``
        every 2PC commit, so ``attempts >= admitted`` holds by
        construction (see :meth:`check_invariants`).

        ``chain_k == 1``: the legacy single fewest-hop region chain over
        the quotient graph, with latency-ordered gate candidates —
        dataflows spanning >= 3 regions decompose into one gateway-pinned
        segment per region instead of retrying until dropped.

        ``chain_k > 1``: Yen k-shortest chains under the load-aware cost
        (the broker's own cut-ledger utilization + gossiped gateway
        occupancy), raced round-robin under the same ``max_cut_attempts``
        2PC budget — when the fewest-hop chain runs hot, a cold bypass
        chain gets probed before the request burns its whole budget."""
        df = req.df
        self.span_stats["attempts"] += 1
        ra = int(self.region_of[df.src])
        rb = int(self.region_of[df.dst])
        can_preempt = self.preempt and req.klass > 0
        if self.chain_k <= 1:
            chain = self._region_chain(ra, rb)
            if chain is None:
                self.span_stats["no_cut"] += 1
                return None
            candidates = self._candidate_chains(df, chain)
            if not candidates:
                self.span_stats["no_cut"] += 1
                return None
            for (splits, gates) in candidates:
                st = self._attempt_candidate(req, chain, splits, gates,
                                             can_preempt)
                if st is not None:
                    return st
            return None
        occ = self.bus.congestion_view(ra)
        chains = self._region_chains(ra, rb, occ)
        if not chains:
            self.span_stats["no_cut"] += 1
            return None
        raced = self._race_candidates(df, chains, occ)
        if not raced:
            self.span_stats["no_cut"] += 1
            return None
        for (chain, splits, gates) in raced:
            st = self._attempt_candidate(req, chain, splits, gates,
                                         can_preempt)
            if st is not None:
                if chain != self._region_chain(ra, rb):
                    self.span_stats["rerouted"] += 1
                return st
        return None

    def _forget_local(self, r: int, lrid: int) -> None:
        """A region terminated (dropped) a local request: the global-rid
        maps must not grow without bound over the plane's lifetime.  The
        plane-level ``on_drop`` hook chains the same cleanup upward when
        this plane is itself a child of a hierarchy."""
        rid = self._grid_of.pop((r, lrid), None)
        if rid is not None:
            self._local.pop(rid, None)
            if self.on_drop is not None:
                self.on_drop(rid)

    def _teardown_span(self, st: SpanningTicket,
                       skip: Optional[tuple[int, int]] = None) -> list[Ticket]:
        """Release every still-live reservation of a spanning placement
        (``skip`` names a (region, tid) already gone, e.g. the preempted
        part) and return the cut bandwidth.  Tolerates parts whose region
        already dropped the local ticket — the teardown must always
        complete for *all* siblings, never leak a partial reservation."""
        old: list[Ticket] = []
        for part in st.parts:
            self._part_of.pop((part.region, part.tid), None)
            if skip is not None and (part.region, part.tid) == skip:
                continue
            tk = self.regions[part.region].placer.tickets.get(part.tid)
            if tk is not None:
                self.regions[part.region].placer.release(part.tid, reason=None)
                old.append(tk)
        for e, b in zip(st.cuts, st.cut_bws):
            self.cut_residual[e] += b
        return old

    def _displace_span_part(self, r: int, part: Ticket) -> None:
        """A spanning segment was preempted (or churn-dropped) out of
        region ``r``: tear down the rest of its composite placement
        (other-region segments + the cut reservations) and requeue the
        whole request with its home region, front of its class band.
        Idempotent — a second displacement of an already-torn-down span
        is a no-op."""
        rid = self._part_of.get((r, part.tid))
        if rid is None:
            return  # not a spanning segment (or span already torn down)
        st = self._span_active.pop(rid, None)
        if st is None:
            self._part_of.pop((r, part.tid), None)
            return
        # the displacement event was already counted once by the victim
        # segment's preemption/drop — siblings are bookkeeping
        old_parts = [part] + self._teardown_span(st, skip=(r, part.tid))
        self.span_stats["displaced"] += 1
        self.span_tenants[st.tenant].preempted += 1
        if self.tracer.enabled:
            self.tracer.flow_point(rid, "displaced", region=r)
        if rid in self._broker_held:
            # a parent plane's reservation: its lifecycle here ends — the
            # parent tears down the composite and requeues at its level
            self._broker_held.discard(rid)
            self.span_tenants[st.tenant].released += 1
            if self.on_broker_displace is not None:
                self.on_broker_displace(rid)
        else:
            self._requeue_or_livelock_drop(st)
        if self._churn_collector is not None:
            self._churn_collector.extend(old_parts)

    # -- release / churn ------------------------------------------------------

    def release(self, rid: int) -> None:
        if rid in self._broker_held:
            raise KeyError(
                f"rid {rid} is a parent-held broker reservation; it is "
                "released through broker_release by the plane that holds it"
            )
        st = self._span_active.pop(rid, None)
        if st is not None:
            # guarded teardown (tolerates a sibling whose region already
            # dropped its local ticket); the request-level release is
            # accounted once, by the broker's ledger — segment releases
            # are regional bookkeeping, exactly like displacement
            self._teardown_span(st)
            self.span_tenants[st.tenant].released += 1
            if self.tracer.enabled:
                self.tracer.flow_end(rid, "release", outcome="released")
            return
        r, lrid = self._local[rid]
        self.regions[r].release(lrid)  # raises if not active (caller bug)
        del self._local[rid]
        del self._grid_of[(r, lrid)]

    def _displace_spans(self, pred) -> list[Ticket]:
        """Tear down every active spanning placement matching ``pred`` and
        requeue its request with its home region (environment displacement
        is handled exactly like preemption: accounted, never dropped).
        Returns the old part tickets, mirroring the centralized churn
        contract."""
        old: list[Ticket] = []
        displaced: list[SpanningTicket] = []
        for rid in [
            g for g, st in self._span_active.items() if pred(st)
        ]:
            st = self._span_active.pop(rid)
            old += self._teardown_span(st)
            self.span_stats["displaced"] += 1
            self.span_tenants[st.tenant].preempted += 1
            if self.tracer.enabled:
                self.tracer.flow_point(rid, "displaced", churn=True)
            if rid in self._broker_held:
                self._broker_held.discard(rid)
                self.span_tenants[st.tenant].released += 1
                if self.on_broker_displace is not None:
                    self.on_broker_displace(rid)
                continue
            displaced.append(st)
        # back-to-front so the batch keeps FIFO-within-class order in any
        # shared home queue (a cumulative-budget drop simply leaves its
        # slot empty)
        for st in reversed(displaced):
            self._requeue_or_livelock_drop(st)
        return old

    def _span_uses_node(self, st: SpanningTicket, v: int) -> bool:
        """Does the placement touch global node ``v`` — as a gateway of
        any hop, or anywhere on a segment's (region-local) route?"""
        for (u, w) in st.cuts:
            if v in (u, w):
                return True
        for part in st.parts:
            view = self.views[part.region]
            if not view.contains(v):
                continue
            lv = view.to_local(v)
            tk = self.regions[part.region].placer.tickets.get(part.tid)
            if tk is not None and lv in tk.mapping.route:
                return True
        return False

    def _span_uses_link(self, st: SpanningTicket, u: int, v: int) -> bool:
        for part in st.parts:
            view = self.views[part.region]
            if not (view.contains(u) and view.contains(v)):
                continue
            lu, lv = view.to_local(u), view.to_local(v)
            tk = self.regions[part.region].placer.tickets.get(part.tid)
            if tk is not None and (
                (lu, lv) in tk.edge_load or (lv, lu) in tk.edge_load
            ):
                return True
        return False

    def _churn_call(self, fn) -> tuple[list[Ticket], list[Ticket]]:
        """Run a region churn operation collecting any spanning placements
        its rescue preemptions displace, so the ``(alive, requeued)``
        return covers every handle the event invalidated."""
        self._churn_collector = hook_old = []
        try:
            alive, requeued = fn()
        finally:
            self._churn_collector = None
        return alive, requeued + hook_old

    def fail_node(self, v: int) -> tuple[list[Ticket], list[Ticket]]:
        """Take global node ``v`` down.  Spanning placements touching it
        (as a gateway or anywhere on a segment route) are displaced back
        to their broker queues first, then the owning region re-maps its
        local tickets on the degraded subgraph (in its local id space; the
        region's view is invalidated — bijection generation bumped).  Same
        ``(alive, requeued)`` contract as the centralized plane;
        ``requeued`` also covers spanning placements displaced by rescue
        preemptions during the re-map."""
        v = int(v)
        self.node_up[v] = False
        requeued_span = self._displace_spans(
            lambda st: self._span_uses_node(st, v)
        )
        r = int(self.region_of[v])
        self.views[r].invalidate()
        lv = int(self.views[r].to_local(v))
        alive, requeued = self._churn_call(
            lambda: self.regions[r].fail_node(lv)
        )
        return alive, requeued + requeued_span

    def fail_link(self, u: int, v: int) -> tuple[list[Ticket], list[Ticket]]:
        """Take a (symmetric) link down: an in-region link fails through
        the owning region (translated to its local id space); a *cut*
        link degrades the quotient graph — every spanning placement riding
        it is displaced and requeued, and chains re-route around it on the
        next pump (healed by ``restore_link``)."""
        u, v = int(u), int(v)
        if self.region_of[u] == self.region_of[v]:
            # spanning segments routed over the link must leave through the
            # broker (the inner remap cannot requeue a composite placement)
            requeued_span = self._displace_spans(
                lambda st: self._span_uses_link(st, u, v)
            )
            r = int(self.region_of[u])
            self.views[r].invalidate()
            lu, lv = int(self.views[r].to_local(u)), int(self.views[r].to_local(v))
            alive, requeued = self._churn_call(
                lambda: self.regions[r].fail_link(lu, lv)
            )
            return alive, requeued + requeued_span
        for e in ((u, v), (v, u)):
            if e in self.cut_link_up:
                self.cut_link_up[e] = False
        requeued_span = self._displace_spans(
            lambda st: any(c in ((u, v), (v, u)) for c in st.cuts)
        )
        return [], requeued_span

    def restore_node(self, v: int) -> None:
        v = int(v)
        self.node_up[v] = True
        r = int(self.region_of[v])
        self.views[r].invalidate()
        self.regions[r].restore_node(int(self.views[r].to_local(v)))

    def restore_link(self, u: int, v: int) -> None:
        u, v = int(u), int(v)
        if self.region_of[u] == self.region_of[v]:
            r = int(self.region_of[u])
            self.views[r].invalidate()
            self.regions[r].restore_link(
                int(self.views[r].to_local(u)), int(self.views[r].to_local(v))
            )
            return
        for e in ((u, v), (v, u)):
            if e in self.cut_link_up:
                self.cut_link_up[e] = bool(np.isfinite(self.base.lat[e]))

    # -- defragmentation ------------------------------------------------------

    def defrag(self, *, max_extras: Optional[int] = None) -> list:
        """Per-region re-optimization — there is deliberately no global
        re-solve (that would be the centralized plane again).  Spanning
        segments are standing tickets with pinned gateways, so each region
        may re-pack them locally; tids (and thus spanning handles) are
        preserved.  Returns one DefragResult per region."""
        return [cp.defrag(max_extras=max_extras) for cp in self.regions]

    # -- reporting / invariants ----------------------------------------------

    def _kernel_impl_counts(self) -> dict:
        """Per-backend solve counts summed over every region's placer."""
        out: dict[str, int] = {}
        for cp in self.regions:
            for k, v in cp._kernel_impl_counts().items():
                out[k] = out.get(k, 0) + v
        return out

    def _solve_counts(self) -> tuple[int, int]:
        solves = n_sum = 0
        for cp in self.regions:
            s, n = cp._solve_counts()
            solves += s
            n_sum += n
        return solves, n_sum

    def engine_stats(self) -> engine.Stats:
        s = engine.Stats(method=self.method)
        s.preemptions = sum(
            cp.placer.stats.preempted for cp in self.regions)
        s.defrag_rounds = sum(
            cp.placer.stats.defrag_rounds for cp in self.regions)
        s.solve_ms = sum(cp.placer.stats.solve_ms for cp in self.regions)
        s.overhead_ms = sum(
            cp.placer.stats.overhead_ms for cp in self.regions)
        s.conflict_resolve_ms = sum(
            cp.placer.stats.conflict_resolve_ms for cp in self.regions)
        s.stale_batches = sum(
            cp.placer.stats.stale_batches for cp in self.regions)
        s.batch_size = self.micro_batch
        s.rounds = self.bus.rounds
        s.gossip_messages = self.bus.messages_sent
        s.twopc_messages = self._twopc_msgs
        s.messages_sent = s.gossip_messages + s.twopc_messages
        solves, n_sum = self._solve_counts()
        if solves:
            s.solve_n = round(n_sum / solves)
        # the non-additive fields fold as labeled consensus, not a sum:
        # the mix of backends that actually ran, never a silent drop
        s.kernel_impl = ControlPlane._consensus_impl(
            self._kernel_impl_counts())
        return s

    def metrics_registry(self) -> obs_metrics.MetricsRegistry:
        """One merged registry: every region's registry labeled
        ``plane=r{r}`` (mirroring the gossip aggregation direction), plus
        the broker's own gossip / 2PC / spanning counters."""
        reg = obs_metrics.MetricsRegistry()
        for r, cp in enumerate(self.regions):
            reg.merge(cp.metrics_registry(), plane=f"r{r}")
        obs_metrics.absorb_gossip_stats(reg, self.bus.gossip_stats())
        obs_metrics.absorb_span_stats(reg, self.span_stats)
        reg.inc("twopc.messages", float(self._twopc_msgs))
        return reg

    def solve_size_report(self) -> dict:
        """The compute-locality story in numbers: the padded node
        dimension every regional DP actually ran over, next to the global
        ``n`` the masked (pre-compaction) plane would have paid."""
        per = []
        for r, cp in enumerate(self.regions):
            st = cp.placer.stats
            per.append({
                "region": r,
                "n_r": self.views[r].n_local,
                "solves": st.solves,
                "mean_solve_n": st.mean_solve_n,
            })
        solves = sum(p["solves"] for p in per)
        nsum = sum(cp.placer.stats.solve_n_sum for cp in self.regions)
        return {
            "global_n": self.base.n,
            "regions": per,
            "solves": solves,
            "mean_solve_n": (nsum / solves) if solves else 0.0,
            "max_solve_n": max(
                (p["n_r"] for p in per if p["solves"]), default=0),
            "balanced_n_r": math.ceil(self.base.n / max(self.R, 1)),
        }

    def resident_state_report(self) -> dict:
        """Max per-component resident state — the scaling metric the
        hierarchical plane is graded on.  Each region holds its
        ``n_r``-sized solve/residual state plus one gossip record per peer
        (R at steady state); the broker holds the quotient graph (R) plus
        its boundary id table — the distinct gateway node ids in the cut
        ledger.  A flat plane's broker is therefore O(boundary + R); the
        hierarchy keeps every level's boundary and peer count at
        O(branching)."""
        gateway_ids = {v for e in self.cut_base for v in e}
        comps = [{
            "component": "broker",
            "id_table": len(gateway_ids),
            "peers": self.R,
            "state": len(gateway_ids) + self.R,
        }]
        for r in range(self.R):
            comps.append({
                "component": f"region[{r}]",
                "solve_n": self.views[r].n_local,
                "peers": self.R,
                "state": self.views[r].n_local + self.R,
            })
        return {
            "components": comps,
            "max_component_state": max(c["state"] for c in comps),
        }

    def coordination_report(self) -> dict:
        """The decentralization story in numbers: gossip volume/staleness
        and 2PC traffic next to the spanning admission outcomes and the
        compacted solve sizes."""
        return {
            "regions": self.R,
            "fanout": self.bus.fanout,
            "gossip_period": self.gossip_period,
            "gossip_rounds": self.bus.rounds,
            "gossip_messages": self.bus.messages_sent,
            "gossip_messages_per_round": (
                self.bus.messages_sent / max(self.bus.rounds, 1)
            ),
            "max_staleness": self.bus.max_staleness(),
            "gossip": self.bus.gossip_stats(),
            "twopc_messages": self._twopc_msgs,
            "spanning": dict(self.span_stats),
            "cut_edges": len(self.cut_base),
            "solve_size": self.solve_size_report(),
            "resident": self.resident_state_report(),
        }

    def fairness_report(self) -> dict:
        rep = fairness_summary(
            self.committed_capacity(),
            self.queued_demand(),
            {t: st.cfg.weight for t, st in self.span_tenants.items()},
        )
        rep["coordination"] = self.coordination_report()
        rep["timing"] = {
            "solve_ms": sum(
                cp.placer.stats.solve_ms for cp in self.regions),
            "overhead_ms": sum(
                cp.placer.stats.overhead_ms for cp in self.regions),
            "conflict_resolve_ms": sum(
                cp.placer.stats.conflict_resolve_ms for cp in self.regions),
        }
        return rep

    def check_invariants(self) -> None:
        """Every region's placer + ledger invariants, the global ledger,
        cut-bandwidth conservation, spanning-handle integrity (liveness,
        chain well-formedness, bijection versions), and the write-through
        global conservation of the compacted substrate: the per-region
        local residuals + local ticket loads, lifted through the views,
        must re-assemble the base network exactly."""
        for cp in self.regions:
            cp.check_invariants()
        led = self.conservation()
        assert led["ok"], f"global ticket conservation violated: {led}"
        # span accounting: attempts/admitted are counted at exactly one
        # site each (_try_place_spanning entry / 2PC commit), so the
        # counters nest strictly — a double-count on any path breaks this
        ss = self.span_stats
        assert 0 <= ss["admitted"] <= ss["attempts"], (
            f"span accounting violated: {ss}")
        assert ss["multi_hop"] <= ss["admitted"], (
            f"span accounting violated: {ss}")
        assert ss["rerouted"] <= ss["admitted"], (
            f"span accounting violated: {ss}")
        assert ss["livelock_dropped"] <= ss["dropped"] <= ss["attempts"], (
            f"span accounting violated: {ss}")
        assert len(self._span_active) <= ss["admitted"] + ss["broker_local"], (
            f"more active spans than admissions: {ss}")
        reserved = {e: 0.0 for e in self.cut_base}
        for st in self._span_active.values():
            for e, b in zip(st.cuts, st.cut_bws):
                reserved[e] += b
        for e, base_bw in self.cut_base.items():
            assert abs(self.cut_residual[e] + reserved[e] - base_bw) < 1e-6, (
                f"cut bandwidth conservation violated on {e}"
            )
            assert self.cut_residual[e] >= -1e-6, (
                f"negative cut residual on {e}"
            )
        for rid, st in self._span_active.items():
            assert len(st.parts) == len(st.cuts) + 1, (
                f"spanning rid {rid}: chain/cut arity mismatch"
            )
            assert list(st.splits) == sorted(st.splits), (
                f"spanning rid {rid}: splits not non-decreasing"
            )
            for i, (u, v) in enumerate(st.cuts):
                assert int(self.region_of[u]) == st.parts[i].region
                assert int(self.region_of[v]) == st.parts[i + 1].region
            for part in st.parts:
                tk = self.regions[part.region].placer.tickets.get(part.tid)
                assert tk is not None and tk.df is part.seg, (
                    f"spanning rid {rid} holds a stale segment in region "
                    f"{part.region}"
                )
                assert self._part_of.get((part.region, part.tid)) == rid
                assert part.version <= self.views[part.region].version, (
                    f"spanning rid {rid}: part minted under a future "
                    "bijection version"
                )
        # write-through conservation: re-assemble the global network from
        # the compacted regional state.  Node capacity must reconstruct
        # exactly; in-region bandwidth likewise; cut bandwidth is checked
        # above (it belongs to the broker, not to any region).
        cap_res = np.zeros(self.base.n)
        cap_load = np.zeros(self.base.n)
        bw_res = np.zeros((self.base.n, self.base.n))
        bw_load = np.zeros((self.base.n, self.base.n))
        in_region = np.zeros((self.base.n, self.base.n), bool)
        for r, cp in enumerate(self.regions):
            view = self.views[r]
            cap_res += view.uncompact_node_vec(cp.placer.cap)
            bw_res += view.uncompact_link_mat(cp.placer.bw)
            in_region |= view.uncompact_link_mat(
                np.ones((view.n_local, view.n_local), bool))
            for tk in cp.placer.tickets.values():
                for gv, c in view.uncompact_node_load(tk.node_load).items():
                    cap_load[gv] += c
                for (gu, gv), b in view.uncompact_edge_load(
                        tk.edge_load).items():
                    bw_load[gu, gv] += b
        assert np.allclose(cap_res + cap_load, self.base.cap, atol=1e-4), (
            "compacted-view write-through broke node-capacity conservation"
        )
        assert np.allclose(
            (bw_res + bw_load)[in_region], self.base.bw[in_region], atol=1e-4
        ), "compacted-view write-through broke link-bandwidth conservation"
