"""Push-gossip dissemination of per-region share estimates.

The paper's central claim is that mapping can be coordinated *without*
aggregating global network state at one node.  The regional control plane
(``service.regions``) applies the same principle to the multi-tenant
fairness layer: no region ever reads another region's live accounting.
Instead each region periodically publishes a versioned :class:`ShareRecord`
— its per-tenant committed compute, queued demand, and residual capacity —
and a :class:`GossipBus` spreads the records epidemically: every round,
every region pushes its *entire current view* (its own fresh record plus
the freshest record it has heard for every other region) to ``fanout``
uniformly-random peers, and receivers keep the per-origin record with the
highest version.

Complexity: one round costs exactly ``R * fanout`` messages (each carrying
at most R small records), independent of the node count ``n`` — the
coordination traffic the centralized plane would need scales with the
global state, flooding scales with ``n^2``; gossip is the bounded-message
middle the paper argues for.  Staleness: with fanout f, a new record
reaches all R regions in O(log_{f+1} R) rounds with high probability; the
regional plane's fairness error is bounded by how much shares can drift
within that window (see ``bench_messages.run_regional`` for the measured
fanout/staleness vs fairness-deviation tradeoff).

Determinism: peer choice comes from a seeded ``numpy`` Generator, so a
fixed seed reproduces the exact dissemination schedule — the property
tests rely on this.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

import numpy as np


@dataclasses.dataclass(frozen=True)
class ShareRecord:
    """One region's published accounting snapshot.

    ``version`` is the origin's monotonic publication counter — the merge
    rule (highest version per origin wins) makes dissemination idempotent
    and order-independent, so duplicated or reordered pushes are harmless.
    """

    origin: int
    version: int
    committed: Mapping[str, float]  # tenant -> committed compute in origin
    queued: Mapping[str, float]  # tenant -> queued demand in origin
    residual_cap: float  # summed live residual node capacity
    # gateway node -> occupancy estimate in [0, 1] for the origin's own
    # gateways; remote regions fold these into chain costs so spanning
    # requests steer around hot gateways *before* probing them with a 2PC
    congestion: Mapping[int, float] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        object.__setattr__(self, "committed", dict(self.committed))
        object.__setattr__(self, "queued", dict(self.queued))
        object.__setattr__(self, "congestion", dict(self.congestion))


class GossipBus:
    """In-process simulation of the push-gossip fabric, message-accounted
    as if the regions were remote.

    ``views[r]`` is region r's current belief: origin -> freshest
    :class:`ShareRecord` it has heard.  ``publish`` refreshes a region's
    own record (bumping its version); ``tick`` runs one synchronous gossip
    round.  ``fanout`` is clamped to ``R - 1`` (a region never pushes to
    itself), so a single-region plane gossips nothing and counts nothing.
    """

    def __init__(self, n_regions: int, *, fanout: int = 2, seed: int = 0):
        self.n_regions = int(n_regions)
        self.fanout = max(0, min(int(fanout), self.n_regions - 1))
        self.rng = np.random.default_rng(seed)
        self.views: list[dict[int, ShareRecord]] = [
            {} for _ in range(self.n_regions)
        ]
        self.messages_sent = 0
        self.records_sent = 0  # ShareRecords carried across all messages
        self.payload_sent = 0  # scalar fields carried (records x record size)
        self.rounds = 0
        # window baselines for snapshot(reset=True): the lifetime counters
        # above are never rewound (CI gates read them directly)
        self._win_base = {"rounds": 0, "messages_sent": 0,
                          "records_sent": 0, "payload_sent": 0}

    # -- publication / dissemination ----------------------------------------

    def publish(
        self,
        origin: int,
        committed: Mapping[str, float],
        queued: Mapping[str, float],
        residual_cap: float,
        congestion: Mapping[int, float] | None = None,
    ) -> ShareRecord:
        """Refresh ``origin``'s own record in its own view (no messages —
        dissemination only happens in :meth:`tick`)."""
        prev = self.views[origin].get(origin)
        rec = ShareRecord(
            origin=origin,
            version=(prev.version + 1) if prev is not None else 1,
            committed=committed,
            queued=queued,
            residual_cap=float(residual_cap),
            congestion=congestion if congestion is not None else {},
        )
        self.views[origin][origin] = rec
        return rec

    @staticmethod
    def _merge(view: dict[int, ShareRecord], payload: Mapping[int, ShareRecord]) -> None:
        for origin, rec in payload.items():
            cur = view.get(origin)
            if cur is None or rec.version > cur.version:
                view[origin] = rec

    def tick(self) -> int:
        """One synchronous gossip round: every region pushes its view (as
        of the round start — a push within a round does not relay) to
        ``fanout`` distinct random peers.  Returns the messages sent this
        round (exactly ``R * fanout`` for R > 1)."""
        self.rounds += 1
        if self.fanout == 0 or self.n_regions <= 1:
            return 0
        snap = [dict(v) for v in self.views]  # round-start freeze
        sent = 0
        for r in range(self.n_regions):
            peers = [p for p in range(self.n_regions) if p != r]
            idx = self.rng.choice(
                len(peers), size=min(self.fanout, len(peers)), replace=False
            )
            nrec = len(snap[r])
            size = sum(self._record_size(rec) for rec in snap[r].values())
            for i in np.sort(idx):  # deterministic merge order
                self._merge(self.views[peers[int(i)]], snap[r])
                sent += 1
                self.records_sent += nrec
                self.payload_sent += size
        self.messages_sent += sent
        return sent

    @staticmethod
    def _record_size(rec: ShareRecord) -> int:
        """Scalar fields one :class:`ShareRecord` carries on the wire:
        origin + version + residual_cap plus one (key, value) entry per
        committed/queued tenant and per congestion gateway."""
        return 3 + len(rec.committed) + len(rec.queued) + len(rec.congestion)

    def gossip_stats(self) -> dict:
        """Message/payload accounting for the bus's lifetime.  A flat
        R-region plane carries up to R records per message (every region
        pushes its whole view); the hierarchy's win is that each level's
        bus only ever carries ``branching`` *aggregated* records."""
        rounds = max(self.rounds, 1)
        msgs = max(self.messages_sent, 1)
        return {
            "n_regions": self.n_regions,
            "fanout": self.fanout,
            "rounds": self.rounds,
            "messages_sent": self.messages_sent,
            "records_sent": self.records_sent,
            "payload_sent": self.payload_sent,
            "messages_per_round": self.messages_sent / rounds,
            "records_per_round": self.records_sent / rounds,
            "payload_per_round": self.payload_sent / rounds,
            "records_per_message": self.records_sent / msgs,
        }

    def snapshot(self, *, reset: bool = False) -> dict:
        """Windowed :meth:`gossip_stats`: counters since the last
        ``snapshot(reset=True)`` (or construction).  ``reset=True`` closes
        the window — benchmark sweeps call this per point so rounds don't
        accumulate across points.  The lifetime counters
        (``messages_sent`` etc.) are baselined, never rewound."""
        win = {k: getattr(self, k) - v for k, v in self._win_base.items()}
        rounds = max(win["rounds"], 1)
        msgs = max(win["messages_sent"], 1)
        out = {
            "n_regions": self.n_regions,
            "fanout": self.fanout,
            **win,
            "messages_per_round": win["messages_sent"] / rounds,
            "records_per_round": win["records_sent"] / rounds,
            "payload_per_round": win["payload_sent"] / rounds,
            "records_per_message": win["records_sent"] / msgs,
        }
        if reset:
            self._win_base = {k: getattr(self, k) for k in self._win_base}
        return out

    # -- estimates -----------------------------------------------------------

    def remote_committed(self, region: int) -> dict[str, float]:
        """Region ``region``'s *estimate* of per-tenant committed compute in
        every other region: the sum of the freshest gossiped records.  May
        be arbitrarily stale — callers must treat it as advisory (drain
        ordering), never as capacity."""
        out: dict[str, float] = {}
        for origin, rec in self.views[region].items():
            if origin == region:
                continue
            for t, c in rec.committed.items():
                out[t] = out.get(t, 0.0) + float(c)
        return out

    def remote_queued(self, region: int) -> dict[str, float]:
        out: dict[str, float] = {}
        for origin, rec in self.views[region].items():
            if origin == region:
                continue
            for t, c in rec.queued.items():
                out[t] = out.get(t, 0.0) + float(c)
        return out

    def congestion_view(self, region: int) -> dict[int, float]:
        """Region ``region``'s belief about gateway occupancy across the
        plane: gateway node -> occupancy in [0, 1], folded from the
        freshest record heard per origin (including its own).  Each origin
        publishes only its own gateways, so keys are disjoint in practice;
        on overlap the max (most pessimistic) estimate wins.  Like every
        gossiped quantity this is advisory: chain *ranking* may use it,
        capacity admission never does."""
        out: dict[int, float] = {}
        for rec in self.views[region].values():
            for node, occ in rec.congestion.items():
                occ = float(occ)
                if occ > out.get(node, -1.0):
                    out[node] = occ
        return out

    def staleness(self, region: int) -> dict[int, int]:
        """Version lag of ``region``'s view per remote origin: 0 = current;
        a missing record counts the origin's full version history."""
        out: dict[int, int] = {}
        for origin in range(self.n_regions):
            if origin == region:
                continue
            latest = self.views[origin].get(origin)
            if latest is None:
                out[origin] = 0  # origin never published; nothing to know
                continue
            mine = self.views[region].get(origin)
            out[origin] = latest.version - (mine.version if mine else 0)
        return out

    def max_staleness(self) -> int:
        return max(
            (lag for r in range(self.n_regions)
             for lag in self.staleness(r).values()),
            default=0,
        )
