"""Selective state-space blocks: Mamba-1 (falcon-mamba) and Mamba-2 (zamba2).

Both are implemented with an *associative scan* over time (TPU-friendly:
log-depth, no sequential HLO while-loop on the hot path), sharing the
recurrence

    h_t = a_t * h_{t-1} + b_t          (elementwise in the state)
    (a, b) ∘ (a', b') = (a*a', a'*b + b')

Mamba-1: per-channel diagonal A (d_inner, N).  Mamba-2 (SSD): scalar decay
per head; state (heads, head_p, N).  Decode carries (conv_state, ssm_state)
and costs O(1) in sequence length — this is what makes the ``long_500k``
cells runnable (DESIGN.md §6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import trunc_normal


def _assoc_scan(a, b):
    """h_t = a_t h_{t-1} + b_t along axis 1 (seq). a, b: (B, S, ...)."""

    def comb(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, ay * bx + by

    _, h = jax.lax.associative_scan(comb, (a, b), axis=1)
    return h


SCAN_CHUNK = 512  # sequence chunk for the chunked recurrence (memory knob)


def _chunked_assoc_scan(a, b, h0=None, chunk: int = SCAN_CHUNK):
    """Associative scan in sequential chunks: live memory O(B * chunk * state)
    instead of O(B * S * state) x log-depth.  h0: optional initial state
    (B, ...) folded into the first step.  Returns (h, last_state)."""
    B, S = a.shape[0], a.shape[1]
    if S <= chunk:
        if h0 is not None:
            b = b.at[:, 0].add(a[:, 0] * h0)
        h = _assoc_scan(a, b)
        return h, h[:, -1]
    nc = S // chunk
    assert S % chunk == 0, (S, chunk)
    state_shape = a.shape[:1] + a.shape[2:]
    ar = a.reshape((B, nc, chunk) + a.shape[2:]).transpose(1, 0, 2, *range(3, a.ndim + 1))
    br = b.reshape((B, nc, chunk) + b.shape[2:]).transpose(1, 0, 2, *range(3, b.ndim + 1))

    def body(h, inp):
        ac, bc = inp
        bc = bc.at[:, 0].add(ac[:, 0] * h)
        hc = _assoc_scan(ac, bc)
        return hc[:, -1], hc

    h_init = jnp.zeros(state_shape, a.dtype) if h0 is None else h0
    last, hs = jax.lax.scan(body, h_init, (ar, br))
    h = hs.transpose(1, 0, 2, *range(3, a.ndim + 1)).reshape(b.shape)
    return h, last


# ---------------------------------------------------------------------------
# Mamba-1
# ---------------------------------------------------------------------------


def init_mamba1(cfg, key):
    s = cfg.ssm
    d = cfg.d_model
    din = s.expand * d
    dtr = s.dt_rank or d // 16
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    ks = jax.random.split(key, 6)
    p = {
        "in_proj": trunc_normal(ks[0], (d, 2 * din), d ** -0.5, dt),
        "conv_w": trunc_normal(ks[1], (s.d_conv, din), 0.3, dt),
        "conv_b": jnp.zeros((din,), dt),
        "x_proj": trunc_normal(ks[2], (din, dtr + 2 * s.d_state), din ** -0.5, dt),
        "dt_proj": trunc_normal(ks[3], (dtr, din), dtr ** -0.5, dt),
        "dt_bias": jnp.asarray(
            np.log(np.expm1(np.clip(np.random.default_rng(0).uniform(1e-3, 0.1, din), 1e-4, None))),
            dt,
        ),
        "A_log": jnp.asarray(
            np.log(np.tile(np.arange(1, s.d_state + 1, dtype=np.float32), (din, 1))), jnp.float32
        ),
        "D": jnp.ones((din,), jnp.float32),
        "out_proj": trunc_normal(ks[4], (din, d), din ** -0.5, dt),
    }
    a = {
        "in_proj": ("d_model", "d_inner_x2"),
        "conv_w": ("conv", "d_inner"),
        "conv_b": ("d_inner",),
        "x_proj": ("d_inner", "ssm_proj"),
        "dt_proj": ("dt_rank", "d_inner"),
        "dt_bias": ("d_inner",),
        "A_log": ("d_inner", "ssm_state"),
        "D": ("d_inner",),
        "out_proj": ("d_inner", "d_model"),
    }
    return p, a


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv.  x: (B, S, din), w: (K, din).

    Returns (y, new_state) where state is the trailing K-1 inputs."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1) :] if K > 1 else None
    return y + b, new_state


def mamba1_block(cfg, p, x, *, state=None):
    """x: (B, S, d).  state: None (train/prefill) or dict for decode carry.

    Returns (y, new_state);  new_state only when ``state`` is provided or
    S == 1 decode usage is intended (prefill returns final state too)."""
    s = cfg.ssm
    B, S, d = x.shape
    din = s.expand * d
    dtr = s.dt_rank or d // 16

    xz = x @ p["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)
    conv_state = None if state is None else state["conv"]
    xi, new_conv = _causal_conv(xi, p["conv_w"], p["conv_b"], conv_state)
    xi = jax.nn.silu(xi)

    proj = xi @ p["x_proj"]
    dt_in, Bc, Cc = jnp.split(proj, [dtr, dtr + s.d_state], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_proj"] + p["dt_bias"]).astype(jnp.float32)
    A = -jnp.exp(p["A_log"])  # (din, N)

    xf = xi.astype(jnp.float32)
    Bf = Bc.astype(jnp.float32)
    Cf = Cc.astype(jnp.float32)
    # discretize: a = exp(dt*A), b = dt * B * x   (ZOH-ish, mamba's simplified)
    a = jnp.exp(dt[..., None] * A[None, None])  # (B, S, din, N)
    bterm = (dt * xf)[..., None] * Bf[:, :, None, :]  # (B, S, din, N)
    h0 = None if state is None else state["ssm"]  # (B, din, N)
    h, last = _chunked_assoc_scan(a, bterm, h0)
    y = jnp.einsum("bsdn,bsn->bsd", h, Cf) + p["D"] * xf
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    return out, {"conv": new_conv, "ssm": last}


def mamba1_decode(cfg, p, x, state):
    """Single-token decode, O(1): x (B, 1, d)."""
    s = cfg.ssm
    B = x.shape[0]
    dtr = s.dt_rank or cfg.d_model // 16
    xz = x[:, 0] @ p["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)
    # conv state: (B, K-1, din)
    xp = jnp.concatenate([state["conv"], xi[:, None]], axis=1)
    y = (xp * p["conv_w"][None]).sum(1) + p["conv_b"]
    new_conv = xp[:, 1:]
    xi = jax.nn.silu(y)
    proj = xi @ p["x_proj"]
    dt_in, Bc, Cc = jnp.split(proj, [dtr, dtr + s.d_state], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_proj"] + p["dt_bias"]).astype(jnp.float32)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt[..., None] * A[None])  # (B, din, N)
    b = (dt * xi.astype(jnp.float32))[..., None] * Bc.astype(jnp.float32)[:, None, :]
    h = a * state["ssm"] + b
    yv = jnp.einsum("bdn,bn->bd", h, Cc.astype(jnp.float32)) + p["D"] * xi.astype(jnp.float32)
    out = (yv.astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    return out[:, None], {"conv": new_conv, "ssm": h}


def mamba1_state_init(cfg, batch, dtype):
    s = cfg.ssm
    din = s.expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, din), dtype),
        "ssm": jnp.zeros((batch, din, s.d_state), jnp.float32),
    }


def mamba1_state_axes():
    return {
        "conv": ("cache_batch", None, "d_inner"),
        "ssm": ("cache_batch", "d_inner", None),
    }


# ---------------------------------------------------------------------------
# Mamba-2 (SSD, scalar decay per head)
# ---------------------------------------------------------------------------


def init_mamba2(cfg, key):
    s = cfg.ssm
    d = cfg.d_model
    din = s.expand * d
    nh = din // s.head_p
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    ks = jax.random.split(key, 4)
    # in_proj emits [z, x, B, C, dt] like mamba2's fused projection
    dout = 2 * din + 2 * s.d_state + nh
    p = {
        "in_proj": trunc_normal(ks[0], (d, dout), d ** -0.5, dt),
        "conv_w": trunc_normal(ks[1], (s.d_conv, din + 2 * s.d_state), 0.3, dt),
        "conv_b": jnp.zeros((din + 2 * s.d_state,), dt),
        "A_log": jnp.asarray(np.log(np.linspace(1.0, 16.0, nh)), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "norm_w": jnp.ones((din,), dt),
        "out_proj": trunc_normal(ks[2], (din, d), din ** -0.5, dt),
    }
    a = {
        "in_proj": ("d_model", "d_inner_x2"),
        "conv_w": ("conv", "d_inner"),
        "conv_b": ("d_inner",),
        "A_log": ("heads_ssm",),
        "dt_bias": ("heads_ssm",),
        "D": ("heads_ssm",),
        "norm_w": ("d_inner",),
        "out_proj": ("d_inner", "d_model"),
    }
    return p, a


def _split_m2(cfg, fused):
    s = cfg.ssm
    din = s.expand * cfg.d_model
    nh = din // s.head_p
    z, xi, Bc, Cc, dt = jnp.split(
        fused, [din, 2 * din, 2 * din + s.d_state, 2 * din + 2 * s.d_state], axis=-1
    )
    return z, xi, Bc, Cc, dt, din, nh


SSD_CHUNK = 256  # SSD chunk length (matmul-form path)
USE_SSD_CHUNKED = True  # EXPERIMENTS.md §Perf iteration A2: matmul-form SSD


def mamba2_block(cfg, p, x, *, state=None):
    """SSD with scalar-per-head decay. x: (B, S, d).

    Two paths: the naive recurrence (associative scan over the materialized
    (B,S,nh,hp,N) state tensor — the paper-faithful-baseline formulation) and
    the *chunked matmul form* (Mamba-2's SSD identity): within a chunk the
    output is a decay-masked (Q,Q) attention-like matmul, across chunks a
    tiny state scan.  The chunked form keeps the working set at
    O(B·nc·nh·Q²) and runs on the MXU — the hillclimb that removed the
    dominant memory term for zamba2 (EXPERIMENTS.md §Perf)."""
    s = cfg.ssm
    B, S, d = x.shape
    fused = x @ p["in_proj"]
    z, xi, Bc, Cc, dtr, din, nh = _split_m2(cfg, fused)
    xbc = jnp.concatenate([xi, Bc, Cc], axis=-1)
    conv_state = None if state is None else state["conv"]
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xbc = jax.nn.silu(xbc)
    xi, Bc, Cc = jnp.split(xbc, [din, din + s.d_state], axis=-1)

    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])  # (B, S, nh)
    A = -jnp.exp(p["A_log"])  # (nh,)
    # §Perf iteration A4: hidden states stay in the compute dtype end to end
    # (the fp32 materialization of (B,S,din)-sized tensors dominated HBM
    # traffic); only log-decay accumulation and the state scan are fp32.
    xh = xi.reshape(B, S, nh, s.head_p)
    h0 = None if state is None else state["ssm"]
    if USE_SSD_CHUNKED and S % SSD_CHUNK == 0 and S > SSD_CHUNK:
        y, last = _ssd_chunked(dt, A, xh, Bc, Cc, h0, SSD_CHUNK)
    else:
        xf = xh.astype(jnp.float32)
        a = jnp.exp(dt * A)  # (B, S, nh)
        bterm = (dt[..., None] * xf)[..., None] * Bc.astype(jnp.float32)[:, :, None, None, :]
        a5 = jnp.broadcast_to(a[..., None, None], bterm.shape)
        h, last = _chunked_assoc_scan(a5, bterm, h0)
        y = jnp.einsum("bshpn,bsn->bshp", h, Cc.astype(jnp.float32))
    y = (y.astype(x.dtype) + (p["D"].astype(x.dtype))[None, None, :, None]
         * xh.astype(x.dtype))
    y = y.reshape(B, S, din)
    # gated RMSNorm (mamba2)
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-5)).astype(x.dtype) * p["norm_w"]
    return y @ p["out_proj"], {"conv": new_conv, "ssm": last}


def _ssd_chunked(dt, A, xh, Bc, Cc, h0, Q):
    """Matmul-form SSD (Mamba-2 identity), per-head scalar decay.

    dt (B,S,nh), A (nh,), xh (B,S,nh,hp), Bc/Cc (B,S,N).
    Output contribution of step s<=q:  C_q^T exp(l_q - l_s) dt_s B_s x_s
    with l_t = cumsum(dt_t * A).  Intra-chunk: decay-masked (Q,Q) matmuls;
    inter-chunk: state scan with per-chunk decay.  All exponents are <= 0
    (A < 0, dt > 0): numerically safe."""
    B, S, nh = dt.shape
    hp = xh.shape[-1]
    N = Bc.shape[-1]
    nc = S // Q
    cdt = jnp.bfloat16  # §Perf iteration A3: intra-chunk math in bf16 —
    # halves the dominant activation traffic; the cross-chunk state scan and
    # all log-decay accumulation stay fp32.
    r = lambda t: t.reshape((B, nc, Q) + t.shape[2:])
    dtc, xc = r(dt), r(xh).astype(cdt)  # (B,nc,Q,nh), (B,nc,Q,nh,hp)
    Bcc, Ccc = r(Bc).astype(cdt), r(Cc).astype(cdt)  # (B,nc,Q,N)
    loga = dtc * A  # (B,nc,Q,nh), <= 0, fp32
    l = jnp.cumsum(loga, axis=2)  # inclusive cumulative log-decay

    # intra-chunk: M[q,s] = G[q,s] * exp(l_q - l_s) * dt_s for s <= q
    G = jnp.einsum("bcqn,bcsn->bcqs", Ccc, Bcc)  # (B,nc,Q,Q) bf16
    dl = l[:, :, :, None, :] - l[:, :, None, :, :]  # (B,nc,Q,Q,nh): l_q - l_s
    causal = jnp.tril(jnp.ones((Q, Q), jnp.float32))
    decay = (jnp.exp(jnp.minimum(dl, 0.0))
             * causal[None, None, :, :, None]).astype(cdt)
    M = G[..., None] * decay * dtc[:, :, None, :, :].astype(cdt)  # fold dt_s
    y_intra = jnp.einsum("bcqsh,bcshp->bcqhp", M, xc,
                         preferred_element_type=jnp.float32)

    # chunk states: S_c = sum_s exp(l_last - l_s) dt_s (x_s ⊗ B_s)
    w = (jnp.exp(l[:, :, -1:, :] - l) * dtc).astype(cdt)  # (B,nc,Q,nh)
    Sc = jnp.einsum("bcqh,bcqhp,bcqn->bchpn", w, xc, Bcc,
                    preferred_element_type=jnp.float32)  # (B,nc,nh,hp,N)
    chunk_decay = jnp.exp(l[:, :, -1, :])  # (B,nc,nh)

    def carry_fn(h, inp):
        dec, sc = inp  # (B,nh), (B,nh,hp,N)
        h_new = dec[..., None, None] * h + sc
        return h_new, h  # emit the state *entering* the chunk

    h_init = jnp.zeros((B, nh, hp, N), jnp.float32) if h0 is None else h0
    last, h_prev = jax.lax.scan(
        carry_fn, h_init,
        (chunk_decay.transpose(1, 0, 2), Sc.transpose(1, 0, 2, 3, 4)),
    )
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)  # (B,nc,nh,hp,N)

    # inter-chunk: y_q += exp(l_q) * C_q^T h_prev
    y_inter = jnp.einsum("bcqn,bchpn->bcqhp", Ccc,
                         h_prev.astype(cdt),
                         preferred_element_type=jnp.float32) * \
        jnp.exp(l)[..., None]
    y = (y_intra + y_inter).astype(cdt).reshape(B, S, nh, hp)
    return y, last


def mamba2_decode(cfg, p, x, state):
    s = cfg.ssm
    B = x.shape[0]
    fused = x[:, 0] @ p["in_proj"]
    z, xi, Bc, Cc, dtr, din, nh = _split_m2(cfg, fused[:, None])
    z, xi, Bc, Cc, dtr = z[:, 0], xi[:, 0], Bc[:, 0], Cc[:, 0], dtr[:, 0]
    xbc = jnp.concatenate([xi, Bc, Cc], axis=-1)
    xp = jnp.concatenate([state["conv"], xbc[:, None]], axis=1)
    y = (xp * p["conv_w"][None]).sum(1) + p["conv_b"]
    new_conv = xp[:, 1:]
    xbc = jax.nn.silu(y)
    xi, Bc, Cc = jnp.split(xbc, [din, din + s.d_state], axis=-1)
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])  # (B, nh)
    A = -jnp.exp(p["A_log"])
    xh = xi.reshape(B, nh, s.head_p).astype(jnp.float32)
    a = jnp.exp(dt * A)[..., None, None]  # (B, nh, 1, 1)
    b = (dt[..., None] * xh)[..., None] * Bc.astype(jnp.float32)[:, None, None, :]
    h = a * state["ssm"] + b
    yv = jnp.einsum("bhpn,bn->bhp", h, Cc.astype(jnp.float32))
    yv = yv + p["D"][None, :, None] * xh
    y = yv.reshape(B, din).astype(x.dtype) * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-5)).astype(x.dtype) * p["norm_w"]
    return (y @ p["out_proj"])[:, None], {"conv": new_conv, "ssm": h}


def mamba2_state_init(cfg, batch, dtype):
    s = cfg.ssm
    din = s.expand * cfg.d_model
    nh = din // s.head_p
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, din + 2 * s.d_state), dtype),
        "ssm": jnp.zeros((batch, nh, s.head_p, s.d_state), jnp.float32),
    }


def mamba2_state_axes():
    return {
        "conv": ("cache_batch", None, "d_inner"),
        "ssm": ("cache_batch", "heads_ssm", None, None),
    }
