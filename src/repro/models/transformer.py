"""Decoder-only LM assembly for dense / MoE / SSM / hybrid / VLM families.

Layers are *scanned* (stacked parameters + ``jax.lax.scan``) so the HLO stays
O(1) in depth — essential for the 512-device dry-run compile times — and the
scanned block is ``jax.checkpoint``-ed (full remat of the block, saving only
the carried activation per layer).  Heterogeneous leading layers (DeepSeekMoE
dense-first) sit outside the scan.

The zamba2 hybrid applies one *shared* transformer block (own cache per call
site, shared weights) every ``cfg.attn_every`` mamba blocks, via ``lax.cond``
inside the scan.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .common import apply_norm, dtype_of, make_norm_params, softmax_cross_entropy, trunc_normal
from .config import ModelConfig
from .mlp import init_mlp, mlp

Pytree = Any


# -- per-family block init ----------------------------------------------------


def _init_block(cfg: ModelConfig, key, kind: str, d_ff_dense: int | None = None):
    ks = jax.random.split(key, 4)
    if kind in ("dense", "moe_dense"):
        n1, na1 = make_norm_params(cfg, dtype_of(cfg.dtype))
        ap, aa = attn_mod.init_attention(cfg, ks[0])
        n2, na2 = make_norm_params(cfg, dtype_of(cfg.dtype))
        mp, ma = init_mlp(cfg, ks[1], d_ff=d_ff_dense)
        return (
            {"ln1": n1, "attn": ap, "ln2": n2, "mlp": mp},
            {"ln1": na1, "attn": aa, "ln2": na2, "mlp": ma},
        )
    if kind == "moe":
        n1, na1 = make_norm_params(cfg, dtype_of(cfg.dtype))
        ap, aa = attn_mod.init_attention(cfg, ks[0])
        n2, na2 = make_norm_params(cfg, dtype_of(cfg.dtype))
        mp, ma = moe_mod.init_moe(cfg, ks[1])
        return (
            {"ln1": n1, "attn": ap, "ln2": n2, "moe": mp},
            {"ln1": na1, "attn": aa, "ln2": na2, "moe": ma},
        )
    if kind == "ssm":
        n1, na1 = make_norm_params(cfg, dtype_of(cfg.dtype))
        init = ssm_mod.init_mamba1 if cfg.ssm.version == 1 else ssm_mod.init_mamba2
        sp, sa = init(cfg, ks[0])
        return {"ln": n1, "ssm": sp}, {"ln": na1, "ssm": sa}
    raise ValueError(kind)


def _stack_init(cfg, key, n, kind):
    keys = jax.random.split(key, n)
    ps, axs = [], None
    for i in range(n):
        p, a = _init_block(cfg, keys[i], kind)
        ps.append(p)
        axs = a
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *ps)
    axes = jax.tree.map(lambda t: ("layers",) + t, axs, is_leaf=lambda x: isinstance(x, tuple))
    return stacked, axes


def init_lm(cfg: ModelConfig, key):
    dt = dtype_of(cfg.dtype)
    ks = jax.random.split(key, 8)
    params: dict = {}
    axes: dict = {}
    params["embed"] = trunc_normal(ks[0], (cfg.vocab, cfg.d_model), 0.02, dt)
    axes["embed"] = ("vocab", "d_model")

    fam = cfg.family
    if fam in ("dense", "vlm"):
        params["blocks"], axes["blocks"] = _stack_init(cfg, ks[1], cfg.n_layers, "dense")
    elif fam == "moe":
        nd = cfg.moe.first_dense_layers
        if nd:
            ps, aas = [], None
            dkeys = jax.random.split(ks[2], nd)
            for i in range(nd):
                p, a = _init_block(cfg, dkeys[i], "moe_dense",
                                   d_ff_dense=cfg.moe.d_ff_dense or cfg.d_ff)
                ps.append(p)
                aas = a
            params["dense_blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *ps)
            axes["dense_blocks"] = jax.tree.map(
                lambda t: ("layers",) + t, aas, is_leaf=lambda x: isinstance(x, tuple)
            )
        params["blocks"], axes["blocks"] = _stack_init(
            cfg, ks[1], cfg.n_layers - nd, "moe"
        )
    elif fam == "ssm":
        params["blocks"], axes["blocks"] = _stack_init(cfg, ks[1], cfg.n_layers, "ssm")
    elif fam == "hybrid":
        params["blocks"], axes["blocks"] = _stack_init(cfg, ks[1], cfg.n_layers, "ssm")
        sp, sa = _init_block(cfg, ks[3], "dense")
        params["shared_attn"] = sp
        axes["shared_attn"] = sa
    else:
        raise ValueError(fam)

    params["final_norm"], axes["final_norm"] = make_norm_params(cfg, dt)
    if not cfg.tie_embeddings:
        params["lm_head"] = trunc_normal(ks[4], (cfg.d_model, cfg.vocab),
                                         cfg.d_model ** -0.5, dt)
        axes["lm_head"] = ("d_model", "vocab")
    return params, axes


# -- forward passes -----------------------------------------------------------


def _dense_block_fwd(cfg, bp, x, positions, q_chunk, kv_chunk,
                     q_spec=None, kv_spec=None):
    h, _ = attn_mod.attention(cfg, bp["attn"], apply_norm(cfg, bp["ln1"], x),
                              positions, q_chunk=q_chunk, kv_chunk=kv_chunk,
                              q_spec=q_spec, kv_spec=kv_spec)
    x = x + h
    key = "mlp" if "mlp" in bp else "moe"
    if key == "mlp":
        return x + mlp(cfg, bp["mlp"], apply_norm(cfg, bp["ln2"], x)), 0.0
    out, aux = moe_mod.moe_block(cfg, bp["moe"], apply_norm(cfg, bp["ln2"], x))
    return x + out, aux


def _ssm_block_fwd(cfg, bp, x, state=None):
    fwd = ssm_mod.mamba1_block if cfg.ssm.version == 1 else ssm_mod.mamba2_block
    out, new_state = fwd(cfg, bp["ssm"], apply_norm(cfg, bp["ln"], x), state=state)
    return x + out, new_state


def hybrid_attn_layers(cfg) -> int:
    """Number of shared-attention call sites in the zamba2-style hybrid."""
    return (cfg.n_layers + cfg.attn_every - 1) // cfg.attn_every


def _hybrid_split(cfg):
    """Grouped-scan decomposition: n_layers = nG * attn_every + tail.

    Each group is [mamba, shared_attn, mamba x (attn_every-1)]; the tail is
    [mamba, shared_attn, mamba x (tail-1)] when tail > 0.  Equivalent to
    "attn after every attn_every-th mamba block" but with *no* lax.cond in
    the scan body — static call sites make the HLO cost/roofline exact and
    avoid branch overhead (DESIGN.md §5)."""
    nG = cfg.n_layers // cfg.attn_every
    tail = cfg.n_layers % cfg.attn_every
    return nG, tail


def _tree_idx(tree, i):
    return jax.tree.map(lambda t: t[i], tree)


def lm_forward(cfg: ModelConfig, params, tokens, *, patch_embeds=None,
               q_chunk=512, kv_chunk=1024, logits_mode="all", remat=True,
               q_spec=None, kv_spec=None):
    """tokens: (B, S) int32.  VLM: patch_embeds (B, n_img, d) prepended.

    logits_mode: 'all' (training) | 'last' (prefill) | 'none' (returns hidden).
    Returns (logits_or_hidden, aux_loss)."""
    x = params["embed"][tokens]
    if patch_embeds is not None:
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        if "dense_blocks" in params:
            def dense_body(x, bp):
                y, _ = _dense_block_fwd(cfg, bp, x, positions, q_chunk,
                                        kv_chunk, q_spec, kv_spec)
                return y, 0.0
            body0 = jax.checkpoint(dense_body) if remat else dense_body
            x, _ = jax.lax.scan(body0, x, params["dense_blocks"])

        def body(x, bp):
            y, aux = _dense_block_fwd(cfg, bp, x, positions, q_chunk,
                                      kv_chunk, q_spec, kv_spec)
            return y, aux

        bodyr = jax.checkpoint(body) if remat else body
        x, auxs = jax.lax.scan(bodyr, x, params["blocks"])
        aux = jnp.sum(auxs)
    elif fam == "ssm":
        def body(x, bp):
            y, _ = _ssm_block_fwd(cfg, bp, x)
            return y, 0.0

        bodyr = jax.checkpoint(body) if remat else body
        x, _ = jax.lax.scan(bodyr, x, params["blocks"])
        aux = 0.0
    elif fam == "hybrid":
        shared = params["shared_attn"]
        nG, tail = _hybrid_split(cfg)
        E = cfg.attn_every

        def run_group(x, gp, n_mamba):
            x, _ = _ssm_block_fwd(cfg, _tree_idx(gp, 0), x)
            x, _ = _dense_block_fwd(cfg, shared, x, positions, q_chunk, kv_chunk)
            if n_mamba > 1:
                def inner(x, bp):
                    y, _ = _ssm_block_fwd(cfg, bp, x)
                    return y, 0.0
                x, _ = jax.lax.scan(
                    inner, x, jax.tree.map(lambda t: t[1:n_mamba], gp)
                )
            return x

        head = jax.tree.map(
            lambda t: t[: nG * E].reshape((nG, E) + t.shape[1:]), params["blocks"]
        )

        def body(x, gp):
            return run_group(x, gp, E), 0.0

        bodyr = jax.checkpoint(body) if remat else body
        x, _ = jax.lax.scan(bodyr, x, head)
        if tail:
            tail_p = jax.tree.map(lambda t: t[nG * E :], params["blocks"])
            x = run_group(x, tail_p, tail)
        aux = 0.0
    else:
        raise ValueError(fam)

    x = apply_norm(cfg, params["final_norm"], x)
    if logits_mode == "none":
        return x, aux
    if logits_mode == "last":
        x = x[:, -1:]
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head, aux


def lm_loss(cfg, params, batch, **kw):
    logits, aux = lm_forward(
        cfg, params, batch["tokens"], patch_embeds=batch.get("patch_embeds"), **kw
    )
    n_img = 0 if batch.get("patch_embeds") is None else batch["patch_embeds"].shape[1]
    if n_img:
        logits = logits[:, n_img:]
    mask = batch.get("loss_mask")
    return softmax_cross_entropy(logits, batch["labels"], mask) + aux


# -- serving ------------------------------------------------------------------


def init_lm_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    """Decode cache pytree + logical axes, per family."""
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        L = cfg.n_layers
        c = attn_mod.init_cache(cfg, batch, max_len, dtype)
        cache = {"attn": jax.tree.map(lambda t: jnp.broadcast_to(t[None], (L,) + t.shape), c)}
        axes = {"attn": jax.tree.map(lambda t: ("layers",) + t, attn_mod.cache_axes(),
                                     is_leaf=lambda x: isinstance(x, tuple))}
        return cache, axes
    if fam == "ssm":
        L = cfg.n_layers
        s = ssm_mod.mamba1_state_init(cfg, batch, dtype) if cfg.ssm.version == 1 \
            else ssm_mod.mamba2_state_init(cfg, batch, dtype)
        sa = ssm_mod.mamba1_state_axes() if cfg.ssm.version == 1 else ssm_mod.mamba2_state_axes()
        cache = {"ssm": jax.tree.map(lambda t: jnp.broadcast_to(t[None], (L,) + t.shape), s)}
        axes = {"ssm": jax.tree.map(lambda t: ("layers",) + t, sa,
                                    is_leaf=lambda x: isinstance(x, tuple))}
        return cache, axes
    if fam == "hybrid":
        L, A = cfg.n_layers, hybrid_attn_layers(cfg)
        s = ssm_mod.mamba2_state_init(cfg, batch, dtype)
        sa = ssm_mod.mamba2_state_axes()
        c = attn_mod.init_cache(cfg, batch, max_len, dtype)
        cache = {
            "ssm": jax.tree.map(lambda t: jnp.broadcast_to(t[None], (L,) + t.shape), s),
            "attn": jax.tree.map(lambda t: jnp.broadcast_to(t[None], (A,) + t.shape), c),
        }
        axes = {
            "ssm": jax.tree.map(lambda t: ("layers",) + t, sa,
                                is_leaf=lambda x: isinstance(x, tuple)),
            "attn": jax.tree.map(lambda t: ("layers",) + t, attn_mod.cache_axes(),
                                 is_leaf=lambda x: isinstance(x, tuple)),
        }
        return cache, axes
    raise ValueError(fam)


def lm_decode_step(cfg: ModelConfig, params, token, cache, pos):
    """token: (B, 1) int32; pos: scalar int32.  Returns (logits, cache)."""
    x = params["embed"][token]
    fam = cfg.family

    if fam in ("dense", "vlm", "moe"):
        def body(x, inp):
            bp, ck = inp
            h = apply_norm(cfg, bp["ln1"], x)
            h, ck = attn_mod.decode_attention(cfg, bp["attn"], h, ck, pos)
            x = x + h
            if "mlp" in bp:
                x = x + mlp(cfg, bp["mlp"], apply_norm(cfg, bp["ln2"], x))
            else:
                o, _ = moe_mod.moe_block(cfg, bp["moe"], apply_norm(cfg, bp["ln2"], x))
                x = x + o
            return x, ck

        if "dense_blocks" in params:
            # DeepSeek dense-first layers share the leading slices of the cache.
            nd = params["dense_blocks"]["ln1"]["w"].shape[0]
            cd = jax.tree.map(lambda t: t[:nd], cache["attn"])
            x, cd = jax.lax.scan(body, x, (params["dense_blocks"], cd))
            cm = jax.tree.map(lambda t: t[nd:], cache["attn"])
            x, cm = jax.lax.scan(body, x, (params["blocks"], cm))
            new_attn = jax.tree.map(lambda a, b: jnp.concatenate([a, b]), cd, cm)
        else:
            x, new_attn = jax.lax.scan(body, x, (params["blocks"], cache["attn"]))
        cache = dict(cache, attn=new_attn)
    elif fam == "ssm":
        dec = ssm_mod.mamba1_decode if cfg.ssm.version == 1 else ssm_mod.mamba2_decode

        def body(x, inp):
            bp, st = inp
            o, st = dec(cfg, bp["ssm"], apply_norm(cfg, bp["ln"], x), st)
            return x + o, st

        x, new_ssm = jax.lax.scan(body, x, (params["blocks"], cache["ssm"]))
        cache = dict(cache, ssm=new_ssm)
    elif fam == "hybrid":
        shared = params["shared_attn"]
        nG, tail = _hybrid_split(cfg)
        E = cfg.attn_every

        def mamba_step(x, bp, st):
            o, st = ssm_mod.mamba2_decode(cfg, bp["ssm"], apply_norm(cfg, bp["ln"], x), st)
            return x + o, st

        def attn_step(x, ck):
            h = apply_norm(cfg, shared["ln1"], x)
            h, ck = attn_mod.decode_attention(cfg, shared["attn"], h, ck, pos)
            x = x + h
            x = x + mlp(cfg, shared["mlp"], apply_norm(cfg, shared["ln2"], x))
            return x, ck

        def run_group(x, gp, sts, ck, n_mamba):
            x, st0 = mamba_step(x, _tree_idx(gp, 0), _tree_idx(sts, 0))
            x, ck = attn_step(x, ck)
            if n_mamba > 1:
                def inner(x, inp):
                    bp, st = inp
                    return mamba_step(x, bp, st)
                sl = lambda t: t[1:n_mamba]
                x, st_rest = jax.lax.scan(
                    inner, x, (jax.tree.map(sl, gp), jax.tree.map(sl, sts))
                )
                new_sts = jax.tree.map(
                    lambda a, b: jnp.concatenate([a[None], b]), st0, st_rest
                )
            else:
                new_sts = jax.tree.map(lambda a: a[None], st0)
            return x, new_sts, ck

        head_p = jax.tree.map(
            lambda t: t[: nG * E].reshape((nG, E) + t.shape[1:]), params["blocks"]
        )
        head_s = jax.tree.map(
            lambda t: t[: nG * E].reshape((nG, E) + t.shape[1:]), cache["ssm"]
        )
        head_c = jax.tree.map(lambda t: t[:nG], cache["attn"])

        def body(x, inp):
            gp, sts, ck = inp
            x, new_sts, ck = run_group(x, gp, sts, ck, E)
            return x, (new_sts, ck)

        x, (new_ssm_h, new_attn_h) = jax.lax.scan(body, x, (head_p, head_s, head_c))
        new_ssm = jax.tree.map(
            lambda t: t.reshape((nG * E,) + t.shape[2:]), new_ssm_h
        )
        new_attn = new_attn_h
        if tail:
            tail_p = jax.tree.map(lambda t: t[nG * E :], params["blocks"])
            tail_s = jax.tree.map(lambda t: t[nG * E :], cache["ssm"])
            tail_c = jax.tree.map(lambda t: t[nG], cache["attn"])
            x, new_tail_s, tail_c = run_group(x, tail_p, tail_s, tail_c, tail)
            new_ssm = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b]), new_ssm, new_tail_s
            )
            new_attn = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b[None]]), new_attn, tail_c
            )
        cache = dict(cache, attn=new_attn, ssm=new_ssm)
    else:
        raise ValueError(fam)

    x = apply_norm(cfg, params["final_norm"], x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head, cache


def lm_prefill(cfg: ModelConfig, params, tokens, cache, *, patch_embeds=None,
               q_chunk=512, kv_chunk=1024):
    """Prefill: run the full sequence, fill caches, return last-token logits.

    For attention families the per-layer K/V computed during the forward pass
    are written into the cache via a scan identical to ``lm_forward`` but
    collecting (k, v).  SSM families return their final states.
    """
    x = params["embed"][tokens]
    if patch_embeds is not None:
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    fam = cfg.family

    if fam in ("dense", "vlm", "moe"):
        def body(x, inp):
            bp, ck = inp
            h = apply_norm(cfg, bp["ln1"], x)
            h, (k, v) = attn_mod.attention(cfg, bp["attn"], h, positions,
                                           q_chunk=q_chunk, kv_chunk=kv_chunk)
            ck = {
                "k": jax.lax.dynamic_update_slice(ck["k"], k.astype(ck["k"].dtype), (0, 0, 0, 0)),
                "v": jax.lax.dynamic_update_slice(ck["v"], v.astype(ck["v"].dtype), (0, 0, 0, 0)),
            }
            x = x + h
            if "mlp" in bp:
                x = x + mlp(cfg, bp["mlp"], apply_norm(cfg, bp["ln2"], x))
            else:
                o, _ = moe_mod.moe_block(cfg, bp["moe"], apply_norm(cfg, bp["ln2"], x))
                x = x + o
            return x, ck

        if "dense_blocks" in params:
            nd = params["dense_blocks"]["ln1"]["w"].shape[0]
            cd = jax.tree.map(lambda t: t[:nd], cache["attn"])
            x, cd = jax.lax.scan(body, x, (params["dense_blocks"], cd))
            cm = jax.tree.map(lambda t: t[nd:], cache["attn"])
            x, cm = jax.lax.scan(body, x, (params["blocks"], cm))
            new_attn = jax.tree.map(lambda a, b: jnp.concatenate([a, b]), cd, cm)
        else:
            x, new_attn = jax.lax.scan(body, x, (params["blocks"], cache["attn"]))
        cache = dict(cache, attn=new_attn)
    elif fam == "ssm":
        def body(x, inp):
            bp, st0 = inp
            y, st = _ssm_block_fwd(cfg, bp, x)
            st = jax.tree.map(lambda a, b: a.astype(b.dtype), st, st0)
            return y, st

        x, new_ssm = jax.lax.scan(body, x, (params["blocks"], cache["ssm"]))
        cache = dict(cache, ssm=new_ssm)
    elif fam == "hybrid":
        shared = params["shared_attn"]
        nG, tail = _hybrid_split(cfg)
        E = cfg.attn_every

        def mamba_step(x, bp, st0):
            h = apply_norm(cfg, bp["ln"], x)
            o, st = ssm_mod.mamba2_block(cfg, bp["ssm"], h)
            st = jax.tree.map(lambda a, b: a.astype(b.dtype), st, st0)
            return x + o, st

        def attn_step(x, ck):
            h = apply_norm(cfg, shared["ln1"], x)
            h, (k, v) = attn_mod.attention(cfg, shared["attn"], h, positions,
                                           q_chunk=q_chunk, kv_chunk=kv_chunk)
            ck = {
                "k": jax.lax.dynamic_update_slice(ck["k"], k.astype(ck["k"].dtype), (0, 0, 0, 0)),
                "v": jax.lax.dynamic_update_slice(ck["v"], v.astype(ck["v"].dtype), (0, 0, 0, 0)),
            }
            x = x + h
            x = x + mlp(cfg, shared["mlp"], apply_norm(cfg, shared["ln2"], x))
            return x, ck

        def run_group(x, gp, sts, ck, n_mamba):
            x, st0 = mamba_step(x, _tree_idx(gp, 0), _tree_idx(sts, 0))
            x, ck = attn_step(x, ck)
            if n_mamba > 1:
                def inner(x, inp):
                    bp, st = inp
                    return mamba_step(x, bp, st)
                sl = lambda t: t[1:n_mamba]
                x, st_rest = jax.lax.scan(
                    inner, x, (jax.tree.map(sl, gp), jax.tree.map(sl, sts))
                )
                new_sts = jax.tree.map(
                    lambda a, b: jnp.concatenate([a[None], b]), st0, st_rest
                )
            else:
                new_sts = jax.tree.map(lambda a: a[None], st0)
            return x, new_sts, ck

        head_p = jax.tree.map(
            lambda t: t[: nG * E].reshape((nG, E) + t.shape[1:]), params["blocks"]
        )
        head_s = jax.tree.map(
            lambda t: t[: nG * E].reshape((nG, E) + t.shape[1:]), cache["ssm"]
        )
        head_c = jax.tree.map(lambda t: t[:nG], cache["attn"])

        def body(x, inp):
            gp, sts, ck = inp
            x, new_sts, ck = run_group(x, gp, sts, ck, E)
            return x, (new_sts, ck)

        x, (new_ssm_h, new_attn_h) = jax.lax.scan(body, x, (head_p, head_s, head_c))
        new_ssm = jax.tree.map(
            lambda t: t.reshape((nG * E,) + t.shape[2:]), new_ssm_h
        )
        new_attn = new_attn_h
        if tail:
            tail_p = jax.tree.map(lambda t: t[nG * E :], params["blocks"])
            tail_s = jax.tree.map(lambda t: t[nG * E :], cache["ssm"])
            tail_c = jax.tree.map(lambda t: t[nG], cache["attn"])
            x, new_tail_s, tail_c = run_group(x, tail_p, tail_s, tail_c, tail)
            new_ssm = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b]), new_ssm, new_tail_s
            )
            new_attn = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b[None]]), new_attn, tail_c
            )
        cache = dict(cache, attn=new_attn, ssm=new_ssm)
    else:
        raise ValueError(fam)

    x = apply_norm(cfg, params["final_norm"], x[:, -1:])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head, cache
