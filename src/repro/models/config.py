"""Model and shape configuration dataclasses (pure data, no jax imports)."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 2
    d_ff_expert: int = 0
    n_shared_experts: int = 0  # DeepSeekMoE shared experts
    d_ff_shared: int = 0  # total shared-expert hidden size
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    norm_topk_prob: bool = True
    first_dense_layers: int = 0  # DeepSeekMoE: leading dense layers
    d_ff_dense: int = 0  # hidden size of those dense layers


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # mamba1; 0 = d_model // 16
    head_p: int = 64  # mamba2 head size
    version: int = 1  # 1 = mamba1 (falcon-mamba), 2 = mamba2/SSD (zamba2)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 = d_model // n_heads
    act: str = "swiglu"  # swiglu | gelu
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    moe: MoEConfig = MoEConfig()
    ssm: SSMConfig = SSMConfig()
    # hybrid (zamba2): one shared transformer block reused every attn_every
    # mamba blocks
    attn_every: int = 0
    # enc-dec (whisper)
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    max_target_len: int = 448
    # vlm: fraction of the sequence that is (stubbed) image patch embeddings
    n_img_tokens: int = 0

    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- analytic parameter / FLOP counts (roofline MODEL_FLOPS) ------------

    def param_count(self) -> int:
        d, f, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd, nq, nkv = self.hd(), self.n_heads, self.n_kv_heads
        emb = V * d * (1 if self.tie_embeddings else 2)
        attn = d * hd * (nq + 2 * nkv) + nq * hd * d
        mlp_sw = 3 * d * f
        mlp_ge = 2 * d * f
        mlp = mlp_sw if self.act == "swiglu" else mlp_ge
        if self.family == "dense":
            return emb + L * (attn + mlp + 2 * d) + d
        if self.family == "moe":
            m = self.moe
            route = d * m.n_experts
            emoe = 3 * d * m.d_ff_expert * m.n_experts
            shared = 3 * d * m.d_ff_shared if m.d_ff_shared else 0
            dense_l = m.first_dense_layers
            dense_mlp = 3 * d * (m.d_ff_dense or f)
            return (
                emb
                + dense_l * (attn + dense_mlp + 2 * d)
                + (L - dense_l) * (attn + emoe + shared + route + 2 * d)
                + d
            )
        if self.family == "ssm":
            s = self.ssm
            din = s.expand * d
            dtr = s.dt_rank or d // 16
            per = (
                d * 2 * din  # in_proj
                + din * s.d_conv  # conv
                + din * (dtr + 2 * s.d_state)  # x_proj
                + dtr * din  # dt_proj
                + din * s.d_state  # A
                + din * 2  # D, dt bias-ish
                + din * d  # out_proj
            )
            return emb + L * (per + d) + d
        if self.family == "hybrid":
            s = self.ssm
            din = s.expand * d
            nh = din // s.head_p
            per = (
                d * 2 * din
                + din * s.d_conv
                + din * 2 * s.d_state  # B, C projections (folded into in_proj
                + nh * 3  # in real mamba2; kept separate here)
                + din * d
                + d
            )
            shared = attn + mlp + 2 * d
            return emb + self.n_layers * per + shared + d
        if self.family == "encdec":
            Le, Ld = self.n_enc_layers, self.n_dec_layers
            enc = Le * (attn + mlp_ge + 2 * d)
            dec = Ld * (2 * attn + mlp_ge + 3 * d)
            return emb + enc + dec + 2 * d + self.max_target_len * d
        if self.family == "vlm":
            return emb + L * (attn + mlp + 2 * d) + d
        raise ValueError(self.family)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        m = self.moe
        d, f, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd, nq, nkv = self.hd(), self.n_heads, self.n_kv_heads
        emb = V * d * (1 if self.tie_embeddings else 2)
        attn = d * hd * (nq + 2 * nkv) + nq * hd * d
        active_moe = 3 * d * m.d_ff_expert * m.top_k + 3 * d * m.d_ff_shared
        dense_l = m.first_dense_layers
        dense_mlp = 3 * d * (m.d_ff_dense or f)
        return (
            emb
            + dense_l * (attn + dense_mlp + 2 * d)
            + (L - dense_l) * (attn + active_moe + d * m.n_experts + 2 * d)
            + d
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int
    microbatch: int = 0  # per-DP-shard microbatch for grad accumulation;
    # 0 = no accumulation (single microbatch)


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}
