"""Model registry: family dispatch + input specs (dry-run) + real batches.

``input_specs(cfg, shape)`` returns ``jax.ShapeDtypeStruct`` stand-ins for
every model input of the given shape cell — weak-type-correct, shardable, no
device allocation (the multi-pod dry-run contract).  ``[audio]``/``[vlm]``
frontends are stubs per the assignment: specs provide precomputed
frame/patch embeddings.

``make_batch`` produces small *real* arrays for CPU smoke tests.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import encdec as encdec_mod
from . import transformer as lm_mod
from .config import ModelConfig, ShapeConfig
from .common import dtype_of


def init_model(cfg: ModelConfig, key):
    if cfg.family == "encdec":
        return encdec_mod.init_encdec(cfg, key)
    return lm_mod.init_lm(cfg, key)


def loss_fn(cfg: ModelConfig):
    if cfg.family == "encdec":
        return encdec_mod.encdec_loss
    return lm_mod.lm_loss


# -- shape-cell input construction -------------------------------------------


def _vlm_split(cfg: ModelConfig, seq_len: int) -> tuple[int, int]:
    n_img = min(cfg.n_img_tokens or seq_len // 8, seq_len // 2)
    return n_img, seq_len - n_img


def batch_shapes(cfg: ModelConfig, shape: ShapeConfig, *,
                 masked: bool = False) -> dict[str, Any]:
    """Abstract shapes/dtypes of the input batch for a shape cell.

    ``masked=True`` adds the packed-document ``loss_mask`` (the real data
    pipeline emits one; the assigned dry-run cells use the unmasked form)."""
    B, S = shape.global_batch, shape.seq_len
    emb_dt = dtype_of(cfg.dtype)
    if shape.kind == "train":
        if cfg.family == "encdec":
            # encoder sees S frames; decoder is teacher-forced on S tokens
            out = {
                "frames": ((B, S, cfg.d_model), emb_dt),
                "tokens": ((B, S), jnp.int32),
                "labels": ((B, S), jnp.int32),
            }
        elif cfg.family == "vlm":
            n_img, n_txt = _vlm_split(cfg, S)
            out = {
                "patch_embeds": ((B, n_img, cfg.d_model), emb_dt),
                "tokens": ((B, n_txt), jnp.int32),
                "labels": ((B, n_txt), jnp.int32),
            }
        else:
            out = {
                "tokens": ((B, S), jnp.int32),
                "labels": ((B, S), jnp.int32),
            }
        if masked:
            out["loss_mask"] = (out["labels"][0], jnp.float32)
        return out
    if shape.kind == "prefill":
        if cfg.family == "encdec":
            return {"frames": ((B, S, cfg.d_model), emb_dt)}
        if cfg.family == "vlm":
            n_img, n_txt = _vlm_split(cfg, S)
            return {
                "patch_embeds": ((B, n_img, cfg.d_model), emb_dt),
                "tokens": ((B, n_txt), jnp.int32),
            }
        return {"tokens": ((B, S), jnp.int32)}
    if shape.kind == "decode":
        return {"token": ((B, 1), jnp.int32)}
    raise ValueError(shape.kind)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, *,
                masked: bool = False) -> dict[str, jax.ShapeDtypeStruct]:
    return {
        k: jax.ShapeDtypeStruct(s, d)
        for k, (s, d) in batch_shapes(cfg, shape, masked=masked).items()
    }


def make_batch(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0) -> dict:
    """Concrete random batch (smoke tests / examples)."""
    rng = np.random.default_rng(seed)
    out = {}
    for k, (s, d) in batch_shapes(cfg, shape).items():
        if d == jnp.int32:
            out[k] = jnp.asarray(rng.integers(0, cfg.vocab, size=s), jnp.int32)
        else:
            out[k] = jnp.asarray(rng.normal(0, 0.02, size=s), d)
    if "labels" in out and "tokens" in out:
        out["labels"] = jnp.roll(out["tokens"], -1, axis=-1)
    return out
