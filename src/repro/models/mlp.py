"""Dense MLPs: SwiGLU (llama/qwen family) and GeLU (whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import trunc_normal


def init_mlp(cfg, key, d_ff: int | None = None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    ks = jax.random.split(key, 3)
    if cfg.act == "swiglu":
        p = {
            "wi": trunc_normal(ks[0], (d, f), d ** -0.5, dt),
            "wg": trunc_normal(ks[1], (d, f), d ** -0.5, dt),
            "wo": trunc_normal(ks[2], (f, d), f ** -0.5, dt),
        }
        a = {"wi": ("d_model", "d_ff"), "wg": ("d_model", "d_ff"),
             "wo": ("d_ff", "d_model")}
    else:
        p = {
            "wi": trunc_normal(ks[0], (d, f), d ** -0.5, dt),
            "bi": jnp.zeros((f,), dt),
            "wo": trunc_normal(ks[2], (f, d), f ** -0.5, dt),
            "bo": jnp.zeros((d,), dt),
        }
        a = {"wi": ("d_model", "d_ff"), "bi": ("d_ff",),
             "wo": ("d_ff", "d_model"), "bo": ("d_model",)}
    return p, a


def mlp(cfg, p, x):
    if "wg" in p:
        return (jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])) @ p["wo"]
    return jax.nn.gelu(x @ p["wi"] + p["bi"], approximate=True) @ p["wo"] + p["bo"]
