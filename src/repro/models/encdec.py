"""Whisper-style encoder-decoder backbone.

Per the assignment, the conv frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings (B, S_enc, d) directly (the two stride-2 convs
of Whisper are not executed).  Encoder: bidirectional pre-LN blocks with
sinusoidal positions.  Decoder: causal self-attention + cross-attention with
learned positions, GeLU MLPs, LayerNorm (Whisper uses LN, not RMSNorm).

Decode step carries a self-attention cache plus *precomputed* cross K/V
(filled once from the encoder output at prefill).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from .common import apply_norm, dtype_of, make_norm_params, sinusoidal_positions, \
    softmax_cross_entropy, trunc_normal
from .mlp import init_mlp, mlp


def _enc_block_init(cfg, key):
    ks = jax.random.split(key, 2)
    n1, na1 = make_norm_params(cfg, dtype_of(cfg.dtype))
    ap, aa = attn_mod.init_attention(cfg, ks[0])
    n2, na2 = make_norm_params(cfg, dtype_of(cfg.dtype))
    mp, ma = init_mlp(cfg, ks[1])
    return {"ln1": n1, "attn": ap, "ln2": n2, "mlp": mp}, \
           {"ln1": na1, "attn": aa, "ln2": na2, "mlp": ma}


def _dec_block_init(cfg, key):
    ks = jax.random.split(key, 3)
    p, a = {}, {}
    p["ln1"], a["ln1"] = make_norm_params(cfg, dtype_of(cfg.dtype))
    p["self_attn"], a["self_attn"] = attn_mod.init_attention(cfg, ks[0])
    p["ln2"], a["ln2"] = make_norm_params(cfg, dtype_of(cfg.dtype))
    p["cross_attn"], a["cross_attn"] = attn_mod.init_attention(cfg, ks[1], cross=True)
    p["ln3"], a["ln3"] = make_norm_params(cfg, dtype_of(cfg.dtype))
    p["mlp"], a["mlp"] = init_mlp(cfg, ks[2])
    return p, a


def _stack(cfg, key, n, init_fn):
    keys = jax.random.split(key, n)
    ps, ax = [], None
    for i in range(n):
        p, a = init_fn(cfg, keys[i])
        ps.append(p)
        ax = a
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *ps)
    axes = jax.tree.map(lambda t: ("layers",) + t, ax, is_leaf=lambda x: isinstance(x, tuple))
    return stacked, axes


def init_encdec(cfg, key):
    dt = dtype_of(cfg.dtype)
    ks = jax.random.split(key, 6)
    params, axes = {}, {}
    params["embed"] = trunc_normal(ks[0], (cfg.vocab, cfg.d_model), 0.02, dt)
    axes["embed"] = ("vocab", "d_model")
    params["dec_pos"] = trunc_normal(ks[1], (cfg.max_target_len, cfg.d_model), 0.02, dt)
    axes["dec_pos"] = (None, "d_model")
    params["enc_blocks"], axes["enc_blocks"] = _stack(cfg, ks[2], cfg.n_enc_layers, _enc_block_init)
    params["dec_blocks"], axes["dec_blocks"] = _stack(cfg, ks[3], cfg.n_dec_layers, _dec_block_init)
    params["enc_norm"], axes["enc_norm"] = make_norm_params(cfg, dt)
    params["dec_norm"], axes["dec_norm"] = make_norm_params(cfg, dt)
    return params, axes


def encode(cfg, params, frames, *, q_chunk=512, kv_chunk=1024, remat=True):
    """frames: (B, S_enc, d) stubbed frame embeddings."""
    B, S, d = frames.shape
    x = frames.astype(dtype_of(cfg.dtype)) + sinusoidal_positions(S, d).astype(frames.dtype)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(x, bp):
        h, _ = attn_mod.attention(cfg, bp["attn"], apply_norm(cfg, bp["ln1"], x),
                                  positions, causal=False, q_chunk=q_chunk,
                                  kv_chunk=kv_chunk, use_rope=False)
        x = x + h
        x = x + mlp(cfg, bp["mlp"], apply_norm(cfg, bp["ln2"], x))
        return x, 0.0

    bodyr = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(bodyr, x, params["enc_blocks"])
    return apply_norm(cfg, params["enc_norm"], x)


def decode_train(cfg, params, tokens, enc_out, *, q_chunk=512, kv_chunk=1024,
                 remat=True):
    """Teacher-forced decoder pass. tokens: (B, S_dec). Returns logits."""
    B, S = tokens.shape
    pos_table = params["dec_pos"]
    if S > pos_table.shape[0]:  # tile learned positions for long-form shapes
        reps = -(-S // pos_table.shape[0])
        pos_table = jnp.tile(pos_table, (reps, 1))
    x = params["embed"][tokens] + pos_table[:S]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(x, bp):
        h, _ = attn_mod.attention(cfg, bp["self_attn"], apply_norm(cfg, bp["ln1"], x),
                                  positions, q_chunk=q_chunk, kv_chunk=kv_chunk,
                                  use_rope=False)
        x = x + h
        h, _ = attn_mod.attention(cfg, bp["cross_attn"], apply_norm(cfg, bp["ln2"], x),
                                  positions, causal=False, xkv=enc_out,
                                  q_chunk=q_chunk, kv_chunk=kv_chunk)
        x = x + h
        x = x + mlp(cfg, bp["mlp"], apply_norm(cfg, bp["ln3"], x))
        return x, 0.0

    bodyr = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(bodyr, x, params["dec_blocks"])
    x = apply_norm(cfg, params["dec_norm"], x)
    return x @ params["embed"].T  # whisper ties output head


def encdec_loss(cfg, params, batch, **kw):
    enc_out = encode(cfg, params, batch["frames"], **kw)
    logits = decode_train(cfg, params, batch["tokens"], enc_out, **kw)
    return softmax_cross_entropy(logits, batch["labels"], batch.get("loss_mask"))


# -- serving ------------------------------------------------------------------


def init_encdec_cache(cfg, batch, max_self_len, max_cross_len, dtype):
    L = cfg.n_dec_layers
    sc = attn_mod.init_cache(cfg, batch, max_self_len, dtype)
    cc = attn_mod.init_cache(cfg, batch, max_cross_len, dtype)
    cache = {
        "self": jax.tree.map(lambda t: jnp.broadcast_to(t[None], (L,) + t.shape), sc),
        "cross": jax.tree.map(lambda t: jnp.broadcast_to(t[None], (L,) + t.shape), cc),
    }
    ax = jax.tree.map(lambda t: ("layers",) + t, attn_mod.cache_axes(),
                      is_leaf=lambda x: isinstance(x, tuple))
    return cache, {"self": ax, "cross": ax}


def encdec_prefill(cfg, params, frames, cache, **kw):
    """Run the encoder and fill per-layer cross K/V caches."""
    enc_out = encode(cfg, params, frames, **kw)

    def body(_, bp):
        k = (enc_out @ bp["cross_attn"]["wk"]).reshape(
            enc_out.shape[0], enc_out.shape[1], cfg.n_kv_heads, cfg.hd())
        v = (enc_out @ bp["cross_attn"]["wv"]).reshape(
            enc_out.shape[0], enc_out.shape[1], cfg.n_kv_heads, cfg.hd())
        return 0, {"k": k, "v": v}

    _, cross = jax.lax.scan(body, 0, params["dec_blocks"])
    cross = jax.tree.map(lambda t, c: t.astype(c.dtype), cross, cache["cross"])
    return dict(cache, cross=cross), enc_out


def encdec_decode_step(cfg, params, token, cache, pos):
    """One decoder token. token (B,1); pos scalar.  Returns (logits, cache)."""
    B = token.shape[0]
    pos_emb = jax.lax.dynamic_index_in_dim(
        params["dec_pos"], pos % params["dec_pos"].shape[0], 0)
    x = params["embed"][token] + pos_emb

    def body(x, inp):
        bp, sc, cc = inp
        h = apply_norm(cfg, bp["ln1"], x)
        h, sc = attn_mod.decode_attention(cfg, bp["self_attn"], h, sc, pos, rope=False)
        x = x + h
        h = apply_norm(cfg, bp["ln2"], x)
        # cross-attention against precomputed K/V (no update, no rope, no mask)
        hd, nq, nkv = cfg.hd(), cfg.n_heads, cfg.n_kv_heads
        g = nq // nkv
        q = (h @ bp["cross_attn"]["wq"]).reshape(B, nkv, g, hd) * hd ** -0.5
        s = jnp.einsum("bkgh,bskh->bkgs", q, cc["k"]).astype(jnp.float32)
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgs,bskh->bkgh", w.astype(cc["v"].dtype), cc["v"])
        x = x + o.reshape(B, 1, nq * hd) @ bp["cross_attn"]["wo"]
        x = x + mlp(cfg, bp["mlp"], apply_norm(cfg, bp["ln3"], x))
        return x, sc

    x, new_self = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["self"], cache["cross"])
    )
    x = apply_norm(cfg, params["dec_norm"], x)
    return x @ params["embed"].T, dict(cache, self=new_self)
