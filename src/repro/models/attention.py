"""GQA attention with RoPE, chunked (flash-style) prefill and KV-cache decode.

Memory discipline: prefill/train never materializes the full (S, S) score
matrix — an online-softmax scan over KV chunks keeps live memory at
O(q_chunk * kv_chunk) per head (DESIGN.md §4).  Decode computes one-step
attention against the cache; with ``cache_seq`` sharded, XLA lowers the
softmax reduction to a flash-decoding-style split-K all-reduce.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import apply_rope, trunc_normal

NEG_INF = -1e30


def init_attention(cfg, key, *, cross: bool = False):
    d, hd = cfg.d_model, cfg.hd()
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    p = {
        "wq": trunc_normal(ks[0], (d, nq * hd), d ** -0.5, dt),
        "wk": trunc_normal(ks[1], (d, nkv * hd), d ** -0.5, dt),
        "wv": trunc_normal(ks[2], (d, nkv * hd), d ** -0.5, dt),
        "wo": trunc_normal(ks[3], (nq * hd, d), (nq * hd) ** -0.5, dt),
    }
    a = {
        "wq": ("d_model", "heads"),
        "wk": ("d_model", "kv_heads"),
        "wv": ("d_model", "kv_heads"),
        "wo": ("heads", "d_model"),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nq * hd,), dt)
        p["bk"] = jnp.zeros((nkv * hd,), dt)
        p["bv"] = jnp.zeros((nkv * hd,), dt)
        a["bq"], a["bk"], a["bv"] = ("heads",), ("kv_heads",), ("kv_heads",)
    return p, a


def _project_qkv(cfg, p, x, xkv=None):
    B, S, _ = x.shape
    hd, nq, nkv = cfg.hd(), cfg.n_heads, cfg.n_kv_heads
    xkv = x if xkv is None else xkv
    q = x @ p["wq"]
    k = xkv @ p["wk"]
    v = xkv @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, nq, hd)
    k = k.reshape(B, xkv.shape[1], nkv, hd)
    v = v.reshape(B, xkv.shape[1], nkv, hd)
    return q, k, v


def _chunked_attention(q, k, v, *, causal: bool, q_chunk: int, kv_chunk: int,
                       q_offset=0):
    """Online-softmax attention. q: (B,Sq,nq,hd), k/v: (B,Skv,nkv,hd).

    GQA handled by reshaping q to (B, Sq, nkv, g, hd).  Scans KV chunks with
    running (max, denom, acc); q chunks via lax.map to bound live memory.
    """
    B, Sq, nq, hd = q.shape
    Skv, nkv = k.shape[1], k.shape[2]
    g = nq // nkv
    scale = hd ** -0.5
    q = (q * scale).reshape(B, Sq, nkv, g, hd)

    nqc = max(1, Sq // max(q_chunk, 1)) if Sq > q_chunk else 1
    q_chunk = Sq // nqc
    nkc = max(1, Skv // max(kv_chunk, 1)) if Skv > kv_chunk else 1
    kv_chunk = Skv // nkc

    q_ch = q.reshape(B, nqc, q_chunk, nkv, g, hd).transpose(1, 0, 2, 3, 4, 5)
    k_ch = k.reshape(B, nkc, kv_chunk, nkv, hd).transpose(1, 0, 2, 3, 4)
    v_ch = v.reshape(B, nkc, kv_chunk, nkv, hd).transpose(1, 0, 2, 3, 4)

    def per_q_chunk(args):
        qi, qc = args  # qc: (B, qch, nkv, g, hd)
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, inputs):
            m, l, acc = carry
            ki, kc, vc = inputs  # (B, kvch, nkv, hd)
            s = jnp.einsum("bqkgh,bskh->bkgqs", qc, kc).astype(jnp.float32)
            if causal:
                kv_pos = ki * kv_chunk + jnp.arange(kv_chunk)
                mask = q_pos[:, None] >= kv_pos[None, :]
                s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            alpha = jnp.exp(m - m_new)
            pexp = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + pexp.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", pexp.astype(vc.dtype), vc
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, nkv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, nkv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, nkv, g, q_chunk, hd), jnp.float32)
        # flash-style backward: never save per-chunk score tensors — the
        # backward pass recomputes them per (q, kv) chunk pair.
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_step, prevent_cse=False),
            (m0, l0, a0), (jnp.arange(nkc), k_ch, v_ch)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 3, 1, 2, 4)  # (B, qch, nkv, g, hd)

    out = jax.lax.map(jax.checkpoint(per_q_chunk, prevent_cse=False),
                      (jnp.arange(nqc), q_ch))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, nq, hd)
    return out.astype(v.dtype)


def attention(cfg, p, x, positions, *, causal=True, xkv=None, kv_positions=None,
              q_chunk=512, kv_chunk=1024, use_rope=True, q_spec=None,
              kv_spec=None):
    """Full-sequence attention (train / prefill / encoder / cross).

    ``q_spec``/``kv_spec`` (optional NamedShardings on the 4D (B,S,H,hd)
    tensors) pin the GQA layout when kv_heads doesn't divide the model axis:
    without them GSPMD splits head_dim and all-reduces every score
    contraction (§Perf B5)."""
    q, k, v = _project_qkv(cfg, p, x, xkv)
    if q_spec is not None:
        q = jax.lax.with_sharding_constraint(q, q_spec)
    if kv_spec is not None:
        k = jax.lax.with_sharding_constraint(k, kv_spec)
        v = jax.lax.with_sharding_constraint(v, kv_spec)
    if xkv is None and use_rope:  # self-attention: rope both
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions if kv_positions is None else kv_positions,
                       cfg.rope_theta)
    out = _chunked_attention(q, k, v, causal=causal, q_chunk=q_chunk,
                             kv_chunk=kv_chunk)
    B, S = x.shape[:2]
    return out.reshape(B, S, -1) @ p["wo"], (k, v)


def init_cache(cfg, batch, max_len, dtype):
    hd, nkv = cfg.hd(), cfg.n_kv_heads
    return {
        "k": jnp.zeros((batch, max_len, nkv, hd), dtype),
        "v": jnp.zeros((batch, max_len, nkv, hd), dtype),
    }


def cache_axes():
    return {
        "k": ("cache_batch", "cache_seq", "cache_kv_heads", "cache_hd"),
        "v": ("cache_batch", "cache_seq", "cache_kv_heads", "cache_hd"),
    }


def decode_attention(cfg, p, x, cache, pos, *, rope: bool = True,
                     update_cache: bool = True):
    """One-token decode. x: (B, 1, d); cache k/v: (B, Smax, nkv, hd);
    pos: scalar int32, or (B,) int32 for per-slot positions (continuous
    batching).  Returns (out, new_cache)."""
    B = x.shape[0]
    hd, nq, nkv = cfg.hd(), cfg.n_heads, cfg.n_kv_heads
    g = nq // nkv
    per_slot = jnp.ndim(pos) == 1
    q, k, v = _project_qkv(cfg, p, x)
    if rope:
        pp = pos[:, None].astype(jnp.int32) if per_slot else jnp.full((B, 1), pos, jnp.int32)
        q = apply_rope(q, pp, cfg.rope_theta)
        k = apply_rope(k, pp, cfg.rope_theta)
    if update_cache:
        if per_slot:
            rows = jnp.arange(B)
            ck = cache["k"].at[rows, pos].set(k[:, 0].astype(cache["k"].dtype))
            cv = cache["v"].at[rows, pos].set(v[:, 0].astype(cache["v"].dtype))
        else:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
        cache = {"k": ck, "v": cv}
    S = cache["k"].shape[1]
    qh = (q * hd ** -0.5).reshape(B, nkv, g, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qh, cache["k"]).astype(jnp.float32)
    if per_slot:
        valid = jnp.arange(S)[None, None, None, :] <= pos[:, None, None, None]
    else:
        valid = jnp.arange(S)[None, None, None, :] <= pos
    s = jnp.where(valid, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", w.astype(cache["v"].dtype), cache["v"])
    out = out.reshape(B, 1, nq * hd)
    return out @ p["wo"], cache
