from .config import ModelConfig, MoEConfig, SSMConfig, ShapeConfig, SHAPES  # noqa: F401
from .registry import init_model, input_specs, loss_fn, make_batch  # noqa: F401
