"""Shared layers: norms, RoPE, initializers, losses.

Parameters are plain pytrees (nested dicts of jnp arrays).  Every ``init_*``
returns ``(params, axes)`` where ``axes`` mirrors ``params`` with tuples of
*logical axis names* per dimension — ``dist/sharding.py`` turns those into
mesh ``PartitionSpec``s (MaxText/t5x-style logical sharding rules).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


def trunc_normal(key, shape, scale, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * scale).astype(dtype)


def init_dense(key, d_in, d_out, dtype, axes_in="d_model", axes_out="d_ff"):
    w = trunc_normal(key, (d_in, d_out), d_in ** -0.5, dtype)
    return w, (axes_in, axes_out)


def rms_norm(x, w, eps):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def layer_norm(x, w, b, eps):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean((x32 - mu) ** 2, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * w + b


def make_norm_params(cfg, dtype):
    if cfg.norm == "rmsnorm":
        return {"w": jnp.ones((cfg.d_model,), dtype)}, {"w": ("d_model",)}
    return (
        {"w": jnp.ones((cfg.d_model,), dtype), "b": jnp.zeros((cfg.d_model,), dtype)},
        {"w": ("d_model",), "b": ("d_model",)},
    )


def apply_norm(cfg, p, x):
    if cfg.norm == "rmsnorm":
        return rms_norm(x, p["w"], cfg.norm_eps)
    return layer_norm(x, p["w"], p["b"], cfg.norm_eps)


# -- rotary position embeddings ---------------------------------------------


def rope_angles(head_dim: int, theta: float, positions):
    """positions: (...,) int32 -> (..., head_dim//2) angles."""
    inv = 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))
    return positions[..., None].astype(jnp.float32) * inv[None, :]


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, hd); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    ang = rope_angles(hd, theta, positions)  # (B, S, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if cos.ndim == 2:  # (S, hd/2) -> broadcast over batch
        cos, sin = cos[None], sin[None]
    cos = cos[:, :, None, :].astype(x.dtype)
    sin = sin[:, :, None, :].astype(x.dtype)
    x1, x2 = x[..., : hd // 2], x[..., hd // 2 :]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def sinusoidal_positions(max_len: int, d: int):
    pos = np.arange(max_len)[:, None]
    dim = np.arange(0, d, 2)[None, :]
    ang = pos / np.power(10000.0, dim / d)
    out = np.zeros((max_len, d), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return jnp.asarray(out)


# -- losses ------------------------------------------------------------------


def softmax_cross_entropy(logits, labels, mask=None):
    """logits (..., V) any dtype -> fp32 mean NLL over masked positions.

    The label logit is extracted with a one-hot contraction rather than
    ``take_along_axis`` so a vocab-sharded logits tensor never needs an
    all-gather (the dynamic-index gather would force one under GSPMD)."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    nll = logz - jnp.sum(logits * onehot, axis=-1)
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
