"""Top-k routed Mixture-of-Experts with capacity buffers + shared experts.

Sort-free GShard-style dispatch with *index* scatter (no (T, E, C) one-hot
tensors): tokens are placed into per-expert capacity buffers via
``segment``-position arithmetic; overflowing tokens are dropped (standard
capacity-factor semantics), and the combine step scatters expert outputs
back weighted by their (optionally re-normalized) top-k router probs.

Compute cost per MoE layer = E * C * 3 * d * d_ff_expert * 2 FLOPs/matmul
with C = ceil(T * top_k / E * capacity_factor) — i.e. proportional to the
*active* parameter count (DESIGN.md §4), which keeps the roofline's
MODEL_FLOPS / HLO_FLOPS ratio honest.

Supports DeepSeekMoE fine-grained experts + shared experts (always-on dense
branch) and Switch-style load-balance aux loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import trunc_normal
from .mlp import init_mlp, mlp


def init_moe(cfg, key):
    m = cfg.moe
    d = cfg.d_model
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    ks = jax.random.split(key, 5)
    p = {
        "router": trunc_normal(ks[0], (d, m.n_experts), d ** -0.5, jnp.float32),
        "wi": trunc_normal(ks[1], (m.n_experts, d, m.d_ff_expert), d ** -0.5, dt),
        "wg": trunc_normal(ks[2], (m.n_experts, d, m.d_ff_expert), d ** -0.5, dt),
        "wo": trunc_normal(ks[3], (m.n_experts, m.d_ff_expert, d),
                           m.d_ff_expert ** -0.5, dt),
    }
    a = {
        "router": ("d_model", "experts"),
        "wi": ("experts", "d_model", "expert_ff"),
        "wg": ("experts", "d_model", "expert_ff"),
        "wo": ("experts", "expert_ff", "d_model"),
    }
    if m.n_shared_experts:
        sp, sa = init_mlp(cfg, ks[4], d_ff=m.d_ff_shared)
        p["shared"], a["shared"] = sp, sa
    return p, a


def moe_block(cfg, p, x):
    """x: (B, S, d) -> (out, aux_loss)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, m.top_k)  # (T, k)
    if m.norm_topk_prob:
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    E = m.n_experts
    C = int((T * m.top_k / E) * m.capacity_factor + 0.5)
    C = max(C, m.top_k)

    # Position of each (token, slot) within its expert's buffer.
    flat_e = gate_idx.reshape(-1)  # (T*k,) expert ids, token-major
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (T*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - 1  # running count per expert
    flat_pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = flat_pos < C
    slot = jnp.where(keep, flat_e * C + flat_pos, E * C)  # E*C = drop bin

    # Dispatch by *index*: scatter token ids (int32) into slots, then gather
    # rows.  Scattering the (E*C, d) float buffer directly makes GSPMD
    # all-reduce the full buffer across the data axis (every shard could
    # write anywhere): ~500 MB fp32 per layer per microbatch observed
    # (EXPERIMENTS.md §Perf).  The index scatter is 4 bytes/slot; the row
    # gather reduces to data movement of only the routed tokens.
    tok_idx = jnp.repeat(jnp.arange(T), m.top_k)
    tok_for_slot = jnp.full((E * C + 1,), T, jnp.int32).at[slot].set(tok_idx)
    xpad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
    eb = xpad[tok_for_slot[: E * C]].reshape(E, C, d)

    # Expert compute (einsum over stacked expert weights).
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", eb, p["wg"])) * jnp.einsum(
        "ecd,edf->ecf", eb, p["wi"]
    )
    eo = jnp.einsum("ecf,efd->ecd", h, p["wo"]).reshape(E * C, d)
    eo = jnp.concatenate([eo, jnp.zeros((1, d), eo.dtype)], axis=0)

    # Combine: gather back, weight by gate, sum over the k slots.
    back = eo[slot] * gate_vals.reshape(-1)[:, None].astype(eo.dtype)
    out = jnp.zeros((T, d), x.dtype).at[tok_idx].add(back)

    # Switch-style load-balance loss.
    me = probs.mean(0)  # mean router prob per expert
    ce = (jax.nn.one_hot(gate_idx[:, 0], E).mean(0)).astype(jnp.float32)
    aux = m.router_aux_coef * E * jnp.sum(me * ce)

    if "shared" in p:
        out = out + mlp(cfg, p["shared"], xt)
    return out.reshape(B, S, d), aux
