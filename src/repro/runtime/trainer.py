"""Fault-tolerant training driver.

Production posture for thousands of nodes (DESIGN.md §4):

- **checkpoint/restart** — periodic async checkpoints; any step exception
  triggers restore-from-latest and continue (``max_restarts`` bound);
- **straggler watchdog** — per-step wall-time tracked against a rolling
  median; steps slower than ``straggler_factor`` x median emit a straggler
  event (callback pluggable: re-shard, demote host, alert);
- **elastic re-mesh** — ``resize(new_mesh)`` re-shards the live train state
  onto a different device mesh between steps (uses the elastic restore path
  in ``ckpt.checkpoint`` semantics but in-memory);
- failure injection hooks for tests (``inject_failure``).

Single-host CPU runs exercise all of these paths (tests/test_runtime.py);
the same driver drives the pod-scale configuration in launch/train.py.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Iterator, Optional

import jax
import numpy as np

from repro.ckpt import checkpoint as ckpt

Pytree = Any


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep_ckpts: int = 3
    max_restarts: int = 3
    straggler_factor: float = 3.0
    straggler_window: int = 20
    async_ckpt: bool = True


class Trainer:
    def __init__(
        self,
        cfg: TrainerConfig,
        state: Pytree,
        step_fn: Callable[[Pytree, dict], tuple[Pytree, dict]],
        data: Iterator[dict],
        *,
        state_shardings: Optional[Pytree] = None,
        on_straggler: Optional[Callable[[int, float, float], None]] = None,
    ):
        self.cfg = cfg
        self.state = state
        self.step_fn = step_fn
        self.data = data
        self.state_shardings = state_shardings
        self.on_straggler = on_straggler
        self.step_times: list[float] = []
        self.events: list[dict] = []
        self.restarts = 0
        self._ckpt_thread = None
        self.inject_failure: Optional[Callable[[int], None]] = None
        self.metrics_log: list[dict] = []

    # -- fault handling -----------------------------------------------------

    def _checkpoint(self, step: int):
        if self._ckpt_thread is not None:
            self._ckpt_thread.join()  # one in flight at a time
        self._ckpt_thread = ckpt.save(
            self.cfg.ckpt_dir, step, self.state, blocking=not self.cfg.async_ckpt
        )
        ckpt.prune(self.cfg.ckpt_dir, self.cfg.keep_ckpts)

    def _restore_latest(self):
        if self._ckpt_thread is not None:
            self._ckpt_thread.join()
        self.state, step = ckpt.restore(
            self.cfg.ckpt_dir, self.state, sharding_tree=self.state_shardings
        )
        self.events.append({"kind": "restore", "step": step})
        return step

    def resize(self, new_state_shardings: Pytree):
        """Elastic re-mesh: redistribute live state onto new shardings."""
        flat, td = jax.tree.flatten(self.state)
        shards = td.flatten_up_to(new_state_shardings)
        self.state = jax.tree.unflatten(
            td, [jax.device_put(np.asarray(t), s) for t, s in zip(flat, shards)]
        )
        self.state_shardings = new_state_shardings
        self.events.append({"kind": "resize"})

    # -- main loop ------------------------------------------------------------

    def run(self, num_steps: int, *, start_step: int = 0) -> Pytree:
        step = start_step
        while step < num_steps:
            try:
                batch = next(self.data)
                if self.inject_failure is not None:
                    self.inject_failure(step)
                t0 = time.perf_counter()
                self.state, metrics = self.step_fn(self.state, batch)
                jax.block_until_ready(jax.tree.leaves(self.state)[0])
                dt = time.perf_counter() - t0
                self._watch(step, dt)
                metrics = {k: float(v) for k, v in metrics.items()}
                metrics["step"] = step
                metrics["step_time_s"] = dt
                self.metrics_log.append(metrics)
                step += 1
                if step % self.cfg.ckpt_every == 0:
                    self._checkpoint(step)
            except (FloatingPointError, RuntimeError, ValueError) as e:
                self.restarts += 1
                self.events.append({"kind": "failure", "step": step, "err": repr(e)})
                if self.restarts > self.cfg.max_restarts:
                    raise
                try:
                    step = self._restore_latest()
                except FileNotFoundError:
                    step = start_step  # no checkpoint yet: restart from scratch
        self._checkpoint(step)
        if self._ckpt_thread is not None:
            self._ckpt_thread.join()
        return self.state

    def _watch(self, step: int, dt: float):
        self.step_times.append(dt)
        w = self.step_times[-self.cfg.straggler_window :]
        if len(w) >= 5:
            med = statistics.median(w)
            if dt > self.cfg.straggler_factor * med:
                self.events.append(
                    {"kind": "straggler", "step": step, "dt": dt, "median": med}
                )
                if self.on_straggler:
                    self.on_straggler(step, dt, med)
