"""Unified telemetry plane: metrics registry, request-lifecycle tracer,
Chrome-trace/Perfetto export.

See ARCHITECTURE.md "Telemetry plane" for the span taxonomy, the
registry merge semantics, and the disabled-mode guarantees.
"""
from .metrics import (
    Histogram, MetricsRegistry, absorb_engine_stats, absorb_gossip_stats,
    absorb_online_stats, absorb_span_stats, absorb_timing,
)
from .trace import NULL, NullTracer, Tracer
from .export import (
    reconstruct_request, text_timeline, to_chrome_trace,
    validate_chrome_trace, write_chrome_trace,
)

__all__ = [
    "Histogram",
    "MetricsRegistry",
    "NULL",
    "NullTracer",
    "Tracer",
    "absorb_engine_stats",
    "absorb_gossip_stats",
    "absorb_online_stats",
    "absorb_span_stats",
    "absorb_timing",
    "reconstruct_request",
    "text_timeline",
    "to_chrome_trace",
    "validate_chrome_trace",
    "write_chrome_trace",
]
