"""Chrome-trace / Perfetto JSON export, text timeline, schema validation.

``to_chrome_trace`` converts a :class:`~repro.obs.trace.Tracer`'s event
buffer into the Chrome trace event format (the JSON flavor Perfetto's
legacy importer and ``chrome://tracing`` both load): span events become
complete events (``ph="X"``), flow events stay async begin/instant/end
(``ph="b"/"n"/"e"``, matched on ``(cat, id)``), and each distinct track
name becomes a named thread via ``thread_name`` metadata events.

``reconstruct_request`` inverts the export for one request id — the
acceptance check that a spanning request's lifecycle (submit → chained
2PC reserves → commit → release) survives the round-trip.
"""
from __future__ import annotations

import json

__all__ = [
    "to_chrome_trace",
    "write_chrome_trace",
    "text_timeline",
    "validate_chrome_trace",
    "reconstruct_request",
]

_PID = 1
_VALID_PH = {"X", "B", "E", "b", "n", "e", "i", "I", "M", "C", "s", "t", "f"}


def _track_ids(events) -> dict[str, int]:
    tracks = sorted({ev.get("track", "main") for ev in events})
    return {t: i + 1 for i, t in enumerate(tracks)}


def to_chrome_trace(tracer_or_events, *, process_name: str = "repro"
                    ) -> dict:
    """Tracer (or raw event list) -> Chrome trace JSON object."""
    events = getattr(tracer_or_events, "events", tracer_or_events)
    tids = _track_ids(events)
    out = [{
        "ph": "M", "name": "process_name", "pid": _PID, "tid": 0,
        "args": {"name": process_name},
    }]
    for track, tid in tids.items():
        out.append({
            "ph": "M", "name": "thread_name", "pid": _PID, "tid": tid,
            "args": {"name": track},
        })
    for ev in events:
        ce = {
            "ph": ev["ph"],
            "name": ev["name"],
            "cat": ev.get("cat", "span"),
            "ts": ev["ts"],
            "pid": _PID,
            "tid": tids[ev.get("track", "main")],
        }
        if ev["ph"] == "X":
            ce["dur"] = ev.get("dur", 0.0)
        if ev["ph"] in ("b", "n", "e"):
            ce["id"] = str(ev["id"])
        if ev["ph"] == "i":
            ce["s"] = ev.get("s", "t")
        if "args" in ev:
            ce["args"] = ev["args"]
        out.append(ce)
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_chrome_trace(tracer_or_events, path: str, *,
                       process_name: str = "repro") -> dict:
    obj = to_chrome_trace(tracer_or_events, process_name=process_name)
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)
        f.write("\n")
    return obj


def validate_chrome_trace(obj) -> list[str]:
    """Check a trace object against the Chrome trace event schema.

    Returns a list of problems (empty == valid): top-level shape, the
    required fields per phase, non-negative durations, and that every
    async begin (``ph="b"``) has a matching end (``ph="e"``) on the
    same ``(cat, id)``.
    """
    errs: list[str] = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return ["top level must be an object with a 'traceEvents' array"]
    evs = obj["traceEvents"]
    if not isinstance(evs, list):
        return ["'traceEvents' must be an array"]
    opened: dict[tuple, int] = {}
    for i, ev in enumerate(evs):
        if not isinstance(ev, dict):
            errs.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _VALID_PH:
            errs.append(f"event {i}: invalid ph {ph!r}")
            continue
        if "name" not in ev:
            errs.append(f"event {i}: missing name")
        if ph != "M":
            if not isinstance(ev.get("ts"), (int, float)):
                errs.append(f"event {i}: missing/invalid ts")
            if not isinstance(ev.get("pid"), int):
                errs.append(f"event {i}: missing/invalid pid")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errs.append(f"event {i}: X event needs dur >= 0")
        if ph in ("b", "n", "e"):
            if "id" not in ev:
                errs.append(f"event {i}: async event missing id")
            if "cat" not in ev:
                errs.append(f"event {i}: async event missing cat")
            key = (ev.get("cat"), str(ev.get("id")))
            if ph == "b":
                opened[key] = opened.get(key, 0) + 1
            elif ph == "e":
                if opened.get(key, 0) <= 0:
                    errs.append(f"event {i}: async end without begin {key}")
                else:
                    opened[key] -= 1
    for key, n in opened.items():
        if n > 0:
            errs.append(f"async begin without end: {key} (x{n})")
    return errs


def reconstruct_request(obj_or_events, rid_or_id) -> list[dict]:
    """Lifecycle of one request from an exported trace (or a raw event
    list): every async event whose id mentions ``req:<rid>``, in
    timestamp order.  Pass either a bare rid or a full scoped id."""
    if isinstance(obj_or_events, dict):
        events = obj_or_events.get("traceEvents", [])
    else:
        events = getattr(obj_or_events, "events", obj_or_events)
    needle = str(rid_or_id)
    if "req:" not in needle:
        needle = f"req:{needle}"
    out = [ev for ev in events
           if ev.get("ph") in ("b", "n", "e")
           and str(ev.get("id", "")).endswith(needle)]
    out.sort(key=lambda ev: ev.get("ts", 0.0))
    return out


def text_timeline(tracer_or_events, *, width: int = 64,
                  max_rows: int = 40) -> str:
    """Compact per-track ASCII timeline of the span (``ph="X"``) events."""
    events = getattr(tracer_or_events, "events", tracer_or_events)
    spans = [ev for ev in events if ev.get("ph") == "X"]
    if not spans:
        return "(no spans)"
    t0 = min(ev["ts"] for ev in spans)
    t1 = max(ev["ts"] + ev.get("dur", 0.0) for ev in spans)
    scale = (width - 1) / max(t1 - t0, 1e-9)
    lines = [f"timeline: {len(spans)} spans over "
             f"{(t1 - t0) / 1e3:.2f} ms"]
    # widest spans first; one row each
    for ev in sorted(spans, key=lambda e: -e.get("dur", 0.0))[:max_rows]:
        a = int((ev["ts"] - t0) * scale)
        b = max(a + 1, int((ev["ts"] + ev.get("dur", 0.0) - t0) * scale))
        bar = " " * a + "#" * (b - a)
        lines.append(f"{bar:<{width}} {ev.get('track', 'main')}:"
                     f"{ev['name']} {ev.get('dur', 0.0) / 1e3:.3f}ms")
    if len(spans) > max_rows:
        lines.append(f"... ({len(spans) - max_rows} more spans)")
    return "\n".join(lines)
