"""Labeled metrics registry unifying the scattered stats surfaces.

``engine.Stats``, ``OnlineStats``, the broker's ``span_stats``, the
``GossipBus`` counters, and the solve/overhead/conflict timing split
all become *views over one registry*: each plane exposes
``metrics_registry()`` which absorbs its own surfaces into counters /
gauges / histograms keyed by ``(name, labels)``, and parent planes
**merge** their children's registries under a composed ``plane`` label
(``"g0/r1"``) — mirroring the gossip aggregation structure, so a
snapshot only ever contains what that plane can legitimately see.

The registry is pull-based: it is built fresh on each
``metrics_registry()`` call from the live stats surfaces, so it adds
zero cost to the admission path (nothing is recorded per-request).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

__all__ = [
    "Histogram",
    "MetricsRegistry",
    "absorb_engine_stats",
    "absorb_online_stats",
    "absorb_gossip_stats",
    "absorb_span_stats",
    "absorb_timing",
]


def _key(name: str, labels: dict) -> tuple:
    return (name, tuple(sorted(labels.items())))


def _label_str(labels: tuple) -> str:
    if not labels:
        return ""
    return "{" + ",".join(f"{k}={v}" for k, v in labels) + "}"


class Histogram:
    """Count/sum/min/max plus power-of-two bucket counts — mergeable
    without holding raw samples."""

    __slots__ = ("count", "sum", "min", "max", "buckets")

    def __init__(self):
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self.buckets: dict[int, int] = {}  # bucket i covers [2^(i-1), 2^i)

    def observe(self, v: float, n: int = 1) -> None:
        """Record value ``v``; ``n`` > 1 records it with weight ``n`` (the
        pull-based adapters fold pre-aggregated ``{value: count}`` surfaces
        like the placer's superstep buckets without replaying samples)."""
        self.count += n
        self.sum += v * n
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        b = int(v).bit_length() if v >= 1 else (-1 if v > 0 else 0)
        self.buckets[b] = self.buckets.get(b, 0) + n

    def merge(self, other: "Histogram") -> None:
        self.count += other.count
        self.sum += other.sum
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)
        for b, c in other.buckets.items():
            self.buckets[b] = self.buckets.get(b, 0) + c

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def to_dict(self) -> dict:
        return {
            "count": self.count,
            "sum": self.sum,
            "mean": self.mean,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
        }


class MetricsRegistry:
    """Counters / gauges / histograms with labels.

    ``merge(other, plane="r0")`` folds another registry in, composing
    any label key both sides define with ``/`` (``plane="g0"`` merged
    over a child metric already labeled ``plane="r1"`` yields
    ``plane="g0/r1"``) — the label path mirrors the plane nesting.
    """

    def __init__(self):
        self._counters: dict[tuple, float] = {}
        self._gauges: dict[tuple, float] = {}
        self._hists: dict[tuple, Histogram] = {}

    # -- record ---------------------------------------------------------------

    def inc(self, name: str, value: float = 1.0, **labels) -> None:
        k = _key(name, labels)
        self._counters[k] = self._counters.get(k, 0.0) + value

    def gauge(self, name: str, value: float, **labels) -> None:
        self._gauges[_key(name, labels)] = value

    def observe(self, name: str, value: float, n: int = 1, **labels) -> None:
        k = _key(name, labels)
        h = self._hists.get(k)
        if h is None:
            h = self._hists[k] = Histogram()
        h.observe(value, n)

    # -- read -----------------------------------------------------------------

    def get(self, name: str, **labels) -> float | None:
        k = _key(name, labels)
        if k in self._counters:
            return self._counters[k]
        if k in self._gauges:
            return self._gauges[k]
        h = self._hists.get(k)
        return h.mean if h is not None else None

    def counters(self) -> dict:
        return dict(self._counters)

    def total(self, name: str) -> float:
        """Sum of a counter over all label sets — the honest global view."""
        return sum(v for (n, _), v in self._counters.items() if n == name)

    def labeled(self, name: str) -> dict:
        """All label-set -> value pairs for one metric name."""
        out = {}
        for store in (self._counters, self._gauges):
            for (n, labels), v in store.items():
                if n == name:
                    out[labels] = v
        for (n, labels), h in self._hists.items():
            if n == name:
                out[labels] = h.to_dict()
        return out

    # -- merge ----------------------------------------------------------------

    @staticmethod
    def _compose(labels: tuple, extra: dict) -> tuple:
        if not extra:
            return labels
        d = dict(labels)
        for k, v in extra.items():
            d[k] = f"{v}/{d[k]}" if k in d else v
        return tuple(sorted(d.items()))

    def merge(self, other: "MetricsRegistry", **extra_labels) -> "MetricsRegistry":
        for (n, labels), v in other._counters.items():
            k = (n, self._compose(labels, extra_labels))
            self._counters[k] = self._counters.get(k, 0.0) + v
        for (n, labels), v in other._gauges.items():
            self._gauges[(n, self._compose(labels, extra_labels))] = v
        for (n, labels), h in other._hists.items():
            k = (n, self._compose(labels, extra_labels))
            mine = self._hists.get(k)
            if mine is None:
                mine = self._hists[k] = Histogram()
            mine.merge(h)
        return self

    @classmethod
    def merged(cls, regs: Iterable[tuple["MetricsRegistry", dict]]
               ) -> "MetricsRegistry":
        out = cls()
        for reg, extra in regs:
            out.merge(reg, **extra)
        return out

    # -- snapshot -------------------------------------------------------------

    def snapshot(self, *, reset: bool = False) -> dict:
        """Flat ``name{label=value,...} -> value`` dict (JSON-friendly)."""
        out: dict[str, object] = {}
        for (n, labels), v in sorted(self._counters.items()):
            out[n + _label_str(labels)] = v
        for (n, labels), v in sorted(self._gauges.items()):
            out[n + _label_str(labels)] = v
        for (n, labels), h in sorted(self._hists.items()):
            out[n + _label_str(labels)] = h.to_dict()
        if reset:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()
        return out


# ---------------------------------------------------------------------------
# adapters: the legacy stats surfaces as registry views
# ---------------------------------------------------------------------------

# engine.Stats fields that sum across solves/regions
_ENGINE_ADDITIVE = (
    "rounds", "messages_sent", "messages_dropped", "maps_generated",
    "fallback_used", "stale_batches", "preemptions", "defrag_rounds",
    "gossip_messages", "twopc_messages",
)


def absorb_engine_stats(reg: MetricsRegistry, s, **labels) -> MetricsRegistry:
    """``engine.Stats`` -> registry.  Additive fields become counters;
    non-additive fields (``kernel_impl``, ``solve_n``, ``method``,
    ``batch_size``) become *labeled* values instead of last-writer-wins
    scalars (the historical merge bug)."""
    for f in _ENGINE_ADDITIVE:
        v = getattr(s, f, 0)
        if v:
            reg.inc(f"engine.{f}", float(v), **labels)
    reg.gauge("engine.max_set_size", float(s.max_set_size), **labels)
    if s.solve_n:
        reg.observe("engine.solve_n", float(s.solve_n), **labels)
    if s.kernel_impl:
        reg.inc("engine.solves", 1.0, kernel_impl=s.kernel_impl, **labels)
    if getattr(s, "method", ""):
        reg.inc("engine.method", 1.0, method=s.method, **labels)
    for f in ("solve_ms", "overhead_ms", "conflict_resolve_ms"):
        v = getattr(s, f, 0.0)
        if v:
            reg.inc(f"timing.{f}", float(v), **labels)
    return reg


def absorb_online_stats(reg: MetricsRegistry, st, **labels) -> MetricsRegistry:
    """``OnlineStats`` (the placer's lifetime counters + timing split +
    per-impl solve counts) -> registry."""
    for f in dataclasses.fields(st):
        v = getattr(st, f.name)
        if f.name in ("solve_ms", "overhead_ms", "conflict_resolve_ms"):
            reg.inc(f"timing.{f.name}", float(v), **labels)
        elif isinstance(v, (int, float)) and not isinstance(v, bool):
            if v:
                reg.inc(f"placer.{f.name}", float(v), **labels)
    for impl, cnt in getattr(st, "kernel_impls", {}).items():
        reg.inc("placer.solves_by_impl", float(cnt), kernel_impl=impl,
                **labels)
    # superstep histograms per solve mode ("cold" vs the warm-started
    # bounded correction pass) — the stat the incremental fast path is
    # graded on: warm solves must report strictly fewer supersteps
    for mode, buckets in getattr(st, "supersteps", {}).items():
        for rounds, cnt in buckets.items():
            reg.observe("engine.supersteps", float(rounds), n=int(cnt),
                        mode=mode, **labels)
    if st.solves:
        reg.gauge("placer.mean_solve_n", float(st.mean_solve_n), **labels)
    return reg


def absorb_gossip_stats(reg: MetricsRegistry, gs: dict, **labels
                        ) -> MetricsRegistry:
    """``GossipBus.gossip_stats()`` / ``snapshot()`` dict -> registry."""
    for f in ("rounds", "messages_sent", "records_sent", "payload_sent"):
        if f in gs:
            reg.inc(f"gossip.{f}", float(gs[f]), **labels)
    for f in ("messages_per_round", "records_per_message"):
        if f in gs:
            reg.gauge(f"gossip.{f}", float(gs[f]), **labels)
    return reg


def absorb_span_stats(reg: MetricsRegistry, ss: dict, **labels
                      ) -> MetricsRegistry:
    """Broker ``span_stats`` dict -> registry (``max_*`` keys — running
    maxima like max_chain / max_req_attempts — are gauges, the rest are
    counters)."""
    for k, v in ss.items():
        if k.startswith("max_"):
            reg.gauge(f"twopc.{k}", float(v), **labels)
        else:
            reg.inc(f"twopc.{k}", float(v), **labels)
    return reg


def absorb_timing(reg: MetricsRegistry, timing: dict, **labels
                  ) -> MetricsRegistry:
    """``fairness_report()['timing']`` dict -> registry counters."""
    for k, v in timing.items():
        reg.inc(f"timing.{k}", float(v), **labels)
    return reg
