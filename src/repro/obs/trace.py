"""Request-lifecycle tracer for the control planes.

One :class:`Tracer` collects Chrome-trace events (in-memory dicts) from
every layer it is threaded through — control planes, placers, the 2PC
broker, gossip rounds, kernel dispatch — against a single monotonic
clock, so a request's lifecycle can be reconstructed across planes.

Two event families:

- **spans** (``span(...)`` context manager): Chrome "complete" events
  (``ph="X"``) with a duration — pump rounds, batched solves,
  validate/commit loops, 2PC reserve phases, gossip ticks, defrag.
- **flow events** (``flow_begin/flow_point/flow_end``): Chrome async
  events (``ph="b"/"n"/"e"``) keyed by a string id derived from the
  request id — submit, dispatch, admit, reject, preempt, per-region 2PC
  reserves, commit, release.  The string id is prefixed by the plane
  scope (see :meth:`Tracer.scoped`) so region-local rids never collide
  with broker-level rids.

Nested planes share one event buffer through :meth:`Tracer.scoped`,
which returns a view whose track names and flow ids carry a
``"r0/"``-style prefix — mirroring how regional registries merge into a
global snapshot.

Disabled mode is the :data:`NULL` singleton: every method is a constant
no-op (``span``/``annotate`` return one cached reusable null context),
so instrumented hot paths pay one attribute lookup + call per hook.
Tracing reads ``time.perf_counter`` only — no RNG, no solver state —
so enabling it cannot perturb placement decisions (bit-identity suites
run with tracing on).
"""
from __future__ import annotations

import time
from contextlib import nullcontext

__all__ = ["Tracer", "NullTracer", "NULL"]

_NULL_CTX = nullcontext()


class _Span:
    """Context manager recording one complete ("X") event on exit."""

    __slots__ = ("_tr", "name", "track", "cat", "args", "_t0")

    def __init__(self, tr, name, track, cat, args):
        self._tr = tr
        self.name = name
        self.track = track
        self.cat = cat
        self.args = args

    def __enter__(self):
        self._t0 = self._tr._now_us()
        return self

    def __exit__(self, exc_type, exc, tb):
        t1 = self._tr._now_us()
        ev = {
            "ph": "X",
            "name": self.name,
            "cat": self.cat or "span",
            "ts": self._t0,
            "dur": t1 - self._t0,
            "track": self.track,
        }
        if self.args:
            ev["args"] = self.args
        self._tr._events.append(ev)
        return False


class Tracer:
    """Collects Chrome-trace events against one monotonic clock."""

    enabled = True

    def __init__(self, *, clock=time.perf_counter):
        self._clock = clock
        self._t0 = clock()
        self._events: list[dict] = []
        self._prefix = ""

    # -- internals ----------------------------------------------------------

    def _now_us(self) -> float:
        return (self._clock() - self._t0) * 1e6

    # -- scoping ------------------------------------------------------------

    def scoped(self, prefix: str) -> "Tracer":
        """A view over the same event buffer whose tracks and flow ids
        carry ``prefix + "/"`` — one per nested plane (region / group)."""
        t = object.__new__(Tracer)
        t._clock = self._clock
        t._t0 = self._t0
        t._events = self._events
        t._prefix = self._prefix + prefix + "/"
        return t

    # -- spans ----------------------------------------------------------------

    def span(self, name: str, *, track: str = "main", cat: str = "",
             **args) -> _Span:
        return _Span(self, name, self._prefix + track, cat, args)

    def instant(self, name: str, *, track: str = "main", cat: str = "",
                **args) -> None:
        ev = {
            "ph": "i",
            "name": name,
            "cat": cat or "instant",
            "ts": self._now_us(),
            "s": "t",
            "track": self._prefix + track,
        }
        if args:
            ev["args"] = args
        self._events.append(ev)

    # -- request-lifecycle flow events ---------------------------------------

    def _flow(self, ph: str, fid, name: str, track: str, args) -> None:
        ev = {
            "ph": ph,
            "name": name,
            "cat": "request",
            "id": f"{self._prefix}req:{fid}",
            "ts": self._now_us(),
            "track": self._prefix + track,
        }
        if args:
            ev["args"] = args
        self._events.append(ev)

    def flow_begin(self, fid, name: str = "request", *,
                   track: str = "lifecycle", **args) -> None:
        self._flow("b", fid, name, track, args)

    def flow_point(self, fid, name: str, *, track: str = "lifecycle",
                   **args) -> None:
        self._flow("n", fid, name, track, args)

    def flow_end(self, fid, name: str = "request", *,
                 track: str = "lifecycle", **args) -> None:
        self._flow("e", fid, name, track, args)

    # -- accelerator hook -----------------------------------------------------

    def annotate(self, name: str):
        """``jax.profiler.TraceAnnotation`` around device dispatch so the
        span shows up in XLA/Perfetto profiles too.  Imported lazily;
        falls back to a null context when jax is unavailable."""
        try:
            from jax.profiler import TraceAnnotation
        except Exception:  # pragma: no cover - jax always present in CI
            return _NULL_CTX
        return TraceAnnotation(self._prefix + name)

    # -- access ---------------------------------------------------------------

    @property
    def events(self) -> list[dict]:
        return self._events

    def clear(self) -> None:
        del self._events[:]


class NullTracer(Tracer):
    """Disabled tracer: every method is a constant no-op.

    ``scoped`` returns itself so plane constructors can scope
    unconditionally; ``span``/``annotate`` return one cached reusable
    null context manager (no allocation per hook)."""

    enabled = False

    def __init__(self):
        self._events = ()
        self._prefix = ""

    def scoped(self, prefix: str) -> "NullTracer":
        return self

    def span(self, name, *, track="main", cat="", **args):
        return _NULL_CTX

    def instant(self, name, *, track="main", cat="", **args):
        return None

    def flow_begin(self, fid, name="request", *, track="lifecycle", **args):
        return None

    def flow_point(self, fid, name, *, track="lifecycle", **args):
        return None

    def flow_end(self, fid, name="request", *, track="lifecycle", **args):
        return None

    def annotate(self, name):
        return _NULL_CTX

    @property
    def events(self):
        return []

    def clear(self):
        return None


#: Module-level disabled tracer; planes default to this when no tracer
#: is passed, so the instrumented paths cost one no-op call per hook.
NULL = NullTracer()
