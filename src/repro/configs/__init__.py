"""Assigned-architecture registry: ``get_config("qwen2.5-14b")`` etc."""
from __future__ import annotations

import importlib

_MODULES = {
    "stablelm-3b": "stablelm_3b",
    "qwen2.5-14b": "qwen2_5_14b",
    "qwen2-0.5b": "qwen2_0_5b",
    "llama3.2-1b": "llama3_2_1b",
    "whisper-medium": "whisper_medium",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "zamba2-7b": "zamba2_7b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "internvl2-2b": "internvl2_2b",
}

ARCHS = list(_MODULES)

# long_500k applicability (DESIGN.md §6): sub-quadratic families only.
LONG_CONTEXT_OK = {"zamba2-7b", "falcon-mamba-7b"}


def _mod(name: str):
    key = name.replace("_", "-").lower()
    if key not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {ARCHS}")
    return importlib.import_module(f"repro.configs.{_MODULES[key]}")


def get_config(name: str, smoke: bool = False):
    m = _mod(name)
    return m.SMOKE if smoke else m.CONFIG


def train_accumulation(name: str) -> int:
    return getattr(_mod(name), "TRAIN_ACC", 1)


def train_mode(name: str) -> str:
    """'tp' (tensor parallel, default) or 'seq' (sequence parallelism — used
    where head counts don't divide the model axis; EXPERIMENTS.md §Perf B)."""
    return getattr(_mod(name), "TRAIN_MODE", "tp")


def cells(include_skipped: bool = False):
    """All (arch, shape_name) dry-run cells; skipped long_500k cells are
    excluded unless requested."""
    from repro.models.config import SHAPES

    out = []
    for a in ARCHS:
        for s in SHAPES:
            skipped = s == "long_500k" and a not in LONG_CONTEXT_OK
            if skipped and not include_skipped:
                continue
            out.append((a, s))
    return out
