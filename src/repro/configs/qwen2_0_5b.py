"""qwen2-0.5b [dense] — 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151936 — GQA, QKV bias  [arXiv:2407.10671; hf].

Tied embeddings (qwen2-0.5b shares input/output embedding); 14 heads / 2 KV
heads shard unevenly on the 16-way model axis.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense", n_layers=24, d_model=896,
    n_heads=14, n_kv_heads=2, d_ff=4864, vocab=151936,
    act="swiglu", norm="rmsnorm", qkv_bias=True, tie_embeddings=True,
    rope_theta=1_000_000.0,
)

SMOKE = CONFIG.with_(n_layers=2, d_model=64, n_heads=2, n_kv_heads=1,
                     d_ff=160, vocab=512, dtype="float32")

TRAIN_ACC = 1

# §Perf hillclimb B: 14 q / 2 kv heads don't divide the 16-way model axis;
# tensor parallelism degenerates into per-chunk all-reduces (the baseline
# cell is 172x collective-bound).  Sequence parallelism makes every
# sub-layer token-local.
TRAIN_MODE = "seq"
