"""falcon-mamba-7b [ssm] — 64L d_model=4096 (attn-free) vocab=65024,
ssm_state=16 — mamba1 architecture  [arXiv:2410.05355; unverified].

Runs long_500k (attention-free: decode is O(1) in context length).
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm", n_layers=64, d_model=4096,
    n_heads=1, n_kv_heads=1, d_ff=0, vocab=65024, norm="rmsnorm",
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, dt_rank=256, version=1),
)

SMOKE = CONFIG.with_(n_layers=2, d_model=64, vocab=512, dtype="float32",
                     ssm=SSMConfig(d_state=8, d_conv=4, expand=2, dt_rank=8,
                                   version=1))

TRAIN_ACC = 16
