"""whisper-medium [audio] — 24L d_model=1024 16H (kv=16) d_ff=4096
vocab=51865 — enc-dec, conv frontend (STUB)  [arXiv:2212.04356; unverified].

Frontend stub per the assignment: input_specs() provides precomputed frame
embeddings (B, S, d); the 2x stride-2 conv stem is not executed.  Shapes:
train_4k = enc 4096 frames + teacher-forced dec 4096 tokens; prefill_32k =
encoder over 32768 frames filling cross K/V; decode_32k = one decoder token
against self-cache 32768 + cross-cache 32768 (DESIGN.md §6).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=4096, vocab=51865,
    act="gelu", norm="layernorm", n_enc_layers=24, n_dec_layers=24,
    max_target_len=448, tie_embeddings=True,
)

SMOKE = CONFIG.with_(n_layers=2, n_enc_layers=2, n_dec_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
                     max_target_len=64, dtype="float32")

TRAIN_ACC = 8
