"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=102400, MoE 64 routed top-6 + 2 shared, fine-grained; first layer is
dense (d_ff 10944)  [arXiv:2401.06066; hf]."""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe", n_layers=28, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1408, vocab=102400,
    act="swiglu", norm="rmsnorm",
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408,
                  n_shared_experts=2, d_ff_shared=2816,
                  first_dense_layers=1, d_ff_dense=10944,
                  capacity_factor=1.25),
)

SMOKE = CONFIG.with_(n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
                     d_ff=64, vocab=512, dtype="float32",
                     moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64,
                                   n_shared_experts=1, d_ff_shared=128,
                                   first_dense_layers=1, d_ff_dense=192,
                                   capacity_factor=1.25))

TRAIN_ACC = 8
