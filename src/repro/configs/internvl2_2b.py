"""internvl2-2b [vlm] — 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553 — InternViT + InternLM2  [arXiv:2404.16821; hf].

Backbone only per the assignment: the InternViT frontend is a STUB —
input_specs() provides precomputed patch embeddings prepended to the text
tokens (train_4k: 1024 patches + 3072 text; prefill_32k: 4096 + 28672).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=8, d_ff=8192, vocab=92553,
    act="swiglu", norm="rmsnorm", rope_theta=1_000_000.0, n_img_tokens=1024,
)

SMOKE = CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                     d_ff=192, vocab=512, n_img_tokens=8, dtype="float32")

TRAIN_ACC = 2
TRAIN_MODE = "seq"
