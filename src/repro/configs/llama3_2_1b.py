"""llama3.2-1b [dense] — 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256 — small llama3  [hf:meta-llama/Llama-3.2-1B; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b", family="dense", n_layers=16, d_model=2048,
    n_heads=32, n_kv_heads=8, d_ff=8192, vocab=128256,
    act="swiglu", norm="rmsnorm", tie_embeddings=True, rope_theta=500_000.0,
)

SMOKE = CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                     d_ff=192, vocab=512, dtype="float32")

TRAIN_ACC = 2
TRAIN_MODE = "seq"
