"""qwen2.5-14b [dense] — 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064 — GQA, QKV bias  [hf:Qwen/Qwen2.5-0.5B; hf].

40 q-heads on a 16-way model axis shard unevenly (GSPMD pads to 48);
see DESIGN.md §4 and the roofline notes.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b", family="dense", n_layers=48, d_model=5120,
    n_heads=40, n_kv_heads=8, d_ff=13824, vocab=152064,
    act="swiglu", norm="rmsnorm", qkv_bias=True, rope_theta=1_000_000.0,
)

SMOKE = CONFIG.with_(n_layers=2, d_model=80, n_heads=5, n_kv_heads=1,
                     d_ff=224, vocab=512, dtype="float32")

TRAIN_ACC = 16
