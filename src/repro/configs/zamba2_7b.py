"""zamba2-7b [hybrid] — 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64 — Mamba2 backbone + ONE shared transformer block
applied every 6 mamba blocks (weights reused, per-call-site KV cache)
[arXiv:2411.15242; unverified].

Runs long_500k: mamba decode state is O(1); the 14 shared-attention call
sites decode against a (cache_seq-sharded) 512k KV cache.
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584,
    n_heads=32, n_kv_heads=32, d_ff=14336, vocab=32000,
    act="swiglu", norm="rmsnorm", attn_every=6,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_p=64, version=2),
)

SMOKE = CONFIG.with_(n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
                     d_ff=160, vocab=512, attn_every=2, dtype="float32",
                     ssm=SSMConfig(d_state=8, d_conv=4, expand=2, head_p=32,
                                   version=2))

TRAIN_ACC = 16
