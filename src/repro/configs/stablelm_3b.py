"""stablelm-3b [dense] — 32L d_model=2560 32H (GQA kv=32) d_ff=6912
vocab=50304  [hf:stabilityai/stablelm-2-1_6b; unverified].

StableLM uses LayerNorm and partial-rotary attention; we model LN + full
rotary (partial-rotary is a fidelity note, not a structural difference).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b", family="dense", n_layers=32, d_model=2560,
    n_heads=32, n_kv_heads=32, d_ff=6912, vocab=50304,
    act="swiglu", norm="layernorm", rope_theta=10000.0,
)

SMOKE = CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                     d_ff=176, vocab=512, dtype="float32")

TRAIN_ACC = 4  # gradient-accumulation microbatches for train_4k
TRAIN_MODE = "seq"
