"""Slot-based continuous-batching serving engine.

A fixed pool of ``n_slots`` sequences decodes in lock-step (one jit'd
per-slot-position decode step per tick); finished slots are refilled from
the request queue by prefililng the new prompt at batch=1 and scattering its
KV cache into the slot (``cache_insert``).  Sampling: temperature / top-k.

CPU-scale demo of the production pattern (examples/serve_pipeline.py); the
same engine drives the pod-scale decode step built by launch/steps.py, and
its stage placement comes from the BCPM mapper (launch/placement.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as lm
from repro.models.config import ModelConfig


def sample_logits(key, logits, *, temperature: float = 1.0, top_k: int = 0):
    """logits (B, V) -> token ids (B,)."""
    if temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k:
        v, _ = jax.lax.top_k(logits, top_k)
        logits = jnp.where(logits < v[:, -1:], -1e30, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)


def cache_insert(cache_pool, cache_one, slot: int):
    """Scatter a batch=1 cache pytree into slot ``slot`` of the pool.

    Attention caches have layout (L, B, S, ...); SSM states (L, B, ...)."""
    return jax.tree.map(
        lambda pool, one: pool.at[:, slot].set(one[:, 0].astype(pool.dtype)),
        cache_pool, cache_one,
    )


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (T,) int32
    max_new: int = 32
    out: Optional[list] = None


class Engine:
    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 4,
                 max_len: int = 256, temperature: float = 0.0, top_k: int = 0,
                 seed: int = 0):
        assert cfg.family in ("dense", "moe", "vlm", "ssm", "hybrid")
        self.cfg, self.params = cfg, params
        self.n_slots, self.max_len = n_slots, max_len
        self.temperature, self.top_k = temperature, top_k
        self.key = jax.random.key(seed)
        self.cache, _ = lm.init_lm_cache(cfg, n_slots, max_len, jnp.float32)
        self.pos = np.zeros(n_slots, np.int32)  # next write position
        self.active: list[Optional[Request]] = [None] * n_slots
        self.last_tok = np.zeros((n_slots, 1), np.int32)
        self.queue: list[Request] = []
        self.done: list[Request] = []

        self._decode = jax.jit(
            lambda p, t, c, pos: lm.lm_decode_step(cfg, p, t, c, pos)
        )
        self._prefill = jax.jit(
            lambda p, t, c: lm.lm_prefill(cfg, p, t, c)
        )

    def submit(self, req: Request):
        req.out = []
        self.queue.append(req)

    def _fill_slot(self, slot: int):
        if not self.queue:
            return
        req = self.queue.pop(0)
        c1, _ = lm.init_lm_cache(self.cfg, 1, self.max_len, jnp.float32)
        logits, c1 = self._prefill(self.params, req.prompt[None, :].astype(np.int32), c1)
        self.cache = cache_insert(self.cache, c1, slot)
        self.key, k = jax.random.split(self.key)
        tok = sample_logits(k, logits[:, -1], temperature=self.temperature,
                            top_k=self.top_k)
        req.out.append(int(tok[0]))
        self.active[slot] = req
        self.pos[slot] = len(req.prompt)
        self.last_tok[slot, 0] = int(tok[0])

    def step(self):
        """One engine tick: refill free slots, one decode step for all."""
        for s in range(self.n_slots):
            if self.active[s] is None:
                self._fill_slot(s)
        if not any(self.active):
            return False
        logits, self.cache = self._decode(
            self.params, jnp.asarray(self.last_tok), self.cache,
            jnp.asarray(self.pos),
        )
        self.key, k = jax.random.split(self.key)
        toks = sample_logits(k, logits[:, 0], temperature=self.temperature,
                             top_k=self.top_k)
        toks = np.asarray(toks)
        for s in range(self.n_slots):
            req = self.active[s]
            if req is None:
                continue
            self.pos[s] += 1
            req.out.append(int(toks[s]))
            if len(req.out) >= req.max_new or self.pos[s] >= self.max_len - 1:
                self.done.append(req)
                self.active[s] = None
            else:
                self.last_tok[s, 0] = int(toks[s])
        return True

    def run(self, max_ticks: int = 10_000):
        ticks = 0
        while (self.queue or any(self.active)) and ticks < max_ticks:
            if not self.step():
                break
            ticks += 1
        return self.done, ticks
