from .engine import Engine, Request, cache_insert, sample_logits  # noqa: F401
