"""Distribution utilities: logical-axis sharding rules (MaxText/t5x style)."""
