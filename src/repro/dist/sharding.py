"""Logical-axis sharding rules (MaxText/t5x style).

Model ``init_*`` functions return ``(params, axes)`` where ``axes`` mirrors
the param pytree with tuples of *logical axis names* per dimension (see
``repro.models.common``).  A :class:`Rules` object maps logical names onto
mesh axes and turns (logical axes, concrete shape) into a
``NamedSharding`` — dropping any assignment whose mesh-axis product does not
divide the dimension and never using one mesh axis twice in a spec, so every
emitted sharding is valid for any mesh/shape combination.

Rule sets:

- ``train_compute_rules``  — tensor parallel over ``model``; batch over the
  data axes (``("pod", "data")`` on the multi-pod mesh).
- ``train_seqpar_rules``   — like compute, but activations shard the
  *sequence* dimension over ``model`` (§Perf B3).
- ``train_state_rules``    — ZeRO-style: master/optimizer state additionally
  sharded over the data axes on the ``d_model`` dimension.
- ``serve_rules``          — decode/prefill: KV-cache batch over data axes,
  heads over ``model``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # noqa: F401

AxisSpec = Union[str, tuple, None]


def _mesh_axis_size(mesh: Mesh, axes: AxisSpec) -> int:
    """Product of mesh-axis sizes a logical axis maps onto (1 for None)."""
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes if a in mesh.shape] or [1]))


def _batch_axes(mesh: Mesh) -> AxisSpec:
    """Every non-model mesh axis carries batch (pod x data on multi-pod)."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else axes


@dataclasses.dataclass
class Rules:
    """Logical-axis -> mesh-axis mapping plus the spec/sharding builders."""

    mesh: Mesh
    rules: dict  # logical axis name -> mesh axis | tuple of mesh axes | None

    def spec(self, logical: tuple, shape: tuple) -> P:
        """PartitionSpec for one array: per-dim lookup with validity checks
        (divisibility; each mesh axis used at most once)."""
        used: set = set()
        out = []
        for name, dim in zip(logical, shape):
            mx = self.rules.get(name) if name is not None else None
            if mx is None:
                out.append(None)
                continue
            axes = (mx,) if isinstance(mx, str) else tuple(mx)
            axes = tuple(a for a in axes if a in self.mesh.shape and a not in used)
            size = _mesh_axis_size(self.mesh, axes)
            if not axes or size <= 1 or int(dim) % size != 0:
                out.append(None)
                continue
            used.update(axes)
            out.append(axes[0] if len(axes) == 1 else axes)
        while out and out[-1] is None:  # canonical short spec
            out.pop()
        return P(*out)

    def sharding(self, logical: tuple, shape: tuple) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical, shape))


def _model_sharded(mesh: Mesh, *, batch: AxisSpec, seq: AxisSpec = None,
                   extra: Optional[dict] = None) -> Rules:
    rules = {
        "batch": batch,
        "seq": seq,
        # weights: shard the "wide" dimension of each layer over model
        "d_ff": "model",
        "heads": "model",
        "kv_heads": "model",
        "vocab": "model",
        "experts": "model",
        "expert_ff": "model",
        "d_inner": "model",
        "heads_ssm": "model",
        # replicated by default
        "d_model": None,
        "ssm_state": None,
        "ssm_proj": None,
        "dt_rank": None,
        "conv": None,
        "moe_dense": None,
        # KV-cache axes (serving)
        "cache_batch": batch,
        "cache_seq": None,
        "cache_kv_heads": "model",
        "cache_hd": None,
    }
    rules.update(extra or {})
    return Rules(mesh, rules)


def train_compute_rules(mesh: Mesh) -> Rules:
    """bf16 compute params: tensor parallel over ``model``, batch over data."""
    return _model_sharded(mesh, batch=_batch_axes(mesh))


def train_seqpar_rules(mesh: Mesh) -> Rules:
    """Sequence parallelism (§Perf B3): activations shard seq over ``model``;
    weight layout matches the TP rules (the math is identical)."""
    return _model_sharded(mesh, batch=_batch_axes(mesh), seq="model")


def train_state_rules(mesh: Mesh) -> Rules:
    """fp32 master params + optimizer moments (and ZeRO-3 compute params):
    additionally sharded over the data axes on ``d_model`` so state memory
    scales down with the full device count, not just the model axis."""
    return _model_sharded(mesh, batch=_batch_axes(mesh),
                          extra={"d_model": _batch_axes(mesh)})


def serve_rules(mesh: Mesh, *, batch: int, kv_heads: int, seq: int) -> Rules:
    """Decode/prefill: slot-batch over the data axes, heads over ``model``.
    The (batch, kv_heads, seq) hints keep the signature explicit at call
    sites; actual divisibility is re-checked per-array in ``Rules.spec``."""
    del batch, kv_heads, seq
    return _model_sharded(mesh, batch=_batch_axes(mesh))


def _is_axes_leaf(x: Any) -> bool:
    return isinstance(x, tuple) and all(e is None or isinstance(e, str) for e in x)


def tree_shardings(rules: Rules, shapes: Any, axes: Any) -> Any:
    """Map a (params-shaped) tree of logical-axes tuples + a matching tree of
    arrays/ShapeDtypeStructs to a tree of NamedShardings."""
    return jax.tree.map(
        lambda a, s: rules.sharding(a, tuple(s.shape)),
        axes, shapes, is_leaf=_is_axes_leaf,
    )


def batch_shardings(rules: Rules, specs: dict) -> dict:
    """Input-batch shardings: dim 0 is the global batch, dim 1 (when present)
    the sequence; trailing dims (e.g. patch embedding width) replicate."""
    out = {}
    for k, v in specs.items():
        logical = ("batch",) + (("seq",) if v.ndim > 1 else ())
        logical = logical + (None,) * (v.ndim - len(logical))
        out[k] = rules.sharding(logical, tuple(v.shape))
    return out
