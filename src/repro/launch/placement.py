"""BCPM device placement — the paper's technique as the framework's
placement engine (DESIGN.md §2).

The 2009 problem maps 1:1 onto pod-scale device placement:

  resource graph  = pod topology, coarsened to *slices* (here: columns of
                    the v5e 16x16 ICI torus, 16 chips each; pods linked by
                    DCI).  Node capacity = aggregate TFLOP/s; link bandwidth
                    = aggregate ICI/DCI GB/s; link latency = hop latency.
  dataflow path   = the model's pipeline stages (layer groups) or a
                    multi-stage serving dataflow (ViT -> LM, encoder ->
                    decoder): C_req = TFLOP/s at the target step rate,
                    B_req = inter-stage activation GB/s.

``plan_pipeline`` / ``plan_serving`` build the BCPM instance from a
ModelConfig and solve it with the LeastCostMap engine (tensorized JAX DP,
falling back to the path-carrying version per DESIGN.md §3).  The launcher
asks this module for a stage->slice assignment before building shardings;
at thousands-of-slices scale the same instance solves decentralized via
``core.distributed.leastcost_shard_map`` (no host ever holds the full
network state — the paper's motivating constraint).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import engine
from repro.core.graph import DataflowPath, Mapping, ResourceGraph
from repro.models.config import ModelConfig, ShapeConfig

# v5e constants (also used by the roofline; see benchmarks/roofline.py)
CHIP_TFLOPS = 197.0  # bf16
ICI_GBPS = 50.0  # per link
DCI_GBPS = 25.0  # inter-pod, per slice pairing (conservative)
ICI_HOP_US = 1.0
DCI_HOP_US = 10.0


@dataclasses.dataclass
class PodTopology:
    pods: int = 1
    rows: int = 16
    cols: int = 16
    chips_per_slice: int = 16  # one torus column

    @property
    def slices_per_pod(self) -> int:
        return self.rows * self.cols // self.chips_per_slice

    @property
    def n_slices(self) -> int:
        return self.pods * self.slices_per_pod


def slice_resource_graph(topo: PodTopology, *, utilization: float = 0.6) -> ResourceGraph:
    """Coarsened resource graph: one node per torus column (slice).

    Adjacent columns are linked by ``rows`` ICI links (torus: column ring);
    pod boundaries by DCI.  Capacity = usable TFLOP/s per slice.
    """
    n = topo.n_slices
    spp = topo.slices_per_pod
    cap = np.full(n, topo.chips_per_slice * CHIP_TFLOPS * utilization, np.float32)
    bw = np.zeros((n, n), np.float32)
    lat = np.full((n, n), np.inf, np.float32)
    np.fill_diagonal(lat, 0.0)
    col_bw = topo.rows * ICI_GBPS  # parallel links between adjacent columns
    for p in range(topo.pods):
        base = p * spp
        for i in range(spp):
            j = (i + 1) % spp  # torus ring over columns
            a, b = base + i, base + j
            bw[a, b] = bw[b, a] = col_bw
            lat[a, b] = lat[b, a] = ICI_HOP_US
    for p in range(topo.pods - 1):  # DCI chain between pods (edge slices)
        a = p * spp + spp - 1
        b = (p + 1) * spp
        bw[a, b] = bw[b, a] = topo.rows * DCI_GBPS
        lat[a, b] = lat[b, a] = DCI_HOP_US
    return ResourceGraph(cap, bw, lat)


@dataclasses.dataclass
class PlacementPlan:
    stage_slices: list  # slice id per pipeline stage
    route: tuple
    latency_us: float
    stage_tflops: list
    stage_bw_gbps: list
    mapping: Mapping


def _stage_flops(cfg: ModelConfig, tokens_per_step: float,
                 n_stages: Optional[int] = None,
                 slice_tflops: float = 16 * CHIP_TFLOPS * 0.6) -> tuple[list, list]:
    """Split the model into per-stage FLOPs + inter-stage activation bytes.

    ``n_stages=None`` auto-sizes stages so each fits one slice's capacity
    (the resource-graph nodes are slices; BCPM maps one stage per visit)."""
    if cfg.family == "encdec":
        n_total = cfg.param_count()
        enc_frac = cfg.n_enc_layers / (cfg.n_enc_layers + 2 * cfg.n_dec_layers)
        stages = [enc_frac, 1 - enc_frac]
        flops = [2 * f * n_total * tokens_per_step for f in stages]
        act = [tokens_per_step * cfg.d_model * 2]  # enc_out bytes/step
        return flops, act
    if cfg.family == "vlm":
        # stub frontend ~ 1/4 of backbone cost; backbone = LM
        lm_flops = 2 * cfg.active_param_count() * tokens_per_step
        flops = [0.25 * lm_flops, lm_flops]
        act = [tokens_per_step * cfg.d_model * 2]
        return flops, act
    total = 2 * cfg.active_param_count() * tokens_per_step
    if n_stages is None:
        n_stages = max(2, int(np.ceil(total / 1e12 / slice_tflops * 1.1)))
        n_stages = min(n_stages, max(cfg.n_layers, 2))
    per = total / n_stages
    act = [tokens_per_step * cfg.d_model * 2] * (n_stages - 1)
    return [per] * n_stages, act


def plan_pipeline(
    cfg: ModelConfig,
    shape: ShapeConfig,
    topo: PodTopology = PodTopology(),
    *,
    steps_per_sec: float = 1.0,
    src_slice: int = 0,
    dst_slice: Optional[int] = None,
    use_jax: bool = True,
    method: Optional[str] = None,
) -> Optional[PlacementPlan]:
    """Place the model's pipeline stages onto pod slices via BCPM.

    Solved through the unified mapper engine (``repro.core.engine.solve``);
    ``method`` picks any registered backend, defaulting to the tensorized
    DP (``use_jax=False`` keeps the legacy path-carrying alias).
    train: backward ~ 2x forward -> 3x forward FLOPs per step.
    """
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 3.0 if shape.kind == "train" else 1.0
    flops, act_bytes = _stage_flops(cfg, tokens * steps_per_sec * mult)
    creq = [f / 1e12 for f in flops]  # TFLOP/s
    breq = [a / 1e9 for a in act_bytes]  # GB/s
    rg = slice_resource_graph(topo)
    # infeasible if more stages than slices or any stage exceeds a slice
    if len(creq) + 2 > rg.n * 4 or (creq and max(creq) > float(rg.cap.max())):
        return None
    dst = dst_slice if dst_slice is not None else topo.n_slices - 1
    # source/sink anchors with zero compute (data in / results out)
    df = DataflowPath(
        creq=np.asarray([0.0] + creq + [0.0], np.float32),
        breq=np.asarray([breq[0] if breq else 1.0] + breq + [breq[-1] if breq else 1.0],
                        np.float32),
        src=src_slice,
        dst=dst,
    )
    method = method or ("leastcost_jax" if use_jax else "leastcost_python")
    mapping, _stats = engine.solve(rg, df, method=method)
    if mapping is None:
        return None
    stage_slices = list(mapping.assign[1:-1])
    return PlacementPlan(
        stage_slices=stage_slices,
        route=mapping.route,
        latency_us=mapping.cost,
        stage_tflops=creq,
        stage_bw_gbps=breq,
        mapping=mapping,
    )


def plan_serving(cfg: ModelConfig, shape: ShapeConfig, topo: PodTopology = PodTopology(),
                 *, requests_per_sec: float = 10.0, **kw) -> Optional[PlacementPlan]:
    """Place a serving dataflow (frontend -> backbone -> sampler)."""
    return plan_pipeline(cfg, shape, topo,
                         steps_per_sec=requests_per_sec / max(shape.global_batch, 1),
                         **kw)


def plan_tree_serving(
    cfg: ModelConfig,
    topo: PodTopology = PodTopology(),
    *,
    branch_tflops: dict | None = None,
    branch_gbps: float = 1.0,
    src_slices: dict | None = None,
    dst_slice: int | None = None,
):
    """Place a multi-source serving dataflow (paper §4 tree extension).

    E.g. a VLM with separate vision and text frontends merging into the LM:

        vision ──┐
                 ├──> backbone ──> sink
        text  ───┘

    ``branch_tflops``: {"vision": x, "text": y, "backbone": z} TFLOP/s.
    Sources/sink pinned to slices.  Solved with core.dag.treemap_leastcost
    on the pod slice graph.  The paper's Fig. 2 DAG (a source feeding two
    stages) reduces to this form by duplicating the pinned source — sound
    because pinned sources carry no compute requirement.
    """
    import numpy as np
    from repro.core.dag import DataflowTree, treemap_leastcost

    b = branch_tflops or {
        "vision": 0.25 * 2 * cfg.active_param_count() / 1e12,
        "text": 0.05 * 2 * cfg.active_param_count() / 1e12,
        "backbone": 2 * cfg.active_param_count() / 1e12,
    }
    # tree nodes: 0=vision-src, 1=text-src, 2=backbone, 3=sink
    creq = np.array([b["vision"], b["text"], b["backbone"], 0.0], np.float32)
    breq = np.array([branch_gbps, branch_gbps, branch_gbps, 0.0], np.float32)
    parent = np.array([2, 2, 3, -1])
    pin = dict(src_slices or {0: 0, 1: 1})
    pin[3] = topo.n_slices - 1 if dst_slice is None else dst_slice
    rg = slice_resource_graph(topo)
    tree = DataflowTree(creq=creq, parent=parent, breq=breq, pinned=pin)
    return treemap_leastcost(rg, tree)
