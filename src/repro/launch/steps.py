"""jit-ready train / prefill / decode step builders for any (arch x shape).

Everything here works from *abstract* shapes (``jax.eval_shape``) so the
multi-pod dry-run can lower+compile without allocating a single parameter —
and the same builders back the real CPU-scale runs (examples/, tests/).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.dist import sharding as shd
from repro.models import encdec as encdec_mod
from repro.models import transformer as lm_mod
from repro.models.common import dtype_of
from repro.models.config import ModelConfig, ShapeConfig
from repro.models.registry import batch_shapes, init_model, input_specs, loss_fn
from repro.optim.adamw import OptConfig, TrainState, apply_updates, init_state

Pytree = Any


@dataclasses.dataclass
class BuiltStep:
    fn: Callable  # jit-wrapped
    in_shardings: tuple
    out_shardings: Any
    abstract_args: tuple  # ShapeDtypeStructs for .lower()
    meta: dict


def abstract_model(cfg: ModelConfig):
    """(param ShapeDtypeStructs, logical axes) without allocating.

    The logical-axes pytree is static python (tuples of strings) built
    alongside the params; it is captured via a side channel during the
    abstract trace so no parameter memory is ever touched."""
    aux: dict = {}

    def helper():
        p, a = init_model(cfg, jax.random.key(0))
        aux["axes"] = a
        return p

    p_shapes = jax.eval_shape(helper)
    return p_shapes, aux["axes"]


def abstract_cache(cfg: ModelConfig, shape: ShapeConfig, dtype):
    aux: dict = {}

    def helper():
        c, a = _cache_for(cfg, shape, dtype)
        aux["axes"] = a
        return c

    c_shapes = jax.eval_shape(helper)
    return c_shapes, aux["axes"]


# -- train -------------------------------------------------------------------


def build_train_step(cfg: ModelConfig, shape: ShapeConfig, mesh,
                     opt: OptConfig = OptConfig(), *, n_acc: Optional[int] = None,
                     remat: bool = True, fsdp: Optional[bool] = None,
                     masked: bool = False, mode: str = "tp") -> BuiltStep:
    rules_c = (shd.train_seqpar_rules(mesh) if mode == "seq"
               else shd.train_compute_rules(mesh))
    rules_s = shd.train_state_rules(mesh)
    loss = loss_fn(cfg)
    n_acc = n_acc or shape.microbatch or 1
    assert shape.global_batch % n_acc == 0
    # each microbatch must still shard over every batch axis (multi-pod has
    # 32 data ways; 256/16 microbatches would leave 0.5 sequences/device)
    batch_ways = shd._mesh_axis_size(mesh, rules_c.rules["batch"])
    while n_acc > 1 and (shape.global_batch // n_acc) % batch_ways:
        n_acc //= 2

    p_shapes, axes = abstract_model(cfg)
    if fsdp is None:
        # ZeRO-3: when the tensor-parallel bf16 copy alone would eat HBM,
        # keep compute params fully sharded and let GSPMD all-gather each
        # layer slice inside the scan (traffic moves to the roofline's
        # collective term; memory term drops by ~data-axis x).
        tp_bytes = 2 * cfg.param_count() / mesh.shape["model"]
        fsdp = tp_bytes > 2.5e9
    compute_shardings = shd.tree_shardings(
        rules_s if fsdp else rules_c, p_shapes, axes
    )
    state_shapes = jax.eval_shape(init_state, p_shapes)
    master_shardings = shd.tree_shardings(rules_s, state_shapes.params, axes)
    state_shardings = TrainState(
        step=shd.NamedSharding(mesh, shd.P()),
        params=master_shardings, m=master_shardings, v=master_shardings,
    )
    specs = input_specs(cfg, shape, masked=masked)
    b_shardings = shd.batch_shardings(rules_c, specs)
    cdt = dtype_of(cfg.dtype)

    loss_kw = {}
    if mode == "seq":
        # sequence parallelism: the device-local S/|model| token block IS the
        # attention q-chunk — no q-chunk loop to fight the sharding (§Perf B3)
        loss_kw = dict(q_chunk=shape.seq_len, kv_chunk=1024)
    elif (cfg.family in ("dense", "vlm", "moe")
          and cfg.n_kv_heads % mesh.shape["model"] != 0):
        # §Perf B5: GQA with kv_heads < model axis — pin K/V replicated and
        # Q sharded on heads (GSPMD pads the uneven head count) so score
        # contractions never split head_dim (which all-reduces per chunk)
        bx = rules_c.rules["batch"]
        loss_kw = dict(
            q_spec=shd.NamedSharding(mesh, shd.P(bx, None, "model", None)),
            kv_spec=shd.NamedSharding(mesh, shd.P(bx, None, None, None)),
        )

    def mb_loss(params, mb):
        return loss(cfg, params, mb, remat=remat, **loss_kw)

    def train_step(state: TrainState, batch):
        params_c = jax.tree.map(lambda t: t.astype(cdt), state.params)
        params_c = jax.lax.with_sharding_constraint(params_c, compute_shardings)
        if n_acc == 1:
            l, grads = jax.value_and_grad(mb_loss)(params_c, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            grads = jax.lax.with_sharding_constraint(grads, master_shardings)
        else:
            mb_shape = jax.tree.map(
                lambda t: t.reshape((n_acc, t.shape[0] // n_acc) + t.shape[1:]),
                batch,
            )
            # the reshape splits the global batch dim; pin the *microbatch*
            # dim to the data axes (GSPMD would otherwise shard the n_acc
            # loop dim and leave each microbatch replicated-wide)
            mb_shardings = {
                k: rules_c.sharding(
                    (None, "batch") + (("seq",) if v.ndim > 2 else ())
                    + (None,) * max(v.ndim - 3, 0),
                    tuple(v.shape))
                for k, v in mb_shape.items()
            }
            mb_shape = jax.lax.with_sharding_constraint(mb_shape, mb_shardings)

            def acc(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(mb_loss)(params_c, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g
                )
                gsum = jax.lax.with_sharding_constraint(gsum, master_shardings)
                return (gsum, lsum + l), None

            g0 = jax.tree.map(
                lambda t: jnp.zeros(t.shape, jnp.float32), state.params
            )
            g0 = jax.lax.with_sharding_constraint(g0, master_shardings)
            (grads, lsum), _ = jax.lax.scan(acc, (g0, 0.0), mb_shape)
            grads = jax.tree.map(lambda g: g / n_acc, grads)
            l = lsum / n_acc
        new_state, metrics = apply_updates(opt, state, grads)
        return new_state, dict(metrics, loss=l)

    fn = jax.jit(
        train_step,
        in_shardings=(state_shardings, b_shardings),
        out_shardings=(state_shardings, None),
        donate_argnums=(0,),
    )
    return BuiltStep(
        fn=fn,
        in_shardings=(state_shardings, b_shardings),
        out_shardings=(state_shardings, None),
        abstract_args=(state_shapes, specs),
        meta=dict(kind="train", n_acc=n_acc, rules_c=rules_c, rules_s=rules_s,
                  compute_shardings=compute_shardings, axes=axes,
                  param_shapes=p_shapes, fsdp=fsdp),
    )


def init_train_state(cfg: ModelConfig, built: BuiltStep, seed: int = 0) -> TrainState:
    """Concrete sharded initialization (used at real-run scale)."""
    state_shardings = built.in_shardings[0]

    def _init():
        params, _ = init_model(cfg, jax.random.key(seed))
        return init_state(params)

    return jax.jit(_init, out_shardings=state_shardings)()


# -- serving -----------------------------------------------------------------


def _cache_for(cfg: ModelConfig, shape: ShapeConfig, dtype):
    if cfg.family == "encdec":
        return encdec_mod.init_encdec_cache(
            cfg, shape.global_batch, shape.seq_len, shape.seq_len, dtype
        )
    return lm_mod.init_lm_cache(cfg, shape.global_batch, shape.seq_len, dtype)


def build_decode_step(cfg: ModelConfig, shape: ShapeConfig, mesh) -> BuiltStep:
    rules = shd.serve_rules(mesh, batch=shape.global_batch,
                            kv_heads=cfg.n_kv_heads, seq=shape.seq_len)
    cdt = dtype_of(cfg.dtype)
    p_shapes, axes = abstract_model(cfg)
    p_shardings = shd.tree_shardings(rules, p_shapes, axes)
    cache_shapes, cache_axes = abstract_cache(cfg, shape, cdt)
    c_shardings = shd.tree_shardings(rules, cache_shapes, cache_axes)
    rep = shd.NamedSharding(mesh, shd.P())
    tok_shard = rules.sharding(("batch", None), (shape.global_batch, 1))

    if cfg.family == "encdec":
        def decode(params, cache, token, pos):
            return encdec_mod.encdec_decode_step(cfg, params, token, cache, pos)
    else:
        def decode(params, cache, token, pos):
            return lm_mod.lm_decode_step(cfg, params, token, cache, pos)

    fn = jax.jit(
        decode,
        in_shardings=(p_shardings, c_shardings, tok_shard, rep),
        out_shardings=(None, c_shardings),
        donate_argnums=(1,),
    )
    tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return BuiltStep(
        fn=fn,
        in_shardings=(p_shardings, c_shardings, tok_shard, rep),
        out_shardings=(None, c_shardings),
        abstract_args=(p_shapes, cache_shapes, tok, pos),
        meta=dict(kind="decode", rules=rules, axes=axes, cache_axes=cache_axes,
                  param_shapes=p_shapes),
    )


def build_prefill_step(cfg: ModelConfig, shape: ShapeConfig, mesh) -> BuiltStep:
    rules = shd.serve_rules(mesh, batch=shape.global_batch,
                            kv_heads=cfg.n_kv_heads, seq=shape.seq_len)
    cdt = dtype_of(cfg.dtype)
    p_shapes, axes = abstract_model(cfg)
    p_shardings = shd.tree_shardings(rules, p_shapes, axes)
    cache_shapes, cache_axes = abstract_cache(cfg, shape, cdt)
    c_shardings = shd.tree_shardings(rules, cache_shapes, cache_axes)
    specs = input_specs(cfg, shape)
    b_shardings = shd.batch_shardings(rules, specs)

    if cfg.family == "encdec":
        def prefill(params, cache, batch):
            new_cache, _enc = encdec_mod.encdec_prefill(
                cfg, params, batch["frames"], cache
            )
            return jnp.zeros((batch["frames"].shape[0], 1, cfg.vocab), jnp.float32), new_cache
    else:
        def prefill(params, cache, batch):
            return lm_mod.lm_prefill(
                cfg, params, batch["tokens"], cache,
                patch_embeds=batch.get("patch_embeds"),
            )

    fn = jax.jit(
        prefill,
        in_shardings=(p_shardings, c_shardings, b_shardings),
        out_shardings=(None, c_shardings),
        donate_argnums=(1,),
    )
    return BuiltStep(
        fn=fn,
        in_shardings=(p_shardings, c_shardings, b_shardings),
        out_shardings=(None, c_shardings),
        abstract_args=(p_shapes, cache_shapes, specs),
        meta=dict(kind="prefill", rules=rules, axes=axes, param_shapes=p_shapes),
    )


def build_step(cfg: ModelConfig, shape: ShapeConfig, mesh, **kw) -> BuiltStep:
    if shape.kind == "train":
        return build_train_step(cfg, shape, mesh, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, mesh)
    if shape.kind == "decode":
        return build_decode_step(cfg, shape, mesh)
    raise ValueError(shape.kind)
