"""Training launcher: any assigned arch (smoke scale on CPU; production
shardings at pod scale — the same builders the dry-run compiles).

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
        --steps 20
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, train_accumulation
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.launch.placement import PodTopology, plan_pipeline
from repro.launch.steps import build_train_step, init_train_state
from repro.models.config import SHAPES, ShapeConfig
from repro.optim.adamw import OptConfig
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k", choices=[k for k, v in SHAPES.items()
                                                            if v.kind == "train"])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + tiny shape (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--production-mesh", action="store_true",
                    help="16x16 mesh (requires a pod or 256 host devices)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.smoke:
        shape = ShapeConfig("train", "train", seq_len=64, global_batch=4)
        mesh = make_local_mesh(1, 1)
        n_acc = 1
    else:
        shape = SHAPES[args.shape]
        mesh = make_production_mesh()
        n_acc = train_accumulation(args.arch)

    plan = plan_pipeline(cfg, shape, PodTopology(pods=1), steps_per_sec=0.1)
    if plan:
        print(f"[placement] stages->slices {plan.stage_slices} "
              f"(lat {plan.latency_us:.1f}us)")

    built = build_train_step(cfg, shape, mesh, OptConfig(
        lr=1e-3, warmup_steps=5, total_steps=max(args.steps, 100)),
        n_acc=n_acc, masked=True)
    state = init_train_state(cfg, built)
    data = Prefetcher(iter(SyntheticLM(cfg.vocab, shape.seq_len,
                                       shape.global_batch, seed=0)))
    tr = Trainer(TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=10),
                 state, built.fn, data, state_shardings=built.in_shardings[0])
    tr.run(args.steps)
    losses = [m["loss"] for m in tr.metrics_log]
    print(f"{args.arch}: {len(losses)} steps, loss {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
