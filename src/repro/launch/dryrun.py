import os
_SCALE = int(os.environ.get("REPRO_DRYRUN_SCALE", "16"))  # mesh edge (tests: 4)
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=" + str(2 * _SCALE * _SCALE)
)
# ^ MUST precede every other import (jax locks the device count on first
#   init).  This module is the ONLY place the 512-device world is created;
#   tests/benches see the real single CPU device.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the production mesh (16x16 single-pod or 2x16x16
multi-pod), constructs the jit'd step (train_step / prefill / serve_step)
with full production shardings, then::

    lowered  = step.lower(*abstract_inputs)      # ShapeDtypeStructs only
    compiled = lowered.compile()
    compiled.memory_analysis()                   # proves it fits HBM
    compiled.cost_analysis()                     # FLOPs / bytes for roofline

and extracts the collective-traffic profile (all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute operand bytes) from the
optimized HLO — cost_analysis does not report collectives (EXPERIMENTS.md
§Dry-run / §Roofline read these JSONs).

Usage::

    python -m repro.launch.dryrun --arch qwen2.5-14b --shape train_4k \
        --mesh single --out results/dryrun
"""
import argparse
import json
import re
import time
import traceback

import jax

from repro.configs import LONG_CONTEXT_OK, get_config, train_accumulation, train_mode
from repro.launch import hlo_cost
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step
from repro.models.config import SHAPES

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_profile(hlo_text: str) -> dict:
    """Sum operand bytes per collective kind from optimized HLO text."""
    prof = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    biggest: list = []
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)(\()", line)
        if not m:
            continue
        op = m.group(2)
        kind = None
        for k in _COLLECTIVES:
            if op == k or op.startswith(k + "-"):  # e.g. all-reduce-start
                kind = k
                break
        if kind is None or op.endswith("-done"):
            continue
        # operand types: inside the call parens
        call = line[line.index(m.group(3)) :]
        operands = _shape_bytes(call)
        if operands == 0:  # fall back to result type
            operands = _shape_bytes(m.group(1))
        prof[kind]["count"] += 1
        prof[kind]["bytes"] += operands
        biggest.append((operands, kind, line[:160]))
    biggest.sort(reverse=True)
    prof["top_ops"] = [
        {"bytes": b, "kind": k, "hlo": h} for b, k, h in biggest[:12]
    ]
    return prof


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             *, save_hlo: bool = False) -> dict:
    t0 = time.time()
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and arch not in LONG_CONTEXT_OK:
        raise SystemExit(f"{arch} x long_500k is a documented skip (DESIGN.md §6)")
    if _SCALE == 16:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    else:  # test scale: same topology, smaller edge
        from repro.launch.mesh import _mk
        if mesh_kind == "multi":
            mesh = _mk((2, _SCALE, _SCALE), ("pod", "data", "model"))
        else:
            mesh = _mk((_SCALE, _SCALE), ("data", "model"))
    kw = {}
    if shape.kind == "train":
        kw["n_acc"] = train_accumulation(arch)
        kw["mode"] = train_mode(arch)
    with mesh:
        built = build_step(cfg, shape, mesh, **kw)
        lowered = built.fn.lower(*built.abstract_args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):  # jax<=0.4.x: one dict per program
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()

    prof = collective_profile(hlo)
    loop_aware = hlo_cost.analyze(hlo)
    n_chips = mesh.devices.size
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "chips": int(n_chips),
        "kind": shape.kind,
        "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "n_acc": kw.get("n_acc", 1),
        "mode": kw.get("mode", "tp"),
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "timing": {"lower_s": t_lower, "compile_s": t_compile},
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "cost": {
            "flops": cost.get("flops"),
            "bytes_accessed": cost.get("bytes accessed"),
            "transcendentals": cost.get("transcendentals"),
        },
        # loop-aware per-device profile (launch/hlo_cost.py): the roofline
        # source of truth — XLA cost_analysis counts while bodies once.
        "loop_aware": loop_aware,
        "collectives": prof,
    }
    os.makedirs(out_dir, exist_ok=True)
    stem = f"{arch.replace('/', '_')}__{shape_name}__{mesh_kind}"
    with open(os.path.join(out_dir, stem + ".json"), "w") as f:
        json.dump(result, f, indent=1)
    if save_hlo:
        with open(os.path.join(out_dir, stem + ".hlo.txt"), "w") as f:
            f.write(hlo)
    print(f"[dryrun] {stem}: compile={t_compile:.1f}s "
          f"flops={result['cost']['flops']:.3e} "
          f"mem(arg={result['memory']['argument_bytes']}, "
          f"temp={result['memory']['temp_bytes']})")
    print("memory_analysis:", mem)
    print("cost_analysis keys:", {k: cost[k] for k in sorted(cost) if isinstance(cost[k], (int, float))})
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()
    run_cell(args.arch, args.shape, args.mesh, args.out, save_hlo=args.save_hlo)


if __name__ == "__main__":
    main()
