"""Mesh construction (function, not module-level constant: importing this
module never touches jax device state)."""
from __future__ import annotations

import jax


def _mk(shape, axes):
    if hasattr(jax.sharding, "AxisType"):  # jax >= 0.6 explicit-sharding API
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """Single v5e pod: 16x16 (data, model).  Multi-pod: 2 pods x 16 x 16
    (pod, data, model); the ``pod`` axis is crossed by DCI, so only
    batch/gradient traffic is mapped onto it (dist/sharding.py)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = jax.device_count()
    assert data * model <= n, f"need {data * model} devices, have {n}"
    return _mk((data, model), ("data", "model"))
