"""Sweep driver: run every (arch x shape x mesh) dry-run cell in a fresh
subprocess (each compile gets a clean XLA world; one bad cell can't kill the
sweep).  Writes per-cell JSON to --out and a summary line per cell.

    PYTHONPATH=src python -m repro.launch.dryrun_all --out results/dryrun
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.configs import cells
from repro.models.config import SHAPES


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--meshes", default="single,multi")
    ap.add_argument("--only", default="", help="substring filter arch__shape")
    ap.add_argument("--skip-done", action="store_true", default=True)
    ap.add_argument("--timeout", type=int, default=1800)
    args = ap.parse_args()

    meshes = args.meshes.split(",")
    todo = []
    for arch, shape in cells():
        for mesh in meshes:
            stem = f"{arch}__{shape}__{mesh}"
            if args.only and args.only not in stem:
                continue
            if args.skip_done and os.path.exists(
                os.path.join(args.out, stem + ".json")
            ):
                print(f"[skip] {stem}")
                continue
            todo.append((arch, shape, mesh, stem))

    failures = []
    for i, (arch, shape, mesh, stem) in enumerate(todo):
        t0 = time.time()
        cmd = [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", arch, "--shape", shape, "--mesh", mesh, "--out", args.out,
        ]
        print(f"[{i+1}/{len(todo)}] {stem} ...", flush=True)
        try:
            p = subprocess.run(
                cmd, capture_output=True, text=True, timeout=args.timeout,
                env=dict(os.environ, PYTHONPATH="src"),
            )
            ok = p.returncode == 0
        except subprocess.TimeoutExpired:
            ok, p = False, None
        dt = time.time() - t0
        if ok:
            print(f"    OK in {dt:.0f}s", flush=True)
        else:
            msg = (p.stderr[-2000:] if p else "TIMEOUT")
            failures.append({"cell": stem, "err": msg})
            print(f"    FAIL in {dt:.0f}s: {msg[-300:]}", flush=True)
    with open(os.path.join(args.out, "_failures.json"), "w") as f:
        json.dump(failures, f, indent=1)
    print(f"done: {len(todo) - len(failures)}/{len(todo)} cells OK")


if __name__ == "__main__":
    main()
