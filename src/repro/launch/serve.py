"""Serving launcher: prefill + continuous-batching decode for any assigned
arch (smoke scale on CPU; the pod-scale decode step is what the dry-run
compiles for the decode_* shape cells).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
        --requests 4
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.placement import PodTopology, plan_serving
from repro.models.config import SHAPES
from repro.models.registry import init_model
from repro.serving import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.7)
    args = ap.parse_args()

    full = get_config(args.arch)
    plan = plan_serving(full, SHAPES["decode_32k"], PodTopology(pods=1),
                        requests_per_sec=100.0)
    if plan:
        print(f"[placement] decode dataflow -> slices {plan.stage_slices}")

    cfg = get_config(args.arch, smoke=True)
    if cfg.family == "encdec":
        from repro.models import encdec as ed
        import jax.numpy as jnp
        params, _ = init_model(cfg, jax.random.key(0))
        frames = jnp.asarray(np.random.default_rng(0).normal(
            0, 0.02, (args.requests, 16, cfg.d_model)), jnp.float32)
        cache, _ = ed.init_encdec_cache(cfg, args.requests, 64, 16, jnp.float32)
        cache, _ = ed.encdec_prefill(cfg, params, frames, cache, remat=False)
        tok = jnp.zeros((args.requests, 1), jnp.int32)
        outs = []
        for pos in range(args.max_new):
            logits, cache = ed.encdec_decode_step(cfg, params, tok, cache,
                                                  jnp.int32(pos))
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
            outs.append(np.asarray(tok[:, 0]))
        print(f"{args.arch} (enc-dec): decoded {args.max_new} steps x "
              f"{args.requests} streams: {np.stack(outs).T.tolist()}")
        return

    params, _ = init_model(cfg, jax.random.key(0))
    eng = Engine(cfg, params, n_slots=args.slots, max_len=64,
                 temperature=args.temperature, top_k=20)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        L = int(rng.integers(4, 10))
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab, L).astype(np.int32),
                           max_new=args.max_new))
    done, ticks = eng.run()
    print(f"{args.arch}: served {len(done)} requests "
          f"({sum(len(r.out) for r in done)} tokens, {ticks} ticks)")


if __name__ == "__main__":
    main()
