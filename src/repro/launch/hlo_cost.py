"""HLO cost model: loop-aware FLOPs / HBM bytes / collective bytes.

``compiled.cost_analysis()`` counts every computation ONCE — a
scanned-layers ``while`` body (trip count 48) or a grad-accumulation loop is
under-counted by its trip count, which would wreck the roofline.  This
module parses the *optimized* (post-SPMD) HLO text and walks the call graph
with multipliers:

- ``while``       -> body/condition weighted by the trip count, recovered
                     from the condition's ``compare(iter, constant)``;
- ``fusion/call/to_apply`` -> callee weighted by caller (bytes are counted
                     at the *call site* — fusion internals don't touch HBM);
- ``conditional`` -> every branch weighted by caller (upper bound; the hot
                     paths contain no conditionals by construction).

Per instruction:
- FLOPs: ``dot`` = 2 x prod(result dims) x prod(contracting dims)
  (counted in whatever computation it appears, incl. fusion bodies);
- HBM bytes: operand + result bytes of top-level instructions (parameter /
  constant / gte / tuple / bitcast excluded; fusion-internal computations
  excluded) — the same operand+output convention XLA's own
  ``bytes accessed`` uses;
- collective bytes: operand bytes of all-reduce / all-gather /
  reduce-scatter / all-to-all / collective-permute (+ their async -start
  forms), attributed per kind.

All quantities are PER DEVICE (the compiled module is the per-device SPMD
program).  This is the profiler of record for EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "s32": 4, "u32": 4,
    "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
# result type matched non-greedily: handles tuple types with layout braces
# and /*index=N*/ comments; first `op(` after the type is the opcode.
_INSTR_RE = re.compile(
    r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\("
)
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_CALLS_RE = re.compile(
    r"(?:calls=|to_apply=|condition=|body=|true_computation=|false_computation=)"
    r"%?([\w.\-]+)"
)
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((-?\d+)\)")
_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
    # control-flow shells: carries alias in place; their bodies' ops are
    # counted (with multipliers) instead
    "while", "conditional", "call",
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    line: str
    call_str: str  # from the opcode's opening paren (operands + attrs)


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    is_entry: bool = False


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        cm = _COMP_RE.match(line.strip())
        if cm and line.strip().endswith("{"):
            cur = Computation(cm.group(2), [], is_entry=bool(cm.group(1)))
            comps[cur.name] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        im = _INSTR_RE.match(line)
        if im:
            cur.instrs.append(
                Instr(im.group(2), im.group(3), im.group(4), line.strip(),
                      line[im.end() - 1 :])
            )
    return comps


_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _trip_count(cond: Computation, consts: dict[str, int]) -> int | None:
    """Recover trip count from compare(iter, const) in the loop condition."""
    local = dict(consts)
    for ins in cond.instrs:
        if ins.op == "constant":
            m = _CONST_RE.search(ins.line)
            if m:
                local[ins.name] = int(m.group(1))
    for ins in cond.instrs:
        if ins.op == "compare" and ("direction=LT" in ins.line or "direction=GT" in ins.line):
            # operand constants may be inlined: compare(s32[] %i, s32[] %c)
            m = _CONST_RE.search(ins.line)
            if m:
                return int(m.group(1))
            names = re.findall(r"%([\w.\-]+)", ins.line[ins.line.index("("):])
            for n in names:
                if n in local:
                    return local[n]
    return None


def _instr_flops(ins: Instr, types: dict[str, str]) -> float:
    if ins.op != "dot" and ins.op != "convolution":
        return 0.0
    out_elems = 1
    for d in _dims(ins.type_str):
        out_elems *= d
    if ins.op == "convolution":
        # rough: 2 * out * kernel_elems; kernel = second operand
        names = re.findall(r"%([\w.\-]+)", ins.line[ins.line.index("("):])
        kdims = _dims(types.get(names[1], "")) if len(names) > 1 else []
        k = 1
        for d in kdims[:-1]:
            k *= d
        return 2.0 * out_elems * max(k, 1)
    # dot: contracting dims of the lhs
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    names_m = re.search(r"\(\s*([a-z0-9]+\[[\d,]*\][^%]*)?%([\w.\-]+)", ins.call_str)
    # operand types may be inline or resolved from the definitions map
    inline = re.findall(r"([a-z0-9]+\[[\d,]*\])[^,)]*%([\w.\-]+)", ins.call_str.split("contracting")[0])
    lhs_type = None
    if inline:
        lhs_type = inline[0][0]
    elif names_m:
        lhs_type = types.get(names_m.group(2))
    cdims = []
    if mc and lhs_type:
        ld = _dims(lhs_type)
        cdims = [ld[int(i)] for i in mc.group(1).split(",") if i != "" and int(i) < len(ld)]
    k = 1
    for c in cdims:
        k *= c
    return 2.0 * out_elems * k


def analyze(text: str) -> dict:
    comps = parse_hlo(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        raise ValueError("no ENTRY computation found")

    # name -> type map (per computation namespace is fine: names are unique
    # module-wide in optimized HLO)
    types: dict[str, str] = {}
    for c in comps.values():
        for ins in c.instrs:
            types[ins.name] = ins.type_str

    # which computations are fusion bodies (skip byte accounting there)
    fusion_bodies: set[str] = set()
    for c in comps.values():
        for ins in c.instrs:
            if ins.op == "fusion":
                for callee in _CALLS_RE.findall(ins.line):
                    fusion_bodies.add(callee)

    # multipliers via BFS over the call graph
    mult: dict[str, float] = defaultdict(float)
    mult[entry.name] = 1.0
    order = [entry.name]
    seen = {entry.name}
    warnings: list[str] = []
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        c = comps.get(cname)
        if c is None:
            continue
        m = mult[cname]
        for ins in c.instrs:
            callees = _CALLS_RE.findall(ins.line)
            bm = _BRANCHES_RE.search(ins.line)
            if bm:
                callees += [s.strip().lstrip("%") for s in bm.group(1).split(",")]
            if not callees:
                continue
            if ins.op == "while":
                cond_name = re.search(r"condition=%?([\w.\-]+)", ins.line)
                body_name = re.search(r"body=%?([\w.\-]+)", ins.line)
                trip = None
                tm = _TRIP_RE.search(ins.line)  # XLA-annotated trip count
                if tm:
                    trip = int(tm.group(1))
                if trip is None and cond_name and cond_name.group(1) in comps:
                    trip = _trip_count(comps[cond_name.group(1)], {})
                if trip is None:
                    trip = 1
                    warnings.append(f"unknown trip count for {ins.name}; using 1")
                for nm, f in ((cond_name, trip + 1), (body_name, trip)):
                    if nm:
                        n = nm.group(1)
                        mult[n] += m * f
                        if n not in seen:
                            seen.add(n)
                            order.append(n)
            else:
                for n in callees:
                    mult[n] += m
                    if n not in seen:
                        seen.add(n)
                        order.append(n)

    flops = 0.0
    bytes_hbm = 0.0
    coll = {k: {"count": 0.0, "bytes": 0.0} for k in _COLLECTIVES}
    top_ops: list = []
    for c in comps.values():
        m = mult.get(c.name, 0.0)
        if m == 0.0:
            continue
        count_bytes = c.name not in fusion_bodies
        for ins in c.instrs:
            flops += m * _instr_flops(ins, types)
            # collective?
            kind = None
            for k in _COLLECTIVES:
                if ins.op == k or ins.op.startswith(k + "-"):
                    kind = k
                    break
            if kind and not ins.op.endswith("-done"):
                b = _type_bytes(ins.call_str)
                if b == 0:
                    b = _type_bytes(ins.type_str)
                coll[kind]["count"] += m
                coll[kind]["bytes"] += m * b
                top_ops.append((m * b, kind, ins.line[:200]))
            if count_bytes and ins.op not in _SKIP_BYTES_OPS:
                inplace_fusion = ins.op == "fusion" and (
                    "dynamic-update-slice" in ins.name or "scatter" in ins.name
                    or "dynamic_update_slice" in ins.name
                )
                if inplace_fusion:
                    # XLA fuses DUS roots in place: the carried buffer appears
                    # as both operand and result but is not re-written; real
                    # traffic = everything minus two copies of that buffer.
                    all_b = _type_bytes(ins.call_str.split(" metadata=")[0]) \
                        + _type_bytes(ins.type_str)
                    sizes = [
                        _type_bytes(s)
                        for s in re.findall(r"[a-z0-9]+\[[\d,]*\]", ins.call_str)
                    ]
                    big = max(sizes, default=0)
                    bytes_hbm += m * max(all_b - 2 * big, 0)
                elif ins.op in ("dynamic-update-slice", "scatter"):
                    # in-place update: traffic ~ 2x the update operand, not
                    # the full buffer (matches XLA's in-place accounting)
                    ops_inline = re.findall(
                        r"([a-z0-9]+\[[\d,]*\])[^,)]*?%", ins.call_str
                    )
                    upd = _type_bytes(ops_inline[1]) if len(ops_inline) > 1 else 0
                    if upd == 0:
                        nms = re.findall(r"%([\w.\-]+)", ins.call_str)
                        if len(nms) > 1:
                            upd = _type_bytes(types.get(nms[1], ""))
                    bytes_hbm += m * 2 * upd
                elif ins.op in ("dynamic-slice", "slice", "gather"):
                    bytes_hbm += m * 2 * _type_bytes(ins.type_str)
                else:
                    # operand types are inlined in the call when present;
                    # fall back to the definitions map
                    ob = _type_bytes(ins.call_str.split(" metadata=")[0])
                    if ob == 0:
                        for nm in re.findall(r"%([\w.\-]+)", ins.call_str)[:8]:
                            ob += _type_bytes(types.get(nm, ""))
                    bytes_hbm += m * (ob + _type_bytes(ins.type_str))
    top_ops.sort(key=lambda t: -t[0])
    return {
        "flops": flops,
        "bytes_hbm": bytes_hbm,
        "collectives": coll,
        "collective_bytes_total": sum(v["bytes"] for v in coll.values()),
        "top_collectives": [
            {"bytes": b, "kind": k, "hlo": h} for b, k, h in top_ops[:12]
        ],
        "warnings": warnings[:10],
        "n_computations": len(comps),
    }
