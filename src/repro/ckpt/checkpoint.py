"""Pytree checkpoints: per-leaf .npy shards, atomic commit, async save,
elastic restore (reshard onto a different mesh on load).

Layout::

    <dir>/step_000123.tmp/...   (write)
    <dir>/step_000123/          (atomic rename on completion)
        META.json               (treedef paths, shapes, dtypes, step)
        leaf_00000.npy ...

Restore never requires the saving mesh: leaves are loaded host-side and
``device_put`` with shardings computed for the *current* mesh — this is the
elastic-scaling path (checkpoint-restart onto however many devices survive).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Callable, Optional

import jax
import numpy as np

Pytree = Any


def _flatten_with_names(tree: Pytree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
             for path, _ in flat]
    leaves = [l for _, l in flat]
    return names, leaves, jax.tree.structure(tree)


def save(directory: str, step: int, tree: Pytree, *, blocking: bool = True):
    """Atomic checkpoint write. Returns the thread when ``blocking=False``."""
    host_tree = jax.tree.map(np.asarray, tree)  # device->host copy now

    def _write():
        names, leaves, _ = _flatten_with_names(host_tree)
        final = os.path.join(directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp, exist_ok=True)
        meta = {"step": step, "leaves": []}
        for i, (name, leaf) in enumerate(zip(names, leaves)):
            fn = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fn), leaf)
            meta["leaves"].append(
                {"name": name, "file": fn, "shape": list(leaf.shape),
                 "dtype": str(leaf.dtype)}
            )
        with open(os.path.join(tmp, "META.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(m.group(1))
        for m in (re.fullmatch(r"step_(\d+)", d) for d in os.listdir(directory))
        if m
    ]
    return max(steps) if steps else None


def restore(directory: str, template: Pytree, *, step: Optional[int] = None,
            sharding_tree: Optional[Pytree] = None) -> tuple[Pytree, int]:
    """Load into the structure of ``template``.  ``sharding_tree`` (same
    structure) redistributes leaves onto the current mesh (elastic restore).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "META.json")) as f:
        meta = json.load(f)
    leaves = [np.load(os.path.join(path, e["file"])) for e in meta["leaves"]]
    treedef = jax.tree.structure(template)
    if treedef.num_leaves != len(leaves):
        raise ValueError(
            f"checkpoint has {len(leaves)} leaves, template {treedef.num_leaves}"
        )
    tree = jax.tree.unflatten(treedef, leaves)
    if sharding_tree is not None:
        flat_t, td = jax.tree.flatten(tree)
        flat_s = td.flatten_up_to(sharding_tree)
        tree = jax.tree.unflatten(
            td, [jax.device_put(t, s) for t, s in zip(flat_t, flat_s)]
        )
    return tree, step


def prune(directory: str, keep: int = 3):
    """Retain only the newest ``keep`` checkpoints."""
    if not os.path.isdir(directory):
        return
    steps = sorted(
        int(m.group(1))
        for m in (re.fullmatch(r"step_(\d+)", d) for d in os.listdir(directory))
        if m
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"), ignore_errors=True)
