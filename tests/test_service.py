"""Control-plane service layer: weighted max-min fair admission, preemption
classes, background defragmentation, and conservation invariants under
adversarial interleavings (seeded fuzz)."""
import numpy as np
import pytest

from repro.core import (
    DataflowPath,
    OnlinePlacer,
    ResourceGraph,
    random_dataflow,
    waxman,
)
from repro.core.engine import Stats, _unify
from repro.service import (
    CLASS_BEST_EFFORT,
    CLASS_CRITICAL,
    ControlPlane,
    FairSharePolicy,
    defrag,
    global_objective,
    maxmin_shares,
    may_preempt,
)

PYM = dict(method="leastcost_python")  # pure-python backend: fast, no jit


def _line_rg(mid_cap: float = 4.0, bw: float = 50.0) -> ResourceGraph:
    """0 -- 1 -- 2 with all compute capacity on node 1."""
    return ResourceGraph.from_edge_list(
        [0.0, mid_cap, 0.0], [(0, 1, bw, 1.0), (1, 2, bw, 1.0)]
    )


def _unit_df(creq: float = 0.5, breq: float = 1.0) -> DataflowPath:
    return DataflowPath.make([0.0, creq, 0.0], [breq, breq], src=0, dst=2)


# ---------------------------------------------------------------------------
# policy: weighted max-min water-filling
# ---------------------------------------------------------------------------


def test_maxmin_shares_waterfilling():
    # both saturated: pure weight split
    assert maxmin_shares({"a": 10, "b": 10}, {"a": 3, "b": 1}, 8) == {
        "a": 6.0, "b": 2.0,
    }
    # a demands less than its share: surplus redistributes to b
    s = maxmin_shares({"a": 1, "b": 10}, {"a": 3, "b": 1}, 8)
    assert s["a"] == 1 and s["b"] == pytest.approx(7.0)
    # capacity exceeds total demand: everyone fully satisfied
    s = maxmin_shares({"a": 2, "b": 3}, {"a": 1, "b": 1}, 100)
    assert s == {"a": 2, "b": 3}
    # zero-demand tenant gets nothing, three-way redistribution
    s = maxmin_shares({"a": 0, "b": 5, "c": 50}, {"a": 1, "b": 1, "c": 1}, 12)
    assert s["a"] == 0 and s["b"] == pytest.approx(5) and s["c"] == pytest.approx(7)
    # shares never exceed capacity
    assert sum(s.values()) <= 12 + 1e-9


def test_may_preempt_strict_order():
    assert may_preempt(0, 1) and may_preempt(1, 2)
    assert not may_preempt(1, 1) and not may_preempt(2, 1)


# ---------------------------------------------------------------------------
# fair admission
# ---------------------------------------------------------------------------


def test_weighted_drain_converges_to_weight_shares():
    """Two saturated tenants, weights 3:1, identical unit requests on a
    single bottleneck node: standing committed capacity must split 3:1."""
    cp = ControlPlane(_line_rg(mid_cap=4.0), micro_batch=8,
                      policy=FairSharePolicy(slack=0.5), **PYM)
    cp.register_tenant("a", weight=3.0)
    cp.register_tenant("b", weight=1.0)
    for _ in range(16):
        cp.submit("a", _unit_df())
        cp.submit("b", _unit_df())
    for _ in range(4):
        cp.pump()
        cp.check_invariants()
    held = cp.committed_capacity()
    assert held["a"] == pytest.approx(3.0, abs=0.51)
    assert held["b"] == pytest.approx(1.0, abs=0.51)
    assert held["a"] + held["b"] == pytest.approx(4.0, abs=1e-6)
    rep = cp.fairness_report()
    assert rep["max_deviation"] <= 0.20


def test_fcfs_baseline_ignores_weights():
    """Same scenario through the bare placer (FCFS): the interleaved
    arrival order splits capacity ~1:1, not 3:1 — the contrast the control
    plane exists to fix."""
    placer = OnlinePlacer(_line_rg(mid_cap=4.0), **PYM)
    held = {"a": 0.0, "b": 0.0}
    for _ in range(16):
        for tenant in ("a", "b"):
            t = placer.admit(_unit_df(), tenant=tenant)
            if t is not None:
                held[tenant] += 0.5
    assert held["a"] == pytest.approx(held["b"], abs=0.51)


def test_budget_caps_committed_capacity():
    cp = ControlPlane(_line_rg(mid_cap=4.0), micro_batch=8, **PYM)
    cp.register_tenant("a", weight=1.0, budget=1.0)
    for _ in range(8):
        cp.submit("a", _unit_df())
    cp.pump(rounds=4)
    cp.check_invariants()
    assert cp.committed_capacity()["a"] <= 1.0 + 1e-9
    assert cp.conservation()["queued"] >= 6  # the rest waits, not dropped
    # the defrag retry path honors the budget too
    res = cp.defrag()
    cp.check_invariants()
    assert cp.committed_capacity()["a"] <= 1.0 + 1e-9
    assert len(res.readmitted) == 0


def test_pump_uses_micro_batches():
    cp = ControlPlane(waxman(16, seed=2), micro_batch=4, **PYM)
    cp.register_tenant("a")
    rg = cp.placer.base
    for i in range(8):
        cp.submit("a", random_dataflow(rg, 4, seed=50 + i,
                                       creq_range=(0.02, 0.1),
                                       breq_range=(0.5, 2.0)))
    cp.pump(rounds=2)
    assert cp.placer.stats.batches == 2  # two admit_many micro-batches
    cp.check_invariants()


# ---------------------------------------------------------------------------
# preemption classes
# ---------------------------------------------------------------------------


def _fill_with_best_effort(cp, k=8):
    for _ in range(k):
        cp.submit("lo", _unit_df(), klass=CLASS_BEST_EFFORT)
    cp.pump(rounds=2)


def test_preemption_displaces_strictly_lower_class():
    cp = ControlPlane(_line_rg(mid_cap=4.0), micro_batch=8, **PYM)
    cp.register_tenant("lo")
    cp.register_tenant("hi")
    _fill_with_best_effort(cp)
    assert cp.committed_capacity()["lo"] == pytest.approx(4.0)

    cp.submit("hi", _unit_df(), klass=CLASS_CRITICAL)
    admitted = cp.pump()
    cp.check_invariants()
    assert len(admitted) == 1 and admitted[0].klass == CLASS_CRITICAL
    assert cp.placer.stats.preempted == 1
    assert cp.tenants["lo"].preempted == 1
    # the preempted request re-entered its tenant queue, not the void
    ledger = cp.conservation()
    assert ledger["ok"] and ledger["dropped"] == 0
    # every surviving best-effort ticket was left alone except the victim
    assert cp.committed_capacity()["lo"] == pytest.approx(3.5)


def test_equal_class_never_preempts():
    cp = ControlPlane(_line_rg(mid_cap=4.0), micro_batch=8, max_attempts=2,
                      **PYM)
    cp.register_tenant("lo")
    cp.register_tenant("hi")
    _fill_with_best_effort(cp)
    cp.submit("hi", _unit_df(), klass=CLASS_BEST_EFFORT)  # same class
    assert cp.pump(rounds=2) == []
    cp.check_invariants()
    assert cp.placer.stats.preempted == 0
    assert cp.committed_capacity()["lo"] == pytest.approx(4.0)


def test_preemption_reclaims_same_window_victim():
    """A rejected request's preemption may displace a sibling admitted in
    the SAME committed micro-batch window.  The sibling must be found in
    the registry and requeued — not leaked as a foreign ticket and then
    'activated' after the placer already released it (the stale-registry
    regression: activation must precede reject handling)."""
    cp = ControlPlane(_line_rg(mid_cap=4.0), micro_batch=8, **PYM)
    cp.register_tenant("lo")
    cp.register_tenant("hi")
    cp.submit("lo", _unit_df(creq=2.0), klass=CLASS_BEST_EFFORT)
    cp.pump()
    assert cp.committed_capacity()["lo"] == pytest.approx(2.0)

    # one batch: hi (critical, needs 3 > residual 2 -> rejected by the
    # plain commit) drains ahead of lo (fits residual exactly); hi's
    # preemption then needs BOTH best-effort tickets — the standing one
    # and the same-window sibling
    cp.submit("hi", DataflowPath.make([0.0, 3.0, 0.0], [1.0, 1.0], 0, 2),
              klass=CLASS_CRITICAL)
    cp.submit("lo", _unit_df(creq=2.0), klass=CLASS_BEST_EFFORT)
    admitted = cp.pump()
    cp.check_invariants()
    assert [t.klass for t in admitted] == [CLASS_CRITICAL]
    assert cp.tenants["lo"].preempted == 2
    # registry and placer agree ticket-for-ticket (object identity)
    for _, tkt in cp.active.values():
        assert cp.placer.tickets.get(tkt.tid) is tkt
    assert cp.committed_capacity() == pytest.approx({"lo": 0.0, "hi": 3.0})
    # both displaced requests re-entered the queue, nothing leaked
    ledger = cp.conservation()
    assert ledger["ok"] and ledger["queued"] == 2 and ledger["dropped"] == 0


def test_preemption_rolls_back_when_it_cannot_help():
    """A request too big for the *base* network must not destroy standing
    capacity on a failed probe: conservative preemption restores
    everything."""
    placer = OnlinePlacer(_line_rg(mid_cap=4.0), **PYM)
    for _ in range(8):
        assert placer.admit(_unit_df(), tenant="lo", klass=0) is not None
    cap0, bw0 = placer.cap.copy(), placer.bw.copy()
    tids0 = set(placer.tickets)

    big = DataflowPath.make([0.0, 10.0, 0.0], [1.0, 1.0], src=0, dst=2)
    t, victims = placer.admit_preempting(big, klass=5, max_preempt=8)
    assert t is None and victims == []
    np.testing.assert_array_equal(placer.cap, cap0)
    np.testing.assert_array_equal(placer.bw, bw0)
    assert set(placer.tickets) == tids0
    assert placer.stats.preempted == 0
    placer.check_invariants()


def test_remap_prefers_higher_class_after_failure():
    """Degraded network fits one of two displaced tickets: the higher class
    survives, the lower is dropped (class-major re-admission order)."""
    # both tickets share node 1 (cap 2); the backup node 2 (cap 1) can hold
    # only one of them after node 1 fails
    rg = ResourceGraph.from_edge_list(
        [0.0, 2.0, 1.0, 0.0],
        [(0, 1, 50.0, 1.0), (1, 3, 50.0, 1.0),
         (0, 2, 50.0, 5.0), (2, 3, 50.0, 5.0)],
    )
    df = DataflowPath.make([0.0, 1.0, 0.0], [1.0, 1.0], src=0, dst=3)
    placer = OnlinePlacer(rg, **PYM)
    lo = placer.admit(df, tenant="lo", klass=0)
    hi = placer.admit(df, tenant="hi", klass=2)
    assert lo.mapping.assign[1] == 1 and hi.mapping.assign[1] == 1
    remapped, dropped = placer.fail_node(1)  # both displaced; node 2 fits 1
    assert [t.klass for t in remapped] == [2]  # high class won the backup
    assert [t.klass for t in dropped] == [0]
    # the remapped ticket kept its tid (external handles survive)
    assert remapped[0].tid == hi.tid
    placer.check_invariants()


# ---------------------------------------------------------------------------
# preemption cost budgets (bound how much lower-class work one admission
# may displace)
# ---------------------------------------------------------------------------


def _filled_placer():
    """Node 1 (cap 4.0) exactly filled by eight 0.5-creq class-0 tickets."""
    placer = OnlinePlacer(_line_rg(mid_cap=4.0), **PYM)
    for _ in range(8):
        assert placer.admit(_unit_df(), tenant="lo", klass=0) is not None
    return placer


def test_preempt_budget_exactly_at_budget_admits():
    """The request needs 1.0 freed (two 0.5 victims); a displaced-cost
    budget of exactly 1.0 admits."""
    placer = _filled_placer()
    big = DataflowPath.make([0.0, 1.0, 0.0], [1.0, 1.0], src=0, dst=2)
    t, victims = placer.admit_preempting(big, klass=2,
                                         max_displaced_cost=1.0)
    assert t is not None and len(victims) == 2
    assert sum(sum(v.node_load.values()) for v in victims) == pytest.approx(1.0)
    assert placer.stats.preempted == 2
    placer.check_invariants()


def test_preempt_budget_one_over_rolls_back_cleanly():
    """With budget 0.9 the second 0.5 victim would overshoot: the probe
    must stop and restore everything bit for bit."""
    placer = _filled_placer()
    cap0, bw0 = placer.cap.copy(), placer.bw.copy()
    tids0 = set(placer.tickets)
    big = DataflowPath.make([0.0, 1.0, 0.0], [1.0, 1.0], src=0, dst=2)
    t, victims = placer.admit_preempting(big, klass=2,
                                         max_displaced_cost=0.9)
    assert t is None and victims == []
    np.testing.assert_array_equal(placer.cap, cap0)
    np.testing.assert_array_equal(placer.bw, bw0)
    assert set(placer.tickets) == tids0
    assert placer.stats.preempted == 0
    placer.check_invariants()


def test_preempt_budget_zero_disables_displacement_but_not_admission():
    """Budget 0 forbids displacing anything, yet a request that fits the
    residual without victims still admits through the same call."""
    placer = _filled_placer()
    t, victims = placer.admit_preempting(_unit_df(), klass=2,
                                         max_displaced_cost=0.0)
    assert t is None and victims == []  # nothing free, nothing displaceable
    placer.release(next(iter(placer.tickets.values())))
    t, victims = placer.admit_preempting(_unit_df(), klass=2,
                                         max_displaced_cost=0.0)
    assert t is not None and victims == []  # fits the freed residual
    placer.check_invariants()


def test_preempt_reclaim_preserves_batch_order_within_class():
    """Re-queueing a batch of displaced victims must not reverse their
    relative order (front-of-class insertion is applied back-to-front)."""
    cp = ControlPlane(_line_rg(mid_cap=4.0), micro_batch=8, **PYM)
    cp.register_tenant("a")
    rids = [cp.submit("a", _unit_df()) for _ in range(3)]
    cp.pump()
    assert sorted(cp.active) == rids
    tickets = [cp.active[r][1] for r in rids]
    assert cp.preempt_reclaim(tickets) == []  # all owned here
    assert [r.rid for r in cp.tenants["a"].queue] == rids


def test_controlplane_preempt_budget_plumbs_through():
    big = DataflowPath.make([0.0, 1.0, 0.0], [1.0, 1.0], src=0, dst=2)
    for budget, admitted in ((1.0, True), (0.9, False)):
        cp = ControlPlane(_line_rg(mid_cap=4.0), micro_batch=8,
                          max_attempts=2, preempt_budget=budget, **PYM)
        cp.register_tenant("lo")
        cp.register_tenant("hi")
        _fill_with_best_effort(cp)
        cp.submit("hi", big, klass=CLASS_CRITICAL)
        out = cp.pump(rounds=2)
        cp.check_invariants()
        assert bool(out) is admitted, (budget, out)
        ledger = cp.conservation()
        assert ledger["ok"] and ledger["dropped"] == (0 if admitted else 1)
        assert cp.placer.stats.preempted == (2 if admitted else 0)


# ---------------------------------------------------------------------------
# defragmentation
# ---------------------------------------------------------------------------


def _two_route_rg():
    """0->3 via node 1 (cost 2) or node 2 (cost 10), one unit of compute
    capacity on each."""
    return ResourceGraph.from_edge_list(
        [0.0, 1.0, 1.0, 0.0],
        [(0, 1, 50.0, 1.0), (1, 3, 50.0, 1.0),
         (0, 2, 50.0, 5.0), (2, 3, 50.0, 5.0)],
    )


def test_defrag_recovers_churn_fragmentation_and_readmits():
    rg = _two_route_rg()
    df = DataflowPath.make([0.0, 1.0, 0.0], [1.0, 1.0], src=0, dst=3)
    placer = OnlinePlacer(rg, **PYM)
    t = placer.admit(df, tenant="a")
    assert t.mapping.assign[1] == 1  # the cheap route
    placer.fail_node(1)  # greedy re-map squeezes it onto node 2
    placer.restore_node(1)  # node 1 back, standing allocation ignores it
    frag = next(iter(placer.tickets.values()))
    assert frag.mapping.assign[1] == 2 and frag.tid == t.tid

    extra = DataflowPath.make([0.0, 1.0, 0.0], [1.0, 1.0], src=0, dst=3)
    before = global_objective(placer)
    res = defrag(placer, extras=[(extra, ("b", 0))])
    placer.check_invariants()
    assert res.committed and res.repacked
    assert res.objective_after > res.objective_before == before
    assert res.moved == 1  # the fragmented ticket moved back to node 1
    assert len(res.readmitted) == 1  # the extra fits on the freed node 2
    assert placer.tickets[t.tid].mapping.assign[1] == 1  # tid survived
    assert placer.stats.defrag_rounds == 1 and placer.stats.defrag_commits == 1


def test_defrag_is_a_noop_on_an_optimal_allocation():
    rg = _two_route_rg()
    df = DataflowPath.make([0.0, 1.0, 0.0], [1.0, 1.0], src=0, dst=3)
    placer = OnlinePlacer(rg, **PYM)
    t = placer.admit(df, tenant="a")
    cap0, bw0 = placer.cap.copy(), placer.bw.copy()
    stats_admitted = placer.stats.admitted
    res = defrag(placer)
    placer.check_invariants()
    assert not res.committed and not res.repacked
    assert res.objective_after == res.objective_before
    np.testing.assert_array_equal(placer.cap, cap0)
    np.testing.assert_array_equal(placer.bw, bw0)
    assert placer.tickets[t.tid] is t  # the very same ticket object
    assert placer.stats.admitted == stats_admitted  # no stats churn
    assert placer.stats.defrag_rounds == 1 and placer.stats.defrag_commits == 0


def test_defrag_fallback_readmits_when_repack_is_infeasible():
    """Greedy class-major re-pack can corner itself (an early ticket grabs
    the bandwidth a later one needs).  The pass must then restore the
    standing state bit-for-bit and still retry the extras on the current
    residual."""
    rg = ResourceGraph.from_edge_list(
        [0.0, 1.0, 0.5, 0.0],
        [(0, 1, 10.0, 1.0), (1, 3, 10.0, 1.0),
         (0, 2, 10.0, 5.0), (2, 3, 10.0, 5.0)],
    )
    a = DataflowPath.make([0.0, 0.5, 0.0], [8.0, 8.0], src=0, dst=3)
    b = DataflowPath.make([0.0, 1.0, 0.0], [8.0, 8.0], src=0, dst=3)
    placer = OnlinePlacer(rg, **PYM)
    ta = placer.admit(a, tenant="a")  # tid 0, short route via node 1
    placer.fail_node(1)  # A displaced onto the detour (node 2)
    placer.restore_node(1)
    tb = placer.admit(b, tenant="b")  # tid 1+, takes the freed short route
    assert placer.tickets[ta.tid].mapping.assign[1] == 2
    assert tb.mapping.assign[1] == 1
    # re-pack order (by tid) sends A back to node 1 first, after which B
    # fits nowhere (node 1 out of capacity, node 2 too small) -> rollback
    extra = DataflowPath.make([0.0, 0.0, 0.0], [1.0, 1.0], src=0, dst=3)
    res = defrag(placer, extras=[(extra, ("c", 0))])
    placer.check_invariants()
    assert res.committed and not res.repacked
    assert len(res.readmitted) == 1
    assert res.objective_after > res.objective_before
    # standing placement untouched by the failed re-pack
    assert placer.tickets[ta.tid].mapping.assign[1] == 2
    assert placer.tickets[tb.tid].mapping.assign[1] == 1


def test_controlplane_defrag_refreshes_handles_and_queue():
    # node 1 (cheap) holds 1.0, node 2 (expensive) holds 2.0.  X (creq 1)
    # gets churned onto node 2; the big request Y (creq 2) then fits
    # nowhere greedily — node 2 has only 1.0 free — until defrag moves X
    # back to node 1.
    rg = ResourceGraph.from_edge_list(
        [0.0, 1.0, 2.0, 0.0],
        [(0, 1, 50.0, 1.0), (1, 3, 50.0, 1.0),
         (0, 2, 50.0, 5.0), (2, 3, 50.0, 5.0)],
    )
    cp = ControlPlane(rg, micro_batch=4, max_attempts=10, **PYM)
    cp.register_tenant("a")
    x = DataflowPath.make([0.0, 1.0, 0.0], [1.0, 1.0], src=0, dst=3)
    y = DataflowPath.make([0.0, 2.0, 0.0], [1.0, 1.0], src=0, dst=3)
    cp.submit("a", x)
    cp.pump()
    cp.fail_node(1)  # X squeezed onto node 2
    cp.restore_node(1)
    cp.submit("a", y)
    cp.pump()  # Y cannot fit around the fragmented X
    assert cp.conservation()["queued"] == 1
    res = cp.defrag()
    cp.check_invariants()
    assert res.committed and len(res.readmitted) == 1
    assert cp.conservation()["queued"] == 0 and len(cp.active) == 2


# ---------------------------------------------------------------------------
# ticket immutability (satellite: frozen dataclass held mutable dicts)
# ---------------------------------------------------------------------------


def test_ticket_loads_are_immutable_views():
    placer = OnlinePlacer(_line_rg(), **PYM)
    t = placer.admit(_unit_df())
    assert t is not None
    with pytest.raises(TypeError):
        t.node_load[1] = 99.0
    with pytest.raises(TypeError):
        t.edge_load[(0, 1)] = 99.0
    placer.check_invariants()


def test_ticket_defensively_copies_constructor_dicts():
    from repro.core.online import Ticket
    from repro.core.graph import Mapping

    node_load, edge_load = {1: 0.5}, {(0, 1): 1.0}
    t = Ticket(0, _unit_df(), Mapping((0, 1, 2), (0, 1, 2), 2.0),
               node_load, edge_load)
    node_load[1] = 999.0  # caller mutates its own dict afterwards
    edge_load[(0, 1)] = 999.0
    assert t.node_load[1] == 0.5 and t.edge_load[(0, 1)] == 1.0


def test_engine_stats_surface_service_counters():
    native = type("S", (), {"preempted": 3, "defrag_rounds": 2})()
    s = _unify(native, "leastcost_python")
    assert s.preemptions == 3 and s.defrag_rounds == 2
    assert Stats().preemptions == 0 and Stats().defrag_rounds == 0

    cp = ControlPlane(_line_rg(), micro_batch=8, **PYM)
    cp.register_tenant("lo")
    cp.register_tenant("hi")
    _fill_with_best_effort(cp)
    cp.submit("hi", _unit_df(), klass=CLASS_CRITICAL)
    cp.pump()
    es = cp.engine_stats()
    assert es.preemptions == 1 and es.method == "leastcost_python"


# ---------------------------------------------------------------------------
# seeded fuzz: adversarial interleavings preserve every invariant
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(("seed", "depth"), [(0, 1), (1, 1), (0, 2), (1, 3)])
def test_fuzz_interleavings_conserve_tickets_and_capacity(seed, depth):
    rng = np.random.default_rng(seed)
    rg = waxman(12, seed=4)
    cp = ControlPlane(rg, micro_batch=6, max_attempts=3,
                      policy=FairSharePolicy(slack=0.4),
                      pipeline_depth=depth, **PYM)
    cp.register_tenant("a", weight=3.0)
    cp.register_tenant("b", weight=1.0)
    cp.register_tenant("c", weight=2.0, budget=1.5)
    tenants = ["a", "b", "c"]
    failed_nodes: list[int] = []
    failed_links: list[tuple[int, int]] = []
    edges = list(rg.edges())

    for step in range(70):
        op = rng.choice(
            ["submit", "pump", "release", "fail_node", "restore_node",
             "fail_link", "restore_link", "defrag"],
            p=[0.30, 0.25, 0.12, 0.08, 0.08, 0.05, 0.05, 0.07],
        )
        if op == "submit":
            df = random_dataflow(rg, 4, seed=1000 * seed + step,
                                 creq_range=(0.05, 0.3),
                                 breq_range=(0.5, 3.0))
            cp.submit(str(rng.choice(tenants)), df,
                      klass=int(rng.integers(0, 3)))
        elif op == "pump":
            cp.pump(rounds=int(rng.integers(1, 3)))
        elif op == "release" and cp.active:
            cp.release(int(rng.choice(list(cp.active))))
        elif op == "fail_node" and len(failed_nodes) < 3:
            v = int(rng.integers(0, rg.n))
            if v not in failed_nodes:
                alive, _ = cp.fail_node(v)
                # the returned handles are all live (incl. rescues)
                assert all(
                    cp.placer.tickets.get(t.tid) is t for t in alive
                )
                failed_nodes.append(v)
        elif op == "restore_node" and failed_nodes:
            cp.restore_node(failed_nodes.pop(
                int(rng.integers(0, len(failed_nodes)))))
        elif op == "fail_link" and len(failed_links) < 2:
            u, v = edges[int(rng.integers(0, len(edges)))]
            alive, _ = cp.fail_link(u, v)
            assert all(cp.placer.tickets.get(t.tid) is t for t in alive)
            failed_links.append((u, v))
        elif op == "restore_link" and failed_links:
            cp.restore_link(*failed_links.pop(
                int(rng.integers(0, len(failed_links)))))
        elif op == "defrag":
            res = cp.defrag()
            # defrag never regresses the objective
            assert res.objective_after >= res.objective_before
        # EVERY step: capacity conservation + the ticket ledger
        cp.check_invariants()

    # mid-stream the ledger must account for in-flight optimistic batches
    ledger = cp.conservation()
    assert ledger["ok"]
    # end state: drain the pipeline, then the ledger adds up exactly and
    # nothing was silently lost
    cp.flush()
    cp.check_invariants()
    ledger = cp.conservation()
    assert ledger["ok"] and ledger["in_flight"] == 0
    assert ledger["submitted"] == (
        ledger["queued"] + ledger["active"] + ledger["released"]
        + ledger["dropped"]
    )
    # every preemption the placer performed reached a tenant ledger (the
    # tenant counter additionally includes displacement-by-failure)
    assert sum(st.preempted for st in cp.tenants.values()) >= (
        cp.placer.stats.preempted
    )


# ---------------------------------------------------------------------------
# pipelined admission: in-flight ledger, flush barrier, timing split
# ---------------------------------------------------------------------------


def test_pipeline_holds_batches_in_flight_until_window_full():
    rg = waxman(12, seed=4)
    cp = ControlPlane(rg, micro_batch=4, pipeline_depth=3, **PYM)
    cp.register_tenant("a")
    for i in range(4):
        cp.submit("a", random_dataflow(rg, 4, seed=100 + i,
                                       creq_range=(0.05, 0.2),
                                       breq_range=(0.5, 2.0)))
    admitted = cp.pump(rounds=1)
    # depth=3 window: the single dispatched batch stays optimistic
    assert admitted == []
    ledger = cp.conservation()
    assert ledger["ok"] and ledger["in_flight"] == 4
    assert len(cp.active) == 0
    cp.check_invariants()

    # the barrier commits everything and returns the live tickets
    tickets = cp.flush()
    assert len(tickets) >= 1
    ledger = cp.conservation()
    assert ledger["ok"] and ledger["in_flight"] == 0
    assert ledger["active"] == len(tickets) == len(cp.active)
    cp.check_invariants()


def test_pipeline_defrag_flushes_first():
    rg = waxman(12, seed=4)
    cp = ControlPlane(rg, micro_batch=4, pipeline_depth=2, **PYM)
    cp.register_tenant("a")
    for i in range(3):
        cp.submit("a", random_dataflow(rg, 4, seed=200 + i,
                                       creq_range=(0.05, 0.2),
                                       breq_range=(0.5, 2.0)))
    cp.pump(rounds=1)
    assert cp.conservation()["in_flight"] == 3
    res = cp.defrag()  # must drain the window before re-solving globally
    assert cp.conservation()["in_flight"] == 0
    assert res.objective_after >= res.objective_before
    cp.check_invariants()


def test_timing_split_reaches_reports():
    rg = waxman(12, seed=4)
    cp = ControlPlane(rg, micro_batch=4, **PYM)
    cp.register_tenant("a")
    for i in range(4):
        cp.submit("a", random_dataflow(rg, 4, seed=300 + i,
                                       creq_range=(0.05, 0.2),
                                       breq_range=(0.5, 2.0)))
    cp.pump()
    es = cp.engine_stats()
    # host-side validate/reserve/commit time is split out from device solve
    assert es.overhead_ms > 0.0
    assert es.conflict_resolve_ms >= 0.0
    assert es.stale_batches == 0
    timing = cp.fairness_report()["timing"]
    assert set(timing) == {"solve_ms", "overhead_ms", "conflict_resolve_ms"}
    assert timing["overhead_ms"] == es.overhead_ms
