"""Tree-topology extension (paper §4 future work)."""
import numpy as np
import pytest

from repro.core import DataflowPath, pathmap_exact, waxman
from repro.core.dag import DataflowTree, treemap_leastcost


def test_tree_two_sources_merge():
    rg = waxman(15, seed=7)
    tree = DataflowTree(
        creq=np.array([0.0, 0.0, 2.0, 0.0], np.float32),
        parent=np.array([2, 2, 3, -1]),
        breq=np.array([20.0, 20.0, 30.0, 0.0], np.float32),
        pinned={0: 0, 1: 1, 3: 2},
    )
    tm = treemap_leastcost(rg, tree)
    assert tm is not None
    assert tm.valid
    assert tm.assign[0] == 0 and tm.assign[1] == 1 and tm.assign[3] == 2
    assert tm.cost >= 0


def test_degenerate_tree_is_a_path():
    """A linear tree must agree with the path solver on feasibility and not
    beat the exact optimum."""
    for seed in range(6):
        rg = waxman(12, seed=seed)
        p = 4
        creq = np.array([0.0, 1.5, 1.0, 0.0], np.float32)
        breq_path = np.array([20.0, 25.0, 15.0], np.float32)
        rng = np.random.default_rng(seed)
        src, dst = rng.choice(rg.n, 2, replace=False)
        df = DataflowPath(creq, breq_path, int(src), int(dst))
        ex, _ = pathmap_exact(rg, df, max_states=200_000)
        # tree edges point towards the sink: parent[i] = i+1
        tree = DataflowTree(
            creq=creq,
            parent=np.array([1, 2, 3, -1]),
            breq=np.concatenate([breq_path, [0.0]]).astype(np.float32),
            pinned={0: int(src), 3: int(dst)},
        )
        tm = treemap_leastcost(rg, tree)
        if ex is None:
            continue  # tree solver is a heuristic; only compare when exact ok
        assert tm is not None
        if tm.valid:
            # tree DP relaxes the shared-capacity constraint per subtree but
            # validates cumulatively; a valid result is a real mapping
            assert tm.cost <= ex.cost * 3 + 1e-6  # sane, same order


def test_capacity_repair():
    # force both compute nodes to prefer one tiny node -> repair must move one
    rg = waxman(10, seed=3, cap_range=(3.0, 3.0))
    tree = DataflowTree(
        creq=np.array([0.0, 2.0, 2.0, 0.0], np.float32),
        parent=np.array([1, 2, 3, -1]),
        breq=np.array([20.0, 20.0, 20.0, 0.0], np.float32),
        pinned={0: 0, 3: 5},
    )
    tm = treemap_leastcost(rg, tree)
    if tm is not None:
        used = {}
        for i, v in enumerate(tm.assign):
            used[v] = used.get(v, 0) + float(tree.creq[i])
        if tm.valid:
            assert all(u <= rg.cap[v] + 1e-6 for v, u in used.items())


def test_paper_fig2_dag_via_source_duplication():
    """The paper's Fig. 2 dataflow: s1, s2 -> x1 -> x2 -> t with an extra
    s1 -> x2 edge (a true DAG).  Pinned sources carry no compute, so s1 is
    duplicated into one copy per outgoing edge — the instance becomes an
    in-tree solvable by treemap_leastcost."""
    from repro.core.topology import paper_example

    rg, _ = paper_example()
    A, B, F = 0, 1, 5
    # nodes: 0=s1a, 1=s1b (the duplicate), 2=s2, 3=x1, 4=x2, 5=t
    tree = DataflowTree(
        creq=np.array([0, 0, 0, 2.0, 1.5, 0], np.float32),
        parent=np.array([3, 4, 3, 4, 5, -1]),
        breq=np.array([20.0, 20.0, 20.0, 25.0, 20.0, 0.0], np.float32),
        pinned={0: A, 1: A, 2: B, 5: F},
    )
    tm = treemap_leastcost(rg, tree)
    assert tm is not None and tm.valid
    assert tm.assign[0] == tm.assign[1] == A  # both s1 copies at A
    assert tm.assign[2] == B and tm.assign[5] == F


def test_tree_serving_placement():
    from repro.configs import get_config
    from repro.launch.placement import PodTopology, plan_tree_serving

    tm = plan_tree_serving(get_config("internvl2-2b"), PodTopology(pods=1))
    assert tm is not None and tm.valid
    assert len(tm.assign) == 4
