"""Capacity-window place-step kernel vs oracle (interpret mode sweep) and
vs the DP's unrolled place step."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.leastcost import _place_step
from repro.kernels.place import place_window, place_window_ref
from repro.kernels.place.place import BIG


def _inst(n, K, seed):
    rng = np.random.default_rng(seed)
    C = np.where(rng.random((n, K)) < 0.4, BIG, rng.random((n, K)) * 10)
    cap = (rng.random(n) * 8).astype(np.float32)
    creq = rng.random(K - 1) * 3
    prefix = np.concatenate([[0.0], np.cumsum(creq)]).astype(np.float32)
    return (jnp.asarray(C, jnp.float32), jnp.asarray(cap), jnp.asarray(prefix))


@pytest.mark.parametrize("n,K", [(10, 3), (64, 9), (130, 7), (256, 17), (300, 33)])
def test_place_kernel_matches_oracle(n, K):
    C, cap, prefix = _inst(n, K, seed=n + K)
    P1, pj1 = place_window(C, cap, prefix)
    P2, pj2 = place_window_ref(C, cap, prefix)
    np.testing.assert_allclose(np.asarray(P1), np.asarray(P2), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(pj1), np.asarray(pj2))


@pytest.mark.parametrize("tiles", [(8, 8), (128, 16), (64, 8)])
def test_place_kernel_tile_sweep(tiles):
    C, cap, prefix = _inst(100, 9, seed=5)
    P1, pj1 = place_window(C, cap, prefix, tiles=tiles)
    P2, pj2 = place_window_ref(C, cap, prefix)
    np.testing.assert_allclose(np.asarray(P1), np.asarray(P2), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(pj1), np.asarray(pj2))


def test_matches_dp_place_step():
    C, cap, prefix = _inst(40, 6, seed=11)
    P1, pj1 = _place_step(C, cap, prefix)
    P2, pj2 = place_window_ref(C, cap, prefix)
    np.testing.assert_allclose(np.asarray(P1), np.asarray(P2), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(pj1), np.asarray(pj2))
