"""Message-complexity regression tests.

Tier-1 port of the ``bench_messages`` claims with fixed seeds:

1. paper §3.4.1/§3.4.3 — the distributed LeastCostMap policy finds the
   optimal mapping with a large constant-factor message reduction over
   exhaustive flooding (the full benchmark measures ~100x at the sizes
   where flooding still terminates; the fixed-seed floor asserted here is
   deliberately conservative so solver-order tweaks don't flake CI);
2. the regional control plane's coordination budget — gossip costs
   ``R * fanout`` messages per round, *independent of the node count*, and
   2PC traffic is bounded per spanning attempt: nothing in the
   decentralized plane re-introduces O(n^2) flooding.
"""
import numpy as np
import pytest

from repro.core import (
    SimConfig,
    pathmap_exact,
    random_dataflow,
    solve,
    waxman,
)
from repro.service import RegionalControlPlane

PYM = dict(method="leastcost_python")


# ---------------------------------------------------------------------------
# flooding vs LeastCostMap (paper claim, fixed seeds)
# ---------------------------------------------------------------------------


def _flood_vs_leastcost(n, p, seeds):
    rows = []
    for i in seeds:
        rg = waxman(n, seed=100 + i)
        df = random_dataflow(rg, p, seed=5100 + i)
        ex, _ = pathmap_exact(rg, df, max_states=400_000)
        if ex is None:
            continue
        _, flood = solve(rg, df, method="simulate",
                         cfg=SimConfig(policy="exact",
                                       max_messages=3_000_000))
        m, lc = solve(rg, df, method="simulate",
                      cfg=SimConfig(policy="leastcost"))
        rows.append({
            "seed": 100 + i,
            "flood_msgs": flood.messages_sent,
            "lc_msgs": lc.messages_sent,
            "reduction": flood.messages_sent / max(lc.messages_sent, 1),
            "optimal": m is not None and abs(m.cost - ex.cost) < 1e-4,
        })
    return rows


def test_leastcost_messages_vs_flooding_fixed_seeds():
    rows = _flood_vs_leastcost(n=20, p=6, seeds=range(6))
    assert len(rows) >= 3  # enough feasible instances to mean anything
    # optimality: the paper claims >99%; on these fixed seeds it is exact
    assert all(r["optimal"] for r in rows), rows
    # message reduction: large on every instance, and a much larger mean
    # (measured ~65x here; thresholds leave headroom for solver-order noise)
    assert all(r["reduction"] >= 5.0 for r in rows), rows
    assert np.mean([r["reduction"] for r in rows]) >= 20.0, rows


@pytest.mark.slow
def test_leastcost_messages_vs_flooding_larger_n():
    """Slow lane: the reduction factor grows with n (paper ~100x)."""
    rows = _flood_vs_leastcost(n=26, p=5, seeds=range(6))
    assert len(rows) >= 3
    assert all(r["optimal"] for r in rows), rows
    assert np.mean([r["reduction"] for r in rows]) >= 40.0, rows


# ---------------------------------------------------------------------------
# regional plane coordination budget
# ---------------------------------------------------------------------------


def _pump_regional(n, R, fanout, pumps, requests=12):
    rg = waxman(n, seed=3)
    cp = RegionalControlPlane(rg, regions=R, fanout=fanout, seed=0, **PYM)
    cp.register_tenant("a")
    for i in range(requests):
        cp.submit("a", random_dataflow(rg, 4, seed=600 + i,
                                       creq_range=(0.02, 0.1),
                                       breq_range=(0.5, 2.0)))
    for _ in range(pumps):
        cp.pump()
    cp.check_invariants()
    return cp


def test_gossip_budget_is_R_fanout_per_round_independent_of_n():
    pumps, R, fanout = 6, 4, 2
    msgs = {}
    for n in (16, 32):
        cp = _pump_regional(n, R, fanout, pumps)
        s = cp.engine_stats()
        # exactly R*fanout per gossip round, every round
        assert s.gossip_messages == pumps * R * fanout
        msgs[n] = s.gossip_messages
    # the budget does not grow with the node count...
    assert msgs[16] == msgs[32]
    # ...and sits far below one flooding exchange on the same network
    assert msgs[32] < 32 * 32


def test_gossip_budget_scales_linearly_in_R_and_fanout():
    base = _pump_regional(24, 2, 1, 5).engine_stats().gossip_messages
    assert base == 5 * 2 * 1
    assert _pump_regional(24, 4, 1, 5).engine_stats().gossip_messages == 2 * base
    assert _pump_regional(24, 4, 2, 5).engine_stats().gossip_messages == 4 * base
    # fanout is clamped to R - 1: a region never pushes to itself
    assert _pump_regional(24, 2, 5, 5).engine_stats().gossip_messages == base


def test_twopc_traffic_bounded_per_spanning_attempt():
    """Each spanning attempt tries at most max_cut_attempts candidates
    and each candidate costs at most ``2 * len(chain) + 2`` messages
    (prepare/commit per segment plus the single blocker's nack +
    preemptive re-prepare); chains never exceed R regions: broker
    coordination is O(attempts * R), never a network flood."""
    cp = _pump_regional(24, 4, 2, 6, requests=24)
    s = cp.engine_stats()
    attempts = cp.span_stats["attempts"]
    assert attempts > 0  # the workload did span regions
    per_candidate = 2 * cp.R + 2
    assert s.twopc_messages <= attempts * (per_candidate * cp.max_cut_attempts)
    assert s.messages_sent == s.gossip_messages + s.twopc_messages
