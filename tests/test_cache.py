"""Incremental admission fast path: SolutionCache + warm-started DP.

Safety story under test, in order of importance:

- **off == pre-cache path, bit for bit** — seeded fuzz over admission /
  release / fail / restore interleavings with all-unique request
  signatures drives the cache machinery (classification, plan merge,
  negative recording) without ever producing a hit, so ``cache_enabled``
  on vs off must agree on every ticket, residual array, and counter at
  every step — at the centralized placer, through the depth>1 pipeline,
  and across an R=4 regional plane.
- **a hit can never over-commit** — positive entries are advisory: every
  hit is revalidated against the float64 residual truth before any
  reserve, so churn (fail/restore/defrag) between fill and hit must
  re-route or reject, never serve a stale mapping onto dead capacity.
- **tier 2 is bounded** — warm-started correction solves report at most
  ``max_correction_supersteps`` relaxation rounds; failures fall back to
  a cold solve, so admission quality never drops below the cold path.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (
    AdmissionPipeline,
    OnlinePlacer,
    SolutionCache,
    random_dataflow,
    request_signature,
    validate_mapping,
    waxman,
)
from repro.core.leastcost import warm_seed_from_mapping
from repro.service import ControlPlane, RegionalControlPlane

PYM = dict(method="leastcost_python")


def _light(rg, k, *, p=5, seed0=500):
    return [
        random_dataflow(rg, p, seed=seed0 + i,
                        creq_range=(0.02, 0.1), breq_range=(0.5, 3.0))
        for i in range(k)
    ]


def _cache_free(stats):
    """Stats minus wall clock and the cache/warm traffic counters (the
    only legitimate on-vs-off divergence when no signature ever repeats:
    the on side counts its misses)."""
    d = dataclasses.asdict(stats)
    for k in ("solve_ms", "overhead_ms", "conflict_resolve_ms",
              "cache_hits", "cache_misses", "cache_stale",
              "cache_neg_hits", "warm_solves", "warm_fallbacks"):
        d.pop(k)
    return d


# ---------------------------------------------------------------------------
# SolutionCache unit behavior
# ---------------------------------------------------------------------------


def test_solution_cache_lru_eviction_and_negative_clearing():
    c = SolutionCache(capacity=2)
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1  # touches "a": now "b" is the LRU entry
    c.put("c", 3)
    assert c.get("b") is None and c.get("a") == 1 and c.get("c") == 3
    assert len(c) == 2
    # a negative entry is exact-stamp: a different stamp is NOT a hit
    c.put_negative("x", (7, 0))
    assert c.negative_hit("x", (7, 0))
    assert not c.negative_hit("x", (8, 0))
    assert not c.negative_hit("y", (7, 0))
    # a positive fill clears the negative for the same signature
    c.put("x", 9)
    assert not c.negative_hit("x", (7, 0))
    c.drop("x")
    assert c.get("x") is None
    c.clear()
    assert len(c) == 0 and c.negatives == 0


def test_request_signature_discriminates_and_repeats():
    rg = waxman(10, seed=0)
    df1 = random_dataflow(rg, 4, seed=1)
    df2 = random_dataflow(rg, 4, seed=1)
    df3 = random_dataflow(rg, 4, seed=2)
    assert request_signature(df1) == request_signature(df2)
    assert request_signature(df1) != request_signature(df3)


# ---------------------------------------------------------------------------
# cache off <-> on bit-identity under unique signatures (all plane levels)
# ---------------------------------------------------------------------------


def _fuzz_identity(seed, make_admit, a, b, rg, steps=30):
    """Shared op fuzz: admit (signatures never repeat), release.  Hit-free
    by construction, so the cache-on side's classification / plan-merge /
    negative-recording machinery must be perfectly transparent — identical
    decisions, tickets, and residual arrays at every step.  (Structural
    churn re-admits *cached* signatures via ``fail_node`` remaps, where
    the fast path legitimately serves a different-but-valid mapping; the
    churn contracts are covered by the stale/warm tests below.)"""
    rng = np.random.default_rng(seed)
    uniq = [0]
    for step in range(steps):
        op = rng.choice(["admit", "release"], p=[0.6, 0.4])
        if op == "admit":
            k = int(rng.integers(1, 5))
            dfs = _light(rg, k, p=4, seed0=10_000 * seed + uniq[0])
            uniq[0] += k  # signatures never repeat across the whole run
            make_admit(dfs)
        elif op == "release" and a.tickets:
            tid = int(rng.choice(sorted(a.tickets)))
            if tid in b.tickets:
                a.release(tid)
                b.release(tid)
        np.testing.assert_array_equal(a.cap, b.cap)
        np.testing.assert_array_equal(a.bw, b.bw)
        assert sorted(a.tickets) == sorted(b.tickets)
        for tid, t in a.tickets.items():
            assert t.mapping == b.tickets[tid].mapping
        a.check_invariants()
        b.check_invariants()
    assert b.stats.cache_hits == 0 and b.stats.warm_solves == 0


@pytest.mark.parametrize("seed", [0, 1])
def test_cache_off_identity_centralized(seed):
    rg = waxman(12, seed=5)
    a = OnlinePlacer(rg, cache_enabled=False)
    b = OnlinePlacer(rg)  # cache on (the default)

    def admit(dfs):
        for x, y in zip(a.admit_many(dfs), b.admit_many(dfs)):
            assert (x is None) == (y is None)
            if x is not None:
                assert x.tid == y.tid
                assert x.mapping.assign == y.mapping.assign

    _fuzz_identity(seed, admit, a, b, rg)
    assert _cache_free(a.stats) == _cache_free(b.stats)


@pytest.mark.parametrize("seed", [0, 1])
def test_cache_off_identity_pipelined_depth3(seed):
    """Both sides drive a depth-3 pipeline (dispatch overlaps up to three
    uncommitted batches), so the cache-on plan path is exercised under
    epoch fencing — releases between dispatch and commit force the
    stale-batch re-solve on both sides identically."""
    rg = waxman(12, seed=5)
    a = OnlinePlacer(rg, cache_enabled=False)
    b = OnlinePlacer(rg)
    pa = AdmissionPipeline(a, depth=3)
    pb = AdmissionPipeline(b, depth=3)

    def admit(dfs):
        oa, ob = pa.push(dfs), pb.push(dfs)
        assert len(oa) == len(ob)  # same batches retire at the same pushes
        for (_, ta), (_, tb) in zip(oa, ob):
            for x, y in zip(ta, tb):
                assert (x is None) == (y is None)
                if x is not None:
                    assert x.tid == y.tid
                    assert x.mapping.assign == y.mapping.assign

    _fuzz_identity(seed, admit, a, b, rg, steps=25)
    for (_, ta), (_, tb) in zip(pa.flush(), pb.flush()):
        assert [t and t.tid for t in ta] == [t and t.tid for t in tb]
    np.testing.assert_array_equal(a.cap, b.cap)
    np.testing.assert_array_equal(a.bw, b.bw)
    assert _cache_free(a.stats) == _cache_free(b.stats)
    a.check_invariants()
    b.check_invariants()


@pytest.mark.parametrize("seed", [0])
def test_cache_off_identity_regional_r4(seed):
    """cache_enabled rides **solve_cfg down to every per-region placer;
    with unique signatures the R=4 plane must behave identically on/off:
    same rids, same tickets, same conservation ledger, every step."""
    rg = waxman(20, seed=7)
    kw = dict(micro_batch=4, max_attempts=3, **PYM)
    a = RegionalControlPlane(rg, regions=4, seed=seed, cache_enabled=False,
                             **kw)
    b = RegionalControlPlane(rg, regions=4, seed=seed, **kw)
    for cp in (a, b):
        cp.register_tenant("t", weight=1.0)
    rng = np.random.default_rng(seed)
    uniq = 0
    for step in range(25):
        op = rng.choice(["submit", "pump", "release"], p=[0.45, 0.35, 0.20])
        if op == "submit":
            df = _light(rg, 1, p=4, seed0=50_000 + uniq)[0]
            uniq += 1
            assert a.submit("t", df) == b.submit("t", df)
        elif op == "pump":
            r = int(rng.integers(1, 3))
            # intra-region placements carry .tid, cross-region spans .rid
            key = lambda t: getattr(t, "rid", None) or getattr(t, "tid", None)
            assert ([key(t) for t in a.pump(rounds=r)]
                    == [key(t) for t in b.pump(rounds=r)])
        elif op == "release":
            ids = a.active_ids()
            assert ids == b.active_ids()
            if ids:
                rid = int(rng.choice(ids))
                a.release(rid)
                b.release(rid)
        assert a.conservation() == b.conservation()
        a.check_invariants()
        b.check_invariants()
    for pa, pb in zip(a.regions, b.regions):
        np.testing.assert_array_equal(pa.placer.cap, pb.placer.cap)
        np.testing.assert_array_equal(pa.placer.bw, pb.placer.bw)
        # the knob rode **solve_cfg down to every per-region placer.  (The
        # broker's chain-retry loop re-admits identical segment signatures
        # on the bit-exact residual its own abort restored, so the cached
        # side may legitimately count hits — each one serving exactly the
        # mapping the deterministic cold solve just produced, which is why
        # the step-by-step state identity above still holds.)
        assert pb.placer.cache is not None
        assert pa.placer.cache is None


# ---------------------------------------------------------------------------
# tier 1: hits skip the DP and are excluded from solve accounting
# ---------------------------------------------------------------------------


def test_repeat_batch_is_pure_hits_and_skips_solve_accounting():
    rg = waxman(16, seed=2)
    placer = OnlinePlacer(rg)
    dfs = _light(rg, 8)
    first = placer.admit_many(dfs)
    assert all(t is not None for t in first)
    base = placer.stats.solves
    base_n = placer.stats.solve_n_sum
    for t in first:
        placer.release(t)
    second = placer.admit_many(dfs)
    assert all(t is not None for t in second)
    assert placer.stats.cache_hits == 8
    # satellite: hit admissions never touch solves / solve_n_sum / solve_ms
    assert placer.stats.solves == base
    assert placer.stats.solve_n_sum == base_n
    # the reused mappings are exactly the previously committed ones
    for x, y in zip(first, second):
        assert y.mapping.assign == x.mapping.assign
        validate_mapping(placer.base, y.df, y.mapping)
    placer.check_invariants()


def test_negative_cache_short_circuits_repeat_rejections():
    rg = waxman(10, seed=4)
    placer = OnlinePlacer(rg)
    impossible = random_dataflow(rg, 4, seed=9,
                                 creq_range=(50.0, 60.0),  # >> any cap
                                 breq_range=(0.1, 0.2))
    assert placer.admit(impossible) is None
    solves = placer.stats.solves
    assert placer.admit(impossible) is None  # same residual stamp
    assert placer.stats.cache_neg_hits == 1
    assert placer.stats.solves == solves  # no re-solve
    # any residual mutation invalidates the stamp: a fresh solve runs
    ok = placer.admit(_light(rg, 1, seed0=77)[0])
    assert ok is not None
    assert placer.admit(impossible) is None
    assert placer.stats.solves > solves
    placer.check_invariants()


# ---------------------------------------------------------------------------
# stale entries under churn: revalidate, never over-commit
# ---------------------------------------------------------------------------


def test_stale_hit_after_node_failure_rerouted_never_overcommitted():
    rg = waxman(16, seed=2)
    placer = OnlinePlacer(rg)
    dfs = _light(rg, 8)
    first = placer.admit_many(dfs)
    assert all(t is not None for t in first)
    victim = first[0].mapping.route[len(first[0].mapping.route) // 2]
    for t in first:
        placer.release(t)
    placer.fail_node(victim)  # cached routes through victim are now stale
    second = placer.admit_many(dfs)
    for t in second:
        if t is not None:
            assert victim not in t.mapping.route
            validate_mapping(placer.base, t.df, t.mapping)
    assert placer.stats.cache_stale >= 1
    placer.check_invariants()
    placer.restore_node(victim)
    placer.check_invariants()


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_stale_cache_churn_property_fuzz(seed):
    """fail / restore / defrag / release between cache fill and hit:
    every committed mapping must validate against the residual it was
    reserved on (check_invariants recomputes the ledger each step)."""
    from repro.service.defrag import defrag as run_defrag

    rg = waxman(14, seed=6)
    placer = OnlinePlacer(rg)
    pool = _light(rg, 6, p=4, seed0=900 * (seed + 1))  # repeats by design
    rng = np.random.default_rng(seed)
    failed: list[int] = []
    for step in range(35):
        op = rng.choice(
            ["admit", "release", "fail", "restore", "defrag"],
            p=[0.40, 0.25, 0.12, 0.13, 0.10],
        )
        if op == "admit":
            df = pool[int(rng.integers(0, len(pool)))]
            t = placer.admit(df)
            if t is not None:
                validate_mapping(placer.base, t.df, t.mapping)
        elif op == "release" and placer.tickets:
            placer.release(int(rng.choice(sorted(placer.tickets))))
        elif op == "fail" and len(failed) < 2:
            v = int(rng.integers(0, rg.n))
            if v not in failed:
                placer.fail_node(v)
                failed.append(v)
        elif op == "restore" and failed:
            placer.restore_node(failed.pop(int(rng.integers(0, len(failed)))))
        elif op == "defrag":
            run_defrag(placer)
        placer.check_invariants()
    # the run must actually have exercised the cache paths
    assert placer.stats.cache_hits + placer.stats.cache_stale > 0


# ---------------------------------------------------------------------------
# tier 2: warm-started bounded correction supersteps
# ---------------------------------------------------------------------------


def test_warm_seed_walks_mapping_and_stops_at_violations():
    rg = waxman(16, seed=2)
    placer = OnlinePlacer(rg)
    t = placer.admit(_light(rg, 1)[0])
    assert t is not None
    # on the *pre-commit* residual the walk spans the whole route: one
    # arrival state per hop, in route order, costs non-decreasing
    placer.release(t)
    seed = warm_seed_from_mapping(placer.residual_graph(), t.df, t.mapping)
    assert seed is not None
    assert len(seed["v"]) == len(t.mapping.route) - 1
    assert list(seed["v"]) == list(t.mapping.route[1:])
    assert np.all(np.diff(seed["cost"]) >= 0)
    assert np.all(seed["j"] >= 1) and np.all(seed["j"] <= t.df.p)
    # a dead node on the route truncates the walk instead of seeding junk
    victim = t.mapping.route[-1]
    placer.fail_node(victim)
    seed2 = warm_seed_from_mapping(placer.residual_graph(), t.df, t.mapping)
    if seed2 is not None:
        assert victim not in seed2["v"]
    placer.restore_node(victim)


def test_warm_solves_respect_the_superstep_fuse():
    rg = waxman(16, seed=2)
    placer = OnlinePlacer(rg)
    fuse = placer.max_correction_supersteps
    dfs = _light(rg, 8)
    ts = placer.admit_many(dfs)
    assert all(t is not None for t in ts)
    routes = [t.mapping.route for t in ts]
    victim = routes[0][1] if len(routes[0]) > 1 else routes[0][0]
    placer.fail_node(victim)  # remaps displaced tickets through stale entries
    for t in list(placer.tickets.values()):
        placer.release(t)
    placer.admit_many(dfs)  # stale entries -> warm-started correction solves
    st = placer.stats
    assert st.warm_solves >= 1, st
    warm = st.supersteps.get("warm", {})
    cold = st.supersteps.get("cold", {})
    assert warm and cold
    # the fuse bounds every warm solve; the cold fixpoint runs past it
    assert max(warm) <= fuse < max(cold), (warm, cold)
    placer.check_invariants()


def test_cache_disabled_means_no_cache_object_no_plan():
    rg = waxman(12, seed=1)
    placer = OnlinePlacer(rg, cache_enabled=False)
    assert placer.cache is None
    pend = placer.dispatch_admit(_light(rg, 3, p=4))
    assert pend.plan is None
    placer.commit_admit(pend)
    assert placer.stats.cache_hits == placer.stats.cache_misses == 0
    placer.check_invariants()
