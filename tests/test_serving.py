"""Continuous-batching engine: slot refill, per-slot positions, determinism
of greedy decode vs a straight-line reference."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as lm
from repro.models.config import ModelConfig
from repro.models.registry import init_model
from repro.serving import Engine, Request

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab=128, dtype="float32")


def _ref_greedy(cfg, params, prompt, n_new):
    toks = list(prompt)
    for _ in range(n_new):
        lg, _ = lm.lm_forward(cfg, params, jnp.asarray([toks], jnp.int32),
                              logits_mode="last", remat=False)
        toks.append(int(jnp.argmax(lg[0, -1])))
    return toks[len(prompt):]


def test_engine_matches_straightline_greedy():
    params, _ = init_model(CFG, jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 128, size=L).astype(np.int32)
               for L in (5, 9, 7, 4, 6)]
    eng = Engine(CFG, params, n_slots=2, max_len=64, temperature=0.0)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=6))
    done, ticks = eng.run()
    assert len(done) == len(prompts)
    for req in done:
        ref = _ref_greedy(CFG, params, list(req.prompt), 6)
        assert req.out == ref, (req.rid, req.out, ref)


def test_engine_more_requests_than_slots():
    params, _ = init_model(CFG, jax.random.key(1))
    eng = Engine(CFG, params, n_slots=2, max_len=32, temperature=0.7, top_k=8,
                 seed=3)
    for i in range(5):
        eng.submit(Request(rid=i, prompt=np.arange(3 + i) % 128, max_new=4))
    done, _ = eng.run()
    assert len(done) == 5
    assert all(len(r.out) == 4 for r in done)
