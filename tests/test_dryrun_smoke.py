"""Deliverable (e) in CI form: the dry-run path (mesh build -> production
shardings -> lower -> compile -> memory/cost/collective extraction) runs end
to end in a subprocess on a scaled-down (4x4 / 2x4x4) host-device mesh."""
import json
import os
import subprocess
import sys

import pytest


@pytest.mark.parametrize("mesh", ["single", "multi"])
def test_dryrun_cell_compiles_scaled(tmp_path, mesh):
    env = dict(os.environ, PYTHONPATH="src", REPRO_DRYRUN_SCALE="4")
    p = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen2-0.5b",
         "--shape", "decode_32k", "--mesh", mesh, "--out", str(tmp_path)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert p.returncode == 0, p.stderr[-2000:]
    rec = json.load(open(tmp_path / f"qwen2-0.5b__decode_32k__{mesh}.json"))
    assert rec["chips"] == (32 if mesh == "multi" else 16)
    la = rec["loop_aware"]
    assert la["flops"] > 0 and la["bytes_hbm"] > 0
    assert rec["memory"]["temp_bytes"] is not None
