"""Online multi-request placement service: admission, residual-capacity
invariants, micro-batched solving, and churn re-mapping."""
import numpy as np
import pytest

from repro.core import (
    DataflowPath,
    OnlinePlacer,
    ResourceGraph,
    random_dataflow,
    validate_mapping,
    waxman,
)


def _light_requests(rg, k, p=5, seed0=500):
    return [
        random_dataflow(rg, p, seed=seed0 + i,
                        creq_range=(0.02, 0.1), breq_range=(0.5, 3.0))
        for i in range(k)
    ]


def test_admit_release_roundtrip():
    rg = waxman(16, seed=2)
    placer = OnlinePlacer(rg)
    df = _light_requests(rg, 1)[0]
    t = placer.admit(df)
    assert t is not None
    ok, why = validate_mapping(rg, df, t.mapping)
    assert ok, why
    assert np.sum(placer.cap) < np.sum(rg.cap)  # capacity committed
    placer.check_invariants()
    placer.release(t)
    np.testing.assert_allclose(placer.cap, rg.cap.astype(np.float64))
    np.testing.assert_allclose(placer.bw, rg.bw.astype(np.float64))
    placer.check_invariants()


def test_admit_many_64_concurrent_with_invariants():
    """The acceptance-criteria scenario: >= 64 concurrent requests admitted
    against residual capacity, invariants intact throughout."""
    rg = waxman(24, seed=7)
    placer = OnlinePlacer(rg)
    dfs = _light_requests(rg, 80)
    tickets = []
    for i in range(0, len(dfs), 32):
        tickets.extend(placer.admit_many(dfs[i:i + 32]))
        placer.check_invariants()
    admitted = [t for t in tickets if t is not None]
    assert len(admitted) >= 64, len(admitted)
    # every committed mapping was feasible on the network it was granted
    assert placer.stats.admitted == len(admitted)
    # aggregate commitments really left the residual
    total_creq = sum(float(np.sum(t.df.creq)) for t in admitted)
    assert np.sum(rg.cap) - np.sum(placer.cap) == pytest.approx(total_creq, rel=1e-6)


def test_admission_rejects_when_capacity_exhausted():
    # tiny network, big requests: the second identical request can't fit
    rg = ResourceGraph.from_edge_list(
        [0.0, 2.0, 0.0], [(0, 1, 50.0, 1.0), (1, 2, 50.0, 1.0)]
    )
    df = DataflowPath.make([0.0, 2.0, 0.0], [5.0, 5.0], src=0, dst=2)
    placer = OnlinePlacer(rg)
    assert placer.admit(df) is not None
    assert placer.admit(df) is None  # node 1 has no residual capacity left
    assert placer.stats.rejected == 1
    placer.check_invariants()


def test_bandwidth_is_committed_too():
    rg = ResourceGraph.from_edge_list(
        [0.0, 5.0, 0.0], [(0, 1, 10.0, 1.0), (1, 2, 10.0, 1.0)]
    )
    df = DataflowPath.make([0.0, 1.0, 0.0], [8.0, 8.0], src=0, dst=2)
    placer = OnlinePlacer(rg)
    assert placer.admit(df) is not None
    # links now hold 2 GB/s residual < 8 required -> reject
    assert placer.admit(df) is None
    placer.check_invariants()


def test_batched_admission_matches_sequential_costs():
    rg = waxman(20, seed=11)
    dfs = _light_requests(rg, 12, seed0=900)
    seq = OnlinePlacer(rg)
    bat = OnlinePlacer(rg)
    t_seq = [seq.admit(d) for d in dfs]
    t_bat = bat.admit_many(dfs)
    for a, b in zip(t_seq, t_bat):
        assert (a is None) == (b is None)
        if a is not None:
            assert abs(a.mapping.cost - b.mapping.cost) < 1e-3
    seq.check_invariants()
    bat.check_invariants()


def test_node_churn_remaps_displaced():
    rg = waxman(24, seed=3)
    placer = OnlinePlacer(rg)
    tickets = [t for t in placer.admit_many(_light_requests(rg, 24)) if t]
    assert tickets
    # fail the most-used intermediate node
    counts = {}
    for t in tickets:
        for v in t.mapping.route:
            if v not in (t.df.src, t.df.dst):
                counts[v] = counts.get(v, 0) + 1
    assert counts, "no intermediate nodes used; instance too easy"
    victim = max(counts, key=counts.get)
    displaced_before = counts[victim]
    remapped, dropped = placer.fail_node(victim)
    assert len(remapped) + len(dropped) >= displaced_before
    placer.check_invariants()
    # no surviving placement routes through the failed node
    for t in placer.tickets.values():
        assert victim not in t.mapping.route
    # re-admitted mappings are valid on the degraded network
    degraded = placer.residual_graph()
    assert degraded.cap[victim] == 0.0
    for t in remapped:
        assert victim not in t.mapping.route


def test_link_churn_remaps_displaced():
    rg = waxman(20, seed=9)
    placer = OnlinePlacer(rg)
    tickets = [t for t in placer.admit_many(_light_requests(rg, 16, seed0=700)) if t]
    multi_hop = [t for t in tickets if len(t.mapping.route) > 1]
    assert multi_hop
    u, v = next(iter(multi_hop[0].edge_load))
    placer.fail_link(u, v)
    placer.check_invariants()
    for t in placer.tickets.values():
        assert (u, v) not in t.edge_load and (v, u) not in t.edge_load


def test_src_down_rejects():
    rg = waxman(16, seed=6)
    placer = OnlinePlacer(rg)
    df = _light_requests(rg, 1, seed0=42)[0]
    placer.fail_node(df.src)
    assert placer.admit(df) is None
    placer.restore_node(df.src)
    assert placer.admit(df) is not None
    placer.check_invariants()


def test_micro_batch_bucketing_bounds_jit_recompiles():
    """``admit_many`` buckets the DP batch to the next power of two, so a
    churny stream of distinct micro-batch sizes compiles at most
    log2(max batch) specializations of the vmapped DP — not one per size.
    Counted directly in the jit cache of the shared vmapped driver."""
    from repro.core import leastcost as lc

    lc._vmapped_dp.cache_clear()
    rg = waxman(12, seed=3)
    placer = OnlinePlacer(rg)  # leastcost_jax: the natively-batching path
    p = 5
    sizes = [1, 2, 3, 4, 5, 6, 7, 8, 3, 5, 7, 2, 6, 1, 8, 4]
    assert len(set(sizes)) == 8  # 8 distinct arrival sizes...
    for j, b in enumerate(sizes):
        dfs = [
            random_dataflow(rg, p, seed=900 + 37 * j + i,
                            creq_range=(0.01, 0.05),
                            breq_range=(0.2, 1.0))
            for i in range(b)
        ]
        for t in placer.admit_many(dfs):
            if t is not None:
                placer.release(t)  # keep capacity churn-free
        placer.check_invariants()
    # one (n, p, max_rounds) driver served every batch...
    assert lc._vmapped_dp.cache_info().currsize == 1
    fn = lc._vmapped_dp(rg.n, p, rg.n - 1)
    # ...with only power-of-two batch specializations: {1, 2, 4, 8}
    assert fn._cache_size() <= 4, fn._cache_size()
