"""Online multi-request placement service: admission, residual-capacity
invariants, micro-batched solving, churn re-mapping, and the pipelined
(dispatch/commit-split) admission path."""
import dataclasses

import numpy as np
import pytest

from repro.core import (
    AdmissionPipeline,
    DataflowPath,
    OnlinePlacer,
    ResourceGraph,
    random_dataflow,
    validate_mapping,
    waxman,
)


def _light_requests(rg, k, p=5, seed0=500):
    return [
        random_dataflow(rg, p, seed=seed0 + i,
                        creq_range=(0.02, 0.1), breq_range=(0.5, 3.0))
        for i in range(k)
    ]


def test_admit_release_roundtrip():
    rg = waxman(16, seed=2)
    placer = OnlinePlacer(rg)
    df = _light_requests(rg, 1)[0]
    t = placer.admit(df)
    assert t is not None
    ok, why = validate_mapping(rg, df, t.mapping)
    assert ok, why
    assert np.sum(placer.cap) < np.sum(rg.cap)  # capacity committed
    placer.check_invariants()
    placer.release(t)
    np.testing.assert_allclose(placer.cap, rg.cap.astype(np.float64))
    np.testing.assert_allclose(placer.bw, rg.bw.astype(np.float64))
    placer.check_invariants()


def test_admit_many_64_concurrent_with_invariants():
    """The acceptance-criteria scenario: >= 64 concurrent requests admitted
    against residual capacity, invariants intact throughout."""
    rg = waxman(24, seed=7)
    placer = OnlinePlacer(rg)
    dfs = _light_requests(rg, 80)
    tickets = []
    for i in range(0, len(dfs), 32):
        tickets.extend(placer.admit_many(dfs[i:i + 32]))
        placer.check_invariants()
    admitted = [t for t in tickets if t is not None]
    assert len(admitted) >= 64, len(admitted)
    # every committed mapping was feasible on the network it was granted
    assert placer.stats.admitted == len(admitted)
    # aggregate commitments really left the residual
    total_creq = sum(float(np.sum(t.df.creq)) for t in admitted)
    assert np.sum(rg.cap) - np.sum(placer.cap) == pytest.approx(total_creq, rel=1e-6)


def test_admission_rejects_when_capacity_exhausted():
    # tiny network, big requests: the second identical request can't fit
    rg = ResourceGraph.from_edge_list(
        [0.0, 2.0, 0.0], [(0, 1, 50.0, 1.0), (1, 2, 50.0, 1.0)]
    )
    df = DataflowPath.make([0.0, 2.0, 0.0], [5.0, 5.0], src=0, dst=2)
    placer = OnlinePlacer(rg)
    assert placer.admit(df) is not None
    assert placer.admit(df) is None  # node 1 has no residual capacity left
    assert placer.stats.rejected == 1
    placer.check_invariants()


def test_bandwidth_is_committed_too():
    rg = ResourceGraph.from_edge_list(
        [0.0, 5.0, 0.0], [(0, 1, 10.0, 1.0), (1, 2, 10.0, 1.0)]
    )
    df = DataflowPath.make([0.0, 1.0, 0.0], [8.0, 8.0], src=0, dst=2)
    placer = OnlinePlacer(rg)
    assert placer.admit(df) is not None
    # links now hold 2 GB/s residual < 8 required -> reject
    assert placer.admit(df) is None
    placer.check_invariants()


def test_batched_admission_matches_sequential_costs():
    rg = waxman(20, seed=11)
    dfs = _light_requests(rg, 12, seed0=900)
    seq = OnlinePlacer(rg)
    bat = OnlinePlacer(rg)
    t_seq = [seq.admit(d) for d in dfs]
    t_bat = bat.admit_many(dfs)
    for a, b in zip(t_seq, t_bat):
        assert (a is None) == (b is None)
        if a is not None:
            assert abs(a.mapping.cost - b.mapping.cost) < 1e-3
    seq.check_invariants()
    bat.check_invariants()


def test_node_churn_remaps_displaced():
    rg = waxman(24, seed=3)
    placer = OnlinePlacer(rg)
    tickets = [t for t in placer.admit_many(_light_requests(rg, 24)) if t]
    assert tickets
    # fail the most-used intermediate node
    counts = {}
    for t in tickets:
        for v in t.mapping.route:
            if v not in (t.df.src, t.df.dst):
                counts[v] = counts.get(v, 0) + 1
    assert counts, "no intermediate nodes used; instance too easy"
    victim = max(counts, key=counts.get)
    displaced_before = counts[victim]
    remapped, dropped = placer.fail_node(victim)
    assert len(remapped) + len(dropped) >= displaced_before
    placer.check_invariants()
    # no surviving placement routes through the failed node
    for t in placer.tickets.values():
        assert victim not in t.mapping.route
    # re-admitted mappings are valid on the degraded network
    degraded = placer.residual_graph()
    assert degraded.cap[victim] == 0.0
    for t in remapped:
        assert victim not in t.mapping.route


def test_link_churn_remaps_displaced():
    rg = waxman(20, seed=9)
    placer = OnlinePlacer(rg)
    tickets = [t for t in placer.admit_many(_light_requests(rg, 16, seed0=700)) if t]
    multi_hop = [t for t in tickets if len(t.mapping.route) > 1]
    assert multi_hop
    u, v = next(iter(multi_hop[0].edge_load))
    placer.fail_link(u, v)
    placer.check_invariants()
    for t in placer.tickets.values():
        assert (u, v) not in t.edge_load and (v, u) not in t.edge_load


def test_src_down_rejects():
    rg = waxman(16, seed=6)
    placer = OnlinePlacer(rg)
    df = _light_requests(rg, 1, seed0=42)[0]
    placer.fail_node(df.src)
    assert placer.admit(df) is None
    placer.restore_node(df.src)
    assert placer.admit(df) is not None
    placer.check_invariants()


def test_micro_batch_bucketing_bounds_jit_recompiles():
    """``admit_many`` buckets the DP batch to the next power of two, so a
    churny stream of distinct micro-batch sizes compiles at most
    log2(max batch) specializations of the vmapped DP — not one per size.
    Counted directly in the jit cache of the shared vmapped driver."""
    from repro.core import leastcost as lc

    lc._vmapped_dp.cache_clear()
    rg = waxman(12, seed=3)
    placer = OnlinePlacer(rg)  # leastcost_jax: the natively-batching path
    p = 5
    sizes = [1, 2, 3, 4, 5, 6, 7, 8, 3, 5, 7, 2, 6, 1, 8, 4]
    assert len(set(sizes)) == 8  # 8 distinct arrival sizes...
    for j, b in enumerate(sizes):
        dfs = [
            random_dataflow(rg, p, seed=900 + 37 * j + i,
                            creq_range=(0.01, 0.05),
                            breq_range=(0.2, 1.0))
            for i in range(b)
        ]
        for t in placer.admit_many(dfs):
            if t is not None:
                placer.release(t)  # keep capacity churn-free
        placer.check_invariants()
    # one (n, p, max_rounds) driver served every batch...
    assert lc._vmapped_dp.cache_info().currsize == 1
    fn = lc._vmapped_dp(rg.n, p, rg.n - 1)
    # ...with only power-of-two batch specializations: {1, 2, 4, 8}
    assert fn._cache_size() <= 4, fn._cache_size()


# ---------------------------------------------------------------------------
# pipelined admission: dispatch/commit split, staleness fencing, warmup
# ---------------------------------------------------------------------------


def _clock_free(stats):
    """Stats minus the wall-clock fields (the only legitimate divergence
    between the synchronous and the depth-1 pipelined path)."""
    d = dataclasses.asdict(stats)
    for k in ("solve_ms", "overhead_ms", "conflict_resolve_ms"):
        d.pop(k)
    return d


@pytest.mark.parametrize("seed", [0, 1])
def test_pipeline_depth1_bit_identical_to_sync(seed):
    """Fuzzed op interleavings: AdmissionPipeline(depth=1) is the synchronous
    ``admit_many`` path — same tickets (tid, assignment, cost), bitwise-same
    residuals, identical stats up to wall clock.  Same pattern as the R=1
    regional identity fuzz."""
    rng = np.random.default_rng(seed)
    rg = waxman(12, seed=5)
    a = OnlinePlacer(rg)
    b = OnlinePlacer(rg)
    pipe = AdmissionPipeline(b, depth=1)
    failed_nodes: list[int] = []
    failed_links: list[tuple[int, int]] = []
    edges = list(rg.edges())

    for step in range(40):
        op = rng.choice(
            ["admit", "release", "fail_node", "restore_node",
             "fail_link", "restore_link"],
            p=[0.45, 0.20, 0.10, 0.10, 0.075, 0.075],
        )
        if op == "admit":
            dfs = [
                random_dataflow(rg, 4, seed=1000 * seed + 13 * step + i,
                                creq_range=(0.05, 0.2),
                                breq_range=(0.5, 2.0))
                for i in range(int(rng.integers(1, 5)))
            ]
            ta = a.admit_many(dfs)
            out = pipe.push(dfs)
            assert len(out) == 1  # depth=1: every push commits in-line
            for x, y in zip(ta, out[0][1]):
                assert (x is None) == (y is None)
                if x is not None:
                    assert x.tid == y.tid
                    assert x.mapping.assign == y.mapping.assign
                    assert x.mapping.cost == y.mapping.cost
        elif op == "release" and a.tickets:
            tid = int(rng.choice(sorted(a.tickets)))
            a.release(tid)
            b.release(tid)
        elif op == "fail_node" and len(failed_nodes) < 2:
            v = int(rng.integers(0, rg.n))
            if v not in failed_nodes:
                rem_a, drop_a = a.fail_node(v)
                rem_b, drop_b = b.fail_node(v)
                assert [t.tid for t in rem_a] == [t.tid for t in rem_b]
                assert [t.tid for t in drop_a] == [t.tid for t in drop_b]
                failed_nodes.append(v)
        elif op == "restore_node" and failed_nodes:
            v = failed_nodes.pop(int(rng.integers(0, len(failed_nodes))))
            a.restore_node(v)
            b.restore_node(v)
        elif op == "fail_link" and len(failed_links) < 2:
            u, v = edges[int(rng.integers(0, len(edges)))]
            a.fail_link(u, v)
            b.fail_link(u, v)
            failed_links.append((u, v))
        elif op == "restore_link" and failed_links:
            u, v = failed_links.pop(int(rng.integers(0, len(failed_links))))
            a.restore_link(u, v)
            b.restore_link(u, v)
        # bit-identical residual state after EVERY op
        assert sorted(a.tickets) == sorted(b.tickets)
        assert np.array_equal(a.cap, b.cap)
        assert np.array_equal(a.bw, b.bw)
        a.check_invariants()
        b.check_invariants()

    assert _clock_free(a.stats) == _clock_free(b.stats)
    assert b.stats.stale_batches == 0  # depth=1 can never go stale


def test_churn_mid_pipeline_displaces_exactly_as_sync():
    """``fail_node`` while a batch is in flight: the epoch fence discards the
    stale optimistic solve and the commit re-solves fresh, so the pipelined
    placer lands in exactly the synchronous placer's state."""
    rg = waxman(16, seed=2)
    a = OnlinePlacer(rg)
    b = OnlinePlacer(rg)
    base = _light_requests(rg, 8)
    a.admit_many(base)
    b.admit_many(base)
    batch = _light_requests(rg, 4, seed0=900)
    pending = b.dispatch_admit(batch)  # optimistic, pre-churn snapshot

    counts: dict[int, int] = {}
    for t in a.tickets.values():
        for v in t.mapping.route:
            if v not in (t.df.src, t.df.dst):
                counts[v] = counts.get(v, 0) + 1
    assert counts, "no intermediate nodes used; instance too easy"
    victim = max(counts, key=counts.get)
    rem_a, drop_a = a.fail_node(victim)
    rem_b, drop_b = b.fail_node(victim)
    assert [t.tid for t in rem_a] == [t.tid for t in rem_b]
    assert [t.tid for t in drop_a] == [t.tid for t in drop_b]

    ta = a.admit_many(batch)  # sync path solves on the degraded network
    tb = b.commit_admit(pending)  # stale path must reach the same result
    assert b.stats.stale_batches == 1
    for x, y in zip(ta, tb):
        assert (x is None) == (y is None)
        if x is not None:
            assert x.mapping.assign == y.mapping.assign
            assert x.mapping.cost == y.mapping.cost
    assert np.array_equal(a.cap, b.cap)
    assert np.array_equal(a.bw, b.bw)
    for t in b.tickets.values():
        assert victim not in t.mapping.route
    a.check_invariants()
    b.check_invariants()


def test_restore_invalidates_in_flight_batch():
    """``restore()`` while a batch is in flight must *invalidate* the stale
    solve (epoch fence), not let it commit against the rolled-back residual:
    the batch was solved on capacity the restore takes away again."""
    rg = waxman(16, seed=4)
    placer = OnlinePlacer(rg)
    # one big standing ticket, snapshotted in
    big = DataflowPath.make(
        [0.0] + [0.3] * 3 + [0.0], [2.0] * 4,
        src=int(_light_requests(rg, 1)[0].src),
        dst=int(_light_requests(rg, 1)[0].dst),
    )
    t_big = placer.admit(big)
    assert t_big is not None
    snap = placer.snapshot()
    epoch_before = placer.epoch

    placer.release(t_big)  # frees capacity the in-flight solve will see
    pending = placer.dispatch_admit(_light_requests(rg, 4, seed0=901))
    placer.restore(snap)  # roll back: the big ticket holds again
    assert placer.epoch > epoch_before  # monotone — never rewound

    tickets = placer.commit_admit(pending)
    # the whole batch was discarded by the fence and re-solved fresh —
    # NOT committed, NOT salvaged via per-request conflict re-solves
    assert placer.stats.stale_batches == 1
    assert placer.stats.batch_conflicts == 0
    assert t_big.tid in placer.tickets
    # whatever the fresh re-solve admitted is live and accounted for
    assert all(t.tid in placer.tickets for t in tickets if t is not None)
    placer.check_invariants()


def test_commit_admit_rejects_double_commit():
    rg = waxman(12, seed=5)
    placer = OnlinePlacer(rg)
    pending = placer.dispatch_admit(_light_requests(rg, 2))
    placer.commit_admit(pending)
    with pytest.raises(AssertionError):
        placer.commit_admit(pending)


def test_warmup_precompiles_every_bucket_and_commits_nothing():
    """``warmup(max_batch=8)`` compiles the single-request shape plus the
    {1,2,4,8} buckets up front; subsequent admissions of any size hit the
    cache, and the warmup itself leaves no trace in residuals or stats."""
    from repro.core import leastcost as lc

    lc._vmapped_dp.cache_clear()
    rg = waxman(12, seed=3)
    placer = OnlinePlacer(rg)
    warm_max = placer.warmup(max_batch=8, p=5)
    assert warm_max == 8
    # nothing committed, nothing counted
    np.testing.assert_array_equal(placer.cap, rg.cap.astype(np.float64))
    assert placer.stats.batches == 0 and placer.stats.solves == 0
    # two vmapped variants: the cold fixpoint DP plus the warm-seeded
    # bounded-correction specialization (tier-2 fast path)
    assert lc._vmapped_dp.cache_info().currsize == 2
    fn = lc._vmapped_dp(rg.n, 5, rg.n - 1, False)
    assert fn._cache_size() == 4, fn._cache_size()  # {1, 2, 4, 8}
    fnw = lc._vmapped_dp(rg.n, 5, placer.max_correction_supersteps, True)
    assert fnw._cache_size() == 4, fnw._cache_size()

    for b in (1, 3, 5, 8):  # non-power-of-two sizes bucket up
        dfs = [
            random_dataflow(rg, 5, seed=40 + 10 * b + i,
                            creq_range=(0.01, 0.05), breq_range=(0.2, 1.0))
            for i in range(b)
        ]
        placer.admit_many(dfs)
    assert lc._vmapped_dp.cache_info().currsize == 2
    assert fn._cache_size() == 4  # no new specializations
    placer.check_invariants()
