"""Unified telemetry plane: metrics registry semantics (labels, merge
composition, windowed snapshots), request-lifecycle tracer (spans, flow
events, scoped prefixes, null-tracer zero-cost guarantees), Chrome-trace
export + lifecycle reconstruction, the non-additive engine-stats fold fix
(kernel_impl / solve_n at every plane level), the solve/overhead/conflict
timing split across sync and pipelined admission, and bit-for-bit identity
of traced vs untraced planes."""
import json

import numpy as np
import pytest

from repro.core import (
    DataflowPath,
    random_dataflow,
    region_line,
    region_tree,
    waxman,
)
from repro.core.engine import Stats
from repro.core.online import OnlinePlacer
from repro.obs import (
    NULL,
    Histogram,
    MetricsRegistry,
    NullTracer,
    Tracer,
    absorb_engine_stats,
    absorb_gossip_stats,
    absorb_online_stats,
    reconstruct_request,
    text_timeline,
    to_chrome_trace,
    validate_chrome_trace,
    write_chrome_trace,
)
from repro.service import (
    ControlPlane,
    FairSharePolicy,
    GossipBus,
    RegionalControlPlane,
)

PYM = dict(method="leastcost_python")  # pure-python backend: fast, no jit


def _unit_df(creq: float = 1.0, src: int = 0, dst: int = 2) -> DataflowPath:
    return DataflowPath.make([0.0, creq, 0.0], [1.0, 1.0], src, dst)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_registry_counters_gauges_histograms():
    reg = MetricsRegistry()
    reg.inc("admit.total")
    reg.inc("admit.total", 2.0)
    reg.gauge("queue.depth", 7.0)
    reg.observe("solve.ms", 3.0)
    reg.observe("solve.ms", 5.0)
    assert reg.get("admit.total") == 3.0
    assert reg.get("queue.depth") == 7.0
    # get() on a histogram series reads its mean; labeled() exposes the
    # full summary
    assert reg.get("solve.ms") == pytest.approx(4.0)
    h = reg.labeled("solve.ms")[()]
    assert h["count"] == 2 and h["sum"] == pytest.approx(8.0)
    assert h["min"] == 3.0 and h["max"] == 5.0


def test_registry_labels_total_and_labeled():
    reg = MetricsRegistry()
    reg.inc("solves", 3.0, kernel_impl="pallas")
    reg.inc("solves", 1.0, kernel_impl="ref")
    reg.inc("solves", 2.0, kernel_impl="pallas")
    assert reg.total("solves") == 6.0
    by = reg.labeled("solves")
    assert by[(("kernel_impl", "pallas"),)] == 5.0
    assert by[(("kernel_impl", "ref"),)] == 1.0
    # unlabeled get with labels selects the exact series
    assert reg.get("solves", kernel_impl="ref") == 1.0


def test_registry_merge_composes_label_paths():
    """Merging child registries tags series with the child's position;
    nesting composes paths the way plane nesting does (g0/r1)."""
    leaf = MetricsRegistry()
    leaf.inc("admitted", 4.0)
    mid = MetricsRegistry()
    mid.merge(leaf, plane="r1")
    assert mid.get("admitted", plane="r1") == 4.0
    top = MetricsRegistry()
    top.merge(mid, plane="g0")
    # duplicate label key composes into a path, outermost first
    assert top.get("admitted", plane="g0/r1") == 4.0
    assert top.total("admitted") == 4.0


def test_registry_merge_sums_same_series_and_histograms():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.inc("c", 1.0)
    b.inc("c", 2.0)
    a.observe("h", 1.0)
    b.observe("h", 3.0)
    a.merge(b)
    assert a.get("c") == 3.0
    h = a.labeled("h")[()]
    assert h["count"] == 2 and h["sum"] == pytest.approx(4.0)
    assert h["min"] == 1.0 and h["max"] == 3.0


def test_registry_snapshot_flat_and_reset():
    reg = MetricsRegistry()
    reg.inc("a", 2.0)
    reg.gauge("g", 1.5)
    reg.observe("h", 4.0)
    snap = reg.snapshot()
    assert snap["a"] == 2.0 and snap["g"] == 1.5
    assert snap["h"]["count"] == 1
    # snapshot must be JSON-serializable (bench records embed it)
    json.dumps(snap)
    reg.snapshot(reset=True)
    assert reg.snapshot() == {}


def test_histogram_pow2_buckets_and_merge():
    h = Histogram()
    for v in (0.5, 1.0, 2.0, 3.0, 700.0):
        h.observe(v)
    d = h.to_dict()
    assert d["count"] == 5 and d["max"] == 700.0
    g = Histogram()
    g.observe(10.0)
    h.merge(g)
    assert h.count == 6
    assert sum(h.buckets.values()) == 6


def test_absorb_adapters_smoke():
    reg = MetricsRegistry()
    s = Stats(method="leastcost_python", rounds=3, solve_n=12,
              kernel_impl="ref", max_set_size=9, gossip_messages=7)
    absorb_engine_stats(reg, s)
    assert reg.total("engine.rounds") == 3.0
    assert reg.total("engine.gossip_messages") == 7.0
    assert reg.get("engine.max_set_size") == 9.0
    assert reg.get("engine.solves", kernel_impl="ref") == 1.0
    absorb_gossip_stats(reg, {"rounds": 2, "messages_sent": 6,
                              "records_sent": 12, "payload_sent": 48})
    assert reg.total("gossip.messages_sent") == 6.0


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


def test_tracer_records_spans_instants_and_flows():
    tr = Tracer()
    with tr.span("solve", track="placer", cat="solve", n=8):
        pass
    tr.instant("epoch", track="placer")
    tr.flow_begin(5, "submit", tenant="a")
    tr.flow_point(5, "dispatch")
    tr.flow_end(5, "release", outcome="released")
    evs = tr.events
    phs = [e["ph"] for e in evs]
    assert phs == ["X", "i", "b", "n", "e"]
    x = evs[0]
    assert x["name"] == "solve" and x["dur"] >= 0 and x["args"]["n"] == 8
    for e in evs[2:]:
        assert e["id"] == "req:5" and e["cat"]
    tr.clear()
    assert tr.events == []


def test_tracer_scoped_prefixes_share_one_buffer():
    tr = Tracer()
    r0 = tr.scoped("r0")
    g = tr.scoped("g1").scoped("r2")
    tr.flow_begin(1, "submit")
    r0.flow_point(1, "dispatch")
    g.flow_point(1, "2pc.reserve")
    ids = [e["id"] for e in tr.events]
    assert ids == ["req:1", "r0/req:1", "g1/r2/req:1"]
    # scoped views write into the parent's buffer, not their own
    assert r0.events is tr.events or list(r0.events) == list(tr.events)


def test_null_tracer_is_inert():
    assert isinstance(NULL, NullTracer) and not NULL.enabled
    # span/annotate return a shared no-op context: no per-call allocation
    assert NULL.span("x") is NULL.span("y", track="t", cat="c", k=1)
    with NULL.span("x"):
        pass
    NULL.instant("i")
    NULL.flow_begin(1, "submit")
    NULL.flow_point(1, "p")
    NULL.flow_end(1, "e")
    assert NULL.events == []
    assert NULL.scoped("r0") is NULL


# ---------------------------------------------------------------------------
# chrome-trace export
# ---------------------------------------------------------------------------


def test_chrome_export_schema_and_timeline(tmp_path):
    tr = Tracer()
    with tr.span("pump.round", track="pump", cat="pump"):
        with tr.span("solve", track="placer", cat="solve"):
            pass
    tr.flow_begin(0, "submit")
    tr.flow_end(0, "release")
    doc = to_chrome_trace(tr)
    assert validate_chrome_trace(doc) == []
    # metadata names every track; real events carry pid/tid
    metas = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert any(m["name"] == "process_name" for m in metas)
    path = tmp_path / "trace.json"
    out = write_chrome_trace(tr, str(path))
    assert validate_chrome_trace(json.loads(path.read_text())) == []
    assert out["traceEvents"]
    txt = text_timeline(tr)
    assert "pump.round" in txt


def test_validate_rejects_malformed_traces():
    assert validate_chrome_trace({"nope": 1})
    bad = {"traceEvents": [{"ph": "X", "name": "a", "ts": 0.0,
                            "pid": 1, "tid": 1}]}  # X without dur
    assert validate_chrome_trace(bad)
    unbalanced = {"traceEvents": [
        {"ph": "b", "name": "s", "cat": "lc", "id": "req:1",
         "ts": 0.0, "pid": 1, "tid": 1}]}
    assert validate_chrome_trace(unbalanced)


def _line_rg(mid_cap: float = 4.0):
    # 0 -- 1 -- 2 line; only node 1 has capacity
    rg = waxman(3, seed=0)
    rg.cap[:] = [0.0, mid_cap, 0.0]
    return rg


def test_centralized_lifecycle_reconstructable():
    tr = Tracer()
    rg = waxman(8, seed=4)
    cp = ControlPlane(rg, micro_batch=4, tracer=tr, **PYM)
    cp.register_tenant("a", weight=1.0)
    rid = cp.submit("a", random_dataflow(rg, 3, seed=1,
                                         creq_range=(0.05, 0.2),
                                         breq_range=(0.5, 2.0)))
    admitted = cp.pump(rounds=2)
    assert admitted, "scenario must admit for the lifecycle to exist"
    cp.release(rid)
    doc = to_chrome_trace(tr)
    assert validate_chrome_trace(doc) == []
    life = reconstruct_request(doc, rid)
    names = [e["name"] for e in life]
    assert names[0] == "submit" and names[-1] == "release"
    assert "admit" in names
    ts = [e["ts"] for e in life]
    assert ts == sorted(ts)


def test_spanning_lifecycle_reconstructable_across_regions():
    """Acceptance shape: one spanning request's submit -> chained 2PC
    reserves across >= 2 regions -> commit -> release is recoverable from
    the exported trace by rid alone."""
    R, k = 3, 4
    rg, assign = region_line(R, k, seed=9)
    tr = Tracer()
    cp = ControlPlane(rg, region_of=assign, micro_batch=8, fanout=2,
                      seed=9, tracer=tr, **PYM)
    cp.register_tenant("a", weight=1.0)
    df = DataflowPath.make([0.0, 0.1, 0.0], [0.5, 0.5], 0, rg.n - 1)
    rid = cp.submit("a", df, klass=1)
    for _ in range(6):
        cp.pump()
        if rid in cp.active_ids():
            break
    assert rid in cp.active_ids()
    cp.release(rid)
    doc = to_chrome_trace(tr)
    assert validate_chrome_trace(doc) == []
    life = reconstruct_request(doc, rid)
    names = [e["name"] for e in life]
    assert names[0] == "submit" and names[-1] == "release"
    assert names.count("2pc.reserve") >= 2
    assert "2pc.commit" in names and "admit" in names
    regions = {e["args"]["region"] for e in life
               if e["name"] == "2pc.reserve" and "region" in e.get("args", {})}
    assert len(regions) >= 2


def test_bit_identity_with_tracing_enabled():
    """A live Tracer must not perturb placement: traced and untraced
    planes replay the same fuzzed op sequence bit for bit."""
    rg = waxman(12, seed=5)
    kw = dict(micro_batch=6, max_attempts=3,
              policy=FairSharePolicy(slack=0.4), **PYM)
    a = ControlPlane(rg, **kw)
    b = ControlPlane(rg, tracer=Tracer(), **kw)
    for cp in (a, b):
        cp.register_tenant("x", weight=2.0)
        cp.register_tenant("y", weight=1.0)
    rng = np.random.default_rng(7)
    for step in range(30):
        op = rng.choice(["submit", "pump", "release"], p=[0.5, 0.35, 0.15])
        if op == "submit":
            df = random_dataflow(rg, 4, seed=900 + step,
                                 creq_range=(0.05, 0.3),
                                 breq_range=(0.5, 3.0))
            t = str(rng.choice(["x", "y"]))
            assert a.submit(t, df) == b.submit(t, df)
        elif op == "pump":
            assert ([t.tid for t in a.pump()]
                    == [t.tid for t in b.pump()])
        elif op == "release":
            ids = a.active_ids()
            assert ids == b.active_ids()
            if ids:
                rid = int(rng.choice(ids))
                a.release(rid)
                b.release(rid)
        np.testing.assert_array_equal(a.placer.cap, b.placer.cap)
        np.testing.assert_array_equal(a.placer.bw, b.placer.bw)
    assert len(b.tracer.events) > 0


# ---------------------------------------------------------------------------
# timing split (solve / overhead / conflict) — satellite 3
# ---------------------------------------------------------------------------


def _pumped_plane(**kw):
    rg = waxman(10, seed=3)
    cp = ControlPlane(rg, micro_batch=4, **kw, **PYM)
    cp.register_tenant("a", weight=1.0)
    for i in range(6):
        cp.submit("a", random_dataflow(rg, 3, seed=40 + i,
                                       creq_range=(0.05, 0.2),
                                       breq_range=(0.5, 2.0)))
    cp.pump(rounds=3)
    return cp


def test_timing_split_present_and_nonnegative_centralized():
    cp = _pumped_plane()
    t = cp.fairness_report()["timing"]
    assert set(t) == {"solve_ms", "overhead_ms", "conflict_resolve_ms"}
    assert all(v >= 0.0 for v in t.values())
    assert t["solve_ms"] > 0.0  # solves happened


def _regional_timing(levels=None):
    rg, assign = region_line(2, 4, seed=2)
    kw = dict(region_of=assign, micro_batch=4, seed=2, **PYM)
    if levels is not None:
        kw["levels"] = levels
    cp = ControlPlane(rg, **kw)
    cp.register_tenant("a", weight=1.0)
    for i in range(4):
        cp.submit("a", random_dataflow(rg, 3, seed=60 + i,
                                       creq_range=(0.05, 0.2),
                                       breq_range=(0.5, 2.0)))
    cp.pump(rounds=3)
    return cp.fairness_report()["timing"]


def test_timing_split_present_regional_plane():
    t = _regional_timing()
    assert set(t) == {"solve_ms", "overhead_ms", "conflict_resolve_ms"}
    assert all(v >= 0.0 for v in t.values())
    assert t["solve_ms"] > 0.0


def test_timing_split_present_hierarchical_plane():
    rg, assign = region_tree(2, 2, 3, seed=1)
    cp = ControlPlane(rg, region_of=assign, levels=2, branching=2,
                      micro_batch=4, seed=1, **PYM)
    cp.register_tenant("a", weight=1.0)
    for i in range(4):
        cp.submit("a", random_dataflow(rg, 3, seed=80 + i,
                                       creq_range=(0.05, 0.2),
                                       breq_range=(0.5, 2.0)))
    cp.pump(rounds=3)
    t = cp.fairness_report()["timing"]
    assert set(t) == {"solve_ms", "overhead_ms", "conflict_resolve_ms"}
    assert all(v >= 0.0 for v in t.values())
    assert t["solve_ms"] > 0.0


def test_timing_split_accumulates_on_pipelined_path():
    """dispatch_admit/commit_admit must feed the same timing counters as
    the synchronous admit_many path — and produce the same tickets."""
    rg = waxman(10, seed=6)
    dfs = [random_dataflow(rg, 3, seed=500 + i, creq_range=(0.05, 0.2),
                           breq_range=(0.5, 2.0)) for i in range(4)]
    sync = OnlinePlacer(rg, **PYM)
    t_sync = sync.admit_many(list(dfs))
    pipe = OnlinePlacer(rg, **PYM)
    pending = pipe.dispatch_admit(list(dfs))
    t_pipe = pipe.commit_admit(pending)
    assert ([t.tid for t in t_sync if t]
            == [t.tid for t in t_pipe if t])
    for st in (sync.stats, pipe.stats):
        assert st.solve_ms > 0.0
        assert st.overhead_ms >= 0.0
        assert st.conflict_resolve_ms >= 0.0
        assert st.solves > 0 and st.solve_n_sum > 0


def test_timing_and_kernel_impls_survive_defrag_and_preempt():
    cp = _pumped_plane(preempt=True)
    st = cp.placer.stats
    # the pure-python backend records no kernel impl; seed the labeled
    # counts the way a kernel backend would to exercise the stats surgery
    st.kernel_impls["ref"] = 3
    solve_before = st.solve_ms
    assert solve_before > 0.0
    cp.defrag()
    st = cp.placer.stats
    # snapshot/rollback around defrag must not lose the non-additive
    # carries or rewind the timing accumulators
    assert st.kernel_impls.get("ref", 0) >= 3
    assert st.solve_ms >= solve_before
    assert st.defrag_rounds >= 1


# ---------------------------------------------------------------------------
# kernel_impl / solve_n fold fix — satellite 1
# ---------------------------------------------------------------------------


def test_engine_stats_carries_kernel_impl_and_solve_n_centralized():
    cp = _pumped_plane()
    # the python backend reports no kernel impl, so seed the labeled count
    # a kernel backend would have left; the fold used to drop it entirely
    cp.placer.stats.kernel_impls["ref"] = cp.placer.stats.solves
    s = cp.engine_stats()
    assert s.kernel_impl == "ref"
    assert s.solve_n > 0  # mean padded solve dimension, not the default 0


def test_engine_stats_carries_kernel_impl_across_regions():
    rg, assign = region_line(2, 4, seed=3)
    cp = ControlPlane(rg, region_of=assign, micro_batch=4, seed=3, **PYM)
    cp.register_tenant("a", weight=1.0)
    for i in range(4):
        cp.submit("a", random_dataflow(rg, 3, seed=70 + i,
                                       creq_range=(0.05, 0.2),
                                       breq_range=(0.5, 2.0)))
    cp.pump(rounds=3)
    # pin distinct per-region backends: the cross-region fold must carry
    # them as a consensus label instead of last-writer-wins (or dropping
    # them to the zero default, the bug this fixes)
    cp.regions[0].placer.stats.kernel_impls["ref"] = 2
    cp.regions[1].placer.stats.kernel_impls["pallas"] = 1
    s = cp.engine_stats()
    assert s.kernel_impl.startswith("mixed(")
    assert "ref" in s.kernel_impl and "pallas" in s.kernel_impl
    assert s.solve_n > 0

    # consensus collapses when every region agrees
    cp.regions[1].placer.stats.kernel_impls = {"ref": 1}
    assert cp.engine_stats().kernel_impl == "ref"


def test_consensus_impl_labels_mixed_backends():
    assert ControlPlane._consensus_impl({"ref": 3}) == "ref"
    mixed = ControlPlane._consensus_impl({"ref": 2, "pallas": 5})
    assert mixed.startswith("mixed(") and "ref" in mixed and "pallas" in mixed
    assert ControlPlane._consensus_impl({}) == ""


# ---------------------------------------------------------------------------
# plane metrics registries
# ---------------------------------------------------------------------------


def test_plane_metrics_registry_centralized():
    cp = _pumped_plane()
    snap = cp.metrics_registry().snapshot()
    json.dumps(snap)  # must serialize into bench records
    assert snap["timing.solve_ms"] > 0.0
    assert any(k.startswith("placer.") for k in snap)


def test_plane_metrics_registry_merges_regions_with_labels():
    rg, assign = region_line(2, 4, seed=4)
    cp = ControlPlane(rg, region_of=assign, micro_batch=4, seed=4, **PYM)
    cp.register_tenant("a", weight=1.0)
    for i in range(4):
        cp.submit("a", random_dataflow(rg, 3, seed=90 + i,
                                       creq_range=(0.05, 0.2),
                                       breq_range=(0.5, 2.0)))
    cp.pump(rounds=3)
    reg = cp.metrics_registry()
    # per-region series are tagged with their plane position
    planes = {dict(lbl).get("plane")
              for lbl in reg.labeled("placer.admitted")}
    assert planes <= {"r0", "r1"} and planes
    assert reg.total("gossip.messages_sent") >= 0.0
    json.dumps(reg.snapshot())


# ---------------------------------------------------------------------------
# gossip windowed snapshot — satellite 2
# ---------------------------------------------------------------------------


def test_gossip_snapshot_windowing_preserves_lifetime():
    rg, assign = region_line(2, 4, seed=5)
    cp = ControlPlane(rg, region_of=assign, micro_batch=4, fanout=1,
                      seed=5, **PYM)
    cp.register_tenant("a", weight=1.0)
    cp.submit("a", _unit_df())
    cp.pump(rounds=3)
    bus = cp.bus
    life1 = bus.gossip_stats()
    w1 = bus.snapshot(reset=True)
    assert w1["messages_sent"] == life1["messages_sent"]
    # a fresh window starts at zero...
    assert bus.snapshot()["messages_sent"] == 0
    cp.pump(rounds=2)
    w2 = bus.snapshot(reset=True)
    assert w2["messages_sent"] > 0
    # ...while the lifetime counters never rewind
    life2 = bus.gossip_stats()
    assert life2["messages_sent"] == life1["messages_sent"] + w2["messages_sent"]


def test_gossip_bus_snapshot_unit():
    bus = GossipBus(3, fanout=1, seed=0)
    for _ in range(2):
        bus.tick()
    assert bus.snapshot()["rounds"] == 2
    bus.snapshot(reset=True)
    assert bus.snapshot()["rounds"] == 0
    assert bus.gossip_stats()["rounds"] == 2
