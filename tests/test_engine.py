"""Unified mapper engine: cross-backend parity + reconstruction fallbacks.

Every registered backend goes through the single ``solve()`` entry point and
must return the same optimal cost on a seeded instance suite (feasible AND
infeasible), with the exact PathMap algorithm as the reference.
"""
import numpy as np
import pytest

from repro.core import (
    DataflowPath,
    ResourceGraph,
    SimConfig,
    pathmap_exact,
    paper_example,
    random_dataflow,
    solve,
    solve_batch,
    validate_mapping,
    waxman,
)
from repro.core import engine
from repro.core.leastcost import leastcost_jax
from repro.core.problem import BIG, pad_request
from repro.core.reconstruct import backtrack, reconstruct_mapping

# seeds verified against pathmap_exact: all backends optimal / all infeasible
FEASIBLE_SEEDS = [0, 1, 3, 4, 6, 7, 8, 9]
INFEASIBLE_SEEDS = [2, 5, 11, 12]

PARITY_METHODS = [
    ("simulate", dict(cfg=SimConfig(policy="exact", max_messages=2_000_000))),
    ("leastcost_python", {}),
    ("leastcost_jax", {}),
    ("shard_map", {}),
]


def _instance(seed):
    rg = waxman(12, seed=seed)
    df = random_dataflow(rg, 5, seed=seed + 77)
    return rg, df


def test_registry_contents():
    for name in ("exact", "simulate", "leastcost_python", "anneal",
                 "random_k", "leastcost_jax", "shard_map"):
        assert name in engine.backends()
    with pytest.raises(ValueError, match="unknown mapper backend"):
        solve(*paper_example(), method="no_such_backend")


@pytest.mark.parametrize("seed", FEASIBLE_SEEDS)
def test_backend_parity_feasible(seed):
    rg, df = _instance(seed)
    ex, _ = pathmap_exact(rg, df, max_states=300_000)
    assert ex is not None
    for method, kw in PARITY_METHODS:
        m, st = solve(rg, df, method=method, **kw)
        assert m is not None, method
        assert abs(m.cost - ex.cost) < 1e-3, (method, m.cost, ex.cost)
        ok, why = validate_mapping(rg, df, m)
        assert ok, (method, why)
        assert st.method == method
        assert st.solve_ms >= 0.0


@pytest.mark.parametrize("seed", INFEASIBLE_SEEDS)
def test_backend_parity_infeasible(seed):
    rg, df = _instance(seed)
    ex, _ = pathmap_exact(rg, df, max_states=300_000)
    assert ex is None
    for method, kw in PARITY_METHODS:
        m, _ = solve(rg, df, method=method, **kw)
        assert m is None, method


def test_unified_stats_fields():
    rg, df = paper_example()
    _, st_sim = solve(rg, df, method="simulate", cfg=SimConfig(policy="leastcost"))
    assert st_sim.messages_sent > 0 and st_sim.virtual_time > 0
    _, st_bsp = solve(rg, df, method="shard_map")
    assert st_bsp.messages_sent > 0 and st_bsp.rounds >= 1
    _, st_py = solve(rg, df, method="leastcost_python")
    assert st_py.max_set_size > 0 and st_py.maps_generated > 0


def test_solve_batch_matches_serial_mixed_p():
    """Mixed-length requests share one padded vmapped DP."""
    rg = waxman(20, seed=5)
    dfs = [random_dataflow(rg, p, seed=30 + i) for i, p in
           enumerate([4, 6, 5, 6, 3, 4])]
    serial = [solve(rg, d, method="leastcost_jax")[0] for d in dfs]
    batched, st = solve_batch(rg, dfs, method="leastcost_jax")
    assert st.batch_size == len(dfs)
    for d, a, b in zip(dfs, serial, batched):
        assert (a is None) == (b is None)
        if a is not None:
            assert abs(a.cost - b.cost) < 1e-3
            ok, why = validate_mapping(rg, d, b)
            assert ok, why


def test_solve_batch_python_backend_loops():
    rg = waxman(12, seed=1)
    dfs = [random_dataflow(rg, 4, seed=60 + i) for i in range(3)]
    batched, st = solve_batch(rg, dfs, method="leastcost_python")
    serial = [solve(rg, d, method="leastcost_python")[0] for d in dfs]
    for a, b in zip(serial, batched):
        assert (a is None) == (b is None)
        if a is not None:
            assert abs(a.cost - b.cost) < 1e-6


# ---------------------------------------------------------------------------
# core.reconstruct unit tests: broken-chain and revisit-anomaly paths
# ---------------------------------------------------------------------------


def _line_graph(n=4, cap=5.0):
    edges = [(i, i + 1, 50.0, 1.0) for i in range(n - 1)]
    return ResourceGraph.from_edge_list([cap] * n, edges)


def test_backtrack_broken_chain_detected():
    rg = _line_graph()
    df = DataflowPath.make([0.0, 1.0, 0.0], [5.0, 5.0], src=0, dst=3)
    p, n = df.p, rg.n
    par_v = np.full((n, p + 1), -1, np.int32)  # no parents at all
    par_j = np.full((n, p + 1), -1, np.int32)
    _, _, ok = backtrack(par_v, par_j, src=0, dst=3, best_j=1, p=p, n=n)
    assert not ok


def test_reconstruct_broken_chain_falls_back():
    """A broken parent chain must trigger the sound path-carrying fallback
    (which still finds the optimum) and mark the stats accordingly."""
    rg = _line_graph()
    df = DataflowPath.make([0.0, 1.0, 0.0], [5.0, 5.0], src=0, dst=3)

    class S:
        validated = True
        fallback_used = False

    par_v = np.full((rg.n, df.p + 1), -1, np.int32)
    par_j = np.full((rg.n, df.p + 1), -1, np.int32)
    m = reconstruct_mapping(rg, df, par_v, par_j, 3.0, 1, stats=S)
    assert m is not None  # fallback solved it
    assert S.fallback_used and not S.validated
    ok, _ = validate_mapping(rg, df, m)
    assert ok


def test_reconstruct_revisit_anomaly_falls_back():
    """A closed chain whose route revisits a node fails validation and must
    also fall back (the DP state carries no visited set)."""
    rg = _line_graph()
    df = DataflowPath.make([0.0, 1.0, 0.0], [5.0, 5.0], src=0, dst=3)
    p, n = df.p, rg.n
    par_v = np.full((n, p + 1), -1, np.int32)
    par_j = np.full((n, p + 1), -1, np.int32)
    # forged pointers: dst(3) <- 2 <- 3 <- ... never happens in a valid DP;
    # the walk 3 -> 2 -> 1 -> 0 closes but we corrupt the cost so the
    # validate step (cost mismatch / revisit) rejects it.
    par_v[3, 1], par_j[3, 1] = 2, 1
    par_v[2, 1], par_j[2, 1] = 1, 1
    par_v[1, 1], par_j[1, 1] = 0, 0

    class S:
        validated = True
        fallback_used = False

    m = reconstruct_mapping(rg, df, par_v, par_j, 999.0, 1, stats=S)
    assert m is not None
    assert S.fallback_used
    ok, _ = validate_mapping(rg, df, m)
    assert ok


def test_reconstruct_infeasible_returns_none():
    rg = _line_graph()
    df = DataflowPath.make([0.0, 1.0, 0.0], [5.0, 5.0], src=0, dst=3)
    par_v = np.full((rg.n, df.p + 1), -1, np.int32)
    par_j = np.full((rg.n, df.p + 1), -1, np.int32)
    assert reconstruct_mapping(rg, df, par_v, par_j, float(BIG), 1) is None


def test_pad_request_preserves_solution():
    """Padding a request to a larger p_max must not change the DP answer."""
    rg = waxman(16, seed=4)
    df = random_dataflow(rg, 4, seed=21)
    m_direct, _ = leastcost_jax(rg, df)
    batched, _ = solve_batch(rg, [df, random_dataflow(rg, 7, seed=22)])
    m_padded = batched[0]
    assert (m_direct is None) == (m_padded is None)
    if m_direct is not None:
        assert abs(m_direct.cost - m_padded.cost) < 1e-3
        assert m_padded.assign == m_direct.assign


def test_pad_request_shapes():
    df = DataflowPath.make([0.0, 1.0, 2.0, 0.0], [5.0, 6.0, 7.0], 0, 3)
    prefix, breq = pad_request(df, p_max=7)
    assert prefix.shape == (8,) and breq.shape == (6,)
    assert prefix[-1] == prefix[4] == pytest.approx(3.0)
    assert np.all(breq[3:] >= BIG / 2)
