"""Regression guard for the loop-aware HLO cost model (the roofline's
profiler of record): trip-count multiplication verified against programs
with analytically known FLOPs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_cost


def _analyze(fn, *args):
    hlo = jax.jit(fn).lower(*args).compile().as_text()
    return hlo_cost.analyze(hlo)


def test_scan_flops_scale_with_trip_count():
    n, d, trips = 64, 128, 12
    w = jax.ShapeDtypeStruct((trips, d, d), jnp.float32)
    x = jax.ShapeDtypeStruct((n, d), jnp.float32)

    def scanned(w, x):
        def body(x, wi):
            return x @ wi, None
        y, _ = jax.lax.scan(body, x, w)
        return y

    r = _analyze(scanned, w, x)
    expect = 2 * n * d * d * trips
    assert r["flops"] == pytest.approx(expect, rel=0.05), (r["flops"], expect)


def test_unrolled_equals_scanned_flops():
    n, d, trips = 32, 64, 6
    w = jax.ShapeDtypeStruct((trips, d, d), jnp.float32)
    x = jax.ShapeDtypeStruct((n, d), jnp.float32)

    def scanned(w, x):
        def body(x, wi):
            return x @ wi, None
        return jax.lax.scan(body, x, w)[0]

    def unrolled(w, x):
        for i in range(trips):
            x = x @ w[i]
        return x

    rs = _analyze(scanned, w, x)
    ru = _analyze(unrolled, w, x)
    assert rs["flops"] == pytest.approx(ru["flops"], rel=0.05)


def test_nested_scan_multiplies():
    d, outer, inner = 32, 5, 7
    w = jax.ShapeDtypeStruct((outer, inner, d, d), jnp.float32)
    x = jax.ShapeDtypeStruct((d, d), jnp.float32)

    def fn(w, x):
        def obody(x, wo):
            def ibody(x, wi):
                return x @ wi, None
            return jax.lax.scan(ibody, x, wo)[0], None
        return jax.lax.scan(obody, x, w)[0]

    r = _analyze(fn, w, x)
    expect = 2 * d ** 3 * outer * inner
    assert r["flops"] == pytest.approx(expect, rel=0.05)


def test_collective_bytes_parsed():
    # single-device "collectives" don't lower to collective ops; just check
    # the parser handles a no-collective module gracefully
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    r = _analyze(lambda a: a @ a, x)
    assert r["collective_bytes_total"] == 0
    assert r["flops"] == pytest.approx(2 * 128 ** 3, rel=0.05)
    assert r["bytes_hbm"] > 0
