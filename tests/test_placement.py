"""BCPM placement engine (the paper's technique driving the launcher)."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import validate_mapping
from repro.launch.placement import (
    PodTopology, plan_pipeline, plan_serving, slice_resource_graph,
)
from repro.models.config import SHAPES


def test_slice_graph_shape():
    topo = PodTopology(pods=2)
    rg = slice_resource_graph(topo)
    assert rg.n == 32
    # ring within each pod + one DCI link between pods
    assert np.isfinite(rg.lat[15, 16])  # DCI
    assert rg.bw[0, 1] == 16 * 50.0


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "llama3.2-1b", "deepseek-moe-16b"])
def test_pipeline_plan_feasible_and_valid(arch):
    cfg = get_config(arch)
    plan = plan_pipeline(cfg, SHAPES["train_4k"], PodTopology(pods=2),
                         steps_per_sec=0.05, dst_slice=31)
    assert plan is not None, arch
    rg = slice_resource_graph(PodTopology(pods=2))
    ok, why = validate_mapping(
        rg,
        _df_of(plan), plan.mapping,
    )
    assert ok, (arch, why)
    # stages occupy a contiguous chain (each slice visited once)
    assert len(set(plan.route)) == len(plan.route)


def _df_of(plan):
    from repro.core.graph import DataflowPath
    creq = np.asarray([0.0] + plan.stage_tflops + [0.0], np.float32)
    breq = np.asarray(
        [plan.stage_bw_gbps[0]] + plan.stage_bw_gbps + [plan.stage_bw_gbps[-1]],
        np.float32,
    )
    return DataflowPath(creq, breq, plan.mapping.assign[0], plan.mapping.assign[-1])


def test_serving_dataflow_colocates_when_cheap():
    cfg = get_config("internvl2-2b")
    plan = plan_serving(cfg, SHAPES["prefill_32k"], requests_per_sec=2)
    assert plan is not None
    # a light 2-stage dataflow should not span the pod
    assert len(set(plan.stage_slices)) <= 2


def test_rate_too_high_is_infeasible():
    cfg = get_config("qwen2.5-14b")
    plan = plan_pipeline(cfg, SHAPES["train_4k"], PodTopology(pods=1),
                         steps_per_sec=1e6)
    assert plan is None
