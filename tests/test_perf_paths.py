"""The §Perf-optimized code paths match their paper-faithful baselines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm


def _ssd_inputs(seed, B=2, S=64, nh=3, hp=4, N=5):
    rng = np.random.default_rng(seed)
    dt = jnp.asarray(np.abs(rng.normal(0.05, 0.02, (B, S, nh))).astype(np.float32))
    A = jnp.asarray(-np.abs(rng.normal(1, 0.3, nh)).astype(np.float32))
    xh = jnp.asarray(rng.normal(size=(B, S, nh, hp)).astype(np.float32))
    Bc = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    Cc = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    h0 = jnp.asarray(rng.normal(size=(B, nh, hp, N)).astype(np.float32))
    return dt, A, xh, Bc, Cc, h0


@pytest.mark.parametrize("seed,Q", [(0, 8), (1, 16), (2, 32)])
def test_ssd_chunked_matches_recurrence(seed, Q):
    dt, A, xh, Bc, Cc, h0 = _ssd_inputs(seed)
    y1, l1 = ssm._ssd_chunked(dt, A, xh, Bc, Cc, h0, Q)
    a = jnp.exp(dt * A)
    bterm = (dt[..., None] * xh)[..., None] * Bc[:, :, None, None, :]
    a5 = jnp.broadcast_to(a[..., None, None], bterm.shape)
    h, l2 = ssm._chunked_assoc_scan(a5, bterm, h0, chunk=16)
    y2 = jnp.einsum("bshpn,bsn->bshp", h, Cc)
    # bf16 intra-chunk math: tolerance reflects the compute dtype
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=5e-2)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=5e-2)


def test_ssd_chunked_no_initial_state():
    dt, A, xh, Bc, Cc, _ = _ssd_inputs(3)
    y1, l1 = ssm._ssd_chunked(dt, A, xh, Bc, Cc, None, 8)
    a = jnp.exp(dt * A)
    bterm = (dt[..., None] * xh)[..., None] * Bc[:, :, None, None, :]
    a5 = jnp.broadcast_to(a[..., None, None], bterm.shape)
    h, l2 = ssm._chunked_assoc_scan(a5, bterm, None, chunk=16)
    y2 = jnp.einsum("bshpn,bsn->bshp", h, Cc)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=5e-2)


def test_seq_parallel_train_step_matches_tp():
    """Sequence-parallel and TP rule-sets produce the same training math."""
    from repro.launch.mesh import make_local_mesh
    from repro.launch.steps import build_train_step, init_train_state
    from repro.models.config import ModelConfig, ShapeConfig
    from repro.models.registry import make_batch
    from repro.optim.adamw import OptConfig

    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                      dtype="float32")
    shape = ShapeConfig("s", "train", seq_len=32, global_batch=4)
    mesh = make_local_mesh(1, 1)
    opt = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    batch = make_batch(cfg, shape)
    losses = {}
    for mode in ("tp", "seq"):
        built = build_train_step(cfg, shape, mesh, opt, mode=mode)
        state = init_train_state(cfg, built, seed=0)
        _, m = built.fn(state, batch)
        losses[mode] = float(m["loss"])
    assert losses["tp"] == pytest.approx(losses["seq"], rel=2e-3)


def test_batched_mapper_matches_serial():
    from repro.core import leastcost_jax, random_dataflow, waxman
    from repro.core.leastcost import leastcost_jax_batched

    rg = waxman(40, seed=9)
    dfs = [random_dataflow(rg, 6, seed=100 + i) for i in range(6)]
    serial = [leastcost_jax(rg, d)[0] for d in dfs]
    batched = leastcost_jax_batched(rg, dfs)
    for s, b in zip(serial, batched):
        assert (s is None) == (b is None)
        if s is not None:
            assert s.cost == pytest.approx(b.cost, rel=1e-4)
