"""Pallas masked min-plus kernel vs pure-jnp oracle: shape/dtype sweep in
interpret mode (CPU), including argmin tie-breaking and padding edges."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.minplus import masked_minplus, masked_minplus_ref
from repro.kernels.minplus.minplus import masked_minplus_pallas, BIG


def _instance(n, K, seed, inf_frac=0.4):
    rng = np.random.default_rng(seed)
    P = np.where(rng.random((n, K)) < inf_frac, BIG,
                 rng.random((n, K)) * 10).astype(np.float32)
    lat = np.where(rng.random((n, n)) < 0.5, BIG,
                   rng.random((n, n)) * 5 + 0.1).astype(np.float32)
    bw = (rng.random((n, n)) * 100).astype(np.float32)
    breq = (rng.random(max(K - 1, 1)) * 80).astype(np.float32)
    return (jnp.asarray(P), jnp.asarray(lat), jnp.asarray(bw),
            jnp.asarray(breq[: K - 1]))


@pytest.mark.parametrize("n,K", [(8, 2), (17, 3), (50, 7), (128, 9), (130, 3),
                                 (256, 33), (300, 17)])
def test_kernel_matches_oracle(n, K):
    args = _instance(n, K, seed=n * 1000 + K)
    C1, pv1 = masked_minplus(*args)
    C2, pv2 = masked_minplus_ref(*args)
    np.testing.assert_allclose(np.asarray(C1), np.asarray(C2), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(pv1), np.asarray(pv2))


@pytest.mark.parametrize("tiles", [(8, 8, 8), (128, 8, 8), (8, 128, 128),
                                   (64, 64, 16)])
def test_kernel_tile_sweep(tiles):
    args = _instance(100, 5, seed=42)
    C1, pv1 = masked_minplus(*args, tiles=tiles)
    C2, pv2 = masked_minplus_ref(*args)
    np.testing.assert_allclose(np.asarray(C1), np.asarray(C2), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(pv1), np.asarray(pv2))


def test_all_infeasible_column():
    n, K = 32, 4
    P, lat, bw, breq = _instance(n, K, seed=7)
    breq = jnp.full((K - 1,), BIG)  # nothing satisfies any bandwidth
    C, pv = masked_minplus(P, lat, bw, breq)
    assert bool((np.asarray(C) >= BIG / 2).all())


def test_ties_break_to_first_v():
    n, K = 16, 3
    P = jnp.zeros((n, K), jnp.float32)  # every v offers cost 0
    lat = jnp.ones((n, n), jnp.float32)
    bw = jnp.full((n, n), 100.0, jnp.float32)
    breq = jnp.asarray([1.0, 1.0], jnp.float32)
    _, pv = masked_minplus(P, lat, bw, breq)
    _, pv_ref = masked_minplus_ref(P, lat, bw, breq)
    np.testing.assert_array_equal(np.asarray(pv), np.asarray(pv_ref))
    assert (np.asarray(pv)[:, 1:] == 0).all()


def test_interpret_flag_explicit():
    args = _instance(64, 4, seed=9)
    from repro.kernels.minplus.ops import _breq_k
    bq = _breq_k(args[3], args[0].shape[1])
    C, pv = masked_minplus_pallas(args[0], args[1], args[2], bq, interpret=True)
    C2, pv2 = masked_minplus_ref(*args)
    np.testing.assert_allclose(np.asarray(C), np.asarray(C2), rtol=1e-6)
