"""Batched fused superstep kernel (kernels/minplus/batched) vs the vmapped
pure-jnp DP: bit-for-bit parity on mixed-p padded batches, in both the
fused-jnp mirror and Pallas interpret mode (the CPU-CI kernel cross-check),
plus tie-breaking / BIG-clamp / padded-column edge cases and the engine /
online-service integration of ``use_kernel=True``."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import OnlinePlacer, random_dataflow, solve_batch, waxman
from repro.core.leastcost import (
    _leastcost_dp,
    _leastcost_dp_batched,
    _move_step_ref,
    _place_step,
    leastcost_jax,
    leastcost_jax_batched,
)
from repro.core.problem import BATCH_IN_AXES, BIG, stack_requests
from repro.kernels.minplus import batched as bk


def _stream(rg, ps, seed0=500):
    """Light requests (several fit the network at once) of mixed length."""
    return [
        random_dataflow(rg, p, seed=seed0 + i,
                        creq_range=(0.02, 0.2), breq_range=(0.5, 5.0))
        for i, p in enumerate(ps)
    ]


def _vmapped_dp(tensors, n, p_max, max_rounds):
    fn = jax.vmap(
        lambda t: _leastcost_dp(t, n=n, p=p_max, max_rounds=max_rounds),
        in_axes=(BATCH_IN_AXES,),
    )
    return fn(tensors)


def _assert_dp_equal(a, b):
    for x, y, name in zip(a[:5], b[:5], ("C", "par_v", "par_j", "cost", "j")):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y), err_msg=name)


# ---------------------------------------------------------------------------
# Full-DP parity: fused batched path vs vmapped jnp oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,ps,seed", [
    (12, [4, 6, 5, 3], 0),
    (16, [5, 5, 5], 7),
    (20, [3, 7, 4, 6, 2, 5], 21),
])
def test_fused_ref_matches_vmapped_bitforbit(n, ps, seed):
    rg = waxman(n, seed=seed)
    dfs = _stream(rg, ps, seed0=1000 * seed)
    tensors, p_max = stack_requests(rg, dfs)
    out_v = _vmapped_dp(tensors, n, p_max, n - 1)
    out_b = _leastcost_dp_batched(tensors, B=len(dfs), n=n, p=p_max,
                                  max_rounds=n - 1, impl="ref")
    _assert_dp_equal(out_v, out_b)


@pytest.mark.parametrize("tiles", [(1, 8, 8, 8), (2, 8, 8, 8), (4, 16, 16, 8),
                                   (2, 8, 16, 4)])
def test_pallas_interpret_matches_ref_bitforbit(tiles):
    """Interpret-mode Pallas kernel vs the fused jnp mirror, including
    b_tile > 1 (padded batch rows) and k_tile < K (multiple k blocks)."""
    n, ps = 13, [4, 6, 3]
    rg = waxman(n, seed=5)
    dfs = _stream(rg, ps, seed0=40)
    tensors, p_max = stack_requests(rg, dfs)
    out_ref = _leastcost_dp_batched(tensors, B=len(dfs), n=n, p=p_max,
                                    max_rounds=n - 1, impl="ref")
    out_pal = _leastcost_dp_batched(tensors, B=len(dfs), n=n, p=p_max,
                                    max_rounds=n - 1, impl="interpret",
                                    tiles=tiles)
    _assert_dp_equal(out_ref, out_pal)


def test_mappings_match_and_respect_mixed_p():
    """End-to-end: kernel-path mappings equal the vmapped path's exactly and
    keep each request's true length (padded columns never leak)."""
    rg = waxman(18, seed=2)
    dfs = _stream(rg, [3, 6, 4, 5, 6, 2], seed0=70)
    ms_v = leastcost_jax_batched(rg, dfs)
    ms_k = leastcost_jax_batched(rg, dfs, use_kernel=True)
    assert any(m is not None for m in ms_v)
    for df, a, b in zip(dfs, ms_v, ms_k):
        assert (a is None) == (b is None)
        if a is not None:
            assert a.assign == b.assign and a.route == b.route
            assert a.cost == b.cost
            assert len(b.assign) == df.p


# ---------------------------------------------------------------------------
# Single-superstep edge cases (ties, BIG clamping, padded masking)
# ---------------------------------------------------------------------------


def _superstep_pair(C, pv, pj, lat, bw, cap, prefix, breq_k, tiles):
    ref = bk.batched_superstep_ref(C, pv, pj, lat, bw, cap, prefix, breq_k)
    pads = bk.pad_batched_problem(lat, bw, cap, prefix, breq_k, tiles=tiles)
    Bp, K_pad = pads["prefix"].shape
    n_pad = pads["lat"].shape[0]
    B, n, K = C.shape

    def fill(x, v):
        return jnp.full((Bp, n_pad, K_pad), v, x.dtype).at[:B, :n, :K].set(x)

    pal = bk.batched_superstep_pallas(
        fill(C, BIG), fill(pv, -1), fill(pj, -1),
        pads["lat"], pads["bw"], pads["cap"], pads["prefix"], pads["breq_k"],
        tiles=tiles, interpret=True,
    )
    pal = tuple(x[:B, :n, :K] for x in pal)
    return ref, pal


def _random_state(B, n, K, seed, big_frac=0.4):
    rng = np.random.default_rng(seed)
    C = np.where(rng.random((B, n, K)) < big_frac, BIG,
                 rng.random((B, n, K)) * 10).astype(np.float32)
    pv = rng.integers(-1, n, size=(B, n, K)).astype(np.int32)
    pj = rng.integers(-1, K, size=(B, n, K)).astype(np.int32)
    lat = np.where(rng.random((n, n)) < 0.5, BIG,
                   rng.random((n, n)) * 5 + 0.1).astype(np.float32)
    np.fill_diagonal(lat, BIG)
    bw = (rng.random((n, n)) * 100).astype(np.float32)
    cap = (rng.random(n) * 6).astype(np.float32)
    creq = rng.random((B, K - 1)).astype(np.float32) * 2
    prefix = np.concatenate(
        [np.zeros((B, 1), np.float32), np.cumsum(creq, axis=1)], axis=1)
    breq_k = np.concatenate(
        [np.full((B, 1), BIG, np.float32),
         (rng.random((B, K - 2)) * 60).astype(np.float32),
         np.full((B, 1), BIG, np.float32)], axis=1)
    j = jnp.asarray
    return (j(C), j(pv), j(pj), j(lat), j(bw), j(cap), j(prefix), j(breq_k))


@pytest.mark.parametrize("seed,tiles", [(0, (1, 8, 8, 8)), (1, (2, 8, 8, 4)),
                                        (2, (4, 16, 8, 8))])
def test_superstep_random_states(seed, tiles):
    args = _random_state(B=3, n=12, K=6, seed=seed)
    ref, pal = _superstep_pair(*args, tiles=tiles)
    for r, p, name in zip(ref, pal, ("C", "par_v", "par_j")):
        np.testing.assert_array_equal(np.asarray(r), np.asarray(p),
                                      err_msg=name)


def test_superstep_ties_break_like_jnp_path():
    """Move ties must break to the FIRST v (kernel: strict `<` across
    v-tiles + first-min within a tile), place ties to the LARGEST j —
    exactly the jnp `_place_step` / `_move_step_ref` rules."""
    B, n, K = 2, 16, 4
    # zero-cost states only at v in {0, 1}, j in {1, 2}: every other row
    # reaches cost 1 through a tie between v=0 and v=1, and the place step
    # at the winning v ties between j=1 and j=2 for k=2
    C = jnp.full((B, n, K), BIG, jnp.float32)
    C = C.at[:, :2, 1:3].set(0.0)
    pv = jnp.full((B, n, K), -1, jnp.int32)
    pj = jnp.full((B, n, K), -1, jnp.int32)
    lat = jnp.full((n, n), 1.0, jnp.float32)  # every move costs 1
    lat = lat.at[jnp.arange(n), jnp.arange(n)].set(BIG)  # no self moves
    bw = jnp.full((n, n), 100.0, jnp.float32)
    cap = jnp.full((n,), 50.0, jnp.float32)
    prefix = jnp.tile(jnp.arange(K, dtype=jnp.float32)[None, :], (B, 1)) * 0.1
    breq_k = jnp.concatenate(
        [jnp.full((B, 1), BIG), jnp.full((B, K - 2), 1.0),
         jnp.full((B, 1), BIG)], axis=1)
    ref, pal = _superstep_pair(C, pv, pj, lat, bw, cap, prefix, breq_k,
                               tiles=(1, 8, 8, 8))
    for r, p in zip(ref, pal):
        np.testing.assert_array_equal(np.asarray(r), np.asarray(p))
    Cn, pvn, pjn = (np.asarray(x) for x in pal)
    assert (Cn[:, 2:, 1:3] == 1.0).all()  # updated via the tied move
    assert (pvn[:, 2:, 1:3] == 0).all()  # v=0 wins the v-tie
    assert (pjn[:, 2:, 1] == 1).all()  # only j=1 reaches k=1
    assert (pjn[:, 2:, 2] == 2).all()  # j in {1,2} tie at k=2 -> largest j


def test_superstep_big_overflow_clamped():
    """Where every feasible move adds lat to a BIG state, the kernel clamps
    BIG + lat while the jnp path does not — the difference must not leak
    through the monotone state update."""
    args = list(_random_state(B=2, n=10, K=5, seed=9, big_frac=1.0))
    # C all BIG -> every move candidate is BIG + lat (incl. lat = BIG rows)
    ref, pal = _superstep_pair(*args, tiles=(1, 8, 8, 8))
    for r, p in zip(ref, pal):
        np.testing.assert_array_equal(np.asarray(r), np.asarray(p))
    # state must be unchanged: nothing can improve on BIG
    np.testing.assert_array_equal(np.asarray(ref[0]), np.asarray(args[0]))


def test_padded_columns_stay_masked():
    """k columns beyond a request's p_eff carry BIG breq (ghost dataflow
    edges): the kernel's padded k/batch blocks must never produce a finite
    cost there."""
    rg = waxman(12, seed=11)
    dfs = _stream(rg, [3, 6], seed0=90)  # p_eff 3 vs 6: columns 4..6 ghost
    tensors, p_max = stack_requests(rg, dfs)
    C, *_ = _leastcost_dp_batched(tensors, B=2, n=12, p=p_max,
                                  max_rounds=11, impl="interpret",
                                  tiles=(2, 8, 8, 8))
    C = np.asarray(C)
    # request 0 has p_eff=3: state columns beyond its true sink (k > 3) are
    # unreachable -> must still hold BIG
    assert (C[0, :, 4:] >= BIG / 2).all()


def test_place_move_refs_still_agree_with_batched_mirrors():
    """The fused mirrors in kernels/minplus/batched must track the canonical
    single-request steps in core.leastcost (guards against drift)."""
    rng = np.random.default_rng(3)
    n, K = 11, 6
    C = jnp.asarray(np.where(rng.random((n, K)) < 0.3, BIG,
                             rng.random((n, K)) * 8).astype(np.float32))
    cap = jnp.asarray((rng.random(n) * 5).astype(np.float32))
    prefix = jnp.asarray(np.concatenate(
        [[0.0], np.cumsum(rng.random(K - 1) * 2)]).astype(np.float32))
    P1, pj1 = _place_step(C, cap, prefix)
    P2, pj2 = bk._place_batched_ref(C[None], cap, prefix[None])
    np.testing.assert_array_equal(np.asarray(P1), np.asarray(P2[0]))
    np.testing.assert_array_equal(np.asarray(pj1), np.asarray(pj2[0]))

    lat = jnp.asarray(np.where(rng.random((n, n)) < 0.5, BIG,
                               rng.random((n, n)) * 4 + 0.1).astype(np.float32))
    bw = jnp.asarray((rng.random((n, n)) * 100).astype(np.float32))
    breq = jnp.asarray((rng.random(K - 2) * 50).astype(np.float32))
    Cm1, pv1 = _move_step_ref(P1, lat, bw, breq)
    breq_k = jnp.concatenate([jnp.full((1,), BIG), breq, jnp.full((1,), BIG)])
    Cm2, pv2 = bk._move_batched_ref(P1[None], lat, bw, breq_k[None])
    np.testing.assert_array_equal(np.asarray(Cm1), np.asarray(Cm2[0]))
    np.testing.assert_array_equal(np.asarray(pv1), np.asarray(pv2[0]))


# ---------------------------------------------------------------------------
# Engine / online-service integration
# ---------------------------------------------------------------------------


def test_bucket_batch_results_unchanged():
    """Power-of-two tensor-level bucketing (the online placer's recompile
    bound) must not change any real request's result, on either DP path."""
    rg = waxman(14, seed=8)
    dfs = _stream(rg, [5, 4, 6], seed0=55)  # 3 requests -> bucket of 4
    for kw in ({}, dict(use_kernel=True)):
        plain = leastcost_jax_batched(rg, dfs, **kw)
        bucketed = leastcost_jax_batched(rg, dfs, bucket_batch=True, **kw)
        assert len(bucketed) == len(dfs)
        for a, b in zip(plain, bucketed):
            assert (a is None) == (b is None)
            if a is not None:
                assert a.assign == b.assign and a.cost == b.cost


def test_engine_solve_batch_kernel_parity():
    rg = waxman(16, seed=13)
    dfs = _stream(rg, [5, 4, 6, 5], seed0=60)
    ms_v, st_v = solve_batch(rg, dfs, method="leastcost_jax")
    ms_k, st_k = solve_batch(rg, dfs, method="leastcost_jax", use_kernel=True)
    assert st_v.kernel_impl == "" and st_k.kernel_impl == "ref"
    assert st_k.batch_size == len(dfs) and st_k.rounds > 0
    for a, b in zip(ms_v, ms_k):
        assert (a is None) == (b is None)
        if a is not None:
            assert a.assign == b.assign and a.cost == b.cost


def test_engine_solve_kernel_single_request():
    rg = waxman(14, seed=17)
    df = _stream(rg, [5], seed0=30)[0]
    m_v, _ = leastcost_jax(rg, df)
    m_k, st = leastcost_jax(rg, df, use_kernel=True)
    assert st.kernel_impl == "ref"
    assert (m_v is None) == (m_k is None)
    if m_v is not None:
        assert m_v.assign == m_k.assign and m_v.cost == m_k.cost


def test_online_placer_kernel_path():
    rg = waxman(16, seed=4)
    dfs = _stream(rg, [4, 5, 3, 5, 4, 6], seed0=20)
    plain = OnlinePlacer(rg)
    fused = OnlinePlacer(rg, use_kernel=True)
    t_p = plain.admit_many(dfs)
    t_f = fused.admit_many(dfs)
    fused.check_invariants()
    assert fused.solve_cfg.get("use_kernel") is True
    assert [t is None for t in t_p] == [t is None for t in t_f]
    for a, b in zip(t_p, t_f):
        if a is not None:
            assert a.mapping.cost == b.mapping.cost
    # churn re-mapping also runs through the kernel path
    used = [v for t in t_f if t for v in t.mapping.route]
    if used:
        fused.fail_node(max(set(used), key=used.count))
        fused.check_invariants()
