"""Hypothesis property tests on the system's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (
    DataflowPath, ResourceGraph, leastcost_jax, leastcost_python,
    pathmap_exact, validate_mapping,
)
from repro.core.graph import route_from_assign


@st.composite
def bcpm_instance(draw):
    n = draw(st.integers(4, 10))
    p = draw(st.integers(2, 5))
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    # random connected-ish graph
    density = draw(st.floats(0.2, 0.7))
    adj = rng.random((n, n)) < density
    adj |= np.roll(np.eye(n, dtype=bool), 1, axis=1)  # ring: connected
    adj &= ~np.eye(n, dtype=bool)
    adj |= adj.T
    cap = rng.uniform(0.5, 8.0, n).astype(np.float32)
    bw = np.where(adj, rng.uniform(5, 100, (n, n)), 0).astype(np.float32)
    bw = np.minimum(bw, bw.T)
    lat = np.where(adj, rng.uniform(0.1, 5, (n, n)), np.inf).astype(np.float32)
    lat = np.minimum(lat, lat.T)
    np.fill_diagonal(lat, 0.0)
    np.fill_diagonal(bw, 0.0)
    rg = ResourceGraph(cap, bw, lat)
    creq = rng.uniform(0, 3, p).astype(np.float32)
    creq[0] = creq[-1] = 0.0
    breq = rng.uniform(5, 70, max(p - 1, 1)).astype(np.float32)
    src, dst = rng.choice(n, 2, replace=False)
    return rg, DataflowPath(creq, breq, int(src), int(dst))


@settings(max_examples=40, deadline=None)
@given(bcpm_instance())
def test_returned_mappings_always_feasible(inst):
    """Any mapping any solver returns satisfies every BCPM constraint."""
    rg, df = inst
    for solver in (leastcost_python, leastcost_jax):
        m, _ = solver(rg, df)
        if m is not None:
            ok, why = validate_mapping(rg, df, m)
            assert ok, why


@settings(max_examples=25, deadline=None)
@given(bcpm_instance())
def test_heuristic_never_beats_exact(inst):
    rg, df = inst
    try:
        ex, _ = pathmap_exact(rg, df, max_states=150_000)
    except MemoryError:
        return
    m, _ = leastcost_python(rg, df)
    if ex is None:
        assert m is None  # heuristic prunes but never invents feasibility
    else:
        # pruning may (rarely) lose feasibility or optimality, but a
        # returned mapping can never beat the optimum
        assert m is None or m.cost >= ex.cost - 1e-5


@settings(max_examples=25, deadline=None)
@given(bcpm_instance(), st.floats(1.1, 3.0))
def test_capacity_monotonicity(inst, scale):
    """Scaling capacities/bandwidths up never loses feasibility."""
    rg, df = inst
    m1, _ = leastcost_python(rg, df)
    rg2 = ResourceGraph(rg.cap * scale, rg.bw * scale, rg.lat)
    m2, _ = leastcost_python(rg2, df)
    if m1 is not None:
        assert m2 is not None
        assert m2.cost <= m1.cost + 1e-5


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 6), min_size=1, max_size=12))
def test_route_from_assign_collapses(assign):
    r = route_from_assign(assign)
    assert len(r) >= 1
    assert all(a != b for a, b in zip(r[:-1], r[1:]))
    # order-preserving subsequence
    it = iter(assign)
    for v in r:
        assert any(x == v for x in it) or True
