"""Decentralized regional control plane: partitioning, gossiped share
estimates, cut-edge two-phase commit, and the property suite — seeded fuzz
over adversarial interleavings of submit/pump/gossip/partition/heal/
release/fail/defrag across R regions, asserting the global conservation
ledger, bit-for-bit R=1 identity with the centralized plane, and
no-over-commit under maximally stale gossip."""
import collections

import numpy as np
import pytest

from repro.core import DataflowPath, random_dataflow, waxman
from repro.service import (
    ControlPlane,
    FairSharePolicy,
    GossipBus,
    RegionalControlPlane,
    cut_edges,
    partition_regions,
    region_subgraph,
    split_dataflow,
)

PYM = dict(method="leastcost_python")  # pure-python backend: fast, no jit


# ---------------------------------------------------------------------------
# partitioning / subgraphs
# ---------------------------------------------------------------------------


def test_partition_is_balanced_deterministic_and_total():
    rg = waxman(23, seed=3)
    a1 = partition_regions(rg, 4, seed=5)
    a2 = partition_regions(rg, 4, seed=5)
    np.testing.assert_array_equal(a1, a2)
    counts = collections.Counter(a1.tolist())
    assert set(counts) == {0, 1, 2, 3}
    assert max(counts.values()) - min(counts.values()) <= 1
    # every node assigned exactly once, R clamped to n
    assert partition_regions(rg, 100, seed=0).max() == rg.n - 1


def test_region_subgraph_masks_foreign_capacity_and_links():
    rg = waxman(16, seed=2)
    assign = partition_regions(rg, 2, seed=0)
    sub = region_subgraph(rg, assign, 0)
    mine = assign == 0
    assert np.all(sub.cap[~mine] == 0)
    np.testing.assert_array_equal(sub.cap[mine], rg.cap[mine])
    # no link leaves the region
    for (u, v) in sub.edges():
        assert mine[u] and mine[v]
    # the masked links are exactly the complement of cuts + foreign links
    cuts = set(cut_edges(rg, assign))
    for (u, v) in rg.edges():
        if mine[u] and mine[v]:
            assert np.isfinite(sub.lat[u, v])
        else:
            assert not np.isfinite(sub.lat[u, v])
            if mine[u] != mine[v]:
                assert (u, v) in cuts


def test_r1_subgraph_is_the_whole_graph_bitwise():
    rg = waxman(12, seed=7)
    assign = partition_regions(rg, 1)
    sub = region_subgraph(rg, assign, 0)
    np.testing.assert_array_equal(sub.cap, rg.cap)
    np.testing.assert_array_equal(sub.bw, rg.bw)
    np.testing.assert_array_equal(sub.lat, rg.lat)
    assert cut_edges(rg, assign) == []


def test_split_dataflow_conserves_requirements():
    df = DataflowPath.make([0.1, 0.2, 0.3, 0.4], [1.0, 2.0, 3.0], src=0, dst=9)
    a, b = split_dataflow(df, 1, 4, 5)
    # ghost gateway endpoints: zero-compute nodes pinned at the cut's
    # tail (a.dst) / head (b.src) gateways, carrying the cut edge's
    # bandwidth from the real boundary node to the gateway
    assert a.src == 0 and a.dst == 4 and b.src == 5 and b.dst == 9
    np.testing.assert_array_equal(
        a.creq, np.concatenate([df.creq[:2], [np.float32(0)]]))
    np.testing.assert_array_equal(
        b.creq, np.concatenate([[np.float32(0)], df.creq[2:]]))
    # real compute is conserved across the split
    assert float(np.sum(a.creq) + np.sum(b.creq)) == pytest.approx(
        float(np.sum(df.creq)))
    # the segments keep their interior edges and each carries the cut
    # edge's requirement (breq[1]) on its gateway-transport edge
    np.testing.assert_array_equal(a.breq, [1.0, 2.0])
    np.testing.assert_array_equal(b.breq, [2.0, 3.0])


def test_split_dataflow_chain_transit_segments():
    """Equal consecutive splits make pure transit segments: no real
    dataflow nodes, only ghost gateway endpoints carrying the one cut
    dataflow edge across the region."""
    from repro.service import split_dataflow_chain

    df = DataflowPath.make([0.5, 0.75], [2.0], src=0, dst=9)
    a, t, b = split_dataflow_chain(df, [0, 0], [(1, 4), (5, 8)])
    np.testing.assert_array_equal(a.creq, [0.5, 0.0])
    np.testing.assert_array_equal(a.breq, [2.0])
    assert (a.src, a.dst) == (0, 1)
    # the transit segment spans the middle region gateway-to-gateway
    np.testing.assert_array_equal(t.creq, [0.0, 0.0])
    np.testing.assert_array_equal(t.breq, [2.0])
    assert (t.src, t.dst) == (4, 5)
    np.testing.assert_array_equal(b.creq, [0.0, 0.75])
    assert (b.src, b.dst) == (8, 9)
    # a transit region whose in/out gateway coincide needs no edge at all
    (_, t1, _) = split_dataflow_chain(df, [0, 0], [(1, 4), (4, 8)])
    np.testing.assert_array_equal(t1.creq, [0.0])
    assert t1.breq.size == 0 and (t1.src, t1.dst) == (4, 4)


# ---------------------------------------------------------------------------
# gossip fabric
# ---------------------------------------------------------------------------


def test_gossip_round_costs_exactly_R_times_fanout():
    bus = GossipBus(4, fanout=2, seed=0)
    for r in range(4):
        bus.publish(r, {"a": float(r)}, {}, 1.0)
    for _ in range(5):
        assert bus.tick() == 4 * 2
    assert bus.messages_sent == 5 * 4 * 2


def test_gossip_merge_keeps_freshest_version_only():
    bus = GossipBus(3, fanout=2, seed=1)
    bus.publish(0, {"a": 1.0}, {}, 1.0)
    bus.tick()
    bus.publish(0, {"a": 5.0}, {}, 1.0)  # version 2 supersedes
    for _ in range(3):
        bus.tick()
    for r in range(3):
        rec = bus.views[r].get(0)
        assert rec is not None and rec.version == 2
        assert rec.committed["a"] == 5.0
    assert bus.max_staleness() == 0


def test_full_fanout_disseminates_in_one_round():
    bus = GossipBus(5, fanout=4, seed=3)  # push to everyone
    for r in range(5):
        bus.publish(r, {"t": float(r)}, {}, 0.0)
    bus.tick()
    assert bus.max_staleness() == 0
    for r in range(5):
        assert bus.remote_committed(r)["t"] == sum(
            float(o) for o in range(5) if o != r
        )


def test_zero_fanout_never_disseminates():
    bus = GossipBus(4, fanout=0, seed=0)
    for r in range(4):
        bus.publish(r, {"t": 1.0}, {}, 0.0)
    for _ in range(10):
        assert bus.tick() == 0
    assert bus.messages_sent == 0
    assert all(bus.remote_committed(r) == {} for r in range(4))


# ---------------------------------------------------------------------------
# facade + spanning placements
# ---------------------------------------------------------------------------


def test_controlplane_facade_dispatches_on_regions():
    rg = waxman(12, seed=1)
    assert isinstance(ControlPlane(rg, **PYM), ControlPlane)
    assert isinstance(ControlPlane(rg, regions=1, **PYM), ControlPlane)
    cp = ControlPlane(rg, regions=3, seed=0, **PYM)
    assert isinstance(cp, RegionalControlPlane)
    assert cp.R == 3


def _regional(n=18, R=2, seed=0, **kw):
    rg = waxman(n, seed=seed)
    cp = RegionalControlPlane(rg, regions=R, seed=seed, **PYM, **kw)
    cp.register_tenant("a", weight=3.0)
    cp.register_tenant("b", weight=1.0)
    return rg, cp


def _spanning_df(cp, creq=0.3, breq=1.0):
    """A p=2 request pinned to the two endpoints of the best cut edge —
    placeable only by decomposition across the cut."""
    (u, v) = max(cp.cut_base, key=cp.cut_base.get)
    return DataflowPath.make([creq, creq], [breq], src=u, dst=v)


def test_spanning_request_places_by_two_phase_commit():
    rg, cp = _regional()
    df = _spanning_df(cp)
    rid = cp.submit("a", df)
    (t,) = cp.pump()
    cp.check_invariants()
    assert t.rid == rid
    (u, v) = t.cut
    assert cp.region_of[u] != cp.region_of[v]
    # one segment reserved in each region, under the right tenant
    part_a, part_b = t.parts
    assert [part_a.region, part_b.region] == [
        int(cp.region_of[u]), int(cp.region_of[v])]
    assert cp.regions[part_a.region].placer.tickets[part_a.tid].tenant == "a"
    # parts record the bijection generation they were minted under
    assert part_a.version == cp.views[part_a.region].version
    # the reserved segments live in the regions' LOCAL id spaces: the
    # gateway pins translate back to the global cut endpoints
    assert cp.views[part_a.region].to_global(part_a.seg.dst) == u
    assert cp.views[part_b.region].to_global(part_b.seg.src) == v
    # the cut reservation left the broker ledger
    assert cp.cut_residual[t.cut] == pytest.approx(cp.cut_base[t.cut] - 1.0)
    assert cp.engine_stats().twopc_messages >= 4  # 2 prepares + 2 commits
    led = cp.conservation()
    assert led["ok"] and led["active"] == 1
    # release returns every reservation
    cp.release(rid)
    cp.check_invariants()
    assert cp.cut_residual[t.cut] == pytest.approx(cp.cut_base[t.cut])
    assert all(not c.placer.tickets for c in cp.regions)
    assert cp.conservation()["released"] == 1


def test_spanning_infeasible_rolls_back_and_eventually_drops():
    rg, cp = _regional(max_attempts=3)
    (u, v) = max(cp.cut_base, key=cp.cut_base.get)
    huge = float(np.sum(rg.cap)) + 1.0  # fits nowhere, ever
    df = DataflowPath.make([0.0, huge, 0.0], [1.0, 1.0], src=u, dst=v)
    cp.submit("a", df)
    for _ in range(3):
        cp.pump()
        cp.check_invariants()
        # nothing was partially committed by the failed 2PC attempts
        assert all(not c.placer.tickets for c in cp.regions)
        assert all(
            cp.cut_residual[e] == pytest.approx(cp.cut_base[e])
            for e in cp.cut_base
        )
    led = cp.conservation()
    assert led["ok"] and led["dropped"] == 1 and led["active"] == 0


def test_dropped_local_requests_do_not_leak_rid_maps():
    """A region dropping a local request must clear the broker's
    global-rid bookkeeping for it (the maps are otherwise append-only)."""
    rg, cp = _regional(max_attempts=1)
    nodes = np.nonzero(cp.region_of == 0)[0]
    huge = float(np.sum(rg.cap)) + 1.0
    df = DataflowPath.make([0.0, huge, 0.0], [1.0, 1.0],
                           src=int(nodes[0]), dst=int(nodes[1]))
    rid = cp.submit("a", df)  # in-region, infeasible forever
    assert rid in cp._local
    cp.pump()
    cp.check_invariants()
    assert cp.conservation()["dropped"] == 1
    assert rid not in cp._local
    assert not cp._grid_of


def test_spanning_survives_regional_defrag():
    rg, cp = _regional()
    rid = cp.submit("a", _spanning_df(cp))
    cp.pump()
    results = cp.defrag()
    cp.check_invariants()  # handle integrity re-checked (tids preserved)
    assert len(results) == cp.R
    cp.release(rid)  # the handle still resolves after re-optimization
    cp.check_invariants()
    assert cp.conservation()["released"] == 1


def test_cut_link_partition_displaces_and_heals():
    rg, cp = _regional()
    rid = cp.submit("a", _spanning_df(cp))
    (t,) = cp.pump()
    alive, requeued = cp.fail_link(*t.cut)  # partition the region pair
    cp.check_invariants()
    assert alive == [] and len(requeued) == 2  # both segments torn down
    led = cp.conservation()
    assert led["active"] == 0 and led["queued"] == 1  # requeued, not dropped
    assert rid not in cp.active_ids()
    cp.restore_link(*t.cut)  # heal
    out = cp.pump()
    cp.check_invariants()
    assert [s.rid for s in out] == [rid]  # same rid readmitted
    assert cp.conservation()["active"] == 1


def test_gateway_node_failure_displaces_spanning_ticket():
    rg, cp = _regional()
    rid = cp.submit("a", _spanning_df(cp))
    (t,) = cp.pump()
    gateway = t.cut[0]
    cp.fail_node(gateway)
    cp.check_invariants()
    assert rid not in cp.active_ids()
    led = cp.conservation()
    assert led["ok"] and led["dropped"] == 0  # displaced to a queue
    cp.restore_node(gateway)
    cp.check_invariants()


def test_spanning_fairness_uses_gossiped_estimates():
    """With instant gossip, a tenant far over its estimated global share is
    not selected for spanning drains before the under-served one."""
    rg, cp = _regional(R=2, fanout=1)
    # saturate tenant b's global holdings via direct in-region admissions
    for r in range(cp.R):
        for tk in range(2):
            nodes = np.nonzero(cp.region_of == r)[0]
            df = DataflowPath.make([0.4], [], src=int(nodes[0]),
                                  dst=int(nodes[0]))
            cp.regions[r].placer.admit(df, tenant="b")
    cp.submit("b", _spanning_df(cp, creq=0.2))
    cp.submit("a", _spanning_df(cp, creq=0.2))
    out = cp.pump()  # gossip spreads b's holdings before the span drain
    # a (weight 3, holding 0 globally) is the most under-served tenant, so
    # the broker drains it first even though b submitted first
    assert out and out[0].tenant == "a"
    cp.check_invariants()


# ---------------------------------------------------------------------------
# property suite: seeded fuzz across R regions
# ---------------------------------------------------------------------------


def _fuzz_plane(cp, rg, seed, steps=60, df_gen=None):
    """Adversarial interleaving of every public operation; every step
    checks placer conservation, the global ledger, cut-bandwidth
    conservation, and spanning-handle integrity.  ``df_gen(rng, step)``
    overrides the submitted workload (e.g. the multi-hop matrix biases it
    toward far-spanning endpoint pairs)."""
    rng = np.random.default_rng(seed)
    failed_nodes: list[int] = []
    failed_cuts: list[tuple[int, int]] = []
    cuts = sorted(cp.cut_base) if hasattr(cp, "cut_base") else []
    for step in range(steps):
        op = rng.choice(
            ["submit", "pump", "release", "fail_node", "restore_node",
             "partition", "heal", "defrag"],
            p=[0.30, 0.25, 0.13, 0.08, 0.08, 0.05, 0.05, 0.06],
        )
        if op == "submit":
            if df_gen is not None:
                df = df_gen(rng, step)
            else:
                df = random_dataflow(rg, 4, seed=1000 * seed + step,
                                     creq_range=(0.05, 0.3),
                                     breq_range=(0.5, 3.0))
            cp.submit(str(rng.choice(["a", "b", "c"])), df,
                      klass=int(rng.integers(0, 3)))
        elif op == "pump":
            cp.pump(rounds=int(rng.integers(1, 3)))
        elif op == "release":
            ids = cp.active_ids()
            if ids:
                cp.release(int(rng.choice(ids)))
        elif op == "fail_node" and len(failed_nodes) < 3:
            v = int(rng.integers(0, rg.n))
            if v not in failed_nodes:
                cp.fail_node(v)
                failed_nodes.append(v)
        elif op == "restore_node" and failed_nodes:
            cp.restore_node(failed_nodes.pop(
                int(rng.integers(0, len(failed_nodes)))))
        elif op == "partition" and cuts and len(failed_cuts) < 2:
            e = cuts[int(rng.integers(0, len(cuts)))]
            if e not in failed_cuts:
                cp.fail_link(*e)
                failed_cuts.append(e)
        elif op == "heal" and failed_cuts:
            cp.restore_link(*failed_cuts.pop(
                int(rng.integers(0, len(failed_cuts)))))
        elif op == "defrag":
            for res in cp.defrag():
                assert res.objective_after >= res.objective_before
        cp.check_invariants()
    # mid-stream the ledger already accounts for in-flight batches; drain
    # the pipeline so the end-state equality below is exact
    cp.flush()
    cp.check_invariants()
    led = cp.conservation()
    assert led["ok"] and led["in_flight"] == 0
    assert led["submitted"] == (
        led["queued"] + led["active"] + led["released"] + led["dropped"]
    )
    return led


def _fresh_regional(R, seed, fanout=2, gossip_period=1, **kw):
    rg = waxman(14, seed=4)
    cp = RegionalControlPlane(
        rg, regions=R, micro_batch=6, max_attempts=3, seed=seed,
        fanout=fanout, gossip_period=gossip_period,
        policy=FairSharePolicy(slack=0.4), **PYM, **kw,
    )
    cp.register_tenant("a", weight=3.0)
    cp.register_tenant("b", weight=1.0)
    cp.register_tenant("c", weight=2.0, budget=1.5)
    return rg, cp


@pytest.mark.parametrize("R", [1, 2, 4])
@pytest.mark.parametrize("seed", [0, 1])
def test_fuzz_regional_conservation(R, seed):
    rg, cp = _fresh_regional(R, seed)
    led = _fuzz_plane(cp, rg, seed)
    assert led["submitted"] > 0


@pytest.mark.parametrize("seed", [0, 1])
def test_fuzz_regional_conservation_pipelined(seed):
    """Depth-2 admission windows in every region: optimistic local batches
    outstanding across pumps, spanning 2PC interleaved, same invariants."""
    rg, cp = _fresh_regional(2, seed, pipeline_depth=2)
    led = _fuzz_plane(cp, rg, seed)
    assert led["submitted"] > 0


def test_spanning_2pc_tolerates_in_flight_batch():
    """The broker's 2PC reserves host-side (bumping the residual *version*,
    not the staleness *epoch*), so a spanning admission while a local batch
    is optimistically in flight just makes that batch's commit re-validate —
    nothing deadlocks, nothing overcommits."""
    rg, cp = _regional(pipeline_depth=2)
    intra = [(u, v) for (u, v) in rg.edges()
             if cp.region_of[u] == cp.region_of[v]]
    assert intra, "no intra-region edge; instance too partitioned"
    u, v = intra[0]
    cp.submit("a", DataflowPath.make([0.1, 0.1], [0.5], src=u, dst=v))
    assert cp.pump() == []  # parked in the region's depth-2 window
    assert cp.conservation()["in_flight"] == 1

    rid = cp.submit("a", _spanning_df(cp))
    (t,) = cp.pump()  # 2PC completes around the outstanding batch
    assert t.rid == rid
    assert cp.conservation()["in_flight"] == 1  # local batch still parked
    cp.check_invariants()

    cp.flush()
    led = cp.conservation()
    assert led["ok"] and led["in_flight"] == 0 and led["active"] >= 1
    cp.check_invariants()


@pytest.mark.slow
@pytest.mark.parametrize("R", [2, 4])
@pytest.mark.parametrize("seed", [2, 3, 4, 5])
def test_fuzz_regional_conservation_extended(R, seed):
    """Slow-lane matrix: more seeds, longer interleavings, staler gossip."""
    rg, cp = _fresh_regional(R, seed, fanout=1, gossip_period=3)
    _fuzz_plane(cp, rg, seed, steps=140)


@pytest.mark.parametrize("seed", [0, 1])
def test_r1_regional_bit_identical_to_centralized(seed):
    """The degenerate single-region plane replays the exact centralized
    behavior: same rids, same tickets, same residual arrays bit for bit,
    same ledger — step by step under a fuzzed op sequence."""
    rg = waxman(14, seed=5)
    kw = dict(micro_batch=6, max_attempts=3,
              policy=FairSharePolicy(slack=0.4), **PYM)
    cen = ControlPlane(rg, **kw)
    reg = RegionalControlPlane(rg, regions=1, seed=seed, **kw)
    for cp in (cen, reg):
        cp.register_tenant("a", weight=3.0)
        cp.register_tenant("b", weight=1.0)
    rng = np.random.default_rng(seed)
    failed: list[int] = []
    for step in range(60):
        op = rng.choice(
            ["submit", "pump", "release", "fail", "restore", "defrag"],
            p=[0.35, 0.28, 0.15, 0.08, 0.07, 0.07],
        )
        if op == "submit":
            df = random_dataflow(rg, 4, seed=2000 * seed + step,
                                 creq_range=(0.05, 0.3),
                                 breq_range=(0.5, 3.0))
            t = str(rng.choice(["a", "b"]))
            k = int(rng.integers(0, 3))
            assert cen.submit(t, df, klass=k) == reg.submit(t, df, klass=k)
        elif op == "pump":
            r = int(rng.integers(1, 3))
            assert (
                [t.tid for t in cen.pump(rounds=r)]
                == [t.tid for t in reg.pump(rounds=r)]
            )
        elif op == "release":
            ids = cen.active_ids()
            assert ids == reg.active_ids()
            if ids:
                rid = int(rng.choice(ids))
                cen.release(rid)
                reg.release(rid)
        elif op == "fail" and len(failed) < 3:
            v = int(rng.integers(0, rg.n))
            if v not in failed:
                a1, q1 = cen.fail_node(v)
                a2, q2 = reg.fail_node(v)
                assert [t.tid for t in a1] == [t.tid for t in a2]
                assert [t.tid for t in q1] == [t.tid for t in q2]
                failed.append(v)
        elif op == "restore" and failed:
            v = failed.pop(int(rng.integers(0, len(failed))))
            cen.restore_node(v)
            reg.restore_node(v)
        elif op == "defrag":
            rc = cen.defrag()
            (rr,) = reg.defrag()
            assert (rc.committed, rc.repacked, rc.moved) == (
                rr.committed, rr.repacked, rr.moved)
        # -- bit-for-bit state equality, every step
        inner = reg.regions[0]
        np.testing.assert_array_equal(cen.placer.cap, inner.placer.cap)
        np.testing.assert_array_equal(cen.placer.bw, inner.placer.bw)
        assert sorted(cen.placer.tickets) == sorted(inner.placer.tickets)
        for tid, tk in cen.placer.tickets.items():
            assert tk.mapping == inner.placer.tickets[tid].mapping
        assert cen.conservation() == reg.conservation()
        cen.check_invariants()
        reg.check_invariants()
    # the regional facade spent zero coordination messages at R = 1
    s = reg.engine_stats()
    assert s.gossip_messages == 0 and s.twopc_messages == 0


def test_maximally_stale_gossip_never_overcommits_a_region():
    """fanout=0: estimates never propagate (staleness grows without
    bound), and tenant load is deliberately skewed — yet no admission may
    ever exceed any region's own residual: over-commit safety must come
    from local validation, not from estimate freshness."""
    rg = waxman(16, seed=9)
    cp = RegionalControlPlane(
        rg, regions=4, fanout=0, micro_batch=8, max_attempts=2, seed=0,
        **PYM,
    )
    cp.register_tenant("a", weight=3.0)
    cp.register_tenant("b", weight=1.0)
    rng = np.random.default_rng(0)
    for step in range(25):
        for _ in range(4):  # heavy skew: a floods, b trickles
            df = random_dataflow(rg, 4, seed=7000 + step * 7,
                                 creq_range=(0.1, 0.5),
                                 breq_range=(0.5, 3.0))
            cp.submit("a", df)
        if step % 3 == 0:
            df = random_dataflow(rg, 4, seed=8000 + step,
                                 creq_range=(0.1, 0.5),
                                 breq_range=(0.5, 3.0))
            cp.submit("b", df)
        cp.pump()
        ids = cp.active_ids()
        if ids and step % 2:
            cp.release(int(rng.choice(ids)))
        # the property: per-region committed never exceeds the region's
        # base capacity, residuals never go negative, anywhere, ever
        for r, rcp in enumerate(cp.regions):
            assert np.all(rcp.placer.cap >= -1e-6)
            assert np.all(rcp.placer.bw >= -1e-6)
            held = sum(
                float(np.sum(t.df.creq))
                for t in rcp.placer.tickets.values()
            )
            assert held <= float(np.sum(rcp.placer.base.cap)) + 1e-6
        cp.check_invariants()
    assert cp.engine_stats().gossip_messages == 0  # it really was stale
    assert cp.bus.max_staleness() >= 20  # versions kept advancing unseen


# ---------------------------------------------------------------------------
# multi-hop spanning decomposition
# ---------------------------------------------------------------------------


def _line_plane(R, k=4, seed=0, **kw):
    from repro.core import region_line

    rg, assign = region_line(R, k, seed=seed)
    cp = RegionalControlPlane(rg, regions=R, region_of=assign, seed=seed,
                              **PYM, **kw)
    cp.register_tenant("a", weight=3.0)
    cp.register_tenant("b", weight=1.0)
    return rg, cp


def test_multi_hop_chain_admission_and_release():
    """A dataflow pinned from region 0 to region 3 of a 4-region line —
    previously retry/drop — is admitted over the full region chain by one
    bounded 2PC, and release returns every reservation on every hop."""
    rg, cp = _line_plane(4)
    df = DataflowPath.make([0.0, 0.2, 0.2, 0.2, 0.0], [1.0] * 4,
                           src=0, dst=rg.n - 1)
    rid = cp.submit("a", df)
    (t,) = cp.pump()
    cp.check_invariants()
    assert t.chain == [0, 1, 2, 3]
    assert len(t.parts) == 4 and len(t.cuts) == 3
    assert cp.span_stats["multi_hop"] == 1
    assert cp.span_stats["max_chain"] == 4
    for e, b in zip(t.cuts, t.cut_bws):
        assert cp.cut_residual[e] == pytest.approx(cp.cut_base[e] - b)
    # the documented per-candidate message bound: <= 2 * chain + 2
    s = cp.engine_stats()
    assert s.twopc_messages <= (
        cp.span_stats["attempts"] * cp.max_cut_attempts * (2 * 4 + 2))
    cp.release(rid)
    cp.check_invariants()
    assert all(cp.cut_residual[e] == pytest.approx(cp.cut_base[e])
               for e in cp.cut_base)
    assert all(not c.placer.tickets for c in cp.regions)
    assert cp.conservation()["released"] == 1


def test_non_adjacent_regions_admitted_via_transit():
    """p=2 between regions 0 and 2 of a 3-region line: the middle region
    hosts no dataflow node — its segment is a pure transit reservation
    (ghost gateway endpoints carrying the one cut dataflow edge)."""
    rg, cp = _line_plane(3)
    df = DataflowPath.make([0.1, 0.1], [1.0], src=0, dst=rg.n - 1)
    rid = cp.submit("a", df)
    (t,) = cp.pump()
    cp.check_invariants()
    assert t.chain == [0, 1, 2] and t.splits == [0, 0]
    mid = t.parts[1]
    assert float(np.sum(mid.seg.creq)) == 0.0  # no compute in transit
    # but the transit route's bandwidth IS reserved in the middle region
    tk = cp.regions[1].placer.tickets[mid.tid]
    assert tk.edge_load and all(
        b == pytest.approx(1.0) for b in tk.edge_load.values())
    cp.release(rid)
    cp.check_invariants()
    assert cp.conservation()["released"] == 1


def test_multi_hop_middle_cut_failure_displaces_and_heals():
    rg, cp = _line_plane(4)
    df = DataflowPath.make([0.0, 0.2, 0.2, 0.2, 0.0], [1.0] * 4,
                           src=0, dst=rg.n - 1)
    rid = cp.submit("a", df)
    (t,) = cp.pump()
    middle_cut = t.cuts[1]
    alive, requeued = cp.fail_link(*middle_cut)
    cp.check_invariants()
    assert alive == [] and len(requeued) == 4  # every segment torn down
    led = cp.conservation()
    assert led["active"] == 0 and led["queued"] == 1  # displaced, not dropped
    # while the quotient graph is partitioned, the request keeps waiting
    cp.pump()
    assert cp.conservation()["active"] == 0
    cp.restore_link(*middle_cut)
    out = cp.pump()
    cp.check_invariants()
    assert [s.rid for s in out] == [rid]  # same rid readmitted post-heal
    assert cp.conservation()["active"] == 1


def test_multi_hop_transit_gateway_failure_displaces():
    rg, cp = _line_plane(3)
    df = DataflowPath.make([0.1, 0.1], [1.0], src=0, dst=rg.n - 1)
    rid = cp.submit("a", df)
    (t,) = cp.pump()
    gateway = t.cuts[0][1]  # inbound gateway of the transit region
    cp.fail_node(gateway)
    cp.check_invariants()
    assert rid not in cp.active_ids()
    led = cp.conservation()
    assert led["ok"] and led["dropped"] == 0 and led["queued"] == 1
    assert all(cp.cut_residual[e] == pytest.approx(cp.cut_base[e])
               for e in cp.cut_base)


# ---------------------------------------------------------------------------
# partial-teardown regressions (release / fail on half-dead spans)
# ---------------------------------------------------------------------------


def test_region_dropping_segment_tears_down_whole_span():
    """Regression: churn driven through the INNER region plane (bypassing
    the broker's own displacement pass) drops a spanning segment the
    local plane has no rid for.  The broker must still learn of it
    (on_foreign_preempt hand-off) and tear down the sibling reservations
    + cut bandwidth instead of leaking them."""
    rg, cp = _line_plane(2)
    (u, v) = max(cp.cut_base, key=cp.cut_base.get)
    rid = cp.submit("a", DataflowPath.make([0.1, 0.1], [1.0], u, v))
    (t,) = cp.pump()
    part = t.parts[0]
    inner = cp.regions[part.region]
    sibling = t.parts[1]
    # kill the segment's pinned local gateway through the inner plane:
    # the re-map cannot re-place a pinned-down endpoint, so the inner
    # plane DROPS a ticket it holds no rid for — the regression path
    inner.fail_node(int(inner.placer.tickets[part.tid].df.dst))
    assert rid not in cp._span_active  # broker reconciled the drop
    assert sibling.tid not in cp.regions[sibling.region].placer.tickets
    assert all(cp.cut_residual[e] == pytest.approx(cp.cut_base[e])
               for e in cp.cut_base)
    led = cp.conservation()
    assert led["ok"] and led["queued"] == 1 and led["dropped"] == 0
    cp.check_invariants()


def test_release_tolerates_already_dropped_sibling():
    """Regression: ``release`` on a spanning ticket one of whose parts
    already vanished must still release every other part and the cut
    bandwidth (guarded teardown), not raise mid-way and leak."""
    rg, cp = _line_plane(2)
    (u, v) = max(cp.cut_base, key=cp.cut_base.get)
    rid = cp.submit("a", DataflowPath.make([0.1, 0.1], [1.0], u, v))
    (t,) = cp.pump()
    # simulate a region having lost its local ticket without telling the
    # broker (the pre-fix partial-teardown hazard)
    part = t.parts[0]
    cp.regions[part.region].placer.release(part.tid, reason=None)
    cp.release(rid)  # must not raise
    sibling = t.parts[1]
    assert sibling.tid not in cp.regions[sibling.region].placer.tickets
    assert all(cp.cut_residual[e] == pytest.approx(cp.cut_base[e])
               for e in cp.cut_base)
    assert not cp._span_active and not cp._part_of


def test_displace_span_part_is_idempotent():
    rg, cp = _line_plane(2)
    (u, v) = max(cp.cut_base, key=cp.cut_base.get)
    cp.submit("a", DataflowPath.make([0.1, 0.1], [1.0], u, v))
    (t,) = cp.pump()
    part = t.parts[0]
    tk = cp.regions[part.region].placer.tickets[part.tid]
    cp.regions[part.region].placer.release(part.tid, reason=None)
    cp._displace_span_part(part.region, tk)
    led1 = cp.conservation()
    cp._displace_span_part(part.region, tk)  # double teardown: no-op
    assert cp.conservation() == led1
    assert led1["queued"] == 1
    cp.check_invariants()


# ---------------------------------------------------------------------------
# multi-hop fuzz matrix
# ---------------------------------------------------------------------------


def _multi_hop_plane(R, seed, fanout=1, k=3):
    from repro.core import region_line

    rg, assign = region_line(R, k, seed=seed)
    cp = RegionalControlPlane(
        rg, regions=R, region_of=assign, micro_batch=6, max_attempts=3,
        seed=seed, fanout=fanout, policy=FairSharePolicy(slack=0.4), **PYM,
    )
    cp.register_tenant("a", weight=3.0)
    cp.register_tenant("b", weight=1.0)
    cp.register_tenant("c", weight=2.0, budget=2.0)

    def df_gen(rng, step):
        # bias toward far-spanning endpoint pairs: half the requests pin
        # src in region 0 and dst in the last region (chain length R)
        if rng.random() < 0.5:
            r1, r2 = 0, R - 1
        else:
            r1, r2 = rng.choice(R, size=2, replace=False)
        src = int(rng.choice(np.nonzero(assign == r1)[0]))
        dst = int(rng.choice(np.nonzero(assign == r2)[0]))
        p = int(rng.integers(2, 6))
        creq = rng.uniform(0.02, 0.15, p).astype(np.float32)
        creq[0] = creq[-1] = 0.0
        breq = rng.uniform(0.5, 2.0, p - 1).astype(np.float32)
        return DataflowPath(creq, breq, src, dst)

    return rg, cp, df_gen


@pytest.mark.parametrize("R", [4, 6])
def test_fuzz_multi_hop_conservation(R):
    """Far-spanning workload on an R-region line: the global ledger, cut
    conservation and spanning-handle integrity hold through adversarial
    interleavings, and chains of >= 3 regions are genuinely exercised."""
    rg, cp, df_gen = _multi_hop_plane(R, seed=R)
    led = _fuzz_plane(cp, rg, seed=R, steps=50, df_gen=df_gen)
    assert led["submitted"] > 0
    assert cp.span_stats["max_chain"] >= 3
    assert cp.span_stats["multi_hop"] >= 1


def test_fuzz_multi_hop_stale_gossip_never_overcommits():
    """fanout=0 (estimates never propagate) on a 4-region line with a
    far-spanning workload: multi-hop 2PC admissions must still never
    exceed any region's own residual — over-commit safety is local
    validation, not estimate freshness, even across chains."""
    rg, cp, df_gen = _multi_hop_plane(4, seed=11, fanout=0)
    _fuzz_plane(cp, rg, seed=11, steps=50, df_gen=df_gen)
    for rcp in cp.regions:
        assert np.all(rcp.placer.cap >= -1e-6)
        assert np.all(rcp.placer.bw >= -1e-6)
        held = sum(float(np.sum(t.df.creq))
                   for t in rcp.placer.tickets.values())
        assert held <= float(np.sum(rcp.placer.base.cap)) + 1e-6
    assert cp.engine_stats().gossip_messages == 0
    assert cp.span_stats["admitted"] > 0  # spans did flow despite staleness


@pytest.mark.slow
@pytest.mark.parametrize("R", [4, 6])
@pytest.mark.parametrize("seed", [2, 3, 4])
def test_fuzz_multi_hop_conservation_extended(R, seed):
    """Slow-lane matrix: more seeds, longer interleavings, staler gossip,
    bigger regions."""
    rg, cp, df_gen = _multi_hop_plane(R, seed=seed, fanout=1, k=4)
    cp.gossip_period = 3
    _fuzz_plane(cp, rg, seed=seed, steps=120, df_gen=df_gen)
    assert cp.span_stats["max_chain"] >= 3


# ---------------------------------------------------------------------------
# accounting / handle-resolution regressions (review findings)
# ---------------------------------------------------------------------------


def test_failed_spanning_probes_are_not_service_rejections():
    """2PC reserve probes that nack must not inflate the regional
    placers' rejected counters (same convention as admit_preempting's
    probes): the spanning outcome is accounted once, by the broker."""
    rg, cp = _regional(max_attempts=3)
    (u, v) = max(cp.cut_base, key=cp.cut_base.get)
    huge = float(np.sum(rg.cap)) + 1.0  # fits nowhere, ever
    cp.submit("a", DataflowPath.make([0.0, huge, 0.0], [1.0, 1.0], u, v))
    for _ in range(3):
        cp.pump()
    assert cp.span_stats["attempts"] >= 3  # probes really ran
    assert all(c.placer.stats.rejected == 0 for c in cp.regions)
    cp.check_invariants()


def test_owner_region_resolves_local_ticket_handles():
    """In-region handles returned by pump() live in their region's local
    id space; owner_region identifies the owner so the route lifts back
    to global ids through the right view."""
    rg, cp = _regional()
    nodes = np.nonzero(cp.region_of == 1)[0]
    df = DataflowPath.make([0.0, 0.2, 0.0], [1.0, 1.0],
                           int(nodes[0]), int(nodes[-1]))
    cp.submit("a", df)
    (t,) = cp.pump()
    r = cp.owner_region(t)
    assert r == 1
    route_global = [int(cp.views[r].to_global(v)) for v in t.mapping.route]
    assert route_global[0] == df.src and route_global[-1] == df.dst
    assert all(cp.region_of[v] == 1 for v in route_global)
    # a released handle resolves to no region
    cp.release(cp.active_ids()[0])
    assert cp.owner_region(t) is None


def test_facade_dispatches_on_region_of_alone():
    """ControlPlane(rg, region_of=...) must build the regional plane (the
    assignment defines the region count) — not silently ignore the
    partition and leak region_of into the solver config; a contradicting
    explicit regions= fails fast."""
    from repro.core import region_line

    rg, assign = region_line(3, 4, seed=1)
    cp = ControlPlane(rg, region_of=assign, **PYM)
    assert isinstance(cp, RegionalControlPlane) and cp.R == 3
    cp.register_tenant("a")
    cp.submit("a", DataflowPath.make([0.0, 0.1], [1.0], 0, 1))
    cp.pump()  # solver must never see region_of
    cp.check_invariants()
    assert isinstance(
        ControlPlane(rg, regions=3, region_of=assign, **PYM),
        RegionalControlPlane)
    with pytest.raises(ValueError, match="contradicts"):
        ControlPlane(rg, regions=2, region_of=assign, **PYM)


def test_candidate_search_bounded_for_long_dataflows():
    """A long dataflow over a long chain must not enumerate the full
    split-combination space: candidate generation stays fast and still
    yields admissible balanced candidates."""
    import time

    from repro.core import region_line

    rg, assign = region_line(6, 4, seed=2)
    cp = RegionalControlPlane(rg, regions=6, region_of=assign, seed=0, **PYM)
    cp.register_tenant("a")
    p = 120  # C(p+m-2, m) would be ~2e8 at m=5 without the windowing
    creq = np.full(p, 0.01, np.float32)
    creq[0] = creq[-1] = 0.0
    df = DataflowPath(creq, np.full(p - 1, 0.5, np.float32), 0, rg.n - 1)
    chain = cp._region_chain(0, 5)
    t0 = time.perf_counter()
    cands = cp._candidate_chains(df, chain)
    assert time.perf_counter() - t0 < 2.0  # bounded enumeration
    assert cands  # and still productive
    rid = cp.submit("a", df)
    (t,) = cp.pump()
    assert t.rid == rid and len(t.chain) == 6
    cp.check_invariants()


# ---------------------------------------------------------------------------
# congestion gossip (per-cut gateway occupancy estimates)
# ---------------------------------------------------------------------------


def _grid_plane(rows=2, cols=3, k=3, seed=0, **kw):
    from repro.core import region_grid

    rg, assign = region_grid(rows, cols, k, seed=seed)
    cp = RegionalControlPlane(rg, regions=rows * cols, region_of=assign,
                              seed=seed, **PYM, **kw)
    cp.register_tenant("a", weight=3.0)
    cp.register_tenant("b", weight=1.0)
    cp.register_tenant("c", weight=2.0, budget=2.0)
    return rg, assign, cp


def _saturate_cut(cp, r1, r2, leave=0.2):
    """Stand a spanning reservation on the single (r1, r2) cut, leaving
    only ``leave`` residual bandwidth on it."""
    (e,) = cp._cut_by_pair[(r1, r2)]
    u, v = e
    b = cp.cut_residual[e] - leave
    rid = cp.submit("b", DataflowPath.make([0.01, 0.01], [b], src=u, dst=v))
    out = cp.pump()
    assert any(getattr(t, "rid", None) == rid for t in out)
    assert cp.cut_residual[e] == pytest.approx(leave, abs=1e-3)
    return e


def _saturate_region_compute(cp, r, frac=0.95):
    """Fill region ``r``'s nodes to ``frac`` occupancy via direct local
    admissions (bypassing the queues, like the fairness test does)."""
    rcp = cp.regions[r]
    for lv in range(rcp.placer.base.cap.shape[0]):
        take = float(rcp.placer.cap[lv]) - (1.0 - frac) * float(
            rcp.placer.base.cap[lv])
        if take > 0:
            df = DataflowPath.make([take], [], src=lv, dst=lv)
            assert rcp.placer.admit(df, tenant="b") is not None


def test_gossip_carries_congestion_estimates():
    bus = GossipBus(3, fanout=2, seed=0)  # fanout 2 of 2 peers: full push
    rec = bus.publish(0, {}, {}, 1.0, congestion={7: 0.5, 9: 0.25})
    assert rec.congestion == {7: 0.5, 9: 0.25}
    # the wire-size accounting includes the congestion entries
    assert GossipBus._record_size(rec) == 3 + 2
    bus.tick()
    for r in range(3):
        assert bus.congestion_view(r)[7] == 0.5
    # the freshest record per origin wins (no merge across versions)
    bus.publish(0, {}, {}, 1.0, congestion={7: 0.9})
    bus.tick()
    for r in range(3):
        view = bus.congestion_view(r)
        assert view[7] == 0.9 and 9 not in view
    # on key overlap across origins the pessimistic max wins
    bus.publish(1, {}, {}, 1.0, congestion={7: 0.1})
    bus.tick()
    assert bus.congestion_view(2)[7] == 0.9


def test_congestion_view_reflects_remote_gateway_heat():
    """A saturated region's gateway occupancy reaches every other
    region's congestion view through the existing share gossip — and the
    load-aware edge cost prices its cuts up accordingly."""
    rg, assign, cp = _grid_plane(fanout=5)  # full-fanout: 1-round spread
    _saturate_region_compute(cp, 1)
    cp.pump()  # publish + tick
    occ = cp.bus.congestion_view(0)
    hot = cp._gateways_of[1]
    assert hot and all(occ.get(u, 0.0) > 0.5 for u in hot)
    (e,) = cp._cut_by_pair[(0, 1)]
    assert cp._edge_cost(e, occ) > float(rg.lat[e]) * 1.5
    cp.check_invariants()


def test_zero_fanout_keeps_congestion_estimates_local():
    rg, assign, cp = _grid_plane(fanout=0)
    _saturate_region_compute(cp, 1)
    cp.pump()
    occ = cp.bus.congestion_view(0)
    assert occ and all(int(assign[u]) == 0 for u in occ)  # own gateways only


# ---------------------------------------------------------------------------
# congestion-aware k-shortest chain routing
# ---------------------------------------------------------------------------


def test_region_grid_generator_shape():
    from repro.core import region_grid

    rows, cols, k = 2, 3, 3
    rg, assign = region_grid(rows, cols, k, seed=0)
    R = rows * cols
    assert rg.n == R * k
    np.testing.assert_array_equal(assign, np.repeat(np.arange(R), k))
    # fully meshed inside every region
    for r in range(R):
        base = r * k
        for i in range(k):
            for j in range(i + 1, k):
                assert rg.bw[base + i, base + j] > 0
    # the quotient graph is the grid: east + south neighbors only
    pairs = {
        (int(assign[u]), int(assign[v]))
        for (u, v) in rg.edges() if assign[u] != assign[v]
    }
    expect = set()
    for i in range(rows):
        for j in range(cols):
            r = i * cols + j
            if j + 1 < cols:
                expect |= {(r, r + 1), (r + 1, r)}
            if i + 1 < rows:
                expect |= {(r, r + cols), (r + cols, r)}
    assert pairs == expect


def test_yen_chains_distinct_loopless_cheapest_first():
    rg, assign, cp = _grid_plane(chain_k=4)
    chains = cp._region_chains(0, 5, {})
    assert chains[0] == cp._region_chain(0, 5)  # cold: fewest-hop first
    assert len(chains) == len({tuple(c) for c in chains}) >= 2
    for c in chains:
        assert c[0] == 0 and c[-1] == 5 and len(set(c)) == len(c)
        for r1, r2 in zip(c, c[1:]):  # every hop really is adjacent
            assert cp._cut_by_pair.get((r1, r2))
    # chain costs are non-decreasing in rank
    adj = cp._cost_adjacency({})
    costs = [sum(adj[a][b] for a, b in zip(c, c[1:])) for c in chains]
    assert costs == sorted(costs)


def test_congestion_reranks_chains_before_any_probe():
    """Hot gossiped gateways re-rank the fewest-hop chain behind a cold
    bypass purely in the cost model — before any 2PC probe spends budget.
    congestion_weight=0 restores pure-latency ranking."""
    rg, assign, cp = _grid_plane(chain_k=2)
    hot = {u: 1.0
           for e in cp._cut_by_pair[(0, 1)] + cp._cut_by_pair[(1, 2)]
           for u in e}
    chains = cp._region_chains(0, 2, hot)
    assert chains[0] == [0, 3, 4, 5, 2]  # cold bypass ranks first
    assert [0, 1, 2] in chains           # hot fewest-hop still raced
    assert cp._region_chains(0, 2, {})[0] == [0, 1, 2]  # cold: fewest-hop
    cp.congestion_weight = 0.0           # weight 0: occupancy is ignored
    assert cp._region_chains(0, 2, hot)[0] == [0, 1, 2]


def test_gateway_hotspot_k_chain_admits_where_single_chain_collapses():
    """The tentpole regression: stand a reservation on the (0, 1) cut so
    the fewest-hop chain 0-1-2 has no feasible candidate.  The legacy
    single-chain broker burns every attempt on that chain and drops the
    request; the k-chain racer probes the cold bypass 0-3-4-5-2 inside
    the same 2PC budget and admits."""
    results = []
    for chain_k in (1, 2):
        rg, assign, cp = _grid_plane(chain_k=chain_k, max_attempts=3)
        hot = _saturate_cut(cp, 0, 1, leave=0.2)
        dst = int(np.nonzero(assign == 2)[0][-1])
        df = DataflowPath.make([0.0, 0.2, 0.2, 0.0], [1.0] * 3,
                               src=0, dst=dst)
        rid = cp.submit("a", df)
        out = [t for _ in range(3) for t in cp.pump()]
        cp.check_invariants()
        results.append((cp, rid, out, hot))
    cp1, rid1, out1, _ = results[0]
    assert out1 == []  # single-chain: never admitted, dropped
    assert cp1.conservation()["dropped"] == 1
    assert cp1.span_stats["no_cut"] >= 3
    cp2, rid2, out2, hot = results[1]
    (t,) = out2
    # admitted over a >2-hop bypass that avoids the saturated cut
    assert t.rid == rid2
    assert t.chain[0] == 0 and t.chain[-1] == 2 and len(t.chain) == 5
    assert t.chain != [0, 1, 2] and hot not in t.cuts
    assert cp2.span_stats["rerouted"] == 1
    assert cp2.span_stats["multi_hop"] >= 1
    assert cp2.span_stats["max_chain"] == 5
    led = cp2.conservation()
    assert led["ok"] and led["dropped"] == 0
    # racing stayed inside the documented per-candidate message bound
    assert cp2.engine_stats().twopc_messages <= (
        cp2.span_stats["attempts"] * cp2.max_cut_attempts * (2 * 5 + 2))


def test_stale_congestion_misroutes_but_never_overcommits():
    """fanout=0: occupancy estimates never propagate, so the router
    prices remote hot gateways as cold and may well rank the hot chain
    first (a misroute).  The property: ranking is ONLY advisory — every
    admission still 2PC-validates against real residuals, so nothing
    over-commits no matter how wrong the view is."""
    rg, assign, cp = _grid_plane(fanout=0, chain_k=3, max_attempts=3,
                                 micro_batch=6)
    _saturate_region_compute(cp, 1)
    rng = np.random.default_rng(3)
    for step in range(20):
        src = int(rng.choice(np.nonzero(assign == 0)[0]))
        dst = int(rng.choice(np.nonzero(assign == 2)[0]))
        cp.submit("a", DataflowPath.make(
            [0.0, 0.3, 0.3, 0.0], [1.0] * 3, src=src, dst=dst))
        cp.pump()
        # the home region's view holds no region-1 estimates to warn it
        assert all(int(assign[u]) == 0 for u in cp.bus.congestion_view(0))
        for rcp in cp.regions:
            assert np.all(rcp.placer.cap >= -1e-6)
            assert np.all(rcp.placer.bw >= -1e-6)
        assert all(-1e-6 <= cp.cut_residual[e] <= cp.cut_base[e] + 1e-6
                   for e in cp.cut_base)
        cp.check_invariants()
    assert cp.span_stats["admitted"] >= 1  # spans still flowed
    assert cp.bus.max_staleness() >= 10    # and the view really was stale


@pytest.mark.parametrize("seed", [0, 1])
def test_chain_racer_bit_identical_on_unique_chain_topology(seed):
    """Acceptance gate: on a region line (ONE loopless chain per pair,
    one gate per hop) the k-chain racer must collapse to the legacy
    single-chain broker bit for bit — same admissions, same residuals,
    same cut ledger, same stats, step by step under fuzzed ops."""
    from repro.core import region_line

    rg, assign = region_line(4, 3, seed=seed)
    kw = dict(regions=4, region_of=assign, micro_batch=6, max_attempts=3,
              seed=seed, fanout=1, **PYM)
    legacy = RegionalControlPlane(rg, chain_k=1, **kw)
    racer = RegionalControlPlane(rg, chain_k=3, **kw)
    for cp in (legacy, racer):
        cp.register_tenant("a", weight=3.0)
        cp.register_tenant("b", weight=1.0)
    cuts = sorted(legacy.cut_base)
    rng = np.random.default_rng(seed)
    failed: list[tuple[int, int]] = []
    for step in range(50):
        op = rng.choice(
            ["submit", "pump", "release", "partition", "heal"],
            p=[0.35, 0.30, 0.15, 0.10, 0.10],
        )
        if op == "submit":
            r1, r2 = rng.choice(4, size=2, replace=False)
            src = int(rng.choice(np.nonzero(assign == r1)[0]))
            dst = int(rng.choice(np.nonzero(assign == r2)[0]))
            p = int(rng.integers(2, 6))
            creq = rng.uniform(0.02, 0.15, p).astype(np.float32)
            creq[0] = creq[-1] = 0.0
            breq = rng.uniform(0.5, 2.0, p - 1).astype(np.float32)
            df = DataflowPath(creq, breq, src, dst)
            t = str(rng.choice(["a", "b"]))
            assert legacy.submit(t, df) == racer.submit(t, df)
        elif op == "pump":
            assert ([getattr(t, "rid", None) for t in legacy.pump()]
                    == [getattr(t, "rid", None) for t in racer.pump()])
        elif op == "release":
            ids = legacy.active_ids()
            assert ids == racer.active_ids()
            if ids:
                rid = int(rng.choice(ids))
                legacy.release(rid)
                racer.release(rid)
        elif op == "partition" and len(failed) < 2:
            e = cuts[int(rng.integers(0, len(cuts)))]
            if e not in failed:
                legacy.fail_link(*e)
                racer.fail_link(*e)
                failed.append(e)
        elif op == "heal" and failed:
            e = failed.pop(int(rng.integers(0, len(failed))))
            legacy.restore_link(*e)
            racer.restore_link(*e)
        assert legacy.cut_residual == racer.cut_residual
        for c1, c2 in zip(legacy.regions, racer.regions):
            np.testing.assert_array_equal(c1.placer.cap, c2.placer.cap)
            np.testing.assert_array_equal(c1.placer.bw, c2.placer.bw)
        assert legacy.conservation() == racer.conservation()
        assert legacy.span_stats == racer.span_stats
        legacy.check_invariants()
        racer.check_invariants()
    assert legacy.span_stats["admitted"] > 0  # the fuzz exercised spans
    assert racer.span_stats["rerouted"] == 0  # nothing to reroute to


def test_displacement_livelock_budget_eventually_drops():
    """Regression: a spanning request ping-ponging between admission and
    displacement used to reset its attempt budget on every displacement —
    livelocking forever.  The cumulative budget (max_cum_attempts) now
    drops it, visibly, after bounded work."""
    rg, cp = _line_plane(2, max_cum_attempts=3)
    (u, v) = max(cp.cut_base, key=cp.cut_base.get)
    rid = cp.submit("a", DataflowPath.make([0.1, 0.1], [1.0], u, v))
    (t,) = cp.pump()
    for i in range(3):
        assert cp.fail_link(*t.cut)[1]  # displaced every time
        cp.restore_link(*t.cut)
        out = cp.pump()
        if i < 2:  # budget not yet spent: readmitted, same rid
            assert [s.rid for s in out] == [rid]
            (t,) = out
        else:      # the third displacement spent the cumulative budget
            assert out == []
    assert cp.span_stats["livelock_dropped"] == 1
    assert cp.span_stats["max_req_attempts"] == 3
    led = cp.conservation()
    assert led["ok"] and led["dropped"] == 1 and led["active"] == 0
    assert all(cp.cut_residual[e] == pytest.approx(cp.cut_base[e])
               for e in cp.cut_base)
    cp.check_invariants()


def test_attempts_admitted_counted_once():
    """Accounting regression: attempts is counted once per
    _try_place_spanning entry, admitted once per 2PC commit — neither is
    double-counted between the pump drain and the broker interface."""
    rg, cp = _regional()
    rid = cp.submit("a", _spanning_df(cp))
    cp.pump()
    assert cp.span_stats["attempts"] == 1 and cp.span_stats["admitted"] == 1
    cp.release(rid)
    (u, v) = max(cp.cut_base, key=cp.cut_base.get)
    huge = float(np.sum(rg.cap)) + 1.0
    cp.submit("a", DataflowPath.make([0.0, huge, 0.0], [1.0, 1.0], u, v))
    cp.pump()  # a failing attempt counts attempts but not admitted
    assert cp.span_stats["attempts"] == 2 and cp.span_stats["admitted"] == 1
    cp.check_invariants()


# ---------------------------------------------------------------------------
# cut-ledger coherence regressions (fail/restore, half-dead spans)
# ---------------------------------------------------------------------------


def test_cut_fail_restore_idempotent_and_restores_full_residual():
    """Double fail / double restore of a cut under a standing span: the
    teardown returns the cut bandwidth exactly once, the healed cut
    reappears with its full base residual in both directions, and the
    displaced request is readmitted."""
    rg, cp = _line_plane(3)
    df = DataflowPath.make([0.0, 0.2, 0.2, 0.0], [1.0] * 3,
                           src=0, dst=rg.n - 1)
    rid = cp.submit("a", df)
    (t,) = cp.pump()
    e = t.cuts[0]
    cp.fail_link(*e)
    cp.fail_link(*e)  # idempotent: nothing left to displace or return
    cp.check_invariants()
    assert all(-1e-6 <= cp.cut_residual[c] <= cp.cut_base[c] + 1e-6
               for c in cp.cut_base)
    assert cp.cut_residual[e] == pytest.approx(cp.cut_base[e])
    assert not cp.cut_link_up[e]
    assert cp._region_chain(0, 2) is None  # quotient graph partitioned
    cp.restore_link(*e)
    cp.restore_link(*e)  # idempotent
    assert cp.cut_link_up[e] and cp.cut_link_up[(e[1], e[0])]
    assert cp.cut_residual[e] == pytest.approx(cp.cut_base[e])
    out = cp.pump()
    assert [s.rid for s in out] == [rid]
    cp.check_invariants()


def test_restore_never_failed_cut_does_not_inflate_residual():
    """restore_link on a healthy cut carrying a live reservation must be
    a no-op on the ledger: residual stays base - reserved (a heal never
    mints bandwidth)."""
    rg, cp = _line_plane(2)
    (u, v) = max(cp.cut_base, key=cp.cut_base.get)
    cp.submit("a", DataflowPath.make([0.1, 0.1], [1.0], u, v))
    (t,) = cp.pump()
    before = dict(cp.cut_residual)
    cp.restore_link(*t.cut)
    assert cp.cut_residual == before
    assert cp.cut_residual[t.cut] == pytest.approx(cp.cut_base[t.cut] - 1.0)
    cp.check_invariants()


def test_half_dead_span_fail_link_returns_cut_bandwidth_once():
    """A region silently losing its segment (placer-level release, no
    broker hand-off) followed by a cut failure: the span teardown must
    return the cut bandwidth exactly once — residual == base, never
    above it."""
    rg, cp = _line_plane(2)
    (u, v) = max(cp.cut_base, key=cp.cut_base.get)
    cp.submit("a", DataflowPath.make([0.1, 0.1], [1.0], u, v))
    (t,) = cp.pump()
    part = t.parts[0]
    cp.regions[part.region].placer.release(part.tid, reason=None)
    cp.fail_link(*t.cut)
    assert cp.cut_residual[t.cut] == pytest.approx(cp.cut_base[t.cut])
    assert all(cp.cut_residual[c] <= cp.cut_base[c] + 1e-6
               for c in cp.cut_base)
    cp.check_invariants()
    assert cp.conservation()["ok"]


def test_release_of_displaced_request_raises_like_centralized():
    """release() of a rid that was displaced back to a queue (not
    active) is a caller bug and raises KeyError — the same contract as
    the centralized plane — and must not corrupt the ledger."""
    rg, cp = _line_plane(2)
    (u, v) = max(cp.cut_base, key=cp.cut_base.get)
    rid = cp.submit("a", DataflowPath.make([0.1, 0.1], [1.0], u, v))
    (t,) = cp.pump()
    cp.fail_link(*t.cut)
    with pytest.raises(KeyError):
        cp.release(rid)
    led = cp.conservation()
    assert led["ok"] and led["queued"] == 1
    cp.restore_link(*t.cut)
    out = cp.pump()
    assert [s.rid for s in out] == [rid]
    cp.check_invariants()
