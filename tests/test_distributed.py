"""Decentralized shard_map engine: multi-device equivalence (subprocess with
8 host devices so the main test process keeps its single-device world)."""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.core import SimConfig, leastcost_python, paper_example, simulate
from repro.core.distributed import leastcost_shard_map


def test_shard_map_single_device_matches_python():
    rg, df = paper_example()
    m1, st = leastcost_shard_map(rg, df)
    m2, _ = leastcost_python(rg, df)
    assert m1 is not None and m2 is not None
    assert abs(m1.cost - m2.cost) < 1e-4
    assert st.supersteps >= 1
    assert st.messages_total > 0


def test_shard_map_multi_device_subprocess():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        assert jax.device_count() == 8
        from repro.core import leastcost_python, random_dataflow, waxman
        from repro.core.distributed import leastcost_shard_map

        for seed in range(6):
            rg = waxman(26, seed=seed)
            df = random_dataflow(rg, 6, seed=seed + 11)
            m1, st = leastcost_shard_map(rg, df)
            m2, _ = leastcost_python(rg, df)
            assert (m1 is None) == (m2 is None), seed
            if m1 is not None:
                assert abs(m1.cost - m2.cost) < 1e-3, (seed, m1.cost, m2.cost)
                assert st.messages_cross_device >= 0
        print("SHARDMAP_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env)
    assert "SHARDMAP_OK" in p.stdout, p.stderr[-2000:]


def test_message_reduction_vs_exact_flooding():
    """The decentralized LeastCostMap sends orders of magnitude fewer
    messages than exhaustive flooding on the same instance (§3.4.1)."""
    from repro.core import waxman, random_dataflow

    rg = waxman(20, seed=3)
    df = random_dataflow(rg, 5, seed=14)
    m_ex, st_ex = simulate(rg, df, SimConfig(policy="exact", max_messages=2_000_000))
    m_lc, st_lc = simulate(rg, df, SimConfig(policy="leastcost"))
    if m_ex is None:
        pytest.skip("infeasible instance")
    assert m_lc is not None
    assert st_lc.messages_sent * 5 < st_ex.messages_sent
